"""repro.obs: metrics registry thread-safety, span tracing and the
admit->flush->plan->kernel->ci tree, kernel profiling, checkpoint round-trip
of metrics state, and the zero-overhead-when-disabled contract."""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import AqpQuery, Range
from repro.data import TelemetryStore
from repro.obs import MetricsRegistry, Tracer


@pytest.fixture
def enabled():
    """Enable obs for one test with a fresh tracer; restore prior state."""
    prev_tracer = obs.set_tracer(Tracer())
    was = obs.enabled()
    obs.enable()
    yield obs.get_tracer()
    if not was:
        obs.disable()
    obs.set_tracer(prev_tracer)


def _store(rng, n=20_000, capacity=512):
    store = TelemetryStore(capacity=capacity, seed=0)
    a = rng.normal(0, 1, n).astype(np.float32)
    b = (0.8 * a + 0.6 * rng.normal(0, 1, n)).astype(np.float32)
    store.add_batch({"a": a, "b": b})
    return store


# --- registry: correctness under concurrency ---------------------------------

def test_counters_concurrent_increments_no_loss():
    reg = MetricsRegistry()
    n_threads, per = 8, 10_000
    barrier = threading.Barrier(n_threads)

    def work(ti):
        barrier.wait()
        for i in range(per):
            # re-resolve through the registry each time: the lookup path is
            # part of what must be thread-safe, not just Counter.inc
            reg.counter("t.hits", thread="shared").inc()
            reg.histogram("t.lat", thread="shared").observe(float(i % 100))
            reg.gauge("t.peak", thread="shared").max(float(i))

    threads = [threading.Thread(target=work, args=(ti,))
               for ti in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("t.hits", thread="shared").value == n_threads * per
    h = reg.histogram("t.lat", thread="shared")
    assert h.count == n_threads * per
    assert h.summary()["max"] == 99.0
    assert reg.gauge("t.peak", thread="shared").value == per - 1


def test_histogram_summary_and_percentiles():
    h = MetricsRegistry().histogram("h")
    for v in (10.0, 20.0, 30.0, 40.0, 1000.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 5 and s["sum"] == 1100.0
    assert s["min"] == 10.0 and s["max"] == 1000.0
    # bucketed percentiles land within the enclosing 1-2-5 decade bucket
    assert 10.0 <= s["p50"] <= 50.0
    assert s["p99"] <= 1000.0          # clamped to the observed max


def test_registry_state_roundtrip_exact():
    reg = MetricsRegistry()
    reg.counter("c", k="x").inc(7)
    reg.gauge("g").set(3.5)
    for v in (5.0, 50.0, 500.0):
        reg.histogram("h", path="range1d").observe(v)
    fresh = MetricsRegistry()
    fresh.load_state(reg.state())
    assert fresh.counter("c", k="x").value == 7
    assert fresh.gauge("g").value == 3.5
    assert fresh.histogram("h", path="range1d").summary() == \
        reg.histogram("h", path="range1d").summary()


# --- tracing ------------------------------------------------------------------

def test_span_nesting_and_tree_with_fake_clock():
    clock = [0.0]
    tr = Tracer(clock=lambda: clock[0])
    with tr.span("root", job="q1") as root:
        clock[0] = 1.0
        with tr.span("child_a"):
            clock[0] = 2.0
        with tr.span("child_b"):
            clock[0] = 5.0
    tree = tr.tree(root.trace_id)
    assert len(tree) == 1 and tree[0]["name"] == "root"
    kids = tree[0]["children"]
    assert [k["name"] for k in kids] == ["child_a", "child_b"]
    assert kids[0]["duration_us"] == pytest.approx(1e6)
    assert kids[1]["duration_us"] == pytest.approx(3e6)
    assert tree[0]["duration_us"] == pytest.approx(5e6)
    assert tree[0]["attrs"] == {"job": "q1"}


def test_explicit_parent_links_across_threads():
    tr = Tracer()
    with tr.span("submit") as sub:
        ctx = sub.ctx
    out = {}

    def other_thread():
        with tr.span("flush", parent=ctx) as f:
            out["trace"], out["parent"] = f.trace_id, f.parent_id

    t = threading.Thread(target=other_thread)
    t.start()
    t.join()
    assert out["trace"] == sub.trace_id and out["parent"] == sub.span_id


def test_disabled_span_is_shared_noop():
    was = obs.enabled()
    obs.disable()
    try:
        s = obs.span("anything", attr=1)
        assert s is obs.NOOP_SPAN and s.ctx is None
        with s as inner:
            assert inner is s
    finally:
        if was:
            obs.enable()


def test_span_tree_reconstructs_admission_to_kernel_path(enabled, rng):
    """One traced query yields the full tree: admission.submit ->
    admission.flush -> engine.run_compiled -> {engine.plan, engine.kernel,
    engine.ci}, with the path recorded on the kernel span."""
    tracer = enabled
    store = _store(rng)
    engine = store.engine()
    engine.execute([AqpQuery("count", (Range("a", -1.0, 1.0),))])  # warm
    tracer.clear()
    with store.session(watermark=None, max_delay=None,
                       auto_flush=False) as sess:
        fut = sess.submit(AqpQuery("count", (Range("a", -0.5, 0.5),)))
        sess.flush()
        fut.result(timeout=10)
    submit = [s for s in tracer.spans() if s.name == "admission.submit"]
    assert len(submit) == 1
    tree = tracer.tree(submit[0].trace_id)
    assert [n["name"] for n in tree] == ["admission.submit"]
    flush = tree[0]["children"]
    assert [n["name"] for n in flush] == ["admission.flush"]
    assert flush[0]["attrs"]["reason"] == "manual"
    run = flush[0]["children"]
    assert [n["name"] for n in run] == ["engine.run_compiled"]
    names = [n["name"] for n in run[0]["children"]]
    assert names[0] == "engine.plan"
    assert "engine.kernel" in names and "engine.ci" in names
    kernel = next(n for n in run[0]["children"]
                  if n["name"] == "engine.kernel")
    assert kernel["attrs"]["path"] == "range1d"
    assert kernel["duration_us"] >= 0.0


# --- kernel profiling ---------------------------------------------------------

def test_kernel_profiling_records_fenced_timings(enabled, rng):
    from repro.kernels import ops, tuning

    x = rng.normal(0, 1, 256).astype(np.float32)
    pts = np.linspace(-1, 1, 32, dtype=np.float32)
    before = obs.get_registry().sum_counter("kernel.calls",
                                            kernel="kde_eval")
    ops.kde_eval(pts, x, np.float32(0.3))
    reg = obs.get_registry()
    assert reg.sum_counter("kernel.calls", kernel="kde_eval") == before + 1
    rows = tuning.measured("kde_eval")
    assert rows and rows[0]["kernel"] == "kde_eval"
    assert rows[0]["count"] >= 1 and rows[0]["max"] > 0.0


# --- durability: metrics ride the PR-5 checkpoint ----------------------------

def test_metrics_state_survives_checkpoint_roundtrip(rng, tmp_path):
    store = _store(rng)
    store.query([AqpQuery("count", (Range("a", -1.0, 1.0),))])
    ingested = store.metrics.sum_counter("aqp.ingest.rows", column="a")
    misses = store.metrics.sum_counter("aqp.cache.misses")
    assert ingested == 20_000 and misses >= 1
    store.save(tmp_path)
    loaded = TelemetryStore.load(tmp_path)
    assert loaded.metrics.sum_counter("aqp.ingest.rows",
                                      column="a") == ingested
    assert loaded.metrics.sum_counter("aqp.cache.misses") == misses
    # restored counters keep counting (no frozen snapshot semantics)
    loaded.add_batch({"a": rng.normal(0, 1, 100).astype(np.float32)})
    assert loaded.metrics.sum_counter("aqp.ingest.rows",
                                      column="a") == ingested + 100


# --- the zero-overhead-when-disabled contract --------------------------------

def test_disabled_mode_no_extra_jit_traces_and_bit_identity(rng):
    from repro.core.aqp import batch_query_1d

    assert not obs.enabled()
    store = _store(rng)
    engine = store.engine()
    specs = [AqpQuery("count", (Range("a", -1.0, 1.0),)),
             AqpQuery("avg", (Range("b", -0.5, 1.5),), target="b")]
    want = engine.execute(specs)
    traces = batch_query_1d._cache_size()
    # steady state: repeating the workload disabled adds no traces
    again = engine.execute(specs)
    assert batch_query_1d._cache_size() == traces
    # enabling obs must not re-trace either (same jitted callables), and
    # estimates stay bit-identical — instrumentation reads, never perturbs
    prev_tracer = obs.set_tracer(Tracer())
    obs.enable()
    try:
        instrumented = engine.execute(specs)
    finally:
        obs.disable()
        obs.set_tracer(prev_tracer)
    assert batch_query_1d._cache_size() == traces
    for w, a, i in zip(want, again, instrumented):
        assert w.estimate == a.estimate == i.estimate
        assert w.ci_lo == a.ci_lo == i.ci_lo
        assert w.path == a.path == i.path


def test_disabled_overhead_is_noise_level():
    """Micro-benchmark: a disabled span + fence is one predicate check and
    a shared no-op object — sub-microsecond territory.  The bound is set an
    order of magnitude above that so scheduler noise can't flake it."""
    import time

    assert not obs.enabled()
    n = 100_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("noop", attr=1):
            pass
        obs.fence(None)
    per_ns = (time.perf_counter() - t0) / n * 1e9
    assert per_ns < 10_000, f"disabled span+fence costs {per_ns:.0f} ns/op"


def test_disabled_admission_counters_still_live(rng):
    """Counters/gauges are always-on (they back stats()); only spans,
    latency histograms, and fencing gate on enabled()."""
    assert not obs.enabled()
    store = _store(rng)
    with store.session(watermark=None, max_delay=None,
                       auto_flush=False) as sess:
        sess.submit(AqpQuery("count", (Range("a", -1.0, 1.0),)))
        sess.flush()
        st = sess.stats()
        assert st["submitted"] == 1 and st["flushes"] == 1
    # but the gated latency histogram stayed empty
    assert store.metrics.sum_histogram("aqp.query.latency_us")[1] == 0
    assert store.metrics.sum_histogram("aqp.admission.flush_us")[1] == 0


# --- export -------------------------------------------------------------------

def test_export_json_merges_registries(tmp_path):
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    r1.counter("a.hits", kind="store").inc(3)
    r2.histogram("k.wall", kernel="kde").observe(12.0)
    path = tmp_path / "m.json"
    doc = obs.export_json(str(path), r1, r2, extra={"mode": "test"})
    on_disk = json.loads(path.read_text())
    assert on_disk == json.loads(json.dumps(doc))
    assert on_disk["mode"] == "test" and "ts" in on_disk
    assert on_disk["counters"]["a.hits"] == [
        {"labels": {"kind": "store"}, "value": 3}]
    (entry,) = on_disk["histograms"]["k.wall"]
    assert entry["labels"] == {"kernel": "kde"} and entry["count"] == 1
