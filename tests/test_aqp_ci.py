"""Empirical confidence-interval coverage (core/aqp_ci.py) — the PR's
acceptance criterion: over 350+ synthetic range/box/GROUP BY/QMC queries
against 200k-row ground truth, the 95% CI reported by every non-exact
execution path must cover the truth at a rate inside [90%, 99%], exact paths
must report zero-width intervals, and exact:cm must report a bounded-error
interval that always contains the truth.

The windows are placed where kernel-smoothing bias is small relative to the
reservoir sampling error the CIs quantify (band and half-line windows, not
narrow mode-centred ones): the analytic/subsample CIs capture *sampling*
variance only, which is the documented contract (docs/aqp.md).  Seeds are
fixed — this is a statistical acceptance test, deterministic by design."""
import numpy as np
import pytest

from repro.core import AqpQuery, Box, Eq, GroupBy, Range
from repro.data import TelemetryStore

N = 200_000
N_SEEDS = 8

# window placement: |smoothing bias| << sampling SE (see module docstring)
COUNT_WINDOWS = [(0.0, 3.0), (-3.0, 0.0), (0.4, 1.8), (-1.8, -0.4),
                 (0.25, 2.2), (-2.2, -0.25)]
SUM_WINDOWS = [(0.4, 1.8), (-1.8, -0.4), (0.25, 2.2), (-2.2, -0.25),
               (-2.5, 2.5)]
BOXES = [((0.0, -6.0), (3.0, 6.0)), ((-6.0, 0.0), (6.0, 3.0)),
         ((0.4, -6.0), (1.8, 6.0)), ((-6.0, 0.25), (6.0, 2.2))]
GROUP_WINDOWS = [(0.0, 3.0), (-1.8, -0.4)]
QMC_BOXES = [((0.0, -6.0), (3.0, 6.0)), ((-6.0, 0.0), (6.0, 3.0)),
             ((0.4, -6.0), (1.8, 6.0)), ((-2.2, -6.0), (-0.25, 6.0)),
             ((0.0, 0.0), (1.5, 1.5)), ((0.25, -1.0), (2.2, 1.0))]


def _one_seed(seed):
    """All four non-exact paths against one independent 200k-row dataset;
    returns {path: [(covered, result, truth), ...]}."""
    rng = np.random.default_rng(9000 + seed)
    x = rng.normal(0, 1, N).astype(np.float32)
    y = (0.6 * x + 0.8 * rng.normal(0, 1, N)).astype(np.float32)
    code = rng.integers(0, 4, N).astype(np.float32)

    store = TelemetryStore(capacity=1024, seed=seed)
    store.track_joint(("x", "y"))
    store.add_batch({"x": x, "y": y})
    # smaller reservoir: LSCV full-H fits are O(m^2), and the grouped path's
    # per-group effective sample should dominate the dictionary smoothing
    small = TelemetryStore(capacity=256, seed=seed)
    small.track_joint(("x", "y"))
    small.track_joint(("code", "x"))
    small.add_batch({"x": x, "y": y, "code": code})

    events = {"range1d": [], "box": [], "box:grouped": [], "qmc": []}

    specs, truths = [], []
    for col, data in (("x", x), ("y", y)):
        for a, b in COUNT_WINDOWS:
            specs.append(AqpQuery("count", (Range(col, a, b),)))
            truths.append(float(((data > a) & (data <= b)).sum()))
        for a, b in SUM_WINDOWS:
            specs.append(AqpQuery("sum", (Range(col, a, b),), target=col))
            truths.append(float(data[(data > a) & (data <= b)].sum()))
    for lo, hi in BOXES:
        m = (x > lo[0]) & (x <= hi[0]) & (y > lo[1]) & (y <= hi[1])
        specs.append(AqpQuery("count", (Box(("x", "y"), lo, hi),)))
        truths.append(float(m.sum()))
        specs.append(AqpQuery("sum", (Box(("x", "y"), lo, hi),), target="y"))
        truths.append(float(y[m].sum()))
    for r, t in zip(store.query(specs), truths):
        assert r.path in ("range1d", "box"), r.path
        events[r.path].append((r.ci_lo <= t <= r.ci_hi, r, t))

    gspecs, gtruths = [], []
    for a, b in GROUP_WINDOWS:
        gspecs.append(AqpQuery("count", (Range("x", a, b),),
                               group_by=GroupBy("code",
                                                values=(0., 1., 2., 3.))))
        gtruths.append({g: float(((code == g) & (x > a) & (x <= b)).sum())
                        for g in (0., 1., 2., 3.)})
    rows = iter(small.query(gspecs))
    for gt in gtruths:
        for _ in range(4):
            r = next(rows)
            assert r.path == "box:grouped", r.path
            events["box:grouped"].append(
                (r.ci_lo <= gt[r.group] <= r.ci_hi, r, gt[r.group]))

    qspecs, qtruths = [], []
    for lo, hi in QMC_BOXES:
        m = (x > lo[0]) & (x <= hi[0]) & (y > lo[1]) & (y <= hi[1])
        qspecs.append(AqpQuery("count", (Box(("x", "y"), lo, hi),),
                               selector="lscv_H"))
        qtruths.append(float(m.sum()))
    for r, t in zip(small.query(qspecs), qtruths):
        assert r.path == "qmc", r.path
        events["qmc"].append((r.ci_lo <= t <= r.ci_hi, r, t))
    return events


@pytest.fixture(scope="module")
def coverage_events():
    total = {}
    for seed in range(N_SEEDS):
        for path, ev in _one_seed(seed).items():
            total.setdefault(path, []).extend(ev)
    return total


def _coverage(events):
    return sum(c for c, _, _ in events) / len(events)


def test_workload_is_large_enough(coverage_events):
    assert sum(len(v) for v in coverage_events.values()) >= 200


@pytest.mark.parametrize("path,min_events", [
    ("range1d", 160), ("box", 60), ("box:grouped", 60), ("qmc", 40)])
def test_ci_coverage_within_band(coverage_events, path, min_events):
    """95% CIs behave like 95% CIs: neither permissive (under-coverage would
    mean the reported intervals lie) nor vacuous (100% coverage would mean
    they are uselessly wide)."""
    events = coverage_events[path]
    assert len(events) >= min_events
    cov = _coverage(events)
    assert 0.90 <= cov <= 0.99, f"{path}: coverage {cov:.3f} of {len(events)}"


def test_ci_fields_are_well_formed(coverage_events):
    """Every non-exact result carries a finite, ordered interval around its
    estimate at the default 95% level, with the effective sample reported."""
    for path, events in coverage_events.items():
        for _, r, _ in events:
            assert np.isfinite(r.ci_lo) and np.isfinite(r.ci_hi), (path, r)
            assert r.ci_lo <= r.estimate <= r.ci_hi, (path, r)
            assert r.ci_level == 0.95
            assert r.n_effective > 0


# --- exact paths: zero-width / bounded-error intervals ------------------------

def test_exact_path_reports_zero_width_and_exact_truth(rng):
    store = TelemetryStore(capacity=512, seed=0)
    store.track_categorical("code")
    code = rng.integers(0, 4, 50_000).astype(np.float32)
    store.add_batch({"code": code})
    for g in (0.0, 1.0, 2.0, 3.0):
        r = store.query([AqpQuery("count", (Eq("code", g),))])[0]
        assert r.path == "exact"
        truth = float((code == g).sum())
        assert r.estimate == truth                    # exact, not approximate
        assert r.ci_lo == r.estimate == r.ci_hi       # zero-width interval
        assert r.rel_width == 0.0
        assert r.n_effective == 50_000


def test_exact_cm_reports_bounded_interval_containing_truth(rng):
    """Count-min over-counts by at most the sketch's error bound: the
    reported interval [est - err, est] must contain the truth, with width
    bounded by depth * err_bound."""
    store = TelemetryStore(capacity=512, seed=0)
    store.track_categorical("wide", kind="cm")
    values = rng.integers(0, 5_000, 100_000).astype(np.float32)
    store.add_batch({"wide": values})
    sketch = store.categoricals["wide"]
    for c in (0.0, 137.0, 4_999.0):
        r = store.query([AqpQuery("count", (Eq("wide", c),))])[0]
        assert r.path == "exact:cm"
        truth = float((values == c).sum())
        assert r.ci_lo <= truth <= r.ci_hi
        assert truth <= r.estimate == r.ci_hi         # over-count only
        assert r.ci_hi - r.ci_lo <= sketch.depth * sketch.err_bound()
        assert r.rel_width == 0.0
