"""Dry-run machinery on a reduced placeholder mesh (subprocess so the main
test process keeps its single CPU device).  The full 512-device sweep is run
by `python -m repro.launch.dryrun --all [--multi-pod]` (EXPERIMENTS.md)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow      # jit-heavy: excluded from tier-1

CELLS = [
    ("llama3.2-1b", "train_4k", "4,2"),
    ("granite-moe-1b-a400m", "decode_32k", "4,2"),
    ("falcon-mamba-7b", "long_500k", "2,2,2"),     # multi-pod axes
    ("whisper-base", "prefill_32k", "2,2,2"),
]


@pytest.mark.parametrize("arch,shape,mesh", CELLS)
def test_dryrun_cell_small_mesh(arch, shape, mesh, tmp_path):
    out = tmp_path / "dry.jsonl"
    env = dict(os.environ, PYTHONPATH="src",
               REPRO_DRYRUN_DEVICES=str(eval(mesh.replace(",", "*"))))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh-shape", mesh, "--out", str(out)],
        capture_output=True, text=True, cwd="/root/repo", timeout=560, env=env)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["ok"], rec
    assert rec["hlo_dot_flops_per_dev"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert rec["memory"]["temp_gb_per_dev"] > 0


def test_long_500k_skips_full_attention():
    from repro.configs.base import cell_runnable
    ok, why = cell_runnable("llama3.2-1b", "long_500k")
    assert not ok and "full-attention" in why
    ok, _ = cell_runnable("falcon-mamba-7b", "long_500k")
    assert ok
    ok, _ = cell_runnable("zamba2-1.2b", "long_500k")
    assert ok
