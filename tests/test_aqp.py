"""AQP queries on KDE synopses (paper §4.3, eqs. 9-11): closed form vs
quadrature, accuracy vs exact answers, invariants, mergeability."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # hypothesis is optional: property tests skip below
    HAVE_HYPOTHESIS = False

from repro.core import (KDESynopsis, count_1d, count_1d_numeric, count_box_diag,
                        sum_1d, sum_1d_numeric)
from repro.data import TelemetryStore


def test_closed_form_equals_quadrature(rng):
    x = jnp.asarray(rng.normal(0, 2, 500).astype(np.float32))
    h = jnp.float32(0.3)
    a, b = jnp.float32(-1.0), jnp.float32(2.5)
    assert float(count_1d(x, h, a, b)) == pytest.approx(
        float(count_1d_numeric(x, h, a, b)), rel=1e-3)
    assert float(sum_1d(x, h, a, b)) == pytest.approx(
        float(sum_1d_numeric(x, h, a, b)), rel=2e-3)


def test_count_accuracy_vs_exact(rng):
    data = rng.normal(10.0, 3.0, 20000).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=2048)
    for a, b in [(7.0, 13.0), (4.0, 10.0), (12.0, 20.0)]:
        approx = float(syn.count(a, b))
        exact = float(((data >= a) & (data <= b)).sum())
        assert approx == pytest.approx(exact, rel=0.08), (a, b)


def test_sum_avg_accuracy(rng):
    data = rng.gamma(4.0, 2.0, 20000).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=2048)
    sel = (data >= 5.0) & (data <= 12.0)
    assert float(syn.sum(5.0, 12.0)) == pytest.approx(float(data[sel].sum()), rel=0.12)
    assert float(syn.avg(5.0, 12.0)) == pytest.approx(float(data[sel].mean()), rel=0.05)


def _check_count_bounds_and_monotonicity(seed, b):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, 256).astype(np.float32))
    h = jnp.float32(0.4)
    c1 = float(count_1d(x, h, jnp.float32(-10.0), jnp.float32(b)))
    c2 = float(count_1d(x, h, jnp.float32(-10.0), jnp.float32(b + 0.5)))
    assert -1e-3 <= c1 <= 256 * (1 + 1e-4)
    assert c2 >= c1 - 1e-4                       # monotone in the upper bound


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 50), b=st.floats(-1.0, 3.0))
    def test_count_bounds_and_monotonicity(seed, b):
        _check_count_bounds_and_monotonicity(seed, b)
else:
    @pytest.mark.parametrize("seed,b", [(0, -1.0), (17, 0.3), (50, 3.0)])
    def test_count_bounds_and_monotonicity(seed, b):
        _check_count_bounds_and_monotonicity(seed, b)


def test_multid_box_count(rng):
    data = rng.normal(0, 1, (8000, 2)).astype(np.float32)
    h = jnp.asarray([0.15, 0.15], jnp.float32)
    approx = float(count_box_diag(jnp.asarray(data), h,
                                  jnp.asarray([-1.0, -1.0], jnp.float32),
                                  jnp.asarray([1.0, 1.0], jnp.float32)))
    exact = float(((np.abs(data) <= 1.0).all(axis=1)).sum())
    assert approx == pytest.approx(exact, rel=0.08)


def test_lscv_H_synopsis_box(rng):
    data = rng.normal(0, 1, (3000, 2)).astype(np.float32)
    data[:, 1] = 0.5 * data[:, 0] + 0.9 * data[:, 1]
    syn = KDESynopsis.fit(jnp.asarray(data), selector="lscv_H", max_sample=512)
    approx = float(syn.count_box([-1.0, -1.0], [1.0, 1.0]))
    inbox = ((data >= -1) & (data <= 1)).all(axis=1).sum()
    assert approx == pytest.approx(float(inbox), rel=0.2)


def test_telemetry_store_and_merge(rng):
    s1 = TelemetryStore(capacity=512, seed=1)
    s2 = TelemetryStore(capacity=512, seed=2)
    a = rng.normal(0, 1, 4000).astype(np.float32)
    b = rng.normal(2, 1, 4000).astype(np.float32)
    s1.add_batch({"loss": a})
    s2.add_batch({"loss": b})
    merged = s1.merge(s2)
    frac = merged.fraction("loss", -10.0, 1.0, selector="silverman")
    exact = float((np.concatenate([a, b]) <= 1.0).mean())
    assert frac == pytest.approx(exact, abs=0.08)
    assert merged.columns["loss"].n_seen == 8000


# --- deterministic closed-form + batched-engine tests ----------------------

def test_closed_forms_vs_trapezoid_of_kde_eval(rng):
    """eqs. 9-10 closed forms vs direct trapezoid quadrature of kde_eval."""
    from repro.core import kde_eval

    x = jnp.asarray(rng.normal(1.0, 1.5, 400).astype(np.float32))
    h = jnp.float32(0.35)
    for a, b in [(-2.0, 0.5), (0.0, 4.0), (-6.0, 6.0)]:
        grid = jnp.linspace(a, b, 2001)
        f = kde_eval(grid, x, h)
        n = x.shape[0]
        want_count = n * float(jnp.trapezoid(f, grid))
        want_sum = n * float(jnp.trapezoid(grid * f, grid))
        assert float(count_1d(x, h, jnp.float32(a), jnp.float32(b))) == \
            pytest.approx(want_count, rel=1e-4), (a, b)
        assert float(sum_1d(x, h, jnp.float32(a), jnp.float32(b))) == \
            pytest.approx(want_sum, rel=1e-4, abs=1e-3), (a, b)


def test_degenerate_ranges(rng):
    x = jnp.asarray(rng.normal(0, 1, 300).astype(np.float32))
    h = jnp.float32(0.4)
    # a == b: zero-measure range (sum_1d may carry fp32 roundoff from the
    # two-term Phi/phi cancellation, so approx rather than exact zero)
    assert float(count_1d(x, h, jnp.float32(0.7), jnp.float32(0.7))) == 0.0
    assert float(sum_1d(x, h, jnp.float32(0.7), jnp.float32(0.7))) == \
        pytest.approx(0.0, abs=1e-6)
    # empty intersection: range far outside the support
    assert float(count_1d(x, h, jnp.float32(50.0), jnp.float32(60.0))) == \
        pytest.approx(0.0, abs=1e-4)
    assert float(sum_1d(x, h, jnp.float32(50.0), jnp.float32(60.0))) == \
        pytest.approx(0.0, abs=1e-3)


def test_avg_of_degenerate_range_is_finite(rng):
    data = rng.normal(0, 1, 5000).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=512)
    assert np.isfinite(float(syn.avg(0.3, 0.3)))
    assert np.isfinite(float(syn.avg(80.0, 90.0)))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_batched_engine_matches_query_loop(rng, backend):
    from repro.core import Query, QueryBatch

    data = rng.gamma(4.0, 2.0, 30000).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=1024)
    ops = ["count", "sum", "avg"]
    lo, hi = float(data.min()), float(data.max())
    queries = []
    for i in range(1001):                 # >= 1000, non-multiple of tile sizes
        a = float(rng.uniform(lo, hi))
        queries.append(Query(ops[i % 3], a, float(rng.uniform(a, hi))))
    queries.append(Query("count", 5.0, 5.0))          # degenerate
    queries.append(Query("avg", hi + 10, hi + 20))    # empty intersection

    got = QueryBatch(queries).run(syn, backend=backend)
    fns = {"count": syn.count, "sum": syn.sum, "avg": syn.avg}
    want = np.asarray([float(fns[q.op](q.a, q.b)) for q in queries])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_query_batch_groups_columns(rng):
    from repro.core import Query, QueryBatch

    d1 = rng.normal(0, 1, 8000).astype(np.float32)
    d2 = rng.normal(5, 2, 8000).astype(np.float32)
    synopses = {
        "a": KDESynopsis.fit(jnp.asarray(d1), selector="plugin", max_sample=512),
        "b": KDESynopsis.fit(jnp.asarray(d2), selector="plugin", max_sample=512),
    }
    queries = [Query("count", -1, 1, column="a"), Query("sum", 3, 7, column="b"),
               Query("avg", -2, 0, column="a"), Query("count", 4, 6, column="b")]
    batch = QueryBatch(queries)
    assert sorted(batch.columns) == ["a", "b"]
    got = batch.run(synopses)
    for q, ans in zip(queries, got):
        syn = synopses[q.column]
        want = float({"count": syn.count, "sum": syn.sum, "avg": syn.avg}[q.op](q.a, q.b))
        assert ans == pytest.approx(want, rel=1e-5, abs=1e-5)


def test_query_rejects_unknown_op():
    from repro.core import Query

    with pytest.raises(ValueError):
        Query("median", 0.0, 1.0)


def test_query_batch_rejects_column_tags_against_bare_synopsis(rng):
    from repro.core import Query, QueryBatch

    data = rng.normal(0, 1, 2000).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=256)
    with pytest.raises(ValueError, match="single synopsis"):
        QueryBatch([Query("count", 0, 1, column="latency")]).run(syn)
