"""AQP queries on KDE synopses (paper §4.3, eqs. 9-11): closed form vs
quadrature, accuracy vs exact answers, invariants, mergeability."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (KDESynopsis, count_1d, count_1d_numeric, count_box_diag,
                        sum_1d, sum_1d_numeric)
from repro.data import TelemetryStore


def test_closed_form_equals_quadrature(rng):
    x = jnp.asarray(rng.normal(0, 2, 500).astype(np.float32))
    h = jnp.float32(0.3)
    a, b = jnp.float32(-1.0), jnp.float32(2.5)
    assert float(count_1d(x, h, a, b)) == pytest.approx(
        float(count_1d_numeric(x, h, a, b)), rel=1e-3)
    assert float(sum_1d(x, h, a, b)) == pytest.approx(
        float(sum_1d_numeric(x, h, a, b)), rel=2e-3)


def test_count_accuracy_vs_exact(rng):
    data = rng.normal(10.0, 3.0, 20000).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=2048)
    for a, b in [(7.0, 13.0), (4.0, 10.0), (12.0, 20.0)]:
        approx = float(syn.count(a, b))
        exact = float(((data >= a) & (data <= b)).sum())
        assert approx == pytest.approx(exact, rel=0.08), (a, b)


def test_sum_avg_accuracy(rng):
    data = rng.gamma(4.0, 2.0, 20000).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=2048)
    sel = (data >= 5.0) & (data <= 12.0)
    assert float(syn.sum(5.0, 12.0)) == pytest.approx(float(data[sel].sum()), rel=0.12)
    assert float(syn.avg(5.0, 12.0)) == pytest.approx(float(data[sel].mean()), rel=0.05)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 50), b=st.floats(-1.0, 3.0))
def test_count_bounds_and_monotonicity(seed, b):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, 256).astype(np.float32))
    h = jnp.float32(0.4)
    c1 = float(count_1d(x, h, jnp.float32(-10.0), jnp.float32(b)))
    c2 = float(count_1d(x, h, jnp.float32(-10.0), jnp.float32(b + 0.5)))
    assert -1e-3 <= c1 <= 256 * (1 + 1e-4)
    assert c2 >= c1 - 1e-4                       # monotone in the upper bound


def test_multid_box_count(rng):
    data = rng.normal(0, 1, (8000, 2)).astype(np.float32)
    h = jnp.asarray([0.15, 0.15], jnp.float32)
    approx = float(count_box_diag(jnp.asarray(data), h,
                                  jnp.asarray([-1.0, -1.0], jnp.float32),
                                  jnp.asarray([1.0, 1.0], jnp.float32)))
    exact = float(((np.abs(data) <= 1.0).all(axis=1)).sum())
    assert approx == pytest.approx(exact, rel=0.08)


def test_lscv_H_synopsis_box(rng):
    data = rng.normal(0, 1, (3000, 2)).astype(np.float32)
    data[:, 1] = 0.5 * data[:, 0] + 0.9 * data[:, 1]
    syn = KDESynopsis.fit(jnp.asarray(data), selector="lscv_H", max_sample=512)
    approx = float(syn.count_box([-1.0, -1.0], [1.0, 1.0]))
    inbox = ((data >= -1) & (data <= 1)).all(axis=1).sum()
    assert approx == pytest.approx(float(inbox), rel=0.2)


def test_telemetry_store_and_merge(rng):
    s1 = TelemetryStore(capacity=512, seed=1)
    s2 = TelemetryStore(capacity=512, seed=2)
    a = rng.normal(0, 1, 4000).astype(np.float32)
    b = rng.normal(2, 1, 4000).astype(np.float32)
    s1.add_batch({"loss": a})
    s2.add_batch({"loss": b})
    merged = s1.merge(s2)
    frac = merged.fraction("loss", -10.0, 1.0, selector="silverman")
    exact = float((np.concatenate([a, b]) <= 1.0).mean())
    assert frac == pytest.approx(exact, abs=0.08)
    assert merged.columns["loss"].n_seen == 8000
