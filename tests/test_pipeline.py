"""SPMD pipeline parallelism: pipelined == sequential layer application."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow      # jit-heavy: excluded from tier-1


def test_pipeline_matches_sequential_subprocess():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.pipeline import spmd_pipeline

mesh = jax.make_mesh((4,), ("pipe",))
rng = np.random.default_rng(0)
n_stages, n_micro, mb, d = 4, 8, 2, 16
W = jnp.asarray(rng.normal(0, 0.5, (n_stages, d, d)).astype(np.float32))
b = jnp.asarray(rng.normal(0, 0.1, (n_stages, d)).astype(np.float32))
xs = jnp.asarray(rng.normal(0, 1, (n_micro, mb, d)).astype(np.float32))

def stage_fn(p, x):
    w, bias = p
    return jnp.tanh(x @ w + bias)

out = spmd_pipeline(stage_fn, (W, b), xs, mesh, "pipe")

# sequential reference
ref = xs
for s in range(n_stages):
    ref = jnp.tanh(ref @ W[s] + b[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)
print("PIPELINE_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd="/root/repo", timeout=420, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "PIPELINE_OK" in r.stdout
