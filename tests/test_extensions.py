"""Beyond-paper extensions: multi-start LSCV_H (paper §6.3's suggested
parallelisation) and the §4.2 alternative kernel functions."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import g_of_H, kde_eval, lscv_H, plugin_bandwidth


@pytest.mark.slow
def test_multistart_lscv_H_no_worse(rng):
    x = rng.normal(0, 1, (150, 2)).astype(np.float32)
    x[:, 1] = 0.7 * x[:, 0] + 0.7 * x[:, 1]
    single = lscv_H(jnp.asarray(x), max_iter=60)
    multi = lscv_H(jnp.asarray(x), max_iter=60, multi_start=4)
    assert float(multi.g) <= float(single.g) + 1e-7
    w = np.linalg.eigvalsh(np.asarray(multi.H, np.float64))
    assert (w > 0).all()
    assert int(multi.nfev) > int(single.nfev)       # really ran 4 instances


@pytest.mark.parametrize("kind", ["epanechnikov", "biweight", "triangular", "uniform"])
def test_alternative_kernels_integrate_to_one(rng, kind):
    x = jnp.asarray(rng.normal(0, 1, 800).astype(np.float32))
    h = plugin_bandwidth(x).h
    grid = np.linspace(-6, 6, 1200).astype(np.float32)
    f = np.asarray(kde_eval(jnp.asarray(grid), x, h, kind=kind))
    assert (f >= -1e-7).all()
    assert np.trapezoid(f, grid) == pytest.approx(1.0, abs=0.02)


def test_kernels_agree_on_smooth_density(rng):
    """Paper §4.2: 'selection of a particular kernel function is not
    critical' — all kernels give similar estimates at a shared (rescaled)
    bandwidth."""
    x = jnp.asarray(rng.normal(0, 1, 4000).astype(np.float32))
    h = float(plugin_bandwidth(x).h)
    grid = np.linspace(-3, 3, 100).astype(np.float32)
    fg = np.asarray(kde_eval(jnp.asarray(grid), x, jnp.float32(h)))
    # canonical rescale: Epanechnikov's equivalent bandwidth ~ 2.214x Gaussian
    fe = np.asarray(kde_eval(jnp.asarray(grid), x, jnp.float32(2.214 * h),
                             kind="epanechnikov"))
    assert np.abs(fg - fe).max() < 0.03


def test_multid_epanechnikov(rng):
    x = jnp.asarray(rng.normal(0, 1, (2000, 2)).astype(np.float32))
    pts = jnp.asarray(np.zeros((1, 2), np.float32))
    f = float(kde_eval(pts, x, jnp.float32(0.8), kind="epanechnikov")[0])
    # true N(0,I) density at origin = 1/(2 pi) ~ 0.159
    assert f == pytest.approx(0.159, abs=0.05)
