import zlib

import numpy as np
import pytest


@pytest.fixture()
def rng(request):
    """Per-test deterministic generator: seeding by test name decouples the
    data each test sees from which other tests ran (no suite-order flakes)."""
    seed = zlib.crc32(request.node.nodeid.encode()) & 0x7FFFFFFF
    return np.random.default_rng(seed)
