import zlib

import numpy as np
import pytest


def pytest_configure(config):
    # also declared in pytest.ini; registering here keeps the marker defined
    # when pytest is invoked with an explicit -c pointing elsewhere
    config.addinivalue_line(
        "markers", "slow: jit-heavy / long-running tests excluded from tier-1")


@pytest.fixture()
def rng(request):
    """Per-test deterministic generator: seeding by test name decouples the
    data each test sees from which other tests ran (no suite-order flakes)."""
    seed = zlib.crc32(request.node.nodeid.encode()) & 0x7FFFFFFF
    return np.random.default_rng(seed)
