"""Training substrate: loss decreases, microbatch equivalence, AdamW."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data import TokenPipeline
from repro.models import build_model
from repro.optim import adamw
from repro.train import make_train_step

pytestmark = pytest.mark.slow      # jit-heavy: excluded from tier-1


def test_loss_decreases_on_tiny_model():
    cfg = get_config("llama3.2-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt_state = adamw.init(params)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40)
    step = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    pipe = TokenPipeline(cfg.vocab_size, 8, 32, seed=0)
    losses = []
    for _ in range(50):
        batch = pipe.next()
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-10:])
    assert last < first - 0.35, (losses[:3], losses[-3:])
    assert np.isfinite(losses).all()


def test_microbatch_grads_equivalent():
    """n_micro=1 and n_micro=4 take (numerically) the same step."""
    cfg = get_config("llama3.2-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    pipe = TokenPipeline(cfg.vocab_size, 8, 32, seed=1)
    batch = pipe.next()

    p1, _, m1 = jax.jit(make_train_step(model, opt_cfg, 1))(params, adamw.init(params), batch)
    p4, _, m4 = jax.jit(make_train_step(model, opt_cfg, 4))(params, adamw.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=3e-2, atol=3e-3)


def test_adamw_schedule_and_clip():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, grad_clip=1.0)
    assert float(adamw.schedule(cfg, 0)) == 0.0
    assert float(adamw.schedule(cfg, 10)) == pytest.approx(1.0)
    assert float(adamw.schedule(cfg, 100)) == pytest.approx(cfg.min_lr_frac, rel=1e-3)
    params = {"w": jnp.ones((4,), jnp.float32)}
    grads = {"w": jnp.full((4,), 100.0, jnp.float32)}
    state = adamw.init(params)
    new_p, state, metrics = adamw.update(cfg, grads, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    assert np.isfinite(np.asarray(new_p["w"])).all()


def test_pipeline_determinism_and_resume():
    p1 = TokenPipeline(100, 4, 16, seed=7)
    seq = [np.asarray(p1.next()["tokens"]) for _ in range(5)]
    p2 = TokenPipeline(100, 4, 16, seed=7)
    for _ in range(3):
        p2.next()
    state = p2.state()
    p3 = TokenPipeline(100, 4, 16, seed=7)
    p3.restore(state)
    np.testing.assert_array_equal(np.asarray(p3.next()["tokens"]), seq[3])
    np.testing.assert_array_equal(np.asarray(p3.next()["tokens"]), seq[4])


def test_pipeline_host_sharding_disjoint():
    a = TokenPipeline(1000, 8, 16, seed=3, host_id=0, n_hosts=2)
    b = TokenPipeline(1000, 8, 16, seed=3, host_id=1, n_hosts=2)
    ba, bb = np.asarray(a.next()["tokens"]), np.asarray(b.next()["tokens"])
    assert ba.shape == bb.shape == (4, 16)
    assert not np.array_equal(ba, bb)     # different host slices
