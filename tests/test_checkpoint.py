"""Checkpointing: roundtrip, atomicity, keep-k, async, reshard-restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": {"w": jax.random.normal(k, (8, 16), jnp.float32)},
            "b": jnp.arange(10, dtype=jnp.int32),
            "c": jax.random.normal(k, (4,), jnp.bfloat16)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = _tree()
    mgr.save(5, tree, {"step": 5, "pipeline": {"step": 5, "seed": 0}})
    assert mgr.latest_step() == 5
    restored, extra = mgr.restore(5, jax.eval_shape(lambda: tree))
    assert extra["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(7, _tree())
    mgr.wait()
    assert mgr.latest_step() == 7


def test_atomicity_tmp_dirs_invisible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    os.makedirs(os.path.join(str(tmp_path), "tmp.99"), exist_ok=True)  # crashed write
    mgr.save(1, _tree())
    assert mgr.all_steps() == [1]           # tmp.* never surfaces


def test_reshard_restore(tmp_path):
    """Save unsharded, restore with explicit shardings (elastic-rescale path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(1, tree)
    mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = mgr.restore(1, jax.eval_shape(lambda: tree), shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


@pytest.mark.slow
def test_driver_restart_resumes(tmp_path):
    """Full crash/restart loop through the training driver (subprocess)."""
    import subprocess
    import sys
    env = dict(os.environ, PYTHONPATH="src")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "llama3.2-1b",
           "--smoke", "--steps", "12", "--batch", "4", "--seq", "32",
           "--ckpt-dir", str(tmp_path), "--ckpt-every", "4",
           "--simulate-failure-at", "6", "--log-every", "100"]
    r1 = subprocess.run(cmd, capture_output=True, text=True, cwd="/root/repo",
                        timeout=420, env=env)
    assert r1.returncode == 42, r1.stderr[-1500:]
    cmd_resume = [c for c in cmd if c not in ("--simulate-failure-at", "6")]
    r2 = subprocess.run(cmd_resume, capture_output=True, text=True,
                        cwd="/root/repo", timeout=420, env=env)
    assert r2.returncode == 0, r2.stderr[-1500:]
    assert "resumed from step" in r2.stdout
    assert "done" in r2.stdout
