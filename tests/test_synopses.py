"""Pluggable density-synopsis backends (repro.synopses) and their engine
integration: registry protocol, RFF convergence to the exact full-H KDE,
the Pallas feature-map kernel, the accuracy gate's exact fallback, checkpoint
round-trips, and the exact path's bit-identity guarantee."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.kde import kde_eval_H


def _joint_data(rng, n):
    loss = rng.gamma(2.0, 1.5, n)
    lat = 10 + 3 * loss + rng.normal(0, 2, n)
    return np.stack([loss, lat], 1).astype(np.float32)


def _fullh_store(rng, n, h_scale, capacity=None):
    """Store with one joint reservoir plus a hand-built full-H synopsis in
    the cache (selector label "lscv_H", no O(n^2) fit)."""
    from repro.core import KDESynopsis
    from repro.data import TelemetryStore

    x = _joint_data(rng, n)
    store = TelemetryStore(capacity=capacity or n, seed=0)
    store.track_joint(("loss", "latency_ms"))
    store.add_batch({"loss": x[:, 0], "latency_ms": x[:, 1]})
    res = store.joints[("loss", "latency_ms")]
    xs = res.sample()
    H = (np.cov(xs.T) * h_scale).astype(np.float32)
    syn = KDESynopsis(x=jnp.asarray(xs), H=jnp.asarray(H),
                      n_source=res.n_seen, selector="lscv_H")
    store.cache.put(("loss", "latency_ms"), "lscv_H", res.version, syn)
    return store, xs, H


def _box_queries(x, k=6, seed=3):
    from repro.core.aqp_query import AqpQuery, Box

    rng = np.random.default_rng(seed)
    mu, sd = x.mean(axis=0), x.std(axis=0)
    cols = ("loss", "latency_ms")
    out = []
    for i in range(k):
        lo = mu + sd * rng.uniform(-1.5, 0.0, 2)
        hi = lo + sd * rng.uniform(1.0, 2.5, 2)
        out.append(AqpQuery(["count", "sum", "avg"][i % 3],
                            (Box(cols, tuple(lo), tuple(hi)),),
                            target=None if i % 3 == 0 else cols[i % 2]))
    return out


# --- registry / protocol ---------------------------------------------------

def test_registry_exposes_builtin_backends():
    from repro import synopses

    assert {"exact", "rff"} <= set(synopses.available())
    assert synopses.get_backend("rff") is synopses.RFFSynopsis
    assert synopses.get_backend("exact") is synopses.ExactSynopsis
    with pytest.raises(KeyError):
        synopses.get_backend("nope")


def test_register_refuses_name_collision():
    from repro import synopses

    with pytest.raises(ValueError):
        @synopses.register("rff")
        class Impostor(synopses.DensitySynopsis):
            pass
    # re-registering the SAME class is an idempotent no-op (module reloads)
    synopses.register("rff")(synopses.RFFSynopsis)
    assert synopses.get_backend("rff") is synopses.RFFSynopsis


def test_protocol_base_raises_and_metadata(rng):
    from repro import synopses

    base = synopses.DensitySynopsis()
    with pytest.raises(NotImplementedError):
        base.eval_batch(np.zeros((3, 2)))
    with pytest.raises(NotImplementedError):
        base.to_state()
    assert base.nbytes == 0
    md = base.error_metadata()
    assert md["backend"] == "?" and md["degraded"] is False


def test_exact_backend_wraps_kde_eval_H(rng):
    from repro.synopses import ExactSynopsis

    x = _joint_data(rng, 500)
    H = np.cov(x.T).astype(np.float32) * 0.3
    syn = ExactSynopsis.fit(x, H)
    pts = x[:40]
    got = np.asarray(syn.eval_batch(pts))
    want = np.asarray(kde_eval_H(jnp.asarray(pts), jnp.asarray(x),
                                 jnp.asarray(H)))
    assert np.array_equal(got, want)
    assert syn.error_metadata()["exact"] is True
    assert syn.n_fitted == 500


# --- RFF backend -----------------------------------------------------------

@pytest.mark.parametrize("m,D,d", [(1, 16, 1), (7, 130, 3), (300, 64, 2)])
def test_rff_pallas_kernel_matches_oracle(rng, m, D, d):
    from repro.kernels import ops as kops
    from repro.kernels import ref

    p = rng.normal(0, 1, (m, d)).astype(np.float32)
    w = rng.normal(0, 1, (D, d)).astype(np.float32)
    b = rng.uniform(0, 2 * np.pi, D).astype(np.float32)
    z = rng.normal(0, 1, D).astype(np.float32)
    got = np.asarray(kops.rff_density(jnp.asarray(p), jnp.asarray(w),
                                      jnp.asarray(b), jnp.asarray(z)))
    want = np.asarray(ref.rff_density(jnp.asarray(p), jnp.asarray(w),
                                      jnp.asarray(b), jnp.asarray(z)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,d,h_scale", [(1500, 1, 1.0), (1500, 2, 0.5),
                                         (2500, 3, 1.0)])
def test_rff_converges_to_kde_with_features(rng, n, d, h_scale):
    """Pointwise density error shrinks as D grows; at D=2048 the fit sits
    inside the engine's gate tolerance for these bandwidths (~1/sqrt(D))."""
    from repro.synopses import RFFSynopsis

    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    x[:, 0] = rng.gamma(2.0, 1.0, n)          # non-Gaussian marginal
    H = (np.atleast_2d(np.cov(x.T)) * h_scale).astype(np.float32)
    probes = x[:64]
    f_exact = np.asarray(kde_eval_H(jnp.asarray(probes), jnp.asarray(x),
                                    jnp.asarray(H)), np.float64)
    denom = float(np.mean(f_exact))

    def rel(D):
        syn = RFFSynopsis.fit(x, H, n_features=D, seed=5)
        f = np.asarray(syn.eval_batch(probes), np.float64)
        return float(np.mean(np.abs(f - f_exact)) / denom)

    r_small, r_big = rel(128), rel(2048)
    assert r_big < 0.05, f"D=2048 rel err {r_big:.3f} exceeds gate headroom"
    # 16x the features should cut the error ~4x; allow generous slack for
    # the randomness of any single frequency draw
    assert r_big < 0.6 * r_small, (r_small, r_big)


def test_rff_fit_is_seed_deterministic(rng):
    from repro.synopses import RFFSynopsis

    x = _joint_data(rng, 800)
    H = np.cov(x.T).astype(np.float32) * 0.4
    a = RFFSynopsis.fit(x, H, n_features=256, seed=9)
    b = RFFSynopsis.fit(x, H, n_features=256, seed=9)
    c = RFFSynopsis.fit(x, H, n_features=256, seed=10)
    assert np.array_equal(np.asarray(a.w), np.asarray(b.w))
    assert np.array_equal(np.asarray(a.z), np.asarray(b.z))
    assert not np.array_equal(np.asarray(a.w), np.asarray(c.w))


def test_kde_chunk_env_override(rng, monkeypatch):
    """REPRO_KDE_CHUNK retunes kde_eval_H's eval chunking per call."""
    x = _joint_data(rng, 600)
    H = np.cov(x.T).astype(np.float32) * 0.4
    pts = jnp.asarray(x[:100])
    xj, Hj = jnp.asarray(x), jnp.asarray(H)
    monkeypatch.setenv("REPRO_KDE_CHUNK", "64")
    via_env = np.asarray(kde_eval_H(pts, xj, Hj))
    explicit = np.asarray(kde_eval_H(pts, xj, Hj, chunk=64))
    assert np.array_equal(via_env, explicit)
    monkeypatch.delenv("REPRO_KDE_CHUNK")
    default = np.asarray(kde_eval_H(pts, xj, Hj))
    np.testing.assert_allclose(via_env, default, rtol=1e-6)


# --- engine integration ----------------------------------------------------

def test_engine_rff_backend_within_ci_of_exact(rng):
    store, x, _H = _fullh_store(rng, 4000, h_scale=0.4)
    engine = store.engine(selector="lscv_H")
    queries = _box_queries(x)
    r_exact = engine.execute(queries, kde_backend="exact")
    r_rff = engine.execute(queries, kde_backend="rff")
    assert {r.path for r in r_exact} == {"qmc"}
    assert {r.path for r in r_rff} == {"qmc:rff"}
    scale_ref = max(abs(r.estimate) for r in r_exact)
    for re_, rr in zip(r_exact, r_rff):
        assert rr.ci_lo <= rr.estimate <= rr.ci_hi
        half = max((rr.ci_hi - rr.ci_lo) / 2.0, 0.02 * scale_ref)
        assert abs(rr.estimate - re_.estimate) <= 4.0 * half
    # per-backend hit counters moved with the traffic
    assert store.metrics.sum_counter("aqp.synopsis.hits", backend="rff") > 0
    assert store.metrics.sum_counter("aqp.synopsis.hits", backend="exact") > 0


def test_engine_exact_backend_bit_identical_to_default(rng):
    """backend="exact" must reproduce the legacy (pre-backend) answers bit
    for bit: same jitted pass, same reductions, no RFF anywhere near it."""
    store, x, _H = _fullh_store(rng, 3000, h_scale=0.4)
    engine = store.engine(selector="lscv_H")
    queries = _box_queries(x)
    base = np.asarray([r.estimate
                       for r in engine.execute(queries)])          # auto < crossover
    again = np.asarray([r.estimate
                        for r in engine.execute(queries, kde_backend="exact")])
    third = np.asarray([r.estimate
                        for r in engine.execute(queries, kde_backend="exact")])
    assert np.array_equal(base, again)
    assert np.array_equal(again, third)


def test_auto_crossover_picks_backend_by_size(rng, monkeypatch):
    from repro.core import aqp_query

    store, x, _H = _fullh_store(rng, 2000, h_scale=0.4)
    engine = store.engine(selector="lscv_H")
    queries = _box_queries(x, k=3)
    monkeypatch.setattr(aqp_query, "KDE_CROSSOVER", 10 ** 9)
    assert {r.path for r in engine.execute(queries)} == {"qmc"}
    monkeypatch.setattr(aqp_query, "KDE_CROSSOVER", 100)
    assert {r.path for r in engine.execute(queries)} == {"qmc:rff"}


def test_accuracy_gate_falls_back_and_counts(rng):
    """A bandwidth far too narrow for the default feature budget must trip
    the probe gate: answers stay on the exact path, the fallback counter
    moves, and the degraded fit is cached (no refit churn)."""
    store, x, _H = _fullh_store(rng, 3000, h_scale=0.002)
    engine = store.engine(selector="lscv_H")
    queries = _box_queries(x, k=3)
    r1 = engine.execute(queries, kde_backend="rff")
    assert {r.path for r in r1} == {"qmc"}          # exact answered
    fb1 = store.metrics.sum_counter("aqp.synopsis.fallback", backend="rff")
    assert fb1 >= 1
    fits1 = sum(h.count for _lbl, h in
                store.metrics.collect_histograms("aqp.synopsis.fit_us"))
    r2 = engine.execute(queries, kde_backend="rff")
    assert {r.path for r in r2} == {"qmc"}
    fb2 = store.metrics.sum_counter("aqp.synopsis.fallback", backend="rff")
    assert fb2 > fb1                                # degraded hit counted
    fits2 = sum(h.count for _lbl, h in
                store.metrics.collect_histograms("aqp.synopsis.fit_us"))
    assert fits2 == fits1                           # cached, not refitted


def test_rff_query_override_beats_engine_default(rng):
    store, x, _H = _fullh_store(rng, 2000, h_scale=0.4)
    engine = store.engine(selector="lscv_H")
    q = _box_queries(x, k=1)[0]
    from dataclasses import replace
    forced = replace(q, kde_backend="rff")
    assert engine.execute([q], kde_backend="exact")[0].path == "qmc"
    assert engine.execute([forced], kde_backend="exact")[0].path == "qmc:rff"
    with pytest.raises(ValueError):
        replace(q, kde_backend="warp")
    with pytest.raises(ValueError):
        store.engine(selector="lscv_H", kde_backend="warp")


# --- durability ------------------------------------------------------------

def test_rff_checkpoint_roundtrip_bit_identical(rng, tmp_path):
    """A fitted RFF synopsis persists through the store checkpoint and the
    restored copy reproduces densities — and engine answers — bit for bit."""
    from repro.data import TelemetryStore

    store, x, _H = _fullh_store(rng, 2500, h_scale=0.4)
    engine = store.engine(selector="lscv_H")
    queries = _box_queries(x, k=4)
    before = np.asarray([r.estimate
                         for r in engine.execute(queries, kde_backend="rff")])
    ckey = next(k for k, _v, s in store.cache.entries()
                if getattr(s, "backend", "") == "rff")
    rff = store.cache.peek(ckey[0], ckey[1],
                           store.joints[("loss", "latency_ms")].version)
    assert rff is not None

    store.save(str(tmp_path / "ck"))
    restored = TelemetryStore.load(str(tmp_path / "ck"))
    rff2 = restored.cache.peek(
        ckey[0], ckey[1], restored.joints[("loss", "latency_ms")].version)
    assert rff2 is not None and rff2.backend == "rff"
    for attr in ("w", "b", "z"):
        assert np.array_equal(np.asarray(getattr(rff, attr)),
                              np.asarray(getattr(rff2, attr)))
    assert rff2.norm == rff.norm and rff2.seed == rff.seed
    probes = jnp.asarray(x[:50])
    assert np.array_equal(np.asarray(rff.eval_batch(probes)),
                          np.asarray(rff2.eval_batch(probes)))
    engine2 = restored.engine(selector="lscv_H")
    after = np.asarray([
        r.estimate for r in engine2.execute(queries, kde_backend="rff")])
    assert np.array_equal(before, after)


def test_cache_sizes_rff_entries_by_own_nbytes(rng):
    from repro.synopses import RFFSynopsis
    from repro.data.aqp_store import _entry_nbytes

    x = _joint_data(rng, 400)
    H = np.cov(x.T).astype(np.float32) * 0.4
    syn = RFFSynopsis.fit(x, H, n_features=128, seed=0)
    # (W: 128x2 + b: 128 + z: 128) float32
    assert _entry_nbytes(syn) == syn.nbytes == 4 * (128 * 2 + 128 + 128)
