"""Async admission & micro-batch scheduling (core/aqp_admission.py):
bit-identical parity with the synchronous engine, watermark vs deadline vs
close flush triggers, out-of-order future resolution across buckets,
mid-flight synopsis-version invalidation, and the admission counters."""
import threading

import numpy as np
import pytest

from repro.core import AqpQuery, Box, Eq, GroupBy, Range
from repro.core.aqp_admission import (FLUSH_CLOSE, FLUSH_DEADLINE,
                                      FLUSH_MANUAL, FLUSH_WATERMARK)
from repro.data import TelemetryStore


def _store(rng, n=20_000, capacity=512, categorical=False):
    store = TelemetryStore(capacity=capacity, seed=0)
    store.track_joint(("a", "b"))
    store.track_joint(("code", "b"))
    if categorical:
        store.track_categorical("code")
    a = rng.normal(0, 1, n).astype(np.float32)
    b = (0.8 * a + 0.6 * rng.normal(0, 1, n)).astype(np.float32)
    code = rng.integers(0, 4, n).astype(np.float32)
    store.add_batch({"a": a, "b": b, "code": code})
    return store


def _manual_session(engine, **kw):
    """A session with no automatic flushing: everything is driven by
    explicit flush()/poll() so tests are deterministic."""
    kw.setdefault("watermark", None)
    kw.setdefault("max_delay", None)
    return engine.session(auto_flush=False, **kw)


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


# --- acceptance: bit-identical to the synchronous path -----------------------

def test_admission_bit_identical_to_execute(rng):
    """Every execution path — range1d, box, exact Eq, GROUP BY expansion,
    per-query selector override (qmc) — answers bit-identically to
    QueryEngine.execute for the same specs."""
    store = _store(rng, categorical=True)
    engine = store.engine()
    specs = [
        AqpQuery("count", (Range("a", -1.0, 1.0),)),
        AqpQuery("sum", (Range("b", -0.5, 2.0),), target="b"),
        AqpQuery("avg", (Box(("a", "b"), (-1.0, -1.0), (1.0, 1.0)),),
                 target="b"),
        AqpQuery("count", (Eq("code", 2.0),)),
        AqpQuery("count", (Range("a", -1.0, 1.0),), selector="lscv_H"),
        AqpQuery("count", (Range("b", -1.0, 1.0),),
                 group_by=GroupBy("code", values=(0.0, 1.0, 2.0, 3.0))),
    ]
    want = engine.execute(specs)
    with _manual_session(engine) as sess:
        futs = [sess.submit(q) for q in specs]
        assert sess.pending > 0 and not futs[0].done()
        sess.flush()
        got = []
        for f in futs:
            r = f.result(timeout=5)
            got.extend(r if isinstance(r, list) else [r])
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.estimate == w.estimate          # bit-identical
        assert g.path == w.path
        assert g.synopsis_version == w.synopsis_version
        assert g.rel_width == w.rel_width
        assert g.group == w.group


def test_session_execute_convenience_matches_engine(rng):
    store = _store(rng)
    engine = store.engine()
    specs = [AqpQuery("count", (Range("a", -1, 1),)),
             AqpQuery("avg", (Range("b", -1, 1),), target="b")]
    want = [r.estimate for r in engine.execute(specs)]
    with _manual_session(engine) as sess:
        got = [r.estimate for r in sess.execute(specs)]
    assert got == want


# --- flush triggers ----------------------------------------------------------

def test_watermark_flush_is_inline_and_scoped_to_bucket(rng):
    store = _store(rng)
    sess = store.session(watermark=3, max_delay=None, auto_flush=False)
    futs = [sess.submit(AqpQuery("count", (Range("a", -1, i),)))
            for i in range(2)]
    assert not any(f.done() for f in futs)       # below watermark: pending
    f3 = sess.submit(AqpQuery("count", (Range("a", -1, 2),)))
    assert f3.done() and all(f.done() for f in futs)
    st = sess.stats()
    assert st["flush_reasons"] == {FLUSH_WATERMARK: 1}
    assert st["mean_batch"] == 3.0 and st["coalesced"] == 3
    sess.close()


def test_deadline_flush_via_poll_with_fake_clock(rng):
    store = _store(rng)
    clock = FakeClock()
    sess = store.session(watermark=None, max_delay=1.0, auto_flush=False,
                         time_fn=clock)
    fut = sess.submit(AqpQuery("count", (Range("a", -1, 1),)))
    assert sess.poll() == 0 and not fut.done()   # deadline not reached
    clock.now = 0.5
    assert sess.poll() == 0 and not fut.done()
    clock.now = 1.0
    assert sess.poll() == 1 and fut.done()
    assert sess.stats()["flush_reasons"] == {FLUSH_DEADLINE: 1}
    sess.close()


def test_deadline_flush_runs_on_next_unrelated_submit(rng):
    """A bucket past its deadline must flush when ANY later submit() arrives,
    even for an unrelated bucket — admissions are the natural poll points, so
    a ticket never waits on the flusher thread (or a manual poll) once fresh
    traffic proves the clock has advanced."""
    store = _store(rng)
    clock = FakeClock()
    sess = store.session(watermark=None, max_delay=1.0, auto_flush=False,
                         time_fn=clock)
    stale = sess.submit(AqpQuery("count", (Range("a", -1, 1),)))
    clock.now = 0.5
    sess.submit(AqpQuery("count", (Range("b", -1, 1),)))
    assert not stale.done()                      # deadline not reached yet
    clock.now = 1.2                              # "a" bucket now past deadline
    fresh = sess.submit(AqpQuery("count", (Range("b", -2, 2),)))
    assert stale.done()                          # flushed by unrelated submit
    assert not fresh.done()                      # "b" deadline is still ahead
    assert sess.stats()["flush_reasons"] == {FLUSH_DEADLINE: 1}
    sess.close()


def test_flush_on_close_resolves_everything(rng):
    store = _store(rng)
    sess = store.session(watermark=None, max_delay=None, auto_flush=False)
    futs = [sess.submit(AqpQuery("count", (Range(c, -1, 1),)))
            for c in ("a", "b", "a")]
    assert not any(f.done() for f in futs)
    sess.close()
    assert all(f.done() for f in futs)
    st = sess.stats()
    assert st["pending"] == 0
    assert st["flush_reasons"] == {FLUSH_CLOSE: 2}   # one per bucket
    with pytest.raises(RuntimeError, match="closed"):
        sess.submit(AqpQuery("count", (Range("a", -1, 1),)))
    sess.close()                                  # idempotent


def test_out_of_order_future_resolution(rng):
    """A later-submitted bucket hitting its watermark resolves before an
    earlier bucket that is still below watermark."""
    store = _store(rng)
    sess = store.session(watermark=2, max_delay=None, auto_flush=False)
    first = sess.submit(AqpQuery("count", (Range("a", -1, 1),)))
    b1 = sess.submit(AqpQuery("count", (Range("b", -1, 1),)))
    b2 = sess.submit(AqpQuery("count", (Range("b", -2, 2),)))
    assert b1.done() and b2.done()               # bucket "b" hit watermark
    assert not first.done()                      # bucket "a" still pending
    sess.flush()
    assert first.done()
    st = sess.stats()
    assert st["flush_reasons"] == {FLUSH_WATERMARK: 1, FLUSH_MANUAL: 1}
    sess.close()


# --- priority classes --------------------------------------------------------

def _tiered_store(rng, n=20_000, capacity=512, n_tiers=4):
    store = TelemetryStore(capacity=capacity, seed=0)
    store.track_tiered("a", n_tiers=n_tiers)
    store.add_batch({"a": rng.normal(0, 1, n).astype(np.float32)})
    return store


def test_priority_classes_map_to_tier_budgets(rng):
    """"coarse" answers from the smallest tier (fewer effective rows, wider
    CI), "full" from the complete reservoir — bit-identical to the plain
    synchronous engine — and the two classes never share a micro-batch."""
    store = _tiered_store(rng)
    engine = store.engine()
    spec = AqpQuery("count", (Range("a", -1.0, 1.0),))
    want = engine.execute(spec)[0]
    with _manual_session(engine) as sess:
        f_coarse = sess.submit(spec, priority="coarse")
        f_full = sess.submit(spec)               # default_priority == "full"
        assert sess.pending == 2                 # distinct tier-keyed buckets
        sess.flush()
        coarse, full = f_coarse.result(timeout=5), f_full.result(timeout=5)
        st = sess.stats()
    assert full.estimate == want.estimate        # full == untiered, bit-exact
    assert full.ci_lo == want.ci_lo and full.ci_hi == want.ci_hi
    assert coarse.n_effective == 512 >> 3        # tier-0 sample
    assert full.n_effective == 512
    assert coarse.ci_width > full.ci_width       # less data -> wider interval
    assert st["flush_reasons"] == {FLUSH_MANUAL: 2}   # one per class bucket
    assert st["priorities"] == {"coarse": 1, "full": 1}


def test_priority_validation_and_custom_classes(rng):
    store = _tiered_store(rng, n=2000, capacity=256)
    engine = store.engine()
    with _manual_session(engine) as sess:
        with pytest.raises(ValueError, match="unknown priority"):
            sess.submit(AqpQuery("count", (Range("a", -1, 1),)),
                        priority="turbo")
        assert sess.pending == 0
    with pytest.raises(ValueError, match="default_priority"):
        engine.session(priority_tiers={"full": None}, default_priority="fast",
                       auto_flush=False)
    with _manual_session(engine, priority_tiers={"fast": 1, "exactish": None},
                         default_priority="fast") as sess:
        fut = sess.submit(AqpQuery("count", (Range("a", -1, 1),)))
        sess.flush()
        assert fut.result(timeout=5).n_effective == 256 >> 2  # tier 1 of 4
        assert sess.stats()["priorities"] == {"fast": 1}


# --- version invalidation ----------------------------------------------------

def test_version_bump_rekeys_in_flight_batch(rng):
    """add_batch between submit and flush: the pending micro-batch is
    re-keyed to the new synopsis version and answers match a fresh
    synchronous execute bit-for-bit (never the stale synopsis)."""
    store = _store(rng)
    engine = store.engine()
    spec = AqpQuery("count", (Range("a", -1.0, 1.0),))
    v0 = store.columns["a"].version
    with _manual_session(engine) as sess:
        fut = sess.submit(spec)
        store.add_batch({"a": rng.normal(3, 1, 4000).astype(np.float32)})
        assert store.columns["a"].version > v0
        sess.flush()
        got = fut.result(timeout=5)
    want = engine.execute(spec)[0]
    assert got.synopsis_version == store.columns["a"].version
    assert got.estimate == want.estimate
    assert sess.stats()["invalidations"] == 1


def test_abandoned_session_is_collectable(rng):
    """A session dropped without close() must not be pinned by the store's
    listener list or by its own flusher thread: the subscription holds only
    a weakref and the flusher re-checks liveness every tick."""
    import gc
    import time as _time
    import weakref

    store = _store(rng, n=2000, capacity=256)
    sess = store.session(watermark=None, max_delay=0.01)   # starts no thread
    fut = sess.submit(AqpQuery("count", (Range("a", -1, 1),)))  # starts it
    fut.result(timeout=10)
    ref = weakref.ref(sess)
    del sess, fut
    gc.collect()
    deadline = _time.monotonic() + 5.0
    while ref() is not None and _time.monotonic() < deadline:
        _time.sleep(0.1)                    # flusher tick drops its ref
        gc.collect()
    assert ref() is None
    # the dead session's listener removes itself on the next notification
    store.add_batch({"a": np.zeros(4, np.float32)})
    assert store._listeners == []


def test_unsubscribed_after_close(rng):
    store = _store(rng)
    sess = store.session(watermark=None, max_delay=None, auto_flush=False)
    sess.close()
    assert store._listeners == []


# --- concurrency -------------------------------------------------------------

def test_concurrent_clients_all_resolve_and_match_sync(rng):
    """8 closed-loop client threads against one auto-flushing session: every
    future resolves and every answer equals the synchronous path."""
    store = _store(rng)
    engine = store.engine()
    n_clients, per_client = 8, 6
    specs = {ci: [AqpQuery("count",
                           (Range("a" if (ci + i) % 2 else "b",
                                  -2.0 + 0.1 * i, 0.5 * ci),))
                  for i in range(per_client)]
             for ci in range(n_clients)}
    flat = [q for ci in range(n_clients) for q in specs[ci]]
    want = engine.answers(flat)
    got = {}
    lock = threading.Lock()
    with engine.session(watermark=4, max_delay=0.002) as sess:
        def client(ci):
            mine = [sess.submit(q).result(timeout=30) for q in specs[ci]]
            with lock:
                got[ci] = [r.estimate for r in mine]
        threads = [threading.Thread(target=client, args=(ci,))
                   for ci in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        st = sess.stats()
    flat_got = [est for ci in range(n_clients) for est in got[ci]]
    np.testing.assert_array_equal(flat_got, want)
    assert st["executed"] == n_clients * per_client
    assert st["flushes"] >= 1


# --- validation & bookkeeping ------------------------------------------------

def test_submit_raises_synchronously_on_bad_specs(rng):
    store = _store(rng)
    with _manual_session(store.engine()) as sess:
        with pytest.raises(KeyError, match="unknown column"):
            sess.submit(AqpQuery("count", (Range("missing", 0, 1),)))
        with pytest.raises(KeyError, match="track_joint"):
            sess.submit(AqpQuery("count", (Range("a", 0, 1),
                                           Range("code", 0, 1))))
        assert sess.pending == 0


def test_session_param_validation(rng):
    store = _store(rng, n=2000, capacity=256)
    with pytest.raises(ValueError, match="watermark"):
        store.session(watermark=0)
    with pytest.raises(ValueError, match="max_delay"):
        store.session(max_delay=-1.0)


def test_store_stats_aggregate_admission_counters(rng):
    store = _store(rng)
    s1 = store.session(watermark=None, max_delay=None, auto_flush=False)
    s2 = store.session(watermark=None, max_delay=None, auto_flush=False)
    s1.submit(AqpQuery("count", (Range("a", -1, 1),)))
    s1.flush()
    s2.submit(AqpQuery("count", (Range("b", -1, 1),)))
    agg = store.stats()["admission"]
    assert agg["sessions"] == 2
    assert agg["submitted"] == 2 and agg["executed"] == 1
    assert agg["pending"] == 1
    assert agg["flush_reasons"] == {FLUSH_MANUAL: 1}
    s1.close()
    s2.close()
    assert store.stats()["admission"]["pending"] == 0


def test_store_stats_aggregate_two_sessions_flushing_concurrently(rng):
    """Regression for the multi-session aggregation bug: two sessions
    flushing from their own threads must aggregate without losing counts,
    and the totals must survive session close + garbage collection (the
    counters live in the store's registry, not on the session object)."""
    import gc

    store = _store(rng)
    engine = store.engine()
    # warm both columns so the timed loop below never jit-compiles
    engine.execute([AqpQuery("count", (Range("a", -1, 1),)),
                    AqpQuery("count", (Range("b", -1, 1),))])
    sessions = [store.session(watermark=None, max_delay=None,
                              auto_flush=False) for _ in range(2)]
    n_each = 6
    errs = []

    def work(si):
        col = "ab"[si]
        try:
            for i in range(n_each):
                fut = sessions[si].submit(
                    AqpQuery("count", (Range(col, -1.0, 0.1 * i),)))
                sessions[si].flush()
                fut.result(timeout=10)
        except Exception as e:              # surfaced after join
            errs.append(e)

    threads = [threading.Thread(target=work, args=(si,)) for si in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    agg = store.stats()["admission"]
    assert agg["sessions"] == 2
    assert agg["submitted"] == agg["executed"] == 2 * n_each
    assert agg["flush_reasons"] == {FLUSH_MANUAL: 2 * n_each}
    assert agg["pending"] == 0
    # closed + gc'd sessions used to vanish from the totals entirely
    while sessions:
        sessions.pop().close()
    gc.collect()
    agg = store.stats()["admission"]
    assert agg["sessions"] == 0
    assert agg["submitted"] == agg["executed"] == 2 * n_each
    assert agg["flush_reasons"] == {FLUSH_MANUAL: 2 * n_each}
    assert agg["pending"] == 0


# --- backpressure: the max_pending bound (ROADMAP follow-up) -----------------

def test_max_pending_shed_raises_and_counts(rng):
    from repro.core import AdmissionFull

    store = _store(rng, n=2000, capacity=256)
    with _manual_session(store.engine(), max_pending=2,
                         overflow="shed") as sess:
        futs = [sess.submit(AqpQuery("count", (Range("a", 0.0, float(i)),)))
                for i in range(2)]
        with pytest.raises(AdmissionFull, match="max_pending=2"):
            sess.submit(AqpQuery("count", (Range("a", 0.0, 9.0),)))
        st = sess.stats()
        assert st["shed"] == 1 and st["max_pending"] == 2
        assert st["submitted"] == 2                  # shed spec not admitted
        sess.flush()
        for f in futs:
            f.result(timeout=10)
        sess.submit(AqpQuery("count", (Range("a", 0.0, 9.0),)))   # room again
        assert sess.stats()["shed"] == 1


def test_max_pending_block_parks_until_flush_frees_room(rng):
    store = _store(rng, n=2000, capacity=256)
    sess = _manual_session(store.engine(), max_pending=2, overflow="block")
    sess.submit(AqpQuery("count", (Range("a", -1.0, 1.0),)))
    sess.submit(AqpQuery("count", (Range("a", -2.0, 2.0),)))
    got = []

    def blocked_submit():
        got.append(sess.submit(
            AqpQuery("count", (Range("a", -3.0, 3.0),))).result(timeout=30))

    t = threading.Thread(target=blocked_submit)
    t.start()
    t.join(timeout=0.2)
    assert t.is_alive()                      # parked at the bound
    assert sess.stats()["blocked"] == 1
    sess.flush()                             # frees room -> submit proceeds
    for _ in range(100):
        if sess.pending:
            break
        t.join(timeout=0.05)
    sess.flush()                             # flush the unblocked submit
    t.join(timeout=10)
    assert not t.is_alive() and len(got) == 1
    assert got[0].estimate == store.engine().execute(
        [AqpQuery("count", (Range("a", -3.0, 3.0),))])[0].estimate
    sess.close()


def test_max_pending_oversized_ticket_admitted_on_empty_queue(rng):
    """A GROUP BY spec whose compiled parts alone exceed max_pending must be
    admitted once the queue is empty — not shed or parked forever."""
    store = _store(rng, n=2000, capacity=256, categorical=True)
    with _manual_session(store.engine(), max_pending=2,
                         overflow="shed") as sess:
        fut = sess.submit(AqpQuery(
            "count", (Range("b", -5.0, 5.0),),
            group_by=GroupBy("code", values=(0.0, 1.0, 2.0, 3.0))))
        assert sess.pending == 4             # > max_pending, admitted anyway
        assert sess.stats()["shed"] == 0
        sess.flush()
        assert len(fut.result(timeout=10)) == 4


def test_max_pending_param_validation_and_close_unblocks(rng):
    store = _store(rng, n=2000, capacity=256)
    with pytest.raises(ValueError, match="max_pending"):
        store.session(max_pending=0)
    with pytest.raises(ValueError, match="overflow"):
        store.session(overflow="drop")

    sess = _manual_session(store.engine(), max_pending=1, overflow="block")
    sess.submit(AqpQuery("count", (Range("a", -1.0, 1.0),)))
    errs = []

    def blocked_submit():
        try:
            sess.submit(AqpQuery("count", (Range("a", -2.0, 2.0),)))
        except RuntimeError as exc:
            errs.append(exc)

    t = threading.Thread(target=blocked_submit)
    t.start()
    t.join(timeout=0.2)
    assert t.is_alive()
    sess.close()                             # close() wakes parked submitters
    t.join(timeout=10)
    assert not t.is_alive() and len(errs) == 1
    assert "closed" in str(errs[0])


def test_store_stats_aggregate_backpressure_counters(rng):
    from repro.core import AdmissionFull

    store = _store(rng, n=2000, capacity=256)
    sess = _manual_session(store.engine(), max_pending=1, overflow="shed")
    sess.submit(AqpQuery("count", (Range("a", -1.0, 1.0),)))
    with pytest.raises(AdmissionFull):
        sess.submit(AqpQuery("count", (Range("a", -2.0, 2.0),)))
    agg = store.stats()["admission"]
    assert agg["shed"] == 1 and agg["blocked"] == 0
    sess.close()


# --- fit offload: slow first fits must not stall the flusher ------------------

def test_fit_offload_requeues_and_resolves(rng):
    """With fit_offload=True, a due bucket whose lscv_H synopsis is not
    cached hands the O(n^2) fit to a worker thread: poll() flushes nothing
    inline, the ticket counts as fit_requeued, and the worker re-flushes
    the bucket (reason "fit") once the synopsis lands — resolving the
    future to the same answer the synchronous engine gives."""
    from repro.core.aqp_admission import FLUSH_FIT

    store = _store(rng, n=256, capacity=256)
    engine = store.engine()
    sess = _manual_session(engine, max_delay=0.0, fit_offload=True)
    q = AqpQuery("count", (Box(("a", "b"), (-1.0, -1.0), (1.0, 1.0)),),
                 selector="lscv_H")
    fut = sess.submit(q)
    assert sess.poll() == 0                      # offloaded, not flushed
    assert sess.fit_requeued == 1
    r = fut.result(timeout=60)
    want = engine.execute([q])[0]                # synopsis now cached
    assert r.estimate == want.estimate and r.path == want.path
    st = sess.stats()
    assert st["flush_reasons"].get(FLUSH_FIT) == 1
    assert st["fit_requeued"] == 1
    # fit spans were recorded for the offloaded fit
    assert store.metrics.sum_counter("aqp.admission.fit_requeued") == 1
    # second ticket on the same key: synopsis cached -> the due bucket
    # flushes inline (submit's opportunistic deadline pass), no new requeue
    fut2 = sess.submit(AqpQuery(
        "count", (Box(("a", "b"), (-2.0, -2.0), (0.0, 0.0)),),
        selector="lscv_H"))
    sess.poll()
    assert fut2.done()
    assert sess.fit_requeued == 1                # no new requeue
    sess.close()
    assert store.stats()["admission"]["fit_requeued"] == 1


def test_fit_offload_disabled_by_default_and_fast_selectors_inline(rng):
    """Without the opt-in, a due lscv_H bucket flushes inline (the old
    behaviour); with it, fast selectors are never offloaded."""
    store = _store(rng, n=256, capacity=256)
    sess = _manual_session(store.engine(), max_delay=0.0)
    fut = sess.submit(AqpQuery("count", (Range("a", -1.0, 1.0),),
                               selector="lscv_H"))
    sess.poll()
    assert fut.done()                            # flushed inline, fit and all
    assert sess.fit_requeued == 0
    sess.close()
    sess2 = _manual_session(store.engine(), max_delay=0.0, fit_offload=True)
    fut2 = sess2.submit(AqpQuery("count", (Range("a", -1.0, 1.0),)))
    sess2.poll()
    assert fut2.done()                           # default selector: inline
    assert sess2.fit_requeued == 0
    sess2.close()
