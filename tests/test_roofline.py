"""Roofline HLO cost model: trip-count correction + collective parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat, roofline


def test_trip_count_correction_on_scan():
    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(scanned).lower(x, w).compile()
    raw = compat.cost_analysis_dict(compiled).get("flops")
    model = roofline.HloCostModel(compiled.as_text())
    corrected = model.dot_flops()
    one_matmul = 2 * 128 ** 3
    assert raw < 1.5 * one_matmul                    # XLA counts body once
    assert corrected == pytest.approx(10 * one_matmul, rel=0.01)


def test_nested_scan_multiplies():
    def nested(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(nested).lower(x, w).compile()
    model = roofline.HloCostModel(compiled.as_text())
    assert model.dot_flops() == pytest.approx(15 * 2 * 64 ** 3, rel=0.01)


def test_collective_bytes_parse():
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import roofline
mesh = jax.make_mesh((8,), ("d",))
x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
sh_x = NamedSharding(mesh, P("d", None))
sh_w = NamedSharding(mesh, P("d", None))   # FSDP weight -> all-gather expected
f = jax.jit(lambda x, w: (x @ w).sum(), in_shardings=(sh_x, sh_w))
compiled = f.lower(x, w).compile()
m = roofline.HloCostModel(compiled.as_text())
total, by_kind = m.collective_bytes()
assert total > 0, by_kind
assert any("all-" in k or "reduce" in k for k in by_kind), by_kind
print("COLL_OK", by_kind)
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd="/root/repo", timeout=300, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "COLL_OK" in r.stdout


def test_model_flops_accounting():
    from repro.configs.base import SHAPES_BY_NAME, get_config
    cfg = get_config("llama3.2-1b")
    tr = roofline.model_flops(cfg, SHAPES_BY_NAME["train_4k"])
    # 6 * ~1.24B params * 1.05M tokens ~ 7.8e15, + attention terms
    assert 5e15 < tr < 3e16
    dec = roofline.model_flops(cfg, SHAPES_BY_NAME["decode_32k"])
    assert dec < tr / 1000

    moe = get_config("qwen3-moe-235b-a22b")
    tm = roofline.model_flops(moe, SHAPES_BY_NAME["train_4k"])
    # active params (~22B), not total (235B), drive the roofline
    assert tm < 6 * moe.param_count() * 4096 * 256 / 5


def test_terms_dominance():
    t = roofline.terms(flops=1e18, hbm=1e12, coll_bytes_per_chip=1e9, chips=256)
    assert t["dominant"] == "compute"
    t = roofline.terms(flops=1e15, hbm=1e15, coll_bytes_per_chip=1e9, chips=256)
    assert t["dominant"] == "memory"
