"""The static-check suite checks itself: per-checker fixture corpora (bad
code flagged, good code silent, pragma'd code counted as allowed), pragma
hygiene, the knob registry's typed accessors, the check.py CLI contract,
the repo-wide zero-violation gate, and an 8-thread stress test asserting
the guarded-by annotations on TelemetryStore match its actual runtime
behaviour under concurrent ingest + snapshot + query traffic."""
import json
import subprocess
import sys
import textwrap
import threading
from pathlib import Path

import numpy as np
import pytest

from repro import knobs
from repro.analysis import (CHECKERS, Project, host_sync, instrument_drift,
                            kernel_contract, knob_registry, lock_discipline,
                            run, run_all, runner)
from repro.core import AqpQuery, Range
from repro.data import TelemetryStore

REPO = Path(__file__).resolve().parents[1]


def make_project(tmp_path, files, roots=("src",)):
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text), encoding="utf-8")
    return Project(tmp_path, roots)


def messages(violations):
    return [v.message for v in violations]


# --- lock-discipline ---------------------------------------------------------

BAD_LOCKS = """\
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.items = []            # guarded-by: _lock
            self.totals = {}           # guarded-by: _lock (writes)

        def bad_read(self):
            return len(self.items)

        def bad_write(self):
            self.totals["x"] = 1

        def bad_closure(self):
            with self._lock:
                def peek():
                    return self.items[0]
                return peek
"""


def test_lock_discipline_flags_unlocked_access(tmp_path):
    project = make_project(tmp_path, {"src/repro/c.py": BAD_LOCKS})
    out = lock_discipline.check(project)
    msgs = "\n".join(messages(out))
    assert len(out) == 3
    assert "self.items accessed in bad_read()" in msgs
    assert "self.totals accessed in bad_write()" in msgs
    # the closure may outlive the with-block: held locks do not leak in
    assert "self.items accessed in bad_closure.peek()" in msgs


def test_lock_discipline_good_patterns_are_silent(tmp_path):
    project = make_project(tmp_path, {"src/repro/c.py": """\
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self._cv = threading.Condition(self._lock)
                self.items = []            # guarded-by: _lock
                self.totals = {}           # guarded-by: _lock (writes)

            def locked(self):
                with self._lock:
                    self.items.append(1)

            def via_condition_alias(self):
                with self._cv:
                    self.items.append(2)

            def unlocked_read_of_writes_only(self):
                return dict(self.totals)

            def _drain(self):  # guarded-by: _lock
                self.items.clear()
                self.totals["n"] = 0
    """})
    assert lock_discipline.check(project) == []


def test_lock_discipline_pragma_moves_to_allowed(tmp_path):
    project = make_project(tmp_path, {"src/repro/c.py": """\
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.items = []            # guarded-by: _lock

            def startup_peek(self):
                return len(self.items)  # repro: allow[lock-discipline] pre-thread startup read
    """})
    res = run(project, select=["lock-discipline"])["lock-discipline"]
    assert res["violations"] == []
    assert len(res["allowed"]) == 1
    assert res["allowed"][0].reason == "pre-thread startup read"


# --- kernel-contract ---------------------------------------------------------

BAD_KERNEL = """\
    import numpy as np
    from jax.experimental import pallas as pl

    TILE = 256

    def _kern(x_ref, o_ref):
        t = x_ref[...].astype("float64")
        o_ref[...] = t + np.random.rand()

    def my_op(x, tile=TILE):
        return pl.pallas_call(_kern)(x)
"""


def test_kernel_contract_flags_bad_module(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/kernels/mymod.py": BAD_KERNEL,
        "src/repro/kernels/ops.py": "",
        "src/repro/kernels/ref.py": "",
    })
    msgs = "\n".join(messages(kernel_contract.check(project)))
    assert "no pure-JAX oracle in kernels/ref.py" in msgs
    assert "no wrapper in kernels/ops.py" in msgs
    assert "defaults tile=TILE at import time" in msgs
    assert "never calls tuning.resolve_tile" in msgs
    assert '"float64" dtype string in kernel body _kern()' in msgs
    assert "nondeterministic call np.random.rand()" in msgs


def test_kernel_contract_good_module_is_silent(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/kernels/mymod.py": """\
            from jax.experimental import pallas as pl
            from .tuning import resolve_tile

            TILE = 256

            def _kern(x_ref, o_ref):
                o_ref[...] = x_ref[...] * 2.0

            def my_op(x, tile=None):
                tile = resolve_tile("REPRO_X_TILE", TILE, tile)
                return pl.pallas_call(_kern)(x)
        """,
        "src/repro/kernels/ops.py": """\
            def my_op(x, tile=None):
                return None
        """,
        "src/repro/kernels/ref.py": """\
            def my_op(x):
                return x * 2.0
        """,
    })
    assert kernel_contract.check(project) == []


def test_kernel_contract_pragma_suppresses(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/kernels/mymod.py": """\
            from jax.experimental import pallas as pl
            from .tuning import resolve_tile

            def _kern(x_ref, o_ref):
                o_ref[...] = x_ref[...]

            # repro: allow[kernel-contract] internal probe op, engine never imports it
            def probe_op(x, tile=None):
                tile = resolve_tile("REPRO_X_TILE", 256, tile)
                return pl.pallas_call(_kern)(x)
        """,
        "src/repro/kernels/ops.py": "",
        "src/repro/kernels/ref.py": "",
    })
    res = run(project, select=["kernel-contract"])["kernel-contract"]
    assert res["violations"] == []
    assert len(res["allowed"]) == 2  # missing oracle + missing wrapper


# --- host-sync ---------------------------------------------------------------

BAD_SYNC = """\
    import jax
    import numpy as np

    def scalar(x):
        return x.item()

    def wait(x):
        return jax.block_until_ready(x)

    def make_fn(f):
        return jax.jit(f)

    @jax.jit
    def traced(x):
        return float(x)

    def drain(batches):
        out = []
        for b in batches:
            y = kde_eval(b, b, 0.5)
            out.append(float(y))
        return out
"""


def test_host_sync_flags_hot_file(tmp_path):
    project = make_project(tmp_path,
                           {"src/repro/kernels/hot.py": BAD_SYNC})
    msgs = "\n".join(messages(host_sync.check(project)))
    assert ".item() synchronises the device" in msgs
    assert "block_until_ready outside obs.fence()" in msgs
    assert "jax.jit() inside make_fn()" in msgs
    assert "float() inside traced function traced()" in msgs
    assert "converts to host every iteration" in msgs


def test_host_sync_cold_files_and_clean_hot_files_silent(tmp_path):
    project = make_project(tmp_path, {
        # cold module: .item() is fine on a summary/CLI path
        "src/repro/launch/report.py": """\
            def summarise(x):
                return x.item()
        """,
        # hot module doing it right: convert at the boundary, un-jitted
        "src/repro/kernels/hot.py": """\
            import numpy as np

            def boundary(xs):
                ys = [kde_eval(b, b, 0.5) for b in xs]
                return np.asarray(ys)
        """,
    })
    assert host_sync.check(project) == []


def test_host_sync_pragma_suppresses(tmp_path):
    project = make_project(tmp_path, {"src/repro/kernels/hot.py": """\
        def scalar(x):
            return x.item()  # repro: allow[host-sync] error path, already cold
    """})
    res = run(project, select=["host-sync"])["host-sync"]
    assert res["violations"] == []
    assert len(res["allowed"]) == 1


# --- knob-registry -----------------------------------------------------------

def test_knob_registry_flags_drift(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/knobs.py": """\
            KNOBS = {}

            def register(name, type, default, doc):
                KNOBS[name] = (type, default, doc)

            register("REPRO_ALPHA", "int", 1, "alpha")
            register("REPRO_DEAD", "int", 1, "nothing reads this")
        """,
        "src/repro/foo.py": """\
            import os

            def f():
                a = os.environ.get("REPRO_ALPHA")
                b = os.environ["REPRO_BETA"]
                return a, b, "REPRO_TYPO"
        """,
        "docs/analysis.md": "| REPRO_ALPHA | REPRO_DEAD | REPRO_GHOST |\n",
    })
    out = knob_registry.check(project)
    msgs = "\n".join(messages(out))
    assert len(out) == 6
    assert "raw environ read of REPRO_ALPHA" in msgs
    assert "raw environ read of REPRO_BETA" in msgs
    assert "REPRO_BETA is not registered" in msgs
    assert "REPRO_TYPO is not registered" in msgs
    assert "REPRO_DEAD is registered but nothing reads it" in msgs
    assert "REPRO_GHOST appears in docs/analysis.md" in msgs


def test_knob_registry_good_tree_is_silent(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/knobs.py": """\
            KNOBS = {}

            def register(name, type, default, doc):
                KNOBS[name] = (type, default, doc)

            register("REPRO_ALPHA", "int", 1, "alpha")
        """,
        "src/repro/foo.py": """\
            from repro import knobs

            def f():
                return knobs.get_int("REPRO_ALPHA")
        """,
        "docs/analysis.md": "| `REPRO_ALPHA` | int | 1 | alpha |\n",
    })
    assert knob_registry.check(project) == []


def test_knob_registry_pragma_suppresses_raw_read(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/knobs.py": """\
            def register(name, type, default, doc):
                pass

            register("REPRO_ALPHA", "int", 1, "alpha")
        """,
        "src/repro/foo.py": """\
            import os

            def f():
                return os.environ.get("REPRO_ALPHA")  # repro: allow[knob-registry] pre-import bootstrap read
        """,
        "docs/analysis.md": "REPRO_ALPHA\n",
    })
    res = run(project, select=["knob-registry"])["knob-registry"]
    assert res["violations"] == []
    assert len(res["allowed"]) == 1


# --- instrument-drift --------------------------------------------------------

DRIFT_DOCS = """\
    ## Metric catalogue

    | name | kind |
    |---|---|
    | `aqp.test.hits` | counter |
    | `aqp.test.ghost` | counter |

    ## Spans

    | span | labels |
    |---|---|
    | `engine.real` | |
"""


def test_instrument_drift_flags_both_directions(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/core/emit.py": """\
            def record(metrics, obs, name):
                metrics.counter("aqp.test.hits").inc()
                metrics.gauge(name).set(1)
                with obs.span("engine.mystery"):
                    pass
        """,
        "docs/observability.md": DRIFT_DOCS,
        "scripts/validate_metrics.py": """\
            REQUIRED = ["aqp.phantom.total"]
        """,
    }, roots=("src",))
    out = instrument_drift.check(project)
    msgs = "\n".join(messages(out))
    assert len(out) == 5
    assert ".gauge(<dynamic name>)" in msgs
    assert "span `engine.mystery` is emitted but missing" in msgs
    assert "metric `aqp.test.ghost` is documented but nothing emits it" in msgs
    assert "span `engine.real` is documented but nothing opens it" in msgs
    assert "validator references `aqp.phantom.total`" in msgs


def test_instrument_drift_matching_catalogue_is_silent(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/core/emit.py": """\
            def record(metrics, obs):
                metrics.counter("aqp.test.hits").inc()
                with obs.span("engine.real"):
                    pass
        """,
        "docs/observability.md": """\
            ## Metric catalogue

            | `aqp.test.hits` | counter |

            ## Spans

            | `engine.real` | |
        """,
        "scripts/validate_metrics.py": """\
            REQUIRED = ["aqp.test.hits"]
        """,
    })
    assert instrument_drift.check(project) == []


def test_instrument_drift_pragma_allows_dynamic_name(tmp_path):
    project = make_project(tmp_path, {
        "src/repro/core/emit.py": """\
            def record(metrics, name):
                metrics.counter(name).inc()  # repro: allow[instrument-drift] per-plugin counter family
        """,
        "docs/observability.md": "## Metric catalogue\n",
    })
    res = run(project, select=["instrument-drift"])["instrument-drift"]
    assert res["violations"] == []
    assert len(res["allowed"]) == 1


# --- pragma hygiene ----------------------------------------------------------

def test_reasonless_and_unknown_pragmas_are_findings(tmp_path):
    project = make_project(tmp_path, {"src/repro/c.py": """\
        X = 1  # repro: allow[host-sync]
        Y = 2  # repro: allow[bogus-check] some reason
    """})
    out = run(project)["pragma"]["violations"]
    msgs = "\n".join(messages(out))
    assert len(out) == 2
    assert "has no reason" in msgs
    assert "allow[bogus-check] names no known checker" in msgs


def test_docstring_pragma_examples_are_not_pragmas(tmp_path):
    project = make_project(tmp_path, {"src/repro/c.py": '''\
        """Docs showing the syntax: # repro: allow[host-sync] why"""
        X = 1
    '''})
    assert run(project)["pragma"]["violations"] == []
    assert project.get("src/repro/c.py").pragmas == []


def test_runner_rejects_unknown_checker(tmp_path):
    project = make_project(tmp_path, {"src/repro/c.py": "X = 1\n"})
    with pytest.raises(KeyError, match="unknown checker"):
        run(project, select=["no-such-check"])


# --- the repo-wide gate ------------------------------------------------------

def test_repo_is_clean():
    """The actual tree carries zero unallowed violations — the same gate CI
    applies via scripts/check.py --all."""
    results = run_all(REPO)
    bad = [v for res in results.values() for v in res["violations"]]
    assert not bad, "unallowed violations:\n" + "\n".join(
        v.format() for v in bad)


def test_every_checker_is_registered():
    assert set(CHECKERS) == {"lock-discipline", "kernel-contract",
                             "host-sync", "knob-registry",
                             "instrument-drift"}
    assert runner.DEFAULT_ROOTS == ("src", "scripts", "benchmarks")


# --- check.py CLI contract ---------------------------------------------------

def _run_cli(*argv):
    return subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check.py"), *argv],
        capture_output=True, text=True, cwd=str(REPO))


def test_cli_all_json_exits_zero_on_clean_tree():
    proc = _run_cli("--all", "--json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(proc.stdout)
    assert set(doc) == set(CHECKERS) | {"pragma"}
    assert all(res["violations"] == [] for res in doc.values())


def test_cli_select_and_summary_lines():
    proc = _run_cli("--select", "host-sync,knob-registry")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "host-sync" in proc.stdout
    assert "knob-registry" in proc.stdout
    assert "lock-discipline" not in proc.stdout
    assert "0 unallowed violations" in proc.stdout


def test_cli_unknown_checker_is_usage_error():
    proc = _run_cli("--select", "no-such-check")
    assert proc.returncode == 2
    assert "unknown checker" in proc.stderr


def test_cli_nonzero_on_violation(tmp_path):
    (tmp_path / "src" / "repro" / "kernels").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "kernels" / "hot.py").write_text(
        "def f(x):\n    return x.item()\n")
    proc = _run_cli("--select", "host-sync", "--root", str(tmp_path))
    assert proc.returncode == 1
    assert ".item() synchronises the device" in proc.stdout


# --- repro.knobs typed accessors ---------------------------------------------

def test_get_int_default_env_and_override(monkeypatch):
    monkeypatch.delenv("REPRO_KDE_CHUNK", raising=False)
    assert knobs.get_int("REPRO_KDE_CHUNK") == 256
    assert knobs.get_int("REPRO_KDE_CHUNK", default=77) == 77
    monkeypatch.setenv("REPRO_KDE_CHUNK", "64")
    assert knobs.get_int("REPRO_KDE_CHUNK") == 64
    assert knobs.get_int("REPRO_KDE_CHUNK", default=77) == 64


@pytest.mark.parametrize("raw", ["abc", "0", "-3", "1.5"])
def test_get_int_is_loud_on_malformed_values(monkeypatch, raw):
    monkeypatch.setenv("REPRO_KDE_CHUNK", raw)
    with pytest.raises(ValueError, match="REPRO_KDE_CHUNK"):
        knobs.get_int("REPRO_KDE_CHUNK")


def test_get_bool_semantics(monkeypatch):
    monkeypatch.delenv("REPRO_OBS", raising=False)
    assert knobs.get_bool("REPRO_OBS") is False
    monkeypatch.setenv("REPRO_OBS", "")
    assert knobs.get_bool("REPRO_OBS") is False
    monkeypatch.setenv("REPRO_OBS", "0")
    assert knobs.get_bool("REPRO_OBS") is False
    monkeypatch.setenv("REPRO_OBS", "1")
    assert knobs.get_bool("REPRO_OBS") is True


def test_get_str_and_path(monkeypatch):
    monkeypatch.delenv("REPRO_TUNING_CACHE", raising=False)
    assert knobs.get_str("REPRO_TUNING_CACHE") == ""
    assert knobs.get_str("REPRO_TUNING_CACHE", default="/x") == "/x"
    monkeypatch.setenv("REPRO_TUNING_CACHE", "/tmp/tiles.json")
    assert knobs.get_str("REPRO_TUNING_CACHE") == "/tmp/tiles.json"


def test_unregistered_knob_raises():
    with pytest.raises(KeyError, match="unregistered"):
        knobs.get_int("REPRO_NOT_A_KNOB")


def test_type_mismatch_raises():
    with pytest.raises(TypeError, match="bool, not int"):
        knobs.get_int("REPRO_OBS")


def test_register_collision_and_idempotence():
    k = knobs.KNOBS["REPRO_OBS"]
    # identical re-registration is a no-op
    assert knobs.register(k.name, k.type, k.default, k.doc) == k
    # different metadata for the same name is the silent fork the registry
    # exists to prevent
    with pytest.raises(ValueError, match="already registered"):
        knobs.register("REPRO_OBS", "bool", True, "different default")
    assert knobs.KNOBS["REPRO_OBS"] == k


def test_knob_validation():
    with pytest.raises(ValueError, match="must start with REPRO_"):
        knobs.Knob("OTHER_NAME", "int", 1, "doc")
    with pytest.raises(ValueError, match="unknown type"):
        knobs.Knob("REPRO_X", "float", 1, "doc")
    with pytest.raises(ValueError, match="needs a docstring"):
        knobs.Knob("REPRO_X", "int", 1, "  ")


# --- guarded-by annotations vs runtime: 8-thread stress ----------------------

def test_store_locking_survives_8_threads(rng):
    """The lock-discipline annotations on TelemetryStore claim that ingest,
    tracking, snapshotting, and admission traffic can race safely.  Hold
    them to it: 8 threads (3 ingest, 1 tracker, 2 snapshot, 2 query
    clients) hammer one store; afterwards the counters must balance
    exactly — a torn track_joint backfill or unlocked listener append
    would show up as lost rows, lost notifications, or an exception."""
    store = TelemetryStore(capacity=256, seed=0)
    store.track_joint(("a", "b"))
    seed_rows = 2_000
    a0 = rng.normal(0, 1, seed_rows).astype(np.float32)
    store.add_batch({"a": a0,
                     "b": (0.5 * a0 + rng.normal(0, 1, seed_rows)
                           ).astype(np.float32)})

    notifications = []
    store.subscribe(lambda versions: notifications.append(dict(versions)))

    n_ingest, n_batches, rows = 3, 12, 200
    barrier = threading.Barrier(8)
    errors = []
    answers = {}

    def ingest(tid):
        g = np.random.default_rng(1000 + tid)
        barrier.wait()
        for _ in range(n_batches):
            a = g.normal(0, 1, rows).astype(np.float32)
            b = (0.5 * a + g.normal(0, 1, rows)).astype(np.float32)
            store.add_batch({"a": a, "b": b})

    def tracker():
        barrier.wait()
        for _ in range(n_batches):
            store.track_joint(("a", "b"))     # idempotent re-track
            store.track_categorical("code")   # registered once, raced often
            store.shared_engine()             # get-or-create under the lock

    def snapshot():
        barrier.wait()
        for _ in range(n_batches):
            st = store.stats()
            assert "admission" in st
            store.metrics.snapshot()

    def client(tid, sess):
        barrier.wait()
        # bounds unique per (client, i) so no two tickets can coalesce
        tickets = [sess.submit(AqpQuery(
            "count", (Range("a", -1.0, 0.2 * (3 * tid + i)),)))
            for i in range(3)]
        answers[tid] = [t.result(timeout=60).estimate for t in tickets]

    def guard(fn, *args):
        def run_guarded():
            try:
                fn(*args)
            except BaseException as e:   # noqa: BLE001 — surfaced below
                errors.append(e)
                try:
                    barrier.abort()
                except Exception:
                    pass
        return run_guarded

    with store.session(watermark=2, max_delay=0.005) as sess:
        threads = ([threading.Thread(target=guard(ingest, t))
                    for t in range(n_ingest)]
                   + [threading.Thread(target=guard(tracker))]
                   + [threading.Thread(target=guard(snapshot))
                      for _ in range(2)]
                   + [threading.Thread(target=guard(client, t, sess))
                      for t in range(2)])
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        sess_stats = sess.stats()

    assert not errors, errors
    # every ingested row is accounted for: no torn batches, no lost updates
    want_rows = seed_rows + n_ingest * n_batches * rows
    assert store.metrics.sum_counter("aqp.ingest.rows", column="a") == want_rows
    assert store.metrics.sum_counter("aqp.ingest.batches") == \
        1 + n_ingest * n_batches
    assert store.columns["a"].n_seen == want_rows
    # the locked listener path lost no notifications (subscribed after the
    # seed batch, so exactly one per threaded add_batch)
    assert len(notifications) == n_ingest * n_batches
    # both clients resolved every future with a finite estimate
    assert sorted(answers) == [0, 1]
    assert all(np.isfinite(est) for ests in answers.values() for est in ests)
    assert sess_stats["submitted"] == 6
    assert sess_stats["executed"] >= 6   # >=: invalidation may re-execute
    # raced get-or-create converged on exactly one shared engine
    assert len(store._engines) == 1
