"""PLUGIN bandwidth selector vs the paper's sequential implementation and
statistical invariants (paper §4.4 eqs. 12-19)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # hypothesis is optional: property tests skip below
    HAVE_HYPOTHESIS = False

from repro.core import plugin_bandwidth, plugin_bandwidth_sequential
from repro.core.binned import binned_plugin_bandwidth


def test_matches_sequential_oracle(rng):
    x = rng.normal(1.0, 2.0, 400).astype(np.float32)
    h_jax = float(plugin_bandwidth(jnp.asarray(x)).h)
    h_seq = plugin_bandwidth_sequential(x)
    assert abs(h_jax - h_seq) / h_seq < 1e-3


def test_pallas_backend_matches(rng):
    x = rng.normal(0.0, 1.0, 700).astype(np.float32)
    a = float(plugin_bandwidth(jnp.asarray(x)).h)
    b = float(plugin_bandwidth(jnp.asarray(x), backend="pallas").h)
    assert abs(a - b) / a < 1e-3


def test_normal_reference_magnitude(rng):
    # For N(0,1), h_PLUGIN should be within a small factor of Silverman's rule.
    n = 2048
    x = rng.normal(0.0, 1.0, n).astype(np.float32)
    h = float(plugin_bandwidth(jnp.asarray(x)).h)
    silverman = 1.06 * n ** -0.2
    assert 0.3 * silverman < h < 2.0 * silverman


def _check_scale_equivariance(scale, shift, seed):
    """h(a*X + b) == a * h(X): bandwidths are scale-equivariant."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0.0, 1.0, 256).astype(np.float32)
    h1 = float(plugin_bandwidth(jnp.asarray(x)).h)
    h2 = float(plugin_bandwidth(jnp.asarray(scale * x + shift, dtype=jnp.float32)).h)
    assert h2 == pytest.approx(scale * h1, rel=5e-3)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(scale=st.floats(0.1, 10.0), shift=st.floats(-5.0, 5.0),
           seed=st.integers(0, 100))
    def test_scale_equivariance(scale, shift, seed):
        _check_scale_equivariance(scale, shift, seed)
else:
    @pytest.mark.parametrize("scale,shift,seed",
                             [(0.1, -5.0, 0), (1.0, 0.0, 7), (10.0, 5.0, 42)])
    def test_scale_equivariance(scale, shift, seed):
        _check_scale_equivariance(scale, shift, seed)


def test_permutation_invariance(rng):
    x = rng.normal(0.0, 1.5, 333).astype(np.float32)
    h1 = float(plugin_bandwidth(jnp.asarray(x)).h)
    h2 = float(plugin_bandwidth(jnp.asarray(rng.permutation(x))).h)
    assert h1 == pytest.approx(h2, rel=1e-4)


def test_binned_close_to_exact(rng):
    x = rng.normal(0.0, 1.0, 4096).astype(np.float32)
    h_exact = float(plugin_bandwidth(jnp.asarray(x)).h)
    h_binned = float(binned_plugin_bandwidth(jnp.asarray(x)))
    assert abs(h_binned - h_exact) / h_exact < 0.02


def test_intermediates_match_paper_constants(rng):
    """g1/g2/psi plumbing: check signs and orderings the formulas imply."""
    x = rng.normal(0.0, 1.0, 512).astype(np.float32)
    r = plugin_bandwidth(jnp.asarray(x))
    assert float(r.psi8) > 0          # eq. 14: positive by construction
    assert float(r.psi6) < 0          # Psi6 < 0 for smooth densities
    assert float(r.psi4) > 0          # Psi4 > 0
    assert 0 < float(r.g1) < 2.0
    assert 0 < float(r.g2) < 2.0
    assert 0 < float(r.h) < 1.0
