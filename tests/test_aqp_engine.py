"""Unified declarative AQP API (core/aqp_query.py): AqpQuery normalization,
QueryEngine routing across execution paths, parity with the legacy stacks
(deprecation shims bit-for-bit), categorical Eq terms, GROUP BY, the batched
QMC fallback, and AqpResult metadata."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AqpQuery, Box, BoxQuery, BoxQueryBatch, Eq, GroupBy,
                        KDESynopsis, Query, QueryBatch, QueryEngine, Range)
from repro.core.aqp import batch_query_1d
from repro.core.aqp_multid import batch_query_box
from repro.core.aqp_query import from_box_query, from_query
from repro.data import TelemetryStore


def _store(rng, n=40_000, capacity=1024):
    a = rng.normal(0, 1, n).astype(np.float32)
    b = (0.8 * a + 0.6 * rng.normal(0, 1, n)).astype(np.float32)
    code = rng.integers(0, 4, n).astype(np.float32)
    store = TelemetryStore(capacity=capacity, seed=0)
    store.track_joint(("a", "b"))
    store.add_batch({"a": a, "b": b, "code": code})
    return store, a, b, code


# --- acceptance: one execute() call, every path, parity 1e-5 ----------------

def test_single_execute_answers_every_path(rng):
    """One QueryEngine.execute call answers a mixed batch of 1-D ranges,
    multi-d boxes, categorical equality, and full-H-fallback queries, and
    each answer agrees with the corresponding direct batched pass to 1e-5."""
    store, a, b, code = _store(rng)
    specs = [
        AqpQuery("count", (Range("a", -1.0, 1.0),)),
        AqpQuery("sum", (Range("b", -0.5, 2.0),), target="b"),
        AqpQuery("avg", (Box(("a", "b"), (-1.0, -1.0), (1.0, 1.0)),),
                 target="b"),
        AqpQuery("count", (Eq("code", 2.0),)),
        AqpQuery("count", (Range("a", -1.0, 1.0),), selector="lscv_H"),
    ]
    results = store.query(specs)
    assert [r.path for r in results] == ["range1d", "range1d", "box",
                                         "range1d", "qmc"]

    # direct closed-form passes against the same cached synopses
    syn_a = store.synopsis("a")
    got0 = float(batch_query_1d(
        syn_a.x, syn_a.h, jnp.asarray([-1.0], jnp.float32),
        jnp.asarray([1.0], jnp.float32), jnp.asarray([0], jnp.int32),
        jnp.float32(syn_a.n_source / syn_a.x.shape[0]))[0])
    assert results[0].estimate == pytest.approx(got0, rel=1e-5)

    syn_ab = store.joint_synopsis(("a", "b"))
    got2 = float(batch_query_box(
        syn_ab.x, syn_ab.h_diag(), jnp.asarray([[-1.0, -1.0]], jnp.float32),
        jnp.asarray([[1.0, 1.0]], jnp.float32), jnp.asarray([1], jnp.int32),
        jnp.asarray([2], jnp.int32),
        jnp.float32(syn_ab.n_source / syn_ab.x.shape[0]))[0])
    assert results[2].estimate == pytest.approx(got2, rel=1e-5)

    syn_code = store.synopsis("code")
    got3 = float(batch_query_1d(
        syn_code.x, syn_code.h, jnp.asarray([1.5], jnp.float32),
        jnp.asarray([2.5], jnp.float32), jnp.asarray([0], jnp.int32),
        jnp.float32(syn_code.n_source / syn_code.x.shape[0]))[0])
    assert results[3].estimate == pytest.approx(got3, rel=1e-5)

    # sanity vs exact answers (QMC and closed forms are both ~% accurate)
    exact = float(((a >= -1) & (a <= 1)).sum())
    assert results[0].estimate == pytest.approx(exact, rel=0.1)
    assert results[4].estimate == pytest.approx(exact, rel=0.15)
    assert results[3].estimate == pytest.approx(float((code == 2).sum()),
                                                rel=0.2)


def test_engine_matches_legacy_stacks_rtol(rng):
    """Mixed batch parity with the pre-refactor dispatch: compiled legacy
    Query/BoxQuery twins answer within 1e-5 relative error."""
    store, a, b, code = _store(rng)
    n_q = 64
    specs, legacy_r, legacy_b, order = [], [], [], []
    ops = ["count", "sum", "avg"]
    for i in range(n_q):
        op = ops[i % 3]
        if i % 3 == 2:
            lo = tuple(rng.uniform(-2.0, 0.0, 2))
            hi = tuple(np.asarray(lo) + rng.uniform(0.5, 3.0, 2))
            specs.append(AqpQuery(op, (Box(("a", "b"), lo, hi),), target="a"))
            legacy_b.append(BoxQuery(op, lo, hi, columns=("a", "b"),
                                     target="a"))
            order.append(("b", len(legacy_b) - 1))
        else:
            col = "a" if i % 2 else "b"
            lo = float(rng.uniform(-2.0, 1.0))
            hi = lo + float(rng.uniform(0.1, 2.0))
            specs.append(AqpQuery(op, (Range(col, lo, hi),),
                                  target=None if op == "count" else col))
            legacy_r.append(Query(op, lo, hi, column=col))
            order.append(("r", len(legacy_r) - 1))
    got = store.engine().answers(specs)
    want_r = store.query_batch(legacy_r)
    want_b = store.query_box_batch(legacy_b)
    want = np.asarray([{"r": want_r, "b": want_b}[k][i] for k, i in order])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# --- deprecation shims ------------------------------------------------------

def test_querybatch_shim_bitwise_and_warns(rng):
    store, *_ = _store(rng, n=8000, capacity=512)
    qs = [Query("count", -1.0, 1.0, column="a"),
          Query("sum", -0.5, 2.0, column="b"),
          Query("avg", 0.0, 1.5, column="a")]
    synopses = {c: store.synopsis(c) for c in ("a", "b")}
    with pytest.warns(DeprecationWarning, match="QueryBatch.run"):
        legacy = QueryBatch(qs).run(synopses)
    engine = QueryEngine(store).answers([from_query(q) for q in qs])
    np.testing.assert_array_equal(legacy, engine)


def test_boxquerybatch_shim_bitwise_and_warns(rng):
    store, *_ = _store(rng, n=8000, capacity=512)
    qs = [BoxQuery("count", (-1, -1), (1, 1), columns=("a", "b")),
          BoxQuery("sum", (-2, -1), (0, 2), columns=("a", "b"), target="b"),
          BoxQuery("avg", (-1, 0), (1, 2), columns=("a", "b"), target="a")]
    synopses = {("a", "b"): store.joint_synopsis(("a", "b"))}
    with pytest.warns(DeprecationWarning, match="BoxQueryBatch.run"):
        legacy = BoxQueryBatch(qs).run(synopses)
    engine = QueryEngine(store).answers([from_box_query(q) for q in qs])
    np.testing.assert_array_equal(legacy, engine)


# --- categorical Eq and GROUP BY --------------------------------------------

def test_eq_counts_dictionary_codes(rng):
    n = 30_000
    code = rng.choice([0, 1, 2, 3], size=n,
                      p=[0.4, 0.3, 0.2, 0.1]).astype(np.float32)
    store = TelemetryStore(capacity=2048, seed=0)
    store.add_batch({"code": code})
    res = store.query([AqpQuery("count", (Eq("code", v),))
                       for v in (0.0, 1.0, 2.0, 3.0)],
                      selector="silverman")
    for v, r in zip((0, 1, 2, 3), res):
        assert r.estimate == pytest.approx(float((code == v).sum()), rel=0.2)
    # the code buckets partition the range: totals agree much tighter
    total = sum(r.estimate for r in res)
    assert total == pytest.approx(n, rel=0.05)


def test_group_by_discovers_codes_and_matches_eq(rng):
    store, a, b, code = _store(rng)
    store.track_joint(("code", "b"))          # backfilled joint for the demo
    store.add_batch({"a": a, "b": b, "code": code})   # stream real rows too
    grouped = store.engine().execute(
        AqpQuery("count", (Range("b", -1.0, 1.0),), group_by="code"))
    assert [r.group for r in grouped] == [0.0, 1.0, 2.0, 3.0]
    assert all(r.path == "box:grouped" for r in grouped)
    # each group row matches the equivalent explicit Eq conjunction; the
    # grouped kernel factors the shared-axis product out of the per-category
    # pass, so agreement is to float tolerance rather than bitwise
    explicit = store.engine().answers(
        [AqpQuery("count", (Range("b", -1.0, 1.0), Eq("code", v)))
         for v in (0.0, 1.0, 2.0, 3.0)])
    np.testing.assert_allclose([r.estimate for r in grouped], explicit,
                               rtol=1e-5, atol=1e-3)
    sel = (b >= -1) & (b <= 1)
    for r in grouped:
        # the joint stream is the backfill window plus one real pass over the
        # data, so the relation it represents is the data twice
        exact = 2.0 * float((sel & (code == r.group)).sum())
        assert r.estimate == pytest.approx(exact, rel=0.35, abs=400)

    pinned = store.engine().execute(
        AqpQuery("count", (Range("b", -1.0, 1.0),),
                 group_by=GroupBy("code", values=(2.0, 0.0))))
    assert [r.group for r in pinned] == [2.0, 0.0]


def test_group_by_with_implicit_target(rng):
    """SUM/AVG over one predicate column may leave the target implicit even
    under GROUP BY — the group term must not count as a predicate column."""
    n = 20_000
    code = rng.integers(0, 3, n).astype(np.float32)
    b = (code + rng.normal(0, 0.3, n)).astype(np.float32)
    store = TelemetryStore(capacity=1024, seed=0)
    store.track_joint(("code", "b"))
    store.add_batch({"code": code, "b": b})
    implicit = store.engine().execute(
        AqpQuery("avg", (Range("b", -2.0, 5.0),), group_by="code"))
    explicit = store.engine().execute(
        AqpQuery("avg", (Range("b", -2.0, 5.0),), target="b",
                 group_by="code"))
    np.testing.assert_array_equal([r.estimate for r in implicit],
                                  [r.estimate for r in explicit])
    for r in implicit:
        assert r.estimate == pytest.approx(float(r.group), abs=0.3)


def test_execute_specs_rejects_store_only_features(rng):
    from repro.core.aqp_query import execute_specs

    syn = KDESynopsis.fit(
        jnp.asarray(rng.normal(0, 1, 1000).astype(np.float32)),
        max_sample=256)
    with pytest.raises(ValueError, match="group_by needs a store"):
        execute_specs([AqpQuery("count", (Range(None, 0, 1),),
                                group_by="code")], syn)
    with pytest.raises(ValueError, match="selector override needs"):
        execute_specs([AqpQuery("count", (Range(None, 0, 1),),
                                selector="lscv_H")], syn)


def test_group_by_guards(rng):
    store, *_ = _store(rng, n=2000, capacity=256)
    with pytest.raises(KeyError, match="group_by column"):
        store.engine().execute(AqpQuery("count", (Range("a", 0, 1),),
                                        group_by="missing"))
    many = rng.normal(0, 100, 2000).astype(np.float32)
    store.add_batch({"many": many})
    with pytest.raises(ValueError, match="max_groups"):
        store.engine().execute(AqpQuery("count", (Range("a", 0, 1),),
                                        group_by="many"))


def test_group_by_single_category_stays_on_plain_path(rng):
    """A one-category GROUP BY has nothing to factor; it runs the ordinary
    box path (the grouped kernel needs >= 2 siblings)."""
    store, *_ = _store(rng)
    store.track_joint(("code", "b"))
    only = store.engine().execute(
        AqpQuery("count", (Range("b", -1.0, 1.0),),
                 group_by=GroupBy("code", values=(2.0,))))
    assert [r.path for r in only] == ["box"]


def test_grouped_kernel_with_group_column_target(rng):
    """SUM/AVG whose target IS the group column exercises the grouped
    kernel's moment-on-group-axis branch."""
    n = 20_000
    code = rng.integers(0, 3, n).astype(np.float32)
    b = (code + rng.normal(0, 0.3, n)).astype(np.float32)
    store = TelemetryStore(capacity=1024, seed=0)
    store.track_joint(("code", "b"))
    store.add_batch({"code": code, "b": b})
    grouped = store.engine().execute(
        AqpQuery("avg", (Range("b", -2.0, 5.0),), target="code",
                 group_by="code"))
    assert all(r.path == "box:grouped" for r in grouped)
    explicit = store.engine().answers(
        [AqpQuery("avg", (Range("b", -2.0, 5.0), Eq("code", v)),
                  target="code") for v in (0.0, 1.0, 2.0)])
    np.testing.assert_allclose([r.estimate for r in grouped], explicit,
                               rtol=1e-4, atol=1e-3)
    for r in grouped:
        # AVG(code) within a category's code window ~ the category code
        assert r.estimate == pytest.approx(float(r.group), abs=0.1)


# --- exact categorical sketches ----------------------------------------------

def test_exact_eq_path_count_sum_avg(rng):
    """Eq terms on a tracked dictionary column answer from the per-code
    frequency sketch: exact, path=="exact", rel_width==0.0 (no smoothing),
    zero-width confidence intervals."""
    n = 25_000
    code = rng.choice([0, 1, 2, 3], size=n,
                      p=[0.4, 0.3, 0.2, 0.1]).astype(np.float32)
    store = TelemetryStore(capacity=1024, seed=0)
    store.track_categorical("code")
    store.add_batch({"code": code})
    res = store.query([
        AqpQuery("count", (Eq("code", 2.0),)),
        AqpQuery("sum", (Eq("code", 2.0),)),
        AqpQuery("avg", (Eq("code", 2.0),)),
        AqpQuery("count", (Eq("code", 7.0),)),        # absent code
    ])
    n2 = float((code == 2).sum())
    assert [r.path for r in res] == ["exact"] * 4
    assert res[0].estimate == n2
    assert res[1].estimate == 2.0 * n2
    assert res[2].estimate == 2.0
    assert res[3].estimate == 0.0
    assert all(r.rel_width == 0.0 for r in res)
    assert all(r.ci_lo == r.estimate == r.ci_hi for r in res)
    assert all(r.n_effective == n for r in res)
    assert res[0].synopsis_version == store.columns["code"].version


def test_rel_width_ordering_exact_best(rng):
    """The deprecated accuracy proxy must rank exact answers BEST (0.0),
    constrained KDE answers in between (finite), and genuinely unconstrained
    estimates worst (inf) — regression for the old rel_width=inf-on-exact
    bug."""
    n = 20_000
    store = TelemetryStore(capacity=512, seed=0)
    store.track_categorical("code")
    store.add_batch({"code": rng.integers(0, 4, n).astype(np.float32),
                     "val": rng.normal(0.0, 1.0, n).astype(np.float32)})
    exact, ranged, uncon = store.query([
        AqpQuery("count", (Eq("code", 2.0),)),           # exact sketch
        AqpQuery("count", (Range("val", -1.0, 1.0),)),   # constrained KDE
        AqpQuery("sum", (), target="val"),               # whole-table SUM
    ])
    assert exact.path == "exact" and exact.rel_width == 0.0
    assert ranged.path == "range1d" and np.isfinite(ranged.rel_width) \
        and ranged.rel_width > 0.0
    assert uncon.rel_width == np.inf
    assert exact.rel_width < ranged.rel_width < uncon.rel_width


def test_exact_eq_falls_back_without_full_coverage(rng):
    """Untracked columns, sketches registered after data, and range (non-Eq)
    predicates all stay on the KDE path."""
    n = 10_000
    code = rng.integers(0, 4, n).astype(np.float32)
    store = TelemetryStore(capacity=1024, seed=0)
    store.add_batch({"code": code})
    # untracked: KDE code-window estimate
    r = store.query([AqpQuery("count", (Eq("code", 1.0),))])[0]
    assert r.path == "range1d"
    # tracked late: sketch misses the first batch -> still KDE
    store.track_categorical("code")
    store.add_batch({"code": code})
    r = store.query([AqpQuery("count", (Eq("code", 1.0),))])[0]
    assert r.path == "range1d"
    assert store.stats()["categoricals"]["code"]["exact"] is False


def test_exact_eq_mixes_with_kde_paths_in_one_batch(rng):
    """One batch mixing exact Eq, ranges, and boxes scatters back to
    submission order with per-row paths."""
    store, a, b, code = _store(rng)
    store2 = TelemetryStore(capacity=1024, seed=0)
    store2.track_categorical("code")
    store2.track_joint(("a", "b"))
    store2.add_batch({"a": a, "b": b, "code": code})
    res = store2.query([
        AqpQuery("count", (Eq("code", 0.0),)),
        AqpQuery("count", (Range("a", -1.0, 1.0),)),
        AqpQuery("count", (Box(("a", "b"), (-1, -1), (1, 1)),)),
        AqpQuery("count", (Eq("code", 3.0),)),
    ])
    assert [r.path for r in res] == ["exact", "range1d", "box", "exact"]
    assert res[0].estimate == float((code == 0).sum())
    assert res[3].estimate == float((code == 3).sum())
    # Eq + Range on the same dictionary column is a range conjunction, not a
    # pure code window: it must NOT take the exact path
    r = store2.query([AqpQuery("count", (Eq("code", 1.0),
                                         Range("code", 0.0, 2.0)))])[0]
    assert r.path == "range1d"


def test_exact_eq_group_by_same_column(rng):
    """COUNT .. GROUP BY code with no other predicate is a pure code window
    per category: every row exact."""
    n = 8_000
    code = rng.integers(0, 3, n).astype(np.float32)
    store = TelemetryStore(capacity=512, seed=0)
    store.track_categorical("code")
    store.add_batch({"code": code})
    rows = store.engine().execute(AqpQuery("count", (), group_by="code"))
    assert [r.path for r in rows] == ["exact"] * 3
    for r in rows:
        assert r.estimate == float((code == r.group).sum())
    assert sum(r.estimate for r in rows) == float(n)


# --- normalization / validation ---------------------------------------------

def test_aqp_query_validation():
    with pytest.raises(ValueError, match="unknown aggregate"):
        AqpQuery("median", (Range("a", 0, 1),))
    with pytest.raises(ValueError, match="no target"):
        AqpQuery("count", (Range("a", 0, 1),), target="a")
    with pytest.raises(ValueError, match="at least one predicate"):
        AqpQuery("count", ())
    with pytest.raises(ValueError, match="predicate term or a target"):
        AqpQuery("sum", ())
    with pytest.raises(TypeError, match="Range/Box/Eq"):
        AqpQuery("count", ("a",))
    with pytest.raises(ValueError, match="mismatch"):
        Box(("a", "b"), (0, 0), (1, 1, 1))
    with pytest.raises(ValueError, match="names"):
        Box(("a",), (0, 0), (1, 1))
    with pytest.raises(ValueError, match="halfwidth"):
        Eq("a", 1.0, halfwidth=0.0)
    # case-insensitive aggregate spelling is normalized
    assert AqpQuery("COUNT", (Range("a", 0, 1),)).aggregate == "count"


def test_engine_compile_errors(rng):
    store, *_ = _store(rng, n=2000, capacity=256)
    eng = store.engine()
    with pytest.raises(ValueError, match="mix named and positional"):
        eng.execute(AqpQuery("count", (Range("a", 0, 1), Range(None, 0, 1))))
    with pytest.raises(ValueError, match="explicit target"):
        eng.execute(AqpQuery("sum", (Range("a", 0, 1), Range("b", 0, 1))))
    with pytest.raises(ValueError, match="name a column"):
        eng.execute(AqpQuery("count", (Range(None, 0, 1),)))
    with pytest.raises(KeyError, match="track_joint"):
        eng.execute(AqpQuery("count", (Range("a", 0, 1), Range("code", 0, 1))))
    with pytest.raises(TypeError, match="AqpQuery"):
        eng.execute([Query("count", 0, 1, column="a")])


def test_mapping_miss_lists_mixed_keys(rng):
    """A unified mapping may mix plain column keys with column tuples; the
    missing-key diagnostic must not crash sorting them against each other."""
    from repro.core.aqp_query import execute_specs

    data = rng.normal(0, 1, (1000, 2)).astype(np.float32)
    syn1 = KDESynopsis.fit(jnp.asarray(data[:, 0]), max_sample=256)
    syn2 = KDESynopsis.fit(jnp.asarray(data), max_sample=256)
    mixed = {"a": syn1, ("a", "b"): syn2}
    with pytest.raises(KeyError, match="no synopsis for column 'c'"):
        execute_specs([AqpQuery("count", (Range("c", -1, 1),))], mixed)
    with pytest.raises(KeyError, match="no joint synopsis"):
        execute_specs([AqpQuery("count", (Range("a", -1, 1),
                                          Range("c", -1, 1)))], mixed)


def test_conjunction_intersects_repeated_columns(rng):
    """Two Range terms on the same column intersect; an empty intersection
    collapses to a zero-measure box (COUNT ~ 0, AVG exactly 0)."""
    store, a, *_ = _store(rng)
    eng = store.engine()
    both = eng.answers([
        AqpQuery("count", (Range("a", -1.0, 2.0), Range("a", 0.0, 5.0))),
        AqpQuery("count", (Range("a", 0.0, 2.0),)),
    ])
    assert both[0] == pytest.approx(both[1], rel=1e-6)
    empty = eng.execute([
        AqpQuery("count", (Range("a", -2.0, -1.0), Range("a", 1.0, 2.0))),
        AqpQuery("avg", (Range("a", -2.0, -1.0), Range("a", 1.0, 2.0)),
                 target="a"),
    ])
    assert empty[0].estimate == pytest.approx(0.0, abs=1e-3)
    assert empty[1].estimate == 0.0


def test_target_outside_predicates_uses_wide_axis(rng):
    """SUM/AVG of a column not mentioned in the predicates adds an
    unconstrained axis: AVG(b) WHERE code == v through the (code, b) joint."""
    n = 30_000
    code = rng.integers(0, 3, n).astype(np.float32)
    b = (code * 2.0 + rng.normal(0, 0.5, n)).astype(np.float32)
    store = TelemetryStore(capacity=2048, seed=0)
    store.track_joint(("code", "b"))
    store.add_batch({"code": code, "b": b})
    res = store.engine().execute(
        [AqpQuery("avg", (Eq("code", v),), target="b") for v in (0.0, 2.0)])
    for r, v in zip(res, (0.0, 2.0)):
        assert r.estimate == pytest.approx(float(b[code == v].mean()),
                                           abs=0.15)
        assert r.rel_width < np.inf           # the code axis is constrained
    whole = store.engine().execute(AqpQuery("sum", (), target="b"))[0]
    assert whole.rel_width == np.inf          # no constrained axis at all
    assert whole.estimate == pytest.approx(float(b.sum()), rel=0.1)


def test_set_matching_reorders_to_tracked_joint(rng):
    """Predicate column order need not match the tracked joint tuple."""
    store, a, b, _ = _store(rng)
    fwd = store.engine().answers(
        [AqpQuery("count", (Range("a", -1, 1), Range("b", -1, 1)))])
    rev = store.engine().answers(
        [AqpQuery("count", (Range("b", -1, 1), Range("a", -1, 1)))])
    np.testing.assert_array_equal(fwd, rev)
    sel = (np.abs(a) <= 1) & (np.abs(b) <= 1)
    assert fwd[0] == pytest.approx(float(sel.sum()), rel=0.1)


def test_result_metadata(rng):
    store, *_ = _store(rng)
    narrow, wide = store.engine().execute([
        AqpQuery("count", (Range("a", 0.0, 0.2),)),
        AqpQuery("count", (Range("a", -2.0, 2.0),)),
    ])
    assert narrow.rel_width < wide.rel_width
    assert narrow.synopsis_version == store.columns["a"].version
    assert float(narrow) == narrow.estimate
    assert narrow.query.aggregate == "count"
    store.add_batch({"a": np.ones(10, np.float32)})
    bumped = store.engine().execute(
        AqpQuery("count", (Range("a", 0.0, 0.2),)))[0]
    assert bumped.synopsis_version == narrow.synopsis_version + 1


@pytest.mark.parametrize("backend", ["pallas"])
def test_engine_pallas_backend_paths(rng, backend):
    store, *_ = _store(rng, n=8000, capacity=512)
    specs = [AqpQuery("count", (Range("a", -1, 1),)),
             AqpQuery("count", (Box(("a", "b"), (-1, -1), (1, 1)),))]
    res = store.engine(backend=backend).execute(specs)
    assert [r.path for r in res] == ["range1d:pallas", "box:pallas"]
    want = store.engine().answers(specs)
    got = np.asarray([r.estimate for r in res])
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-2)


# --- batched QMC fallback ----------------------------------------------------

def test_batched_qmc_matches_per_query_loop(rng):
    """The shared-node batched fallback agrees with the old per-query loop;
    identical boxes share the exact node set, so agreement is tight there."""
    from repro.core.aqp import box_qmc_terms
    from repro.core.aqp_multid import _qmc_box_answers

    x = jnp.asarray(rng.normal(0, 1, (384, 2)).astype(np.float32))
    H = jnp.asarray([[0.16, 0.05], [0.05, 0.2]], jnp.float32)
    syn = KDESynopsis(x=x, H=H, n_source=384)
    same = [BoxQuery(op, (-1.0, -1.2), (1.2, 1.0), target=t)
            for op, t in (("count", 0), ("sum", 1), ("avg", 0))]
    got = _qmc_box_answers(syn, same)
    for q, g in zip(same, got):
        cnt, sm = box_qmc_terms(x, H, jnp.asarray(q.lo), jnp.asarray(q.hi),
                                target=q.target_index())
        want = {"count": float(cnt), "sum": float(sm),
                "avg": float(sm) / float(cnt)}[q.op]
        assert g == pytest.approx(want, rel=1e-4)

    mixed = [BoxQuery("count", tuple(lo), tuple(lo + rng.uniform(1.0, 2.5, 2)))
             for lo in [rng.uniform(-2.0, 0.0, 2) for _ in range(6)]]
    got = _qmc_box_answers(syn, mixed)
    for q, g in zip(mixed, got):
        cnt, _ = box_qmc_terms(x, H, jnp.asarray(q.lo), jnp.asarray(q.hi))
        assert g == pytest.approx(float(cnt), rel=0.08, abs=2.0)


def test_full_h_group_in_engine_close_to_closed_form(rng):
    """A full-H selector routes to the qmc path and lands near the
    diagonal-bandwidth closed-form answer for the same box."""
    x = rng.normal(0, 1, (512, 2)).astype(np.float32)
    store = TelemetryStore(capacity=512, seed=0)
    store.track_joint(("u", "v"))
    store.add_batch({"u": x[:, 0], "v": x[:, 1]})
    spec = AqpQuery("count", (Box(("u", "v"), (-1.5, -1.0), (1.0, 1.5)),))
    diag = store.engine().execute(spec, selector="plugin")[0]
    full = store.engine().execute(spec, selector="lscv_H")[0]
    assert diag.path == "box" and full.path == "qmc"
    assert full.estimate == pytest.approx(diag.estimate, rel=0.1)
