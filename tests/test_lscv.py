"""LSCV_h / LSCV_H selectors: float64 oracles, the §4.5 reformulation
equivalence, SPD constraints, Nelder-Mead behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import g_of_H, lscv_H, lscv_h
from repro.core.lscv import g_of_h_sequential, h0_start, h_start, matrix_sqrt
from repro.core.nelder_mead import minimize as nm_minimize


def test_g_of_h_matches_float64_oracle(rng):
    x = rng.normal(0.0, 1.0, (150, 3)).astype(np.float32)
    res = lscv_h(jnp.asarray(x), n_h=7)
    for i in [0, 3, 6]:
        oracle = g_of_h_sequential(x, float(res.h_grid[i]))
        assert float(res.g_values[i]) == pytest.approx(oracle, rel=2e-3)


def test_store_s_fused_pallas_agree(rng):
    """Paper two-phase (store S), streaming fused, and the Pallas kernels all
    evaluate the same objective (the §4.5 claim: same values, fewer ops)."""
    x = rng.normal(0.0, 2.0, (220, 2)).astype(np.float32)
    a = lscv_h(jnp.asarray(x), n_h=20, store_s=True)
    b = lscv_h(jnp.asarray(x), n_h=20, store_s=False)
    c = lscv_h(jnp.asarray(x), n_h=20, backend="pallas")
    np.testing.assert_allclose(a.g_values, b.g_values, rtol=3e-4)
    np.testing.assert_allclose(a.g_values, c.g_values, rtol=3e-4)
    assert float(a.h) == float(b.h) == float(c.h)


def test_h0_1d_is_silverman():
    # eq. (28) for d=1 must reduce to (4/3)^(1/5) n^(-1/5)
    n = 1000
    assert h0_start(n, 1) == pytest.approx((4.0 / 3.0) ** 0.2 * n ** -0.2, rel=1e-6)


def test_optimum_interior(rng):
    x = rng.normal(0.0, 1.0, 400).astype(np.float32)
    res = lscv_h(jnp.asarray(x))
    # argmin not on the search boundary (eq. 29 interval is adequate)
    assert float(res.h_grid[0]) < float(res.h) < float(res.h_grid[-1])


def test_scale_equivariance_lscv_h(rng):
    x = rng.normal(0.0, 1.0, 300).astype(np.float32)
    h1 = float(lscv_h(jnp.asarray(x)).h)
    h2 = float(lscv_h(jnp.asarray(3.0 * x)).h)
    # the Mahalanobis kernel whitens by Sigma, so h is scale-invariant
    assert h2 == pytest.approx(h1, rel=5e-2)


def test_g_of_H_oracle(rng):
    x = rng.normal(0.0, 1.0, (120, 2)).astype(np.float32)
    H = np.array([[0.2, 0.03], [0.03, 0.3]], np.float32)

    # float64 numpy oracle of eq. (32)
    import math
    xd = x.astype(np.float64)
    Hd = H.astype(np.float64)
    n, d = xd.shape
    det = np.linalg.det(Hd)
    inv = np.linalg.inv(Hd)
    acc = 0.0
    for i in range(n):
        for j in range(i + 1, n):
            u = xd[i] - xd[j]
            s = u @ inv @ u
            acc += ((4 * math.pi) ** (-d / 2) * det ** -0.5 * math.exp(-0.25 * s)
                    - 2 * (2 * math.pi) ** (-d / 2) * det ** -0.5 * math.exp(-0.5 * s))
    oracle = 2.0 / (n * n) * acc + 2.0 ** (-d) * math.pi ** (-d / 2) * det ** -0.5 / n

    got = float(g_of_H(jnp.asarray(x), jnp.asarray(H)))
    got_pallas = float(g_of_H(jnp.asarray(x), jnp.asarray(H), backend="pallas"))
    assert got == pytest.approx(oracle, rel=2e-3)
    assert got_pallas == pytest.approx(oracle, rel=2e-3)


def test_lscv_H_improves_and_is_spd(rng):
    x = rng.normal(0.0, 1.0, (200, 2)).astype(np.float32)
    x[:, 1] = 0.6 * x[:, 0] + 0.8 * x[:, 1]
    res = lscv_H(jnp.asarray(x), max_iter=80)
    g_start = float(g_of_H(jnp.asarray(x), res.H_start))
    assert float(res.g) <= g_start + 1e-7          # NM never worsens
    w = np.linalg.eigvalsh(np.asarray(res.H, np.float64))
    assert (w > 0).all()                           # SPD by construction


def test_h_start_matches_eq37(rng):
    x = rng.normal(0.0, 2.0, (500, 3)).astype(np.float32)
    n, d = x.shape
    H0 = np.asarray(h_start(jnp.asarray(x)), np.float64)
    sigma = np.cov(x.astype(np.float64), rowvar=False)
    expect = (4.0 / (d + 2)) ** (1.0 / (d + 4)) * n ** (-1.0 / (d + 4)) * \
        _sqrtm(sigma)
    np.testing.assert_allclose(H0, expect, rtol=2e-2)


def _sqrtm(a):
    w, v = np.linalg.eigh(a)
    return (v * np.sqrt(w)) @ v.T


def test_nelder_mead_on_rosenbrock():
    def rosen(p):
        return (1 - p[0]) ** 2 + 100.0 * (p[1] - p[0] ** 2) ** 2

    res = nm_minimize(rosen, jnp.asarray([-1.2, 1.0], jnp.float32), max_iter=400,
                      init_scale=0.5)
    assert float(res.fun) < 1e-2
    np.testing.assert_allclose(np.asarray(res.x), [1.0, 1.0], atol=0.15)
