"""Per-kernel shape/dtype sweeps: every Pallas kernel vs its ref.py oracle
(interpret mode on CPU), plus the Appendix-A triangle index math."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # hypothesis is optional: property tests skip below
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.kernels.triangle import bx_to_ql, n_tri_tiles, ql_to_bx


def _check_triangle_roundtrip(bx):
    q, l = bx_to_ql(jnp.asarray([bx]))
    assert int(ql_to_bx(q, l)[0]) == bx
    assert 0 <= int(q[0]) <= int(l[0])


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(bx=st.integers(0, 10_000_000))
    def test_triangle_roundtrip(bx):
        _check_triangle_roundtrip(bx)
else:
    @pytest.mark.parametrize("bx", [0, 1, 2, 5, 977, 123_456, 10_000_000])
    def test_triangle_roundtrip(bx):
        _check_triangle_roundtrip(bx)


@pytest.mark.parametrize("n", [5, 64, 257, 1000])
@pytest.mark.parametrize("kind", ["k4", "k6", "gauss"])
def test_pairwise_ksum(n, kind):
    # dedicated per-case generator: K^(6) pair sums can cancel towards zero,
    # so the comparison needs deterministic data + a |sum|-scaled atol.
    local = np.random.default_rng(1234 + n)
    x = jnp.asarray(local.normal(0, 1, n).astype(np.float32))
    g = jnp.float32(0.4)
    a = ops.pairwise_scaled_ksum(x, g, kind=kind, tile=64)
    b = ref.pairwise_scaled_ksum(x, g, kind)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4,
                               atol=max(1e-5, 1e-6 * n))


@pytest.mark.parametrize("n,d", [(9, 1), (64, 2), (130, 5), (300, 16)])
@pytest.mark.parametrize("alg", ["paper", "mxu"])
def test_sv_matrix(rng, n, d, alg):
    x = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
    m0 = rng.normal(0, 1, (d, d)).astype(np.float32)
    m = jnp.asarray(0.2 * (m0 @ m0.T) + np.eye(d, dtype=np.float32))
    a = ops.sv_matrix(x, m, tile=64, algorithm=alg)
    b = ref.sv_matrix(x, m)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,d", [(40, 2), (222, 4), (513, 8)])
def test_gh_fused(rng, n, d):
    x = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
    m0 = rng.normal(0, 1, (d, d)).astype(np.float32)
    m = jnp.asarray(0.1 * (m0 @ m0.T) + np.eye(d, dtype=np.float32))
    a = ops.gh_fused_sum(x, m, 0.31, 0.17, tile=64)
    b = ref.gh_fused_sum(x, m, 0.31, 0.17)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-4)


@pytest.mark.parametrize("n,d,n_h", [(100, 2, 5), (257, 3, 13)])
def test_lscv_grid(rng, n, d, n_h):
    x = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
    m0 = rng.normal(0, 1, (d, d)).astype(np.float32)
    m = jnp.asarray(0.1 * (m0 @ m0.T) + np.eye(d, dtype=np.float32))
    hg = jnp.linspace(0.3, 2.0, n_h).astype(jnp.float32)
    a = ops.lscv_grid_sums(x, m, hg, 0.3, 0.2, tile=64, h_tile=4)
    b = ref.lscv_grid_sums(x, m, hg, 0.3, 0.2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("m,n,d", [(3, 17, 1), (65, 64, 2), (128, 500, 8)])
def test_kde_eval(rng, m, n, d):
    pts = jnp.asarray(rng.normal(0, 1, (m, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
    a = ops.kde_eval(pts, x, jnp.float32(0.6), tile=64)
    b = ref.kde_eval(pts, x, jnp.float32(0.6))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-7)


def test_kernels_match_at_tile_boundaries(rng):
    """Exercise n == tile, n == tile+1, n == 2*tile-1 edge shapes."""
    for n in [64, 65, 127, 128]:
        x = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
        a = ops.pairwise_scaled_ksum(x, jnp.float32(0.5), kind="k4", tile=64)
        b = ref.pairwise_scaled_ksum(x, jnp.float32(0.5), "k4")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4, atol=1e-5)


@pytest.mark.parametrize("n,q", [(17, 3), (64, 16), (500, 257)])
def test_aqp_batch_sums(rng, n, q):
    x = jnp.asarray(rng.normal(0, 2, n).astype(np.float32))
    a = jnp.asarray(rng.uniform(-4, 4, q).astype(np.float32))
    b = a + jnp.asarray(rng.uniform(0, 3, q).astype(np.float32))
    h = jnp.float32(0.5)
    c1, s1 = ops.aqp_batch_sums(x, h, a, b, tile=64, q_tile=16)
    c2, s2 = ref.aqp_batch_sums(x, h, a, b)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,q,d", [(17, 3, 2), (64, 16, 3), (500, 130, 4)])
def test_aqp_box_sums(rng, n, q, d):
    x = jnp.asarray(rng.normal(0, 1.5, (n, d)).astype(np.float32))
    h = jnp.asarray(rng.uniform(0.2, 0.8, d).astype(np.float32))
    lo = jnp.asarray(rng.uniform(-3, 1, (q, d)).astype(np.float32))
    hi = lo + jnp.asarray(rng.uniform(0.2, 3, (q, d)).astype(np.float32))
    tgt = jnp.asarray(rng.integers(0, d, q), jnp.int32)
    c1, s1 = ops.aqp_box_sums(x, h, lo, hi, tgt, tile=64, q_tile=16)
    c2, s2 = ref.aqp_box_sums(x, h, lo, hi, tgt)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_aqp_box_sums_tile_boundaries(rng):
    """n == tile, n == tile+1, q == q_tile, q == q_tile+1 edge shapes."""
    d = 2
    h = jnp.asarray([0.4, 0.6], jnp.float32)
    for n, q in [(64, 16), (65, 17), (127, 15), (128, 16)]:
        x = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
        lo = jnp.asarray(rng.uniform(-2, 0, (q, d)).astype(np.float32))
        hi = lo + 1.5
        tgt = jnp.asarray(rng.integers(0, d, q), jnp.int32)
        c1, s1 = ops.aqp_box_sums(x, h, lo, hi, tgt, tile=64, q_tile=16)
        c2, s2 = ref.aqp_box_sums(x, h, lo, hi, tgt)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_aqp_box_sums_empty_sample():
    """Zero grid iterations must not expose uninitialized output memory."""
    x = jnp.zeros((0, 3), jnp.float32)
    lo = jnp.zeros((2, 3), jnp.float32)
    hi = jnp.ones((2, 3), jnp.float32)
    tgt = jnp.zeros((2,), jnp.int32)
    c, s = ops.aqp_box_sums(x, jnp.ones((3,), jnp.float32), lo, hi, tgt)
    np.testing.assert_array_equal(np.asarray(c), 0.0)
    np.testing.assert_array_equal(np.asarray(s), 0.0)


@pytest.mark.parametrize("n,G,d,g_axis", [
    (17, 3, 2, 0), (64, 16, 3, 1), (65, 17, 2, 1), (127, 1, 4, 2),
    (128, 64, 2, 0), (500, 33, 3, 2), (200, 7, 1, 0)])
def test_aqp_grouped_sums(rng, n, G, d, g_axis):
    """Grouped kernel vs oracle across tile boundaries, G=1, d=1, odd G."""
    x = jnp.asarray(rng.normal(0, 1.5, (n, d)).astype(np.float32))
    h = jnp.asarray(rng.uniform(0.2, 0.8, d).astype(np.float32))
    lo = jnp.asarray(rng.uniform(-3, 0, d).astype(np.float32))
    hi = lo + jnp.asarray(rng.uniform(1, 4, d).astype(np.float32))
    glo = jnp.asarray(np.sort(rng.uniform(-2, 2, G)).astype(np.float32))
    ghi = glo + 0.5
    for tgt in {0, g_axis, d - 1}:
        c1, s1 = ops.aqp_grouped_sums(x, h, lo, hi, glo, ghi, g_axis, tgt,
                                      tile=64, g_tile=16)
        c2, s2 = ref.aqp_grouped_sums(x, h, lo, hi, glo, ghi, g_axis, tgt)
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-4, atol=1e-4)


def test_aqp_grouped_sums_matches_box_fanout(rng):
    """The factored pass answers exactly what per-category box fan-out
    answers: each category's box is the shared box with the group axis
    replaced by its window."""
    n, d, G, g_axis, tgt = 300, 3, 9, 1, 2
    x = jnp.asarray(rng.normal(0, 1.2, (n, d)).astype(np.float32))
    h = jnp.asarray(rng.uniform(0.3, 0.7, d).astype(np.float32))
    lo = jnp.asarray(rng.uniform(-2, 0, d).astype(np.float32))
    hi = lo + 2.5
    glo = jnp.asarray(np.arange(G, dtype=np.float32) - 4.0)
    ghi = glo + 0.8
    blo = jnp.tile(lo, (G, 1)).at[:, g_axis].set(glo)
    bhi = jnp.tile(hi, (G, 1)).at[:, g_axis].set(ghi)
    tgts = jnp.full((G,), tgt, jnp.int32)
    c1, s1 = ops.aqp_grouped_sums(x, h, lo, hi, glo, ghi, g_axis, tgt,
                                  tile=64, g_tile=16)
    c2, s2 = ops.aqp_box_sums(x, h, blo, bhi, tgts, tile=64, q_tile=16)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-3)


def test_aqp_grouped_sums_empty():
    """Zero grid iterations must not expose uninitialized output memory."""
    x = jnp.zeros((0, 2), jnp.float32)
    lo = jnp.zeros((2,), jnp.float32)
    hi = jnp.ones((2,), jnp.float32)
    glo = jnp.asarray([0.0, 1.0], jnp.float32)
    ghi = glo + 0.5
    c, s = ops.aqp_grouped_sums(x, jnp.ones((2,), jnp.float32), lo, hi,
                                glo, ghi, 0, 1)
    np.testing.assert_array_equal(np.asarray(c), 0.0)
    np.testing.assert_array_equal(np.asarray(s), 0.0)


@pytest.mark.parametrize("n,d,q,m", [
    (17, 2, 3, 33), (64, 3, 16, 64), (65, 2, 17, 129), (100, 1, 1, 200),
    (128, 4, 15, 256)])
def test_qmc_box_reduce(rng, n, d, q, m):
    """Fused QMC kernel vs dense oracle: non-tile-multiple n/m, q=1, d=1."""
    x = jnp.asarray(rng.normal(0, 1.0, (n, d)).astype(np.float32))
    nodes = jnp.asarray(rng.uniform(-2, 2, (m, d)).astype(np.float32))
    A = rng.normal(0, 0.3, (d, d))
    Hm = (A @ A.T + np.eye(d) * 0.5).astype(np.float32)
    h_inv = jnp.asarray(np.linalg.inv(Hm))
    log_norm = jnp.float32(-0.5 * d * np.log(2 * np.pi)
                           - 0.5 * np.linalg.slogdet(Hm)[1])
    lo = jnp.asarray(rng.uniform(-2, 0, (q, d)).astype(np.float32))
    hi = lo + 1.5
    tgt = jnp.asarray(rng.integers(0, d, q), jnp.int32)
    c1, s1 = ops.qmc_box_reduce(nodes, x, h_inv, log_norm, lo, hi, tgt,
                                tile=64, m_tile=32, q_tile=8)
    c2, s2 = ref.qmc_box_reduce(nodes, x, h_inv, log_norm, lo, hi, tgt)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-6)


def test_qmc_box_reduce_empty():
    """Zero grid iterations must not expose uninitialized output memory."""
    x = jnp.zeros((0, 2), jnp.float32)
    nodes = jnp.zeros((4, 2), jnp.float32)
    h_inv = jnp.eye(2, dtype=jnp.float32)
    lo = jnp.zeros((3, 2), jnp.float32)
    hi = jnp.ones((3, 2), jnp.float32)
    tgt = jnp.zeros((3,), jnp.int32)
    c, s = ops.qmc_box_reduce(nodes, x, h_inv, jnp.float32(0.0), lo, hi, tgt)
    np.testing.assert_array_equal(np.asarray(c), 0.0)
    np.testing.assert_array_equal(np.asarray(s), 0.0)


def test_env_tile_override(monkeypatch):
    """TILE/Q_TILE defaults resolve through env vars (real-TPU tuning)."""
    from repro import knobs
    from repro.kernels.tuning import env_int

    monkeypatch.setitem(
        knobs.KNOBS, "REPRO_TEST_TILE",
        knobs.Knob("REPRO_TEST_TILE", "int", 128, "scratch knob for this test"))
    monkeypatch.setenv("REPRO_TEST_TILE", "512")
    assert env_int("REPRO_TEST_TILE", 128) == 512
    monkeypatch.delenv("REPRO_TEST_TILE")
    assert env_int("REPRO_TEST_TILE", 128) == 128
    monkeypatch.setenv("REPRO_TEST_TILE", "not-a-number")
    with pytest.raises(ValueError, match="positive integer"):
        env_int("REPRO_TEST_TILE", 128)
    monkeypatch.setenv("REPRO_TEST_TILE", "-4")
    with pytest.raises(ValueError, match="positive integer"):
        env_int("REPRO_TEST_TILE", 128)
    with pytest.raises(KeyError, match="unregistered"):
        env_int("REPRO_NOT_REGISTERED_TILE", 128)


def test_aqp_batch_sums_empty_sample():
    """Zero grid iterations must not expose uninitialized output memory."""
    x = jnp.zeros((0,), jnp.float32)
    a = jnp.asarray([0.0, 1.0], jnp.float32)
    b = jnp.asarray([1.0, 2.0], jnp.float32)
    c, s = ops.aqp_batch_sums(x, jnp.float32(0.5), a, b)
    np.testing.assert_array_equal(np.asarray(c), 0.0)
    np.testing.assert_array_equal(np.asarray(s), 0.0)
