"""Tile autotuner: call-time resolution, sweep invariants, cache persistence.

The import-freeze regression matters most: tiles used to be baked into
wrapper defaults at import (`tile=_mod.TILE`), so env changes or sweep
results after import could never move them.  Every resolution here happens
with the env/cache mutated AFTER repro.kernels is imported.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.kernels import autotune, ops
from repro.kernels.tuning import resolve_tile


@pytest.fixture(autouse=True)
def _fresh_tuner(monkeypatch):
    monkeypatch.delenv("REPRO_TUNING_CACHE", raising=False)
    autotune.reset()
    yield
    autotune.reset()


def test_resolve_tile_call_time_env(monkeypatch):
    """Env changes after import move the resolved tile (no import freeze)."""
    from repro import knobs

    monkeypatch.setitem(
        knobs.KNOBS, "REPRO_AT_TEST_TILE",
        knobs.Knob("REPRO_AT_TEST_TILE", "int", 128,
                   "scratch knob for this test"))
    monkeypatch.delenv("REPRO_AT_TEST_TILE", raising=False)
    assert resolve_tile("REPRO_AT_TEST_TILE", 128) == 128
    monkeypatch.setenv("REPRO_AT_TEST_TILE", "32")
    assert resolve_tile("REPRO_AT_TEST_TILE", 128) == 32
    assert resolve_tile("REPRO_AT_TEST_TILE", 128, override=64) == 64
    with pytest.raises(ValueError, match="positive integer"):
        resolve_tile("REPRO_AT_TEST_TILE", 128, override=0)


def test_ops_wrapper_resolves_env_at_call_time(monkeypatch):
    """The ops.py wrapper picks up a late env override — observed through
    the tile label on the recorded kernel metrics."""
    monkeypatch.setenv("REPRO_AQP_BOXES_TILE", "32")
    monkeypatch.setenv("REPRO_AQP_BOXES_Q_TILE", "8")
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (40, 2))
                    .astype(np.float32))
    lo = jnp.asarray([[-1.0, -1.0]], jnp.float32)
    hi = jnp.asarray([[1.0, 1.0]], jnp.float32)
    tgt = jnp.zeros((1,), jnp.int32)
    was = obs.enabled()
    obs.enable()
    try:
        ops.aqp_box_sums(x, jnp.ones((2,), jnp.float32), lo, hi, tgt)
    finally:
        if not was:
            obs.disable()
    rows = [labels for labels, _h in obs.get_registry().collect_histograms(
        "kernel.wall_us", kernel="aqp_box_sums", tile="32", q_tile="8")]
    assert rows, "late env override did not reach the kernel dispatch"


def test_shape_key_buckets_sizes_not_d():
    k1 = autotune.shape_key("k", {"n": 500, "d": 3, "G": 17})
    k2 = autotune.shape_key("k", {"n": 512, "d": 3, "G": 32})
    k3 = autotune.shape_key("k", {"n": 512, "d": 4, "G": 32})
    assert k1 == k2        # 500 -> 512, 17 -> 32
    assert k2 != k3        # d stays exact


def test_sweep_winner_never_slower_than_default():
    entry = autotune.sweep("aqp_grouped_sums", {"n": 256, "d": 2, "G": 16},
                           repeats=2, quick=True, persist=False)
    assert entry["us"] <= entry["default_us"]
    assert entry["swept"][0]["tiles"] == entry["default_tiles"]
    assert autotune.lookup("aqp_grouped_sums",
                           {"n": 256, "d": 2, "G": 16}) == entry["tiles"]


def test_sweep_unknown_kernel():
    with pytest.raises(KeyError, match="no sweep registered"):
        autotune.sweep("nope", {"n": 8})


def test_cache_persists_and_fresh_process_loads_without_resweep(
        tmp_path, monkeypatch):
    """The acceptance path: sweep once, persist, simulate a fresh process
    (reset), and require the cached tiles to resolve with ZERO sweeps."""
    cache = tmp_path / "tiles.json"
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(cache))
    shape = {"n": 256, "d": 2, "G": 8}
    entry = autotune.sweep("aqp_box_sums", shape, repeats=1, quick=True)
    doc = json.loads(cache.read_text())
    assert doc["version"] == 1 and len(doc["entries"]) == 1

    autotune.reset()                       # fresh process
    reg = obs.get_registry()
    sweeps_before = reg.sum_counter("autotune.sweeps")
    tiles = autotune.resolve(
        "aqp_box_sums", shape,
        tile=(None, "REPRO_AQP_BOXES_TILE", 128),
        q_tile=(None, "REPRO_AQP_BOXES_Q_TILE", 64))
    assert tiles == (entry["tiles"]["tile"], entry["tiles"]["q_tile"])
    assert reg.sum_counter("autotune.sweeps") == sweeps_before


def test_cached_tiles_lose_to_explicit_kwarg(tmp_path, monkeypatch):
    cache = tmp_path / "tiles.json"
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(cache))
    shape = {"n": 128, "d": 2, "G": 8}
    autotune.record("aqp_box_sums", shape, {"tile": 512, "q_tile": 256})
    tiles = autotune.resolve(
        "aqp_box_sums", shape,
        tile=(32, "REPRO_AQP_BOXES_TILE", 128),
        q_tile=(None, "REPRO_AQP_BOXES_Q_TILE", 64))
    assert tiles == (32, 256)              # kwarg wins, cache fills the rest


def test_load_cache_rejects_unknown_version(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"version": 99, "entries": []}))
    with pytest.raises(ValueError, match="unsupported tile-cache version"):
        autotune.load_cache(str(p))


def test_engine_grouped_backend_parity(rng):
    """Engine GROUP BY answers on the Pallas backend: bit-identical to the
    direct kernel-level computation (no drift through engine plumbing) and
    allclose to the jnp backend."""
    from repro.core.aqp_query import AqpQuery, Range
    from repro.data.aqp_store import TelemetryStore

    n = 20_000
    code = rng.integers(0, 4, n).astype(np.float32)
    b = (code + rng.normal(0, 0.4, n)).astype(np.float32)
    store = TelemetryStore(capacity=1024, seed=0)
    store.track_joint(("code", "b"))
    store.add_batch({"code": code, "b": b})
    q = AqpQuery("count", (Range("b", -2.0, 6.0),), group_by="code")

    r_jnp = store.engine().execute(q)
    r_pal = store.engine(backend="pallas").execute(q)
    assert all(r.path == "box:grouped" for r in r_jnp)
    assert all(r.path == "box:grouped:pallas" for r in r_pal)
    np.testing.assert_allclose([r.estimate for r in r_pal],
                               [r.estimate for r in r_jnp],
                               rtol=1e-4, atol=1e-2)

    # bit-identity: the engine's pallas answers equal the direct kernel call
    # with the same geometry and scale
    from repro.core.aqp_multid import batch_query_box_grouped
    from repro.core.aqp_query import _pad_count, _pad_rows
    eng = store.engine(backend="pallas")
    resolve = eng.resolver()
    fam = [resolve(ci)[1] for ci in eng.compile([q])]
    _key, _c2, plan, _ver = resolve(fam[0])
    g_axis = fam[0].group_axis
    gm = _pad_count(len(fam))
    glo = _pad_rows(np.asarray([e.lo[g_axis] for e in fam], np.float32), gm)
    ghi = _pad_rows(np.asarray([e.hi[g_axis] for e in fam], np.float32), gm)
    direct = batch_query_box_grouped(
        plan.x_rows, plan.syn.h_diag(), fam[0].lo, fam[0].hi, glo, ghi,
        g_axis=g_axis, tgt=fam[0].tgt, op=fam[0].op,
        scale=jnp.float32(plan.scale), backend="pallas")
    np.testing.assert_array_equal(
        np.asarray(direct, np.float64)[:len(fam)],
        np.asarray([r.estimate for r in r_pal]))


def test_engine_qmc_backend_parity(rng):
    """qmc:pallas fused-kernel answers match the jnp qmc path to rtol 1e-5
    on a reasonably-conditioned full-H synopsis."""
    from repro.core.aqp_query import AqpQuery, Range
    from repro.data.aqp_store import TelemetryStore

    n = 20_000
    a = rng.normal(0, 1.0, n).astype(np.float32)
    b = rng.normal(1.0, 1.5, n).astype(np.float32)
    store = TelemetryStore(capacity=1024, seed=0)
    store.track_joint(("a", "b"))
    store.add_batch({"a": a, "b": b})
    qs = [AqpQuery("count", (Range("a", -1.0, 1.0), Range("b", 0.0, 2.0)),
                   selector="lscv_H"),
          AqpQuery("avg", (Range("a", -1.0, 1.0), Range("b", 0.0, 2.0)),
                   target="b", selector="lscv_H"),
          AqpQuery("sum", (Range("a", -0.5, 2.0), Range("b", -1.0, 3.0)),
                   target="a", selector="lscv_H")]
    r_jnp = store.engine().execute(qs)
    r_pal = store.engine(backend="pallas").execute(qs)
    assert {r.path for r in r_jnp} == {"qmc"}
    assert {r.path for r in r_pal} == {"qmc:pallas"}
    np.testing.assert_allclose([r.estimate for r in r_pal],
                               [r.estimate for r in r_jnp],
                               rtol=1e-5, atol=1e-3)
