"""TieredReservoir (data/aqp_store.py) and progressive execution
(core/aqp_query.py): geometric tier ladder invariants, chained weighted
merges, per-code stratification keeping rare GROUP BY groups alive, the
checkpoint round-trip (save -> load -> add_batch bit-identity, mirroring
test_aqp_durability.py), and progressive mode whose final round reproduces
plain execute bit-for-bit while CI widths tighten tier over tier."""
import numpy as np
import pytest

from repro.core import AqpQuery, Box, GroupBy, Range
from repro.data import TelemetryStore, TieredReservoir


def _tiered_store(rng, n=30_000, capacity=1024, n_tiers=4):
    """Tiered 1-D column (stratified), tiered joint, plain column, exact
    sketch — every durable shape the tiered checkpoint format covers."""
    store = TelemetryStore(capacity=capacity, seed=0)
    store.track_tiered("loss", n_tiers=n_tiers)
    store.track_tiered(("a", "b"), n_tiers=n_tiers, strat_column="a")
    store.track_tiered("code", n_tiers=n_tiers, strat_column="code")
    store.track_categorical("kind")
    a = rng.normal(0, 1, n).astype(np.float32)
    store.add_batch({
        "loss": rng.gamma(2.0, 1.5, n).astype(np.float32),
        "a": a,
        "b": (0.8 * a + 0.6 * rng.normal(0, 1, n)).astype(np.float32),
        "code": rng.integers(0, 4, n).astype(np.float32),
        "kind": rng.integers(0, 3, n).astype(np.float32),
        "plain": rng.normal(2, 1, n).astype(np.float32),
    })
    return store


def _batch(rng, n=4_000):
    a = rng.normal(0.3, 1, n).astype(np.float32)
    return {
        "loss": rng.gamma(2.0, 1.5, n).astype(np.float32),
        "a": a,
        "b": (0.8 * a + 0.6 * rng.normal(0, 1, n)).astype(np.float32),
        "code": rng.integers(0, 4, n).astype(np.float32),
        "kind": rng.integers(0, 3, n).astype(np.float32),
        "plain": rng.normal(2, 1, n).astype(np.float32),
    }


_SPECS = [
    AqpQuery("count", (Range("loss", 1.0, 4.0),)),
    AqpQuery("sum", (Range("loss", 0.0, 6.0),), target="loss"),
    AqpQuery("avg", (Box(("a", "b"), (-1.0, -1.0), (1.0, 1.0)),),
             target="b"),
    AqpQuery("count", (Range("plain", 1.0, 3.0),)),
]


def _assert_members_identical(r1, r2):
    np.testing.assert_array_equal(r1.sample(), r2.sample())
    assert (r1.n_seen, r1.n_filled, r1.version) == \
        (r2.n_seen, r2.n_filled, r2.version)
    assert r1.rng.bit_generator.state == r2.rng.bit_generator.state


def _assert_tiered_identical(t1: TieredReservoir, t2: TieredReservoir):
    assert (t1.n_tiers, t1.capacity, t1.columns, t1.strat_column) == \
        (t2.n_tiers, t2.capacity, t2.columns, t2.strat_column)
    for a, b in zip(t1.tiers, t2.tiers):
        _assert_members_identical(a, b)
    assert sorted(t1.strata) == sorted(t2.strata)
    for code in t1.strata:
        _assert_members_identical(t1.strata[code], t2.strata[code])
    assert t1.strata_overflow == t2.strata_overflow


def _assert_rows_identical(r1, r2):
    for x, y in zip(r1, r2):
        assert x.estimate == y.estimate, (x, y)
        assert x.path == y.path and x.synopsis_version == y.synopsis_version
        assert (x.ci_lo, x.ci_hi, x.n_effective) == \
            (y.ci_lo, y.ci_hi, y.n_effective)
        assert x.group == y.group


# --- ladder invariants --------------------------------------------------------

def test_tier_geometry_counters_and_clamping(rng):
    res = TieredReservoir(capacity=1024, n_tiers=4, seed=0)
    res.add(rng.normal(0, 1, 5_000).astype(np.float32))
    assert res.tier_sizes() == [128, 256, 512, 1024]
    assert res.n_seen == 5_000 and res.n_filled == 1024 and res.version == 1
    np.testing.assert_array_equal(res.sample(), res.sample(3))
    np.testing.assert_array_equal(res.sample(99), res.sample(3))  # clamped
    np.testing.assert_array_equal(res.sample(-7), res.sample(0))
    assert res.sample(0).shape == (128,)
    with pytest.raises(ValueError, match="n_tiers"):
        TieredReservoir(capacity=64, n_tiers=0)
    with pytest.raises(ValueError, match="too small"):
        TieredReservoir(capacity=4, n_tiers=8)


def test_every_tier_is_a_sample_of_the_whole_stream(rng):
    """Each tier sees every row (not a partition): tier n_seen counters all
    equal the stream length, and each tier's retained rows are a subset of
    the stream's values."""
    res = TieredReservoir(capacity=256, n_tiers=4, seed=1)
    x = rng.normal(0, 1, 10_000).astype(np.float32)
    res.add(x)
    pool = set(x.tolist())
    for i, tier in enumerate(res.tiers):
        assert tier.n_seen == 10_000
        assert set(res.sample(i).tolist()) <= pool


# --- weighted merges ----------------------------------------------------------

def test_chained_weighted_merges_preserve_totals(rng):
    """Property: merge totals are preserved per tier AND per stratum across a
    chain of merges — each tier of the merged ladder claims exactly the sum
    of its parents' streams (the weighted-merge core, tier by tier)."""
    parts = []
    for i, (mu, n) in enumerate([(0.0, 8_000), (3.0, 4_000), (6.0, 2_000)]):
        t = TieredReservoir(capacity=128, n_tiers=3, seed=i,
                            strat_column="x", columns=None)
        vals = rng.normal(mu, 1, n).astype(np.float32)
        codes = rng.integers(0, 3, n).astype(np.float32) + 10.0 * i
        t.add(np.where(rng.random(n) < 0.5, vals, codes).astype(np.float32))
        parts.append(t)
    merged = parts[0].merge(parts[1]).merge(parts[2])
    for i in range(3):
        assert merged.tiers[i].n_seen == 14_000
        assert merged.tiers[i].n_filled == merged.tiers[i].capacity
    assert merged.n_seen == 14_000
    # strata union: every parent code present, totals additive
    want_codes = set()
    for p in parts:
        want_codes |= set(p.strata)
    assert set(merged.strata) == want_codes
    for code in want_codes:
        total = sum(p.strata[code].n_seen for p in parts if code in p.strata)
        assert merged.strata[code].n_seen == total


def test_merge_shape_mismatch_raises(rng):
    base = TieredReservoir(capacity=64, n_tiers=3)
    with pytest.raises(ValueError, match="different shape"):
        base.merge(TieredReservoir(capacity=64, n_tiers=2))
    with pytest.raises(ValueError, match="different shape"):
        base.merge(TieredReservoir(capacity=64, n_tiers=3,
                                   columns=("a", "b")))


# --- stratification: rare codes never lose their last representative ----------

def test_rare_code_survives_flood_and_one_sided_merge(rng):
    """A code seen 10 times in a 50k-row stream is (with overwhelming
    probability) displaced from every uniform tier, but its stratum keeps a
    representative — including through a merge with a ladder that never saw
    the code at all."""
    res = TieredReservoir(capacity=128, n_tiers=4, seed=0, strat_column="x")
    res.add(np.full(10, 9.0, np.float32))                  # the rare code
    res.add(rng.integers(0, 3, 50_000).astype(np.float32))  # the flood
    assert 9.0 not in set(res.sample().tolist())           # displaced
    assert 9.0 in res.codes()                              # still discovered
    stratum = res.stratum(9.0)
    assert stratum is not None and len(stratum) == 10
    np.testing.assert_array_equal(stratum, np.full(10, 9.0, np.float32))

    other = TieredReservoir(capacity=128, n_tiers=4, seed=5, strat_column="x")
    other.add(rng.integers(0, 3, 5_000).astype(np.float32))
    merged = res.merge(other)
    assert 9.0 in merged.codes()                           # one-sided survive
    assert len(merged.stratum(9.0)) == 10
    assert merged.stratum(123.0) is None


def test_max_strata_overflow_is_sticky_and_keeps_existing(rng):
    res = TieredReservoir(capacity=64, n_tiers=2, seed=0, strat_column="x",
                          max_strata=4)
    res.add(np.arange(4, dtype=np.float32))
    assert not res.strata_overflow
    res.add(np.arange(8, dtype=np.float32))       # 4 new codes rejected
    assert res.strata_overflow and len(res.strata) == 4
    assert res.codes() == [0.0, 1.0, 2.0, 3.0]
    assert res.strata[2.0].n_seen == 2            # existing keep updating
    # NaN codes are never stratified
    res.add(np.asarray([np.nan, 1.0], np.float32))
    assert not any(np.isnan(c) for c in res.codes())


# --- checkpoint round-trip (acceptance) ---------------------------------------

def test_tiered_roundtrip_then_add_batch_is_bit_identical(rng, tmp_path):
    """Acceptance: save -> load -> add_batch(B) equals the un-restored store
    fed the same batch — every tier buffer, stratum, counter, and RNG state
    bit-exact, and query answers (estimates AND confidence intervals)
    identical."""
    store = _tiered_store(rng)
    store.save(str(tmp_path))
    restored = TelemetryStore.load(str(tmp_path))
    _assert_tiered_identical(store.columns["loss"], restored.columns["loss"])
    _assert_tiered_identical(store.columns["code"], restored.columns["code"])
    _assert_tiered_identical(store.joints[("a", "b")],
                             restored.joints[("a", "b")])
    _assert_members_identical(store.columns["plain"],
                              restored.columns["plain"])

    batch = _batch(rng)
    store.add_batch(batch)
    restored.add_batch(batch)
    _assert_tiered_identical(store.columns["loss"], restored.columns["loss"])
    _assert_tiered_identical(store.columns["code"], restored.columns["code"])
    _assert_tiered_identical(store.joints[("a", "b")],
                             restored.joints[("a", "b")])
    _assert_rows_identical(store.query(_SPECS), restored.query(_SPECS))


def test_tiered_restore_warm_starts_synopses_and_plans(rng, tmp_path):
    """Per-tier fitted synopses ride in the snapshot and the shared engine's
    plans are primed on restore: a warm-started store answers previously-seen
    specs (including a tier-0 coarse pass) with zero cache misses and zero
    plan misses."""
    store = _tiered_store(rng, n=10_000, capacity=512)
    engine = store.shared_engine()
    compiled = engine.compile(_SPECS)
    engine.run_compiled(compiled, tier=0)          # fit tier-0 synopses
    want = engine.run_compiled(compiled)           # and the full-tier ones
    store.save(str(tmp_path))

    restored = TelemetryStore.load(str(tmp_path))
    r_engine = restored.shared_engine()
    misses0 = restored.cache.stats()["misses"]
    plan_misses0 = r_engine.plans.stats()["misses"]
    r_compiled = r_engine.compile(_SPECS)
    r_engine.run_compiled(r_compiled, tier=0)
    got = r_engine.run_compiled(r_compiled)
    assert restored.cache.stats()["misses"] == misses0
    assert r_engine.plans.stats()["misses"] == plan_misses0
    _assert_rows_identical(want, got)


def test_track_tiered_validation(rng):
    store = TelemetryStore(capacity=256, seed=0)
    with pytest.raises(ValueError, match="strat_column"):
        store.track_tiered("x", strat_column="y")
    store.add_batch({"x": rng.normal(0, 1, 100).astype(np.float32)})
    with pytest.raises(ValueError, match="before add_batch"):
        store.track_tiered("x")
    store.track_tiered("y", n_tiers=3)
    store.track_tiered("y", n_tiers=3)            # idempotent


# --- progressive execution ----------------------------------------------------

def test_progressive_final_round_matches_execute_bit_identically(rng):
    """mode="progressive" yields one result set per tier; the last round must
    reproduce plain execute() bit-for-bit (estimates, paths, versions, AND
    confidence intervals), because the top tier IS the full sample."""
    store = _tiered_store(rng)
    engine = store.shared_engine()
    rounds = list(engine.execute(_SPECS, mode="progressive"))
    assert [t for t, _ in rounds] == [0, 1, 2, 3]
    want = engine.execute(_SPECS)
    _assert_rows_identical(rounds[-1][1], want)


def test_progressive_ci_widths_tighten_and_n_effective_grows(rng):
    """Tier over tier, each query's effective sample grows geometrically and
    the median CI width shrinks; untiered columns stay constant across
    rounds (they have only the one sample)."""
    store = _tiered_store(rng)
    rounds = list(store.shared_engine().execute(_SPECS, mode="progressive"))
    tiered_q = 0                                   # Range on tiered "loss"
    plain_q = 3                                    # Range on untiered column
    n_eff = [r[1][tiered_q].n_effective for r in rounds]
    assert n_eff == [128, 256, 512, 1024]
    widths = np.asarray(
        [[q.ci_width for q in results] for _, results in rounds])
    assert np.all(np.isfinite(widths))
    med = np.median(widths, axis=1)
    assert all(a >= b for a, b in zip(med, med[1:]))          # tightening
    assert widths[0, tiered_q] > widths[-1, tiered_q]
    np.testing.assert_array_equal(widths[:, plain_q],
                                  np.full(4, widths[0, plain_q]))


def test_progressive_mode_validation(rng):
    store = _tiered_store(rng, n=2_000, capacity=256)
    with pytest.raises(ValueError, match="mode"):
        store.shared_engine().execute(_SPECS, mode="bogus")


# --- rare GROUP BY discovery via strata ---------------------------------------

def test_rare_group_discovered_from_strata_union(rng):
    """GROUP BY value discovery unions the uniform sample's codes with the
    strata codes: a 10-in-40k group displaced from every tier still gets a
    result row (with a real estimate from the KDE), instead of silently
    vanishing from the answer."""
    store = TelemetryStore(capacity=128, seed=0)
    store.track_tiered("code", strat_column="code")
    store.add_batch({"code": np.full(10, 9.0, np.float32)})
    store.add_batch(
        {"code": rng.integers(0, 3, 40_000).astype(np.float32)})
    res = store.columns["code"]
    assert 9.0 not in set(np.round(res.sample()).tolist())   # displaced
    rows = store.query(
        [AqpQuery("count", (), group_by=GroupBy("code"))])
    groups = {r.group for r in rows}
    assert groups == {0.0, 1.0, 2.0, 9.0}
    rare = next(r for r in rows if r.group == 9.0)
    assert np.isfinite(rare.estimate) and rare.estimate >= 0.0
