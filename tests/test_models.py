"""Per-architecture smoke tests (assignment requirement) + model invariants:
one forward/train step on CPU with a reduced same-family config, asserting
output shapes and no NaNs; prefill/decode consistency; MoE dispatch bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import batch_struct, build_model, param_structs
from repro.models.moe import moe_block, moe_params

pytestmark = pytest.mark.slow      # jit-heavy: excluded from tier-1


def _smoke_batch(cfg, B=2, S=64):
    batch = {"tokens": jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.ones((B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _smoke_batch(cfg)
    logits = jax.jit(model.apply)(params, batch)
    S_total = batch["tokens"].shape[1] + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_total, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, max_len = 2, 32
    cache = model.init_cache(B, max_len)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache pytree structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "falcon-mamba-7b", "zamba2-1.2b",
                                  "whisper-base"])
def test_prefill_decode_matches_full_forward(arch):
    """logits from (prefill S tokens, then decode token S) must match the
    teacher-forced forward on S+1 tokens at position S."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 16
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
    batch_full = {"tokens": toks}
    batch_prefix = {"tokens": toks[:, :S]}
    if cfg.family == "encdec":
        frames = jnp.asarray(rng.normal(0, 1, (B, cfg.enc_seq, cfg.d_model)), jnp.bfloat16)
        batch_full["enc_frames"] = frames
        batch_prefix["enc_frames"] = frames

    full_logits = model.apply(params, batch_full)                # (B, S+1, V)
    pre_logits, cache = model.prefill(params, batch_prefix)
    # pad time axis of KV caches from S to S+1 where applicable
    cache = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0)] * 2 + [(0, 1)] + [(0, 0)] * (a.ndim - 3))
        if a.ndim >= 3 and a.shape[2] == S else a, cache)
    step_logits, _ = model.decode_step(params, cache, toks[:, S:S + 1], jnp.int32(S))

    # bf16 params: the single-step decode path accumulates in a different
    # order than the full-sequence scan; tolerances sized to bf16 eps.
    np.testing.assert_allclose(np.asarray(pre_logits[:, -1], np.float32),
                               np.asarray(full_logits[:, S - 1], np.float32),
                               rtol=5e-2, atol=6e-2)
    np.testing.assert_allclose(np.asarray(step_logits[:, 0], np.float32),
                               np.asarray(full_logits[:, S], np.float32),
                               rtol=5e-2, atol=6e-2)


def test_moe_dispatch_capacity_and_gates():
    from repro.configs.base import get_config
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    p = moe_params(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model), jnp.float32)
    out = moe_block(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    # with capacity_factor >= 1 and uniform-ish routing, output is non-trivial
    assert float(jnp.mean(jnp.abs(out))) > 0


def test_vlm_loss_masks_image_positions():
    cfg = get_config("internvl2-2b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _smoke_batch(cfg)
    loss = float(model.loss(params, batch))
    # loss over text tokens only: finite and ~ log(padded vocab) at init
    assert 0 < loss < np.log(cfg.padded_vocab) + 2.0


def test_sliding_window_attention_limits_context():
    """hybrid apply(window=w) must equal apply() when w >= S, differ when small."""
    cfg = get_config("zamba2-1.2b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _smoke_batch(cfg, B=1, S=48)
    a = np.asarray(model.apply(params, batch), np.float32)
    b = np.asarray(model.apply(params, batch, window=64), np.float32)
    c = np.asarray(model.apply(params, batch, window=4), np.float32)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
    assert np.abs(a - c).max() > 1e-4
