"""Distributed (shard_map) KDE selectors + gradient compression, on a
multi-device placeholder mesh via subprocess (tests keep 1 device locally)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import gaussian as G
from repro.core.distributed import distributed_lscv_h, sharded_pairwise_reduce
from repro.core.reductions import pairwise_reduce
from repro.optim.grad_compress import compressed_psum, init_error, quantize


def _mesh1():
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])


def test_sharded_pairwise_single_device(rng):
    mesh = _mesh1()
    x = jnp.asarray(rng.normal(0, 1, 500).astype(np.float32))
    fun = lambda d: G.k6(d / 0.4)
    a = float(sharded_pairwise_reduce(fun, x, mesh))
    b = float(pairwise_reduce(fun, x))
    assert a == pytest.approx(b, rel=1e-4)


def test_distributed_lscv_h_single_device(rng):
    from repro.core import lscv_h
    mesh = _mesh1()
    x = jnp.asarray(rng.normal(0, 1, (200, 2)).astype(np.float32))
    h, grid, g = distributed_lscv_h(x, mesh, n_h=15)
    ref = lscv_h(x, n_h=15)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ref.g_values), rtol=1e-3)
    assert float(h) == pytest.approx(float(ref.h), rel=1e-4)


def test_multi_device_agreement_subprocess():
    """8 placeholder devices: distributed == single-path results."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core.distributed import distributed_lscv_h, sharded_pairwise_reduce
from repro.core.reductions import pairwise_reduce
from repro import compat
from repro.core import gaussian as G, lscv_h
rng = np.random.default_rng(1)
mesh = jax.make_mesh((4, 2), ("data", "model"))
x = jnp.asarray(rng.normal(0, 1, 1000).astype(np.float32))
fun = lambda d: G.k4(d / 0.3)
a = float(sharded_pairwise_reduce(fun, x, mesh))
b = float(pairwise_reduce(fun, x))
assert abs(a - b) / abs(b) < 1e-3, (a, b)
x2 = jnp.asarray(rng.normal(0, 1, (300, 3)).astype(np.float32))
h, grid, g = distributed_lscv_h(x2, mesh, n_h=20)
ref = lscv_h(x2, n_h=20)
np.testing.assert_allclose(np.asarray(g), np.asarray(ref.g_values), rtol=2e-3)
print("MULTIDEV_OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd="/root/repo", timeout=420, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MULTIDEV_OK" in r.stdout


def test_quantize_error_feedback_contracts(rng):
    g = jnp.asarray(rng.normal(0, 1, (64,)).astype(np.float32))
    err = jnp.zeros_like(g)
    q, scale, new_err = quantize(g, err)
    deq = np.asarray(q, np.float32) * float(scale)
    assert np.abs(deq - np.asarray(g)).max() <= float(scale) * 0.5 + 1e-6
    # residual exactly the quantisation error
    np.testing.assert_allclose(np.asarray(new_err), np.asarray(g) - deq, atol=1e-6)


def test_compressed_psum_matches_exact(rng):
    mesh = jax.make_mesh((1,), ("dp",), devices=jax.devices()[:1])
    from jax.sharding import PartitionSpec as P
    g = {"w": jnp.asarray(rng.normal(0, 1, (128,)).astype(np.float32))}
    e = init_error(g)

    def f(g, e):
        return compressed_psum(g, e, "dp")

    out, new_e = compat.shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=P())(g, e)
    # single replica: compressed mean == dequantised self, error small
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]), atol=0.02)
    # error feedback: adding residual back reconstructs g exactly
    np.testing.assert_allclose(np.asarray(out["w"]) + np.asarray(new_e["w"]),
                               np.asarray(g["w"]), atol=1e-6)
