"""Elastic re-meshing + straggler detection logic."""
import pytest

from repro.checkpoint import ElasticController, StragglerMonitor, plan_mesh


def test_plan_mesh_prefers_big_tp():
    assert plan_mesh(256, tp_divisor_of=(8192, 1280)) == (16, 16)
    assert plan_mesh(128, tp_divisor_of=(8192, 1280)) == (8, 16)


def test_plan_mesh_respects_divisors():
    # model dims divisible only by 4 -> tp capped at 4
    data, tp = plan_mesh(64, tp_divisor_of=(12, 20))
    assert tp == 4 and data == 16


def test_elastic_controller_failure_replans():
    ec = ElasticController(n_hosts=64, devices_per_host=4, tp_divisor_of=(8192,))
    assert ec.current_mesh() == (16, 16)
    data, tp = ec.fail(step=100, hosts=[0, 1, 2, 3])       # lose 16 devices
    assert data * tp <= 240
    assert tp == 16
    assert len(ec.events) == 1


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(k_sigma=2.0, patience=3)
    for step in range(20):
        for h in range(8):
            mon.record(h, 1.0 + (2.5 if h == 5 and step > 5 else 0.0))
        if step > 5:
            mon.update_strikes()
    assert 5 in mon.stragglers()
    assert all(h not in mon.stragglers() for h in range(5))


def test_straggler_monitor_recovers():
    mon = StragglerMonitor(k_sigma=2.0, patience=2)
    for _ in range(10):
        for h in range(4):
            mon.record(h, 1.0)
    assert mon.stragglers() == []
