"""End-to-end behaviour tests for the paper's system: the full AQP flow
(data -> synopsis -> queries), bandwidth selection on realistic mixtures, and
the KDE quality improvement that optimal bandwidths buy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KDESynopsis, kde_eval, lscv_h, plugin_bandwidth,
                        silverman_h)


def _bimodal(rng, n):
    a = rng.normal(-2.0, 0.5, n // 2)
    b = rng.normal(2.0, 1.0, n - n // 2)
    return np.concatenate([a, b]).astype(np.float32)


def _true_bimodal_pdf(x):
    from math import pi
    pa = np.exp(-0.5 * ((x + 2) / 0.5) ** 2) / (0.5 * np.sqrt(2 * pi))
    pb = np.exp(-0.5 * ((x - 2) / 1.0) ** 2) / (1.0 * np.sqrt(2 * pi))
    return 0.5 * pa + 0.5 * pb


def test_kde_with_plugin_bandwidth_recovers_density(rng):
    x = _bimodal(rng, 4000)
    h = plugin_bandwidth(jnp.asarray(x)).h
    grid = np.linspace(-5, 6, 200).astype(np.float32)
    f = np.asarray(kde_eval(jnp.asarray(grid), jnp.asarray(x), h))
    truth = _true_bimodal_pdf(grid)
    ise = np.trapezoid((f - truth) ** 2, grid)
    assert ise < 5e-3
    # density must integrate to ~1 and be bimodal
    assert np.trapezoid(f, grid) == pytest.approx(1.0, abs=0.02)
    mid = f[(grid > -0.5) & (grid < 0.5)].max()
    assert f[(grid > -2.6) & (grid < -1.4)].max() > 2 * mid


def test_lscv_h_beats_extreme_bandwidths(rng):
    """ISE with the LSCV-selected h must beat grossly over- and
    under-smoothed bandwidths — i.e. selection actually matters (the paper's
    motivation).  Unimodal data: LSCV's well-known small-sample
    undersmoothing on sharp mixtures would make a bimodal version of this
    assertion statistically flaky, not a code property."""
    x = rng.normal(0.0, 1.0, 1500).astype(np.float32)
    res = lscv_h(jnp.asarray(x))
    h = float(res.h)
    grid = np.linspace(-4.5, 4.5, 200).astype(np.float32)
    truth = np.exp(-0.5 * grid ** 2) / np.sqrt(2 * np.pi)

    def ise(hh):
        f = np.asarray(kde_eval(jnp.asarray(grid), jnp.asarray(x), jnp.float32(hh)))
        return np.trapezoid((f - truth) ** 2, grid)

    assert ise(h) < ise(h / 6.0)
    assert ise(h) < ise(6.0 * h)


def test_full_aqp_flow_three_selectors(rng):
    """The paper's end-to-end scenario: a numeric column, three selector
    classes (rule-of-thumb / plug-in / cross-validation), range aggregates."""
    table = rng.gamma(3.0, 2.0, 50_000).astype(np.float32)
    exact_count = float(((table >= 3) & (table <= 9)).sum())
    exact_sum = float(table[(table >= 3) & (table <= 9)].sum())
    for selector in ["silverman", "plugin", "lscv_h"]:
        syn = KDESynopsis.fit(jnp.asarray(table), selector=selector, max_sample=1024)
        assert float(syn.count(3, 9)) == pytest.approx(exact_count, rel=0.1), selector
        assert float(syn.sum(3, 9)) == pytest.approx(exact_sum, rel=0.12), selector
    # synopsis payload is tiny vs the relation (the AQP value proposition)
    assert syn.x.size <= 1024 < table.size


def test_synopsis_stable_under_refit(rng):
    data = rng.normal(5, 2, 30_000).astype(np.float32)
    s1 = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=1024, seed=1)
    s2 = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=1024, seed=2)
    # different subsamples, same answers (within sampling error)
    assert float(s1.count(3, 7)) == pytest.approx(float(s2.count(3, 7)), rel=0.07)
