"""Parallel reduction primitives (paper §5.2-5.5) vs numpy float64."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # hypothesis is optional: property tests skip below
    HAVE_HYPOTHESIS = False

from repro.core import gaussian as G
from repro.core.reductions import (kahan_sum, map_reduce, pairwise_quadform_reduce,
                                   pairwise_reduce, pairwise_sv_matrix, reduce_sum)


def test_map_reduce(rng):
    x = rng.normal(0, 1, 10_000).astype(np.float32)
    got = float(map_reduce(lambda v: v * v + 1.0, jnp.asarray(x), chunk=777))
    want = float((x.astype(np.float64) ** 2 + 1).sum())
    assert got == pytest.approx(want, rel=1e-5)


@pytest.mark.parametrize("n,chunk", [(10, 4), (100, 32), (1000, 256), (1001, 256)])
def test_pairwise_reduce(rng, n, chunk):
    x = rng.normal(0, 1, n).astype(np.float32)
    got = float(pairwise_reduce(lambda d: G.k4(d / 0.5), jnp.asarray(x), chunk=chunk))
    d = (x[:, None] - x[None, :]) / 0.5
    x2 = d.astype(np.float64) ** 2
    k4 = (x2 ** 2 - 6 * x2 + 3) * np.exp(-x2 / 2) / np.sqrt(2 * np.pi)
    want = float(k4[np.triu_indices(n, 1)].sum())
    assert got == pytest.approx(want, rel=1e-3, abs=1e-4)


def test_pairwise_quadform(rng):
    n, d = 123, 4
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    m0 = rng.normal(0, 1, (d, d)).astype(np.float32)
    m = 0.2 * m0 @ m0.T + np.eye(d, dtype=np.float32)
    got = float(pairwise_quadform_reduce(lambda s: jnp.exp(-s), jnp.asarray(x),
                                         jnp.asarray(m), chunk=32))
    v = x[:, None, :] - x[None, :, :]
    s = np.einsum("ijd,de,ije->ij", v, m, v)
    want = float(np.exp(-s)[np.triu_indices(n, 1)].sum())
    assert got == pytest.approx(want, rel=1e-3)


def test_sv_matrix_masked(rng):
    n, d = 50, 3
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    m = np.eye(d, dtype=np.float32)
    s = np.asarray(pairwise_sv_matrix(jnp.asarray(x), jnp.asarray(m), chunk=16))
    assert (s[np.tril_indices(n)] == 0).all()      # strict upper triangle only
    v = x[:, None, :] - x[None, :, :]
    want = np.einsum("ijd,ijd->ij", v, v)
    np.testing.assert_allclose(s[np.triu_indices(n, 1)],
                               want[np.triu_indices(n, 1)], rtol=1e-4, atol=1e-5)


def test_kahan_beats_naive_on_adversarial():
    """Classic compensation case: 1 + 1e-8 * 1e6.  A naive fp32 fold loses
    every small addend (1e-8 < ulp(1)); Kahan's running compensation keeps
    them (paper §5.2 accuracy discussion, refs [17]/[22])."""
    x = jnp.asarray(np.array([1.0] + [1e-8] * 1_000_000, np.float32))
    exact = 1.0 + 1e-8 * 1_000_000          # = 1.01

    def naive_fold(a):
        def body(c, v):
            return c + v, None
        s, _ = __import__("jax").lax.scan(body, jnp.float32(0.0), a)
        return float(s)

    naive = naive_fold(x)
    k = float(kahan_sum(x))
    assert abs(naive - exact) > 5e-3         # naive drops the tail
    assert k == pytest.approx(exact, abs=1e-4)


def _check_pairwise_permutation_invariance(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, 128).astype(np.float32)
    f = lambda d: G.phi(d / 0.7)
    a = float(pairwise_reduce(f, jnp.asarray(x), chunk=32))
    b = float(pairwise_reduce(f, jnp.asarray(rng.permutation(x)), chunk=32))
    assert a == pytest.approx(b, rel=1e-4)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 99))
    def test_pairwise_permutation_invariance(seed):
        _check_pairwise_permutation_invariance(seed)
else:
    @pytest.mark.parametrize("seed", [0, 17, 99])
    def test_pairwise_permutation_invariance(seed):
        _check_pairwise_permutation_invariance(seed)
