"""data/aqp_store.py: reservoir determinism, cross-host merge associativity,
and SynopsisCache hit/invalidation semantics."""
import numpy as np
import pytest

from repro.core import Query
from repro.data import Reservoir, SynopsisCache, TelemetryStore


def test_reservoir_deterministic_under_fixed_seed(rng):
    data = rng.normal(0, 1, 20_000).astype(np.float32)
    r1 = Reservoir(capacity=512, seed=7)
    r2 = Reservoir(capacity=512, seed=7)
    r1.add(data)
    r2.add(data)
    np.testing.assert_array_equal(r1.sample(), r2.sample())
    assert r1.n_seen == r2.n_seen == 20_000
    # a different seed keeps a different subsample (overwhelmingly likely)
    r3 = Reservoir(capacity=512, seed=8)
    r3.add(data)
    assert not np.array_equal(r1.sample(), r3.sample())


def test_telemetry_store_deterministic_across_instances(rng):
    data = rng.gamma(3.0, 1.0, 30_000).astype(np.float32)
    outs = []
    for _ in range(2):
        store = TelemetryStore(capacity=1024, seed=0)
        store.add_batch({"loss": data})
        outs.append(store.query_batch([Query("count", 1.0, 4.0, column="loss")]))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_reservoir_version_counts_updates(rng):
    r = Reservoir(capacity=64, seed=0)
    assert r.version == 0
    r.add(rng.normal(0, 1, 10))
    assert r.version == 1
    r.add(np.empty((0,), np.float32))      # empty batch: no state change
    assert r.version == 1
    r.add(rng.normal(0, 1, 200))
    assert r.version == 2


def test_synopsis_merge_associative_across_hosts(rng):
    """(A + B) + C vs A + (B + C): same n_seen, and both orders answer
    fraction queries to within synopsis accuracy."""
    parts = [rng.normal(m, 1, 5000).astype(np.float32) for m in (0.0, 1.0, 2.0)]
    stores = []
    for i, part in enumerate(parts):
        st = TelemetryStore(capacity=512, seed=i)
        st.add_batch({"x": part})
        stores.append(st)
    left = stores[0].merge(stores[1]).merge(stores[2])
    right = stores[0].merge(stores[1].merge(stores[2]))
    assert left.columns["x"].n_seen == right.columns["x"].n_seen == 15_000
    exact = float((np.concatenate(parts) <= 1.0).mean())
    for merged in (left, right):
        frac = merged.fraction("x", -50.0, 1.0, selector="silverman")
        assert frac == pytest.approx(exact, abs=0.08)


def test_synopsis_cache_hit_and_invalidation(rng):
    data = rng.normal(0, 1, 5000).astype(np.float32)
    store = TelemetryStore(capacity=512, seed=0)
    store.add_batch({"loss": data})

    s1 = store.synopsis("loss", selector="silverman")
    assert store.cache.stats()["misses"] == 1
    s2 = store.synopsis("loss", selector="silverman")
    assert s2 is s1                               # served from cache
    assert store.cache.stats()["hits"] == 1

    # a different selector is a distinct cache entry
    store.synopsis("loss", selector="plugin")
    assert store.cache.stats()["entries"] == 2

    # new data bumps the reservoir version -> stale entry is a miss
    store.add_batch({"loss": rng.normal(3, 1, 1000).astype(np.float32)})
    s3 = store.synopsis("loss", selector="silverman")
    assert s3 is not s1
    assert s3.n_source == 6000


def test_synopsis_cache_explicit_invalidate():
    cache = SynopsisCache(max_entries=2)
    cache.put("a", "plugin", 1, "syn_a")
    cache.put("b", "plugin", 1, "syn_b")
    assert cache.get("a", "plugin", 1) == "syn_a"
    cache.invalidate("a")
    assert cache.get("a", "plugin", 1) is None
    # bounded: inserting past max_entries evicts the oldest entry
    cache.put("c", "plugin", 1, "syn_c")
    cache.put("d", "plugin", 1, "syn_d")
    assert len(cache) == 2


def test_query_batch_uses_cached_synopses(rng):
    data = {"a": rng.normal(0, 1, 4000).astype(np.float32),
            "b": rng.normal(5, 1, 4000).astype(np.float32)}
    store = TelemetryStore(capacity=512, seed=0)
    store.add_batch(data)
    queries = [Query("count", -1, 1, column="a"), Query("avg", 4, 6, column="b")]
    store.query_batch(queries)
    misses0 = store.cache.stats()["misses"]
    store.query_batch(queries)
    assert store.cache.stats()["misses"] == misses0     # second run: all hits
    assert store.cache.stats()["hits"] >= 2


def test_merge_with_mismatched_capacities_stays_finite_and_weighted(rng):
    """A merge must never expose uninitialized buffer slots, and must keep
    each side's contribution proportional to its stream size even when the
    retained-sample sizes are wildly different."""
    r1 = Reservoir(capacity=4096, seed=0)
    r1.add(rng.normal(0, 1, 100).astype(np.float32))            # 1% of stream
    r2 = Reservoir(capacity=64, seed=1)
    r2.add(rng.normal(10, 1, 10_000).astype(np.float32))        # 99% of stream
    m = r1.merge(r2)
    s = m.sample()
    # k is capped at len(s2)/w2 ~ 64 so the 100 r1 points cannot be forced in
    assert len(s) == m.n_filled <= 64
    assert np.isfinite(s).all()
    assert m.n_seen == 10_100
    # r1's well-separated values (~0) must stay a small fraction of the sample
    assert (s < 5.0).mean() < 0.2
    # adding after a merge replaces within the filled region (never grows a
    # deficit sample, which would overweight new data) and stays finite
    filled = m.n_filled
    m.add(rng.normal(0, 1, 500).astype(np.float32))
    assert m.n_filled == filled
    assert np.isfinite(m.sample()).all()


def test_store_query_batch_requires_column(rng):
    store = TelemetryStore(capacity=64, seed=0)
    store.add_batch({"x": rng.normal(0, 1, 100).astype(np.float32)})
    with pytest.raises(ValueError, match="name a column"):
        store.query_batch([Query("count", 0.0, 1.0)])


def test_store_merge_preserves_cache_bound(rng):
    s1 = TelemetryStore(capacity=64, seed=0, cache_entries=8)
    s2 = TelemetryStore(capacity=64, seed=1, cache_entries=8)
    s1.add_batch({"x": rng.normal(0, 1, 100).astype(np.float32)})
    s2.add_batch({"x": rng.normal(0, 1, 100).astype(np.float32)})
    assert s1.merge(s2).cache.max_entries == 8


def test_merge_snapshot_does_not_alias_single_side_columns(rng):
    s1 = TelemetryStore(capacity=64, seed=0)
    s2 = TelemetryStore(capacity=64, seed=1)
    s1.add_batch({"only_in_a": rng.normal(0, 1, 50).astype(np.float32)})
    s2.add_batch({"shared": rng.normal(0, 1, 50).astype(np.float32)})
    s1.add_batch({"shared": rng.normal(0, 1, 50).astype(np.float32)})
    m = s1.merge(s2)
    before = m.columns["only_in_a"].n_seen
    s1.add_batch({"only_in_a": rng.normal(0, 1, 500).astype(np.float32)})
    assert m.columns["only_in_a"].n_seen == before     # snapshot, not alias


# --- multi-d subsystem: joint reservoirs, byte-bounded LRU cache ------------

def test_chained_weighted_merge_unbiased_over_three_hops(rng):
    """Satellite: ((A + B) + C) + D must keep every stream's contribution
    proportional to its n_seen — the weighted merge may not drift as depth
    grows.  Checked on the merged-sample mean vs the exact stream mean."""
    from repro.data import Reservoir

    means = [0.0, 3.0, 6.0, 9.0]
    sizes = [8000, 4000, 2000, 1000]
    parts = [rng.normal(m, 1.0, s).astype(np.float32)
             for m, s in zip(means, sizes)]
    reservoirs = []
    for i, part in enumerate(parts):
        r = Reservoir(capacity=1024, seed=i)
        r.add(part)
        reservoirs.append(r)
    merged = reservoirs[0]
    for r in reservoirs[1:]:          # 3 hops
        merged = merged.merge(r)
    assert merged.n_seen == sum(sizes)
    exact_mean = float(np.concatenate(parts).mean())
    sample = merged.sample()
    assert len(sample) > 256          # the cap must not collapse the sample
    # se ~ spread/sqrt(len(sample)) ~ 0.1; 0.5 is a 5-sigma-ish bound
    assert float(sample.mean()) == pytest.approx(exact_mean, abs=0.5)


def test_multireservoir_rows_and_determinism(rng):
    from repro.data import MultiReservoir

    rows = rng.normal(0, 1, (20000, 2)).astype(np.float32)
    rows[:, 1] = rows[:, 0] * 2.0      # exact functional correlation
    r1 = MultiReservoir(("a", "b"), capacity=512, seed=7)
    r2 = MultiReservoir(("a", "b"), capacity=512, seed=7)
    r1.add(rows)
    r2.add(rows)
    np.testing.assert_array_equal(r1.sample(), r2.sample())
    s = r1.sample()
    assert s.shape == (512, 2)
    # row sampling preserves the cross-column relation exactly
    np.testing.assert_allclose(s[:, 1], 2.0 * s[:, 0], rtol=1e-6)
    with pytest.raises(ValueError, match="shape"):
        r1.add(rng.normal(0, 1, (10, 3)).astype(np.float32))


def test_multireservoir_weighted_merge(rng):
    from repro.data import MultiReservoir

    r1 = MultiReservoir(("a", "b"), capacity=512, seed=0)
    r2 = MultiReservoir(("a", "b"), capacity=512, seed=1)
    r1.add(rng.normal(0, 1, (9000, 2)).astype(np.float32))
    r2.add(rng.normal(5, 1, (1000, 2)).astype(np.float32))
    m = r1.merge(r2)
    assert m.n_seen == 10_000
    s = m.sample()
    assert s.shape[1] == 2 and np.isfinite(s).all()
    # ~10% of the stream came from the mean-5 side
    frac_high = float((s[:, 0] > 2.5).mean())
    assert frac_high == pytest.approx(0.1, abs=0.06)
    r3 = MultiReservoir(("a", "c"), capacity=512, seed=2)
    with pytest.raises(ValueError, match="different"):
        r1.merge(r3)


def test_cache_byte_bound_eviction():
    import jax.numpy as jnp

    from repro.core import KDESynopsis

    def syn_of(n):
        return KDESynopsis(x=jnp.zeros((n,), jnp.float32),
                           h=jnp.float32(1.0), n_source=n)

    payload = 1024 * 4 + 4                   # x nbytes + h nbytes
    cache = SynopsisCache(max_entries=16, max_bytes=int(2.5 * payload))
    cache.put("a", "plugin", 1, syn_of(1024))
    cache.put("b", "plugin", 1, syn_of(1024))
    assert cache.stats()["evictions"] == 0
    cache.put("c", "plugin", 1, syn_of(1024))    # 3 * payload > bound
    st = cache.stats()
    assert st["evictions"] == 1
    assert st["bytes"] <= 2.5 * payload
    assert cache.get("a", "plugin", 1) is None   # oldest evicted
    assert cache.get("b", "plugin", 1) is not None

    # an entry that can never fit is refused, NOT admitted-then-thrashed:
    # the resident entries survive and the refusal is counted separately
    cache.put("big", "plugin", 1, syn_of(4096))
    st = cache.stats()
    assert st["oversize"] == 1 and st["evictions"] == 1
    assert cache.get("big", "plugin", 1) is None
    assert cache.get("b", "plugin", 1) is not None


def test_cache_selector_case_insensitive():
    """Satellite: selector strings differing only by case are ONE entry —
    "Plugin" and "plugin" must not coexist as two live copies.  The paper's
    scalar/full-matrix LSCV pair legitimately differs only by case and stays
    distinct."""
    cache = SynopsisCache(max_entries=8)
    cache.put("a", "Plugin", 1, "syn_a")
    assert cache.get("a", "plugin", 1) == "syn_a"
    assert cache.get("a", "PLUGIN", 1) == "syn_a"
    cache.put("a", "plugin", 1, "syn_a2")          # same entry, replaced
    assert len(cache) == 1
    assert cache.get("a", "Plugin", 1) == "syn_a2"
    # lscv_h (scalar) vs lscv_H (full matrix) are different selectors
    cache.put("a", "lscv_h", 1, "syn_scalar")
    cache.put("a", "lscv_H", 1, "syn_full")
    assert cache.get("a", "lscv_h", 1) == "syn_scalar"
    assert cache.get("a", "lscv_H", 1) == "syn_full"
    assert len(cache) == 3


def test_store_selector_case_shares_cache_entry(rng):
    store = TelemetryStore(capacity=256, seed=0)
    store.add_batch({"x": rng.normal(0, 1, 2000).astype(np.float32)})
    s1 = store.synopsis("x", selector="silverman")
    s2 = store.synopsis("x", selector="SILVERMAN")
    assert s2 is s1                                # one entry, served cached
    assert store.cache.stats()["entries"] == 1


def test_cache_lru_recency_not_fifo():
    cache = SynopsisCache(max_entries=2)
    cache.put("a", "plugin", 1, "syn_a")
    cache.put("b", "plugin", 1, "syn_b")
    assert cache.get("a", "plugin", 1) == "syn_a"   # refreshes 'a'
    cache.put("c", "plugin", 1, "syn_c")            # evicts 'b', not 'a'
    assert cache.get("a", "plugin", 1) == "syn_a"
    assert cache.get("b", "plugin", 1) is None


def test_store_joint_tracking_and_box_queries(rng):
    from repro.core import BoxQuery

    n = 30_000
    a = rng.normal(0, 1, n).astype(np.float32)
    b = (0.8 * a + 0.6 * rng.normal(0, 1, n)).astype(np.float32)
    store = TelemetryStore(capacity=2048, seed=0)
    store.track_joint(("a", "b"))
    store.add_batch({"a": a, "b": b})

    queries = [BoxQuery("count", (-1, -1), (1, 1), columns=("a", "b")),
               BoxQuery("avg", (-1, -1), (1, 1), columns=("a", "b"),
                        target="b")]
    ans = store.query_box_batch(queries)
    sel = (np.abs(a) <= 1) & (np.abs(b) <= 1)
    assert ans[0] == pytest.approx(float(sel.sum()), rel=0.10)
    assert ans[1] == pytest.approx(float(b[sel].mean()), abs=0.05)

    # joint synopsis is cached under the column *tuple* (no collision with
    # per-column entries) and served from cache on the second batch
    misses0 = store.cache.stats()["misses"]
    store.query_box_batch(queries)
    assert store.cache.stats()["misses"] == misses0

    st = store.stats()
    assert st["cache"]["hits"] >= 1
    assert st["joints"][("a", "b")] == n
    assert st["columns"]["a"] == n

    with pytest.raises(KeyError, match="track_joint"):
        store.joint_synopsis(("a", "missing"))


def test_track_joint_backfills_from_per_column_reservoirs(rng):
    """Satellite: registering a joint over already-tracked columns seeds the
    MultiReservoir from the per-column samples (zip-aligned window) instead
    of starting empty, flags it in stats(), and scales to the stream size."""
    n = 20_000
    a = rng.normal(0, 1, n).astype(np.float32)
    b = rng.normal(5, 2, n).astype(np.float32)
    store = TelemetryStore(capacity=512, seed=0)
    store.add_batch({"a": a, "b": b})

    store.track_joint(("a", "b"))                 # AFTER the data arrived
    res = store.joints[("a", "b")]
    assert res.backfilled and res.n_filled == 512
    assert res.n_seen == n                        # window represents the stream
    assert store.stats()["backfilled"][("a", "b")] is True

    # marginals are usable immediately: box count over (almost) everything
    from repro.core import BoxQuery
    ans = store.query_box_batch(
        [BoxQuery("count", (-10.0, -10.0), (10.0, 20.0), columns=("a", "b"))])
    assert ans[0] == pytest.approx(n, rel=0.15)

    # real rows keep streaming in afterwards
    store.add_batch({"a": a[:1000], "b": b[:1000]})
    assert store.joints[("a", "b")].n_seen == n + 1000

    # opt-out and the cold-start path stay empty / unflagged
    store2 = TelemetryStore(capacity=512, seed=0)
    store2.add_batch({"a": a})                    # only one of the columns
    store2.track_joint(("a", "b"))
    assert not store2.joints[("a", "b")].backfilled
    assert store2.joints[("a", "b")].n_filled == 0
    store3 = TelemetryStore(capacity=512, seed=0)
    store3.add_batch({"a": a, "b": b})
    store3.track_joint(("a", "b"), backfill=False)
    assert not store3.joints[("a", "b")].backfilled


def test_store_merge_carries_joints(rng):
    s1 = TelemetryStore(capacity=512, seed=0)
    s2 = TelemetryStore(capacity=512, seed=1)
    for st in (s1, s2):
        st.track_joint(("x", "y"))
    s1.add_batch({"x": rng.normal(0, 1, 3000).astype(np.float32),
                  "y": rng.normal(0, 1, 3000).astype(np.float32)})
    s2.add_batch({"x": rng.normal(2, 1, 1000).astype(np.float32),
                  "y": rng.normal(2, 1, 1000).astype(np.float32)})
    m = s1.merge(s2)
    assert m.joints[("x", "y")].n_seen == 4000
    syn = m.joint_synopsis(("x", "y"), selector="silverman")
    assert syn.x.shape[1] == 2 and syn.n_source == 4000


def test_add_batch_ragged_joint_fails_before_mutation(rng):
    """A ragged batch for a tracked joint must fail atomically: no reservoir
    (per-column or joint) may have accepted anything."""
    store = TelemetryStore(capacity=64, seed=0)
    store.track_joint(("a", "b"))
    store.add_batch({"a": rng.normal(0, 1, 10).astype(np.float32),
                     "b": rng.normal(0, 1, 10).astype(np.float32)})
    with pytest.raises(ValueError, match="row-aligned"):
        store.add_batch({"a": rng.normal(0, 1, 5).astype(np.float32),
                         "b": rng.normal(0, 1, 3).astype(np.float32)})
    assert store.columns["a"].n_seen == 10
    assert store.columns["b"].n_seen == 10
    assert store.joints[("a", "b")].n_seen == 10


# --- categorical sketches and version notification ---------------------------

def test_categorical_sketch_counts_and_merge(rng):
    from repro.data import CategoricalSketch

    s1, s2 = CategoricalSketch(), CategoricalSketch()
    a = rng.integers(0, 4, 5000).astype(np.float32)
    b = rng.integers(2, 6, 3000).astype(np.float32)
    s1.add(a)
    s2.add(b)
    m = s1.merge(s2)
    assert m.n_rows == 8000 and not m.overflowed
    for v in range(6):
        want = int((a == v).sum() + (b == v).sum())
        cnt, sm = m.range_terms(v - 0.5, v + 0.5)
        assert cnt == want and sm == pytest.approx(v * want)
    # full-range terms cover every row
    assert m.range_terms(-1.0, 10.0)[0] == 8000


def test_categorical_sketch_overflow_disables_exact(rng):
    store = TelemetryStore(capacity=256, seed=0)
    store.track_categorical("wide", max_codes=16)
    store.add_batch({"wide": np.arange(64, dtype=np.float32)})
    cat = store.stats()["categoricals"]["wide"]
    assert cat["overflowed"] and cat["exact"] is False and cat["codes"] == 0
    # engine must fall back to the KDE window, not crash
    from repro.core import AqpQuery, Eq
    assert store.query([AqpQuery("count", (Eq("wide", 3.0),))],
                       selector="silverman")[0].path == "range1d"


def test_cm_conservative_update_never_undercounts_and_beats_standard(rng):
    """Estan-Varghese conservative update: per-code estimates stay upper
    bounds, are never looser than the standard update (same seed, so cells
    line up), and realised total error drops strictly on a skewed stream
    forced into a tiny table."""
    from repro.data.aqp_store import CountMinSketch

    codes = (rng.zipf(1.3, 20_000) % 400).astype(np.float32)
    std = CountMinSketch(width=64, depth=3, seed=1)
    cu = CountMinSketch(width=64, depth=3, seed=1, conservative=True)
    for chunk in np.array_split(codes, 16):      # streamed, multi-batch
        std.add(chunk)
        cu.add(chunk)
    assert std.n_rows == cu.n_rows == 20_000
    err_std = err_cu = 0
    for c in np.unique(codes):
        truth = int((codes == c).sum())
        es, ec = std.estimate(float(c)), cu.estimate(float(c))
        assert ec >= truth          # CU keeps the upper-bound invariant
        assert ec <= es             # and is cell-wise <= the standard table
        err_std += es - truth
        err_cu += ec - truth
    assert err_cu < err_std
    # analytic bound unchanged: both are worst-case e/width * n
    assert cu.err_bound() == std.err_bound()


def test_cm_conservative_merge_flag_and_state_roundtrip(rng):
    from repro.data.aqp_store import CountMinSketch

    a = rng.integers(0, 50, 3000).astype(np.float32)
    cu1 = CountMinSketch(width=128, depth=3, seed=2, conservative=True)
    cu2 = CountMinSketch(width=128, depth=3, seed=2, conservative=True)
    std = CountMinSketch(width=128, depth=3, seed=2)
    for sk in (cu1, cu2, std):
        sk.add(a)
    # merge is cell-wise additive; conservative only when both inputs are
    both = cu1.merge(cu2)
    assert both.conservative and both.n_rows == 6000
    np.testing.assert_array_equal(both.table, cu1.table + cu2.table)
    assert not cu1.merge(std).conservative
    # the flag and table survive the snapshot state round-trip
    back = CountMinSketch.from_state(*cu1.state())
    assert back.conservative
    np.testing.assert_array_equal(back.table, cu1.table)
    assert back.estimate(7.0) == cu1.estimate(7.0)
    # pre-flag snapshots (no "conservative" key) load as standard
    arrays, meta = std.state()
    meta.pop("conservative")
    assert not CountMinSketch.from_state(arrays, meta).conservative


def test_cm_conservative_via_store_and_err_gauge(rng):
    store = TelemetryStore(capacity=256, seed=0)
    store.track_categorical("code", kind="cm", width=128, depth=3,
                            conservative=True)
    with pytest.raises(ValueError, match="count-min mode"):
        store.track_categorical("other", kind="exact", conservative=True)
    codes = rng.integers(0, 40, 5000).astype(np.float32)
    store.add_batch({"code": codes})
    sk = store.categoricals["code"]
    assert sk.conservative and store.stats()["categoricals"]["code"][
        "conservative"]
    # the estimated-error gauge tracks the sketch's analytic bound
    assert store.metrics.sum_gauge("aqp.sketch.err_bound",
                                   column="code") == sk.err_bound()
    # covered stream still answers on the bounded-error path
    from repro.core import AqpQuery, Eq
    (r,) = store.query([AqpQuery("count", (Eq("code", 3.0),))],
                       selector="silverman")
    assert r.path == "exact:cm"
    assert r.estimate >= int((codes == np.float32(3.0)).sum())


def test_store_merge_with_one_sided_sketch_disables_exact(rng):
    s1 = TelemetryStore(capacity=256, seed=0)
    s2 = TelemetryStore(capacity=256, seed=1)
    s1.track_categorical("code")
    code = rng.integers(0, 3, 2000).astype(np.float32)
    s1.add_batch({"code": code})
    s2.add_batch({"code": code})
    m = s1.merge(s2)
    cat = m.stats()["categoricals"]["code"]
    assert cat["rows"] == 2000                  # only s1's side was sketched
    assert cat["exact"] is False                # stream is 4000 rows
    # two-sided sketches keep exact coverage across the merge
    s2.track_categorical("code")
    s2.add_batch({"code": code})
    m2 = s1.merge(s2)
    assert m2.stats()["categoricals"]["code"]["exact"] is False  # s2 late
    s3 = TelemetryStore(capacity=256, seed=2)
    s3.track_categorical("code")
    s3.add_batch({"code": code})
    m3 = s1.merge(s3)
    assert m3.stats()["categoricals"]["code"]["exact"] is True


def test_subscribe_notifies_bumped_versions(rng):
    store = TelemetryStore(capacity=256, seed=0)
    store.track_joint(("x", "y"))
    seen = []
    unsubscribe = store.subscribe(seen.append)
    store.add_batch({"x": rng.normal(0, 1, 100).astype(np.float32),
                     "y": rng.normal(0, 1, 100).astype(np.float32)})
    assert len(seen) == 1
    bumped = seen[0]
    assert bumped["x"] == store.columns["x"].version
    assert bumped[("x", "y")] == store.joints[("x", "y")].version
    unsubscribe()
    store.add_batch({"x": rng.normal(0, 1, 10).astype(np.float32)})
    assert len(seen) == 1                        # unsubscribed: no more calls
    unsubscribe()                                # idempotent


# --- count-min code grids (non-integer lattices) ------------------------------

def test_cm_off_grid_codes_disable_range_answers(rng):
    """A count-min sketch fed codes off its declared lattice must refuse
    range answers (None -> KDE fallback) instead of silently enumerating
    integer codes and mis-weighting the SUM — the pre-fix behaviour."""
    from repro.data.aqp_store import CountMinSketch

    sk = CountMinSketch(width=512, depth=3, seed=0)     # default 1.0 grid
    sk.add(np.array([0.5, 1.0, 1.5, 2.0], np.float32))
    assert sk.off_grid
    assert sk.range_terms(0.0, 2.0) is None
    assert sk.range_err(0.0, 2.0) is None
    assert sk.stats()["off_grid"] is True
    # point estimates are grid-free and keep working
    assert sk.estimate(0.5) >= 1
    # on-grid streams never flip the flag
    ok = CountMinSketch(width=512, depth=3, seed=0)
    ok.add(np.arange(8, dtype=np.float32))
    assert not ok.off_grid and ok.range_terms(0.0, 7.0) is not None


def test_cm_declared_grid_weights_range_sums_correctly(rng):
    """Declaring the actual lattice (grid_step=0.5) restores exact-path
    range coverage with each code's count weighted by its fractional
    value, not a rounded integer."""
    from repro.data.aqp_store import CountMinSketch

    sk = CountMinSketch(width=512, depth=3, seed=0, grid_step=0.5)
    vals = np.repeat(np.array([0.5, 1.0, 1.5, 2.0], np.float32),
                     [3, 5, 7, 2])
    sk.add(rng.permutation(vals))
    assert not sk.off_grid
    cnt, sm = sk.range_terms(0.4, 1.6)          # {0.5, 1.0, 1.5}
    assert cnt == 15
    assert sm == pytest.approx(0.5 * 3 + 1.0 * 5 + 1.5 * 7)
    # a bound sitting ON a grid point includes it
    cnt_all, _sm_all = sk.range_terms(0.5, 2.0)
    assert cnt_all == 17
    # windows wider than max_enumerate still decline (unchanged contract)
    tiny = CountMinSketch(width=64, depth=2, seed=1, grid_step=0.5,
                          max_enumerate=4)
    tiny.add(vals)
    assert tiny.range_terms(0.0, 10.0) is None


def test_cm_grid_merge_and_state_roundtrip(rng):
    from repro.data.aqp_store import CountMinSketch

    a = CountMinSketch(width=128, depth=3, seed=2, grid_step=0.5,
                       grid_origin=0.25)
    b = CountMinSketch(width=128, depth=3, seed=2, grid_step=0.5,
                       grid_origin=0.25)
    a.add(np.array([0.25, 0.75], np.float32))
    b.add(np.array([1.25, 9.0], np.float32))    # 9.0 is off this lattice
    assert not a.off_grid and b.off_grid
    m = a.merge(b)
    assert (m.grid_step, m.grid_origin) == (0.5, 0.25)
    assert m.off_grid                           # poisoned side wins
    with pytest.raises(ValueError, match="grid"):
        a.merge(CountMinSketch(width=128, depth=3, seed=2))
    back = CountMinSketch.from_state(*b.state())
    assert (back.grid_step, back.grid_origin, back.off_grid) == \
        (0.5, 0.25, True)
    # pre-grid snapshots (no grid keys) load on the default integer lattice
    arrays, meta = CountMinSketch(width=64, depth=2, seed=0).state()
    for k in ("grid_step", "grid_origin", "off_grid"):
        meta.pop(k)
    legacy = CountMinSketch.from_state(arrays, meta)
    assert (legacy.grid_step, legacy.grid_origin, legacy.off_grid) == \
        (1.0, 0.0, False)


def test_cm_uint32_saturating_add_drops_coverage(rng):
    """A cell at the uint32 cap clips instead of wrapping, on both update
    paths, and any clip voids the coverage gate (the min-estimate may then
    under-count, so 'exact:cm' must not serve)."""
    from repro.data.aqp_store import _CM_MAX, CountMinSketch

    vals = rng.integers(0, 50, 300).astype(np.float32)
    for conservative in (False, True):
        sk = CountMinSketch(width=64, depth=3, seed=1,
                            conservative=conservative)
        sk.add(vals)
        assert sk.saturated == 0 and sk.exact_for(300)
        sk.table[:] = _CM_MAX          # 4e9 rows into every cell, simulated
        sk.add(np.array([7.0], np.float32))
        assert sk.saturated > 0
        assert sk.estimate(7.0) == _CM_MAX          # capped, never wrapped
        assert not sk.exact_for(sk.n_rows)
        assert sk.stats()["saturated"] == sk.saturated


def test_cm_uint32_table_halves_checkpoint_bytes(rng):
    from repro.data.aqp_store import CountMinSketch

    sk = CountMinSketch(width=256, depth=4, seed=0)
    sk.add(rng.integers(0, 100, 1000).astype(np.float32))
    arrays, meta = sk.state()
    assert arrays["table"].dtype == np.uint32
    assert arrays["table"].nbytes == 4 * 256 * 4    # half the int64 original
    assert meta["saturated"] == 0


def test_cm_legacy_int64_snapshot_clips_and_counts(rng):
    """Legacy int64 tables load unchanged below the cap; cells past it clip
    on load and register as saturations (coverage gate sees them)."""
    from repro.data.aqp_store import _CM_MAX, CountMinSketch

    sk = CountMinSketch(width=64, depth=2, seed=3)
    sk.add(rng.integers(0, 20, 500).astype(np.float32))
    arrays, meta = sk.state()
    arrays = {**arrays, "table": arrays["table"].astype(np.int64)}
    meta = dict(meta)
    meta.pop("saturated")                 # pre-uint32 snapshots lack the key
    back = CountMinSketch.from_state(arrays, meta)
    assert back.saturated == 0 and back.exact_for(500)
    np.testing.assert_array_equal(back.table, sk.table)

    arrays["table"] = arrays["table"].copy()
    arrays["table"][0, 0] = _CM_MAX + 17
    hot = CountMinSketch.from_state(arrays, meta)
    assert hot.saturated == 1 and hot.table[0, 0] == _CM_MAX
    assert not hot.exact_for(500)


def test_cm_merge_saturation_accounting(rng):
    from repro.data.aqp_store import _CM_MAX, CountMinSketch

    a = CountMinSketch(width=64, depth=2, seed=4)
    b = CountMinSketch(width=64, depth=2, seed=4)
    a.add(rng.integers(0, 30, 200).astype(np.float32))
    b.add(rng.integers(0, 30, 200).astype(np.float32))
    m = a.merge(b)
    assert m.saturated == 0 and m.exact_for(400)

    a.table[:] = _CM_MAX                  # both halves near the cap
    b.table[:] = 1
    m2 = a.merge(b)
    assert m2.saturated == 64 * 2         # every cell clipped once
    assert np.all(m2.table == _CM_MAX)
    assert not m2.exact_for(400)
    # input saturations carry through additively
    a.saturated = 3
    assert a.merge(b).saturated == 3 + 64 * 2


def test_cm_grid_via_store_eq_query(rng):
    """End to end: Eq on a half-step code column answers on the
    bounded-error sketch path when its grid is declared, and falls back to
    a density path (not exact:cm) when the stream goes off-grid — the
    pre-fix behaviour silently enumerated integer codes and answered 0."""
    from repro.core import AqpQuery, Eq

    store = TelemetryStore(capacity=512, seed=0)
    store.track_categorical("code", kind="cm", width=512, depth=3,
                            grid_step=0.5)
    with pytest.raises(ValueError, match="count-min"):
        store.track_categorical("other", kind="exact", grid_step=0.5)
    codes = (rng.integers(1, 9, 4000) * 0.5).astype(np.float32)
    store.add_batch({"code": codes})
    # Eq's halfwidth matches the code spacing: +-0.25 captures one code
    (r,) = store.query([AqpQuery("count", (Eq("code", 1.5,
                                              halfwidth=0.25),))],
                       selector="silverman")
    assert r.path == "exact:cm"
    truth = int((codes == np.float32(1.5)).sum())
    assert truth <= r.estimate <= truth + store.categoricals[
        "code"].err_bound()
    (rs,) = store.query([AqpQuery("sum", (Eq("code", 1.5,
                                             halfwidth=0.25),),
                                  target="code")], selector="silverman")
    assert rs.estimate == pytest.approx(1.5 * r.estimate)
    st = store.stats()["categoricals"]["code"]
    assert st["grid_step"] == 0.5 and st["off_grid"] is False
    # off-grid stream: sketch declines, the engine answers on a KDE path
    store2 = TelemetryStore(capacity=512, seed=0)
    store2.track_categorical("code", kind="cm", width=512, depth=3)
    store2.add_batch({"code": codes})            # halves on an integer grid
    assert store2.categoricals["code"].off_grid
    assert store2.stats()["categoricals"]["code"]["off_grid"] is True
    (r2,) = store2.query([AqpQuery("count", (Eq("code", 1.5,
                                                halfwidth=0.25),))],
                         selector="silverman")
    assert r2.path != "exact:cm"
