"""data/aqp_store.py: reservoir determinism, cross-host merge associativity,
and SynopsisCache hit/invalidation semantics."""
import numpy as np
import pytest

from repro.core import Query
from repro.data import Reservoir, SynopsisCache, TelemetryStore


def test_reservoir_deterministic_under_fixed_seed(rng):
    data = rng.normal(0, 1, 20_000).astype(np.float32)
    r1 = Reservoir(capacity=512, seed=7)
    r2 = Reservoir(capacity=512, seed=7)
    r1.add(data)
    r2.add(data)
    np.testing.assert_array_equal(r1.sample(), r2.sample())
    assert r1.n_seen == r2.n_seen == 20_000
    # a different seed keeps a different subsample (overwhelmingly likely)
    r3 = Reservoir(capacity=512, seed=8)
    r3.add(data)
    assert not np.array_equal(r1.sample(), r3.sample())


def test_telemetry_store_deterministic_across_instances(rng):
    data = rng.gamma(3.0, 1.0, 30_000).astype(np.float32)
    outs = []
    for _ in range(2):
        store = TelemetryStore(capacity=1024, seed=0)
        store.add_batch({"loss": data})
        outs.append(store.query_batch([Query("count", 1.0, 4.0, column="loss")]))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_reservoir_version_counts_updates(rng):
    r = Reservoir(capacity=64, seed=0)
    assert r.version == 0
    r.add(rng.normal(0, 1, 10))
    assert r.version == 1
    r.add(np.empty((0,), np.float32))      # empty batch: no state change
    assert r.version == 1
    r.add(rng.normal(0, 1, 200))
    assert r.version == 2


def test_synopsis_merge_associative_across_hosts(rng):
    """(A + B) + C vs A + (B + C): same n_seen, and both orders answer
    fraction queries to within synopsis accuracy."""
    parts = [rng.normal(m, 1, 5000).astype(np.float32) for m in (0.0, 1.0, 2.0)]
    stores = []
    for i, part in enumerate(parts):
        st = TelemetryStore(capacity=512, seed=i)
        st.add_batch({"x": part})
        stores.append(st)
    left = stores[0].merge(stores[1]).merge(stores[2])
    right = stores[0].merge(stores[1].merge(stores[2]))
    assert left.columns["x"].n_seen == right.columns["x"].n_seen == 15_000
    exact = float((np.concatenate(parts) <= 1.0).mean())
    for merged in (left, right):
        frac = merged.fraction("x", -50.0, 1.0, selector="silverman")
        assert frac == pytest.approx(exact, abs=0.08)


def test_synopsis_cache_hit_and_invalidation(rng):
    data = rng.normal(0, 1, 5000).astype(np.float32)
    store = TelemetryStore(capacity=512, seed=0)
    store.add_batch({"loss": data})

    s1 = store.synopsis("loss", selector="silverman")
    assert store.cache.stats()["misses"] == 1
    s2 = store.synopsis("loss", selector="silverman")
    assert s2 is s1                               # served from cache
    assert store.cache.stats()["hits"] == 1

    # a different selector is a distinct cache entry
    store.synopsis("loss", selector="plugin")
    assert store.cache.stats()["entries"] == 2

    # new data bumps the reservoir version -> stale entry is a miss
    store.add_batch({"loss": rng.normal(3, 1, 1000).astype(np.float32)})
    s3 = store.synopsis("loss", selector="silverman")
    assert s3 is not s1
    assert s3.n_source == 6000


def test_synopsis_cache_explicit_invalidate():
    cache = SynopsisCache(max_entries=2)
    cache.put("a", "plugin", 1, "syn_a")
    cache.put("b", "plugin", 1, "syn_b")
    assert cache.get("a", "plugin", 1) == "syn_a"
    cache.invalidate("a")
    assert cache.get("a", "plugin", 1) is None
    # bounded: inserting past max_entries evicts the oldest entry
    cache.put("c", "plugin", 1, "syn_c")
    cache.put("d", "plugin", 1, "syn_d")
    assert len(cache) == 2


def test_query_batch_uses_cached_synopses(rng):
    data = {"a": rng.normal(0, 1, 4000).astype(np.float32),
            "b": rng.normal(5, 1, 4000).astype(np.float32)}
    store = TelemetryStore(capacity=512, seed=0)
    store.add_batch(data)
    queries = [Query("count", -1, 1, column="a"), Query("avg", 4, 6, column="b")]
    store.query_batch(queries)
    misses0 = store.cache.stats()["misses"]
    store.query_batch(queries)
    assert store.cache.stats()["misses"] == misses0     # second run: all hits
    assert store.cache.stats()["hits"] >= 2


def test_merge_with_mismatched_capacities_stays_finite_and_weighted(rng):
    """A merge must never expose uninitialized buffer slots, and must keep
    each side's contribution proportional to its stream size even when the
    retained-sample sizes are wildly different."""
    r1 = Reservoir(capacity=4096, seed=0)
    r1.add(rng.normal(0, 1, 100).astype(np.float32))            # 1% of stream
    r2 = Reservoir(capacity=64, seed=1)
    r2.add(rng.normal(10, 1, 10_000).astype(np.float32))        # 99% of stream
    m = r1.merge(r2)
    s = m.sample()
    # k is capped at len(s2)/w2 ~ 64 so the 100 r1 points cannot be forced in
    assert len(s) == m.n_filled <= 64
    assert np.isfinite(s).all()
    assert m.n_seen == 10_100
    # r1's well-separated values (~0) must stay a small fraction of the sample
    assert (s < 5.0).mean() < 0.2
    # adding after a merge replaces within the filled region (never grows a
    # deficit sample, which would overweight new data) and stays finite
    filled = m.n_filled
    m.add(rng.normal(0, 1, 500).astype(np.float32))
    assert m.n_filled == filled
    assert np.isfinite(m.sample()).all()


def test_store_query_batch_requires_column(rng):
    store = TelemetryStore(capacity=64, seed=0)
    store.add_batch({"x": rng.normal(0, 1, 100).astype(np.float32)})
    with pytest.raises(ValueError, match="name a column"):
        store.query_batch([Query("count", 0.0, 1.0)])


def test_store_merge_preserves_cache_bound(rng):
    s1 = TelemetryStore(capacity=64, seed=0, cache_entries=8)
    s2 = TelemetryStore(capacity=64, seed=1, cache_entries=8)
    s1.add_batch({"x": rng.normal(0, 1, 100).astype(np.float32)})
    s2.add_batch({"x": rng.normal(0, 1, 100).astype(np.float32)})
    assert s1.merge(s2).cache.max_entries == 8


def test_merge_snapshot_does_not_alias_single_side_columns(rng):
    s1 = TelemetryStore(capacity=64, seed=0)
    s2 = TelemetryStore(capacity=64, seed=1)
    s1.add_batch({"only_in_a": rng.normal(0, 1, 50).astype(np.float32)})
    s2.add_batch({"shared": rng.normal(0, 1, 50).astype(np.float32)})
    s1.add_batch({"shared": rng.normal(0, 1, 50).astype(np.float32)})
    m = s1.merge(s2)
    before = m.columns["only_in_a"].n_seen
    s1.add_batch({"only_in_a": rng.normal(0, 1, 500).astype(np.float32)})
    assert m.columns["only_in_a"].n_seen == before     # snapshot, not alias
