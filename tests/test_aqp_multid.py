"""Multi-dimensional AQP (core/aqp_multid.py): BoxQueryBatch vs brute-force
eq. 11, the quasi-MC fallback, per-axis bandwidth fitting, planner semantics,
and the graceful full-H routing in the 1-D engine."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BoxQuery, BoxQueryBatch, KDESynopsis, Query,
                        QueryBatch)
from repro.core.aqp import AVG_MIN_COUNT

_erf = np.vectorize(math.erf)


def _brute_force_eq11(x, h_diag, queries, n_source):
    """Direct float64 evaluation of the eq. 11 closed forms, one query at a
    time — the oracle the batched engine must reproduce."""
    x = np.asarray(x, np.float64)
    h = np.asarray(h_diag, np.float64)
    scale = n_source / x.shape[0]
    inv_sqrt_2pi = 1.0 / math.sqrt(2.0 * math.pi)
    out = np.empty((len(queries),), np.float64)
    for qi, q in enumerate(queries):
        za = (np.asarray(q.lo) - x) / h
        zb = (np.asarray(q.hi) - x) / h
        d_Phi = 0.5 * (_erf(zb / math.sqrt(2)) - _erf(za / math.sqrt(2)))
        d_phi = inv_sqrt_2pi * (np.exp(-0.5 * zb * zb) - np.exp(-0.5 * za * za))
        count = scale * np.sum(np.prod(d_Phi, axis=1))
        t = q.target_index()
        moment = x * d_Phi - h * d_phi
        factors = d_Phi.copy()
        factors[:, t] = moment[:, t]
        s = scale * np.sum(np.prod(factors, axis=1))
        if q.op == "count":
            out[qi] = count
        elif q.op == "sum":
            out[qi] = s
        else:
            out[qi] = s / count if count > AVG_MIN_COUNT else 0.0
    return out


def _mixed_boxes(rng, d, n_queries):
    ops = ["count", "sum", "avg"]
    queries = []
    for i in range(n_queries):
        lo = rng.uniform(-2.0, 0.0, d)
        hi = lo + rng.uniform(0.8, 3.0, d)
        queries.append(BoxQuery(ops[i % 3], tuple(lo), tuple(hi),
                                target=int(rng.integers(d))))
    return queries


@pytest.mark.parametrize("d", [2, 3])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_box_batch_matches_brute_force_eq11(rng, d, backend):
    """Acceptance bar: batched (and Pallas) eq. 11 answers match a float64
    per-query brute-force evaluation to 1e-5 relative error."""
    data = rng.normal(0, 1, (1024, d)).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=2048)
    syn.n_source = 100_000      # exercise a non-trivial sample->relation scale
    queries = _mixed_boxes(rng, d, 33)    # non-multiple of any tile size

    got = BoxQueryBatch(queries).run(syn, backend=backend)
    want = _brute_force_eq11(syn.x, np.asarray(syn.h), queries, syn.n_source)
    np.testing.assert_allclose(
        got, want, rtol=1e-5, atol=1e-5 * max(1.0, np.abs(want).max()))


def test_box_batch_vs_exact_answers(rng):
    data = rng.normal(0, 1, (40000, 2)).astype(np.float32)
    data[:, 1] = 0.6 * data[:, 0] + 0.8 * data[:, 1]      # correlated columns
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=2048)
    lo, hi = (-1.0, -1.0), (1.0, 1.0)
    ans = BoxQueryBatch([
        BoxQuery("count", lo, hi),
        BoxQuery("sum", lo, hi, target=0),
        BoxQuery("avg", lo, hi, target=1),
    ]).run(syn)
    sel = ((data >= -1.0) & (data <= 1.0)).all(axis=1)
    assert ans[0] == pytest.approx(float(sel.sum()), rel=0.08)
    # SUM of a near-symmetric column cancels towards zero -> bound by count
    assert abs(ans[1] - data[sel, 0].sum()) < 0.05 * sel.sum()
    assert ans[2] == pytest.approx(float(data[sel, 1].mean()), abs=0.05)


def test_box_batch_matches_qmc_fallback(rng):
    """H = diag(h^2) makes the full-H density identical to the product
    kernel, so the quasi-MC route must agree with the closed forms to QMC
    accuracy — this pins the two independent integration paths together."""
    x = jnp.asarray(rng.normal(0, 1, (512, 2)).astype(np.float32))
    h = jnp.asarray([0.35, 0.45], jnp.float32)
    syn_diag = KDESynopsis(x=x, h=h, n_source=512)
    syn_full = KDESynopsis(x=x, H=jnp.diag(h * h), n_source=512)
    queries = [BoxQuery("count", (-1.5, -1.0), (1.0, 1.5)),
               BoxQuery("sum", (-1.5, -1.0), (1.0, 1.5), target=1),
               BoxQuery("avg", (-1.5, -1.0), (1.0, 1.5), target=0)]
    a = BoxQueryBatch(queries).run(syn_diag)
    b = BoxQueryBatch(queries).run(syn_full)
    np.testing.assert_allclose(a, b, rtol=0.05, atol=0.05)


def test_multid_fit_per_axis_bandwidths(rng):
    data = rng.normal(0, 1, (4000, 3)).astype(np.float32)
    data[:, 2] *= 10.0                      # wider axis -> wider bandwidth
    for selector in ["plugin", "silverman"]:
        syn = KDESynopsis.fit(jnp.asarray(data), selector=selector,
                              max_sample=1024)
        h = np.asarray(syn.h)
        assert h.shape == (3,)
        assert (h > 0).all()
        assert h[2] > 3.0 * h[0]            # scales with the axis spread


def test_degenerate_boxes(rng):
    data = rng.normal(0, 1, (2000, 2)).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=512)
    ans = BoxQueryBatch([
        BoxQuery("count", (0.3, 0.3), (0.3, 0.3)),       # zero-measure box
        BoxQuery("count", (40.0, 40.0), (50.0, 50.0)),   # empty intersection
        BoxQuery("avg", (40.0, 40.0), (50.0, 50.0), target=1),
    ]).run(syn)
    assert ans[0] == pytest.approx(0.0, abs=1e-5)
    assert ans[1] == pytest.approx(0.0, abs=1e-3)
    assert ans[2] == 0.0 and np.isfinite(ans).all()


def test_box_batch_groups_column_tuples(rng):
    d1 = rng.normal(0, 1, (8000, 2)).astype(np.float32)
    d2 = rng.normal(3, 1, (8000, 3)).astype(np.float32)
    synopses = {
        ("a", "b"): KDESynopsis.fit(jnp.asarray(d1), selector="plugin",
                                    max_sample=512),
        ("u", "v", "w"): KDESynopsis.fit(jnp.asarray(d2), selector="plugin",
                                         max_sample=512),
    }
    queries = [
        BoxQuery("count", (-1, -1), (1, 1), columns=("a", "b")),
        BoxQuery("sum", (2, 2, 2), (4, 4, 4), columns=("u", "v", "w"),
                 target="w"),
        BoxQuery("avg", (-2, -2), (0, 0), columns=("a", "b"), target="b"),
    ]
    batch = BoxQueryBatch(queries)
    assert sorted(batch.column_groups) == [("a", "b"), ("u", "v", "w")]
    got = batch.run(synopses)
    for q, ans in zip(queries, got):
        syn = synopses[q.columns]
        t = q.target_index()
        want = {"count": lambda: syn.count_box(q.lo, q.hi),
                "sum": lambda: syn.sum_box(q.lo, q.hi, target=t),
                "avg": lambda: syn.avg_box(q.lo, q.hi, target=t)}[q.op]()
        assert ans == pytest.approx(float(want), rel=1e-5, abs=1e-5)


def test_box_query_validation():
    with pytest.raises(ValueError, match="unknown op"):
        BoxQuery("median", (0, 0), (1, 1))
    with pytest.raises(ValueError, match="mismatch"):
        BoxQuery("count", (0, 0), (1, 1, 1))
    with pytest.raises(ValueError, match="names"):
        BoxQuery("count", (0, 0), (1, 1), columns=("a",))
    with pytest.raises(ValueError, match="target"):
        BoxQuery("sum", (0, 0), (1, 1), target=5)
    with pytest.raises(ValueError, match="target column"):
        BoxQuery("sum", (0, 0), (1, 1), columns=("a", "b"), target="c")
    with pytest.raises(ValueError, match="mix box dimensionalities"):
        BoxQueryBatch([BoxQuery("count", (0,), (1,)),
                       BoxQuery("count", (0, 0), (1, 1))])


def test_box_batch_synopsis_mismatches(rng):
    data = rng.normal(0, 1, (1000, 2)).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=256)
    with pytest.raises(ValueError, match="single synopsis"):
        BoxQueryBatch([BoxQuery("count", (0, 0), (1, 1),
                                columns=("a", "b"))]).run(syn)
    with pytest.raises(ValueError, match="name their columns"):
        BoxQueryBatch([BoxQuery("count", (0, 0), (1, 1))]).run({("a", "b"): syn})
    with pytest.raises(KeyError, match="no joint synopsis"):
        BoxQueryBatch([BoxQuery("count", (0, 0), (1, 1),
                                columns=("x", "y"))]).run({("a", "b"): syn})
    with pytest.raises(ValueError, match="2-d"):
        BoxQueryBatch([BoxQuery("count", (0, 0, 0), (1, 1, 1))]).run(syn)


def test_query_batch_full_H_fallback(rng):
    """Satellite: a full-H 1-D synopsis no longer raises in the batched
    engine — its group routes through the quasi-MC path."""
    data = rng.normal(5.0, 2.0, 20000).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="lscv_H", max_sample=512)
    assert syn.h is None and syn.H is not None
    queries = [Query("count", 3.0, 7.0), Query("sum", 3.0, 7.0),
               Query("avg", 3.0, 7.0)]
    got = QueryBatch(queries).run(syn)
    sel = (data >= 3.0) & (data <= 7.0)
    assert got[0] == pytest.approx(float(sel.sum()), rel=0.15)
    assert got[1] == pytest.approx(float(data[sel].sum()), rel=0.15)
    assert got[2] == pytest.approx(float(data[sel].mean()), rel=0.10)


def test_query_batch_multid_synopsis_points_to_box_engine(rng):
    data = rng.normal(0, 1, (2000, 2)).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=256)
    with pytest.raises(ValueError, match="BoxQueryBatch"):
        QueryBatch([Query("count", 0.0, 1.0)]).run(syn)


def test_synopsis_query_box_batch_method(rng):
    data = rng.normal(0, 1, (5000, 2)).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=512)
    ans = syn.query_box_batch([BoxQuery("count", (-1, -1), (1, 1))])
    assert ans[0] == pytest.approx(float(syn.count_box((-1, -1), (1, 1))),
                                   rel=1e-6)
