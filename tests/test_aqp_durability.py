"""Durable AQP store: checkpoint/restore round-trips (reservoir buffers +
RNG bit-generator state, categorical sketches, joint registrations, fitted
synopses), post-restore bit-identical determinism, the snapshot-vs-mutation
coverage invariant, count-min sketches for high-cardinality columns, and the
restart-a-serving-process acceptance scenario."""
import threading

import numpy as np
import pytest

from repro.core import AqpQuery, Box, Eq, Range
from repro.data import CategoricalSketch, CountMinSketch, TelemetryStore


def _full_store(rng, n=20_000, capacity=512):
    """A store exercising every durable part: per-column reservoirs, a
    streamed joint, a backfilled joint, an exact sketch, a count-min
    sketch."""
    store = TelemetryStore(capacity=capacity, seed=0)
    store.track_joint(("a", "b"))
    store.track_categorical("code")
    store.track_categorical("wide", kind="cm")
    a = rng.normal(0, 1, n).astype(np.float32)
    store.add_batch({
        "a": a,
        "b": (0.8 * a + 0.6 * rng.normal(0, 1, n)).astype(np.float32),
        "code": rng.integers(0, 4, n).astype(np.float32),
        "wide": rng.integers(0, 10_000, n).astype(np.float32),
    })
    store.track_joint(("code", "b"))     # backfilled from per-column samples
    return store


def _batch(rng, n=5_000):
    a = rng.normal(0.5, 1, n).astype(np.float32)
    return {
        "a": a,
        "b": (0.8 * a + 0.6 * rng.normal(0, 1, n)).astype(np.float32),
        "code": rng.integers(0, 4, n).astype(np.float32),
        "wide": rng.integers(0, 10_000, n).astype(np.float32),
    }


_SPECS = [
    AqpQuery("count", (Range("a", -1.0, 1.0),)),
    AqpQuery("sum", (Range("b", -0.5, 2.0),), target="b"),
    AqpQuery("avg", (Box(("a", "b"), (-1.0, -1.0), (1.0, 1.0)),), target="b"),
    AqpQuery("count", (Eq("code", 2.0),)),
    AqpQuery("count", (Eq("wide", 137.0),)),
]


def _assert_rows_identical(r1, r2):
    for x, y in zip(r1, r2):
        assert x.estimate == y.estimate, (x, y)
        assert x.path == y.path and x.synopsis_version == y.synopsis_version


def _assert_stores_identical(s1: TelemetryStore, s2: TelemetryStore):
    assert sorted(s1.columns) == sorted(s2.columns)
    for name, res in s1.columns.items():
        other = s2.columns[name]
        np.testing.assert_array_equal(res.sample(), other.sample())
        assert (res.n_seen, res.n_filled, res.version) == \
            (other.n_seen, other.n_filled, other.version)
        assert res.rng.bit_generator.state == other.rng.bit_generator.state
    assert sorted(s1.joints) == sorted(s2.joints)
    for key, res in s1.joints.items():
        other = s2.joints[key]
        np.testing.assert_array_equal(res.sample(), other.sample())
        assert res.backfilled == other.backfilled
        assert (res.n_seen, res.version) == (other.n_seen, other.version)


# --- round-trip determinism (satellite + acceptance) -------------------------

def test_roundtrip_then_add_batch_is_bit_identical(rng, tmp_path):
    """save -> load -> add_batch(B) must yield bit-identical samples,
    versions, RNG states, and query answers to the un-restored store fed the
    same batch — the RNG bit-generator state survives the checkpoint, so
    post-restore reservoir acceptance draws replay exactly."""
    store = _full_store(rng)
    store.save(str(tmp_path))
    restored = TelemetryStore.load(str(tmp_path))
    _assert_stores_identical(store, restored)

    batch = _batch(rng)
    store.add_batch(batch)
    restored.add_batch(batch)
    _assert_stores_identical(store, restored)
    _assert_rows_identical(store.query(_SPECS), restored.query(_SPECS))


def test_restart_serving_process_scenario(rng, tmp_path):
    """Acceptance: a serving process killed and restarted from a snapshot
    answers the same query batch bit-identically to an uninterrupted one,
    with the exact categorical path still active after restore."""
    uninterrupted = _full_store(rng)
    uninterrupted.save(str(tmp_path))

    # "kill" the process: drop every live object, restart from disk only
    restarted = TelemetryStore.load(str(tmp_path))
    batch = _batch(rng)
    uninterrupted.add_batch(batch)
    restarted.add_batch(batch)

    with uninterrupted.session(auto_flush=False, watermark=None,
                               max_delay=None) as s1, \
            restarted.session(auto_flush=False, watermark=None,
                              max_delay=None) as s2:
        r1 = s1.execute(_SPECS)
        r2 = s2.execute(_SPECS)
    _assert_rows_identical(r1, r2)
    assert r2[3].path == "exact"             # whole-stream coverage survived
    assert r2[4].path == "exact:cm"
    assert restarted.stats()["categoricals"]["code"]["exact"] is True


def test_restore_warm_starts_fitted_synopses(rng, tmp_path):
    """The fitted synopses ride along in the snapshot: a warm-started store
    answers the same specs with ZERO cache misses (no bandwidth refit)."""
    store = _full_store(rng)
    store.query(_SPECS)                      # fit + populate the cache
    store.save(str(tmp_path))
    restored = TelemetryStore.load(str(tmp_path))
    misses0 = restored.cache.stats()["misses"]
    r = restored.query(_SPECS)
    assert restored.cache.stats()["misses"] == misses0
    _assert_rows_identical(store.query(_SPECS), r)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chained_weighted_merge_then_restore(seed, tmp_path):
    """Property (over seeds): a store built by chained weighted merges
    round-trips like any other — post-restore updates and answers are
    bit-identical to the un-restored merged store's."""
    rng = np.random.default_rng(seed)
    parts = []
    for i, (mu, n) in enumerate([(0.0, 8000), (3.0, 4000), (6.0, 2000)]):
        st = TelemetryStore(capacity=256, seed=i)
        st.track_categorical("code")
        st.add_batch({"x": rng.normal(mu, 1, n).astype(np.float32),
                      "code": rng.integers(0, 3, n).astype(np.float32)})
        parts.append(st)
    merged = parts[0].merge(parts[1]).merge(parts[2])
    merged.save(str(tmp_path))
    restored = TelemetryStore.load(str(tmp_path))
    _assert_stores_identical(merged, restored)

    batch = {"x": rng.normal(1, 1, 3000).astype(np.float32),
             "code": rng.integers(0, 3, 3000).astype(np.float32)}
    merged.add_batch(batch)
    restored.add_batch(batch)
    _assert_stores_identical(merged, restored)
    specs = [AqpQuery("count", (Range("x", -1.0, 4.0),)),
             AqpQuery("count", (Eq("code", 1.0),))]
    _assert_rows_identical(merged.query(specs), restored.query(specs))


def test_save_keep_k_retains_latest(rng, tmp_path):
    from repro.checkpoint import CheckpointManager

    store = _full_store(rng, n=2_000, capacity=128)
    for _ in range(4):
        store.add_batch(_batch(rng, n=500))
        store.save(str(tmp_path), keep=2)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    assert len(mgr.all_steps()) == 2           # keep-k GC ran
    restored = TelemetryStore.load(str(tmp_path))
    _assert_stores_identical(store, restored)


# --- snapshot-vs-mutation consistency (satellite) ----------------------------

def test_snapshot_never_persists_uncovered_sketch_rows(rng):
    """A snapshot racing add_batch must see whole batches only: no persisted
    sketch may claim more rows than its reservoir's n_seen (a restored store
    would claim exact coverage it doesn't have).  Hammer to_state from a
    second thread while the main thread streams batches."""
    store = TelemetryStore(capacity=128, seed=0)
    store.track_categorical("code")
    snapshots = []
    stop = threading.Event()

    def snapshotter():
        while not stop.is_set():
            snapshots.append(store.to_state())

    t = threading.Thread(target=snapshotter)
    t.start()
    try:
        for _ in range(40):
            store.add_batch(
                {"code": rng.integers(0, 4, 2_000).astype(np.float32)})
    finally:
        stop.set()
        t.join(5.0)
    assert len(snapshots) >= 2
    for tree, meta in snapshots:
        cat = meta["categoricals"].get("code")
        col = meta["columns"].get("code")
        if cat is None or col is None:
            continue
        # whole-batch atomicity: coverage holds exactly, not just <=
        assert cat["n_rows"] == col["n_seen"], (cat, col)
        TelemetryStore.from_state(tree, meta)      # never raises


def test_from_state_rejects_inconsistent_sketch(rng, tmp_path):
    store = TelemetryStore(capacity=128, seed=0)
    store.track_categorical("code")
    store.add_batch({"code": rng.integers(0, 4, 1_000).astype(np.float32)})
    tree, meta = store.to_state()
    meta["categoricals"]["code"]["n_rows"] += 5    # claims unseen rows
    with pytest.raises(ValueError, match="inconsistent snapshot"):
        TelemetryStore.from_state(tree, meta)


def test_from_state_rejects_unknown_format(rng):
    store = TelemetryStore(capacity=64, seed=0)
    tree, meta = store.to_state()
    meta["format"] = 999
    with pytest.raises(ValueError, match="format"):
        TelemetryStore.from_state(tree, meta)


# --- restored versions flow through subscribe (admission re-keying) ----------

def test_restore_state_notifies_subscribers_and_rekeys_sessions(rng):
    """restore_state on a live store must push the restored versions through
    the subscribe listeners, so in-flight admission buckets re-key and flush
    against (and report) the restored synopsis versions."""
    store = TelemetryStore(capacity=256, seed=0)
    store.add_batch({"x": rng.normal(0, 1, 4_000).astype(np.float32)})
    snapshot = store.to_state()                    # x at version 1
    store.add_batch({"x": rng.normal(0, 1, 1_000).astype(np.float32)})
    assert store.columns["x"].version == 2

    seen = []
    store.subscribe(seen.append)
    session = store.session(auto_flush=False, watermark=None, max_delay=None)
    fut = session.submit(AqpQuery("count", (Range("x", -1.0, 1.0),)))
    store.restore_state(*snapshot)                 # roll back to version 1
    assert seen and seen[-1]["x"] == 1
    assert session.stats()["invalidations"] == 1   # pending bucket re-keyed
    session.flush()
    assert fut.result().synopsis_version == 1
    session.close()


# --- dtype normalization regression (satellite) ------------------------------

def test_sketch_counts_under_float32_codes_like_the_reservoir(rng):
    """Regression: CategoricalSketch.add used to coerce to float64 while
    Reservoir._coerce uses float32, so a code that is not exactly
    float32-representable (16777217 rounds to 16777216) counted under
    different codes on the exact path vs the KDE fallback."""
    store = TelemetryStore(capacity=64, seed=0)
    store.track_categorical("code")
    store.add_batch({"code": np.full(8, 16777217.0)})   # float64 input
    sketch = store.categoricals["code"]
    # one code, and it is the float32 rounding the reservoir sampled
    assert set(sketch.counts) == {16777216.0}
    np.testing.assert_array_equal(store.columns["code"].sample(),
                                  np.full(8, 16777216.0, np.float32))
    r = store.query([AqpQuery("count", (Eq("code", 16777216.0),))])[0]
    assert r.path == "exact" and r.estimate == 8.0
    # the unrepresentable spelling holds no mass on the exact path, matching
    # the KDE sample (where it cannot be distinguished either)
    r2 = store.query([AqpQuery("count", (Eq("code", 16777217.0),))])[0]
    assert r2.path == "exact" and r2.estimate == 0.0


def test_count_min_uses_float32_codes(rng):
    cm = CountMinSketch(seed=3)
    cm.add(np.full(10, 16777217.0))                 # float64 input
    assert cm.estimate(float(np.float32(16777216.0))) >= 10


# --- count-min sketches (high-cardinality fallback) --------------------------

def test_count_min_estimates_overcount_within_bound(rng):
    cm = CountMinSketch(width=2048, depth=4, seed=1)
    values = rng.integers(0, 5_000, 30_000).astype(np.float32)
    cm.add(values)
    assert cm.n_rows == 30_000 and cm.exact_for(30_000)
    for code in (0.0, 17.0, 4_999.0):
        true = int((values == code).sum())
        est = cm.estimate(code)
        assert est >= true                          # CM never undercounts
        assert est - true <= 4 * cm.err_bound()     # and stays bounded


def test_count_min_store_path_and_wide_window_fallback(rng):
    store = TelemetryStore(capacity=256, seed=0)
    store.track_categorical("wide", kind="cm")
    values = rng.integers(0, 5_000, 20_000).astype(np.float32)
    store.add_batch({"wide": values})
    cat = store.stats()["categoricals"]["wide"]
    assert cat["kind"] == "cm" and cat["exact"] is True
    assert not cat["overflowed"]                    # CM never overflows

    r = store.query([AqpQuery("count", (Eq("wide", 137.0),))],
                    selector="silverman")[0]
    assert r.path == "exact:cm"
    assert r.estimate >= int((values == 137.0).sum())
    # a window too wide to enumerate falls back to the KDE, not garbage
    wide_eq = AqpQuery("count", (Eq("wide", 2_500.0, halfwidth=1_000.0),))
    assert store.query([wide_eq], selector="silverman")[0].path == "range1d"
    # late coverage gate: a second un-sketched stream disables the path
    store2 = TelemetryStore(capacity=256, seed=0)
    store2.add_batch({"wide": values})
    store2.track_categorical("wide", kind="cm")     # AFTER data
    store2.add_batch({"wide": values[:100]})
    r2 = store2.query([AqpQuery("count", (Eq("wide", 137.0),))],
                      selector="silverman")[0]
    assert r2.path == "range1d"


def test_count_min_merge_is_additive(rng):
    s1 = TelemetryStore(capacity=128, seed=0)
    s2 = TelemetryStore(capacity=128, seed=1)
    for st in (s1, s2):
        st.track_categorical("wide", kind="cm")     # same column -> same seed
    v1 = rng.integers(0, 1_000, 8_000).astype(np.float32)
    v2 = rng.integers(0, 1_000, 4_000).astype(np.float32)
    s1.add_batch({"wide": v1})
    s2.add_batch({"wide": v2})
    m = s1.merge(s2)
    sk = m.categoricals["wide"]
    assert sk.n_rows == 12_000 and sk.exact_for(12_000)
    true = int((v1 == 5.0).sum() + (v2 == 5.0).sum())
    assert sk.estimate(5.0) >= true
    with pytest.raises(ValueError, match="geometry"):
        CountMinSketch(width=64, seed=0).merge(CountMinSketch(width=128,
                                                              seed=0))


def test_exact_sketch_state_roundtrip_overflowed(rng):
    sk = CategoricalSketch(max_codes=8)
    sk.add(np.arange(64, dtype=np.float32))          # overflow
    back = CategoricalSketch.from_state(*sk.state())
    assert back.overflowed and back.n_rows == 64 and back.counts == {}


# --- review regressions ------------------------------------------------------

def test_state_roundtrip_with_nan_codes(rng):
    """A NaN row in a tracked categorical column must not make save()
    crash: state() serializes counts by items() (NaN keys can never be
    looked up again, nan != nan)."""
    store = TelemetryStore(capacity=64, seed=0)
    store.track_categorical("code")
    store.add_batch({"code": np.asarray([1.0, 2.0, np.nan], np.float32)})
    tree, meta = store.to_state()                    # must not raise
    restored = TelemetryStore.from_state(tree, meta)
    sk = restored.categoricals["code"]
    assert sk.n_rows == 3
    assert sk.range_terms(0.5, 2.5) == (2, pytest.approx(3.0))


def test_count_min_range_dedupes_float32_aliased_codes():
    """Consecutive ints above 2^24 alias to one float32 code; a window
    covering both must count the shared cell once, not per-int."""
    cm = CountMinSketch(width=256, depth=4, seed=0)
    cm.add(np.full(10, 16777216.0, np.float32))
    cnt, _ = cm.range_terms(16777214.5, 16777217.5)  # ints ..216 and ..217
    assert cnt == 10                                 # not 20


def test_count_min_restore_keeps_hash_parameters(rng, tmp_path):
    """The hash multipliers are persisted, not re-derived from the seed on
    load — a table read through different hashes is silently wrong.  A
    restored sketch must also still merge with the original (geometry is
    compared on the actual parameters)."""
    store = TelemetryStore(capacity=128, seed=0)
    store.track_categorical("wide", kind="cm")
    values = rng.integers(0, 2_000, 10_000).astype(np.float32)
    store.add_batch({"wide": values})
    store.save(str(tmp_path))
    back = TelemetryStore.load(str(tmp_path)).categoricals["wide"]
    orig = store.categoricals["wide"]
    np.testing.assert_array_equal(back._mul, orig._mul)
    np.testing.assert_array_equal(back._add, orig._add)
    assert back.estimate(17.0) == orig.estimate(17.0)
    merged = orig.merge(back)                        # same parameters: fine
    assert merged.n_rows == 20_000


def test_to_state_consistent_under_concurrent_queries(rng):
    """Snapshots race live query traffic: cache hits reorder the LRU list
    while to_state serializes it, which must never blow up mid-iteration."""
    from repro.core import AqpQuery, Range

    store = TelemetryStore(capacity=128, seed=0)
    store.add_batch({"x": rng.normal(0, 1, 4_000).astype(np.float32),
                     "y": rng.normal(0, 1, 4_000).astype(np.float32)})
    stop = threading.Event()
    errs = []

    def querier():
        try:
            i = 0
            while not stop.is_set():
                col = ("x", "y")[i % 2]
                sel = ("plugin", "silverman")[i % 2]
                store.query([AqpQuery("count", (Range(col, -1.0, 1.0),))],
                            selector=sel)
                i += 1
        except BaseException as exc:          # pragma: no cover
            errs.append(exc)

    t = threading.Thread(target=querier)
    t.start()
    try:
        for _ in range(30):
            tree, meta = store.to_state()
            TelemetryStore.from_state(tree, meta)
    finally:
        stop.set()
        t.join(10.0)
    assert not errs
