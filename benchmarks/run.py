"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]

Prints ``name,us_per_call,derived`` CSV lines.  --quick sets
REPRO_BENCH_QUICK=1, which suites honouring it (aqp_boxes, aqp_engine,
aqp_serve, aqp_restore, aqp_progressive) read at run() time to shrink to a
CI-smoke configuration.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

SUITES = ("paper_validation", "plugin", "lscv_h", "lscv_H", "table3",
          "kernels", "aqp_batch", "aqp_boxes", "aqp_engine", "aqp_serve",
          "aqp_restore", "aqp_progressive", "roofline", "serving")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help=f"one of {SUITES}")
    ap.add_argument("--quick", action="store_true",
                    help="small shapes for CI smoke runs")
    args = ap.parse_args()
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    suites = [args.only] if args.only else list(SUITES)

    print("name,us_per_call,derived")
    t0 = time.time()
    for s in suites:
        mod = __import__(f"benchmarks.bench_{s}", fromlist=["run"])
        print(f"# --- {s} ({time.time() - t0:.0f}s elapsed) ---", flush=True)
        mod.run()
    print(f"# total {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
