"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick] \
        [--json [PATH]]

Prints ``name,us_per_call,derived`` CSV lines.  --quick sets
REPRO_BENCH_QUICK=1, which suites honouring it (aqp_boxes, aqp_engine,
aqp_serve, aqp_restore, aqp_progressive, aqp_rff) read at run() time to
shrink to a CI-smoke configuration.  Every run also writes the
machine-readable report to BENCH_aqp.json at the repo root (--json PATH
overrides the destination): every emitted measurement with name,
us_per_call, p50/p99 when raw samples were provided, suite-specific extras
(speedups, batch depths), plus git sha, config, and wall time — CI archives
it and `scripts/validate_metrics.py --bench` schema-checks it.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

SUITES = ("paper_validation", "plugin", "lscv_h", "lscv_H", "table3",
          "kernels", "aqp_batch", "aqp_boxes", "aqp_grouped", "aqp_engine",
          "aqp_rff",
          "aqp_serve", "aqp_restore", "aqp_progressive", "roofline",
          "serving")

# the always-on report lands at the repo root regardless of the cwd the
# harness was launched from, so CI archiving finds one canonical path
_DEFAULT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_aqp.json")


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10,
        ).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help=f"one of {SUITES}")
    ap.add_argument("--quick", action="store_true",
                    help="small shapes for CI smoke runs")
    ap.add_argument("--json", nargs="?", const=_DEFAULT_JSON,
                    default=_DEFAULT_JSON, metavar="PATH",
                    help="where to write the machine-readable report "
                         "(always written; default BENCH_aqp.json at the "
                         "repo root)")
    args = ap.parse_args()
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    suites = [args.only] if args.only else list(SUITES)

    from . import common

    print("name,us_per_call,derived")
    t0 = time.time()
    for s in suites:
        mod = __import__(f"benchmarks.bench_{s}", fromlist=["run"])
        print(f"# --- {s} ({time.time() - t0:.0f}s elapsed) ---", flush=True)
        mod.run()
    wall = time.time() - t0
    print(f"# total {wall:.0f}s", flush=True)

    if args.json:
        doc = {
            "git_sha": _git_sha(),
            "ts": time.time(),
            "config": {"quick": bool(args.quick), "suites": suites,
                       "argv": sys.argv[1:]},
            "wall_s": wall,
            "results": common.RESULTS,
        }
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"# wrote {len(common.RESULTS)} results -> {args.json}",
              flush=True)


if __name__ == "__main__":
    main()
