"""Batched vs per-query multi-d box AQP throughput (core/aqp_multid.py).

A mixed COUNT/SUM/AVG box batch against one joint synopsis is answered
three ways:
  loop    — one jitted call per query (count_box/sum_box/avg_box)
  batch   — single jitted, vmapped eq. 11 product-kernel pass
  pallas  — the kernels/aqp_boxes.py tile kernel (interpret mode on CPU)

Reports queries/s and the batch-over-loop speedup; the acceptance bar for
the multi-d engine is >= 10x over the per-query Python loop on CPU.

Set REPRO_BENCH_QUICK=1 (or `python -m benchmarks.run --quick`) for the CI
smoke configuration: one small batch, d=2 only.
"""
from __future__ import annotations


import numpy as np

from .common import emit, time_call
from .common import quick as common_quick

Q_SIZES = (64, 512)
SAMPLE = 2048
DIMS = (2, 3)


def _quick() -> bool:
    return common_quick()


def _setup(n_queries: int, d: int, seed: int = 0):
    import jax.numpy as jnp

    from repro.core import KDESynopsis
    from repro.launch.serve import make_box_query_mix

    rng = np.random.default_rng(seed)
    n_rows = 100_000
    # correlated joint columns: a latent factor plus per-axis noise
    latent = rng.normal(0, 1, n_rows)
    data = np.stack([latent + rng.normal(0, 0.5 + 0.2 * j, n_rows)
                     for j in range(d)], axis=1).astype(np.float32)
    sample = SAMPLE if not _quick() else 512
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin",
                          max_sample=sample)
    columns = tuple(f"c{j}" for j in range(d))
    ranges = {c: (float(data[:, j].min()), float(data[:, j].max()))
              for j, c in enumerate(columns)}
    queries = make_box_query_mix(n_queries, columns, ranges, seed=seed)
    # a single-synopsis batch carries no column names
    from repro.core import BoxQuery
    bare = [BoxQuery(q.op, q.lo, q.hi, target=q.target_index())
            for q in queries]
    return syn, bare


def _loop_answers(syn, queries) -> np.ndarray:
    out = np.empty((len(queries),), np.float64)
    for i, q in enumerate(queries):
        t = q.target_index()
        if q.op == "count":
            out[i] = float(syn.count_box(q.lo, q.hi))
        elif q.op == "sum":
            out[i] = float(syn.sum_box(q.lo, q.hi, target=t))
        else:
            out[i] = float(syn.avg_box(q.lo, q.hi, target=t))
    return out


def run() -> dict:
    from repro.core.aqp_multid import run_legacy_boxes

    out = {}
    q_sizes = Q_SIZES if not _quick() else (32,)
    dims = DIMS if not _quick() else (2,)
    for d in dims:
        for nq in q_sizes:
            syn, queries = _setup(nq, d)

            want = _loop_answers(syn, queries)
            got = run_legacy_boxes(queries, syn)
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-2)

            t_loop = time_call(_loop_answers, syn, queries, repeats=3,
                               warmup=1)
            t_batch = time_call(run_legacy_boxes, queries, syn,
                                repeats=5, warmup=2)
            speedup = t_loop / t_batch
            emit(f"aqp_boxes_loop_d{d}_q{nq}", t_loop,
                 f"{nq / (t_loop * 1e-6):,.0f} q/s")
            emit(f"aqp_boxes_batch_d{d}_q{nq}", t_batch,
                 f"{nq / (t_batch * 1e-6):,.0f} q/s, {speedup:.1f}x over loop")
            out[f"speedup_d{d}_q{nq}"] = speedup

            # Pallas tile kernel path: correctness always, timing as reported.
            # Wider tolerance than the jnp pass: per-tile fp32 accumulation
            # noise is amplified by the sample->relation scale (~1e2 here).
            got_pl = run_legacy_boxes(queries, syn, backend="pallas")
            np.testing.assert_allclose(got_pl, want, rtol=5e-4, atol=5e-2)
            t_pl = time_call(lambda: run_legacy_boxes(queries, syn,
                                                      backend="pallas"),
                             repeats=3, warmup=1)
            emit(f"aqp_boxes_pallas_d{d}_q{nq}", t_pl,
                 f"{nq / (t_pl * 1e-6):,.0f} q/s (interpret mode on CPU, "
                 f"{t_loop / t_pl:.1f}x over loop)")
            out[f"speedup_pallas_d{d}_q{nq}"] = t_loop / t_pl
    return out


if __name__ == "__main__":
    run()
