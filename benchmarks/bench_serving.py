"""Serving-throughput projection from the decode-cell rooflines.

For each decode/long-context cell: steady-state tokens/s/pod = global_batch /
max(compute, memory, collective term) — the roofline-implied decode rate on
the 256-chip pod (perfect overlap assumption; the dominant term binds).
Also reports per-token HBM cost (the memory term) and the SSM-vs-attention
context-cost contrast the long_500k cells exist to show."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
BATCH = {"decode_32k": 128, "long_500k": 1}


def run() -> dict:
    recs = []
    path = os.path.join(ART, "dryrun_1pod.jsonl")
    if not os.path.exists(path):
        emit("serving_no_artifacts", 0.0, "run the dry-run first")
        return {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("ok") and r.get("shape") in BATCH:
                recs.append(r)
    out = {}
    for r in recs:
        t = r["roofline"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        tps = BATCH[r["shape"]] / bound
        name = f"serving_{r['arch']}_{r['shape']}"
        emit(name, bound * 1e6,
             f"projected {tps:,.0f} tok/s/pod (bound: {t['dominant']})")
        out[name] = tps
    return out


if __name__ == "__main__":
    run()
