"""Shared benchmark utilities: timing, and the paper's asymptotic-speedup
estimator (eqs. 61-63): fit runtime(n) with a 2nd-order polynomial by least
squares, then speedup_limit = a_slow / a_fast."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time in microseconds; blocks on jax async dispatch."""
    import jax
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def quad_fit(ns, times_us):
    """Least-squares fit t(n) = a n^2 + b n + c (paper Speedup(n) framework)."""
    ns = np.asarray(ns, np.float64)
    t = np.asarray(times_us, np.float64)
    A = np.stack([ns ** 2, ns, np.ones_like(ns)], axis=1)
    coef, *_ = np.linalg.lstsq(A, t, rcond=None)
    return coef  # (a, b, c)


def speedup_limit(ns_slow, t_slow, ns_fast, t_fast) -> float:
    """eq. (63): lim_{n->inf} Speedup(n) = a_slow / a_fast."""
    a_s = quad_fit(ns_slow, t_slow)[0]
    a_f = quad_fit(ns_fast, t_fast)[0]
    return float(a_s / max(a_f, 1e-30))


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
