"""Shared benchmark utilities: timing, and the paper's asymptotic-speedup
estimator (eqs. 61-63): fit runtime(n) with a 2nd-order polynomial by least
squares, then speedup_limit = a_slow / a_fast."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np


def quick() -> bool:
    """True in CI-smoke mode (`benchmarks.run --quick` sets the knob)."""
    from repro import knobs
    return knobs.get_bool("REPRO_BENCH_QUICK")


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time in microseconds; blocks on jax async dispatch."""
    import jax
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def quad_fit(ns, times_us):
    """Least-squares fit t(n) = a n^2 + b n + c (paper Speedup(n) framework)."""
    ns = np.asarray(ns, np.float64)
    t = np.asarray(times_us, np.float64)
    A = np.stack([ns ** 2, ns, np.ones_like(ns)], axis=1)
    coef, *_ = np.linalg.lstsq(A, t, rcond=None)
    return coef  # (a, b, c)


def speedup_limit(ns_slow, t_slow, ns_fast, t_fast) -> float:
    """eq. (63): lim_{n->inf} Speedup(n) = a_slow / a_fast."""
    a_s = quad_fit(ns_slow, t_slow)[0]
    a_f = quad_fit(ns_fast, t_fast)[0]
    return float(a_s / max(a_f, 1e-30))


#: every `emit()` call of the process, in order — `benchmarks.run --json`
#: drains this into BENCH_aqp.json
RESULTS: List[Dict] = []


def emit(name: str, us: float, derived: str = "",
         samples: List[float] = None, **extra) -> None:
    """Print the CSV line and record the measurement.

    Besides stdout, each call appends a row to `RESULTS` (for the JSON
    report) and routes the measurement through the process-global metrics
    registry as a ``bench.us_per_call{bench=name}`` histogram — benchmarks
    use the same instrument the serving stack exports, so one
    `obs.export_json` snapshot carries both.  `samples` (raw per-repeat
    timings, µs) enriches the JSON row with p50/p99; `extra` keys (e.g.
    ``speedup=3.2``) pass through to the row verbatim.
    """
    print(f"{name},{us:.1f},{derived}", flush=True)
    row: Dict = {"name": name, "us_per_call": float(us), "derived": derived}
    if samples:
        s = np.sort(np.asarray(samples, np.float64))
        row["p50_us"] = float(s[len(s) // 2])
        row["p99_us"] = float(s[min(len(s) - 1, int(len(s) * 0.99))])
    row.update(extra)
    RESULTS.append(row)
    try:
        from repro import obs
    except ImportError:        # registry is optional for standalone use
        return
    h = obs.get_registry().histogram("bench.us_per_call", bench=name)
    for v in (samples if samples else (us,)):
        h.observe(float(v))
