"""Paper Table 3: processing times for the largest instances.

Paper's numbers (ms, GeForce 480GTX / SSE i7 / sequential):
    PLUGIN  n=32768:           GPU 87.9   | SSE 1442.3 | Seq 47435.3
    LSCV_h  n=1024, d=16:      GPU (n/a)  | SSE 344.1  | Seq 8283.6
    LSCV_H  n=16384, d=16:     GPU 184.2  | SSE 2320   | Seq 53258.8

We run the same instances with the vectorised JAX implementation on this
container's CPU and report them side by side.  (Absolute times are a CPU
apples-to-oranges vs 2012 GPUs; the reproduction claim validated here is the
orders-of-magnitude gap to the sequential implementation, plus completing the
paper's largest instances at all.)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import g_of_H, lscv_h, plugin_bandwidth
from .common import emit, time_call

PAPER_MS = {
    "plugin_n32768": {"gpu": 87.9, "sse": 1442.3, "seq": 47435.3},
    "lscv_h_n1024_d16": {"gpu": None, "sse": 344.1, "seq": 8283.6},
    "gH_n16384_d16": {"gpu": 184.2, "sse": 2320.0, "seq": 53258.8},
}


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}

    x = jnp.asarray(rng.normal(0, 1, 32768).astype(np.float32))
    us = time_call(lambda: plugin_bandwidth(x, chunk=1024).h, repeats=2)
    emit("table3_plugin_n32768", us,
         f"paper: seq {PAPER_MS['plugin_n32768']['seq']}ms sse {PAPER_MS['plugin_n32768']['sse']}ms gpu {PAPER_MS['plugin_n32768']['gpu']}ms")
    out["plugin_n32768_ms"] = us / 1e3

    x = jnp.asarray(rng.normal(0, 1, (1024, 16)).astype(np.float32))
    us = time_call(lambda: lscv_h(x).h, repeats=2)
    emit("table3_lscv_h_n1024_d16", us,
         f"paper: seq {PAPER_MS['lscv_h_n1024_d16']['seq']}ms sse {PAPER_MS['lscv_h_n1024_d16']['sse']}ms")
    out["lscv_h_n1024_d16_ms"] = us / 1e3

    x = jnp.asarray(rng.normal(0, 1, (16384, 16)).astype(np.float32))
    H = jnp.asarray(np.eye(16, dtype=np.float32) * 0.5)
    us = time_call(lambda: g_of_H(x, H, chunk=64), repeats=2)
    emit("table3_gH_n16384_d16", us,
         f"paper: seq {PAPER_MS['gH_n16384_d16']['seq']}ms sse {PAPER_MS['gH_n16384_d16']['sse']}ms gpu {PAPER_MS['gH_n16384_d16']['gpu']}ms")
    out["gH_n16384_d16_ms"] = us / 1e3
    return out


if __name__ == "__main__":
    run()
