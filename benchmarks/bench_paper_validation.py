"""Paper-faithfulness validation pack (EXPERIMENTS.md §Paper-validation):

  1. PLUGIN h == sequential implementation's h (bit-close).
  2. §4.5 reformulation: identical g(h) grids, modified strictly faster.
  3. AQP COUNT/SUM accuracy vs exact on a 100k-row synthetic relation.
  4. KDE ISE with selected bandwidths vs naive bandwidths (selection wins).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import (KDESynopsis, lscv_h, plugin_bandwidth,
                        plugin_bandwidth_sequential)
from .common import emit, time_call


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}

    # 1. PLUGIN vs sequential oracle
    x = rng.normal(2.0, 1.5, 1024).astype(np.float32)
    h_vec = float(plugin_bandwidth(jnp.asarray(x)).h)
    h_seq = plugin_bandwidth_sequential(x)
    rel = abs(h_vec - h_seq) / h_seq
    emit("validate_plugin_vs_sequential", 0.0, f"rel_err={rel:.2e}")
    out["plugin_rel_err"] = rel

    # 2. §4.5: same objective values, less time
    x2 = jnp.asarray(rng.normal(0, 1, (512, 8)).astype(np.float32))
    r_store = lscv_h(x2, store_s=True)
    r_fused = lscv_h(x2)
    same = bool(np.allclose(r_store.g_values, r_fused.g_values, rtol=3e-4))
    emit("validate_s_precompute_equivalence", 0.0, f"g_grids_match={same}")
    out["s_precompute_match"] = same

    # 3. AQP accuracy
    table = rng.lognormal(1.0, 0.6, 100_000).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(table), selector="plugin", max_sample=2048)
    errs = []
    for a, b in [(1.0, 4.0), (2.0, 8.0), (0.5, 2.0), (5.0, 20.0)]:
        approx = float(syn.count(a, b))
        exact = float(((table >= a) & (table <= b)).sum())
        errs.append(abs(approx - exact) / max(exact, 1))
    emit("validate_aqp_count_mean_rel_err", 0.0, f"{np.mean(errs):.3f}")
    out["aqp_count_err"] = float(np.mean(errs))

    # 4. bandwidth selection matters (ISE ordering)
    from repro.core import kde_eval
    mix = np.concatenate([rng.normal(-2, .5, 2000), rng.normal(2, 1., 2000)]).astype(np.float32)
    grid = np.linspace(-5, 6, 256).astype(np.float32)
    truth = (np.exp(-0.5 * ((grid + 2) / .5) ** 2) / (.5 * np.sqrt(2 * np.pi)) +
             np.exp(-0.5 * ((grid - 2) / 1.) ** 2) / (1. * np.sqrt(2 * np.pi))) / 2

    def ise(h):
        f = np.asarray(kde_eval(jnp.asarray(grid), jnp.asarray(mix), jnp.float32(h)))
        return float(np.trapezoid((f - truth) ** 2, grid))

    h_sel = float(plugin_bandwidth(jnp.asarray(mix)).h)
    emit("validate_ise_selected_vs_4x", 0.0,
         f"ise_sel={ise(h_sel):.2e} ise_4x={ise(4 * h_sel):.2e}")
    out["ise_ordering_ok"] = ise(h_sel) < ise(4 * h_sel)
    return out


if __name__ == "__main__":
    run()
