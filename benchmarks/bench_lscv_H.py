"""Paper Fig. 10: LSCV_H — time to evaluate the g(H) objective (the paper
also benchmarks only g(H): '...only implemented computing of the g(H)
objective function, as this is the only element with influence on
performance')."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import g_of_H
from .common import emit, speedup_limit, time_call


def g_of_H_sequential_time(x, H) -> float:
    """Scalar float32 loops (the paper's Sequential implementation)."""
    import math
    import time
    x = np.asarray(x, np.float32)
    H = np.asarray(H, np.float32)
    n, d = x.shape
    t0 = time.perf_counter()
    det = np.linalg.det(H)
    inv = np.linalg.inv(H).astype(np.float32)
    c_k = np.float32((2 * math.pi) ** (-d / 2) * det ** -0.5)
    c_kk = np.float32((4 * math.pi) ** (-d / 2) * det ** -0.5)
    acc = np.float32(0.0)
    for i in range(n):
        for j in range(i + 1, n):
            u = x[i] - x[j]
            s = float(u @ inv @ u)
            acc += c_kk * math.exp(-0.25 * s) - 2 * c_k * math.exp(-0.5 * s)
    _ = 2.0 / (n * n) * acc
    return (time.perf_counter() - t0) * 1e6


def run() -> dict:
    rng = np.random.default_rng(0)
    seq_ns, seq_ts = [256, 512, 1024], []
    d = 4
    H = np.eye(d, dtype=np.float32) * 0.3
    for n in seq_ns:
        x = rng.normal(0, 1, (n, d)).astype(np.float32)
        seq_ts.append(g_of_H_sequential_time(x, H))
        emit(f"gH_sequential_n{n}_d{d}", seq_ts[-1])

    jit_ns, jit_ts, pl_ts = [1024, 2048, 4096, 8192, 16384], [], []
    for n in jit_ns:
        x = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
        Hj = jnp.asarray(H)
        us = time_call(lambda x=x: g_of_H(x, Hj), repeats=2)
        jit_ts.append(us)
        emit(f"gH_fused_n{n}_d{d}", us)

    limit = speedup_limit(seq_ns, seq_ts, jit_ns, jit_ts)
    emit("gH_speedup_limit_vec_over_seq", 0.0, f"{limit:.0f}x")

    # d-sweep at fixed n (paper's d = 1..16 curves)
    for dd in [1, 2, 4, 8, 16]:
        x = jnp.asarray(rng.normal(0, 1, (2048, dd)).astype(np.float32))
        Hd = jnp.asarray(np.eye(dd, dtype=np.float32) * 0.3)
        us = time_call(lambda x=x, Hd=Hd: g_of_H(x, Hd), repeats=2)
        emit(f"gH_fused_n2048_d{dd}", us)
    return {"speedup_limit": limit}


if __name__ == "__main__":
    run()
