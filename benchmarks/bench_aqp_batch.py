"""Batched vs per-query AQP throughput (core/aqp.py QueryBatch engine).

A mixed COUNT/SUM/AVG batch against one synopsis is answered three ways:
  loop    — one jitted call per query (the seed's only path)
  batch   — single jitted, vmapped closed-form pass
  pallas  — the kernels/aqp_batch.py tile kernel (interpret mode on CPU)

Reports queries/s and the batch-over-loop speedup; the batch amortises
dispatch + planning across the whole batch, which is where DEANN-style
batched KDE evaluation gets its wins.
"""
from __future__ import annotations

import numpy as np

from .common import emit, time_call

Q_SIZES = (64, 1024)
SAMPLE = 2048


def _setup(n_queries: int, seed: int = 0):
    import jax.numpy as jnp

    from repro.core import KDESynopsis
    from repro.launch.serve import make_query_mix

    rng = np.random.default_rng(seed)
    data = rng.gamma(4.0, 2.0, 200_000).astype(np.float32)
    syn = KDESynopsis.fit(jnp.asarray(data), selector="plugin", max_sample=SAMPLE)
    queries = make_query_mix(n_queries, {None: (float(data.min()), float(data.max()))},
                             seed=seed)
    return syn, queries


def _loop_answers(syn, queries) -> np.ndarray:
    fns = {"count": syn.count, "sum": syn.sum, "avg": syn.avg}
    return np.asarray([float(fns[q.op](q.a, q.b)) for q in queries])


def run() -> dict:
    from repro.core.aqp import run_legacy_queries

    out = {}
    for nq in Q_SIZES:
        syn, queries = _setup(nq)

        want = _loop_answers(syn, queries)
        got = run_legacy_queries(queries, syn)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

        t_loop = time_call(_loop_answers, syn, queries, repeats=3, warmup=1)
        t_batch = time_call(run_legacy_queries, queries, syn,
                            repeats=5, warmup=2)
        speedup = t_loop / t_batch
        emit(f"aqp_loop_q{nq}", t_loop, f"{nq / (t_loop * 1e-6):,.0f} q/s")
        emit(f"aqp_batch_q{nq}", t_batch,
             f"{nq / (t_batch * 1e-6):,.0f} q/s, {speedup:.1f}x over loop")
        out[f"speedup_q{nq}"] = speedup

        # Pallas tile kernel path: correctness always, timing as reported.
        # Wider tolerance than the jnp pass: per-tile fp32 accumulation noise
        # is amplified by the sample->relation scale (~1e2 here).
        got_pl = run_legacy_queries(queries, syn, backend="pallas")
        np.testing.assert_allclose(got_pl, want, rtol=5e-4, atol=1e-2)
        t_pl = time_call(lambda: run_legacy_queries(queries, syn,
                                                    backend="pallas"),
                         repeats=3, warmup=1)
        emit(f"aqp_pallas_q{nq}", t_pl, f"{nq / (t_pl * 1e-6):,.0f} q/s "
             "(interpret mode on CPU)")
    return out


if __name__ == "__main__":
    run()
