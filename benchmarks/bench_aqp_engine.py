"""Unified AQP engine (core/aqp_query.py): mixed-batch throughput and the
batched quasi-MC fallback.

Two comparisons:

  engine    — ONE QueryEngine.execute call over a heterogeneous batch
              (1-D ranges + eq. 11 boxes + categorical Eq terms)
  two-stack — the same workload split across the legacy CALL PATTERN: a
              store.query_batch call (ranges + Eq compiled to ranges) plus a
              store.query_box_batch call (boxes), then re-interleaved.  Both
              entry points now execute on the unified engine, so this leg
              measures the planning/dispatch overhead of splitting the batch
              into per-kind calls — not the pre-PR code, which is gone.

  qmc batch — full-H group answered by the shared-Halton-node batched pass
              (one KDE evaluation per group)
  qmc loop  — a faithful replica of the pre-batching fallback: one Halton
              node set + one KDE evaluation per query (`box_qmc_terms` loop)

The acceptance bar for this PR is the batched QMC fallback >= 5x over the
per-query loop on CPU (asserted outside quick mode); the engine-vs-two-stack
numbers document that the single mixed entry point costs no more than the
split dispatch it replaces.

Set REPRO_BENCH_QUICK=1 (or `python -m benchmarks.run --quick`) for the CI
smoke configuration.
"""
from __future__ import annotations

import warnings

import numpy as np

from .common import emit, time_call
from .common import quick as common_quick

N_MIXED = 768
N_QMC_QUERIES = 64
QMC_SAMPLE = 512


def _quick() -> bool:
    return common_quick()


def _setup_store(seed: int = 0):
    from repro.data import TelemetryStore

    rng = np.random.default_rng(seed)
    n = 100_000
    data = {
        "loss": rng.gamma(3.0, 0.7, n).astype(np.float32),
        "latency_ms": np.where(rng.random(n) < 0.8, rng.normal(40, 8, n),
                               rng.normal(160, 30, n)).astype(np.float32),
        "code": rng.integers(0, 4, n).astype(np.float32),
    }
    store = TelemetryStore(capacity=2048 if not _quick() else 512, seed=0)
    store.track_joint(("loss", "latency_ms"))
    store.add_batch(data)
    ranges = {c: (float(v.min()), float(v.max()))
              for c, v in data.items() if c != "code"}
    return store, ranges


def _legacy_split(specs):
    """Compile the mixed AqpQuery batch back onto the two legacy stacks:
    ranges and Eq terms become `Query` rows, boxes become `BoxQuery` rows."""
    from repro.core import BoxQuery, Query
    from repro.core.aqp_query import Box, Eq, Range

    range_qs, box_qs, order = [], [], []
    for q in specs:
        p = q.predicates[0]
        if isinstance(p, Box):
            order.append(("box", len(box_qs)))
            tgt = None if q.aggregate == "count" else q.target
            box_qs.append(BoxQuery(q.aggregate, p.lo, p.hi,
                                   columns=p.columns, target=tgt))
        elif isinstance(p, Eq):
            order.append(("range", len(range_qs)))
            range_qs.append(Query(q.aggregate, p.value - p.halfwidth,
                                  p.value + p.halfwidth, column=p.column))
        else:
            assert isinstance(p, Range)
            order.append(("range", len(range_qs)))
            range_qs.append(Query(q.aggregate, p.a, p.b, column=p.column))
    return range_qs, box_qs, order


def _two_stack_answers(store, range_qs, box_qs, order) -> np.ndarray:
    r = store.query_batch(range_qs)
    b = store.query_box_batch(box_qs) if box_qs else np.empty((0,))
    parts = {"range": r, "box": b}
    return np.asarray([parts[kind][i] for kind, i in order])


def _setup_qmc(n_queries: int, seed: int = 0):
    """A full-H joint synopsis (H from the sample covariance — no LSCV cost)
    plus a mixed box batch against it."""
    import jax.numpy as jnp

    from repro.core import BoxQuery, KDESynopsis

    rng = np.random.default_rng(seed)
    n = QMC_SAMPLE if not _quick() else 256
    latent = rng.normal(0, 1, n)
    x = np.stack([latent + rng.normal(0, 0.6, n),
                  latent + rng.normal(0, 0.8, n)], axis=1).astype(np.float32)
    H = (np.cov(x.T) * n ** (-1 / 3)).astype(np.float32)
    syn = KDESynopsis(x=jnp.asarray(x), H=jnp.asarray(H), n_source=250_000)
    ops = ["count", "sum", "avg"]
    queries = []
    for i in range(n_queries):
        lo = rng.uniform(-2.0, 0.0, 2)
        hi = lo + rng.uniform(1.0, 3.0, 2)
        queries.append(BoxQuery(ops[i % 3], tuple(lo), tuple(hi),
                                target=int(rng.integers(2))))
    return syn, queries


def _qmc_loop_answers(syn, queries) -> np.ndarray:
    """The pre-batching fallback: one Halton node set + one KDE evaluation
    per query (what `_qmc_box_answers` did before this PR)."""
    import jax.numpy as jnp

    from repro.core.aqp import box_qmc_terms
    from repro.core.aqp_multid import _avg_or_zero

    x = syn.x
    scale = syn.n_source / x.shape[0]
    out = np.empty((len(queries),), np.float64)
    for i, q in enumerate(queries):
        cnt, sm = box_qmc_terms(x, syn.H, jnp.asarray(q.lo, jnp.float32),
                                jnp.asarray(q.hi, jnp.float32),
                                target=q.target_index())
        cnt, sm = scale * cnt, scale * sm
        if q.op == "count":
            out[i] = float(cnt)
        else:
            out[i] = float(sm if q.op == "sum" else _avg_or_zero(cnt, sm))
    return out


def run() -> dict:
    from repro.core.aqp_multid import _qmc_box_answers
    from repro.launch.serve import make_mixed_aqp_queries

    out = {}

    # --- mixed batch: one engine call vs the old two-stack dispatch --------
    n_mixed = N_MIXED if not _quick() else 96
    store, ranges = _setup_store()
    specs = make_mixed_aqp_queries(n_mixed, ranges, ("loss", "latency_ms"),
                                   "code", (0.0, 1.0, 2.0, 3.0), seed=1)
    engine = store.engine()
    range_qs, box_qs, order = _legacy_split(specs)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        want = _two_stack_answers(store, range_qs, box_qs, order)
        got = engine.answers(specs)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

        t_engine = time_call(engine.answers, specs, repeats=5, warmup=2)
        t_two = time_call(_two_stack_answers, store, range_qs, box_qs, order,
                          repeats=5, warmup=2)
    emit(f"aqp_engine_mixed_q{n_mixed}", t_engine,
         f"{n_mixed / (t_engine * 1e-6):,.0f} q/s, one execute() call")
    emit(f"aqp_engine_twostack_q{n_mixed}", t_two,
         f"{n_mixed / (t_two * 1e-6):,.0f} q/s split into per-kind calls, "
         f"{t_two / t_engine:.2f}x the unified call")
    out["mixed_vs_twostack"] = t_two / t_engine

    # --- batched QMC fallback vs the per-query loop ------------------------
    n_q = N_QMC_QUERIES if not _quick() else 24
    syn, queries = _setup_qmc(n_q)
    want = _qmc_loop_answers(syn, queries)
    got = _qmc_box_answers(syn, queries)
    # both are ~1e-2-accurate QMC integrators on different node sets
    np.testing.assert_allclose(got, want, rtol=0.1,
                               atol=0.02 * np.abs(want).max())

    t_loop = time_call(_qmc_loop_answers, syn, queries, repeats=3, warmup=1)
    t_batch = time_call(_qmc_box_answers, syn, queries, repeats=3, warmup=1)
    speedup = t_loop / t_batch
    emit(f"aqp_qmc_loop_q{n_q}", t_loop, f"{n_q / (t_loop * 1e-6):,.0f} q/s")
    emit(f"aqp_qmc_batch_q{n_q}", t_batch,
         f"{n_q / (t_batch * 1e-6):,.0f} q/s, {speedup:.1f}x over loop "
         "(shared Halton nodes, one KDE pass)")
    out["qmc_speedup"] = speedup
    if not _quick():
        assert speedup >= 5.0, (
            f"batched QMC fallback must be >= 5x over the per-query loop, "
            f"got {speedup:.1f}x")
    return out


if __name__ == "__main__":
    run()
