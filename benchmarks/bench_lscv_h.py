"""Paper Fig. 9: LSCV_h — and the §4.5 reformulation ablation.

unmodified  = recompute the quadratic form for every h on the grid
              (O(n_h n^2 d^2), eq. 24 as written)
modified    = paper's §4.5: S(v) precomputed once, reused for all n_h
              (O(n^2 (d^2 + n_h)))  [store_s=True]
fused       = beyond-paper streaming variant (same FLOPs, O(chunk*n) memory)

The paper's central algorithmic claim is the modified/unmodified ratio; with
n_h=150 and small d the predicted win is ~ n_h d^2/(d^2+n_h).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lscv import N_H_DEFAULT, h_grid_for, lscv_h
from repro.core import gaussian as G
from repro.core.reductions import pairwise_quadform_reduce
from .common import emit, time_call


def lscv_h_unmodified(x, n_h=N_H_DEFAULT, chunk=128):
    """eq. (24) evaluated naively: the exponent S(v)/h^2 is recomputed inside
    the pairwise pass for EVERY h (no precompute) — the paper's baseline."""
    from repro.core.lscv import covariance
    n, d = x.shape
    sigma = covariance(x)
    det = jnp.linalg.det(sigma)
    inv = jnp.linalg.inv(sigma)
    c_k, c_kk, r_k = G.lscv_h_consts(d, det)
    h_grid = h_grid_for(n, d, n_h).astype(x.dtype)

    def g_of_h(h):
        fun1 = lambda s: c_kk * jnp.exp(-0.25 * s / (h * h)) - 2.0 * c_k * jnp.exp(-0.5 * s / (h * h))
        t = pairwise_quadform_reduce(fun1, x, inv, chunk)   # full O(n^2 d^2) pass
        return h ** (-d) * (2.0 / (n * n) * t + r_k / n)

    g = jax.lax.map(g_of_h, h_grid)
    return h_grid[jnp.argmin(g)]


_unmod_jit = jax.jit(lscv_h_unmodified, static_argnames=("n_h", "chunk"))


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}
    for d in [1, 2, 4, 8, 16]:
        n = 512
        x = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
        t_unmod = time_call(lambda x=x: _unmod_jit(x), repeats=2)
        t_mod = time_call(lambda x=x: lscv_h(x, store_s=True).h, repeats=2)
        t_fused = time_call(lambda x=x: lscv_h(x).h, repeats=2)
        emit(f"lscv_h_unmodified_n{n}_d{d}", t_unmod)
        emit(f"lscv_h_modified_n{n}_d{d}", t_mod, f"{t_unmod / t_mod:.1f}x vs unmodified")
        emit(f"lscv_h_fused_n{n}_d{d}", t_fused, f"{t_unmod / t_fused:.1f}x vs unmodified")
        out[d] = {"unmod": t_unmod, "mod": t_mod, "fused": t_fused}

    for n in [64, 128, 256, 512, 1024]:
        x = jnp.asarray(rng.normal(0, 1, (n, 2)).astype(np.float32))
        us = time_call(lambda x=x: lscv_h(x).h, repeats=2)
        emit(f"lscv_h_fused_n{n}_d2", us)
    return out


if __name__ == "__main__":
    run()
