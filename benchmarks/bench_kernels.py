"""Kernel-level ablation: the paper's eq. (60) loop algorithm vs the
TPU-native MXU quadratic-form expansion (DESIGN.md §2, sv_precompute).

Interpret-mode timings measure *algorithm* cost on CPU, not TPU performance;
the structural win (d^2 VPU passes -> 2 small matmuls) is what §Perf records.
Also times the jnp reference paths at matched sizes for a like-for-like view.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from .common import emit, time_call


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}
    n, d = 1024, 8
    x = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
    m0 = rng.normal(0, 1, (d, d)).astype(np.float32)
    m = jnp.asarray(0.2 * m0 @ m0.T + np.eye(d, dtype=np.float32))

    t_paper = time_call(lambda: ops.sv_matrix(x, m, algorithm="paper"), repeats=2)
    t_mxu = time_call(lambda: ops.sv_matrix(x, m, algorithm="mxu"), repeats=2)
    t_ref = time_call(lambda: ref.sv_matrix(x, m), repeats=2)
    emit(f"sv_tile_paper_alg_n{n}_d{d}", t_paper)
    emit(f"sv_tile_mxu_alg_n{n}_d{d}", t_mxu, f"{t_paper / t_mxu:.2f}x vs paper alg")
    emit(f"sv_jnp_ref_n{n}_d{d}", t_ref)
    out["paper_over_mxu"] = t_paper / t_mxu

    xg = jnp.asarray(rng.normal(0, 1, 4096).astype(np.float32))
    t_k = time_call(lambda: ops.pairwise_scaled_ksum(xg, jnp.float32(0.3), kind="k6"),
                    repeats=2)
    t_kr = time_call(lambda: ref.pairwise_scaled_ksum(xg, jnp.float32(0.3), "k6"),
                     repeats=2)
    emit("pairwise_k6_tile_n4096", t_k)
    emit("pairwise_k6_ref_n4096", t_kr)
    return out


if __name__ == "__main__":
    run()
