"""Durable-store warm start (data/aqp_store.py to_state/save/load): restart
wall time from a checkpoint vs a cold refit.

The paper's economics make bandwidth fitting the expensive step; a restart
that re-ingests the stream and refits every synopsis repeats exactly that
work.  Two legs, answering the same mixed query batch after a simulated
process restart:

  cold  — rebuild a TelemetryStore from the raw stream: add_batch the full
          history (O(history)), then the first query batch refits every
          synopsis (O(sample^2) for LSCV selectors)
  warm  — TelemetryStore.load(snapshot): reservoirs, sketches, AND the
          fitted synopses come back from the atomic keep-k checkpoint; the
          first query batch runs entirely on cache hits

Answers must be bit-identical across the original, cold, and warm stores
(same capacity/seed/stream -> same reservoirs -> same synopses), with the
exact categorical path still active after restore — both asserted always.
Outside quick mode the warm leg must also beat the cold leg >= 1.5x and
serve the batch with zero synopsis-cache misses and zero plan-cache misses
(the snapshot persists the PlanCache keys, so a restored engine replans
nothing it had already planned).

Set REPRO_BENCH_QUICK=1 (or `python -m benchmarks.run --quick`) for the CI
smoke configuration.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from .common import emit
from .common import quick as common_quick

ROWS = 200_000
CAPACITY = 2048
N_QUERIES = 64


def _quick() -> bool:
    return common_quick()


def _telemetry(n: int):
    rng = np.random.default_rng(0)
    return {
        "loss": rng.gamma(3.0, 0.7, n).astype(np.float32),
        "latency_ms": np.where(rng.random(n) < 0.8, rng.normal(40, 8, n),
                               rng.normal(160, 30, n)).astype(np.float32),
        "model_id": rng.integers(0, 4, n).astype(np.float32),
    }


def _build(data, capacity: int):
    from repro.data import TelemetryStore

    store = TelemetryStore(capacity=capacity, seed=0)
    store.track_joint(("loss", "latency_ms"))
    store.track_categorical("model_id")
    store.add_batch(data)
    return store


def _specs(n_queries: int):
    from repro.core import AqpQuery, Box, Eq, Range

    rng = np.random.default_rng(7)
    ops = ["count", "sum", "avg"]
    specs = []
    for i in range(n_queries):
        op = ops[int(rng.integers(3))]
        if i % 4 == 1:
            lo = [float(rng.uniform(0, 4)), float(rng.uniform(20, 60))]
            hi = [lo[0] + 2.0, lo[1] + 60.0]
            specs.append(AqpQuery(
                op, (Box(("loss", "latency_ms"), tuple(lo), tuple(hi)),),
                target=None if op == "count" else "latency_ms"))
        elif i % 8 == 3:
            specs.append(AqpQuery("count", (Eq("model_id",
                                               float(rng.integers(4))),)))
        else:
            a = float(rng.uniform(0, 5))
            specs.append(AqpQuery(op, (Range("loss", a, a + 2.0),),
                                  target=None if op == "count" else "loss"))
    return specs


def run() -> dict:
    quick = _quick()
    n = ROWS if not quick else 30_000
    capacity = CAPACITY if not quick else 512
    data = _telemetry(n)
    specs = _specs(N_QUERIES if not quick else 24)

    # the running process: fits + caches synopses, then checkpoints.  Its
    # execute also compiles every batched pass the timed legs hit, so the
    # cold/warm comparison measures ingest+fit vs load, not jit compiles.
    original = _build(data, capacity)
    want = original.query(specs)
    snap_dir = tempfile.mkdtemp(prefix="bench_aqp_restore_")
    try:
        t0 = time.perf_counter()
        step = original.save(snap_dir)
        t_save = time.perf_counter() - t0

        from repro.data import TelemetryStore

        # --- cold restart: re-ingest the stream, refit on first query ------
        t0 = time.perf_counter()
        cold = _build(data, capacity)
        cold_rows = cold.query(specs)
        t_cold = time.perf_counter() - t0

        # --- warm restart: load the snapshot, first query is all cache hits
        t0 = time.perf_counter()
        warm = TelemetryStore.load(snap_dir)
        warm_rows = warm.query(specs)
        t_warm = time.perf_counter() - t0
        warm_misses = warm.cache.stats()["misses"]
        warm_plan_misses = warm.shared_engine().plans.stats()["misses"]
    finally:
        shutil.rmtree(snap_dir, ignore_errors=True)

    for rows, label in ((cold_rows, "cold"), (warm_rows, "warm")):
        for r, w in zip(rows, want):
            assert r.estimate == w.estimate and r.path == w.path, \
                (label, r, w)
    assert any(r.path == "exact" for r in warm_rows), \
        "exact categorical coverage must survive the restore"

    speedup = t_cold / t_warm
    emit(f"aqp_restore_save_n{n}", t_save * 1e6,
         f"atomic keep-k snapshot (step {step})")
    emit(f"aqp_restore_cold_n{n}", t_cold * 1e6,
         f"re-ingest {n:,} rows + refit {len(specs)} queries")
    emit(f"aqp_restore_warm_n{n}", t_warm * 1e6,
         f"load + query, {speedup:.1f}x over cold refit, "
         f"{warm_misses} cache misses, {warm_plan_misses} plan misses")

    if not quick:
        assert warm_misses == 0, \
            f"warm start must not refit, got {warm_misses} cache misses"
        assert warm_plan_misses == 0, \
            "warm start must not replan: the checkpoint carries the " \
            f"PlanCache keys, got {warm_plan_misses} plan misses"
        assert speedup >= 1.5, \
            f"warm start should beat cold refit >= 1.5x, got {speedup:.2f}x"
    return {"speedup": speedup, "t_save_us": t_save * 1e6}


if __name__ == "__main__":
    run()
