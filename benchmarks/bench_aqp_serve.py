"""Admission micro-batching (core/aqp_admission.py): throughput and latency
versus per-call `QueryEngine.execute` under concurrent clients.

Two legs over the same workload, the same store, and the same N closed-loop
clients (one outstanding query each):

  per-call   — every client answers each query with its own
               `engine.execute([q])` call: per-query planning + dispatch,
               nothing shared across callers (the pre-admission pattern in
               `serve --mode aqp`)
  admission  — every client submits to one shared `AqpSession`
               (watermark = client count): pending specs coalesce across
               clients into micro-batches keyed by (column tuple, selector,
               synopsis version) and flush through one batched pass

The acceptance bar for this PR: admission >= 3x per-call throughput at batch
depth >= 16 (asserted outside quick mode), with *bit-identical* answers to
the synchronous path (asserted always — same specs, same synopses, same
compiled execution core).

Set REPRO_BENCH_QUICK=1 (or `python -m benchmarks.run --quick`) for the CI
smoke configuration.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from .common import emit
from .common import quick as common_quick

N_CLIENTS = 16
PER_CLIENT = 48
ROWS = 100_000


def _quick() -> bool:
    return common_quick()


def _setup(seed: int = 0):
    from repro.data import TelemetryStore

    rng = np.random.default_rng(seed)
    n = ROWS if not _quick() else 20_000
    data = {
        "loss": rng.gamma(3.0, 0.7, n).astype(np.float32),
        "latency_ms": np.where(rng.random(n) < 0.8, rng.normal(40, 8, n),
                               rng.normal(160, 30, n)).astype(np.float32),
    }
    store = TelemetryStore(capacity=2048 if not _quick() else 512, seed=0)
    store.add_batch(data)
    ranges = {c: (float(v.min()), float(v.max())) for c, v in data.items()}
    return store, ranges


def _client_specs(n_clients: int, per_client: int, ranges):
    """Mixed COUNT/SUM/AVG ranges over ONE column: a single (column,
    selector) bucket, so the micro-batch depth equals the number of
    concurrent clients (the acceptance bar is pinned at depth >= 16).
    Heterogeneous multi-bucket traffic is covered by `serve --mode aqp`
    and the admission tests."""
    from repro.core import AqpQuery, Range

    ops = ["count", "sum", "avg"]
    col = sorted(ranges)[0]
    lo, hi = ranges[col]
    per = []
    for ci in range(n_clients):
        rng = np.random.default_rng(1000 + ci)
        specs = []
        for _ in range(per_client):
            a = float(rng.uniform(lo, hi))
            op = ops[int(rng.integers(3))]
            specs.append(AqpQuery(op, (Range(col, a, float(rng.uniform(a, hi)))),
                                  target=None if op == "count" else col))
        per.append(specs)
    return per


def _run_clients(n_clients, work):
    """Run one callable per client on its own thread; wall time in seconds."""
    threads = [threading.Thread(target=work, args=(ci,))
               for ci in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0


def run() -> dict:
    quick = _quick()
    n_clients = N_CLIENTS if not quick else 8
    per_client = PER_CLIENT if not quick else 12
    n_total = n_clients * per_client

    store, ranges = _setup()
    engine = store.engine()
    per = _client_specs(n_clients, per_client, ranges)
    flat = [q for specs in per for q in specs]

    # ground truth + warm-up: fits synopses and compiles every shape the
    # timed legs hit — single-query per-call batches pad to 8, admission
    # flushes pad near the watermark, and the parity pass pads to the full
    # batch — so neither leg pays a jit compile inside its timed region
    sync_rows = engine.execute(flat)
    want = {}
    k = 0
    for ci, specs in enumerate(per):
        for qi in range(len(specs)):
            want[(ci, qi)] = sync_rows[k]
            k += 1
    engine.execute(flat[: n_clients])
    engine.execute([flat[0]])

    # --- leg 1: per-call execute(), one call per query per client ----------
    def percall_worker(ci):
        for q in per[ci]:
            engine.execute([q])
    t_percall = _run_clients(n_clients, percall_worker)

    # --- leg 2: shared admission session ------------------------------------
    session = engine.session(watermark=n_clients, max_delay=0.002)
    got = {}
    got_lock = threading.Lock()
    latencies = []

    def admission_worker(ci):
        mine = []
        lats = []
        for qi, q in enumerate(per[ci]):
            t0 = time.perf_counter()
            r = session.submit(q).result()
            lats.append(time.perf_counter() - t0)
            mine.append((qi, r))
        with got_lock:
            got.update({(ci, qi): r for qi, r in mine})
            latencies.extend(lats)
    t_admission = _run_clients(n_clients, admission_worker)
    st = session.stats()
    session.close()

    # bit-identical to the synchronous path: same estimate, path, version
    assert len(got) == n_total
    for key, r in got.items():
        w = want[key]
        assert r.estimate == w.estimate and r.path == w.path, (key, r, w)

    # --- leg 3: admission again, obs-enabled --------------------------------
    # Same workload with span tracing, fenced per-path latency histograms,
    # and kernel profiling live.  The instrumentation contract is <= 5%
    # throughput overhead and bit-identical answers; wall-clock noise on a
    # shared box can exceed the margin, so take the best of three attempts
    # before asserting.
    from repro import obs

    was_enabled = obs.enabled()
    best_overhead = float("inf")
    t_inst = None
    for _attempt in range(3):
        session2 = engine.session(watermark=n_clients, max_delay=0.002)
        got2 = {}

        def instrumented_worker(ci):
            mine = [(qi, session2.submit(q).result())
                    for qi, q in enumerate(per[ci])]
            with got_lock:
                got2.update({(ci, qi): r for qi, r in mine})

        obs.enable()
        try:
            t_attempt = _run_clients(n_clients, instrumented_worker)
        finally:
            if not was_enabled:
                obs.disable()
        session2.close()
        assert len(got2) == n_total
        for key, r in got2.items():
            w = want[key]
            assert r.estimate == w.estimate and r.path == w.path, (key, r, w)
        overhead = t_attempt / t_admission - 1.0
        if overhead < best_overhead:
            best_overhead, t_inst = overhead, t_attempt
        if overhead <= 0.05:
            break

    qps_percall = n_total / t_percall
    qps_admission = n_total / t_admission
    speedup = t_percall / t_admission
    lat = np.sort(np.asarray(latencies))
    p50 = lat[len(lat) // 2] * 1e3
    p95 = lat[int(len(lat) * 0.95)] * 1e3

    emit(f"aqp_serve_percall_c{n_clients}_q{n_total}",
         t_percall * 1e6 / n_total,
         f"{qps_percall:,.0f} q/s, one execute() per query",
         qps=qps_percall)
    emit(f"aqp_serve_admission_c{n_clients}_q{n_total}",
         t_admission * 1e6 / n_total,
         f"{qps_admission:,.0f} q/s, {speedup:.1f}x over per-call; "
         f"mean batch {st['mean_batch']:.1f}, {st['flushes']} flushes, "
         f"p50 {p50:.2f} ms, p95 {p95:.2f} ms",
         samples=[v * 1e6 for v in latencies],
         qps=qps_admission, speedup=speedup, mean_batch=st["mean_batch"])
    emit(f"aqp_serve_instrumented_c{n_clients}_q{n_total}",
         t_inst * 1e6 / n_total,
         f"{n_total / t_inst:,.0f} q/s with obs enabled, "
         f"{best_overhead:+.1%} vs uninstrumented admission",
         overhead=best_overhead)

    out = {"speedup": speedup, "mean_batch": st["mean_batch"],
           "obs_overhead": best_overhead}
    if not quick:
        assert st["mean_batch"] >= 8.0, (
            f"admission should coalesce across clients, mean batch "
            f"{st['mean_batch']:.1f}")
        assert speedup >= 3.0, (
            f"micro-batched admission must be >= 3x per-call execute at "
            f"batch depth >= 16, got {speedup:.1f}x")
        assert best_overhead <= 0.05, (
            f"obs-enabled admission must stay within 5% of uninstrumented "
            f"throughput, got {best_overhead:+.1%}")
    return out


if __name__ == "__main__":
    run()
