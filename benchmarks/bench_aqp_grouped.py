"""Factored GROUP BY kernel vs per-category fan-out (kernels/aqp_grouped.py).

A GROUP BY family — one shared box crossed with G per-category windows on
the group axis — can be answered two ways on the Pallas path:

  fanout  — expand to G full boxes and run kernels/aqp_boxes.py
            (O(n d) work per category: the shared d-1 axes recompute G times)
  grouped — the factored kernels/aqp_grouped.py pass (shared box terms once,
            then an O(n G) per-category sweep)

Reports categories/s for both and the grouped-over-fanout speedup; outside
--quick the harness asserts >= 3x at G >= 32 (the paper-scale regime where
the redundant d-1 axis work dominates).  A second leg checks the fused QMC
indicator kernel (kernels/qmc_reduce.py) against the jnp shared-node path:
estimates must agree to rtol 1e-5, timings reported for both.

Set REPRO_BENCH_QUICK=1 (or `python -m benchmarks.run --quick`) for the CI
smoke configuration: one small shape, no speedup floor.
"""
from __future__ import annotations


import numpy as np

from .common import emit, time_call
from .common import quick as common_quick

N_ROWS = 16_384
DIMS = 6
GROUPS = (8, 32, 64)
MIN_SPEEDUP = 3.0


def _quick() -> bool:
    return common_quick()


def _setup(n: int, d: int, g: int, seed: int = 0):
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1.5, (n, d)).astype(np.float32))
    h = jnp.asarray(rng.uniform(0.2, 0.6, d).astype(np.float32))
    lo = jnp.asarray(rng.uniform(-3, -1, d).astype(np.float32))
    hi = lo + 4.0
    glo = jnp.asarray((np.arange(g) - g / 2).astype(np.float32))
    ghi = glo + 1.0
    # the expanded per-category boxes the fan-out path answers
    lo_g = jnp.tile(lo[None, :], (g, 1)).at[:, 0].set(glo)
    hi_g = jnp.tile(hi[None, :], (g, 1)).at[:, 0].set(ghi)
    tgt_g = jnp.full((g,), min(1, d - 1), jnp.int32)
    return x, h, lo, hi, glo, ghi, lo_g, hi_g, tgt_g


def _grouped_leg(out: dict) -> None:
    from repro.kernels import autotune, ops as kops

    n = N_ROWS if not _quick() else 2048
    d = DIMS if not _quick() else 3
    groups = GROUPS if not _quick() else (32,)
    tgt = min(1, d - 1)
    for g in groups:
        if not _quick():
            # measurement-driven tiles for BOTH sides: the sweep winners land
            # in the in-process cache, and the ops.py wrappers resolve them
            # automatically on every timed call below (the serving path)
            for kern in ("aqp_grouped_sums", "aqp_box_sums"):
                e = autotune.sweep(kern, {"n": n, "d": d, "G": g},
                                   repeats=2, quick=True, persist=False)
                emit(f"autotune_{kern}_g{g}", e["us"],
                     f"tiles {e['tiles']} ({e['default_us'] / e['us']:.1f}x "
                     f"over default {e['default_tiles']})")
        x, h, lo, hi, glo, ghi, lo_g, hi_g, tgt_g = _setup(n, d, g)

        cnt_f, sum_f = kops.aqp_box_sums(x, h, lo_g, hi_g, tgt_g)
        cnt_g, sum_g = kops.aqp_grouped_sums(x, h, lo, hi, glo, ghi,
                                             g_axis=0, tgt=tgt)
        np.testing.assert_allclose(np.asarray(cnt_g), np.asarray(cnt_f),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(sum_g), np.asarray(sum_f),
                                   rtol=1e-4, atol=1e-3)

        t_fan = time_call(
            lambda: kops.aqp_box_sums(x, h, lo_g, hi_g, tgt_g),
            repeats=5, warmup=2)
        t_grp = time_call(
            lambda: kops.aqp_grouped_sums(x, h, lo, hi, glo, ghi,
                                          g_axis=0, tgt=tgt),
            repeats=5, warmup=2)
        speedup = t_fan / t_grp
        emit(f"aqp_grouped_fanout_d{d}_g{g}", t_fan,
             f"{g / (t_fan * 1e-6):,.0f} cat/s")
        emit(f"aqp_grouped_factored_d{d}_g{g}", t_grp,
             f"{g / (t_grp * 1e-6):,.0f} cat/s, {speedup:.1f}x over fanout")
        out[f"speedup_g{g}"] = speedup
        if not _quick() and g >= 32:
            assert speedup >= MIN_SPEEDUP, (
                f"factored grouped kernel only {speedup:.2f}x over "
                f"per-category fan-out at G={g} (floor {MIN_SPEEDUP}x)")


def _qmc_leg(out: dict) -> None:
    from repro.core.aqp_multid import batch_query_qmc

    n = 4096 if not _quick() else 512
    n_qmc = 1024 if not _quick() else 256
    d, q = 2, 16
    rng = np.random.default_rng(1)
    import jax.numpy as jnp
    x = jnp.asarray(rng.normal(0, 1.0, (n, d)).astype(np.float32))
    H = jnp.asarray(np.diag([0.3, 0.5]).astype(np.float32)
                    + np.float32(0.05))
    lo = rng.uniform(-2, 0, (q, d))
    hi = lo + rng.uniform(0.5, 2, (q, d))
    tgt = rng.integers(0, d, q)
    ops_np = rng.integers(0, 3, q)

    want = np.asarray(batch_query_qmc(x, H, lo, hi, tgt, ops_np,
                                      scale=100.0, n_qmc=n_qmc))
    got = np.asarray(batch_query_qmc(x, H, lo, hi, tgt, ops_np, scale=100.0,
                                     n_qmc=n_qmc, backend="pallas"))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    t_jnp = time_call(lambda: batch_query_qmc(x, H, lo, hi, tgt, ops_np,
                                              scale=100.0, n_qmc=n_qmc),
                      repeats=3, warmup=1)
    t_pal = time_call(lambda: batch_query_qmc(x, H, lo, hi, tgt, ops_np,
                                              scale=100.0, n_qmc=n_qmc,
                                              backend="pallas"),
                      repeats=3, warmup=1)
    emit(f"aqp_qmc_jnp_q{q}", t_jnp, f"{q / (t_jnp * 1e-6):,.0f} q/s")
    emit(f"aqp_qmc_pallas_q{q}", t_pal,
         f"{q / (t_pal * 1e-6):,.0f} q/s (fused indicator, rtol 1e-5 vs jnp)")
    out["qmc_pallas_over_jnp"] = t_jnp / t_pal


def run() -> dict:
    out: dict = {}
    _grouped_leg(out)
    _qmc_leg(out)
    return out


if __name__ == "__main__":
    run()
