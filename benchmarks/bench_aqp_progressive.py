"""Progressive tiered execution (data/aqp_store.py TieredReservoir +
core/aqp_query.py progressive mode): latency of a coarse first answer vs the
full-accuracy pass, and the CI-width convergence it buys.

A Verdict-style tier ladder keeps geometric sub-samples of the reservoir
(tier 0 is 1/2^(n_tiers-1) of the full sample), each an independent uniform
sample of the whole stream.  Progressive mode answers every query on tier 0
first — same estimator, same confidence machinery, just less data — then
re-answers on each larger tier until the top tier reproduces the plain
batch answer bit-for-bit.  The trade this benchmark quantifies:

  tier0 — run_compiled(compiled, tier=0): O(tier0_size) kernel passes
  full  — run_compiled(compiled):         O(capacity) kernel passes

Always asserted: the final progressive round is bit-identical to plain
execute (estimates AND confidence intervals), and the median CI width never
widens from one round to the next.  Outside quick mode the tier-0 pass must
be >= 5x faster (p50) than the full pass at 200k rows.

Set REPRO_BENCH_QUICK=1 (or `python -m benchmarks.run --quick`) for the CI
smoke configuration.
"""
from __future__ import annotations

import time

import numpy as np

from .common import emit
from .common import quick as common_quick

ROWS = 200_000
CAPACITY = 16_384
N_TIERS = 6          # tier 0 holds CAPACITY >> 5 = 512 rows
N_QUERIES = 256
REPS = 7


def _quick() -> bool:
    return common_quick()


def _build(n: int, capacity: int, n_tiers: int):
    from repro.data import TelemetryStore

    rng = np.random.default_rng(0)
    store = TelemetryStore(capacity=capacity, seed=0)
    store.track_tiered("loss", n_tiers=n_tiers)
    store.track_tiered(("loss", "latency_ms"), n_tiers=n_tiers)
    store.add_batch({
        "loss": rng.gamma(3.0, 0.7, n).astype(np.float32),
        "latency_ms": np.where(rng.random(n) < 0.8, rng.normal(40, 8, n),
                               rng.normal(160, 30, n)).astype(np.float32),
    })
    return store


def _specs(n_queries: int):
    from repro.core import AqpQuery, Box, Range

    rng = np.random.default_rng(7)
    ops = ["count", "sum", "avg"]
    specs = []
    for i in range(n_queries):
        op = ops[int(rng.integers(3))]
        if i % 4 == 1:
            lo = [float(rng.uniform(0, 4)), float(rng.uniform(20, 60))]
            hi = [lo[0] + 2.0, lo[1] + 60.0]
            specs.append(AqpQuery(
                op, (Box(("loss", "latency_ms"), tuple(lo), tuple(hi)),),
                target=None if op == "count" else "latency_ms"))
        else:
            a = float(rng.uniform(0, 5))
            specs.append(AqpQuery(op, (Range("loss", a, a + 2.0),),
                                  target=None if op == "count" else "loss"))
    return specs


def run() -> dict:
    quick = _quick()
    n = ROWS if not quick else 30_000
    capacity = CAPACITY if not quick else 2_048
    n_tiers = N_TIERS if not quick else 4
    specs = _specs(N_QUERIES if not quick else 64)

    store = _build(n, capacity, n_tiers)
    engine = store.shared_engine()

    # --- convergence: one progressive sweep, median CI width per round ------
    rounds = list(engine.execute(specs, mode="progressive"))
    assert len(rounds) == n_tiers
    med_widths = []
    for _, rows in rounds:
        widths = [r.ci_width for r in rows if np.isfinite(r.ci_width)]
        assert widths, "progressive rounds must report finite CIs"
        med_widths.append(float(np.median(widths)))
    for a, b in zip(med_widths, med_widths[1:]):
        assert a >= b, f"median CI width widened across rounds: {med_widths}"

    want = engine.execute(specs)
    for r, w in zip(rounds[-1][1], want):
        assert r.estimate == w.estimate and r.path == w.path, (r, w)
        assert (r.ci_lo, r.ci_hi) == (w.ci_lo, w.ci_hi), (r, w)

    # --- latency: coarse tier-0 pass vs the full pass (both pre-compiled
    # and pre-fitted by the sweep above, so this times kernels, not jit) ----
    compiled = engine.compile(specs)
    t0_times, full_times = [], []
    for _ in range(REPS):
        t0 = time.perf_counter()
        engine.run_compiled(compiled, tier=0)
        t0_times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        engine.run_compiled(compiled)
        full_times.append(time.perf_counter() - t0)
    tier0_p50 = float(np.median(t0_times))
    full_p50 = float(np.median(full_times))
    ratio = full_p50 / tier0_p50

    tier0_rows = capacity >> (n_tiers - 1)
    emit(f"aqp_progressive_tier0_n{n}", tier0_p50 * 1e6,
         f"{len(specs)} queries on {tier0_rows}-row tier, "
         f"median CI width {med_widths[0]:.1f}")
    emit(f"aqp_progressive_full_n{n}", full_p50 * 1e6,
         f"{len(specs)} queries on {capacity}-row sample, "
         f"{ratio:.1f}x tier-0 latency, median CI width {med_widths[-1]:.1f}")

    if not quick:
        assert ratio >= 5.0, \
            f"tier-0 pass should be >= 5x faster than full, got {ratio:.2f}x"
    return {"ratio": ratio, "med_widths": med_widths}


if __name__ == "__main__":
    run()
