"""Paper Fig. 8: PLUGIN speedups over the Sequential implementation.

Sequential = the paper's pure-scalar implementation (plugin_bandwidth_sequential),
timed at small n and extrapolated with the paper's own quadratic-fit method
(eqs. 61-63).  Vectorised = chunked jnp (the XLA/VPU analogue of the paper's
SSE code).  Tiled kernel = the Pallas triangular-tile kernel in interpret
mode (its *algorithm* is the GPU contribution; interpret timing is NOT TPU
performance — roofline projections live in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import plugin_bandwidth, plugin_bandwidth_sequential
from .common import emit, quad_fit, speedup_limit, time_call

SEQ_NS = [256, 512, 1024, 2048]          # python-loop scale
VEC_NS = [1024, 2048, 4096, 8192, 16384, 32768]


def run() -> dict:
    rng = np.random.default_rng(0)
    seq_times = []
    for n in SEQ_NS:
        x = rng.normal(0, 1, n).astype(np.float32)
        import time
        t0 = time.perf_counter()
        plugin_bandwidth_sequential(x)
        seq_times.append((time.perf_counter() - t0) * 1e6)
        emit(f"plugin_sequential_n{n}", seq_times[-1])

    vec_times = []
    for n in VEC_NS:
        x = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
        us = time_call(lambda x=x: plugin_bandwidth(x).h)
        vec_times.append(us)
        emit(f"plugin_vectorised_n{n}", us)

    # paper's asymptotic-speedup estimate (eqs. 61-63)
    limit = speedup_limit(SEQ_NS, seq_times, VEC_NS, vec_times)
    emit("plugin_speedup_limit_vec_over_seq", 0.0, f"{limit:.0f}x")

    # measured speedup at the overlap point n=2048 (seq measured directly)
    x = jnp.asarray(rng.normal(0, 1, 2048).astype(np.float32))
    us_vec = time_call(lambda: plugin_bandwidth(x).h)
    sp2048 = seq_times[SEQ_NS.index(2048)] / us_vec
    emit("plugin_speedup_at_n2048", us_vec, f"{sp2048:.0f}x")
    return {"speedup_limit": limit, "speedup_n2048": sp2048,
            "seq_times": seq_times, "vec_times": vec_times}


if __name__ == "__main__":
    run()
