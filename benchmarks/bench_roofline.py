"""Roofline table emitter: reads the dry-run JSONL artifacts (written by
`python -m repro.launch.dryrun --all --out artifacts/dryrun_*.jsonl`) and
prints the §Roofline table rows.  Falls back to a note when artifacts are
absent (the full 512-device sweep is run once, not per bench invocation)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def load_records():
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, "dryrun_*.jsonl"))):
        with open(path) as f:
            for line in f:
                recs.append(json.loads(line))
    return recs


def run() -> dict:
    recs = [r for r in load_records() if r.get("ok")]
    if not recs:
        emit("roofline_no_artifacts", 0.0,
             "run: python -m repro.launch.dryrun --all --out artifacts/dryrun_1pod.jsonl")
        return {}
    for r in recs:
        t = r["roofline"]
        mesh = "x".join(str(v) for v in r["mesh"].values())
        name = f"roofline_{r['arch']}_{r['shape']}_{mesh}"
        derived = (f"compute={t['compute_s']:.2e}s memory={t['memory_s']:.2e}s "
                   f"collective={t['collective_s']:.2e}s dom={t['dominant']} "
                   f"useful_ratio={r['useful_flops_ratio']:.2f}")
        emit(name, r["compile_s"] * 1e6, derived)
    return {"cells": len(recs)}


if __name__ == "__main__":
    run()
