"""Sublinear RFF synopsis backend (repro.synopses) vs the exact full-H path.

One joint full-H group at relation scale (n = 200k fitted rows), answered
twice through the SAME engine/planning core:

  exact — `kde_backend="exact"`: the reference quasi-MC pass, one chunked
          O(n_qmc * n) KDE evaluation over the shared Halton nodes
  rff   — `kde_backend="rff"`: the fitted Random-Fourier synopsis, one
          O(n_qmc * D) feature pass (D = 2048 by default) plus the
          feature-block CI — cost independent of n

The acceptance bar is rff >= 5x over exact at n >= 200k (asserted outside
quick mode), with the RFF answers inside the engine's accuracy envelope:
the one-shot probe gate must pass (no degraded fallback — every answer
reports path "qmc:rff"), and each estimate must sit within a few reported
CI half-widths of the exact answer (the feature-block batch-means CI is
calibrated the same way test_aqp_ci.py calibrates the exact path's).

The synopsis is hand-built from the sample covariance (selector "lscv_H"
by label only) — an actual LSCV_H fit is O(n^2) and would dominate the
benchmark without exercising anything this PR changed.

Set REPRO_BENCH_QUICK=1 (or `python -m benchmarks.run --quick`) for the CI
smoke configuration (n = 20k, no speedup assertion).
"""
from __future__ import annotations

import time

import numpy as np

from .common import emit, time_call
from .common import quick as common_quick

N_ROWS = 200_000
N_QUERIES = 48
H_SCALE = 0.25       # bandwidth scale on the sample covariance: wide enough
                     # that the RFF probe gate passes at the default D
COLS = ("loss", "latency_ms")


def _quick() -> bool:
    return common_quick()


def _setup(n: int, seed: int = 0):
    """Store with one joint reservoir holding the full n rows and a
    hand-built full-H synopsis primed into the cache (no O(n^2) LSCV)."""
    import jax.numpy as jnp

    from repro.core import KDESynopsis
    from repro.data import TelemetryStore

    rng = np.random.default_rng(seed)
    loss = rng.gamma(2.0, 1.5, n)
    lat = 10 + 3 * loss + rng.normal(0, 2, n)
    store = TelemetryStore(capacity=n, seed=0)
    store.track_joint(COLS)
    store.add_batch({"loss": loss.astype(np.float32),
                     "latency_ms": lat.astype(np.float32)})
    res = store.joints[COLS]
    x = res.sample()
    H = (np.cov(x.T) * H_SCALE).astype(np.float32)
    syn = KDESynopsis(x=jnp.asarray(x), H=jnp.asarray(H),
                      n_source=res.n_seen, selector="lscv_H")
    store.cache.put(COLS, "lscv_H", res.version, syn)
    return store, x


def _make_queries(x: np.ndarray, n_queries: int, seed: int = 1):
    from repro.core.aqp_query import AqpQuery, Box

    rng = np.random.default_rng(seed)
    mu, sd = x.mean(axis=0), x.std(axis=0)
    ops = ["count", "sum", "avg"]
    out = []
    for i in range(n_queries):
        lo = mu + sd * rng.uniform(-1.5, 0.0, 2)
        hi = lo + sd * rng.uniform(1.0, 2.5, 2)
        tgt = COLS[int(rng.integers(2))]
        out.append(AqpQuery(ops[i % 3],
                            (Box(COLS, tuple(lo), tuple(hi)),),
                            target=None if i % 3 == 0 else tgt))
    return out


def run() -> dict:
    n = N_ROWS if not _quick() else 20_000
    n_q = N_QUERIES if not _quick() else 12
    store, x = _setup(n)
    engine = store.engine(selector="lscv_H")
    queries = _make_queries(x, n_q)

    def answers(kde_backend):
        return engine.execute(queries, kde_backend=kde_backend)

    # warm both paths: compiles the jitted passes and (rff) fits the synopsis
    t0 = time.perf_counter()
    r_exact = answers("exact")
    t_exact_cold = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    r_rff = answers("rff")
    t_fit_cold = (time.perf_counter() - t0) * 1e6

    # the accuracy envelope: the probe gate must have passed (every answer on
    # the rff path, none degraded back to exact) ...
    paths = {r.path for r in r_rff}
    assert paths == {"qmc:rff"}, (
        f"RFF fit failed the accuracy gate (paths {sorted(paths)}) — the "
        f"benchmark bandwidth must keep the probe error inside the gate")
    assert {r.path for r in r_exact} == {"qmc"}
    # ... and every estimate must sit within a few reported CI half-widths
    # of the exact answer (feature-block batch-means, dof = n_blocks - 1)
    scale_ref = max(abs(r.estimate) for r in r_exact)
    for re_, rr in zip(r_exact, r_rff):
        half = max((rr.ci_hi - rr.ci_lo) / 2.0, 0.02 * scale_ref)
        err = abs(rr.estimate - re_.estimate)
        assert err <= 4.0 * half, (
            f"RFF answer outside its CI envelope: exact={re_.estimate:.1f} "
            f"rff={rr.estimate:.1f} (err {err:.1f} > 4 * {half:.1f})")

    t_exact = time_call(answers, "exact", repeats=3, warmup=1)
    t_rff = time_call(answers, "rff", repeats=3, warmup=1)
    speedup = t_exact / t_rff
    emit(f"aqp_rff_exact_n{n}_q{n_q}", t_exact,
         f"{n_q / (t_exact * 1e-6):,.0f} q/s, O(n_qmc*n) exact KDE pass")
    emit(f"aqp_rff_rff_n{n}_q{n_q}", t_rff,
         f"{n_q / (t_rff * 1e-6):,.0f} q/s, {speedup:.1f}x over exact "
         f"(O(n_qmc*D) feature pass + block CI)")
    emit(f"aqp_rff_fit_n{n}", t_fit_cold,
         "one-shot fit + probe gate + first eval (cold, amortised)")
    emit(f"aqp_rff_exact_cold_n{n}", t_exact_cold,
         "exact path cold (compile + first pass)")
    out = {"rff_speedup": speedup, "n": n}
    if not _quick():
        assert speedup >= 5.0, (
            f"RFF backend must be >= 5x over the exact full-H pass at "
            f"n={n}, got {speedup:.1f}x")
    return out


if __name__ == "__main__":
    run()
