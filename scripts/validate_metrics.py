#!/usr/bin/env python
"""Schema-check observability artifacts (CI gate).

    python scripts/validate_metrics.py /tmp/aqp-metrics.json
    python scripts/validate_metrics.py --bench BENCH_aqp.json
    python scripts/validate_metrics.py --tuning /tmp/tiles.json

Default mode validates a `serve --mode aqp --metrics-out` snapshot
(`obs.export_json` format): the required instruments must be present with
sane values — queue depth gauge, per-path latency histograms, synopsis
cache hit/miss counters, and flush-reason counters.  `--bench` validates a
`benchmarks.run --json` report instead; `--tuning` a persisted tile cache
(`kernels/autotune.py` REPRO_TUNING_CACHE format), enforcing on top of the
schema that every swept winner is no slower than the env/default tiles it
was measured against.  Exits non-zero with one line per violation.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

HIST_KEYS = {"labels", "count", "sum", "mean", "min", "max",
             "p50", "p95", "p99"}


def _entries(doc: dict, kind: str, name: str, errs: List[str]) -> list:
    entries = doc.get(kind, {}).get(name)
    if not entries:
        errs.append(f"missing {kind[:-1]} {name!r}")
        return []
    for e in entries:
        if "labels" not in e or not isinstance(e["labels"], dict):
            errs.append(f"{name}: entry without labels dict: {e}")
    return entries


def validate_metrics(doc: dict) -> List[str]:
    errs: List[str] = []
    for key in ("ts", "counters", "gauges", "histograms"):
        if key not in doc:
            errs.append(f"missing top-level key {key!r}")
    if errs:
        return errs

    # queue depth gauge, one per session
    for e in _entries(doc, "gauges", "aqp.admission.depth", errs):
        if "session" not in e["labels"]:
            errs.append(f"aqp.admission.depth entry missing session label: "
                        f"{e['labels']}")
        if e.get("value", -1) < 0:
            errs.append(f"aqp.admission.depth negative: {e}")

    # per-path latency histograms with full summaries
    paths = set()
    for e in _entries(doc, "histograms", "aqp.query.latency_us", errs):
        missing = HIST_KEYS - set(e)
        if missing:
            errs.append(f"aqp.query.latency_us entry missing {sorted(missing)}")
            continue
        paths.add(e["labels"].get("path"))
        if e["count"] > 0 and not (e["min"] <= e["p50"] <= e["p95"]
                                   <= e["p99"] <= e["max"]):
            errs.append(f"aqp.query.latency_us percentiles out of order for "
                        f"path={e['labels'].get('path')}")
    if not paths - {None}:
        errs.append("aqp.query.latency_us has no path-labelled entries")

    # cache hit rates need both counters present (zero values are fine)
    _entries(doc, "counters", "aqp.cache.hits", errs)
    _entries(doc, "counters", "aqp.cache.misses", errs)

    # flush reasons, every label from the admission vocabulary ("fit" is the
    # offloaded-synopsis-fit re-flush)
    known = {"watermark", "deadline", "manual", "close", "fit"}
    for e in _entries(doc, "counters", "aqp.admission.flush_reason", errs):
        reason = e["labels"].get("reason")
        if reason not in known:
            errs.append(f"unknown flush reason {reason!r}")

    # synopsis-backend instruments are conditional: they only exist once a
    # full-H query ran through the pluggable backend layer, but when present
    # they must be backend-labelled and well-formed
    for name in ("aqp.synopsis.hits", "aqp.synopsis.fallback"):
        for e in doc.get("counters", {}).get(name, []):
            if e.get("labels", {}).get("backend") not in ("exact", "rff"):
                errs.append(f"{name} entry missing/unknown backend label: "
                            f"{e.get('labels')}")
    for name in ("aqp.synopsis.fit_us", "aqp.synopsis.eval_us"):
        for e in doc.get("histograms", {}).get(name, []):
            missing = HIST_KEYS - set(e)
            if missing:
                errs.append(f"{name} entry missing {sorted(missing)}")
            elif e.get("labels", {}).get("backend") != "rff":
                errs.append(f"{name} entry missing backend=rff label: "
                            f"{e.get('labels')}")
    return errs


def validate_bench(doc: dict) -> List[str]:
    errs: List[str] = []
    for key in ("git_sha", "ts", "config", "results"):
        if key not in doc:
            errs.append(f"missing top-level key {key!r}")
    if errs:
        return errs
    if not doc["results"]:
        errs.append("empty results list")
    timed = set()
    for r in doc["results"]:
        for key in ("name", "us_per_call"):
            if key not in r:
                errs.append(f"result missing {key!r}: {r}")
        # us_per_call == 0 marks a non-timing row (parity checks, skipped
        # suites); negative is always a bug
        if r.get("us_per_call", -1) < 0:
            errs.append(f"negative us_per_call: {r.get('name')}")
        if r.get("us_per_call", 0) > 0:
            timed.add(r.get("name", ""))
    if not any(n.startswith("aqp_") for n in timed):
        errs.append("no timed aqp_* benchmark results present")
    return errs


def validate_tuning(doc: dict) -> List[str]:
    errs: List[str] = []
    if doc.get("version") != 1:
        errs.append(f"unsupported tile-cache version {doc.get('version')!r}")
        return errs
    if "ts" not in doc:
        errs.append("missing top-level key 'ts'")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        errs.append("empty or missing entries list")
        return errs
    for e in entries:
        name = e.get("kernel", "<unnamed>")
        for key in ("kernel", "shape", "key", "tiles", "us",
                    "default_tiles", "default_us", "repeats", "swept"):
            if key not in e:
                errs.append(f"{name}: entry missing {key!r}")
        for field in ("shape", "tiles", "default_tiles"):
            v = e.get(field)
            if isinstance(v, dict):
                bad = {k: x for k, x in v.items()
                       if not isinstance(x, int) or x <= 0}
                if bad:
                    errs.append(f"{name}: non-positive {field} values {bad}")
        if e.get("us", -1) <= 0:
            errs.append(f"{name}: non-positive winning time {e.get('us')}")
        # the invariant the sweep guarantees by construction (the default
        # config is always candidate #0): tuned tiles never regress
        if "us" in e and "default_us" in e and e["us"] > e["default_us"]:
            errs.append(f"{name}: tuned tiles SLOWER than defaults "
                        f"({e['us']:.1f}us > {e['default_us']:.1f}us)")
        swept = e.get("swept")
        if isinstance(swept, list):
            if not any(s.get("tiles") == e.get("tiles") for s in swept):
                errs.append(f"{name}: winning tiles absent from swept list")
            if swept and swept[0].get("tiles") != e.get("default_tiles"):
                errs.append(f"{name}: candidate #0 is not the default config")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="JSON artifact to validate")
    ap.add_argument("--bench", action="store_true",
                    help="validate a benchmarks.run --json report instead "
                         "of a metrics snapshot")
    ap.add_argument("--tuning", action="store_true",
                    help="validate a kernels/autotune.py tile cache instead "
                         "of a metrics snapshot")
    args = ap.parse_args()
    with open(args.path, encoding="utf-8") as f:
        doc = json.load(f)
    if args.bench and args.tuning:
        print("FAIL: --bench and --tuning are mutually exclusive",
              file=sys.stderr)
        return 1
    if args.bench:
        errs, kind = validate_bench(doc), "bench report"
    elif args.tuning:
        errs, kind = validate_tuning(doc), "tile cache"
    else:
        errs, kind = validate_metrics(doc), "metrics snapshot"
    for e in errs:
        print(f"FAIL {args.path}: {e}", file=sys.stderr)
    if not errs:
        print(f"OK {args.path}: valid {kind}")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
