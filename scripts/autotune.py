#!/usr/bin/env python
"""Measurement-driven tile sweep CLI (kernels/autotune.py front end).

    PYTHONPATH=src python scripts/autotune.py --shape aqp_grouped_sums:n=16384,d=6,G=64
    PYTHONPATH=src python scripts/autotune.py --metrics /tmp/aqp-metrics.json
    PYTHONPATH=src REPRO_TUNING_CACHE=tiles.json python scripts/autotune.py ...

Sweeps candidate tile configurations for the shapes the workload actually
ran — from a `serve --metrics-out` snapshot's `kernel.wall_us` labels
(--metrics), from the live process registry (`tuning.measured()`, the
default when any tunable kernel already ran in-process), or from explicit
--shape specs — and records the winners in the tile cache.  With --cache
(or REPRO_TUNING_CACHE already set) the winners persist to the tile-cache
JSON that `scripts/validate_metrics.py --tuning` schema-checks and a fresh
process loads with zero re-sweeps.

--assert-no-regress exits non-zero if any swept winner timed slower than
the env/default configuration it was measured against (the sweep times the
default as candidate #0, so this only trips on measurement pathology —
CI runs it as a tripwire).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

SHAPE_LABELS = ("n", "d", "G", "m")


def parse_shape(spec: str):
    """'kernel:n=16384,d=6,G=64' -> (kernel, {'n': 16384, 'd': 6, 'G': 64})"""
    kernel, _, rest = spec.partition(":")
    if not kernel or not rest:
        raise ValueError(f"malformed --shape {spec!r}; expected "
                         f"kernel:n=...,d=...[,G=...,m=...]")
    shape = {}
    for part in rest.split(","):
        k, _, v = part.partition("=")
        if k not in SHAPE_LABELS:
            raise ValueError(f"--shape {spec!r}: unknown axis {k!r} "
                             f"(have {SHAPE_LABELS})")
        shape[k] = int(v)
    return kernel, shape


def shapes_from_rows(rows, known):
    """(kernel, shape) specs from measured kernel.wall_us label rows,
    deduped by cache key; sweep-generated rows are excluded (they describe
    the sweep itself, not the workload)."""
    from repro.kernels import autotune

    out, seen = [], set()
    for row in rows:
        kernel = row.get("kernel")
        if kernel not in known or row.get("autotune") == "sweep":
            continue
        shape = {k: int(row[k]) for k in SHAPE_LABELS if k in row}
        if not shape:
            continue
        key = autotune.shape_key(kernel, shape)
        if key not in seen:
            seen.add(key)
            out.append((kernel, shape))
    return out


def shapes_from_snapshot(path: str, known):
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    rows = [e.get("labels", {})
            for e in doc.get("histograms", {}).get("kernel.wall_us", [])]
    return shapes_from_rows(rows, known)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", action="append", default=[],
                    metavar="KERNEL:n=..,d=..",
                    help="explicit sweep spec (repeatable); e.g. "
                         "aqp_grouped_sums:n=16384,d=6,G=64")
    ap.add_argument("--metrics", metavar="PATH",
                    help="obs.export_json snapshot: sweep every shape its "
                         "kernel.wall_us entries measured")
    ap.add_argument("--cache", metavar="PATH",
                    help="persist winners here (sets REPRO_TUNING_CACHE)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="per-axis extremes only (CI smoke)")
    ap.add_argument("--assert-no-regress", action="store_true",
                    help="exit non-zero if any winner timed slower than the "
                         "default tiles")
    args = ap.parse_args()
    if args.cache:
        os.environ["REPRO_TUNING_CACHE"] = args.cache

    from repro.kernels import autotune
    from repro.kernels.tuning import measured

    targets = [parse_shape(s) for s in args.shape]
    if args.metrics:
        targets += shapes_from_snapshot(args.metrics, autotune.SWEEPS)
    if not args.shape and not args.metrics:
        targets += shapes_from_rows(measured(), autotune.SWEEPS)
    if not targets:
        print("nothing to sweep: no --shape given and no measured "
              "kernel.wall_us shapes found", file=sys.stderr)
        return 2

    regressed = []
    for kernel, shape in targets:
        entry = autotune.sweep(kernel, shape, repeats=args.repeats,
                               quick=args.quick)
        gain = entry["default_us"] / entry["us"] if entry["us"] else 1.0
        print(f"{kernel} {shape}: {entry['tiles']} "
              f"{entry['us']:.1f}us ({gain:.2f}x over default "
              f"{entry['default_tiles']} {entry['default_us']:.1f}us, "
              f"{len(entry['swept'])} candidates)")
        if entry["us"] > entry["default_us"]:
            regressed.append((kernel, shape))

    from repro import knobs
    path = knobs.get_str("REPRO_TUNING_CACHE")
    if path:
        print(f"persisted {len(targets)} entr"
              f"{'y' if len(targets) == 1 else 'ies'} -> {path}")
    if args.assert_no_regress and regressed:
        for kernel, shape in regressed:
            print(f"FAIL: {kernel} {shape} tuned tiles slower than default",
                  file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
