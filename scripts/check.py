#!/usr/bin/env python
"""Run the repro.analysis static-check suite (the CI gate).

    PYTHONPATH=src python scripts/check.py --all
    PYTHONPATH=src python scripts/check.py --select lock-discipline,host-sync
    PYTHONPATH=src python scripts/check.py --all --json

Exit status: 0 when every checker is clean (pragma'd exceptions are
reported but do not fail); 1 when any unallowed violation remains;
2 on usage errors.  `--json` prints a machine-readable report instead of
the per-checker summary (still sets the exit status).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import asdict
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


def main() -> int:
    from repro.analysis import runner

    ap = argparse.ArgumentParser(
        description="project-specific static checks")
    ap.add_argument("--all", action="store_true",
                    help="run every checker (default when --select absent)")
    ap.add_argument("--select", metavar="ID[,ID...]",
                    help="comma-separated checker ids: "
                         + ", ".join(sorted(runner.CHECKERS)))
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    ap.add_argument("--root", default=str(REPO),
                    help="repo root to analyse (default: this repo)")
    args = ap.parse_args()

    select = None
    if args.select:
        select = [s.strip() for s in args.select.split(",") if s.strip()]
    try:
        results = runner.run_all(Path(args.root), select=select)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2

    failed = 0
    if args.json:
        doc = {
            check_id: {
                "violations": [asdict(v) for v in res["violations"]],
                "allowed": [asdict(v) for v in res["allowed"]],
            }
            for check_id, res in results.items()
        }
        print(json.dumps(doc, indent=2, sort_keys=True))
        failed = sum(len(r["violations"]) for r in results.values())
    else:
        for check_id, res in results.items():
            bad, ok = res["violations"], res["allowed"]
            status = "FAIL" if bad else "ok"
            line = (f"{check_id:<18} {status:>4}  "
                    f"{len(bad)} violation{'s' if len(bad) != 1 else ''}")
            if ok:
                line += f", {len(ok)} allowed"
            print(line)
            for v in bad:
                print(f"  {v.format()}")
            for v in ok:
                print(f"  {v.format()}")
            failed += len(bad)
        total_allowed = sum(len(r["allowed"]) for r in results.values())
        print(f"\n{failed} unallowed violation"
              f"{'s' if failed != 1 else ''}, {total_allowed} allowed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
