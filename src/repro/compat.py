"""Shims over jax API drift so the repo runs on both old and new releases.

jax moved `shard_map` from `jax.experimental` to the top level, added
`jax.lax.pvary`, and changed `Compiled.cost_analysis()` from a list of
per-computation dicts to a single dict.  Every call site routes through here
instead of version-checking locally.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map  # noqa: F401


def pvary(x, axis_names):
    """`jax.lax.pvary` where available; identity otherwise (older jax treats
    unvaried replicated values implicitly, so no tagging is needed)."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axis_names) if fn is not None else x


def cost_analysis_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` as a flat dict on every jax version."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        return ca[0] if ca else {}
    return ca or {}
