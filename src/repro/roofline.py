"""Roofline analysis from compiled dry-run artifacts.

Hardware model (TPU v5e-class, per chip):
    peak bf16 compute  197 TFLOP/s
    HBM bandwidth      819 GB/s
    ICI link bandwidth ~50 GB/s

Three terms per (arch x shape x mesh), in seconds:
    compute    = FLOPs / (chips * peak)
    memory     = HBM bytes / (chips * hbm_bw)
    collective = collective bytes / (chips * link_bw)

FLOPs / collective bytes are extracted from the *compiled per-device HLO* by a
structural parser (`HloCostModel`) because XLA's `cost_analysis()` counts
`while` (scan) bodies exactly once: this repo lowers every model as
scan-over-layers, so raw numbers undercount depth.  The parser rebuilds the
call graph (while/fusion/call/to_apply edges), derives each while's trip count
from its condition's comparison constant, and multiplies dot-FLOPs and
collective result-bytes by the product of enclosing trip counts.  Raw
`cost_analysis()` numbers are reported alongside for reference.

MODEL_FLOPS uses the standard 6*N*D (train) / 2*N*D (per decoded token)
accounting with N = (active) parameter count, D = tokens — the "useful
compute" yardstick the §Roofline table compares against.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(%?[\w\.\-]+)\s*=\s*(.*)$")
_CALLSITE_RE = re.compile(r"(?:body|condition|to_apply|calls)=([%\w\.\-]+)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _parse_shape(text: str) -> Tuple[Optional[str], List[int]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None, []
    dtype, dims = m.group(1), m.group(2)
    if dtype not in _DTYPE_BYTES:
        return None, []
    return dtype, [int(d) for d in dims.split(",") if d]


def _shape_bytes(text: str) -> int:
    """Total bytes of all array shapes in a (possibly tuple) type string."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Tuple[str, str]]            # (result_name, rhs text)
    callees: List[str]


class HloCostModel:
    """Structural HLO cost extraction with while-trip-count multiplication."""

    def __init__(self, hlo_text: str):
        self.computations: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self.multipliers = self._compute_multipliers()

    # -- parsing -------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[Computation] = None
        for line in text.splitlines():
            stripped = line.strip()
            if stripped.startswith(("HloModule",)):
                continue
            # computation header, e.g.
            #   %region_0.2 (arg: (s32[], f32[128,128])) -> (s32[], ...) {
            #   ENTRY %main.4 (x: f32[...]) -> f32[...] {
            header = re.match(r"^(ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*->.*\{\s*$", stripped)
            if header:
                name = header.group(2).lstrip("%")
                cur = Computation(name=name, instructions=[], callees=[])
                self.computations[name] = cur
                if header.group(1):
                    self.entry = name
                continue
            if stripped == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(stripped)
            if not m:
                continue
            rname, rhs = m.group(1).lstrip("%"), m.group(2)
            cur.instructions.append((rname, rhs))

    def _trip_count(self, cond_name: str) -> int:
        """Trip count from the condition computation's comparison constant."""
        comp = self.computations.get(cond_name)
        if comp is None:
            return 1
        consts = []
        for _, rhs in comp.instructions:
            m = re.search(r"constant\((\d+)\)", rhs)
            if m:
                consts.append(int(m.group(1)))
            # trip constant may be wrapped in a fusion operand computation:
            cm = re.search(r"calls=([%\w\.\-]+)", rhs)
            if cm:
                consts.append(self._trip_count(cm.group(1).lstrip("%")))
        return max(consts) if consts else 1

    def _call_edges(self) -> Dict[str, List[Tuple[str, int]]]:
        """caller -> [(callee, weight per caller-execution)]."""
        edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
        for cname, comp in self.computations.items():
            for _, rhs in comp.instructions:
                if " while(" in rhs:
                    bm = re.search(r"body=([%\w\.\-]+)", rhs)
                    cm = re.search(r"condition=([%\w\.\-]+)", rhs)
                    trips = self._trip_count(cm.group(1).lstrip("%")) if cm else 1
                    trips = max(trips, 1)
                    if bm:
                        edges[cname].append((bm.group(1).lstrip("%"), trips))
                    if cm:
                        edges[cname].append((cm.group(1).lstrip("%"), trips))
                else:
                    for m in _CALLSITE_RE.finditer(rhs):
                        edges[cname].append((m.group(1).lstrip("%"), 1))
        return edges

    def _compute_multipliers(self) -> Dict[str, int]:
        """multiplier(c) = number of executions of computation c per program
        run = sum over call sites of caller-multiplier * site weight."""
        edges = self._call_edges()
        entry = self.entry or next(iter(self.computations))
        mult: Dict[str, int] = defaultdict(int)
        mult[entry] = 1
        # topological accumulation via DFS with memo (HLO call graphs are DAGs)
        order: List[str] = []
        seen = set()

        def topo(name: str):
            if name in seen:
                return
            seen.add(name)
            for callee, _ in edges.get(name, ()):
                topo(callee)
            order.append(name)

        topo(entry)
        for name in reversed(order):        # callers before callees
            m = mult.get(name, 0)
            if m == 0:
                continue
            for callee, w in edges.get(name, ()):
                mult[callee] += m * w
        return dict(mult)

    # -- queries ---------------------------------------------------------------
    def _shape_of(self, comp: Computation) -> Dict[str, str]:
        return {name: rhs for name, rhs in comp.instructions}

    def dot_flops(self) -> float:
        """2 * prod(result dims) * prod(contracting dims) per dot, x multiplier."""
        total = 0.0
        for cname, comp in self.computations.items():
            m = self.multipliers.get(cname, 0)
            if m == 0:
                continue
            shapes = {}
            for rname, rhs in comp.instructions:
                dt, dims = _parse_shape(rhs)
                if dt is not None:
                    shapes[rname] = dims
            for rname, rhs in comp.instructions:
                if " dot(" not in rhs and not rhs.startswith("dot("):
                    continue
                dt, rdims = _parse_shape(rhs)
                if dt is None:
                    continue
                opm = re.search(r"dot\(([^)]*)\)", rhs)
                contracting = 1
                if opm:
                    lm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                    lhs_dims: List[int] = []
                    # newer HLO dumps type operands inline:
                    #   dot(f32[64,64]{1,0} %lhs, f32[64,64]{1,0} %rhs)
                    inline = _SHAPE_RE.search(opm.group(1))
                    if inline and inline.group(1) in _DTYPE_BYTES:
                        lhs_dims = [int(d) for d in inline.group(2).split(",") if d]
                    else:                       # untyped: resolve %lhs by name
                        names = re.findall(r"%([\w\.\-]+)", opm.group(1))
                        if names and names[0] in shapes:
                            lhs_dims = shapes[names[0]]
                    if lm and lhs_dims:
                        for d in lm.group(1).split(","):
                            if d:
                                contracting *= lhs_dims[int(d)]
                res = 1
                for d in rdims:
                    res *= d
                total += 2.0 * res * contracting * m
        return total

    def collective_bytes(self) -> Tuple[float, Dict[str, float]]:
        total = 0.0
        by_kind: Dict[str, float] = defaultdict(float)
        for cname, comp in self.computations.items():
            m = self.multipliers.get(cname, 0)
            if m == 0:
                continue
            for rname, rhs in comp.instructions:
                for kind in _COLLECTIVES:
                    token = f" {kind}(" if not rhs.startswith(kind) else f"{kind}("
                    if rhs.startswith(f"{kind}(") or f" {kind}(" in rhs or f"{kind}-start(" in rhs:
                        if f"{kind}-done" in rhs:
                            break
                        b = _shape_bytes(rhs.split("(")[0]) * m
                        total += b
                        by_kind[kind] += b
                        break
        return total, dict(by_kind)


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS + HBM-byte accounting
# ---------------------------------------------------------------------------

def model_flops(cfg, shape, n_layers_attn_quadratic: bool = True) -> float:
    """6*N*D train / 2*N*D per-token decode, + attention score FLOPs."""
    from repro.configs.base import ModelConfig, ShapeConfig
    N_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        flops = 6.0 * N_active * tokens
        flops += _attn_flops(cfg, B, S, causal=True) * 3.0   # fwd + 2x bwd
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * N_active * tokens
        flops += _attn_flops(cfg, B, S, causal=True)
    else:  # decode: one token against an S-long context
        flops = 2.0 * N_active * B
        flops += _attn_decode_flops(cfg, B, S)
    return flops


def _attn_flops(cfg, B, S, causal: bool) -> float:
    if cfg.family == "ssm":
        # selective scan: ~ 6 * di * N flops per token per layer
        return 6.0 * cfg.d_inner * cfg.ssm_state * B * S * cfg.n_layers
    factor = 0.5 if causal else 1.0
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        ssm = 6.0 * cfg.d_inner * cfg.ssm_state * B * S * cfg.n_layers
        win = min(cfg.sliding_window or S, S)
        attn = 4.0 * B * S * win * cfg.n_heads * cfg.head_dim_ * n_attn * factor
        return ssm + attn
    L = cfg.n_layers + getattr(cfg, "n_enc_layers", 0)
    return 4.0 * B * S * S * cfg.n_heads * cfg.head_dim_ * L * factor


def _attn_decode_flops(cfg, B, S) -> float:
    if cfg.family == "ssm":
        return 6.0 * cfg.d_inner * cfg.ssm_state * B * cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        win = min(cfg.sliding_window or S, S)
        return (6.0 * cfg.d_inner * cfg.ssm_state * B * cfg.n_layers +
                4.0 * B * win * cfg.n_heads * cfg.head_dim_ * n_attn)
    return 4.0 * B * S * cfg.n_heads * cfg.head_dim_ * cfg.n_layers


def hbm_bytes(cfg, shape, n_micro: int = 1) -> float:
    """Per-step global HBM traffic estimate (see EXPERIMENTS.md §Roofline for
    the formula).  Sharding spreads this evenly, so the per-chip term divides
    by the chip count."""
    N = cfg.param_count()
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    if shape.kind == "train":
        params = 2.0 * N * 2          # bf16 read in fwd + bwd
        opt = 4.0 * N * 4 + 2.0 * N * 4   # m,v read+write f32; grads write/read
        act_ckpt = 2.0 * 2 * B * S * D * cfg.n_layers   # write+read layer inputs
        logits = 2.0 * B * S * cfg.vocab_size * 2 / max(n_micro, 1) * n_micro
        return params + opt + act_ckpt + logits
    if shape.kind == "prefill":
        params = 2.0 * N
        act = 2.0 * 2 * B * S * D * cfg.n_layers
        kv = kv_cache_bytes(cfg, B, S)
        return params + act + kv
    # decode: params once + read the whole cache
    return 2.0 * N + kv_cache_bytes(cfg, B, S)


def kv_cache_bytes(cfg, B, S) -> float:
    if cfg.family == "ssm":
        return B * cfg.n_layers * (cfg.d_inner * cfg.ssm_state * 4 +
                                   (cfg.d_conv - 1) * cfg.d_inner * 2)
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        ssm = B * cfg.n_layers * (cfg.d_inner * cfg.ssm_state * 4 +
                                  (cfg.d_conv - 1) * cfg.d_inner * 2)
        win = min(cfg.sliding_window or S, S)
        return ssm + 2.0 * B * win * cfg.n_kv_heads * cfg.head_dim_ * n_attn * 2
    L = cfg.n_layers
    kv = 2.0 * B * S * cfg.n_kv_heads * cfg.head_dim_ * L * 2
    if cfg.family == "encdec":
        kv += 2.0 * B * cfg.enc_seq * cfg.n_kv_heads * cfg.head_dim_ * L * 2
    return kv


# ---------------------------------------------------------------------------
# the three terms
# ---------------------------------------------------------------------------

def terms(flops: float, hbm: float, coll_bytes_per_chip: float, chips: int) -> Dict[str, float]:
    compute = flops / (chips * PEAK_FLOPS)
    memory = hbm / (chips * HBM_BW)
    collective = coll_bytes_per_chip / ICI_BW     # already per-chip from SPMD HLO
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    return {"compute_s": compute, "memory_s": memory, "collective_s": collective,
            "dominant": dominant}
