"""Central registry for every ``REPRO_*`` environment knob.

The repo grew ~20 env knobs across kernels, the engine, the synopsis layer,
observability, and the benches.  Each used to be an ad-hoc
``os.environ.get`` at its call site, which meant a knob could silently fork:
two sites reading the same name with different defaults, or a renamed knob
leaving a dead read behind.  This module is the single source of truth —
one :class:`Knob` per name with its default, type, and docstring — and the
``repro.analysis`` knob-registry checker enforces that

  * every ``REPRO_*`` name referenced anywhere in src/scripts/benchmarks is
    registered here,
  * raw ``os.environ`` reads of ``REPRO_*`` names happen only in this module
    (or carry an audited ``# repro: allow[knob-registry]`` pragma), and
  * the registry and the knob table in ``docs/analysis.md`` match
    bidirectionally.

Accessors are typed and LOUD on malformed values: a silently ignored typo
in a tuning sweep wastes a TPU reservation (the same contract
``kernels/tuning.env_int`` always had — it now delegates here).  Reads are
uncached on purpose — knobs resolve at *call* time so a late env change or
an in-process sweep can move them without a restart (PR 9's import-freeze
fix depends on this).

This module imports nothing outside the standard library, so the earliest
riser (``launch/dryrun.py`` sets XLA_FLAGS before jax initialises) can use
it safely.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Optional

__all__ = ["KNOBS", "Knob", "get_bool", "get_int", "get_raw", "get_str",
           "register"]


@dataclass(frozen=True)
class Knob:
    """One environment knob: its name, parsed type, default, and doc."""

    name: str
    type: str           # "int" | "bool" | "str" | "path"
    default: object
    doc: str

    def __post_init__(self):
        if not self.name.startswith("REPRO_"):
            raise ValueError(f"knob {self.name!r} must start with REPRO_")
        if self.type not in ("int", "bool", "str", "path"):
            raise ValueError(f"knob {self.name}: unknown type {self.type!r}")
        if not self.doc.strip():
            raise ValueError(f"knob {self.name} needs a docstring")


KNOBS: Dict[str, Knob] = {}


def register(name: str, type: str, default: object, doc: str) -> Knob:
    """Register one knob; duplicate registration with different metadata is
    a collision (exactly the silent fork this registry exists to prevent)."""
    knob = Knob(name, type, default, doc)
    prev = KNOBS.get(name)
    if prev is not None and prev != knob:
        raise ValueError(f"knob {name!r} already registered with different "
                         f"metadata: {prev} vs {knob}")
    KNOBS[name] = knob
    return knob


def _lookup(name: str) -> Knob:
    knob = KNOBS.get(name)
    if knob is None:
        raise KeyError(
            f"unregistered knob {name!r}: add it to repro/knobs.py (and the "
            f"docs/analysis.md table) before reading it")
    return knob


def get_raw(name: str) -> Optional[str]:
    """The raw env string for a registered knob, or None when unset/empty."""
    _lookup(name)
    raw = os.environ.get(name)  # repro: allow[knob-registry] the one audited raw read behind every typed accessor
    if raw is None or not raw.strip():
        return None
    return raw


def get_int(name: str, default: Optional[int] = None) -> int:
    """Positive-int knob.  `default` overrides the registered default (the
    tile helpers pass per-kernel module constants)."""
    knob = _lookup(name)
    if knob.type != "int":
        raise TypeError(f"knob {name} is {knob.type}, not int")
    raw = get_raw(name)
    if raw is None:
        return int(knob.default if default is None else default)
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(f"{name} must be a positive integer, got {raw!r}")
    if value <= 0:
        raise ValueError(f"{name} must be a positive integer, got {value}")
    return value


def get_bool(name: str) -> bool:
    """Flag knob: unset, empty, and "0" are False; anything else is True
    (matching the historical REPRO_OBS semantics)."""
    knob = _lookup(name)
    if knob.type != "bool":
        raise TypeError(f"knob {name} is {knob.type}, not bool")
    raw = os.environ.get(name, "")  # repro: allow[knob-registry] bool knobs must distinguish "" from "0" pre-strip
    return raw not in ("", "0")


def get_str(name: str, default: Optional[str] = None) -> str:
    """String/path knob; empty and unset both resolve to the default."""
    knob = _lookup(name)
    if knob.type not in ("str", "path"):
        raise TypeError(f"knob {name} is {knob.type}, not str/path")
    raw = get_raw(name)
    if raw is None:
        return str(knob.default if default is None else default)
    return raw


# ---------------------------------------------------------------------------
# The registry.  Keep this table in sync with docs/analysis.md (the
# knob-registry checker enforces the match bidirectionally).
# ---------------------------------------------------------------------------

register("REPRO_OBS", "bool", False,
         "Enable the gated observability layer (span tracing, fenced "
         "latency histograms, kernel profiling); see docs/observability.md.")
register("REPRO_BENCH_QUICK", "bool", False,
         "Shrink honouring bench suites to a CI-smoke configuration "
         "(set by `benchmarks.run --quick`).")
register("REPRO_TUNING_CACHE", "path", "",
         "Path of the persisted measured-tile cache (kernels/autotune.py); "
         "sweeps write it, fresh processes lazy-load it with zero re-sweeps.")
register("REPRO_DRYRUN_DEVICES", "int", 512,
         "Placeholder host-device count for launch/dryrun.py meshes (must "
         "be set before jax initialises).")

register("REPRO_KDE_CHUNK", "int", 256,
         "Evaluation-point chunk size for the exact kde_eval_H pass "
         "(core/kde.py) — bounds peak memory of the (chunk, n) kernel "
         "matrix.")
register("REPRO_KDE_CROSSOVER", "int", 16384,
         "Fitted-sample size above which kde_backend='auto' switches the "
         "full-H density pass from exact to the RFF synopsis.")
register("REPRO_RFF_FEATURES", "int", 2048,
         "Random-Fourier feature count D for the RFF density synopsis "
         "(accuracy ~ 1/sqrt(D); fit cost O(n*D)).")

register("REPRO_AQP_TILE", "int", 256,
         "Data-tile size of the aqp_batch_sums Pallas kernel.")
register("REPRO_AQP_Q_TILE", "int", 128,
         "Query-tile size of the aqp_batch_sums Pallas kernel.")
register("REPRO_AQP_BOXES_TILE", "int", 256,
         "Data-tile size of the aqp_box_sums Pallas kernel.")
register("REPRO_AQP_BOXES_Q_TILE", "int", 8,
         "Query-tile size of the aqp_box_sums Pallas kernel.")
register("REPRO_AQP_GROUPED_TILE", "int", 256,
         "Data-tile size of the aqp_grouped_sums Pallas kernel.")
register("REPRO_AQP_GROUPED_G_TILE", "int", 16,
         "Category-tile size of the aqp_grouped_sums Pallas kernel.")
register("REPRO_QMC_TILE", "int", 256,
         "Data-tile size of the qmc_box_reduce Pallas kernel.")
register("REPRO_QMC_M_TILE", "int", 128,
         "Node-tile size of the qmc_box_reduce Pallas kernel.")
register("REPRO_QMC_Q_TILE", "int", 8,
         "Box-tile size of the qmc_box_reduce Pallas kernel.")
register("REPRO_RFF_TILE", "int", 256,
         "Feature-tile size of the rff_density Pallas kernel.")
register("REPRO_RFF_P_TILE", "int", 128,
         "Point-tile size of the rff_density Pallas kernel.")

register("REPRO_PAIRWISE_TILE", "int", 256,
         "Data-tile size of the pairwise_scaled_ksum Pallas kernel "
         "(PLUGIN selector inner sums).")
register("REPRO_SV_TILE", "int", 256,
         "Data-tile size of the sv_matrix Pallas kernel (LSCV_H "
         "precompute).")
register("REPRO_GH_TILE", "int", 256,
         "Data-tile size of the gh_fused_sum Pallas kernel (fused LSCV_H "
         "objective).")
register("REPRO_KDE_EVAL_TILE", "int", 256,
         "Data-tile size of the kde_eval Pallas kernel (grid KDE "
         "evaluation).")
register("REPRO_LSCV_TILE", "int", 256,
         "Data-tile size of the lscv_grid_sums Pallas kernel.")
register("REPRO_LSCV_H_TILE", "int", 8,
         "Bandwidth-grid tile size of the lscv_grid_sums Pallas kernel.")
