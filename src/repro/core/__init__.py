"""repro.core — the paper's contribution: KDE bandwidth selection + AQP.

Public API:
  plugin_bandwidth, lscv_h, lscv_H, g_of_H       — bandwidth selectors (§4.4)
  kde_eval, kde_eval_H, silverman_h              — density estimation (§4.2)
  KDESynopsis, count_1d, sum_1d                  — AQP on KDE synopses (§4.3)
  AqpQuery, QueryEngine, AqpResult               — unified declarative AQP API
  Range, Box, Eq, GroupBy                        — AqpQuery predicate terms
  AqpSession, AdmissionQueue                     — async admission / micro-batch
                                                   scheduling over QueryEngine
  Query/QueryBatch, BoxQuery/BoxQueryBatch       — legacy stacks (deprecated
                                                   shims over aqp_query)
  reductions.*                                   — parallel primitives (§5)
  distributed.*                                  — multi-chip selectors (beyond paper)
  binned.*                                       — binned/FFT variants (§2.2)
"""
from .aqp import (KDESynopsis, Query, QueryBatch, batch_query_1d, count_1d,
                  count_1d_numeric, count_box_H, count_box_diag, sum_1d,
                  sum_1d_numeric, sum_box_H, sum_box_diag)
from .aqp_admission import (DEFAULT_PRIORITY_TIERS, AdmissionFull,
                            AdmissionQueue, AqpSession)
from .aqp_ci import DEFAULT_CI_LEVEL, norm_ppf, t_ppf
from .aqp_multid import (BoxQuery, BoxQueryBatch, batch_query_box,
                         batch_query_box_grouped, batch_query_qmc)
from .aqp_query import (AqpQuery, AqpResult, Box, Eq, GroupBy, PlanCache,
                        QueryEngine, Range)
from .kde import kde_eval, kde_eval_H, silverman_h
from .lscv import LSCVHResult, LSCVhResult, g_of_H, lscv_H, lscv_h
from .plugin import PluginResult, plugin_bandwidth, plugin_bandwidth_sequential

__all__ = [
    "KDESynopsis", "Query", "QueryBatch", "BoxQuery", "BoxQueryBatch",
    "AqpQuery", "AqpResult", "QueryEngine", "Range", "Box", "Eq", "GroupBy",
    "AqpSession", "AdmissionQueue", "AdmissionFull", "PlanCache",
    "DEFAULT_PRIORITY_TIERS", "DEFAULT_CI_LEVEL", "norm_ppf", "t_ppf",
    "batch_query_1d", "batch_query_box", "batch_query_box_grouped",
    "batch_query_qmc",
    "count_1d", "count_1d_numeric", "count_box_H", "count_box_diag",
    "sum_1d", "sum_1d_numeric", "sum_box_H", "sum_box_diag",
    "kde_eval", "kde_eval_H", "silverman_h", "LSCVHResult",
    "LSCVhResult", "g_of_H", "lscv_H", "lscv_h", "PluginResult",
    "plugin_bandwidth", "plugin_bandwidth_sequential",
]
