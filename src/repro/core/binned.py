"""Binned / FFT-accelerated KDE (paper §2.2 related work, beyond the paper's
exact-computation scope — included because a production AQP engine wants both:
exact selectors for fitting, O(g log g) binned evaluation for serving).

`linear_binning`  — assigns each source point to its two neighbouring grid
                    points with linear weights ("mass of the data near g_i").
`binned_kde_fft`  — evaluates the KDE on the grid via circular convolution with
                    an explicitly zero-padded kernel (no aliasing — the [16]
                    setback the paper cites is avoided by padding).
`binned_psi_r`    — binned Psi_r functionals, giving an O(g^2) PLUGIN variant
                    whose error vs the exact O(n^2) one is measured in tests.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import gaussian as G


@partial(jax.jit, static_argnames=("g",))
def linear_binning(x: jax.Array, lo: jax.Array, hi: jax.Array, g: int = 512):
    """Returns (grid, counts) with sum(counts) == n."""
    grid = jnp.linspace(lo, hi, g)
    delta = (hi - lo) / (g - 1)
    pos = jnp.clip((x - lo) / delta, 0.0, g - 1.0)
    left = jnp.floor(pos)
    w_right = pos - left
    li = left.astype(jnp.int32)
    ri = jnp.minimum(li + 1, g - 1)
    counts = jnp.zeros((g,), x.dtype)
    counts = counts.at[li].add(1.0 - w_right)
    counts = counts.at[ri].add(w_right)
    return grid, counts


@partial(jax.jit, static_argnames=())
def binned_kde_fft(grid: jax.Array, counts: jax.Array, h: jax.Array) -> jax.Array:
    """KDE on the grid in O(g log g) via zero-padded FFT convolution."""
    g = grid.shape[0]
    delta = grid[1] - grid[0]
    n = jnp.sum(counts)
    # Kernel taps out to the edge of the grid; pad to 2g to make the circular
    # convolution linear (anti-aliasing).
    taps = jnp.arange(-(g - 1), g) * delta
    kern = G.phi(taps / h) / h
    size = 4 * g  # next pow2-ish safe size
    fc = jnp.fft.rfft(counts, size)
    fk = jnp.fft.rfft(kern, size)
    conv = jnp.fft.irfft(fc * fk, size)
    out = conv[g - 1:2 * g - 1]
    return out / n


def binned_psi_r(grid: jax.Array, counts: jax.Array, gbw: jax.Array, r: int) -> jax.Array:
    """Binned Psi_r functional: Psi_r ~= n^-2 g^-(r+1) sum_ab c_a c_b K^(r)((g_a-g_b)/gbw).

    O(g^2) instead of O(n^2); evaluated with a Toeplitz trick: K^(r) depends
    only on a-b, so sum_ab c_a c_b K_ab = sum_t K_t * (c (*) c)[t], where (*)
    is cross-correlation, computed via FFT in O(g log g)."""
    g = grid.shape[0]
    delta = grid[1] - grid[0]
    n = jnp.sum(counts)
    kfun = G.k6 if r == 6 else G.k4
    size = 4 * g
    fc = jnp.fft.rfft(counts, size)
    autocorr = jnp.fft.irfft(fc * jnp.conj(fc), size)   # c (*) c at lags 0..g-1 and wrap
    lags = jnp.arange(g) * delta
    k_at_lags = kfun(lags / gbw)
    # lag 0 counted once, lags +-t combined (K^(r) even for even r)
    total = autocorr[0] * k_at_lags[0] + 2.0 * jnp.sum(autocorr[1:g] * k_at_lags[1:])
    return total / (n * n * gbw ** (r + 1))


def binned_plugin_bandwidth(x: jax.Array, g: int = 1024):
    """PLUGIN with binned Psi functionals (beyond-paper accuracy/speed trade)."""
    import math
    from .plugin import variance_estimator
    n = x.shape[0]
    lo = jnp.min(x) - 1e-3
    hi = jnp.max(x) + 1e-3
    grid, counts = linear_binning(x, lo, hi, g)
    v = variance_estimator(x)
    sigma = jnp.sqrt(v)
    psi8 = 105.0 / (32.0 * math.sqrt(math.pi) * sigma ** 9)
    g1 = (-2.0 * G.K6_AT_0 / (G.MU2_K * psi8 * n)) ** (1.0 / 9.0)
    psi6 = binned_psi_r(grid, counts, g1, 6)
    g2 = (-2.0 * G.K4_AT_0 / (G.MU2_K * psi6 * n)) ** (1.0 / 7.0)
    psi4 = binned_psi_r(grid, counts, g2, 4)
    h = (G.R_K_1D / (G.MU2_K ** 2 * psi4 * n)) ** 0.2
    return h
