"""Real error bars for AQP answers — per-path confidence-interval math.

The `rel_width` accuracy proxy (bandwidth-relative box width) says nothing a
caller can act on: it is unitless, path-dependent, and was outright wrong on
the exact paths.  This module computes actual confidence intervals for the
KDE execution paths, per the anytime-accuracy framing of Verdict-style tiered
sampling:

  range1d / box    analytic product-kernel variance.  The estimate is
                   scale * sum_i t_i over the m retained sample points, where
                   t_i is the per-point closed-form term (the Phi-difference
                   product for COUNT, the first-moment product for SUM).  The
                   sample points are an iid draw from the stream, so
                       Var(est) = scale^2 * m * Var(t)
                   and the sample variance of t gives a normal-theory CI.
                   AVG = SUM/COUNT uses the delta method with the exact
                   simplification sum(s_i - r*c_i) = 0 at r = sum(s)/sum(c).
  qmc              no closed form under a full bandwidth matrix; the CI comes
                   from subsample (batch-means) variance: split the retained
                   sample into K equal chunks — reservoir buffers are in
                   random order, so chunks are independent uniform
                   subsamples, the same structure as the tiers of a
                   `TieredReservoir` — answer each chunk on the shared node
                   set, and use the across-chunk spread with a Student-t
                   quantile (K-1 dof).
  exact            zero width (no smoothing, no sampling).
  exact:cm         bounded-error width from the count-min sketch parameters
                   (see `_StoreResolver.try_exact`).

The moment kernels mirror the estimate kernels in aqp.py/aqp_multid.py
(same per-point terms, extended with second moments) but run as a SEPARATE
jitted pass: the estimate passes stay byte-identical to the pre-CI engine,
which the admission bit-identity tests rely on.

Quantiles are closed-form approximations (Acklam's inverse normal CDF,
a Cornish-Fisher expansion for Student-t), accurate to ~1e-4 in the central
range — far below the statistical error of the intervals themselves — so no
scipy dependency is needed.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .aqp import AVG_MIN_COUNT, OP_COUNT, OP_SUM, _Phi, _phi

DEFAULT_CI_LEVEL = 0.95

# Subsample count for the quasi-MC batch-means CI.  Small enough that each
# chunk still sees a useful sample, large enough for a usable t quantile.
QMC_SUBSAMPLES = 8


# --- quantiles --------------------------------------------------------------

_A = (-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
      1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00)
_B = (-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
      6.680131188771972e+01, -1.328068155288572e+01)
_C = (-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
      -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00)
_D = (7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
      3.754408661907416e+00)


def norm_ppf(p: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation,
    |error| < 1.2e-9 over (0, 1))."""
    if not 0.0 < p < 1.0:
        if p == 0.0:
            return -math.inf
        if p == 1.0:
            return math.inf
        raise ValueError(f"p must be in [0, 1], got {p}")
    p_low = 0.02425
    if p < p_low:
        q = math.sqrt(-2.0 * math.log(p))
        return ((((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q
                  + _C[4]) * q + _C[5])
                / ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1.0))
    if p > 1.0 - p_low:
        return -norm_ppf(1.0 - p)
    q = p - 0.5
    r = q * q
    return (((((_A[0] * r + _A[1]) * r + _A[2]) * r + _A[3]) * r + _A[4]) * r
            + _A[5]) * q / \
           (((((_B[0] * r + _B[1]) * r + _B[2]) * r + _B[3]) * r + _B[4]) * r
            + 1.0)


def t_ppf(p: float, dof: int) -> float:
    """Student-t quantile by the Cornish-Fisher expansion around the normal
    quantile — exact enough (<1e-3 for dof >= 4 in the central range) for
    batch-means CIs, whose dominant error is the K-chunk variance estimate."""
    if dof < 1:
        return math.inf
    z = norm_ppf(p)
    if not math.isfinite(z):
        return z
    z2 = z * z
    g1 = z * (z2 + 1.0) / 4.0
    g2 = z * (5.0 * z2 * z2 + 16.0 * z2 + 3.0) / 96.0
    g3 = z * (3.0 * z2 ** 3 + 19.0 * z2 * z2 + 17.0 * z2 - 15.0) / 384.0
    g4 = z * (79.0 * z2 ** 4 + 776.0 * z2 ** 3 + 1482.0 * z2 * z2
              - 1920.0 * z2 - 945.0) / 92160.0
    d = float(dof)
    return z + g1 / d + g2 / d ** 2 + g3 / d ** 3 + g4 / d ** 4


# --- analytic moment kernels (range1d / box paths) --------------------------
#
# Per-query sums over the m sample points of the unscaled closed-form terms:
# (sum c, sum s, sum c^2, sum s^2, sum c*s) with c_i the COUNT term and s_i
# the SUM term.  The same per-point math as _batch_terms/_box_terms, so the
# implied estimates match the estimate pass to float32 rounding.

@jax.jit
def moments_1d(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array):
    """x: (m,) sample; a/b: (q,).  Returns five (q,) arrays."""
    def one(aq, bq):
        za = (aq - x) / h
        zb = (bq - x) / h
        c = _Phi(zb) - _Phi(za)
        s = x * c - h * (_phi(zb) - _phi(za))
        return (jnp.sum(c), jnp.sum(s),
                jnp.sum(c * c), jnp.sum(s * s), jnp.sum(c * s))
    return jax.vmap(one)(a, b)


@jax.jit
def moments_box(x: jax.Array, h_diag: jax.Array, lo: jax.Array,
                hi: jax.Array, tgt: jax.Array):
    """x: (m,d) rows; lo/hi: (q,d); tgt: (q,).  Returns five (q,) arrays.
    Queries run in 64-query slabs like `_box_terms` (same cache argument)."""
    axis = jnp.arange(x.shape[1])

    def one(loq, hiq, t):
        za = (loq[None, :] - x) / h_diag[None, :]
        zb = (hiq[None, :] - x) / h_diag[None, :]
        d_Phi = _Phi(zb) - _Phi(za)                               # (m, d)
        moment = x * d_Phi - h_diag[None, :] * (_phi(zb) - _phi(za))
        c = jnp.prod(d_Phi, axis=1)
        factors = jnp.where(axis[None, :] == t, moment, d_Phi)
        s = jnp.prod(factors, axis=1)
        return (jnp.sum(c), jnp.sum(s),
                jnp.sum(c * c), jnp.sum(s * s), jnp.sum(c * s))

    q_chunk = 64
    q, d = lo.shape
    if q <= q_chunk:
        return jax.vmap(one)(lo, hi, tgt)
    pad = (-q) % q_chunk
    lop = jnp.pad(lo, ((0, pad), (0, 0))).reshape(-1, q_chunk, d)
    hip = jnp.pad(hi, ((0, pad), (0, 0))).reshape(-1, q_chunk, d)
    tgtp = jnp.pad(tgt, (0, pad)).reshape(-1, q_chunk)
    out = jax.lax.map(lambda args: jax.vmap(one)(*args), (lop, hip, tgtp))
    return tuple(r.reshape(-1)[:q] for r in out)


def se_from_moments(ops: np.ndarray, moments, scale: float,
                    m: int) -> np.ndarray:
    """Per-query standard error of the scaled estimate from the raw moment
    sums; `ops` selects the COUNT/SUM/AVG formula per query.

    est = scale * sum(t)  =>  SE = scale * sqrt(m/(m-1)) *
                                   sqrt(sum(t^2) - sum(t)^2 / m).
    AVG uses the delta method on r = S/C; at r = sum(s)/sum(c) the residuals
    u_i = s_i - r c_i sum to zero exactly, so the variance term reduces to
    sum(u^2) = sum(s^2) - 2 r sum(cs) + r^2 sum(c^2).  Empty selections
    (scaled count below AVG_MIN_COUNT, where the engine pins AVG to 0) get an
    infinite SE — the estimate is a guard value, not an estimator.
    """
    m1c, m1s, m2c, m2s, m12 = (np.asarray(v, np.float64) for v in moments)
    ops = np.asarray(ops)
    if m < 2:
        return np.full(m1c.shape, np.inf)
    corr = m / (m - 1.0)
    se_count = scale * np.sqrt(corr * np.maximum(m2c - m1c * m1c / m, 0.0))
    se_sum = scale * np.sqrt(corr * np.maximum(m2s - m1s * m1s / m, 0.0))
    count = scale * m1c
    ok = count > AVG_MIN_COUNT
    r = np.where(ok, m1s / np.where(m1c != 0.0, m1c, 1.0), 0.0)
    quad = np.maximum(m2s - 2.0 * r * m12 + r * r * m2c, 0.0)
    se_avg = np.where(ok, scale * np.sqrt(corr * quad)
                      / np.maximum(count, AVG_MIN_COUNT), np.inf)
    return np.select([ops == OP_COUNT, ops == OP_SUM],
                     [se_count, se_sum], se_avg)


# --- subsample (batch-means) CI for the quasi-MC path -----------------------

def qmc_subsample_se(x: jax.Array, H: jax.Array, lo: np.ndarray,
                     hi: np.ndarray, tgt: np.ndarray, ops: np.ndarray,
                     n_source: int, n_qmc: int,
                     k_sub: int = QMC_SUBSAMPLES
                     ) -> Tuple[np.ndarray, int]:
    """(per-query SE, t dof) for a full-H group, by batch-means over K equal
    chunks of the retained sample (reservoir order is random, so chunks are
    independent uniform subsamples).  All chunks reduce over the node set
    planned for the FULL sample (`_qmc_plan`), so the deterministic QMC
    integration error is common-mode and the spread isolates sampling
    variance — the error source the CI is for."""
    from .aqp_multid import _halton_unit, _qmc_plan, _qmc_shared_terms

    q = np.asarray(lo).shape[0]
    m = x.shape[0]
    k = min(k_sub, m // 2)
    if k < 2:
        return np.full((q,), np.inf), 1
    plan = _qmc_plan(np.asarray(x, np.float64), np.asarray(H), lo, hi, n_qmc)
    if plan is None:                  # zero-measure boxes: estimate is 0
        return np.zeros((q,), np.float64), k - 1
    glo, ghi, clo, chi, n_nodes = plan
    unit = _halton_unit(n_nodes, x.shape[1])
    glo_d = jnp.asarray(glo, jnp.float32)
    ghi_d = jnp.asarray(ghi, jnp.float32)
    clo_d = jnp.asarray(clo, jnp.float32)
    chi_d = jnp.asarray(chi, jnp.float32)
    tgt_d = jnp.asarray(tgt, jnp.int32)
    ops = np.asarray(ops)
    chunk = m // k
    scale_k = n_source / chunk
    ests = []
    for j in range(k):
        xs = x[j * chunk: (j + 1) * chunk]
        cnt_raw, sum_raw = _qmc_shared_terms(xs, H, glo_d, ghi_d, clo_d,
                                             chi_d, tgt_d, unit)
        counts = scale_k * np.asarray(cnt_raw, np.float64)
        sums = scale_k * np.asarray(sum_raw, np.float64)
        avgs = np.where(counts > AVG_MIN_COUNT,
                        sums / np.maximum(counts, 1e-12), 0.0)
        ests.append(np.select([ops == OP_COUNT, ops == OP_SUM],
                              [counts, sums], avgs))
    e = np.stack(ests)
    return e.std(axis=0, ddof=1) / math.sqrt(k), k - 1
