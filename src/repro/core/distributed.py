"""Distributed (multi-chip) bandwidth selection — the scale-out layer the
paper's single-device design lacks (DESIGN.md §2, last row of the table).

Decomposition: the implicit n x n upper-triangular pairwise matrix is split by
*strided row ownership* — device p owns rows {p, p+P, p+2P, ...}.  A contiguous
block-row split would give device 0 ~2x the work of device P-1 (triangle);
striding balances each device's pair count to within n/2 pairs (the same
load-balancing concern the paper solves with its eq. 49/50 block-index math —
here solved by ownership pattern instead, no index arithmetic needed).

The sample x is *replicated* (O(n) bytes; even n=4M fp32 is 16 MB — trivial
against 95 GB HBM), each device reduces its own rows with the same chunked
slab computation used on a single chip, and a single `psum` produces the global
sum — one small scalar/vector collective per reduction, which is why these
selectors scale to a full pod essentially linearly (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

from . import gaussian as G
from .lscv import h_grid_for
from .reductions import pairwise_reduce


def _strided_pairwise_partial(fun: Callable, x: jax.Array, p: jax.Array, n_dev: int,
                              chunk: int = 256, axes=()) -> jax.Array:
    """Partial sum_{i<j, i mod P == p} fun(x_i - x_j) on one device (1-D x)."""
    n = x.shape[0]
    rows_per_dev = -(-n // n_dev)
    c = min(chunk, rows_per_dev)
    pad_rows = (-rows_per_dev) % c
    cols = jnp.arange(n)

    def body(acc, r):
        local = r * c + jnp.arange(c)                     # local row index
        row_idx = local * n_dev + p                       # strided global rows
        ok = row_idx < n
        rows = jnp.take(x, jnp.where(ok, row_idx, 0), axis=0)
        diff = rows[:, None] - x[None, :]
        vals = fun(diff)
        mask = ok[:, None] & (row_idx[:, None] < cols[None, :])
        return acc + jnp.sum(jnp.where(mask, vals, 0.0)), None

    nsteps = (rows_per_dev + pad_rows) // c
    acc0 = jnp.zeros((), x.dtype)
    if axes:  # carry is device-varying inside shard_map (jax>=0.7 vma typing)
        acc0 = compat.pvary(acc0, axes)
    acc, _ = jax.lax.scan(body, acc0, jnp.arange(nsteps))
    return acc


def sharded_pairwise_reduce(fun: Callable, x: jax.Array, mesh: Mesh,
                            chunk: int = 256) -> jax.Array:
    """RR_fun over every device of `mesh` (all axes flattened)."""
    axes = tuple(mesh.axis_names)
    n_dev = mesh.devices.size

    def shard_fn(x_rep):
        p = jax.lax.axis_index(axes)
        partial_sum = _strided_pairwise_partial(fun, x_rep, p, n_dev, chunk, axes)
        return jax.lax.psum(partial_sum, axes)

    f = compat.shard_map(shard_fn, mesh=mesh, in_specs=P(), out_specs=P())
    return f(x)


def sharded_plugin_psi_sums(x: jax.Array, g1: jax.Array, g2: jax.Array, mesh: Mesh,
                            chunk: int = 256):
    """Distributed Psi6/Psi4 pairwise sums for PLUGIN (the O(n^2) stages)."""
    s6 = sharded_pairwise_reduce(lambda dx: G.k6(dx / g1), x, mesh, chunk)
    s4 = sharded_pairwise_reduce(lambda dx: G.k4(dx / g2), x, mesh, chunk)
    return s6, s4


def sharded_lscv_h_grid(x: jax.Array, sigma_inv: jax.Array, h_grid: jax.Array,
                        c_k: float, c_kk: float, mesh: Mesh, chunk: int = 64,
                        h_chunk: int = 8, algorithm: str = "mxu") -> jax.Array:
    """Distributed fused LSCV_h grid: every device folds its strided rows'
    quadratic-form slabs into per-h partial sums; one psum over the vector.

    algorithm="einsum": per-pair quadratic form (paper's eq. 60 layout,
    O(d^2) VPU work per pair).  "mxu": expansion S = qr + qx - 2 r M x^T —
    the cross term is one (c,d)x(d,n) matmul per slab on the MXU (§Perf
    hillclimb H4; same numbers, validated in tests)."""
    axes = tuple(mesh.axis_names)
    n_dev = mesh.devices.size
    n, d = x.shape
    n_h = h_grid.shape[0]
    pad_h = (-n_h) % h_chunk
    inv2 = jnp.pad(0.5 / (h_grid * h_grid), (0, pad_h)).reshape(-1, h_chunk)
    inv4 = jnp.pad(0.25 / (h_grid * h_grid), (0, pad_h)).reshape(-1, h_chunk)

    def shard_fn(x_rep, hg2, hg4):
        p = jax.lax.axis_index(axes)
        rows_per_dev = -(-n // n_dev)
        c = min(chunk, rows_per_dev)
        nsteps = -(-rows_per_dev // c)
        cols = jnp.arange(n)
        if algorithm == "mxu":
            mx = x_rep @ sigma_inv                       # (n, d), hoisted
            qx = jnp.sum(mx * x_rep, axis=1)             # (n,)

        def body(acc, r):
            local = r * c + jnp.arange(c)
            row_idx = local * n_dev + p
            ok = row_idx < n
            rows = jnp.take(x_rep, jnp.where(ok, row_idx, 0), axis=0)
            if algorithm == "mxu":
                mr = rows @ sigma_inv                     # (c, d)
                qr = jnp.sum(mr * rows, axis=1)           # (c,)
                cross = mr @ x_rep.T                      # (c, n) on the MXU
                s = qr[:, None] + qx[None, :] - 2.0 * cross
            else:
                v = rows[:, None, :] - x_rep[None, :, :]
                s = jnp.einsum("rnd,de,rne->rn", v, sigma_inv, v)
            mask = (ok[:, None] & (row_idx[:, None] < cols[None, :])).astype(s.dtype)
            sm = s * mask

            def per_hc(args):   # one h-chunk at a time: (hc, c, n) slab
                i2, i4 = args
                e2 = jnp.exp(-sm[None] * i2[:, None, None]) * mask[None]
                e4 = jnp.exp(-sm[None] * i4[:, None, None]) * mask[None]
                return jnp.sum(c_kk * e4 - 2.0 * c_k * e2, axis=(1, 2))

            contrib = jax.lax.map(per_hc, (hg2, hg4)).reshape(-1)[:n_h]
            return acc + contrib, None

        acc0 = compat.pvary(jnp.zeros((n_h,), x.dtype), axes)
        acc, _ = jax.lax.scan(body, acc0, jnp.arange(nsteps))
        return jax.lax.psum(acc, axes)

    f = compat.shard_map(shard_fn, mesh=mesh, in_specs=(P(), P(), P()), out_specs=P())
    return f(x, inv2, inv4)


def distributed_lscv_h(x: jax.Array, mesh: Mesh, n_h: int = 150, chunk: int = 64):
    """End-to-end distributed LSCV_h (paper §6.2 on a pod instead of a GPU)."""
    from .lscv import covariance
    if x.ndim == 1:
        x = x[:, None]
    n, d = x.shape
    sigma = covariance(x)
    det_sigma = jnp.linalg.det(sigma)
    sigma_inv = jnp.linalg.inv(sigma)
    c_k, c_kk, r_k = G.lscv_h_consts(d, det_sigma)
    h_grid = h_grid_for(n, d, n_h).astype(x.dtype)
    t_sums = sharded_lscv_h_grid(x, sigma_inv, h_grid, c_k, c_kk, mesh, chunk)
    g_values = h_grid ** (-d) * (2.0 / (n * n) * t_sums + r_k / n)
    return h_grid[jnp.argmin(g_values)], h_grid, g_values
