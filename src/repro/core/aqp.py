"""Approximate query processing on KDE synopses (paper §4.3, eqs. 9-11).

A `KDESynopsis` replaces a column (or a small set of columns) of a relation:
  COUNT(a<=X<=b)  ~= n * Integral_a^b f^(x) dx                  (eq. 9)
  SUM(X; a..b)    ~= n * Integral_a^b x f^(x) dx                (eq. 10)
  AVG             = SUM / COUNT                                 (§4.3)

For the Gaussian kernel both 1-D integrals have closed forms, which we use
instead of the generic quadrature the paper mentions (§4.1(b)) — an exactness
*and* speed win recorded in DESIGN.md §2:

  Integral_a^b K_h(x - Xi) dx           = Phi((b-Xi)/h) - Phi((a-Xi)/h)
  Integral_a^b x K_h(x - Xi) dx         = Xi [Phi(.)]_a^b - h [phi((x-Xi)/h)]_a^b

Multi-d axis-aligned boxes: product of per-axis Phi terms for scalar/diagonal
bandwidths (eq. 11); full-H synopses fall back to deterministic quasi-MC.
Synopses are *mergeable* (weighted union of sample points) so they can be
folded across hosts of a training fleet — the scale-out behaviour the paper's
single-node design lacks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .kde import kde_eval, silverman_h
from .lscv import lscv_H, lscv_h
from .plugin import plugin_bandwidth

SQRT1_2 = 1.0 / math.sqrt(2.0)


def canonical_selector(selector: str) -> str:
    """Case-normalized bandwidth-selector name, used for cache keys and
    engine group keys.

    "Plugin"/"PLUGIN"/"plugin" must resolve to ONE selector (two live cache
    copies of the same synopsis waste the byte budget and can serve a stale
    copy after the other refits).  The one legitimate case pair is the
    paper's scalar vs full-matrix LSCV — "lscv_h" and "lscv_H" are
    *different* selectors and stay distinct.
    """
    low = selector.lower()
    if low == "lscv_h" and selector.endswith("H"):
        return "lscv_H"
    return low


def _Phi(z):
    return 0.5 * (1.0 + jax.scipy.special.erf(z * SQRT1_2))


def _phi(z):
    return jnp.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


@jax.jit
def count_1d(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """eq. (9), closed form: n * mean_i [Phi((b-Xi)/h) - Phi((a-Xi)/h)]."""
    n = x.shape[0]
    za = (a - x) / h
    zb = (b - x) / h
    return n * jnp.mean(_Phi(zb) - _Phi(za))


@jax.jit
def sum_1d(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """eq. (10), closed form for the Gaussian kernel."""
    za = (a - x) / h
    zb = (b - x) / h
    term_mu = x * (_Phi(zb) - _Phi(za))
    term_h = -h * (_phi(zb) - _phi(za))
    return jnp.sum(term_mu + term_h)


@partial(jax.jit, static_argnames=("n_grid",))
def count_1d_numeric(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array,
                     n_grid: int = 513) -> jax.Array:
    """eq. (9) by trapezoid quadrature — the generic path the paper describes;
    kept as a cross-check oracle against the closed form."""
    n = x.shape[0]
    grid = jnp.linspace(a, b, n_grid)
    f = kde_eval(grid, x, h)
    return n * jnp.trapezoid(f, grid)


@partial(jax.jit, static_argnames=("n_grid",))
def sum_1d_numeric(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array,
                   n_grid: int = 513) -> jax.Array:
    n = x.shape[0]
    grid = jnp.linspace(a, b, n_grid)
    f = kde_eval(grid, x, h)
    return n * jnp.trapezoid(grid * f, grid)


@jax.jit
def count_box_diag(x: jax.Array, h_diag: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """eq. (11) for axis-aligned boxes with scalar/diagonal bandwidth:
    product kernel => per-axis Phi factors.  x: (n,d), h_diag: (d,)."""
    n = x.shape[0]
    za = (lo[None, :] - x) / h_diag[None, :]
    zb = (hi[None, :] - x) / h_diag[None, :]
    per_axis = _Phi(zb) - _Phi(za)            # (n, d)
    return n * jnp.mean(jnp.prod(per_axis, axis=1))


@jax.jit
def sum_box_diag(x: jax.Array, h_diag: jax.Array, lo: jax.Array, hi: jax.Array,
                 target: jax.Array) -> jax.Array:
    """SUM of axis `target` over an axis-aligned box (eq. 11 x eq. 10):
    the product kernel factorises, so the box integral of x_t f^(x) is the
    per-axis Phi-difference product with axis t's factor replaced by the 1-D
    first-moment closed form  X_it [Phi]_a^b - h_t [phi]_a^b.
    x: (n,d), h_diag: (d,), target: scalar int axis index."""
    za = (lo[None, :] - x) / h_diag[None, :]
    zb = (hi[None, :] - x) / h_diag[None, :]
    d_Phi = _Phi(zb) - _Phi(za)                               # (n, d)
    moment = x * d_Phi - h_diag[None, :] * (_phi(zb) - _phi(za))
    axis = jnp.arange(x.shape[1])
    factors = jnp.where(axis[None, :] == target, moment, d_Phi)
    return jnp.sum(jnp.prod(factors, axis=1))


def _halton(n: int, d: int) -> jnp.ndarray:
    """Deterministic quasi-MC nodes (for full-H boxes)."""
    import numpy as np
    primes = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37][:d]
    out = np.zeros((n, d))
    for k, p in enumerate(primes):
        i = np.arange(1, n + 1)
        f = np.zeros(n)
        denom = 1.0
        rem = i.astype(np.float64)
        base = np.zeros(n)
        denom = p
        while rem.max() > 0:
            base += (rem % p) / denom
            rem = rem // p
            denom *= p
        out[:, k] = base
    return jnp.asarray(out, jnp.float32)


def box_qmc_terms(x: jax.Array, H: jax.Array, lo: jax.Array, hi: jax.Array,
                  target: int = 0, n_qmc: int = 4096):
    """Full-matrix-H box integrals by deterministic quasi-MC, returning
    (count, sum_target) from ONE density evaluation — the Halton nodes and
    the O(n_qmc * sample) kde_eval_H pass are the whole cost, and COUNT and
    SUM share both:  count = n vol mean(f),  sum = n vol mean(node_t f)."""
    from .kde import kde_eval_H
    n, d = x.shape
    nodes = lo[None, :] + _halton(n_qmc, d) * (hi - lo)[None, :]
    f = kde_eval_H(nodes, x, H)
    vol = jnp.prod(hi - lo)
    return n * vol * jnp.mean(f), n * vol * jnp.mean(nodes[:, target] * f)


def count_box_H(x: jax.Array, H: jax.Array, lo: jax.Array, hi: jax.Array,
                n_qmc: int = 4096) -> jax.Array:
    """Full-matrix-H COUNT over a box via quasi-Monte-Carlo on the box."""
    return box_qmc_terms(x, H, lo, hi, n_qmc=n_qmc)[0]


def sum_box_H(x: jax.Array, H: jax.Array, lo: jax.Array, hi: jax.Array,
              target: int = 0, n_qmc: int = 4096) -> jax.Array:
    """Full-matrix-H SUM of axis `target` over a box (quasi-MC)."""
    return box_qmc_terms(x, H, lo, hi, target=target, n_qmc=n_qmc)[1]


@dataclass
class KDESynopsis:
    """A fitted density synopsis for one numeric column (or column set).

    `h` is a scalar bandwidth for 1-D synopses, and may be a (d,) diagonal
    bandwidth vector for multi-d synopses (per-axis PLUGIN / silverman —
    the product-kernel form of eq. 11).  `H` is the full bandwidth matrix
    (LSCV_H); exactly one of `h`/`H` is set.
    """
    x: jax.Array                  # retained sample (the synopsis payload)
    h: Optional[jax.Array] = None # scalar or (d,) diagonal bandwidth
    H: Optional[jax.Array] = None # full bandwidth matrix (LSCV_H)
    n_source: int = 0             # size of the original relation
    selector: str = "plugin"

    @classmethod
    def fit(cls, data: jax.Array, selector: str = "plugin", max_sample: int = 4096,
            seed: int = 0, backend: str = "jnp") -> "KDESynopsis":
        data = jnp.asarray(data, jnp.float32)
        n_source = data.shape[0]
        if n_source > max_sample:   # numerosity reduction (paper §2.1)
            idx = jax.random.permutation(jax.random.PRNGKey(seed), n_source)[:max_sample]
            sample = data[idx]
        else:
            sample = data
        if selector == "plugin":
            if sample.ndim == 1:
                h = plugin_bandwidth(sample, backend=backend).h
            else:
                # per-axis PLUGIN: the paper's selector is univariate (§4.4),
                # so the multi-d product kernel takes one PLUGIN h per axis
                h = jnp.stack([plugin_bandwidth(sample[:, j], backend=backend).h
                               for j in range(sample.shape[1])])
            return cls(x=sample, h=h, n_source=n_source, selector=selector)
        if selector == "silverman":
            if sample.ndim == 1:
                h = silverman_h(sample)
            else:
                h = jnp.stack([silverman_h(sample[:, j])
                               for j in range(sample.shape[1])])
            return cls(x=sample, h=h, n_source=n_source, selector=selector)
        if selector == "lscv_h":
            res = lscv_h(sample, backend=backend)
            return cls(x=sample, h=res.h, n_source=n_source, selector=selector)
        if selector == "lscv_H":
            res = lscv_H(sample if sample.ndim == 2 else sample[:, None])
            return cls(x=sample, H=res.H, n_source=n_source, selector=selector)
        raise ValueError(f"unknown selector {selector!r}")

    # --- queries ----------------------------------------------------------
    def _scale(self) -> float:
        """Scale factor from retained sample to the full relation."""
        return self.n_source / self.x.shape[0]

    def count(self, a: float, b: float) -> jax.Array:
        if self.x.ndim == 1:
            return self._scale() * count_1d(self.x, self.h, jnp.float32(a), jnp.float32(b))
        raise ValueError("use count_box for multi-d synopses")

    def sum(self, a: float, b: float) -> jax.Array:
        if self.x.ndim == 1:
            return self._scale() * sum_1d(self.x, self.h, jnp.float32(a), jnp.float32(b))
        raise ValueError("1-D only")

    def avg(self, a: float, b: float) -> jax.Array:
        return _avg_or_zero(self.count(a, b), self.sum(a, b))

    def _as_rows(self) -> jax.Array:
        return self.x[:, None] if self.x.ndim == 1 else self.x

    def h_diag(self) -> jax.Array:
        """Per-axis bandwidth vector (scalar h broadcast to every axis)."""
        d = self._as_rows().shape[1]
        return jnp.broadcast_to(jnp.asarray(self.h, jnp.float32), (d,))

    def _target_index(self, target) -> int:
        d = self._as_rows().shape[1]
        t = 0 if target is None else int(target)
        if not 0 <= t < d:
            raise ValueError(f"target axis {t} out of range for d={d}")
        return t

    def count_box(self, lo, hi) -> jax.Array:
        x = self._as_rows()
        lo = jnp.asarray(lo, jnp.float32)
        hi = jnp.asarray(hi, jnp.float32)
        if self.H is not None:
            return self._scale() * count_box_H(x, self.H, lo, hi)
        return self._scale() * count_box_diag(x, self.h_diag(), lo, hi)

    def sum_box(self, lo, hi, target: Optional[int] = None) -> jax.Array:
        """SUM of axis `target` (default axis 0) over an axis-aligned box."""
        x = self._as_rows()
        lo = jnp.asarray(lo, jnp.float32)
        hi = jnp.asarray(hi, jnp.float32)
        t = self._target_index(target)
        if self.H is not None:
            return self._scale() * sum_box_H(x, self.H, lo, hi, target=t)
        return self._scale() * sum_box_diag(x, self.h_diag(), lo, hi, jnp.int32(t))

    def avg_box(self, lo, hi, target: Optional[int] = None) -> jax.Array:
        return _avg_or_zero(self.count_box(lo, hi), self.sum_box(lo, hi, target))

    def merge(self, other: "KDESynopsis", max_sample: int = 4096, seed: int = 0) -> "KDESynopsis":
        """Mergeable synopses (beyond paper): union the retained samples
        (subsample if needed) and refit the bandwidth on the merged sample."""
        merged = jnp.concatenate([self.x, other.x], axis=0)
        return KDESynopsis.fit(merged, selector=self.selector, max_sample=max_sample,
                               seed=seed)._replace_source(self.n_source + other.n_source)

    def _replace_source(self, n_source: int) -> "KDESynopsis":
        self.n_source = n_source
        return self

    def query_batch(self, queries: Sequence["Query"], backend: str = "jnp") -> np.ndarray:
        """Answer N COUNT/SUM/AVG range queries in one jitted pass."""
        queries = [q if isinstance(q, Query) else Query(*q) for q in queries]
        return run_legacy_queries(queries, self, backend=backend)

    def query_box_batch(self, queries, backend: str = "jnp") -> np.ndarray:
        """Answer N COUNT/SUM/AVG box queries (eq. 11) in one jitted pass."""
        from .aqp_multid import BoxQuery, run_legacy_boxes
        queries = [q if isinstance(q, BoxQuery) else BoxQuery(*q)
                   for q in queries]
        return run_legacy_boxes(queries, self, backend=backend)


# --- batched query engine -------------------------------------------------
#
# A production AQP front end amortises planning and kernel launches across
# thousands of concurrent queries (cf. Verdict's batch planner).  The closed
# forms of eqs. 9-10 share all their per-sample work — Phi/phi differences —
# so a whole heterogeneous batch against one synopsis reduces to ONE
# (queries x sample) two-channel reduction, then a per-query select.
#
# `Query`/`QueryBatch` are the legacy 1-D surface: `QueryBatch.run` is a
# deprecated shim over the unified declarative engine in aqp_query.py
# (`AqpQuery` + `QueryEngine`), which also routes boxes, categorical Eq
# terms, GROUP BY, and the full-H quasi-MC fallback.

OP_COUNT, OP_SUM, OP_AVG = 0, 1, 2
OP_CODES = {"count": OP_COUNT, "sum": OP_SUM, "avg": OP_AVG}

# COUNT below this is an empty selection for AVG purposes (see _avg_or_zero).
AVG_MIN_COUNT = 1e-3


def _avg_or_zero(counts, sums):
    """AVG = SUM / COUNT, defined as 0 for (effectively) empty selections:
    below the threshold the ratio is 0/0 noise amplified by 1/count.  Both the
    scalar and the batched path route through here so they agree exactly."""
    return jnp.where(counts > AVG_MIN_COUNT,
                     sums / jnp.maximum(counts, 1e-12), 0.0)


@dataclass(frozen=True)
class Query:
    """One aggregate range query: OP(column) WHERE a <= column <= b."""
    op: str                        # "count" | "sum" | "avg"
    a: float
    b: float
    column: Optional[str] = None   # None when run against a single synopsis

    def __post_init__(self):
        if self.op not in OP_CODES:
            raise ValueError(f"unknown op {self.op!r}; expected one of {sorted(OP_CODES)}")


def _batch_terms(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array):
    """vmapped closed forms: per-query unscaled (count_raw, sum_raw)."""
    def one(aq, bq):
        za = (aq - x) / h
        zb = (bq - x) / h
        d_Phi = _Phi(zb) - _Phi(za)
        cnt = jnp.sum(d_Phi)
        # same elementwise association as sum_1d so both paths agree tightly
        sm = jnp.sum(x * d_Phi - h * (_phi(zb) - _phi(za)))
        return cnt, sm
    return jax.vmap(one)(a, b)


@partial(jax.jit, static_argnames=("backend",))
def batch_query_1d(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array,
                   ops: jax.Array, scale: jax.Array,
                   backend: str = "jnp") -> jax.Array:
    """Answer a mixed batch against one 1-D synopsis in a single jitted call.

    x: (n,) retained sample; a/b/ops: (q,); scale: sample->relation factor.
    backend="pallas" routes the (queries x sample) reduction through the
    kernels/aqp_batch.py tile kernel.
    """
    if backend == "pallas":
        from repro.kernels import ops as kops
        cnt_raw, sum_raw = kops.aqp_batch_sums(x, h, a, b)
    else:
        cnt_raw, sum_raw = _batch_terms(x, h, a, b)
    counts = scale * cnt_raw
    sums = scale * sum_raw
    avgs = _avg_or_zero(counts, sums)
    return jnp.select([ops == OP_COUNT, ops == OP_SUM], [counts, sums], avgs)


@dataclass
class QueryBatch:
    """Planner for heterogeneous query batches.

    Groups queries by target column so each synopsis is answered in a single
    jitted pass, then scatters results back to submission order.
    """
    queries: Sequence[Query]
    _groups: Dict[Optional[str], List[int]] = field(init=False, repr=False)

    def __post_init__(self):
        self.queries = [q if isinstance(q, Query) else Query(*q) for q in self.queries]
        groups: Dict[Optional[str], List[int]] = {}
        for i, q in enumerate(self.queries):
            groups.setdefault(q.column, []).append(i)
        self._groups = groups

    def __len__(self) -> int:
        return len(self.queries)

    @property
    def columns(self) -> List[Optional[str]]:
        return list(self._groups)

    def plan(self, column: Optional[str]):
        """(indices, a, b, opcodes) device arrays for one column's group."""
        idx = self._groups[column]
        qs = [self.queries[i] for i in idx]
        a = jnp.asarray([q.a for q in qs], jnp.float32)
        b = jnp.asarray([q.b for q in qs], jnp.float32)
        ops_arr = jnp.asarray([OP_CODES[q.op] for q in qs], jnp.int32)
        return idx, a, b, ops_arr

    def run(self, synopses: Union[KDESynopsis, Mapping[str, KDESynopsis]],
            backend: str = "jnp") -> np.ndarray:
        """Deprecated shim: compiles to `AqpQuery` specs and executes through
        the unified engine (repro.core.aqp_query); answers in submission
        order, bit-for-bit identical to `QueryEngine.execute`."""
        import warnings

        warnings.warn(
            "QueryBatch.run is deprecated; build AqpQuery specs and execute "
            "them through repro.core.aqp_query.QueryEngine (or "
            "TelemetryStore.query)", DeprecationWarning, stacklevel=2)
        return run_legacy_queries(self.queries, synopses, backend=backend)


def run_legacy_queries(queries: Sequence[Query], synopses,
                       backend: str = "jnp") -> np.ndarray:
    """Execute legacy 1-D `Query` objects through the unified engine —
    the shim body, shared with `KDESynopsis.query_batch` (which keeps its
    non-deprecated convenience signature)."""
    from .aqp_query import execute_specs, from_query
    return execute_specs([from_query(q) for q in queries], synopses,
                         backend=backend)
