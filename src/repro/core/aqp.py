"""Approximate query processing on KDE synopses (paper §4.3, eqs. 9-11).

A `KDESynopsis` replaces a column (or a small set of columns) of a relation:
  COUNT(a<=X<=b)  ~= n * Integral_a^b f^(x) dx                  (eq. 9)
  SUM(X; a..b)    ~= n * Integral_a^b x f^(x) dx                (eq. 10)
  AVG             = SUM / COUNT                                 (§4.3)

For the Gaussian kernel both 1-D integrals have closed forms, which we use
instead of the generic quadrature the paper mentions (§4.1(b)) — an exactness
*and* speed win recorded in DESIGN.md §2:

  Integral_a^b K_h(x - Xi) dx           = Phi((b-Xi)/h) - Phi((a-Xi)/h)
  Integral_a^b x K_h(x - Xi) dx         = Xi [Phi(.)]_a^b - h [phi((x-Xi)/h)]_a^b

Multi-d axis-aligned boxes: product of per-axis Phi terms for scalar/diagonal
bandwidths (eq. 11); full-H synopses fall back to deterministic quasi-MC.
Synopses are *mergeable* (weighted union of sample points) so they can be
folded across hosts of a training fleet — the scale-out behaviour the paper's
single-node design lacks.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .kde import kde_eval, silverman_h
from .lscv import lscv_H, lscv_h
from .plugin import plugin_bandwidth

SQRT1_2 = 1.0 / math.sqrt(2.0)


def _Phi(z):
    return 0.5 * (1.0 + jax.scipy.special.erf(z * SQRT1_2))


def _phi(z):
    return jnp.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


@jax.jit
def count_1d(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """eq. (9), closed form: n * mean_i [Phi((b-Xi)/h) - Phi((a-Xi)/h)]."""
    n = x.shape[0]
    za = (a - x) / h
    zb = (b - x) / h
    return n * jnp.mean(_Phi(zb) - _Phi(za))


@jax.jit
def sum_1d(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """eq. (10), closed form for the Gaussian kernel."""
    za = (a - x) / h
    zb = (b - x) / h
    term_mu = x * (_Phi(zb) - _Phi(za))
    term_h = -h * (_phi(zb) - _phi(za))
    return jnp.sum(term_mu + term_h)


@partial(jax.jit, static_argnames=("n_grid",))
def count_1d_numeric(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array,
                     n_grid: int = 513) -> jax.Array:
    """eq. (9) by trapezoid quadrature — the generic path the paper describes;
    kept as a cross-check oracle against the closed form."""
    n = x.shape[0]
    grid = jnp.linspace(a, b, n_grid)
    f = kde_eval(grid, x, h)
    return n * jnp.trapezoid(f, grid)


@partial(jax.jit, static_argnames=("n_grid",))
def sum_1d_numeric(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array,
                   n_grid: int = 513) -> jax.Array:
    n = x.shape[0]
    grid = jnp.linspace(a, b, n_grid)
    f = kde_eval(grid, x, h)
    return n * jnp.trapezoid(grid * f, grid)


@jax.jit
def count_box_diag(x: jax.Array, h_diag: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """eq. (11) for axis-aligned boxes with scalar/diagonal bandwidth:
    product kernel => per-axis Phi factors.  x: (n,d), h_diag: (d,)."""
    n = x.shape[0]
    za = (lo[None, :] - x) / h_diag[None, :]
    zb = (hi[None, :] - x) / h_diag[None, :]
    per_axis = _Phi(zb) - _Phi(za)            # (n, d)
    return n * jnp.mean(jnp.prod(per_axis, axis=1))


def _halton(n: int, d: int) -> jnp.ndarray:
    """Deterministic quasi-MC nodes (for full-H boxes)."""
    import numpy as np
    primes = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37][:d]
    out = np.zeros((n, d))
    for k, p in enumerate(primes):
        i = np.arange(1, n + 1)
        f = np.zeros(n)
        denom = 1.0
        rem = i.astype(np.float64)
        base = np.zeros(n)
        denom = p
        while rem.max() > 0:
            base += (rem % p) / denom
            rem = rem // p
            denom *= p
        out[:, k] = base
    return jnp.asarray(out, jnp.float32)


def count_box_H(x: jax.Array, H: jax.Array, lo: jax.Array, hi: jax.Array,
                n_qmc: int = 4096) -> jax.Array:
    """Full-matrix-H COUNT over a box via quasi-Monte-Carlo on the box."""
    from .kde import kde_eval_H
    n, d = x.shape
    nodes = lo[None, :] + _halton(n_qmc, d) * (hi - lo)[None, :]
    f = kde_eval_H(nodes, x, H)
    vol = jnp.prod(hi - lo)
    return n * vol * jnp.mean(f)


@dataclass
class KDESynopsis:
    """A fitted density synopsis for one numeric column (or column set)."""
    x: jax.Array                  # retained sample (the synopsis payload)
    h: Optional[jax.Array] = None # scalar bandwidth (PLUGIN / LSCV_h / silverman)
    H: Optional[jax.Array] = None # full bandwidth matrix (LSCV_H)
    n_source: int = 0             # size of the original relation
    selector: str = "plugin"

    @classmethod
    def fit(cls, data: jax.Array, selector: str = "plugin", max_sample: int = 4096,
            seed: int = 0, backend: str = "jnp") -> "KDESynopsis":
        data = jnp.asarray(data, jnp.float32)
        n_source = data.shape[0]
        if n_source > max_sample:   # numerosity reduction (paper §2.1)
            idx = jax.random.permutation(jax.random.PRNGKey(seed), n_source)[:max_sample]
            sample = data[idx]
        else:
            sample = data
        if selector == "plugin":
            if sample.ndim != 1:
                raise ValueError("PLUGIN selector is 1-D only (paper §4.4)")
            h = plugin_bandwidth(sample, backend=backend).h
            return cls(x=sample, h=h, n_source=n_source, selector=selector)
        if selector == "silverman":
            return cls(x=sample, h=silverman_h(sample), n_source=n_source, selector=selector)
        if selector == "lscv_h":
            res = lscv_h(sample, backend=backend)
            return cls(x=sample, h=res.h, n_source=n_source, selector=selector)
        if selector == "lscv_H":
            res = lscv_H(sample if sample.ndim == 2 else sample[:, None])
            return cls(x=sample, H=res.H, n_source=n_source, selector=selector)
        raise ValueError(f"unknown selector {selector!r}")

    # --- queries ----------------------------------------------------------
    def _scale(self) -> float:
        """Scale factor from retained sample to the full relation."""
        return self.n_source / self.x.shape[0]

    def count(self, a: float, b: float) -> jax.Array:
        if self.x.ndim == 1:
            return self._scale() * count_1d(self.x, self.h, jnp.float32(a), jnp.float32(b))
        raise ValueError("use count_box for multi-d synopses")

    def sum(self, a: float, b: float) -> jax.Array:
        if self.x.ndim == 1:
            return self._scale() * sum_1d(self.x, self.h, jnp.float32(a), jnp.float32(b))
        raise ValueError("1-D only")

    def avg(self, a: float, b: float) -> jax.Array:
        return _avg_or_zero(self.count(a, b), self.sum(a, b))

    def count_box(self, lo, hi) -> jax.Array:
        lo = jnp.asarray(lo, jnp.float32)
        hi = jnp.asarray(hi, jnp.float32)
        if self.H is not None:
            return self._scale() * count_box_H(self.x, self.H, lo, hi)
        h_diag = jnp.full((self.x.shape[1],), self.h, jnp.float32)
        return self._scale() * count_box_diag(self.x, h_diag, lo, hi)

    def merge(self, other: "KDESynopsis", max_sample: int = 4096, seed: int = 0) -> "KDESynopsis":
        """Mergeable synopses (beyond paper): union the retained samples
        (subsample if needed) and refit the bandwidth on the merged sample."""
        merged = jnp.concatenate([self.x, other.x], axis=0)
        return KDESynopsis.fit(merged, selector=self.selector, max_sample=max_sample,
                               seed=seed)._replace_source(self.n_source + other.n_source)

    def _replace_source(self, n_source: int) -> "KDESynopsis":
        self.n_source = n_source
        return self

    def query_batch(self, queries: Sequence["Query"], backend: str = "jnp") -> np.ndarray:
        """Answer N COUNT/SUM/AVG range queries in one jitted pass."""
        return QueryBatch(queries).run(self, backend=backend)


# --- batched query engine -------------------------------------------------
#
# A production AQP front end amortises planning and kernel launches across
# thousands of concurrent queries (cf. Verdict's batch planner).  The closed
# forms of eqs. 9-10 share all their per-sample work — Phi/phi differences —
# so a whole heterogeneous batch against one synopsis reduces to ONE
# (queries x sample) two-channel reduction, then a per-query select.

OP_COUNT, OP_SUM, OP_AVG = 0, 1, 2
OP_CODES = {"count": OP_COUNT, "sum": OP_SUM, "avg": OP_AVG}

# COUNT below this is an empty selection for AVG purposes (see _avg_or_zero).
AVG_MIN_COUNT = 1e-3


def _avg_or_zero(counts, sums):
    """AVG = SUM / COUNT, defined as 0 for (effectively) empty selections:
    below the threshold the ratio is 0/0 noise amplified by 1/count.  Both the
    scalar and the batched path route through here so they agree exactly."""
    return jnp.where(counts > AVG_MIN_COUNT,
                     sums / jnp.maximum(counts, 1e-12), 0.0)


@dataclass(frozen=True)
class Query:
    """One aggregate range query: OP(column) WHERE a <= column <= b."""
    op: str                        # "count" | "sum" | "avg"
    a: float
    b: float
    column: Optional[str] = None   # None when run against a single synopsis

    def __post_init__(self):
        if self.op not in OP_CODES:
            raise ValueError(f"unknown op {self.op!r}; expected one of {sorted(OP_CODES)}")


def _batch_terms(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array):
    """vmapped closed forms: per-query unscaled (count_raw, sum_raw)."""
    def one(aq, bq):
        za = (aq - x) / h
        zb = (bq - x) / h
        d_Phi = _Phi(zb) - _Phi(za)
        cnt = jnp.sum(d_Phi)
        # same elementwise association as sum_1d so both paths agree tightly
        sm = jnp.sum(x * d_Phi - h * (_phi(zb) - _phi(za)))
        return cnt, sm
    return jax.vmap(one)(a, b)


@partial(jax.jit, static_argnames=("backend",))
def batch_query_1d(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array,
                   ops: jax.Array, scale: jax.Array,
                   backend: str = "jnp") -> jax.Array:
    """Answer a mixed batch against one 1-D synopsis in a single jitted call.

    x: (n,) retained sample; a/b/ops: (q,); scale: sample->relation factor.
    backend="pallas" routes the (queries x sample) reduction through the
    kernels/aqp_batch.py tile kernel.
    """
    if backend == "pallas":
        from repro.kernels import ops as kops
        cnt_raw, sum_raw = kops.aqp_batch_sums(x, h, a, b)
    else:
        cnt_raw, sum_raw = _batch_terms(x, h, a, b)
    counts = scale * cnt_raw
    sums = scale * sum_raw
    avgs = _avg_or_zero(counts, sums)
    return jnp.select([ops == OP_COUNT, ops == OP_SUM], [counts, sums], avgs)


@dataclass
class QueryBatch:
    """Planner for heterogeneous query batches.

    Groups queries by target column so each synopsis is answered in a single
    jitted pass, then scatters results back to submission order.
    """
    queries: Sequence[Query]
    _groups: Dict[Optional[str], List[int]] = field(init=False, repr=False)

    def __post_init__(self):
        self.queries = [q if isinstance(q, Query) else Query(*q) for q in self.queries]
        groups: Dict[Optional[str], List[int]] = {}
        for i, q in enumerate(self.queries):
            groups.setdefault(q.column, []).append(i)
        self._groups = groups

    def __len__(self) -> int:
        return len(self.queries)

    @property
    def columns(self) -> List[Optional[str]]:
        return list(self._groups)

    def plan(self, column: Optional[str]):
        """(indices, a, b, opcodes) device arrays for one column's group."""
        idx = self._groups[column]
        qs = [self.queries[i] for i in idx]
        a = jnp.asarray([q.a for q in qs], jnp.float32)
        b = jnp.asarray([q.b for q in qs], jnp.float32)
        ops_arr = jnp.asarray([OP_CODES[q.op] for q in qs], jnp.int32)
        return idx, a, b, ops_arr

    def run(self, synopses: Union[KDESynopsis, Mapping[str, KDESynopsis]],
            backend: str = "jnp") -> np.ndarray:
        """Answer every query; returns answers in submission order."""
        out = np.empty((len(self.queries),), np.float64)
        for column in self._groups:
            if isinstance(synopses, KDESynopsis):
                if column is not None:
                    raise ValueError("queries name columns but a single synopsis "
                                     "was given; pass a {column: synopsis} mapping")
                syn = synopses
            else:
                if column is None:
                    raise ValueError("queries must name a column when running "
                                     "against a synopsis mapping")
                if column not in synopses:
                    raise KeyError(f"no synopsis for column {column!r}; "
                                   f"have {sorted(synopses)}")
                syn = synopses[column]
            if syn.x.ndim != 1 or syn.h is None:
                raise ValueError("batched engine answers 1-D scalar-h synopses; "
                                 "use count_box for multi-d")
            idx, a, b, ops_arr = self.plan(column)
            scale = jnp.float32(syn.n_source / syn.x.shape[0])
            ans = batch_query_1d(syn.x, syn.h, a, b, ops_arr, scale,
                                 backend=backend)
            out[np.asarray(idx)] = np.asarray(ans, np.float64)
        return out
