"""PLUGIN bandwidth selector (paper §4.4, eqs. 12-19). 1-D only, as in the paper.

Pipeline:  Vhat -> sigma -> Psi8_NS -> g1 -> Psi6(g1) -> g2 -> Psi4(g2) -> h

The two O(n^2) stages (Psi6, Psi4) are pairwise derivative-kernel sums
(RR_fun, §5.4); everything else is O(1)/O(n) and stays scalar, exactly as the
paper's §6.1 notes ("steps 2,3,4,6,8 ... performed on CPU in negligible time").
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import gaussian as G
from .reductions import pairwise_reduce, reduce_sum


class PluginResult(NamedTuple):
    h: jax.Array
    sigma: jax.Array
    g1: jax.Array
    g2: jax.Array
    psi8: jax.Array
    psi6: jax.Array
    psi4: jax.Array


def variance_estimator(x: jax.Array) -> jax.Array:
    """eq. (12): unbiased variance via the two-sum form the paper parallelises."""
    n = x.shape[0]
    s2 = reduce_sum(x * x)
    s1 = reduce_sum(x)
    return s2 / (n - 1) - (s1 * s1) / (n * (n - 1))


def _psi_r(pair_sum: jax.Array, k_at_0: float, n: int, g: jax.Array, r: int) -> jax.Array:
    """Psi_r(g) = (2 * sum_{i<j} K^(r)(dx/g) + n K^(r)(0)) / (n^2 g^(r+1)).

    This is eqs. (16)/(18) with the diagonal written explicitly: the full
    double sum over (i,j) has n diagonal K^(r)(0) terms and twice the i<j sum.
    """
    return (2.0 * pair_sum + n * k_at_0) / (n * n * g ** (r + 1))


@partial(jax.jit, static_argnames=("chunk", "backend"))
def plugin_bandwidth(x: jax.Array, chunk: int = 512, backend: str = "jnp") -> PluginResult:
    """Compute the PLUGIN h for a 1-D sample (float32 in, paper uses fp32 too)."""
    if x.ndim != 1:
        raise ValueError("PLUGIN is defined for univariate data only (paper §4.4)")
    n = x.shape[0]

    if backend == "pallas":
        from repro.kernels import ops as kops
        rr_k6 = lambda g: kops.pairwise_scaled_ksum(x, g, kind="k6")
        rr_k4 = lambda g: kops.pairwise_scaled_ksum(x, g, kind="k4")
    else:
        rr_k6 = lambda g: pairwise_reduce(lambda dx: G.k6(dx / g), x, chunk=chunk)
        rr_k4 = lambda g: pairwise_reduce(lambda dx: G.k4(dx / g), x, chunk=chunk)

    # Steps 1-2 (eqs. 12-13)
    v = variance_estimator(x)
    sigma = jnp.sqrt(v)

    # Step 3 (eq. 14): Psi8 normal-scale estimate
    psi8 = 105.0 / (32.0 * math.sqrt(math.pi) * sigma ** 9)

    # Step 4 (eq. 15): g1 = (-2 K6(0) / (mu2 Psi8 n))^(1/9)
    g1 = (-2.0 * G.K6_AT_0 / (G.MU2_K * psi8 * n)) ** (1.0 / 9.0)

    # Step 5 (eq. 16): Psi6(g1) — O(n^2) pairwise sum of K^(6)
    psi6 = _psi_r(rr_k6(g1), G.K6_AT_0, n, g1, 6)

    # Step 6 (eq. 17): g2 = (-2 K4(0) / (mu2 Psi6 n))^(1/7)
    g2 = (-2.0 * G.K4_AT_0 / (G.MU2_K * psi6 * n)) ** (1.0 / 7.0)

    # Step 7 (eq. 18): Psi4(g2) — O(n^2) pairwise sum of K^(4)
    psi4 = _psi_r(rr_k4(g2), G.K4_AT_0, n, g2, 4)

    # Step 8 (eq. 19): final h
    h = (G.R_K_1D / (G.MU2_K ** 2 * psi4 * n)) ** 0.2
    return PluginResult(h=h, sigma=sigma, g1=g1, g2=g2, psi8=psi8, psi6=psi6, psi4=psi4)


def plugin_bandwidth_sequential(x) -> float:
    """Paper's 'Sequential implementation': faithful scalar python loops, float32.

    Used as the baseline in benchmarks (Fig. 8) and as an independent oracle in
    tests.  O(n^2) python-level work — keep n small.
    """
    import numpy as np

    x = np.asarray(x, dtype=np.float32)
    n = x.shape[0]
    s1 = np.float32(0.0)
    s2 = np.float32(0.0)
    for i in range(n):
        s1 += x[i]
        s2 += x[i] * x[i]
    v = s2 / np.float32(n - 1) - s1 * s1 / np.float32(n * (n - 1))
    sigma = np.sqrt(v)
    psi8 = np.float32(105.0 / (32.0 * math.sqrt(math.pi))) / sigma ** 9
    g1 = (np.float32(-2.0 * G.K6_AT_0) / (psi8 * n)) ** (1.0 / 9.0)

    inv_sqrt_2pi = np.float32(G.INV_SQRT_2PI)

    def k6s(t):
        t2 = t * t
        return (((t2 - 15.0) * t2 + 45.0) * t2 - 15.0) * inv_sqrt_2pi * np.exp(-0.5 * t2)

    def k4s(t):
        t2 = t * t
        return ((t2 - 6.0) * t2 + 3.0) * inv_sqrt_2pi * np.exp(-0.5 * t2)

    acc = np.float32(0.0)
    for i in range(n):
        for j in range(i + 1, n):
            acc += k6s((x[i] - x[j]) / g1)
    psi6 = (2.0 * acc + n * np.float32(G.K6_AT_0)) / (n * n * g1 ** 7)
    g2 = (np.float32(-2.0 * G.K4_AT_0) / (psi6 * n)) ** (1.0 / 7.0)
    acc = np.float32(0.0)
    for i in range(n):
        for j in range(i + 1, n):
            acc += k4s((x[i] - x[j]) / g2)
    psi4 = (2.0 * acc + n * np.float32(G.K4_AT_0)) / (n * n * g2 ** 5)
    h = (np.float32(G.R_K_1D) / (psi4 * n)) ** 0.2
    return float(h)
