"""LSCV bandwidth selectors (paper §4.4) with the §4.5 reformulation.

LSCV_h  — scalar bandwidth h, any d: brute-force minimisation of g(h) (eq. 24)
          on a 150-point grid over Z(h0) = [h0/4, 4*h0] (eq. 29), using either
          (a) the paper-faithful §4.5 two-phase scheme: precompute all
              S(v) = v^T Sigma^-1 v once, reuse for every h   [store_s=True]
          (b) a beyond-paper *streaming fused* scheme that never materialises
              S: each (chunk x n) slab of quadratic forms is folded into the
              per-h partial sums for the whole grid in one pass.  Same FLOPs as
              (a), O(chunk*n) memory instead of O(n^2)        [store_s=False]
LSCV_H  — full SPD bandwidth matrix: Nelder-Mead over a log-Cholesky
          parametrisation of H (guarantees SPD — the paper instead rejects
          non-SPD candidates inside NM; see DESIGN.md §2), objective g(H)
          (eq. 32) evaluated with the fused quadratic-form+T_H+reduce pass the
          paper describes for its GPU kernel in §6.3.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import gaussian as G
from .nelder_mead import minimize as nm_minimize
from .reductions import (pairwise_quadform_chunks, pairwise_quadform_reduce,
                         pairwise_sv_matrix)

N_H_DEFAULT = 150  # paper §7.1: objective evaluated at a fixed 150 grid points


# ---------------------------------------------------------------------------
# Covariance (eqs. 20-23), in the two-sum form the paper uses for reductions.
# ---------------------------------------------------------------------------

def covariance(x: jax.Array) -> jax.Array:
    """x: (n, d) row-per-sample (the paper stores samples in columns; we use
    rows, the JAX-native layout).  Returns (d, d) Sigma per eqs. (22)/(23)."""
    n = x.shape[0]
    s1 = jnp.sum(x, axis=0)                       # (d,)
    s2 = x.T @ x                                  # (d, d) sum of outer products
    return s2 / (n - 1) - jnp.outer(s1, s1) / (n * (n - 1))


def h0_start(n: int, d: int) -> float:
    """eq. (28).  Constants exactly as printed in the paper; for d=1 this
    reduces to Silverman's (4/3)^(1/5) n^(-1/5)."""
    rk_over_mu2 = 1.0 / (2.0 ** d * math.pi ** (d / 2.0) * d ** 2)
    r_f2 = d * (d + 2.0) / (2.0 ** (d + 2) * math.pi ** (d / 2.0))
    return float((rk_over_mu2 / (r_f2 * n)) ** (1.0 / (d + 4)))


def h_grid_for(n: int, d: int, n_h: int = N_H_DEFAULT) -> jax.Array:
    """Z(h0) = [h0/4, 4 h0] (eq. 29), n_h uniform points (paper §7.1)."""
    h0 = h0_start(n, d)
    return jnp.linspace(h0 / 4.0, 4.0 * h0, n_h, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# LSCV_h
# ---------------------------------------------------------------------------

class LSCVhResult(NamedTuple):
    h: jax.Array
    h_grid: jax.Array
    g_values: jax.Array
    sigma: jax.Array       # covariance matrix used by the Mahalanobis kernel
    det_sigma: jax.Array
    h0: jax.Array


def _t_sums_from_S(s_matrix: jax.Array, mask: jax.Array, h_grid: jax.Array,
                   c_k: jax.Array, c_kk: jax.Array, h_chunk: int = 8) -> jax.Array:
    """Paper-faithful phase 2 (§6.2): for each h on the grid, reduce
    T~(v) = (K~*K~)(v) - 2 K~(v) over the precomputed S values (eqs. 40-42)."""
    def per_h(h):
        e2 = jnp.exp(-0.5 * s_matrix / (h * h))
        e4 = jnp.exp(-0.25 * s_matrix / (h * h))
        return jnp.sum(jnp.where(mask, c_kk * e4 - 2.0 * c_k * e2, 0.0))

    return jax.lax.map(per_h, h_grid, batch_size=h_chunk)


def _t_sums_streaming(x: jax.Array, sigma_inv: jax.Array, h_grid: jax.Array,
                      c_k: jax.Array, c_kk: jax.Array, chunk: int = 128,
                      h_chunk: int = 8) -> jax.Array:
    """Beyond-paper fused grid: one pass over quadratic-form slabs accumulates
    sum_{i<j} T~ for every h simultaneously.  Memory O(chunk * n * h_chunk)."""
    scan_slabs = pairwise_quadform_chunks(x, sigma_inv, chunk)
    inv2 = 0.5 / (h_grid * h_grid)   # (n_h,)
    inv4 = 0.25 / (h_grid * h_grid)

    def consume(acc, s, mask):
        sm = jnp.where(mask, s, 0.0)
        w = mask.astype(s.dtype)

        def per_h_chunk(args):
            i2, i4 = args   # (hc,)
            e2 = jnp.exp(-sm[None, :, :] * i2[:, None, None])
            e4 = jnp.exp(-sm[None, :, :] * i4[:, None, None])
            return jnp.sum((c_kk * e4 - 2.0 * c_k * e2) * w[None, :, :], axis=(1, 2))

        n_h = h_grid.shape[0]
        pad = (-n_h) % h_chunk
        i2 = jnp.pad(inv2, (0, pad)).reshape(-1, h_chunk)
        i4 = jnp.pad(inv4, (0, pad)).reshape(-1, h_chunk)
        contrib = jax.lax.map(per_h_chunk, (i2, i4)).reshape(-1)[:n_h]
        return acc + contrib

    return scan_slabs(consume, jnp.zeros((h_grid.shape[0],), x.dtype))


@partial(jax.jit, static_argnames=("n_h", "store_s", "chunk", "backend"))
def lscv_h(x: jax.Array, n_h: int = N_H_DEFAULT, store_s: bool = False,
           chunk: int = 128, backend: str = "jnp") -> LSCVhResult:
    """Full LSCV_h algorithm (paper §6.2 steps 1-7). x: (n, d)."""
    if x.ndim == 1:
        x = x[:, None]
    n, d = x.shape

    # Steps 1-3: covariance, det, inverse (sequential scalar work in the paper).
    sigma = covariance(x)
    det_sigma = jnp.linalg.det(sigma)
    sigma_inv = jnp.linalg.inv(sigma)

    # Steps 4-5: h0 and search range (eqs. 28-29).
    h0 = jnp.asarray(h0_start(n, d), x.dtype)
    h_grid = h_grid_for(n, d, n_h).astype(x.dtype)

    c_k, c_kk, r_k = G.lscv_h_consts(d, det_sigma)

    # Steps 6-7: S(v) precompute + grid search (paper), or fused streaming.
    if backend == "pallas":
        from repro.kernels import ops as kops
        t_sums = kops.lscv_grid_sums(x, sigma_inv, h_grid, c_k, c_kk)
    elif store_s:
        s_matrix = pairwise_sv_matrix(x, sigma_inv, chunk)
        rows = jnp.arange(n)
        mask = rows[:, None] < rows[None, :]
        t_sums = _t_sums_from_S(s_matrix, mask, h_grid, c_k, c_kk)
    else:
        t_sums = _t_sums_streaming(x, sigma_inv, h_grid, c_k, c_kk, chunk)

    g_values = h_grid ** (-d) * (2.0 / (n * n) * t_sums + r_k / n)   # eq. (43)
    best = jnp.argmin(g_values)
    return LSCVhResult(h=h_grid[best], h_grid=h_grid, g_values=g_values,
                       sigma=sigma, det_sigma=det_sigma, h0=h0)


def g_of_h_sequential(x, h) -> float:
    """Unmodified eq. (24) evaluated naively in float64 numpy — the oracle for
    validating the §4.5 reformulation (recomputes the exponent for every pair
    at every h, i.e. the O(n_h n^2 d^2) path)."""
    import numpy as np

    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    n, d = x.shape
    sigma = np.cov(x, rowvar=False, ddof=1).reshape(d, d)
    det = np.linalg.det(sigma)
    inv = np.linalg.inv(sigma)
    c_k = (2 * math.pi) ** (-d / 2) * det ** -0.5
    c_kk = (4 * math.pi) ** (-d / 2) * det ** -0.5
    acc = 0.0
    for i in range(n):
        for j in range(i + 1, n):
            u = (x[i] - x[j]) / h
            s = float(u @ inv @ u)
            acc += c_kk * math.exp(-0.25 * s) - 2.0 * c_k * math.exp(-0.5 * s)
    return float(h ** (-d) * (2.0 / (n * n) * acc + c_kk / n))


# ---------------------------------------------------------------------------
# LSCV_H
# ---------------------------------------------------------------------------

class LSCVHResult(NamedTuple):
    H: jax.Array
    g: jax.Array
    H_start: jax.Array
    it: jax.Array
    nfev: jax.Array


def g_of_H(x: jax.Array, H: jax.Array, chunk: int = 128, backend: str = "jnp") -> jax.Array:
    """Objective g(H) (eq. 32), evaluated with the fused pass of §6.3."""
    if x.ndim == 1:
        x = x[:, None]
    n, d = x.shape
    det_H = jnp.linalg.det(H)
    H_inv = jnp.linalg.inv(H)
    c_k, c_kk, r_k = G.lscv_H_consts(d, det_H)

    if backend == "pallas":
        from repro.kernels import ops as kops
        t_sum = kops.gh_fused_sum(x, H_inv, c_k, c_kk)
    else:
        fun1 = lambda s: c_kk * jnp.exp(-0.25 * s) - 2.0 * c_k * jnp.exp(-0.5 * s)
        t_sum = pairwise_quadform_reduce(fun1, x, H_inv, chunk)
    return 2.0 / (n * n) * t_sum + r_k / n


def matrix_sqrt(a: jax.Array) -> jax.Array:
    """SPD matrix square root via eigendecomposition (paper uses ALGLIB)."""
    w, v = jnp.linalg.eigh(a)
    return (v * jnp.sqrt(jnp.clip(w, 0.0))) @ v.T


def h_start(x: jax.Array) -> jax.Array:
    """eq. (37): H_start = (4/(d+2))^(1/(d+4)) n^(-1/(d+4)) Sigma^(1/2)."""
    n, d = x.shape
    sigma = covariance(x)
    return (4.0 / (d + 2.0)) ** (1.0 / (d + 4)) * n ** (-1.0 / (d + 4)) * matrix_sqrt(sigma)


def _vech_indices(d: int):
    return jnp.tril_indices(d)


def _theta_to_H(theta: jax.Array, d: int) -> jax.Array:
    """log-Cholesky: theta packs L's lower triangle, diagonal stored as log."""
    il, jl = _vech_indices(d)
    L = jnp.zeros((d, d), theta.dtype).at[il, jl].set(theta)
    L = L.at[jnp.diag_indices(d)].set(jnp.exp(jnp.diagonal(L)))
    return L @ L.T


def _H_to_theta(H: jax.Array) -> jax.Array:
    L = jnp.linalg.cholesky(H)
    L = L.at[jnp.diag_indices(H.shape[0])].set(jnp.log(jnp.diagonal(L)))
    il, jl = _vech_indices(H.shape[0])
    return L[il, jl]


@partial(jax.jit, static_argnames=("max_iter", "chunk", "backend", "multi_start"))
def lscv_H(x: jax.Array, max_iter: int = 150, chunk: int = 128,
           backend: str = "jnp", multi_start: int = 1) -> LSCVHResult:
    """Full LSCV_H: Nelder-Mead over log-Cholesky(vech) of H (d(d+1)/2 dof).

    multi_start > 1 runs that many independent Nelder-Mead instances from
    perturbed H_start points *in parallel* (vmap) and keeps the best — the
    exact parallelisation the paper proposes for this inherently sequential
    optimiser in §6.3 ("start multiple parallel instances ... each from a
    different starting point"); on TPU the instances batch over the MXU.
    """
    if x.ndim == 1:
        x = x[:, None]
    n, d = x.shape
    H0 = h_start(x)
    theta0 = _H_to_theta(H0)

    def objective(theta):
        return g_of_H(x, _theta_to_H(theta, d), chunk=chunk, backend=backend)

    if multi_start == 1:
        res = nm_minimize(objective, theta0, max_iter=max_iter)
        H = _theta_to_H(res.x, d)
        return LSCVHResult(H=H, g=res.fun, H_start=H0, it=res.it, nfev=res.nfev)

    keys = jax.random.split(jax.random.key(0), multi_start - 1)
    jitter = jax.vmap(lambda k: 0.25 * jax.random.normal(k, theta0.shape))(keys)
    starts = jnp.concatenate([theta0[None], theta0[None] + jitter], axis=0)
    runs = jax.vmap(lambda t: nm_minimize(objective, t, max_iter=max_iter))(starts)
    best = jnp.argmin(runs.fun)
    H = _theta_to_H(runs.x[best], d)
    return LSCVHResult(H=H, g=runs.fun[best], H_start=H0,
                       it=runs.it[best], nfev=jnp.sum(runs.nfev))
