"""JIT-able Nelder-Mead simplex minimiser (paper §4.4 LSCV_H, ref. [27]).

The paper uses Nelder-Mead over vech(H) with rejection of non-positive-definite
candidates.  We keep the same simplex mechanics but expose them as a pure JAX
`lax.while_loop`, so the optimiser itself can be jitted/vmapped (e.g. the
multi-start parallelisation the paper suggests in §6.3: "start multiple
parallel instances ... each from a different starting point").
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class NMState(NamedTuple):
    simplex: jax.Array   # (k+1, k)
    values: jax.Array    # (k+1,)
    it: jax.Array
    nfev: jax.Array


class NMResult(NamedTuple):
    x: jax.Array
    fun: jax.Array
    it: jax.Array
    nfev: jax.Array


def _sorted(state: NMState) -> NMState:
    order = jnp.argsort(state.values)
    return state._replace(simplex=state.simplex[order], values=state.values[order])


@partial(jax.jit, static_argnames=("fun", "max_iter"))
def minimize(fun: Callable, x0: jax.Array, *, init_scale: float = 0.1,
             max_iter: int = 200, xtol: float = 1e-6, ftol: float = 1e-9) -> NMResult:
    """Minimise `fun: R^k -> R` starting at x0.  Standard NM coefficients
    (alpha=1, gamma=2, rho=0.5, sigma=0.5)."""
    k = x0.shape[0]
    # Initial simplex: x0 plus per-axis perturbations (scaled to |x0| where nonzero).
    steps = jnp.where(jnp.abs(x0) > 1e-8, init_scale * jnp.abs(x0), init_scale)
    simplex = jnp.concatenate([x0[None, :], x0[None, :] + jnp.diag(steps)], axis=0)
    values = jax.vmap(fun)(simplex)
    state = _sorted(NMState(simplex, values, jnp.zeros((), jnp.int32), jnp.asarray(k + 1, jnp.int32)))

    def not_done(s: NMState):
        spread_f = s.values[-1] - s.values[0]
        spread_x = jnp.max(jnp.abs(s.simplex - s.simplex[0]))
        return (s.it < max_iter) & ((spread_f > ftol) | (spread_x > xtol))

    def step(s: NMState) -> NMState:
        best, worst = s.values[0], s.values[-1]
        second_worst = s.values[-2]
        centroid = jnp.mean(s.simplex[:-1], axis=0)

        xr = centroid + (centroid - s.simplex[-1])           # reflection
        fr = fun(xr)
        xe = centroid + 2.0 * (centroid - s.simplex[-1])     # expansion
        fe = fun(xe)
        xc = centroid + 0.5 * (s.simplex[-1] - centroid)     # contraction
        fc = fun(xc)

        # Decide replacement for the worst vertex (no-shrink path).
        use_exp = (fr < best) & (fe < fr)
        use_ref = (fr < second_worst) & ~use_exp
        use_con = (fc < worst) & ~use_exp & ~use_ref
        new_pt = jnp.where(use_exp, xe, jnp.where(use_ref, xr, xc))
        new_val = jnp.where(use_exp, fe, jnp.where(use_ref, fr, fc))
        accepted = use_exp | use_ref | use_con

        # Shrink path (when even contraction fails).
        shrunk = s.simplex[0][None, :] + 0.5 * (s.simplex - s.simplex[0][None, :])
        shrunk_vals = jax.vmap(fun)(shrunk)

        simplex = jnp.where(accepted,
                            s.simplex.at[-1].set(new_pt),
                            shrunk)
        values = jnp.where(accepted,
                           s.values.at[-1].set(new_val),
                           shrunk_vals)
        nfev = s.nfev + jnp.where(accepted, 3, 3 + k + 1)
        return _sorted(NMState(simplex, values, s.it + 1, nfev))

    state = jax.lax.while_loop(not_done, step, state)
    return NMResult(x=state.simplex[0], fun=state.values[0], it=state.it, nfev=state.nfev)
