"""Kernel density estimation (paper §4.2, eqs. 3-8).

`kde_eval`   — scalar-h estimator f^(x, h) (eq. 3), any d.
`kde_eval_H` — full-matrix estimator f^(x, H) (eq. 6).

Both are the O(m*n) "direct evaluation" the paper discusses in §2.2; the
binned/FFT accelerations from the related-work section live in binned.py.
The evaluation loop is chunked over evaluation points so memory stays
O(chunk * n); the TPU hot-spot kernel is kernels/kde_eval.py.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def _chunked_eval(points: jax.Array, x: jax.Array, kfun, chunk: int):
    """mean over data of kfun(p - x), scanned over eval chunks."""
    m = points.shape[0]
    c = min(chunk, m)
    pad = (-m) % c
    pp = jnp.pad(points, ((0, pad), (0, 0)))

    def body(_, p_chunk):
        diff = p_chunk[:, None, :] - x[None, :, :]         # (c, n, d)
        return None, jnp.mean(kfun(diff), axis=1)

    _, vals = jax.lax.scan(body, None, pp.reshape(-1, c, points.shape[1]))
    return vals.reshape(-1)[:m]


# --- product-kernel profiles (paper §4.2: "Other commonly used kernel
# functions are Epanechnikov, uniform, triangular, biweight") -------------
# Each maps |u| <= ... per-dimension; constants normalise to integrate to 1.

def _profiles(kind: str, d: int, h):
    if kind == "gaussian":
        ln = -d / 2.0 * math.log(2.0 * math.pi) - d * jnp.log(h)
        return lambda diff: jnp.exp(ln - 0.5 * jnp.sum((diff / h) ** 2, axis=-1))
    per_dim = {
        "epanechnikov": (0.75, lambda u: jnp.maximum(1.0 - u * u, 0.0)),
        "biweight": (15.0 / 16.0, lambda u: jnp.maximum(1.0 - u * u, 0.0) ** 2),
        "triangular": (1.0, lambda u: jnp.maximum(1.0 - jnp.abs(u), 0.0)),
        "uniform": (0.5, lambda u: (jnp.abs(u) <= 1.0).astype(jnp.float32)),
    }[kind]
    cst, prof = per_dim

    def kfun(diff):
        u = diff / h
        return jnp.prod(cst * prof(u), axis=-1) / h ** d

    return kfun


@partial(jax.jit, static_argnames=("chunk", "backend", "kind"))
def kde_eval(points: jax.Array, x: jax.Array, h: jax.Array, chunk: int = 256,
             backend: str = "jnp", kind: str = "gaussian") -> jax.Array:
    """f^(points; x, h) per eq. (3).  kind selects the kernel function —
    Gaussian (eq. 5, default) or the compact-support kernels the paper lists
    in §4.2 (Epanechnikov / biweight / triangular / uniform, product form).

    points: (m, d) or (m,); x: (n, d) or (n,); returns (m,).
    """
    if x.ndim == 1:
        x = x[:, None]
    if points.ndim == 1:
        points = points[:, None]
    n, d = x.shape

    if backend == "pallas":
        from repro.kernels import ops as kops
        assert kind == "gaussian", "pallas kde kernel implements the Gaussian"
        return kops.kde_eval(points, x, h)

    return _chunked_eval(points, x, _profiles(kind, d, h), chunk)


@partial(jax.jit, static_argnames=("chunk",))
def _kde_eval_H(points: jax.Array, x: jax.Array, H: jax.Array, chunk: int) -> jax.Array:
    if x.ndim == 1:
        x = x[:, None]
    if points.ndim == 1:
        points = points[:, None]
    n, d = x.shape
    H_inv = jnp.linalg.inv(H)
    _, logdet = jnp.linalg.slogdet(H)
    log_norm = -d / 2.0 * math.log(2.0 * math.pi) - 0.5 * logdet

    def kfun(diff):
        quad = 0.5 * jnp.einsum("cnd,de,cne->cn", diff, H_inv, diff)
        return jnp.exp(log_norm - quad)

    return _chunked_eval(points, x, kfun, chunk)


def kde_eval_H(points: jax.Array, x: jax.Array, H: jax.Array,
               chunk: int | None = None) -> jax.Array:
    """f^(points; x, H) per eq. (6): n^-1 |H|^-1/2 sum K(H^-1/2 (x - X_i)).

    chunk=None reads REPRO_KDE_CHUNK (default 256) per call — the env var
    must be resolved outside the jit, so this wrapper stays un-jitted and
    delegates to the jitted body (jit-of-jit inlines, so callers that trace
    this inside their own jit compile identically).
    """
    if chunk is None:
        from repro.kernels.tuning import env_int
        chunk = env_int("REPRO_KDE_CHUNK", 256)
    return _kde_eval_H(points, x, H, chunk)


def silverman_h(x: jax.Array) -> jax.Array:
    """Rule-of-thumb bandwidth (paper §2.3 'first class' selector), 1-D."""
    n = x.shape[0]
    std = jnp.std(x, ddof=1)
    iqr = jnp.percentile(x, 75) - jnp.percentile(x, 25)
    a = jnp.minimum(std, iqr / 1.349)
    return 0.9 * a * n ** (-0.2)
