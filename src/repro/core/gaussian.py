"""Gaussian kernel functions and derivative kernels used by the bandwidth selectors.

All formulas follow the paper's §4 numbering:
  - K (eq. 5): standard d-dim Gaussian kernel
  - K^(4), K^(6): 4th/6th derivative kernels used by PLUGIN (eqs. 16, 18)
  - Sigma-shaped kernels K / (K*K) used by LSCV_h (eqs. 26, 27)
  - H-shaped kernels K_H / (K*K)_H used by LSCV_H (eqs. 34, 35)
"""
from __future__ import annotations

import math

import jax.numpy as jnp

INV_SQRT_2PI = 1.0 / math.sqrt(2.0 * math.pi)

# Constants from the paper (eqs. 14-19).
K4_AT_0 = 3.0 * INV_SQRT_2PI           # K^(4)(0) = 3/sqrt(2*pi)
K6_AT_0 = -15.0 * INV_SQRT_2PI         # K^(6)(0) = -15/sqrt(2*pi)
R_K_1D = 1.0 / (2.0 * math.sqrt(math.pi))  # R(K) for 1-D Gaussian (eq. 19)
MU2_K = 1.0                            # second moment of the Gaussian kernel


def phi(x):
    """Standard normal density."""
    return INV_SQRT_2PI * jnp.exp(-0.5 * x * x)


def k4(x):
    """K^(4)(x) = (x^4 - 6x^2 + 3) phi(x)  (eq. 18)."""
    x2 = x * x
    return ((x2 - 6.0) * x2 + 3.0) * phi(x)


def k6(x):
    """K^(6)(x) = (x^6 - 15x^4 + 45x^2 - 15) phi(x)  (eq. 16)."""
    x2 = x * x
    return (((x2 - 15.0) * x2 + 45.0) * x2 - 15.0) * phi(x)


def gauss_kernel_1d(u):
    """K(u) for d=1 (eq. 5)."""
    return phi(u)


def lscv_h_consts(d: int, det_sigma):
    """Normalisation constants of the Sigma-shaped kernels (eqs. 26, 27).

    Returns (c_K, c_KK, r_K) with
      K(u)      = c_K  * exp(-1/2 u^T Sigma^-1 u)
      (K*K)(u)  = c_KK * exp(-1/4 u^T Sigma^-1 u)
      R(K)      = (K*K)(0) = c_KK
    """
    det_root = det_sigma ** -0.5
    c_k = (2.0 * math.pi) ** (-d / 2.0) * det_root
    c_kk = (4.0 * math.pi) ** (-d / 2.0) * det_root
    return c_k, c_kk, c_kk


def lscv_H_consts(d: int, det_H):
    """Normalisation constants of the H-shaped kernels (eqs. 34-36)."""
    det_root = det_H ** -0.5
    c_k = (2.0 * math.pi) ** (-d / 2.0) * det_root
    c_kk = (4.0 * math.pi) ** (-d / 2.0) * det_root
    r_k = 2.0 ** (-d) * math.pi ** (-d / 2.0) * det_root  # eq. 36 == c_kk
    return c_k, c_kk, r_k
