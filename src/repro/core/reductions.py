"""Parallel reduction building blocks (paper §5.2-5.4), TPU-idiomatic.

The paper defines four primitives:

  R(A)          = sum_i A_i                               (§5.2)
  R_fun(A)      = sum_i fun(A_i)                          (§5.3)
  RR_fun(A)     = sum_{i<j} fun(A_i - A_j)                (§5.4)
  RR^v_fun(A)   = sum_{i<j} fun1(fun2(A_:,i - A_:,j))     (§5.5)

On CUDA these are staged through shared memory in k x k tiles; on TPU the same
blocking is expressed either as a Pallas kernel (see repro.kernels) or — for the
pure-JAX reference path used below — as a `lax.scan` over row *chunks* so the
live working set stays O(chunk * n) instead of O(n^2).  XLA's `reduce` is already
a tree reduction, which matches the paper's pairwise-accuracy argument ([17]):
the O(log n) error constant comes for free.  A Kahan-compensated variant is
provided for the accuracy discussion in EXPERIMENTS.md §Paper-validation.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


def reduce_sum(a: jax.Array) -> jax.Array:
    """R(A) (§5.2).  XLA lowers this to a tree reduction."""
    return jnp.sum(a)


def kahan_sum(a: jax.Array) -> jax.Array:
    """Kahan-compensated sequential sum — O(1) error constant ([22] in paper).

    Used only as an accuracy oracle in tests/benchmarks; it serialises.
    """
    def body(carry, x):
        s, c = carry
        y = x - c
        t = s + y
        c = (t - s) - y
        return (t, c), None

    (s, _), _ = jax.lax.scan(body, (jnp.zeros((), a.dtype), jnp.zeros((), a.dtype)), a.reshape(-1))
    return s


def map_reduce(fun: Callable, a: jax.Array, chunk: int = 65536) -> jax.Array:
    """R_fun(A) (§5.3): sum_i fun(A_i), computed on-the-fly without storing fun(A).

    `a` is 1-D.  Chunked so fun values never materialise beyond `chunk` elements
    (the paper's "compute and add on the fly" modification of the reduction).
    """
    n = a.shape[0]
    c = min(chunk, n)
    pad = (-n) % c
    ap = jnp.pad(a, (0, pad))
    valid = jnp.arange(ap.shape[0]) < n
    ap = ap.reshape(-1, c)
    valid = valid.reshape(-1, c)

    def body(acc, xv):
        x, v = xv
        return acc + jnp.sum(jnp.where(v, fun(x), 0.0)), None

    acc0 = jnp.zeros((), ap.dtype)
    acc, _ = jax.lax.scan(body, acc0, (ap, valid))
    return acc


def _row_chunks(n: int, chunk: int) -> int:
    return -(-n // chunk)


def pairwise_reduce(fun: Callable, x: jax.Array, chunk: int = 256) -> jax.Array:
    """RR_fun(A) (§5.4): sum_{i<j} fun(x_i - x_j) for 1-D x.

    TPU adaptation of the paper's triangular tiling (Fig. 3): we scan over row
    chunks of size `chunk`; each step materialises a (chunk, n) difference slab
    (the analogue of one tile *row stripe*), applies `fun` elementwise on the
    VPU, masks the lower triangle + diagonal + padding, and accumulates.  The
    dedicated Pallas kernel (kernels/pairwise_reduce.py) blocks both sides.
    """
    n = x.shape[0]
    c = min(chunk, n)
    pad = (-n) % c
    xp = jnp.pad(x, (0, pad))
    nrows = xp.shape[0] // c
    cols = jnp.arange(xp.shape[0])

    def body(acc, r):
        row_idx = r * c + jnp.arange(c)                       # global row ids
        rows = jax.lax.dynamic_slice_in_dim(xp, r * c, c)
        diff = rows[:, None] - xp[None, :]                    # (c, n_pad)
        vals = fun(diff)
        mask = (row_idx[:, None] < cols[None, :]) & (cols[None, :] < n) & (row_idx[:, None] < n)
        return acc + jnp.sum(jnp.where(mask, vals, 0.0)), None

    acc, _ = jax.lax.scan(body, jnp.zeros((), x.dtype), jnp.arange(nrows))
    return acc


def pairwise_quadform_chunks(x: jax.Array, m: jax.Array, chunk: int = 128):
    """Yields the S(v) slabs of RR^v_fun (§5.5): S_{ij} = (x_i-x_j)^T M (x_i-x_j).

    Returns a function `scan_slabs(consume, init)` that scans over row chunks;
    `consume(acc, s_slab, mask)` folds each masked (chunk, n) slab of quadratic
    forms into the accumulator.  This is the streaming backbone shared by the
    paper-faithful store-S path, the fused LSCV_h grid path and the LSCV_H
    objective (where M = H^-1 changes per evaluation, §6.3).
    """
    n, d = x.shape
    c = min(chunk, n)
    pad = (-n) % c
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    nrows = xp.shape[0] // c
    cols = jnp.arange(xp.shape[0])

    def scan_slabs(consume: Callable, init):
        def body(acc, r):
            row_idx = r * c + jnp.arange(c)
            rows = jax.lax.dynamic_slice_in_dim(xp, r * c, c)
            v = rows[:, None, :] - xp[None, :, :]             # (c, n_pad, d)
            s = jnp.einsum("rnd,de,rne->rn", v, m, v)         # quadratic forms
            mask = (row_idx[:, None] < cols[None, :]) & (cols[None, :] < n) & (row_idx[:, None] < n)
            return consume(acc, s, mask), None

        acc, _ = jax.lax.scan(body, init, jnp.arange(nrows))
        return acc

    return scan_slabs


def pairwise_quadform_reduce(fun1: Callable, x: jax.Array, m: jax.Array, chunk: int = 128) -> jax.Array:
    """RR^v_fun (§5.5 + §5.3 fused, as in the paper's LSCV_H GPU kernel §6.3):

        sum_{i<j} fun1( (x_i-x_j)^T M (x_i-x_j) )

    computed in one pass without materialising the S matrix.
    """
    scan_slabs = pairwise_quadform_chunks(x, m, chunk)

    def consume(acc, s, mask):
        return acc + jnp.sum(jnp.where(mask, fun1(s), 0.0))

    return scan_slabs(consume, jnp.zeros((), x.dtype))


def pairwise_sv_matrix(x: jax.Array, m: jax.Array, chunk: int = 128) -> jax.Array:
    """Paper-faithful §4.5 precompute: dense (n, n) matrix of S(v) values with the
    lower triangle + diagonal zeroed.  (The paper packs the upper triangle into a
    flat buffer; on TPU a dense masked matrix keeps layouts trivial and costs 2x
    memory — acceptable because the *stored-S* path is only used at paper scale,
    n <= 8192.  The streaming path above has no such limit.)
    """
    n = x.shape[0]
    scan_slabs = pairwise_quadform_chunks(x, m, chunk)
    c = min(chunk, n)
    pad = (-n) % c

    def consume(rows_acc, s, mask):
        out, r = rows_acc
        out = jax.lax.dynamic_update_slice_in_dim(out, jnp.where(mask, s, 0.0), r * c, axis=0)
        return (out, r + 1)

    out0 = jnp.zeros((n + pad, n + pad), x.dtype)
    out, _ = scan_slabs(consume, (out0, 0))
    return out[:n, :n]
