"""Async admission & micro-batch scheduling for the AQP QueryEngine.

The paper's economics only work when the expensive density work is amortized
across many cheap queries (DEANN makes the same argument for KDE-ANN): one
jitted pass answers a thousand range queries for barely more than one.  The
synchronous `QueryEngine.execute` amortizes *within* one call, but concurrent
callers each pay their own planning, dispatch, and Phi pass.  This module
adds the layer the ROADMAP names: callers submit `AqpQuery` specs and get
futures back, while the session coalesces pending specs *across callers* into
micro-batches and flushes them through the engine's planning/execution core.

  `AdmissionQueue` — pure bookkeeping: pending entries bucketed by
                     (column tuple, selector, tier, synopsis version),
                     per-bucket oldest-submit timestamps, queue-depth
                     accounting.  No locking, no execution — the session
                     owns both.
  `AqpSession`     — the long-lived, thread-safe admission surface:

      session = store.session(watermark=32, max_delay=0.005)
      fut = session.submit(AqpQuery("count", (Range("loss", 1, 4),)))
      fut.result()          # AqpResult (list of them for GROUP BY specs)

Priority classes: a submission's `priority` maps to a tier budget over the
store's `TieredReservoir`s ("coarse" -> tier 0, "full" -> the whole sample
by default; configurable via `priority_tiers`).  The tier rides in the
bucket key, so a fast-coarse ticket never queues behind — or coalesces
into — a full-reservoir pass: it flushes on its own small-sample plan and
reports wider confidence intervals, trading accuracy for latency exactly
the way the paper frames AQP.  Columns without tiered reservoirs ignore
the budget (the tier normalizes to the full sample).

A bucket flushes when it reaches `watermark` pending queries (inline, on the
submitting thread), when its oldest entry ages past `max_delay` (a background
flusher thread, or an explicit `poll()` for single-threaded drivers), on
`flush()` (reason "manual"), and on `close()` (reason "close").  Flushes
execute through `QueryEngine.run_compiled` — the same compile/_execute
machinery as the synchronous path, so admission answers are bit-identical to
`execute()` for the same specs (test-enforced).

Backpressure: the pending set is bounded by `max_pending` (ROADMAP
follow-up; unbounded by default for drop-in compatibility).  At the bound
the `overflow` policy either parks the submitting thread until a flush
frees room ("block") or raises `AdmissionFull` ("shed"); both outcomes are
counted in `stats()` and aggregated into `store.stats()["admission"]`.

Version invalidation: the session subscribes to the store's version-change
notifications; when `add_batch` bumps a reservoir, pending buckets keyed to
the stale version are re-keyed to the new one (counted in
`stats()["invalidations"]`), so a flush never mixes synopsis versions and
results always carry the version that actually answered them.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
import weakref
from collections import OrderedDict
from concurrent.futures import Future
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro import obs

from .aqp_query import AqpQuery, AqpResult, QueryEngine, _Compiled

FLUSH_WATERMARK = "watermark"
FLUSH_DEADLINE = "deadline"
FLUSH_MANUAL = "manual"
FLUSH_CLOSE = "close"
FLUSH_FIT = "fit"        # re-flush after an offloaded synopsis fit lands

# Selectors whose first fit is superlinear (the paper's O(n^2) LSCV passes):
# with `fit_offload=True` a bucket needing one of these fits hands the fit to
# a worker thread instead of stalling the flusher (canonical names — see
# `canonical_selector`; the scalar/full-matrix LSCV pair stays distinct).
SLOW_SELECTORS = frozenset({"lscv_h", "lscv_H"})

# priority class -> tier budget: "coarse" answers from the smallest tier of
# a TieredReservoir, "full" from the whole sample (None = no budget)
DEFAULT_PRIORITY_TIERS: Dict[str, Optional[int]] = {"full": None, "coarse": 0}

# Session ids label each session's registry counters.  The pid component
# keeps ids distinct across serving restarts: a restored checkpoint carries
# the previous process's counters, and a new session reusing an old label
# would silently resume (inflate) the dead session's totals.
_SESSION_IDS = itertools.count(1)


def _new_session_id() -> str:
    return f"{os.getpid():x}.{next(_SESSION_IDS)}"


class AdmissionFull(RuntimeError):
    """submit() refused: the session is at `max_pending` and its overflow
    policy is "shed".  The caller should retry later or back off."""


class _Ticket:
    """One submission: a future plus the scatter state for its compiled parts
    (GROUP BY specs expand to one part per category)."""

    __slots__ = ("future", "parts", "remaining", "single", "failed")

    def __init__(self, n_parts: int, single: bool):
        self.future: Future = Future()
        self.parts: List[Optional[AqpResult]] = [None] * n_parts
        self.remaining = n_parts
        self.single = single
        self.failed = False


class _Pending:
    """One compiled execution unit awaiting flush.  `ctx` carries the submit
    span's (trace_id, span_id) across the submit->flusher thread hop so the
    flush span can parent onto it (None when tracing is disabled)."""

    __slots__ = ("compiled", "ticket", "part", "submitted_at", "ctx")

    def __init__(self, compiled: _Compiled, ticket: _Ticket, part: int,
                 submitted_at: float, ctx: Optional[Tuple[int, int]] = None):
        self.compiled = compiled
        self.ticket = ticket
        self.part = part
        self.submitted_at = submitted_at
        self.ctx = ctx


# (column-or-tuple, selector, tier-or-None, version)
BucketKey = Tuple[object, str, Optional[int], int]


class AdmissionQueue:
    """Pending micro-batches keyed by (column tuple, selector, tier,
    synopsis version).  Pure data structure — the owning session serializes
    access."""

    def __init__(self):
        self.buckets: "OrderedDict[BucketKey, List[_Pending]]" = OrderedDict()
        self.depth = 0

    def add(self, key: BucketKey, pending: _Pending) -> int:
        bucket = self.buckets.setdefault(key, [])
        bucket.append(pending)
        self.depth += 1
        return len(bucket)

    def pop(self, key: BucketKey) -> List[_Pending]:
        bucket = self.buckets.pop(key, [])
        self.depth -= len(bucket)
        return bucket

    def pop_all(self) -> List[Tuple[BucketKey, List[_Pending]]]:
        out = list(self.buckets.items())
        self.buckets.clear()
        self.depth = 0
        return out

    def oldest(self, key: BucketKey) -> float:
        return self.buckets[key][0].submitted_at

    def first_due(self, now: float, max_delay: float,
                  skip: frozenset = frozenset()) -> Optional[BucketKey]:
        """The longest-waiting bucket whose deadline has passed, if any.
        Buckets in `skip` (fit-in-progress) are passed over — their deadline
        is deliberately on hold until the offloaded fit lands."""
        best = None
        best_ts = None
        for key, bucket in self.buckets.items():
            if key in skip:
                continue
            ts = bucket[0].submitted_at
            if now - ts >= max_delay and (best_ts is None or ts < best_ts):
                best, best_ts = key, ts
        return best

    def next_deadline(self, max_delay: float) -> Optional[float]:
        if not self.buckets:
            return None
        return min(b[0].submitted_at for b in self.buckets.values()) + max_delay

    def rekey(self, stale: BucketKey, fresh: BucketKey) -> int:
        """Move a stale-version bucket under the bumped version's key; the
        merged bucket keeps the earliest submit time first so deadlines hold."""
        moved = self.buckets.pop(stale, [])
        if not moved:
            return 0
        bucket = self.buckets.setdefault(fresh, [])
        bucket.extend(moved)
        bucket.sort(key=lambda p: p.submitted_at)
        return len(moved)


class AqpSession:
    """Streaming admission over a `QueryEngine` (see module docstring).

    watermark  — flush a bucket as soon as it holds this many pending queries
                 (None disables size-triggered flushes)
    max_delay  — seconds a pending query may wait before its bucket flushes
                 (None disables deadline flushes; with both disabled only
                 `flush()`/`close()` drain the queue)
    auto_flush — run the deadline flusher on a daemon thread; pass False for
                 single-threaded drivers and tests, and pump via `poll()`
    max_pending — bound on the pending queue depth (None: unbounded).  At
                 the bound, `overflow` decides: "block" parks the submitting
                 thread until a flush frees room (needs a flusher — the
                 auto_flush thread, watermark flushes from other submitters,
                 or an external poll()er); "shed" raises `AdmissionFull`
                 immediately so the caller can back off.  A single spec
                 whose compiled parts alone exceed the bound (a wide GROUP
                 BY) is admitted once the queue is empty rather than
                 deadlocking.  Both outcomes are counted in `stats()`.
    time_fn    — injectable clock (tests drive deadlines deterministically)
    priority_tiers — {class name: tier budget} (default: "full" -> None,
                 "coarse" -> 0); `submit(query, priority=...)` picks one
    default_priority — class used when submit() gets no explicit priority
    fit_offload — guard against slow first fits: when a due bucket's
                 selector is in `SLOW_SELECTORS` and its synopsis is not yet
                 in the store cache (an O(n^2) LSCV fit stands between the
                 flush and its answers), hand the fit to a worker thread and
                 leave the bucket queued (skipped by the deadline scan)
                 instead of stalling the flusher — other buckets keep
                 flushing on time.  The worker re-flushes the bucket with
                 reason "fit" once the synopsis lands; deferred queries are
                 counted in `stats()["fit_requeued"]`.
    """

    def __init__(self, engine: QueryEngine, watermark: Optional[int] = 32,
                 max_delay: Optional[float] = 0.005, auto_flush: bool = True,
                 selector: Optional[str] = None, backend: Optional[str] = None,
                 max_pending: Optional[int] = None, overflow: str = "block",
                 time_fn: Callable[[], float] = time.monotonic,
                 priority_tiers: Optional[Dict[str, Optional[int]]] = None,
                 default_priority: str = "full",
                 fit_offload: bool = False):
        if watermark is not None and watermark < 1:
            raise ValueError(f"watermark must be >= 1, got {watermark}")
        if max_delay is not None and max_delay < 0:
            raise ValueError(f"max_delay must be >= 0, got {max_delay}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if overflow not in ("block", "shed"):
            raise ValueError(f"overflow must be 'block' or 'shed', "
                             f"got {overflow!r}")
        self.priority_tiers = dict(priority_tiers
                                   if priority_tiers is not None
                                   else DEFAULT_PRIORITY_TIERS)
        if default_priority not in self.priority_tiers:
            raise ValueError(
                f"default_priority {default_priority!r} not in "
                f"priority_tiers {sorted(self.priority_tiers)}")
        self.default_priority = default_priority
        self.engine = engine
        self.watermark = watermark
        self.max_delay = max_delay
        self.max_pending = max_pending
        self.overflow = overflow
        self.selector = selector or engine.selector
        self.backend = backend or engine.backend
        self.time_fn = time_fn
        self.fit_offload = fit_offload
        self._auto_flush = auto_flush
        self._lock = threading.RLock()
        self._wakeup = threading.Condition(self._lock)
        self._queue = AdmissionQueue()      # guarded-by: _lock
        # BucketKeys with a fit in flight
        self._fitting: set = set()          # guarded-by: _lock
        self._closed = False                # guarded-by: _lock
        self._thread: Optional[threading.Thread] = None  # guarded-by: _lock
        store = engine.store
        # Counters live in the store's metrics registry, labelled with this
        # session's id — NOT on the session object.  The registry outlives
        # the session, so `store.stats()["admission"]` aggregates every
        # session ever opened (the old per-object counters vanished with
        # each garbage-collected session, silently dropping totals).  The
        # legacy attribute names (`session.submitted`, ...) remain as
        # read-only properties below.
        self.sid = _new_session_id()
        metrics = getattr(store, "metrics", None)
        if metrics is None:
            metrics = obs.MetricsRegistry()     # engine over a bare store
        self.metrics = metrics
        sid = self.sid
        self._c_submitted = metrics.counter("aqp.admission.submitted",
                                            session=sid)
        self._c_executed = metrics.counter("aqp.admission.executed",
                                           session=sid)
        self._c_flushes = metrics.counter("aqp.admission.flushes",
                                          session=sid)
        self._c_coalesced = metrics.counter("aqp.admission.coalesced",
                                            session=sid)
        self._c_invalidations = metrics.counter("aqp.admission.invalidations",
                                                session=sid)
        self._c_blocked = metrics.counter("aqp.admission.blocked",
                                          session=sid)
        self._c_shed = metrics.counter("aqp.admission.shed", session=sid)
        self._c_fit_requeued = metrics.counter("aqp.admission.fit_requeued",
                                               session=sid)
        self._c_batch_rows = metrics.counter("aqp.admission.batch_rows",
                                             session=sid)
        self._g_depth = metrics.gauge("aqp.admission.depth", session=sid)
        self._g_max_depth = metrics.gauge("aqp.admission.max_depth",
                                          session=sid)
        self._h_batch = metrics.histogram(
            "aqp.admission.batch_size",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024),
            session=sid)
        # A session abandoned without close() may hold pending entries its
        # flusher never drains (the thread exits when the weakref dies);
        # zero its depth gauge at collection so store-level `pending` does
        # not leak phantom queries forever.
        weakref.finalize(self, self._g_depth.set, 0.0)
        unsub = getattr(store, "subscribe", None)
        self._unsubscribe = None
        if unsub is not None:
            # subscribe through a weakref: a store outlives its sessions, and
            # a strong listener would pin every un-close()d session (and its
            # flusher thread) for the store's lifetime
            ref = weakref.ref(self)

            def _notify(bumped):
                session = ref()
                if session is None:
                    unsubscribe()          # self-clean once collected
                else:
                    session._on_versions(bumped)
            unsubscribe = unsub(_notify)
            self._unsubscribe = unsubscribe
        register = getattr(store, "_register_session", None)
        if register is not None:
            register(self)

    # -- client surface ------------------------------------------------------

    def submit(self, query: AqpQuery,
               priority: Optional[str] = None) -> Future:
        """Admit one spec; returns a future resolving to its `AqpResult`
        (a list of them for GROUP BY specs, in category order).  Compilation
        and synopsis-key resolution run synchronously, so malformed specs and
        unknown columns raise here, not inside the future.

        `priority` picks a class from `priority_tiers` (default
        `default_priority`): its tier budget keys the pending bucket, so
        coarse-tier tickets flush on small-sample plans without queueing
        behind full-accuracy passes."""
        name = self.default_priority if priority is None else priority
        if name not in self.priority_tiers:
            raise ValueError(f"unknown priority {name!r}; "
                             f"have {sorted(self.priority_tiers)}")
        tier = self.priority_tiers[name]
        # The submit span is the root of the query's trace; its ctx rides on
        # every _Pending so the flush (another thread) can parent onto it.
        with obs.span("admission.submit", aggregate=query.aggregate,
                      priority=name, session=self.sid) as sp:
            parts = self.engine.compile(query)
            resolver = self.engine.resolver(self.selector, tier=tier)
            keyed = []
            for c in parts:
                key3, c2, version = resolver.key_for(c)
                keyed.append((key3 + (version,), c2))
            ticket = _Ticket(len(parts), single=query.group_by is None)
            due: List[BucketKey] = []
            with self._lock:
                if self._closed:
                    raise RuntimeError("cannot submit to a closed AqpSession")
                self._admit(len(keyed))
                now = self.time_fn()
                for part, (key, c) in enumerate(keyed):
                    size = self._queue.add(
                        key, _Pending(c, ticket, part, now, ctx=sp.ctx))
                    if self.watermark is not None and size >= self.watermark:
                        due.append(key)
                self._c_submitted.inc()
                self.metrics.counter("aqp.admission.priority",
                                     session=self.sid, priority=name).inc()
                self._g_depth.set(self._queue.depth)
                self._g_max_depth.max(self._queue.depth)
                if self._auto_flush and self.max_delay is not None \
                        and self._thread is None:
                    self._start_flusher()
                self._wakeup.notify_all()
        # Past-deadline buckets flush first (oldest-first, via poll): without
        # this, a lone sub-watermark ticket whose deadline has passed would
        # keep waiting for the background flusher even while fresh submits
        # prove the session is alive.
        if self.max_delay is not None:
            self.poll()
        for key in due:
            self._flush_key(key, FLUSH_WATERMARK)
        return ticket.future

    def submit_many(self, queries: Sequence[AqpQuery],
                    priority: Optional[str] = None) -> List[Future]:
        return [self.submit(q, priority=priority) for q in queries]

    def execute(self, queries: Union[AqpQuery, Sequence[AqpQuery]]):
        """Submit-and-wait convenience: admit the specs, flush anything still
        pending from them, and return results like `QueryEngine.execute`
        (GROUP BY rows flattened in place)."""
        single = isinstance(queries, AqpQuery)
        futs = self.submit_many([queries] if single else list(queries))
        self.flush()
        out: List[AqpResult] = []
        for fut in futs:
            res = fut.result()
            out.extend(res if isinstance(res, list) else [res])
        return out

    def poll(self, now: Optional[float] = None) -> int:
        """Flush every bucket whose max-delay deadline has passed; returns the
        number of buckets flushed.  The manual pump for auto_flush=False."""
        if self.max_delay is None:
            return 0
        flushed = 0
        while True:
            with self._lock:
                key = self._queue.first_due(
                    self.time_fn() if now is None else now, self.max_delay,
                    skip=frozenset(self._fitting))
            if key is None:
                return flushed
            flushed += self._flush_key(key, FLUSH_DEADLINE)

    def flush(self) -> int:
        """Flush every pending bucket now; returns queries flushed."""
        return self._flush_all(FLUSH_MANUAL)

    def close(self) -> None:
        """Stop the flusher, flush everything still pending (reason "close"),
        and detach from the store.  Idempotent; submit() afterwards raises."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._wakeup.notify_all()
            thread = self._thread
        if thread is not None and thread is not threading.current_thread():
            thread.join(timeout=5.0)
        self._flush_all(FLUSH_CLOSE)
        if self._unsubscribe is not None:
            self._unsubscribe()
            self._unsubscribe = None

    def __enter__(self) -> "AqpSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def pending(self) -> int:
        with self._lock:
            return self._queue.depth

    # Legacy counter attributes, now views over this session's registry
    # instruments (same names and semantics callers relied on).

    @property
    def submitted(self) -> int:
        return int(self._c_submitted.value)

    @property
    def executed(self) -> int:
        return int(self._c_executed.value)

    @property
    def flushes(self) -> int:
        return int(self._c_flushes.value)

    @property
    def coalesced(self) -> int:
        return int(self._c_coalesced.value)

    @property
    def invalidations(self) -> int:
        return int(self._c_invalidations.value)

    @property
    def blocked(self) -> int:
        return int(self._c_blocked.value)

    @property
    def shed(self) -> int:
        return int(self._c_shed.value)

    @property
    def fit_requeued(self) -> int:
        return int(self._c_fit_requeued.value)

    @property
    def max_depth(self) -> int:
        return int(self._g_max_depth.value)

    @property
    def flush_reasons(self) -> Dict[str, int]:
        return {labels["reason"]: int(n) for labels, n in
                self.metrics.collect_counters("aqp.admission.flush_reason",
                                              session=self.sid)}

    @property
    def priority_counts(self) -> Dict[str, int]:
        return {labels["priority"]: int(n) for labels, n in
                self.metrics.collect_counters("aqp.admission.priority",
                                              session=self.sid)}

    def stats(self) -> Dict[str, object]:
        """This session's counters as the familiar dict — a *view* over the
        metrics registry (every value below is also queryable there under
        `aqp.admission.*` with `session=sid` labels)."""
        with self._lock:
            pending = self._queue.depth
        flushes = self.flushes
        mean_batch = (self._c_batch_rows.value / flushes
                      if flushes else 0.0)
        return {
            "submitted": self.submitted,
            "executed": self.executed,
            "pending": pending,
            "flushes": flushes,
            "coalesced": self.coalesced,
            "mean_batch": mean_batch,
            "flush_reasons": self.flush_reasons,
            "invalidations": self.invalidations,
            "max_pending": self.max_pending,
            "blocked": self.blocked,
            "shed": self.shed,
            "fit_requeued": self.fit_requeued,
            "max_depth": self.max_depth,
            "priorities": self.priority_counts,
            "plan_cache": self.engine.plans.stats(),
        }

    # -- internals -----------------------------------------------------------

    # Idle flusher threads re-check liveness at this cadence; it bounds both
    # how long an abandoned (never close()d) session stays pinned by its own
    # thread and the latency of noticing closure without a wakeup.
    _FLUSHER_TICK = 0.5

    # Blocked submitters re-check capacity at this cadence even without a
    # wakeup, so an external poll()er draining the queue out-of-band still
    # unblocks them promptly.
    _BLOCK_TICK = 0.05

    def _admit(self, n_parts: int) -> None:  # guarded-by: _lock
        """Enforce the max_pending bound (lock held).  A ticket whose parts
        alone exceed the bound is admitted once the queue is empty — refusing
        it forever (shed) or parking it forever (block) would deadlock wide
        GROUP BY specs behind a bound meant for queue depth."""
        if self.max_pending is None:
            return

        def over() -> bool:  # guarded-by: _lock
            return (self._queue.depth > 0
                    and self._queue.depth + n_parts > self.max_pending)

        if not over():
            return
        if self.overflow == "shed":
            self._c_shed.inc()
            raise AdmissionFull(
                f"admission queue at max_pending={self.max_pending} "
                f"({self._queue.depth} pending); resubmit later")
        self._c_blocked.inc()
        while over():
            self._wakeup.wait(timeout=self._BLOCK_TICK)
            if self._closed:
                raise RuntimeError(
                    "AqpSession closed while submit was blocked on "
                    "max_pending")

    def _start_flusher(self) -> None:  # guarded-by: _lock
        self._thread = threading.Thread(
            target=AqpSession._flusher_main, args=(weakref.ref(self),),
            name="aqp-admission-flusher", daemon=True)
        self._thread.start()

    @staticmethod
    def _flusher_main(ref: "weakref.ref") -> None:
        # Holds the session only via weakref between iterations (and for at
        # most _FLUSHER_TICK inside one): when the last external reference
        # drops without close(), the thread notices and exits so the session
        # can be collected.
        while True:
            session = ref()
            if session is None or session._closed:
                return
            with session._wakeup:
                deadline = session._queue.next_deadline(session.max_delay)
                tick = AqpSession._FLUSHER_TICK
                if deadline is None:
                    timeout = tick
                else:
                    timeout = min(max(deadline - session.time_fn(), 0.0), tick)
                if timeout > 0:
                    session._wakeup.wait(timeout=timeout)
                if session._closed:
                    return
            session.poll()
            session = None          # drop the strong ref before sleeping again

    def _on_versions(self, bumped: Dict[object, int]) -> None:
        """Store notification: add_batch bumped these reservoir versions.
        Re-key affected pending buckets so the flush executes (and reports)
        against the fresh synopsis version."""
        with self._lock:
            for key in list(self._queue.buckets):
                colkey, sel, tier, version = key
                fresh = bumped.get(colkey)
                if fresh is not None and fresh != version:
                    self._c_invalidations.inc(self._queue.rekey(
                        key, (colkey, sel, tier, fresh)))

    def _flush_key(self, key: BucketKey, reason: str) -> int:
        if self.fit_offload and reason != FLUSH_FIT \
                and self._maybe_offload(key):
            return 0
        with self._lock:
            pendings = self._queue.pop(key)
            if pendings:
                self._g_depth.set(self._queue.depth)
                self._wakeup.notify_all()     # free submitters at max_pending
        if not pendings:
            return 0
        self._run_flush(key, pendings, reason)
        return 1

    def _maybe_offload(self, key: BucketKey) -> bool:
        """True when this bucket's flush would block on a slow synopsis fit
        and the fit was handed to (or is already with) a worker thread; the
        bucket stays queued — skipped by the deadline scan — until the
        worker re-flushes it with reason "fit"."""
        colkey, sel, tier, version = key
        if sel not in SLOW_SELECTORS:
            return False
        cache = getattr(self.engine.store, "cache", None)
        peek = getattr(cache, "peek", None)
        if peek is None:
            return False
        from .aqp_query import _tier_key
        if peek(_tier_key(colkey, tier), sel, version) is not None:
            return False                      # already fitted: flush inline
        with self._lock:
            if self._closed or key not in self._queue.buckets:
                return False
            if key in self._fitting:
                return True                   # a worker is already on it
            self._fitting.add(key)
            self._c_fit_requeued.inc(len(self._queue.buckets[key]))
        threading.Thread(
            target=AqpSession._fit_worker, args=(weakref.ref(self), key),
            name="aqp-admission-fit", daemon=True).start()
        return True

    @staticmethod
    def _fit_worker(ref: "weakref.ref", key: BucketKey) -> None:
        """Run one slow synopsis fit off the flusher thread, then re-flush
        the bucket that was waiting on it (reason "fit").  A fit failure is
        left for the flush to re-raise — it lands in the tickets' futures
        through the normal error path rather than dying silently here."""
        session = ref()
        if session is None:
            return
        colkey, sel, tier, version = key
        try:
            resolver = session.engine.resolver(sel, tier=tier)
            with obs.span("admission.fit", key=colkey, selector=sel,
                          tier=tier, session=session.sid):
                resolver.plan_for((colkey, sel, tier), version)
        except BaseException:
            pass
        finally:
            with session._lock:
                session._fitting.discard(key)
            session._flush_key(key, FLUSH_FIT)

    def _flush_all(self, reason: str) -> int:
        with self._lock:
            batches = self._queue.pop_all()
            if batches:
                self._g_depth.set(0)
                self._wakeup.notify_all()     # free submitters at max_pending
        total = 0
        for key, pendings in batches:
            self._run_flush(key, pendings, reason)
            total += len(pendings)
        return total

    def _run_flush(self, key: BucketKey, pendings: List[_Pending],
                   reason: str) -> None:
        """Execute one micro-batch through the engine core and scatter the
        results (or the failure) onto the waiting tickets.  The bucket key
        carries the tier budget, so a coarse-priority batch executes on its
        tier's plan rather than the full sample."""
        compiled = []
        for i, p in enumerate(pendings):
            p.compiled.slot = i
            compiled.append(p.compiled)
        error: Optional[BaseException] = None
        results: List[AqpResult] = []
        # Parent the flush span onto the oldest pending's submit span: the
        # trace started at submit() continues here even though the flush runs
        # on a different thread (the ctx tuple made the hop explicitly).
        t0 = time.perf_counter()
        with obs.span("admission.flush", parent=pendings[0].ctx,
                      reason=reason, batch=len(pendings), key=key[0],
                      tier=key[2], session=self.sid):
            try:
                results = self.engine.run_compiled(
                    compiled, selector=self.selector, backend=self.backend,
                    tier=key[2])
            except BaseException as exc:        # surface through the futures
                error = exc
        if obs.enabled():
            self.metrics.histogram("aqp.admission.flush_us",
                                   session=self.sid).observe(
                (time.perf_counter() - t0) * 1e6)
        done: List[_Ticket] = []
        with self._lock:
            self._c_flushes.inc()
            self.metrics.counter("aqp.admission.flush_reason",
                                 session=self.sid, reason=reason).inc()
            self._c_batch_rows.inc(len(pendings))
            self._h_batch.observe(len(pendings))
            self._c_executed.inc(len(pendings))
            if len(pendings) > 1:
                self._c_coalesced.inc(len(pendings))
            for p in pendings:
                t = p.ticket
                if error is not None:
                    t.failed = True
                else:
                    t.parts[p.part] = results[p.compiled.slot]
                t.remaining -= 1
                if t.remaining == 0:
                    done.append(t)
        # futures resolve outside the lock: done-callbacks may re-enter the
        # session (e.g. a client submitting its next query inline)
        for t in done:
            if t.failed:
                t.future.set_exception(
                    error if error is not None
                    else RuntimeError("admission flush failed"))
            else:
                t.future.set_result(t.parts[0] if t.single else list(t.parts))
