"""Multi-dimensional AQP: box predicates over joint KDE synopses (eq. 11).

The paper's multivariate formulation (§4.3) answers aggregates over
axis-aligned boxes with a *product kernel*: for diagonal bandwidths the box
integral factorises into per-axis 1-D integrals, each with the same Gaussian
closed forms as eqs. 9-10:

  COUNT(box) ~= scale * sum_i  prod_j  [Phi((hi_j-X_ij)/h_j) - Phi((lo_j-X_ij)/h_j)]
  SUM(t;box) ~= scale * sum_i  m_it * prod_{j!=t} [Phi]_ij
               with  m_ij = X_ij [Phi]_ij - h_j [phi]_ij      (eq. 10 per axis)
  AVG        =  SUM / COUNT  (same empty-selection guard as the 1-D engine)

A heterogeneous batch against one joint synopsis therefore reduces to ONE
(queries x samples x dims) Phi-product reduction — evaluated either by a
jitted vmapped pass here or by the kernels/aqp_boxes.py Pallas tile kernel.
Full-H synopses (LSCV_H) don't factorise; their groups fall back to the
batched deterministic quasi-MC path (`batch_query_qmc`: shared Halton nodes,
one KDE evaluation per group), never failing the batch.

The planner classes here are legacy: `BoxQueryBatch.run` is a deprecated
shim over the unified engine in aqp_query.py.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import lru_cache, partial
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .aqp import (OP_CODES, OP_COUNT, OP_SUM, KDESynopsis, _avg_or_zero,
                  _halton, _Phi, _phi)
from .kde import kde_eval_H

ColumnsKey = Optional[Tuple[str, ...]]


@dataclass(frozen=True)
class BoxQuery:
    """One aggregate over an axis-aligned box: OP WHERE lo_j <= X_j <= hi_j.

    `columns` names the joint synopsis (None when run against a single
    synopsis); `target` picks the SUM/AVG axis — a column name (requires
    `columns`) or an integer axis index, default axis 0.
    """
    op: str                                   # "count" | "sum" | "avg"
    lo: Tuple[float, ...]
    hi: Tuple[float, ...]
    columns: Optional[Tuple[str, ...]] = None
    target: Optional[Union[int, str]] = None

    def __post_init__(self):
        if self.op not in OP_CODES:
            raise ValueError(f"unknown op {self.op!r}; expected one of {sorted(OP_CODES)}")
        object.__setattr__(self, "lo", tuple(float(v) for v in np.ravel(self.lo)))
        object.__setattr__(self, "hi", tuple(float(v) for v in np.ravel(self.hi)))
        if len(self.lo) != len(self.hi):
            raise ValueError(f"lo/hi dimensionality mismatch: "
                             f"{len(self.lo)} vs {len(self.hi)}")
        if self.columns is not None:
            object.__setattr__(self, "columns", tuple(self.columns))
            if len(self.columns) != len(self.lo):
                raise ValueError(f"box has {len(self.lo)} axes but names "
                                 f"{len(self.columns)} columns")
        self.target_index()      # validate eagerly: planning must not fail late

    @property
    def d(self) -> int:
        return len(self.lo)

    def target_index(self) -> int:
        """Resolve `target` to an axis index (0 when unset)."""
        if self.target is None:
            return 0
        if isinstance(self.target, str):
            if self.columns is None or self.target not in self.columns:
                raise ValueError(f"target column {self.target!r} not among "
                                 f"box columns {self.columns}")
            return self.columns.index(self.target)
        t = int(self.target)
        if not 0 <= t < self.d:
            raise ValueError(f"target axis {t} out of range for d={self.d}")
        return t


def _box_terms(x: jax.Array, h_diag: jax.Array, lo: jax.Array, hi: jax.Array,
               tgt: jax.Array, q_chunk: int = 64):
    """vmapped eq. 11 closed forms: per-query unscaled (count_raw, sum_raw).
    x: (n,d), h_diag: (d,), lo/hi: (q,d), tgt: (q,) int32.

    Queries are processed in `q_chunk` slabs (lax.map over vmapped chunks):
    the full (q, n, d) intermediate spills out of cache for serving-sized
    batches, and the slab form measures ~12% faster on CPU at q=512.
    """
    axis = jnp.arange(x.shape[1])

    def one(loq, hiq, t):
        za = (loq[None, :] - x) / h_diag[None, :]
        zb = (hiq[None, :] - x) / h_diag[None, :]
        d_Phi = _Phi(zb) - _Phi(za)                               # (n, d)
        moment = x * d_Phi - h_diag[None, :] * (_phi(zb) - _phi(za))
        cnt = jnp.sum(jnp.prod(d_Phi, axis=1))
        factors = jnp.where(axis[None, :] == t, moment, d_Phi)
        sm = jnp.sum(jnp.prod(factors, axis=1))
        return cnt, sm

    q, d = lo.shape
    if q <= q_chunk:
        return jax.vmap(one)(lo, hi, tgt)
    pad = (-q) % q_chunk
    lop = jnp.pad(lo, ((0, pad), (0, 0))).reshape(-1, q_chunk, d)
    hip = jnp.pad(hi, ((0, pad), (0, 0))).reshape(-1, q_chunk, d)
    tgtp = jnp.pad(tgt, (0, pad)).reshape(-1, q_chunk)
    cnt, sm = jax.lax.map(lambda args: jax.vmap(one)(*args), (lop, hip, tgtp))
    return cnt.reshape(-1)[:q], sm.reshape(-1)[:q]


@partial(jax.jit, static_argnames=("backend",))
def batch_query_box(x: jax.Array, h_diag: jax.Array, lo: jax.Array,
                    hi: jax.Array, tgt: jax.Array, ops: jax.Array,
                    scale: jax.Array, backend: str = "jnp") -> jax.Array:
    """Answer a mixed box-query batch against one diagonal-bandwidth joint
    synopsis in a single jitted call.

    x: (n,d) retained rows; lo/hi: (q,d); tgt/ops: (q,); scale: sample ->
    relation factor.  backend="pallas" routes the (queries x samples x dims)
    Phi-product reduction through the kernels/aqp_boxes.py tile kernel.
    """
    if backend == "pallas":
        from repro.kernels import ops as kops
        cnt_raw, sum_raw = kops.aqp_box_sums(x, h_diag, lo, hi, tgt)
    else:
        cnt_raw, sum_raw = _box_terms(x, h_diag, lo, hi, tgt)
    counts = scale * cnt_raw
    sums = scale * sum_raw
    avgs = _avg_or_zero(counts, sums)
    return jnp.select([ops == OP_COUNT, ops == OP_SUM], [counts, sums], avgs)


@dataclass
class BoxQueryBatch:
    """Planner for heterogeneous box-query batches.

    Groups queries by their column tuple so each joint synopsis is answered in
    a single jitted pass, then scatters results back to submission order —
    the multi-d counterpart of QueryBatch.
    """
    queries: Sequence[BoxQuery]
    _groups: Dict[ColumnsKey, List[int]] = field(init=False, repr=False)
    _plans: Dict[ColumnsKey, tuple] = field(init=False, repr=False)

    def __post_init__(self):
        self.queries = [q if isinstance(q, BoxQuery) else BoxQuery(*q)
                        for q in self.queries]
        groups: Dict[ColumnsKey, List[int]] = {}
        for i, q in enumerate(self.queries):
            groups.setdefault(q.columns, []).append(i)
        for key, idx in groups.items():
            dims = {self.queries[i].d for i in idx}
            if len(dims) > 1:
                raise ValueError(f"queries for synopsis {key} mix box "
                                 f"dimensionalities {sorted(dims)}")
        self._groups = groups
        self._plans = {}    # device arrays built once, reused across run()s

    def __len__(self) -> int:
        return len(self.queries)

    @property
    def column_groups(self) -> List[ColumnsKey]:
        return list(self._groups)

    def plan(self, columns: ColumnsKey):
        """(indices, lo, hi, target, opcodes) device arrays for one group;
        memoised so repeated run() calls amortise the host->device build."""
        if columns in self._plans:
            return self._plans[columns]
        idx = self._groups[columns]
        qs = [self.queries[i] for i in idx]
        lo = jnp.asarray([q.lo for q in qs], jnp.float32)
        hi = jnp.asarray([q.hi for q in qs], jnp.float32)
        tgt = jnp.asarray([q.target_index() for q in qs], jnp.int32)
        ops_arr = jnp.asarray([OP_CODES[q.op] for q in qs], jnp.int32)
        self._plans[columns] = (idx, lo, hi, tgt, ops_arr)
        return self._plans[columns]

    def _resolve(self, synopses, columns: ColumnsKey) -> KDESynopsis:
        if isinstance(synopses, KDESynopsis):
            if columns is not None:
                raise ValueError("queries name columns but a single synopsis "
                                 "was given; pass a {columns: synopsis} mapping")
            return synopses
        if columns is None:
            raise ValueError("queries must name their columns when running "
                             "against a synopsis mapping")
        if columns not in synopses:
            raise KeyError(f"no joint synopsis for columns {columns!r}; "
                           f"have {sorted(synopses)}")
        return synopses[columns]

    def run(self, synopses: Union[KDESynopsis, Mapping[Tuple[str, ...], KDESynopsis]],
            backend: str = "jnp") -> np.ndarray:
        """Deprecated shim: compiles to `AqpQuery` specs and executes through
        the unified engine (repro.core.aqp_query); answers in submission
        order, bit-for-bit identical to `QueryEngine.execute`."""
        import warnings

        warnings.warn(
            "BoxQueryBatch.run is deprecated; build AqpQuery specs and "
            "execute them through repro.core.aqp_query.QueryEngine (or "
            "TelemetryStore.query)", DeprecationWarning, stacklevel=2)
        return run_legacy_boxes(self.queries, synopses, backend=backend)


def run_legacy_boxes(queries: Sequence[BoxQuery], synopses,
                     backend: str = "jnp") -> np.ndarray:
    """Execute legacy `BoxQuery` objects through the unified engine — the
    shim body, shared with `KDESynopsis.query_box_batch` (which keeps its
    non-deprecated convenience signature)."""
    from .aqp_query import execute_specs, from_box_query
    return execute_specs([from_box_query(q) for q in queries], synopses,
                         backend=backend)


# --- grouped GROUP BY evaluation (shared box terms factored out) ------------
#
# A GROUP BY over a dictionary column expands to one box per category that
# differs from its siblings on exactly ONE axis (the group column's code
# window).  Fanning those out through the generic batched pass recomputes the
# shared axes' Phi factors once per category: O(n * d * G).  The grouped form
# computes the shared product once and only the group axis per category:
# O(n * d + n * G).


@partial(jax.jit, static_argnames=("g_axis", "tgt_is_group"))
def _grouped_box_terms(x: jax.Array, h_diag: jax.Array, lo: jax.Array,
                       hi: jax.Array, glo: jax.Array, ghi: jax.Array,
                       tgt: jax.Array, g_axis: int, tgt_is_group: bool):
    """Unscaled (count_raw, sum_raw), one entry per category.

    x: (n,d); lo/hi: (d,) the shared box (the group axis' entries are
    ignored); glo/ghi: (G,) per-category interval on axis `g_axis`; tgt:
    scalar target axis.  `tgt_is_group` statically selects whether the
    first-moment factor lives on the shared axes or the group axis.
    """
    za = (lo[None, :] - x) / h_diag[None, :]
    zb = (hi[None, :] - x) / h_diag[None, :]
    d_Phi = _Phi(zb) - _Phi(za)                               # (n, d)
    axis = jnp.arange(x.shape[1])
    keep = axis != g_axis
    shared_cnt = jnp.prod(jnp.where(keep[None, :], d_Phi, 1.0), axis=1)

    xg = x[:, g_axis]
    hg = h_diag[g_axis]
    gza = (glo[None, :] - xg[:, None]) / hg                   # (n, G)
    gzb = (ghi[None, :] - xg[:, None]) / hg
    g_Phi = _Phi(gzb) - _Phi(gza)
    cnt = jnp.sum(shared_cnt[:, None] * g_Phi, axis=0)        # (G,)

    if tgt_is_group:
        g_moment = xg[:, None] * g_Phi - hg * (_phi(gzb) - _phi(gza))
        sm = jnp.sum(shared_cnt[:, None] * g_moment, axis=0)
    else:
        moment = x * d_Phi - h_diag[None, :] * (_phi(zb) - _phi(za))
        factors = jnp.where(axis[None, :] == tgt, moment, d_Phi)
        shared_sm = jnp.prod(jnp.where(keep[None, :], factors, 1.0), axis=1)
        sm = jnp.sum(shared_sm[:, None] * g_Phi, axis=0)
    return cnt, sm


def batch_query_box_grouped(x: jax.Array, h_diag: jax.Array, lo, hi,
                            glo, ghi, g_axis: int, tgt: int, op: int,
                            scale, backend: str = "jnp") -> jax.Array:
    """Answer one GROUP BY family — a shared box crossed with G per-category
    windows on axis `g_axis` — in a single factored pass (one answer per
    category, the family shares one aggregate op).  backend="pallas" routes
    the factored reduction through the kernels/aqp_grouped.py tile kernel."""
    if backend == "pallas":
        from repro.kernels import ops as kops
        cnt_raw, sum_raw = kops.aqp_grouped_sums(
            x, h_diag, jnp.asarray(lo, jnp.float32),
            jnp.asarray(hi, jnp.float32), jnp.asarray(glo, jnp.float32),
            jnp.asarray(ghi, jnp.float32), int(g_axis), int(tgt))
    else:
        cnt_raw, sum_raw = _grouped_box_terms(
            x, h_diag, jnp.asarray(lo, jnp.float32),
            jnp.asarray(hi, jnp.float32), jnp.asarray(glo, jnp.float32),
            jnp.asarray(ghi, jnp.float32), jnp.int32(tgt), int(g_axis),
            bool(tgt == g_axis))
    counts = scale * cnt_raw
    sums = scale * sum_raw
    if op == OP_COUNT:
        return counts
    if op == OP_SUM:
        return sums
    return _avg_or_zero(counts, sums)


# --- batched quasi-MC fallback (full-H groups) ------------------------------
#
# eq. 11 has no product form under a full bandwidth matrix.  The old fallback
# ran one Halton node-set + density evaluation per query (a Python loop); the
# batched form evaluates the KDE ONCE on a shared node set spanning the
# queries' bounding box and reduces every box in a single vmapped indicator
# pass — the whole group costs one O(nodes x sample) evaluation.

MAX_QMC_NODES = 32_768


@lru_cache(maxsize=16)
def _halton_unit(n_nodes: int, d: int) -> jax.Array:
    """Shared unit-cube Halton nodes; cached so repeated batches reuse them."""
    return _halton(n_nodes, d)


@partial(jax.jit, static_argnames=())
def _qmc_shared_terms(x: jax.Array, H: jax.Array, glo: jax.Array,
                      ghi: jax.Array, lo: jax.Array, hi: jax.Array,
                      tgt: jax.Array, unit: jax.Array):
    """Per-query unscaled (count_raw, sum_raw) from ONE density evaluation.

    Nodes cover the group's bounding box [glo, ghi]; each box q reduces the
    shared f values under its indicator:  count_q = n vol(G) mean(f 1_q).
    """
    n = x.shape[0]
    nodes = glo[None, :] + unit * (ghi - glo)[None, :]        # (m, d)
    f = kde_eval_H(nodes, x, H)                                # (m,)
    vol_g = jnp.prod(ghi - glo)

    def one(loq, hiq, t):
        inside = jnp.all((nodes >= loq[None, :]) & (nodes <= hiq[None, :]),
                         axis=1)
        w = f * inside
        cnt = n * vol_g * jnp.mean(w)
        sm = n * vol_g * jnp.mean(jnp.take(nodes, t, axis=1) * w)
        return cnt, sm

    return jax.vmap(one)(lo, hi, tgt)


def _qmc_plan(x_host: np.ndarray, H: np.ndarray, lo: np.ndarray,
              hi: np.ndarray, n_qmc: int):
    """Host-side quasi-MC planning, shared by the estimate pass and the
    subsample-CI pass (repro.core.aqp_ci) so both reduce over the same
    clipped boxes and node set.

    Axes wider than the synopsis support are clipped to support +- 6
    per-axis sigma ("unconstrained" axes from SUM/AVG targets); essentially
    all Gaussian mass lies inside, and it keeps the shared node set finite.
    Small boxes inside a large bounding box see fewer effective nodes, so the
    node budget grows (up to MAX_QMC_NODES) when the narrowest box covers a
    small fraction of the group hull.

    Returns (glo, ghi, clo, chi, n_nodes) float64 host arrays, or None when
    every box is zero-measure.
    """
    lo = np.asarray(lo, np.float64).reshape(lo.shape[0], -1)
    hi = np.asarray(hi, np.float64).reshape(hi.shape[0], -1)
    sig = np.sqrt(np.diag(np.asarray(H, np.float64)))
    slo = x_host.min(axis=0) - 6.0 * sig
    shi = x_host.max(axis=0) + 6.0 * sig
    clo = np.clip(lo, slo[None, :], shi[None, :])
    chi = np.clip(hi, slo[None, :], shi[None, :])
    glo = clo.min(axis=0)
    ghi = chi.max(axis=0)
    vol_g = float(np.prod(ghi - glo))
    if vol_g <= 0.0:                       # every box is zero-measure
        return None
    ratios = np.prod(chi - clo, axis=1) / vol_g
    ratios = ratios[ratios > 0]
    min_ratio = float(ratios.min()) if ratios.size else 1.0
    n_nodes = int(min(MAX_QMC_NODES, n_qmc / max(min_ratio, n_qmc / MAX_QMC_NODES)))
    # Quantize the budget to the next power of two: a continuous function of
    # box geometry would give almost every batch its own node-set shape,
    # retracing _qmc_shared_terms and churning the Halton cache on each call.
    n_nodes = 1 << max(int(np.ceil(np.log2(max(n_nodes, 1)))),
                       int(np.ceil(np.log2(n_qmc))))
    return glo, ghi, clo, chi, n_nodes


def batch_query_qmc(x: jax.Array, H: jax.Array, lo: np.ndarray, hi: np.ndarray,
                    tgt: np.ndarray, ops: np.ndarray, scale: float,
                    n_qmc: int = 4096, backend: str = "jnp") -> jax.Array:
    """Answer a mixed box batch against one full-H synopsis in one KDE pass.

    lo/hi: (q, d) host arrays; the bounding box and node budget are planned
    on the host by `_qmc_plan` (support clipping, shared-node budget).
    backend="pallas" fuses the (nodes x sample) density evaluation with the
    (boxes x nodes) indicator reduction through kernels/qmc_reduce.py — the
    shared f vector is never materialized.
    """
    d = x.shape[1]
    plan = _qmc_plan(np.asarray(x, np.float64), np.asarray(H), lo, hi, n_qmc)
    if plan is None:                       # every box is zero-measure
        return jnp.zeros((np.asarray(lo).shape[0],), jnp.float32)
    glo, ghi, clo, chi, n_nodes = plan

    if backend == "pallas":
        from repro.kernels import ops as kops
        glo_d = jnp.asarray(glo, jnp.float32)
        ghi_d = jnp.asarray(ghi, jnp.float32)
        nodes = glo_d[None, :] + _halton_unit(n_nodes, d) * (ghi_d - glo_d)[None, :]
        Hf = jnp.asarray(H, jnp.float32)
        h_inv = jnp.linalg.inv(Hf)          # same numerics as kde.kde_eval_H
        log_norm = (-0.5 * d * jnp.log(2.0 * jnp.pi)
                    - 0.5 * jnp.linalg.slogdet(Hf)[1])
        cnt_sums, sum_sums = kops.qmc_box_reduce(
            nodes, x, h_inv, log_norm, jnp.asarray(clo, jnp.float32),
            jnp.asarray(chi, jnp.float32), jnp.asarray(tgt, jnp.int32))
        # n vol(G) mean_m(f 1_q) with f = (1/n) sum_i k(...): the n cancels,
        # leaving vol(G)/m times the kernel's raw double sums.
        factor = float(np.prod(ghi - glo)) / n_nodes
        cnt_raw = factor * cnt_sums
        sum_raw = factor * sum_sums
    else:
        cnt_raw, sum_raw = _qmc_shared_terms(
            x, H, jnp.asarray(glo, jnp.float32), jnp.asarray(ghi, jnp.float32),
            jnp.asarray(clo, jnp.float32), jnp.asarray(chi, jnp.float32),
            jnp.asarray(tgt, jnp.int32), _halton_unit(n_nodes, d))
    counts = scale * cnt_raw
    sums = scale * sum_raw
    return jnp.select([np.asarray(ops) == OP_COUNT, np.asarray(ops) == OP_SUM],
                      [counts, sums], _avg_or_zero(counts, sums))


@jax.jit
def _qmc_indicator_terms(nodes: jax.Array, f: jax.Array, glo: jax.Array,
                         ghi: jax.Array, lo: jax.Array, hi: jax.Array,
                         tgt: jax.Array, n: jax.Array):
    """The indicator half of `_qmc_shared_terms` for precomputed densities —
    the density evaluation happens outside (a synopsis backend's eval runs
    at top level so obs fencing sees concrete arrays, not tracers)."""
    vol_g = jnp.prod(ghi - glo)

    def one(loq, hiq, t):
        inside = jnp.all((nodes >= loq[None, :]) & (nodes <= hiq[None, :]),
                         axis=1)
        w = f * inside
        cnt = n * vol_g * jnp.mean(w)
        sm = n * vol_g * jnp.mean(jnp.take(nodes, t, axis=1) * w)
        return cnt, sm

    return jax.vmap(one)(lo, hi, tgt)


def batch_query_qmc_rff(x_host: np.ndarray, H: np.ndarray, rff,
                        lo: np.ndarray, hi: np.ndarray, tgt: np.ndarray,
                        ops: np.ndarray, scale: float,
                        n_qmc: int = 4096) -> jax.Array:
    """`batch_query_qmc` with the density pass routed through a fitted
    sublinear synopsis (`repro.synopses.rff.RFFSynopsis`, duck-typed: needs
    `eval_batch`).  Shares `_qmc_plan` with the exact path, so both reduce
    over identical support-clipped boxes and Halton nodes — the only
    difference is O(nodes x D) feature eval vs O(nodes x n) kernel sums.

    x_host is the fitted sample (planning only: support hull + row count);
    the densities never touch it."""
    d = x_host.shape[1]
    plan = _qmc_plan(np.asarray(x_host, np.float64), np.asarray(H), lo, hi,
                     n_qmc)
    if plan is None:                       # every box is zero-measure
        return jnp.zeros((np.asarray(lo).shape[0],), jnp.float32)
    glo, ghi, clo, chi, n_nodes = plan

    unit = _halton_unit(n_nodes, d)
    glo_d = jnp.asarray(glo, jnp.float32)
    ghi_d = jnp.asarray(ghi, jnp.float32)
    nodes = glo_d[None, :] + unit * (ghi_d - glo_d)[None, :]
    f = rff.eval_batch(nodes)              # Pallas kernel, top level
    cnt_raw, sum_raw = _qmc_indicator_terms(
        nodes, f, glo_d, ghi_d, jnp.asarray(clo, jnp.float32),
        jnp.asarray(chi, jnp.float32), jnp.asarray(tgt, jnp.int32),
        jnp.float32(x_host.shape[0]))
    counts = scale * cnt_raw
    sums = scale * sum_raw
    return jnp.select([np.asarray(ops) == OP_COUNT, np.asarray(ops) == OP_SUM],
                      [counts, sums], _avg_or_zero(counts, sums))


def qmc_rff_se(rff, x_host: np.ndarray, H: np.ndarray, lo: np.ndarray,
               hi: np.ndarray, tgt: np.ndarray, ops: np.ndarray,
               n_source: int, n_qmc: int,
               n_blocks: int = 8) -> Tuple[np.ndarray, int]:
    """(per-query SE, t dof) for the RFF QMC path, by batch-means over
    feature blocks.  The exact path's `qmc_subsample_se` replicates over
    sample chunks — O(n x nodes), which would erase the sublinear win.  The
    RFF synopsis's independent replicates are its *features*: each block of
    D/B features gives an unbiased density estimate (`block_densities`), and
    every block reduces over the same plan/nodes, so QMC integration error is
    common-mode and the spread isolates feature-sampling variance — the
    dominant error this backend adds."""
    from .aqp import AVG_MIN_COUNT

    q = np.asarray(lo).shape[0]
    plan = _qmc_plan(np.asarray(x_host, np.float64), np.asarray(H), lo, hi,
                     n_qmc)
    if plan is None:                  # zero-measure boxes: estimate is 0
        return np.zeros((q,), np.float64), n_blocks - 1
    glo, ghi, clo, chi, n_nodes = plan
    unit = _halton_unit(n_nodes, x_host.shape[1])
    glo_d = jnp.asarray(glo, jnp.float32)
    ghi_d = jnp.asarray(ghi, jnp.float32)
    clo_d = jnp.asarray(clo, jnp.float32)
    chi_d = jnp.asarray(chi, jnp.float32)
    tgt_d = jnp.asarray(tgt, jnp.int32)
    ops = np.asarray(ops)
    nodes = glo_d[None, :] + unit * (ghi_d - glo_d)[None, :]
    fb = rff.block_densities(nodes, n_blocks)            # (B, m)
    scale = n_source / x_host.shape[0]
    n_f = jnp.float32(x_host.shape[0])
    ests = []
    for j in range(n_blocks):
        cnt_raw, sum_raw = _qmc_indicator_terms(nodes, fb[j], glo_d, ghi_d,
                                                clo_d, chi_d, tgt_d, n_f)
        counts = scale * np.asarray(cnt_raw, np.float64)
        sums = scale * np.asarray(sum_raw, np.float64)
        avgs = np.where(counts > AVG_MIN_COUNT,
                        sums / np.maximum(counts, 1e-12), 0.0)
        ests.append(np.select([ops == OP_COUNT, ops == OP_SUM],
                              [counts, sums], avgs))
    e = np.stack(ests)
    return e.std(axis=0, ddof=1) / math.sqrt(n_blocks), n_blocks - 1


def _qmc_box_answers(syn: KDESynopsis, qs: Sequence[BoxQuery],
                     n_qmc: int = 4096) -> np.ndarray:
    """Full-H fallback for a group of BoxQuery objects, batched (ROADMAP
    follow-up: the per-query Python loop of `box_qmc_terms` calls is gone)."""
    x = syn.x[:, None] if syn.x.ndim == 1 else syn.x
    scale = jnp.float32(syn.n_source / x.shape[0])
    lo = np.asarray([q.lo for q in qs], np.float64)
    hi = np.asarray([q.hi for q in qs], np.float64)
    tgt = np.asarray([q.target_index() for q in qs], np.int32)
    ops = np.asarray([OP_CODES[q.op] for q in qs], np.int32)
    ans = batch_query_qmc(x, syn.H, lo, hi, tgt, ops, scale, n_qmc=n_qmc)
    return np.asarray(ans, np.float64)
