"""Unified declarative AQP query API — one spec, one engine, many paths.

After the 1-D (`Query`/`QueryBatch`) and multi-d (`BoxQuery`/`BoxQueryBatch`)
stacks, this module makes the *query surface* the product (cf. VerdictDB's
single logical query interface over many execution backends, and DEANN's
estimator-contract / acceleration-backend split):

  `AqpQuery`   — a declarative aggregate: COUNT/SUM/AVG under a conjunction of
                 predicate terms, optionally grouped by a dictionary column.
      Range(column, a, b)   a <= column <= b        (eqs. 9-10 closed forms)
      Box(columns, lo, hi)  axis-aligned box        (eq. 11 product kernel)
      Eq(column, value)     dictionary/categorical equality (code +- 1/2)
  `QueryEngine` — the facade over a `TelemetryStore`: normalizes/validates a
                 heterogeneous batch, groups it by (column tuple, selector),
                 and routes each group to the cheapest applicable path:

      path      synopsis                 kernel
      -------   ----------------------   -----------------------------------
      range1d   1-D sample, scalar h     closed forms (`batch_query_1d`, or
                                         the Pallas `aqp_batch` tile kernel)
      box       rows, diagonal h         eq. 11 product kernel
                                         (`batch_query_box` / Pallas
                                         `aqp_boxes` tiles)
      qmc       full bandwidth matrix H  batched quasi-MC: shared Halton
                                         nodes, ONE KDE pass per group
                                         (`batch_query_qmc`)

  `AqpResult`  — estimate + the chosen path, a relative-width accuracy proxy,
                 and the synopsis version that answered the query.

The legacy stacks survive as deprecated shims: `QueryBatch.run` /
`BoxQueryBatch.run` compile their queries to `AqpQuery` specs and execute
through this module, bit-for-bit identical to `QueryEngine.execute`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from .aqp import OP_CODES, KDESynopsis, batch_query_1d, canonical_selector
from .aqp_multid import batch_query_box, batch_query_qmc

ColumnKey = Union[None, str, Tuple[str, ...]]

EQ_HALFWIDTH = 0.5   # dictionary codes are unit-spaced: `== v` is v +- 1/2
WIDE = 1e30          # "unconstrained axis": Phi saturates to {0,1}, phi to 0


# --- predicate terms --------------------------------------------------------

@dataclass(frozen=True)
class Range:
    """a <= column <= b.  `column=None` addresses a bare (unnamed) synopsis."""
    column: Optional[str]
    a: float
    b: float

    def __post_init__(self):
        object.__setattr__(self, "a", float(self.a))
        object.__setattr__(self, "b", float(self.b))


@dataclass(frozen=True)
class Eq:
    """Dictionary/categorical equality: column == value.

    Dictionary-coded columns hold unit-spaced numeric codes, so equality is
    the range [value - halfwidth, value + halfwidth] over the code axis — the
    KDE mass the synopsis assigns to that code's bucket.
    """
    column: Optional[str]
    value: float
    halfwidth: float = EQ_HALFWIDTH

    def __post_init__(self):
        object.__setattr__(self, "value", float(self.value))
        object.__setattr__(self, "halfwidth", float(self.halfwidth))
        if self.halfwidth <= 0:
            raise ValueError(f"Eq halfwidth must be positive, got {self.halfwidth}")


@dataclass(frozen=True)
class Box:
    """Axis-aligned box: lo_j <= columns_j <= hi_j.  `columns=None` addresses
    the positional axes of a bare (unnamed) multi-d synopsis."""
    columns: Optional[Tuple[str, ...]]
    lo: Tuple[float, ...]
    hi: Tuple[float, ...]

    def __post_init__(self):
        object.__setattr__(self, "lo", tuple(float(v) for v in np.ravel(self.lo)))
        object.__setattr__(self, "hi", tuple(float(v) for v in np.ravel(self.hi)))
        if len(self.lo) != len(self.hi):
            raise ValueError(f"lo/hi dimensionality mismatch: "
                             f"{len(self.lo)} vs {len(self.hi)}")
        if self.columns is not None:
            object.__setattr__(self, "columns", tuple(self.columns))
            if len(self.columns) != len(self.lo):
                raise ValueError(f"box has {len(self.lo)} axes but names "
                                 f"{len(self.columns)} columns")


Predicate = Union[Range, Box, Eq]


@dataclass(frozen=True)
class GroupBy:
    """GROUP BY over a dictionary column.  `values=None` discovers the code
    set from the store's reservoir sample at execution time."""
    column: str
    values: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.values is not None:
            object.__setattr__(self, "values",
                               tuple(float(v) for v in self.values))


@dataclass(frozen=True)
class AqpQuery:
    """One declarative aggregate: COUNT/SUM/AVG of `target` under the
    conjunction of `predicates`, optionally per `group_by` category.

    `selector` overrides the engine's bandwidth selector for this query only
    (e.g. one `lscv_H` query inside a `plugin` batch routes to the quasi-MC
    path while the rest stay on the closed forms).
    """
    aggregate: str                               # "count" | "sum" | "avg"
    predicates: Tuple[Predicate, ...] = ()
    target: Optional[Union[str, int]] = None     # SUM/AVG column (or axis)
    group_by: Optional[Union[str, "GroupBy"]] = None
    selector: Optional[str] = None               # per-query selector override

    def __post_init__(self):
        agg = str(self.aggregate).lower()
        if agg not in OP_CODES:
            raise ValueError(f"unknown aggregate {self.aggregate!r}; "
                             f"expected one of {sorted(OP_CODES)}")
        object.__setattr__(self, "aggregate", agg)
        preds = self.predicates
        if isinstance(preds, (Range, Box, Eq)):
            preds = (preds,)
        preds = tuple(preds)
        for p in preds:
            if not isinstance(p, (Range, Box, Eq)):
                raise TypeError(f"predicate terms must be Range/Box/Eq, "
                                f"got {type(p).__name__}")
        object.__setattr__(self, "predicates", preds)
        if isinstance(self.group_by, str):
            object.__setattr__(self, "group_by", GroupBy(self.group_by))
        if self.group_by is not None and not isinstance(self.group_by, GroupBy):
            raise TypeError("group_by must be a column name or GroupBy")
        if agg == "count":
            if self.target is not None:
                raise ValueError("COUNT takes no target column")
            if not preds and self.group_by is None:
                raise ValueError("COUNT needs at least one predicate term")
        elif not preds and self.target is None:
            raise ValueError("SUM/AVG needs a predicate term or a target column")


@dataclass(frozen=True)
class AqpResult:
    """One answered aggregate.

    estimate         — the approximate answer
    path             — execution path: "range1d" | "box" | "qmc"
                       (":pallas" suffix when the Pallas tile kernels ran)
    rel_width        — accuracy proxy: the narrowest constrained axis measured
                       in bandwidths, min_j (hi_j - lo_j) / h_j.  Small values
                       (below ~2) mean the kernel smoothing dominates the mass
                       in the box, so expect higher relative error; inf when
                       no axis is constrained (whole-table SUM/AVG).
    synopsis_version — reservoir version of the synopsis that answered it
                       (0 when executed against bare synopses, not a store)
    group            — group_by category code (None outside GROUP BY)
    query            — the originating AqpQuery spec
    """
    estimate: float
    path: str
    rel_width: float
    synopsis_version: int
    group: Optional[float] = None
    query: Optional[AqpQuery] = None

    def __float__(self) -> float:
        return self.estimate


# --- normalization: AqpQuery -> one axis-aligned box per (sub-)query --------

@dataclass
class _Compiled:
    """One execution unit: an axis-aligned box (possibly with wide, i.e.
    unconstrained, axes) plus the aggregate opcode and target axis."""
    slot: int                            # output row
    query: AqpQuery
    group: Optional[float]
    cols: Optional[Tuple[str, ...]]      # None -> positional (bare synopsis)
    lo: List[float]
    hi: List[float]
    constrained: List[bool]              # wide target fills are False
    op: int
    tgt: int
    selector: Optional[str]


def _compile(query: AqpQuery, slot: int,
             group_value: Optional[float] = None) -> _Compiled:
    """Normalize one query (plus its group term) to a canonical box: terms
    merge per column by interval intersection, SUM/AVG targets outside the
    predicate columns get a wide (unconstrained) axis."""
    intervals: "Dict[Union[str, int], List]" = {}
    named: Optional[bool] = None

    def add(key, lo_v, hi_v, is_named):
        nonlocal named
        if named is None:
            named = is_named
        elif named != is_named:
            raise ValueError("cannot mix named and positional (column=None) "
                             "predicate terms in one AqpQuery")
        ent = intervals.get(key)
        if ent is None:
            intervals[key] = [float(lo_v), float(hi_v), True]
        else:
            ent[0] = max(ent[0], float(lo_v))
            ent[1] = min(ent[1], float(hi_v))
            if ent[1] < ent[0]:           # empty conjunction -> zero measure
                ent[1] = ent[0]

    for p in query.predicates:
        if isinstance(p, Range):
            add(p.column if p.column is not None else 0, p.a, p.b,
                p.column is not None)
        elif isinstance(p, Eq):
            add(p.column if p.column is not None else 0,
                p.value - p.halfwidth, p.value + p.halfwidth,
                p.column is not None)
        else:
            if p.columns is None:
                for j, (lo_v, hi_v) in enumerate(zip(p.lo, p.hi)):
                    add(j, lo_v, hi_v, False)
            else:
                for c, lo_v, hi_v in zip(p.columns, p.lo, p.hi):
                    add(c, lo_v, hi_v, True)

    # Implicit-target resolution runs BEFORE the group term is appended:
    # "SUM(b) WHERE ... GROUP BY code" has one predicate column even though
    # the executed box gains the code axis.
    tgt = 0
    if query.aggregate in ("sum", "avg"):
        t = query.target
        if t is None:
            if len(intervals) != 1:
                raise ValueError("SUM/AVG needs an explicit target unless "
                                 "exactly one predicate column is given")
        elif isinstance(t, bool):
            raise TypeError("target must be a column name or axis index")
        elif isinstance(t, (int, np.integer)):
            if not 0 <= int(t) < len(intervals):
                raise ValueError(f"target axis {t} out of range for "
                                 f"d={len(intervals)}")
            tgt = int(t)
        else:
            if named is False:
                raise ValueError("a string target needs named predicate "
                                 "columns")
            if t not in intervals:
                named = True
                intervals[t] = [-WIDE, WIDE, False]
            tgt = list(intervals).index(t)

    if group_value is not None:
        g = query.group_by
        add(g.column, group_value - EQ_HALFWIDTH, group_value + EQ_HALFWIDTH,
            True)

    if named is False:
        keys = sorted(intervals)
        if keys != list(range(len(keys))):
            raise ValueError(f"positional predicate axes must be contiguous "
                             f"from 0, got {keys}")
        items = [(k, intervals[k]) for k in keys]
        cols = None
    else:
        items = list(intervals.items())
        cols = tuple(k for k, _ in items)
    return _Compiled(
        slot=slot, query=query, group=group_value, cols=cols,
        lo=[e[0] for _, e in items], hi=[e[1] for _, e in items],
        constrained=[e[2] for _, e in items], op=OP_CODES[query.aggregate],
        tgt=tgt, selector=query.selector)


def _reorder(c: _Compiled, new_cols: Tuple[str, ...]) -> _Compiled:
    """Permute a compiled box to a tracked joint's axis order."""
    perm = [c.cols.index(col) for col in new_cols]
    return _Compiled(
        slot=c.slot, query=c.query, group=c.group, cols=new_cols,
        lo=[c.lo[j] for j in perm], hi=[c.hi[j] for j in perm],
        constrained=[c.constrained[j] for j in perm], op=c.op,
        tgt=perm.index(c.tgt), selector=c.selector)


# --- synopsis resolution ----------------------------------------------------

class _StoreResolver:
    """Maps a compiled query to a (group key, synopsis, version) against a
    TelemetryStore: single columns use the per-column reservoirs, multi-column
    boxes match a tracked joint (exact tuple first, then by column *set*,
    reordering the box to the joint's axis order)."""

    def __init__(self, store, selector: str):
        self.store = store
        self.selector = selector

    def __call__(self, c: _Compiled):
        # canonical: "Plugin" and "plugin" must land in ONE group (and one
        # cache entry), not two duplicate jitted passes over the same data
        sel = canonical_selector(c.selector or self.selector)
        if c.cols is None:
            raise ValueError("every query must name a column when running "
                             "against a TelemetryStore")
        if len(c.cols) == 1:
            col = c.cols[0]
            syn = self.store.synopsis(col, sel)
            return (col, sel), c, syn, self.store.columns[col].version
        cols = c.cols
        joints = self.store.joints
        if cols not in joints:
            match = next((k for k in joints if set(k) == set(cols)), None)
            if match is not None:
                c = _reorder(c, match)
                cols = match
        syn = self.store.joint_synopsis(cols, sel)   # KeyError: track_joint
        return (cols, sel), c, syn, joints[cols].version


class _MappingResolver:
    """Resolution against a bare synopsis or a {column(s): synopsis} mapping —
    the legacy-shim execution context (no store, no versions)."""

    def __init__(self, synopses):
        self.synopses = synopses

    def __call__(self, c: _Compiled):
        d = len(c.lo)
        if isinstance(self.synopses, KDESynopsis):
            if c.cols is not None:
                noun = "column" if d == 1 else "columns"
                raise ValueError(f"queries name columns but a single synopsis "
                                 f"was given; pass a {{{noun}: synopsis}} "
                                 f"mapping")
            return None, c, self.synopses, 0
        if c.cols is None:
            if d == 1:
                raise ValueError("queries must name a column when running "
                                 "against a synopsis mapping")
            raise ValueError("queries must name their columns when running "
                             "against a synopsis mapping")
        key = c.cols[0] if len(c.cols) == 1 else c.cols
        if key not in self.synopses:
            # key=str for the listing: the unified mapping may mix plain
            # column keys with column tuples, which don't sort against
            # each other
            have = sorted(self.synopses, key=str)
            if len(c.cols) == 1:
                raise KeyError(f"no synopsis for column {key!r}; have {have}")
            raise KeyError(f"no joint synopsis for columns {key!r}; "
                           f"have {have}")
        return key, c, self.synopses[key], 0


# --- execution --------------------------------------------------------------

def _rel_width(c: _Compiled, h_axes: np.ndarray) -> float:
    widths = [(hi - lo) / h for lo, hi, k, h
              in zip(c.lo, c.hi, c.constrained, h_axes) if k]
    return float(min(widths)) if widths else float("inf")


def _execute(compiled: Sequence[_Compiled], n_out: int, resolver,
             backend: str = "jnp", n_qmc: int = 4096) -> List[AqpResult]:
    """Group compiled queries by resolved synopsis, answer each group in one
    batched pass on its execution path, scatter back to submission order."""
    groups: "Dict[object, dict]" = {}
    for c in compiled:
        key, c2, syn, version = resolver(c)
        g = groups.setdefault(key, {"syn": syn, "version": version,
                                    "entries": []})
        g["entries"].append(c2)

    results: List[Optional[AqpResult]] = [None] * n_out
    for key, g in groups.items():
        syn: KDESynopsis = g["syn"]
        entries: List[_Compiled] = g["entries"]
        x = syn.x[:, None] if syn.x.ndim == 1 else syn.x
        d_syn = x.shape[1]
        for c in entries:
            if len(c.lo) != d_syn:
                if len(c.lo) == 1:
                    raise ValueError(
                        "multi-dimensional synopses answer box predicates, "
                        "not scalar ranges; add one term per axis (legacy: "
                        "BoxQueryBatch, repro.core.aqp_multid)")
                raise ValueError(f"synopsis for {key} is {d_syn}-d but its "
                                 f"queries are {len(c.lo)}-d boxes")
        scale = jnp.float32(syn.n_source / x.shape[0])
        ops_np = np.asarray([c.op for c in entries], np.int32)
        if syn.H is not None:
            lo = np.asarray([c.lo for c in entries], np.float64)
            hi = np.asarray([c.hi for c in entries], np.float64)
            tgt = np.asarray([c.tgt for c in entries], np.int32)
            ans = batch_query_qmc(x, syn.H, lo, hi, tgt, ops_np, scale,
                                  n_qmc=n_qmc)
            path = "qmc"
            h_axes = np.sqrt(np.diag(np.asarray(syn.H, np.float64)))
        elif syn.x.ndim == 1:
            a = jnp.asarray([c.lo[0] for c in entries], jnp.float32)
            b = jnp.asarray([c.hi[0] for c in entries], jnp.float32)
            ans = batch_query_1d(syn.x, syn.h, a, b, jnp.asarray(ops_np),
                                 scale, backend=backend)
            path = "range1d" if backend == "jnp" else f"range1d:{backend}"
            h_axes = np.asarray([float(syn.h)], np.float64)
        else:
            lo = jnp.asarray([c.lo for c in entries], jnp.float32)
            hi = jnp.asarray([c.hi for c in entries], jnp.float32)
            tgt = jnp.asarray([c.tgt for c in entries], jnp.int32)
            ans = batch_query_box(x, syn.h_diag(), lo, hi, tgt,
                                  jnp.asarray(ops_np), scale, backend=backend)
            path = "box" if backend == "jnp" else f"box:{backend}"
            h_axes = np.asarray(syn.h_diag(), np.float64)
        ans_np = np.asarray(ans, np.float64)
        for c, est in zip(entries, ans_np):
            results[c.slot] = AqpResult(
                estimate=float(est), path=path,
                rel_width=_rel_width(c, h_axes),
                synopsis_version=g["version"], group=c.group, query=c.query)
    return results


# --- the facade -------------------------------------------------------------

class QueryEngine:
    """Single entry point for AQP batches against a `TelemetryStore`.

    A heterogeneous batch — 1-D ranges, multi-d boxes, categorical equality,
    GROUP BY expansions, mixed selectors — is normalized, grouped by
    (column tuple, selector), and each group is answered in one batched call
    on its execution path (closed forms, eq. 11 product kernel, the Pallas
    tile kernels, or the batched quasi-MC fallback for full-H synopses).

        engine = QueryEngine(store)                # or store.engine()
        results = engine.execute([
            AqpQuery("count", (Range("loss", 1.0, 4.0),)),
            AqpQuery("avg", (Box(("loss", "latency_ms"), (1, 20), (4, 60)),),
                     target="latency_ms"),
            AqpQuery("count", (Eq("model_id", 2),)),
        ])
    """

    def __init__(self, store, selector: str = "plugin", backend: str = "jnp",
                 n_qmc: int = 4096, max_groups: int = 64):
        self.store = store
        self.selector = selector
        self.backend = backend
        self.n_qmc = n_qmc
        self.max_groups = max_groups

    def execute(self, queries: Union[AqpQuery, Sequence[AqpQuery]],
                selector: Optional[str] = None,
                backend: Optional[str] = None) -> List[AqpResult]:
        """Answer a batch of AqpQuery specs; one AqpResult per query (one per
        group value for GROUP BY queries, in discovered/declared order)."""
        if isinstance(queries, AqpQuery):
            queries = [queries]
        compiled: List[_Compiled] = []
        for q in queries:
            if not isinstance(q, AqpQuery):
                raise TypeError(f"QueryEngine.execute takes AqpQuery specs, "
                                f"got {type(q).__name__}")
            for gv in self._group_values(q):
                compiled.append(_compile(q, len(compiled), group_value=gv))
        resolver = _StoreResolver(self.store, selector or self.selector)
        return _execute(compiled, len(compiled), resolver,
                        backend=backend or self.backend, n_qmc=self.n_qmc)

    def answers(self, queries, **kw) -> np.ndarray:
        """`execute`, reduced to the estimates (submission order)."""
        return np.asarray([r.estimate for r in self.execute(queries, **kw)],
                          np.float64)

    def _group_values(self, q: AqpQuery) -> List[Optional[float]]:
        if q.group_by is None:
            return [None]
        gb = q.group_by
        if gb.values is not None:
            return list(gb.values)
        res = self.store.columns.get(gb.column)
        if res is None:
            raise KeyError(f"unknown group_by column {gb.column!r}; "
                           f"have {sorted(self.store.columns)}")
        codes = np.unique(np.round(res.sample().astype(np.float64)))
        if codes.size == 0:
            raise ValueError(f"group_by column {gb.column!r} has no data")
        if codes.size > self.max_groups:
            raise ValueError(
                f"group_by {gb.column!r} has {codes.size} distinct codes "
                f"(max_groups={self.max_groups}); pass "
                f"GroupBy({gb.column!r}, values=...) to pin the categories")
        return [float(v) for v in codes]


# --- legacy bridges (QueryBatch / BoxQueryBatch shims) ----------------------

def from_query(q) -> AqpQuery:
    """Compile a legacy 1-D `Query` to an AqpQuery spec."""
    return AqpQuery(q.op, (Range(q.column, q.a, q.b),))


def from_box_query(q) -> AqpQuery:
    """Compile a legacy `BoxQuery` to an AqpQuery spec."""
    target = None if q.op == "count" else q.target_index()
    return AqpQuery(q.op, (Box(q.columns, q.lo, q.hi),), target=target)


def execute_specs(specs: Sequence[AqpQuery], synopses,
                  backend: str = "jnp", n_qmc: int = 4096) -> np.ndarray:
    """Execute AqpQuery specs against a bare synopsis or a mapping (the
    legacy-shim context); returns estimates in submission order.

    GROUP BY expansion and per-query selector overrides need a store (the
    category discovery and the re-fit both live there), so specs carrying
    them are rejected here rather than silently half-executed.
    """
    for q in specs:
        if q.group_by is not None:
            raise ValueError("group_by needs a store-backed QueryEngine; "
                             "execute_specs runs against pre-fitted synopses")
        if q.selector is not None:
            raise ValueError("a per-query selector override needs a "
                             "store-backed QueryEngine; execute_specs runs "
                             "against pre-fitted synopses")
    compiled = [_compile(q, i) for i, q in enumerate(specs)]
    res = _execute(compiled, len(compiled), _MappingResolver(synopses),
                   backend=backend, n_qmc=n_qmc)
    return np.asarray([r.estimate for r in res], np.float64)
