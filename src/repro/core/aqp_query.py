"""Unified declarative AQP query API — one spec, one engine, many paths.

After the 1-D (`Query`/`QueryBatch`) and multi-d (`BoxQuery`/`BoxQueryBatch`)
stacks, this module makes the *query surface* the product (cf. VerdictDB's
single logical query interface over many execution backends, and DEANN's
estimator-contract / acceleration-backend split):

  `AqpQuery`   — a declarative aggregate: COUNT/SUM/AVG under a conjunction of
                 predicate terms, optionally grouped by a dictionary column.
      Range(column, a, b)   a <= column <= b        (eqs. 9-10 closed forms)
      Box(columns, lo, hi)  axis-aligned box        (eq. 11 product kernel)
      Eq(column, value)     dictionary/categorical equality (code +- 1/2)
  `QueryEngine` — the facade over a `TelemetryStore`: normalizes/validates a
                 heterogeneous batch, groups it by (column tuple, selector),
                 and routes each group to the cheapest applicable path:

      path      synopsis                 kernel
      -------   ----------------------   -----------------------------------
      range1d   1-D sample, scalar h     closed forms (`batch_query_1d`, or
                                         the Pallas `aqp_batch` tile kernel)
      box       rows, diagonal h         eq. 11 product kernel
                                         (`batch_query_box` / Pallas
                                         `aqp_boxes` tiles)
      qmc       full bandwidth matrix H  batched quasi-MC: shared Halton
                                         nodes, ONE KDE pass per group
                                         (`batch_query_qmc`)

  `AqpResult`  — estimate + the chosen path, a relative-width accuracy proxy,
                 and the synopsis version that answered the query.

The legacy stacks survive as deprecated shims: `QueryBatch.run` /
`BoxQueryBatch.run` compile their queries to `AqpQuery` specs and execute
through this module, bit-for-bit identical to `QueryEngine.execute`.
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels.tuning import env_int

from .aqp import (OP_CODES, OP_COUNT, OP_SUM, KDESynopsis,
                  batch_query_1d, canonical_selector)
from .aqp_ci import (DEFAULT_CI_LEVEL, moments_1d, moments_box, norm_ppf,
                     qmc_subsample_se, se_from_moments, t_ppf)
from .aqp_multid import (batch_query_box, batch_query_box_grouped,
                         batch_query_qmc, batch_query_qmc_rff, qmc_rff_se)

ColumnKey = Union[None, str, Tuple[str, ...]]

EQ_HALFWIDTH = 0.5   # dictionary codes are unit-spaced: `== v` is v +- 1/2
WIDE = 1e30          # "unconstrained axis": Phi saturates to {0,1}, phi to 0

# --- density-synopsis backend selection (repro.synopses) --------------------
#
# The quasi-MC path's density pass is pluggable: "exact" is the direct
# kde_eval_H evaluation (O(n) per node, bit-identical to the pre-backend
# engine), "rff" the sublinear random-Fourier-feature synopsis (O(D) per
# node after an O(n*D) once-per-version fit).  "auto" picks by sample size:
# below the crossover the exact pass is already cheap and the RFF fit would
# never amortize.
KDE_BACKENDS = ("auto", "exact", "rff")
# Module constants are the *defaults*; the env knobs are re-read per call
# (an import-time env_int froze them before a late env change could move
# them — the same bug PR 9 fixed for kernel tiles).  Tests monkeypatch the
# constants; the env vars still win when set.
KDE_CROSSOVER = 16384
DEFAULT_RFF_FEATURES = 2048
# one-shot empirical accuracy gate at fit time: mean relative density error
# on probe points from the fitted sample; above tolerance the synopsis is
# marked degraded and the group falls back to the exact pass (counted)
RFF_GATE_PROBES = 32
RFF_GATE_TOL = 0.15


def _kde_crossover() -> int:
    return env_int("REPRO_KDE_CROSSOVER", KDE_CROSSOVER)


def _rff_features() -> int:
    return env_int("REPRO_RFF_FEATURES", DEFAULT_RFF_FEATURES)


def _resolve_kde_backend(requested: Optional[str], default: str,
                         n: int) -> str:
    name = requested or default or "auto"
    if name == "auto":
        return "rff" if n >= _kde_crossover() else "exact"
    return name


def _rff_cache_key(col, n_features: int):
    """SynopsisCache column key for a fitted RFF synopsis — suffixed like
    `_tier_key` so RFF state coexists with the exact synopsis entry and
    round-trips the checkpoint serializer untouched."""
    if isinstance(col, tuple):
        return col + (f"#rff{n_features}",)
    return f"{col}#rff{n_features}"


# --- tier addressing (TieredReservoir, repro.data.aqp_store) ----------------

def _effective_tier(res, tier: Optional[int]) -> Optional[int]:
    """Normalize a tier request against a reservoir: None (or a plain
    untiered reservoir) means the full sample, and a request for the top
    tier of a `TieredReservoir` collapses to None too — the top tier IS the
    full sample, so full-accuracy requests share cache keys, plans, and
    jitted executables with untiered execution."""
    n_tiers = getattr(res, "n_tiers", None)
    if tier is None or n_tiers is None:
        return None
    t = max(0, min(int(tier), n_tiers - 1))
    return None if t >= n_tiers - 1 else t


def _tier_key(col, tier: Optional[int]):
    """Suffix a synopsis-cache column key with the tier so tiered synopses
    coexist with the full-sample entry.  '#' cannot appear in a tracked
    column tuple's joint (names are user column names), and the suffixed key
    round-trips through the checkpoint cache serialization untouched."""
    if tier is None:
        return col
    if isinstance(col, tuple):
        return col + (f"#tier{tier}",)
    return f"{col}#tier{tier}"


# --- predicate terms --------------------------------------------------------

@dataclass(frozen=True)
class Range:
    """a <= column <= b.  `column=None` addresses a bare (unnamed) synopsis."""
    column: Optional[str]
    a: float
    b: float

    def __post_init__(self):
        object.__setattr__(self, "a", float(self.a))
        object.__setattr__(self, "b", float(self.b))


@dataclass(frozen=True)
class Eq:
    """Dictionary/categorical equality: column == value.

    Dictionary-coded columns hold unit-spaced numeric codes, so equality is
    the range [value - halfwidth, value + halfwidth] over the code axis — the
    KDE mass the synopsis assigns to that code's bucket.
    """
    column: Optional[str]
    value: float
    halfwidth: float = EQ_HALFWIDTH

    def __post_init__(self):
        object.__setattr__(self, "value", float(self.value))
        object.__setattr__(self, "halfwidth", float(self.halfwidth))
        if self.halfwidth <= 0:
            raise ValueError(f"Eq halfwidth must be positive, got {self.halfwidth}")


@dataclass(frozen=True)
class Box:
    """Axis-aligned box: lo_j <= columns_j <= hi_j.  `columns=None` addresses
    the positional axes of a bare (unnamed) multi-d synopsis."""
    columns: Optional[Tuple[str, ...]]
    lo: Tuple[float, ...]
    hi: Tuple[float, ...]

    def __post_init__(self):
        object.__setattr__(self, "lo", tuple(float(v) for v in np.ravel(self.lo)))
        object.__setattr__(self, "hi", tuple(float(v) for v in np.ravel(self.hi)))
        if len(self.lo) != len(self.hi):
            raise ValueError(f"lo/hi dimensionality mismatch: "
                             f"{len(self.lo)} vs {len(self.hi)}")
        if self.columns is not None:
            object.__setattr__(self, "columns", tuple(self.columns))
            if len(self.columns) != len(self.lo):
                raise ValueError(f"box has {len(self.lo)} axes but names "
                                 f"{len(self.columns)} columns")


Predicate = Union[Range, Box, Eq]


@dataclass(frozen=True)
class GroupBy:
    """GROUP BY over a dictionary column.  `values=None` discovers the code
    set from the store's reservoir sample at execution time."""
    column: str
    values: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.values is not None:
            object.__setattr__(self, "values",
                               tuple(float(v) for v in self.values))


@dataclass(frozen=True)
class AqpQuery:
    """One declarative aggregate: COUNT/SUM/AVG of `target` under the
    conjunction of `predicates`, optionally per `group_by` category.

    `selector` overrides the engine's bandwidth selector for this query only
    (e.g. one `lscv_H` query inside a `plugin` batch routes to the quasi-MC
    path while the rest stay on the closed forms).

    `kde_backend` overrides the engine's density-synopsis backend for this
    query only ("auto" | "exact" | "rff"); it matters only on the quasi-MC
    (full-H) path and is ignored by the closed-form and exact-sketch paths.
    """
    aggregate: str                               # "count" | "sum" | "avg"
    predicates: Tuple[Predicate, ...] = ()
    target: Optional[Union[str, int]] = None     # SUM/AVG column (or axis)
    group_by: Optional[Union[str, "GroupBy"]] = None
    selector: Optional[str] = None               # per-query selector override
    kde_backend: Optional[str] = None            # per-query density backend

    def __post_init__(self):
        if self.kde_backend is not None:
            kb = str(self.kde_backend).lower()
            if kb not in KDE_BACKENDS:
                raise ValueError(f"unknown kde_backend {self.kde_backend!r}; "
                                 f"expected one of {KDE_BACKENDS}")
            object.__setattr__(self, "kde_backend", kb)
        agg = str(self.aggregate).lower()
        if agg not in OP_CODES:
            raise ValueError(f"unknown aggregate {self.aggregate!r}; "
                             f"expected one of {sorted(OP_CODES)}")
        object.__setattr__(self, "aggregate", agg)
        preds = self.predicates
        if isinstance(preds, (Range, Box, Eq)):
            preds = (preds,)
        preds = tuple(preds)
        for p in preds:
            if not isinstance(p, (Range, Box, Eq)):
                raise TypeError(f"predicate terms must be Range/Box/Eq, "
                                f"got {type(p).__name__}")
        object.__setattr__(self, "predicates", preds)
        if isinstance(self.group_by, str):
            object.__setattr__(self, "group_by", GroupBy(self.group_by))
        if self.group_by is not None and not isinstance(self.group_by, GroupBy):
            raise TypeError("group_by must be a column name or GroupBy")
        if agg == "count":
            if self.target is not None:
                raise ValueError("COUNT takes no target column")
            if not preds and self.group_by is None:
                raise ValueError("COUNT needs at least one predicate term")
        elif not preds and self.target is None:
            raise ValueError("SUM/AVG needs a predicate term or a target column")


@dataclass(frozen=True)
class AqpResult:
    """One answered aggregate.

    estimate         — the approximate answer
    path             — execution path: "range1d" | "box" | "qmc" | "exact"
                       | "exact:cm" (":pallas" suffix when the Pallas tile
                       kernels ran; "box:grouped" for GROUP BY families
                       answered by the factored grouped kernel; "exact"
                       answers come from a CategoricalSketch, "exact:cm"
                       from a bounded-error CountMinSketch — not the KDE;
                       "qmc:rff" when the full-H density pass ran on the
                       sublinear random-Fourier-feature synopsis backend)
    ci_lo / ci_hi    — confidence interval at `ci_level`, computed per path:
                       analytic product-kernel variance for range1d/box (and
                       box:grouped), subsample (batch-means) variance for
                       qmc, exact zero width for "exact", and the count-min
                       error bound for "exact:cm".  Infinite endpoints mean
                       the estimate carries no finite error bound (e.g. AVG
                       over an effectively empty selection).
    ci_level         — nominal coverage of [ci_lo, ci_hi] (default 0.95)
    n_effective      — rows behind the answer: the retained sample size for
                       the KDE paths (the tier size under a tier budget),
                       the sketch's full row count for the exact paths
    rel_width        — DEPRECATED accuracy proxy (narrowest constrained axis
                       in bandwidths, min_j (hi_j - lo_j) / h_j); kept for
                       one release, prefer the CI fields.  0.0 on the exact
                       paths (no smoothing at all); inf only when no axis is
                       constrained (whole-table SUM/AVG).
    synopsis_version — reservoir version of the synopsis that answered it
                       (0 when executed against bare synopses, not a store)
    group            — group_by category code (None outside GROUP BY)
    query            — the originating AqpQuery spec
    """
    estimate: float
    path: str
    rel_width: float
    synopsis_version: int
    group: Optional[float] = None
    query: Optional[AqpQuery] = None
    ci_lo: float = float("nan")
    ci_hi: float = float("nan")
    ci_level: float = DEFAULT_CI_LEVEL
    n_effective: int = 0

    @property
    def ci_width(self) -> float:
        return self.ci_hi - self.ci_lo

    def __float__(self) -> float:
        return self.estimate


# --- normalization: AqpQuery -> one axis-aligned box per (sub-)query --------

@dataclass
class _Compiled:
    """One execution unit: an axis-aligned box (possibly with wide, i.e.
    unconstrained, axes) plus the aggregate opcode and target axis."""
    slot: int                            # output row
    query: AqpQuery
    group: Optional[float]
    cols: Optional[Tuple[str, ...]]      # None -> positional (bare synopsis)
    lo: List[float]
    hi: List[float]
    constrained: List[bool]              # wide target fills are False
    op: int
    tgt: int
    selector: Optional[str]
    all_eq: bool = False                 # every interval is a code window
    group_axis: Optional[int] = None     # axis of the group_by column
    kde_backend: Optional[str] = None    # per-query density backend


def _compile(query: AqpQuery, slot: int,
             group_value: Optional[float] = None) -> _Compiled:
    """Normalize one query (plus its group term) to a canonical box: terms
    merge per column by interval intersection, SUM/AVG targets outside the
    predicate columns get a wide (unconstrained) axis."""
    intervals: "Dict[Union[str, int], List]" = {}
    eq_only: "Dict[Union[str, int], bool]" = {}
    named: Optional[bool] = None

    def add(key, lo_v, hi_v, is_named, is_eq=False):
        nonlocal named
        if named is None:
            named = is_named
        elif named != is_named:
            raise ValueError("cannot mix named and positional (column=None) "
                             "predicate terms in one AqpQuery")
        eq_only[key] = eq_only.get(key, True) and is_eq
        ent = intervals.get(key)
        if ent is None:
            intervals[key] = [float(lo_v), float(hi_v), True]
        else:
            ent[0] = max(ent[0], float(lo_v))
            ent[1] = min(ent[1], float(hi_v))
            if ent[1] < ent[0]:           # empty conjunction -> zero measure
                ent[1] = ent[0]

    for p in query.predicates:
        if isinstance(p, Range):
            add(p.column if p.column is not None else 0, p.a, p.b,
                p.column is not None)
        elif isinstance(p, Eq):
            add(p.column if p.column is not None else 0,
                p.value - p.halfwidth, p.value + p.halfwidth,
                p.column is not None, is_eq=True)
        else:
            if p.columns is None:
                for j, (lo_v, hi_v) in enumerate(zip(p.lo, p.hi)):
                    add(j, lo_v, hi_v, False)
            else:
                for c, lo_v, hi_v in zip(p.columns, p.lo, p.hi):
                    add(c, lo_v, hi_v, True)

    # Implicit-target resolution runs BEFORE the group term is appended:
    # "SUM(b) WHERE ... GROUP BY code" has one predicate column even though
    # the executed box gains the code axis.
    tgt = 0
    if query.aggregate in ("sum", "avg"):
        t = query.target
        if t is None:
            if len(intervals) != 1:
                raise ValueError("SUM/AVG needs an explicit target unless "
                                 "exactly one predicate column is given")
        elif isinstance(t, bool):
            raise TypeError("target must be a column name or axis index")
        elif isinstance(t, (int, np.integer)):
            if not 0 <= int(t) < len(intervals):
                raise ValueError(f"target axis {t} out of range for "
                                 f"d={len(intervals)}")
            tgt = int(t)
        else:
            if named is False:
                raise ValueError("a string target needs named predicate "
                                 "columns")
            if t not in intervals:
                named = True
                intervals[t] = [-WIDE, WIDE, False]
                eq_only[t] = False
            tgt = list(intervals).index(t)

    if group_value is not None:
        g = query.group_by
        # the group term is a dictionary-code window, i.e. an Eq term
        add(g.column, group_value - EQ_HALFWIDTH, group_value + EQ_HALFWIDTH,
            True, is_eq=True)

    if named is False:
        keys = sorted(intervals)
        if keys != list(range(len(keys))):
            raise ValueError(f"positional predicate axes must be contiguous "
                             f"from 0, got {keys}")
        items = [(k, intervals[k]) for k in keys]
        cols = None
    else:
        items = list(intervals.items())
        cols = tuple(k for k, _ in items)
    group_axis = None
    if group_value is not None and cols is not None:
        group_axis = cols.index(query.group_by.column)
    return _Compiled(
        slot=slot, query=query, group=group_value, cols=cols,
        lo=[e[0] for _, e in items], hi=[e[1] for _, e in items],
        constrained=[e[2] for _, e in items], op=OP_CODES[query.aggregate],
        tgt=tgt, selector=query.selector,
        all_eq=all(eq_only[k] for k, _ in items), group_axis=group_axis,
        kde_backend=query.kde_backend)


def _reorder(c: _Compiled, new_cols: Tuple[str, ...]) -> _Compiled:
    """Permute a compiled box to a tracked joint's axis order."""
    perm = [c.cols.index(col) for col in new_cols]
    return _Compiled(
        slot=c.slot, query=c.query, group=c.group, cols=new_cols,
        lo=[c.lo[j] for j in perm], hi=[c.hi[j] for j in perm],
        constrained=[c.constrained[j] for j in perm], op=c.op,
        tgt=perm.index(c.tgt), selector=c.selector, all_eq=c.all_eq,
        group_axis=None if c.group_axis is None else perm.index(c.group_axis),
        kde_backend=c.kde_backend)


# --- group plans and synopsis resolution ------------------------------------

@dataclass
class _GroupPlan:
    """Execution plan for one (column tuple, selector) group: the resolved
    synopsis plus everything derivable from it alone — the execution path,
    per-axis bandwidths for the accuracy proxy, and the sample->relation
    scale.  Cached by the engine keyed on the synopsis version so repeated
    flushes against an unchanged reservoir skip re-resolution."""
    syn: KDESynopsis
    kind: str                 # "range1d" | "box" | "qmc"
    h_axes: np.ndarray
    scale: float

    @property
    def x_rows(self) -> jnp.ndarray:
        return self.syn.x[:, None] if self.syn.x.ndim == 1 else self.syn.x


def _make_plan(syn: KDESynopsis) -> _GroupPlan:
    x = syn.x[:, None] if syn.x.ndim == 1 else syn.x
    if syn.H is not None:
        kind = "qmc"
        h_axes = np.sqrt(np.diag(np.asarray(syn.H, np.float64)))
    elif syn.x.ndim == 1:
        kind = "range1d"
        h_axes = np.asarray([float(syn.h)], np.float64)
    else:
        kind = "box"
        h_axes = np.asarray(syn.h_diag(), np.float64)
    return _GroupPlan(syn=syn, kind=kind, h_axes=h_axes,
                      scale=syn.n_source / x.shape[0])


class PlanCache:
    """Version-keyed memo of `_GroupPlan`s, owned by a QueryEngine.  An entry
    whose stored version differs from the reservoir's current version misses
    (add_batch therefore invalidates implicitly, same contract as the
    SynopsisCache underneath)."""

    def __init__(self, metrics: Optional[obs.MetricsRegistry] = None):
        self._entries: Dict[object, Tuple[int, _GroupPlan]] = {}
        self.hits = 0
        self.misses = 0
        # registry mirror (aqp.plan.hits/misses), resolved once
        if metrics is not None:
            self._m_hits = metrics.counter("aqp.plan.hits")
            self._m_misses = metrics.counter("aqp.plan.misses")
        else:
            self._m_hits = self._m_misses = None

    def get(self, key, version: int) -> Optional[_GroupPlan]:
        ent = self._entries.get(key)
        if ent is not None and ent[0] == version:
            self.hits += 1
            if self._m_hits is not None:
                self._m_hits.inc()
            return ent[1]
        self.misses += 1
        if self._m_misses is not None:
            self._m_misses.inc()
        return None

    def put(self, key, version: int, plan: _GroupPlan) -> None:
        self._entries[key] = (version, plan)

    def entries(self) -> List[Tuple[object, int]]:
        """[(key, version)] for every live entry — the checkpoint
        serializer's view (plans rebuild from persisted synopses on
        restore, so only the keys need to be durable)."""
        return [(key, version) for key, (version, _plan)
                in self._entries.items()]

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._entries)}


class _StoreResolver:
    """Maps a compiled query to a (group key, plan, version) against a
    TelemetryStore: single columns use the per-column reservoirs, multi-column
    boxes match a tracked joint (exact tuple first, then by column *set*,
    reordering the box to the joint's axis order).

    `key_for` is the cheap half (no synopsis fit) — the admission layer uses
    it to bucket pending queries without forcing a fit at submit time.

    `tier` (a `TieredReservoir` tier index, None for the full sample) rides
    in the group key, so a coarse-tier flush and a full-accuracy flush over
    the same column resolve to distinct plans and synopses.
    """

    def __init__(self, store, selector: str,
                 plans: Optional[PlanCache] = None,
                 tier: Optional[int] = None):
        self.store = store
        self.selector = selector
        self.plans = plans
        self.tier = tier

    def key_for(self, c: _Compiled):
        """(group key, reordered compiled, reservoir version) — no fitting."""
        # canonical: "Plugin" and "plugin" must land in ONE group (and one
        # cache entry), not two duplicate jitted passes over the same data
        sel = canonical_selector(c.selector or self.selector)
        if c.cols is None:
            raise ValueError("every query must name a column when running "
                             "against a TelemetryStore")
        if len(c.cols) == 1:
            col = c.cols[0]
            res = self.store.columns.get(col)
            if res is None:
                raise KeyError(f"unknown column {col!r}; "
                               f"have {sorted(self.store.columns)}")
            return (col, sel, _effective_tier(res, self.tier)), c, res.version
        cols = c.cols
        joints = self.store.joints
        if cols not in joints:
            match = next((k for k in joints if set(k) == set(cols)), None)
            if match is not None:
                c = _reorder(c, match)
                cols = match
            else:
                raise KeyError(f"no joint reservoir for columns {cols!r}; "
                               f"call track_joint({cols!r}) before add_batch "
                               f"(have {sorted(joints)})")
        res = joints[cols]
        return (cols, sel, _effective_tier(res, self.tier)), c, res.version

    def plan_for(self, key, version: int) -> _GroupPlan:
        """Fit-or-fetch the group's plan for the given reservoir version."""
        if self.plans is not None:
            plan = self.plans.get(key, version)
            if plan is not None:
                return plan
        col, sel, tier = key
        if isinstance(col, tuple):
            syn = self.store.joint_synopsis(col, sel, tier=tier)
        else:
            syn = self.store.synopsis(col, sel, tier=tier)
        plan = _make_plan(syn)
        if self.plans is not None:
            self.plans.put(key, version, plan)
        return plan

    def __call__(self, c: _Compiled):
        key, c2, version = self.key_for(c)
        return key, c2, self.plan_for(key, version), version

    def density_for(self, key, version: int, plan: _GroupPlan):
        """Fit-or-fetch the sublinear RFF density synopsis for a resolved
        full-H group; returns the fitted `RFFSynopsis` or None (exact pass).

        Fits live in the store's `SynopsisCache` next to the exact synopsis,
        keyed (column#rffD, selector) and invalidated by version like every
        other entry — they also persist through the store checkpoint, so a
        restored process serves warm.  A fit that fails the one-shot probe
        accuracy gate is cached *degraded* (no refit churn) and this returns
        None ever after, with the fallback counted per backend.
        """
        from repro.synopses import RFFSynopsis

        col, sel, tier = key
        syn = plan.syn
        if syn.H is None:
            return None
        n_features = _rff_features()
        ckey = _rff_cache_key(_tier_key(col, tier), n_features)
        cache = getattr(self.store, "cache", None)
        metrics = getattr(self.store, "metrics", None)
        if cache is not None:
            hit = cache.get(ckey, sel, version)
            if hit is not None:
                if hit.degraded and metrics is not None:
                    metrics.counter("aqp.synopsis.fallback",
                                    backend="rff").inc()
                return None if hit.degraded else hit
        x = plan.x_rows
        # the seed is a pure function of the (column, selector) identity so
        # refits after version bumps — and fits on other hosts — draw the
        # same frequencies
        seed = zlib.crc32(repr((ckey, sel)).encode()) & 0x7FFFFFFF
        t_fit = time.perf_counter()
        with obs.span("synopsis.fit", backend="rff", n=int(x.shape[0]),
                      n_features=n_features):
            rff = RFFSynopsis.fit(x, syn.H,
                                  n_features=n_features, seed=seed)
            # one-shot gate: mean relative density error on probe points
            # drawn from the fitted sample itself (where the mass is)
            from .kde import kde_eval_H
            probes = x[:RFF_GATE_PROBES]
            f_exact = np.asarray(kde_eval_H(probes, x, syn.H), np.float64)
            f_rff = np.asarray(rff.eval_batch(probes), np.float64)
            denom = max(float(np.mean(f_exact)), 1e-300)
            rff.probe_rel_err = float(np.mean(np.abs(f_rff - f_exact))
                                      / denom)
            rff.degraded = rff.probe_rel_err > RFF_GATE_TOL
        rff.n_source = syn.n_source
        rff.selector = sel
        if metrics is not None:
            metrics.histogram("aqp.synopsis.fit_us", backend="rff").observe(
                (time.perf_counter() - t_fit) * 1e6)
            if rff.degraded:
                metrics.counter("aqp.synopsis.fallback", backend="rff").inc()
        if cache is not None:
            cache.put(ckey, sel, version, rff)
        return None if rff.degraded else rff

    def try_exact(self, c: _Compiled):
        """Sketch answer for an all-Eq single-column query, when the column
        carries a categorical sketch covering its whole stream; returns
        (estimate, version, path, ci_lo, ci_hi, n_effective) or None (KDE
        fallback).  The path is "exact" for a `CategoricalSketch` (zero CI
        width) and "exact:cm" for the bounded-error `CountMinSketch` (CI
        from the deterministic over-count bound — count-min never
        under-counts, so the interval is one-sided for COUNT); a count-min
        window too wide to enumerate (range_terms -> None) falls back to
        the KDE too."""
        if not c.all_eq or c.cols is None or len(c.cols) != 1:
            return None
        col = c.cols[0]
        sketch = getattr(self.store, "categoricals", {}).get(col)
        res = self.store.columns.get(col)
        if sketch is None or res is None or not sketch.exact_for(res.n_seen):
            return None
        terms = sketch.range_terms(c.lo[0], c.hi[0])
        if terms is None:
            return None
        cnt, sm = terms
        if c.op == OP_COUNT:
            est = float(cnt)
        elif c.op == OP_SUM:
            est = float(sm)
        else:
            est = float(sm / cnt) if cnt > 0 else 0.0
        n_eff = int(sketch.n_rows)
        range_err = getattr(sketch, "range_err", None)
        if range_err is None:
            return est, res.version, sketch.path, est, est, n_eff
        err = range_err(c.lo[0], c.hi[0])
        if err is None:                       # raced the coverage gate
            return None
        cnt_err, sum_pos, sum_neg = err
        if c.op == OP_COUNT:
            ci_lo, ci_hi = max(0.0, est - cnt_err), est
        elif c.op == OP_SUM:
            # over-counted positive codes inflate the sum, over-counted
            # negative codes deflate it: the truth window is asymmetric
            ci_lo, ci_hi = sm - sum_pos, sm + sum_neg
        else:
            if cnt <= 0:
                ci_lo, ci_hi = -float("inf"), float("inf")
            else:
                nums = (sm - sum_pos, sm + sum_neg)
                dens = [d for d in (float(cnt), float(max(0, cnt - cnt_err)))
                        if d > 0]
                ratios = [n / d for n in nums for d in dens]
                ci_lo, ci_hi = min(ratios), max(ratios)
        return est, res.version, sketch.path, ci_lo, ci_hi, n_eff


class _MappingResolver:
    """Resolution against a bare synopsis or a {column(s): synopsis} mapping —
    the legacy-shim execution context (no store, no versions)."""

    def __init__(self, synopses):
        self.synopses = synopses
        self._plans: Dict[int, _GroupPlan] = {}   # keyed on synopsis identity

    def _plan(self, syn: KDESynopsis) -> _GroupPlan:
        plan = self._plans.get(id(syn))
        if plan is None:
            plan = self._plans[id(syn)] = _make_plan(syn)
        return plan

    def __call__(self, c: _Compiled):
        d = len(c.lo)
        if isinstance(self.synopses, KDESynopsis):
            if c.cols is not None:
                noun = "column" if d == 1 else "columns"
                raise ValueError(f"queries name columns but a single synopsis "
                                 f"was given; pass a {{{noun}: synopsis}} "
                                 f"mapping")
            return None, c, self._plan(self.synopses), 0
        if c.cols is None:
            if d == 1:
                raise ValueError("queries must name a column when running "
                                 "against a synopsis mapping")
            raise ValueError("queries must name their columns when running "
                             "against a synopsis mapping")
        key = c.cols[0] if len(c.cols) == 1 else c.cols
        if key not in self.synopses:
            # key=str for the listing: the unified mapping may mix plain
            # column keys with column tuples, which don't sort against
            # each other
            have = sorted(self.synopses, key=str)
            if len(c.cols) == 1:
                raise KeyError(f"no synopsis for column {key!r}; have {have}")
            raise KeyError(f"no joint synopsis for columns {key!r}; "
                           f"have {have}")
        return key, c, self._plan(self.synopses[key]), 0


# --- execution --------------------------------------------------------------

def _rel_width(c: _Compiled, h_axes: np.ndarray) -> float:
    widths = [(hi - lo) / h for lo, hi, k, h
              in zip(c.lo, c.hi, c.constrained, h_axes) if k]
    return float(min(widths)) if widths else float("inf")


# Batch shapes are quantized so a stream of variable-size micro-batch flushes
# reuses a handful of jitted executables instead of compiling per size: small
# batches round up to the next power of two (floor 8), larger ones to the next
# multiple of 64 (<= 63 padded rows, each a copy of the last real row, sliced
# off after the pass — per-row vmapped results are unaffected).
_PAD_STEP = 64


def _pad_count(n: int) -> int:
    if n >= _PAD_STEP:
        return -(-n // _PAD_STEP) * _PAD_STEP
    return max(8, 1 << max(n - 1, 0).bit_length())


def _pad_rows(arr: np.ndarray, m: int) -> np.ndarray:
    pad = m - arr.shape[0]
    if pad <= 0:
        return arr
    return np.concatenate([arr, np.repeat(arr[-1:], pad, axis=0)])


def _run_group(key, plan: _GroupPlan, entries: List[_Compiled],
               backend: str, n_qmc: int,
               ci_level: float = DEFAULT_CI_LEVEL,
               metrics: Optional[obs.MetricsRegistry] = None,
               tier: Optional[int] = None,
               kde_backend: str = "auto", rff=None
               ) -> List[Tuple[float, str, float, float, int]]:
    """Answer one resolved group in batched passes; returns one
    (estimate, path label, ci_lo, ci_hi, n_effective) per entry, in entry
    order.  The CI comes from a SEPARATE moments pass (aqp_ci) so the
    estimate kernels — and therefore the estimates — stay bit-identical to
    the pre-CI engine.

    GROUP BY families — entries expanded from one query that differ only on
    the group column's code window — are peeled off onto the factored grouped
    kernel (shared box terms evaluated once per flush) when the group runs the
    diagonal-bandwidth box path.
    """
    syn = plan.syn
    x = plan.x_rows
    d_syn = x.shape[1]
    for c in entries:
        if len(c.lo) != d_syn:
            if len(c.lo) == 1:
                raise ValueError(
                    "multi-dimensional synopses answer box predicates, "
                    "not scalar ranges; add one term per axis (legacy: "
                    "BoxQueryBatch, repro.core.aqp_multid)")
            raise ValueError(f"synopsis for {key} is {d_syn}-d but its "
                             f"queries are {len(c.lo)}-d boxes")
    scale = jnp.float32(plan.scale)

    families: List[List[_Compiled]] = []
    rest: List[_Compiled] = []
    if plan.kind == "box":
        by_query: Dict[int, List[_Compiled]] = {}
        for c in entries:
            if (c.group is not None and c.group_axis is not None):
                by_query.setdefault(id(c.query), []).append(c)
            else:
                rest.append(c)
        for fam in by_query.values():
            if len(fam) >= 2:
                families.append(fam)
            else:
                rest.extend(fam)
    else:
        rest = list(entries)

    n_eff = int(x.shape[0])
    p = 0.5 + ci_level / 2.0

    # Instrumentation below (spans, fences, histograms) only fires with
    # `repro.obs` enabled: the NOOP span costs one call, `obs.fence` returns
    # immediately, and the kernel invocations themselves are untouched — so
    # disabled-mode execution stays bit-identical with no extra jit traces
    # (both test-enforced).  Fencing inside the kernel/CI spans makes their
    # durations device-true instead of async-dispatch artifacts.
    enabled = obs.enabled()

    # Full-H entries whose resolved density backend is the fitted sublinear
    # synopsis peel off onto the RFF quasi-MC driver; everything else —
    # including every entry when the fit is missing or gated off (rff=None)
    # — continues through the UNTOUCHED legacy pass, so `kde_backend="exact"`
    # answers stay bit-identical to the pre-backend engine.
    rff_entries: List[_Compiled] = []
    if plan.kind == "qmc" and rff is not None:
        still_exact: List[_Compiled] = []
        for c in rest:
            if _resolve_kde_backend(c.kde_backend, kde_backend,
                                    n_eff) == "rff":
                rff_entries.append(c)
            else:
                still_exact.append(c)
        rest = still_exact

    out: Dict[int, Tuple[float, str, float, float, int]] = {}
    if rest:
        n = len(rest)
        m = _pad_count(n)
        t_grp = time.perf_counter() if enabled else 0.0
        ops_np = _pad_rows(np.asarray([c.op for c in rest], np.int32), m)
        if plan.kind == "qmc":
            lo = _pad_rows(np.asarray([c.lo for c in rest], np.float64), m)
            hi = _pad_rows(np.asarray([c.hi for c in rest], np.float64), m)
            tgt = _pad_rows(np.asarray([c.tgt for c in rest], np.int32), m)
            if metrics is not None:
                metrics.counter("aqp.synopsis.hits", backend="exact").inc(n)
            path = "qmc" if backend == "jnp" else f"qmc:{backend}"
            with obs.span("engine.kernel", path=path, n=n, tier=tier):
                ans = batch_query_qmc(x, syn.H, lo, hi, tgt, ops_np, scale,
                                      n_qmc=n_qmc, backend=backend)
                obs.fence(ans)
            with obs.span("engine.ci", path=path, n=n):
                se, dof = qmc_subsample_se(x, syn.H, lo, hi, tgt, ops_np,
                                           syn.n_source, n_qmc)
                obs.fence(se)
            q_ci = t_ppf(p, dof)
        elif plan.kind == "range1d":
            a = _pad_rows(np.asarray([c.lo[0] for c in rest], np.float32), m)
            b = _pad_rows(np.asarray([c.hi[0] for c in rest], np.float32), m)
            path = "range1d" if backend == "jnp" else f"range1d:{backend}"
            with obs.span("engine.kernel", path=path, n=n, tier=tier):
                ans = batch_query_1d(syn.x, syn.h, jnp.asarray(a),
                                     jnp.asarray(b), jnp.asarray(ops_np),
                                     scale, backend=backend)
                obs.fence(ans)
            with obs.span("engine.ci", path=path, n=n):
                mom = moments_1d(syn.x, syn.h, jnp.asarray(a), jnp.asarray(b))
                se = se_from_moments(ops_np, mom, plan.scale, n_eff)
                obs.fence(se)
            q_ci = norm_ppf(p)
        else:
            lo = _pad_rows(np.asarray([c.lo for c in rest], np.float32), m)
            hi = _pad_rows(np.asarray([c.hi for c in rest], np.float32), m)
            tgt = _pad_rows(np.asarray([c.tgt for c in rest], np.int32), m)
            path = "box" if backend == "jnp" else f"box:{backend}"
            with obs.span("engine.kernel", path=path, n=n, tier=tier):
                ans = batch_query_box(x, syn.h_diag(), jnp.asarray(lo),
                                      jnp.asarray(hi), jnp.asarray(tgt),
                                      jnp.asarray(ops_np), scale,
                                      backend=backend)
                obs.fence(ans)
            with obs.span("engine.ci", path=path, n=n):
                mom = moments_box(x, syn.h_diag(), jnp.asarray(lo),
                                  jnp.asarray(hi), jnp.asarray(tgt))
                se = se_from_moments(ops_np, mom, plan.scale, n_eff)
                obs.fence(se)
            q_ci = norm_ppf(p)
        ans_np = np.asarray(ans, np.float64)[:n]
        se_np = np.asarray(se, np.float64)[:n]
        if enabled and metrics is not None:
            metrics.histogram("aqp.query.latency_us", path=path,
                              tier=tier).observe(
                (time.perf_counter() - t_grp) * 1e6)
        for c, est, s in zip(rest, ans_np, se_np):
            est = float(est)
            out[id(c)] = (est, path, est - q_ci * s, est + q_ci * s, n_eff)

    if rff_entries:
        n = len(rff_entries)
        m = _pad_count(n)
        t_grp = time.perf_counter() if enabled else 0.0
        ops_np = _pad_rows(np.asarray([c.op for c in rff_entries], np.int32),
                           m)
        lo = _pad_rows(np.asarray([c.lo for c in rff_entries], np.float64), m)
        hi = _pad_rows(np.asarray([c.hi for c in rff_entries], np.float64), m)
        tgt = _pad_rows(np.asarray([c.tgt for c in rff_entries], np.int32), m)
        if metrics is not None:
            metrics.counter("aqp.synopsis.hits", backend="rff").inc(n)
        with obs.span("synopsis.eval", backend="rff", n=n,
                      n_features=rff.n_features):
            with obs.span("engine.kernel", path="qmc:rff", n=n, tier=tier):
                ans = batch_query_qmc_rff(x, syn.H, rff, lo, hi, tgt, ops_np,
                                          scale, n_qmc=n_qmc)
                obs.fence(ans)
        # feature-block batch-means SE (O(m*D)) — the sample-chunk subsample
        # CI of the exact path would cost the O(n) pass this backend avoids
        with obs.span("engine.ci", path="qmc:rff", n=n):
            se, dof = qmc_rff_se(rff, x, syn.H, lo, hi, tgt, ops_np,
                                 syn.n_source, n_qmc)
            obs.fence(se)
        q_ci = t_ppf(p, dof)
        ans_np = np.asarray(ans, np.float64)[:n]
        se_np = np.asarray(se, np.float64)[:n]
        if enabled and metrics is not None:
            lat = (time.perf_counter() - t_grp) * 1e6
            metrics.histogram("aqp.query.latency_us", path="qmc:rff",
                              tier=tier).observe(lat)
            metrics.histogram("aqp.synopsis.eval_us",
                              backend="rff").observe(lat)
        for c, est, s in zip(rff_entries, ans_np, se_np):
            est = float(est)
            out[id(c)] = (est, "qmc:rff",
                          est - q_ci * s, est + q_ci * s, n_eff)

    fam_path = ("box:grouped" if backend == "jnp"
                else f"box:grouped:{backend}")
    for fam in families:
        g_axis = fam[0].group_axis
        gm = _pad_count(len(fam))
        t_grp = time.perf_counter() if enabled else 0.0
        glo = _pad_rows(np.asarray([c.lo[g_axis] for c in fam], np.float32),
                        gm)
        ghi = _pad_rows(np.asarray([c.hi[g_axis] for c in fam], np.float32),
                        gm)
        with obs.span("engine.kernel", path=fam_path, n=len(fam),
                      tier=tier):
            ans = batch_query_box_grouped(
                x, syn.h_diag(), fam[0].lo, fam[0].hi, glo, ghi,
                g_axis=g_axis, tgt=fam[0].tgt, op=fam[0].op, scale=scale,
                backend=backend)
            obs.fence(ans)
        ans_np = np.asarray(ans, np.float64)[:len(fam)]
        # family moments run on the per-entry FULL boxes (each entry's box
        # already carries its group window from _compile)
        flo = _pad_rows(np.asarray([c.lo for c in fam], np.float32), gm)
        fhi = _pad_rows(np.asarray([c.hi for c in fam], np.float32), gm)
        ftgt = _pad_rows(np.asarray([c.tgt for c in fam], np.int32), gm)
        fops = np.full(gm, fam[0].op, np.int32)
        with obs.span("engine.ci", path=fam_path, n=len(fam)):
            mom = moments_box(x, syn.h_diag(), jnp.asarray(flo),
                              jnp.asarray(fhi), jnp.asarray(ftgt))
            se = se_from_moments(fops, mom, plan.scale, n_eff)
            obs.fence(se)
        se_np = np.asarray(se, np.float64)[:len(fam)]
        if enabled and metrics is not None:
            metrics.histogram("aqp.query.latency_us", path=fam_path,
                              tier=tier).observe(
                (time.perf_counter() - t_grp) * 1e6)
        q_ci = norm_ppf(p)
        for c, est, s in zip(fam, ans_np, se_np):
            est = float(est)
            out[id(c)] = (est, fam_path,
                          est - q_ci * s, est + q_ci * s, n_eff)

    return [out[id(c)] for c in entries]


def _execute(compiled: Sequence[_Compiled], n_out: int, resolver,
             backend: str = "jnp", n_qmc: int = 4096,
             ci_level: float = DEFAULT_CI_LEVEL,
             kde_backend: str = "auto") -> List[AqpResult]:
    """Answer compiled queries: exact categorical sketches first (when the
    resolver offers them), then group the rest by resolved synopsis, answer
    each group in batched passes on its execution path, and scatter back to
    submission order."""
    results: List[Optional[AqpResult]] = [None] * n_out
    try_exact = getattr(resolver, "try_exact", None)
    remaining: List[_Compiled] = []
    for c in compiled:
        hit = try_exact(c) if try_exact is not None else None
        if hit is not None:
            est, version, path, ci_lo, ci_hi, n_eff = hit
            # rel_width=0.0: an exact answer has NO smoothing — the proxy
            # must rank it best, not worst (inf is reserved for genuinely
            # unconstrained estimates)
            results[c.slot] = AqpResult(
                estimate=est, path=path, rel_width=0.0,
                synopsis_version=version, group=c.group, query=c.query,
                ci_lo=ci_lo, ci_hi=ci_hi, ci_level=ci_level,
                n_effective=n_eff)
        else:
            remaining.append(c)

    # store-backed resolvers expose the owning store's registry and their
    # tier budget; the mapping resolver (execute_specs) has neither
    metrics = getattr(getattr(resolver, "store", None), "metrics", None)
    tier = getattr(resolver, "tier", None)

    groups: "Dict[object, dict]" = {}
    with obs.span("engine.plan", n=len(remaining), tier=tier):
        for c in remaining:
            key, c2, plan, version = resolver(c)
            g = groups.setdefault(key, {"plan": plan, "version": version,
                                        "entries": []})
            g["entries"].append(c2)

    for key, g in groups.items():
        plan: _GroupPlan = g["plan"]
        entries: List[_Compiled] = g["entries"]
        rff = None
        if plan.kind == "qmc":
            # fit-or-fetch the sublinear synopsis only when some entry's
            # resolved backend wants it (and the resolver is store-backed:
            # the fit cache and the accuracy-gate counters live there)
            n_rows = int(plan.x_rows.shape[0])
            density_for = getattr(resolver, "density_for", None)
            if density_for is not None and any(
                    _resolve_kde_backend(c.kde_backend, kde_backend,
                                         n_rows) == "rff"
                    for c in entries):
                rff = density_for(key, g["version"], plan)
        answered = _run_group(key, plan, entries, backend, n_qmc,
                              ci_level=ci_level, metrics=metrics, tier=tier,
                              kde_backend=kde_backend, rff=rff)
        for c, (est, path, ci_lo, ci_hi, n_eff) in zip(entries, answered):
            results[c.slot] = AqpResult(
                estimate=est, path=path,
                rel_width=_rel_width(c, plan.h_axes),
                synopsis_version=g["version"], group=c.group, query=c.query,
                ci_lo=ci_lo, ci_hi=ci_hi, ci_level=ci_level,
                n_effective=n_eff)
    return results


# --- the facade -------------------------------------------------------------

class QueryEngine:
    """Single entry point for AQP batches against a `TelemetryStore`.

    A heterogeneous batch — 1-D ranges, multi-d boxes, categorical equality,
    GROUP BY expansions, mixed selectors — is normalized, grouped by
    (column tuple, selector), and each group is answered in one batched call
    on its execution path (closed forms, eq. 11 product kernel, the Pallas
    tile kernels, or the batched quasi-MC fallback for full-H synopses).

        engine = QueryEngine(store)                # or store.engine()
        results = engine.execute([
            AqpQuery("count", (Range("loss", 1.0, 4.0),)),
            AqpQuery("avg", (Box(("loss", "latency_ms"), (1, 20), (4, 60)),),
                     target="latency_ms"),
            AqpQuery("count", (Eq("model_id", 2),)),
        ])
    """

    def __init__(self, store, selector: str = "plugin", backend: str = "jnp",
                 n_qmc: int = 4096, max_groups: int = 64,
                 ci_level: float = DEFAULT_CI_LEVEL,
                 kde_backend: str = "auto"):
        if kde_backend not in KDE_BACKENDS:
            raise ValueError(f"unknown kde_backend {kde_backend!r}; "
                             f"expected one of {KDE_BACKENDS}")
        self.store = store
        self.selector = selector
        self.backend = backend
        self.n_qmc = n_qmc
        self.max_groups = max_groups
        self.ci_level = ci_level
        self.kde_backend = kde_backend
        self.plans = PlanCache(metrics=getattr(store, "metrics", None))

    # -- planning core (shared by the synchronous path and the admission
    #    layer in repro.core.aqp_admission) ----------------------------------

    def compile(self, queries: Union[AqpQuery, Sequence[AqpQuery]]
                ) -> List[_Compiled]:
        """Normalize specs to execution units (one per GROUP BY category),
        slotted in submission order."""
        if isinstance(queries, AqpQuery):
            queries = [queries]
        compiled: List[_Compiled] = []
        for q in queries:
            if not isinstance(q, AqpQuery):
                raise TypeError(f"QueryEngine.execute takes AqpQuery specs, "
                                f"got {type(q).__name__}")
            for gv in self._group_values(q):
                compiled.append(_compile(q, len(compiled), group_value=gv))
        return compiled

    def resolver(self, selector: Optional[str] = None,
                 tier: Optional[int] = None) -> _StoreResolver:
        """Store resolver wired to this engine's version-keyed plan cache.
        `tier` budgets resolution to one tier of a `TieredReservoir` (None =
        the full sample; plain reservoirs ignore it)."""
        return _StoreResolver(self.store, selector or self.selector,
                              plans=self.plans, tier=tier)

    def run_compiled(self, compiled: Sequence[_Compiled],
                     selector: Optional[str] = None,
                     backend: Optional[str] = None,
                     tier: Optional[int] = None,
                     kde_backend: Optional[str] = None) -> List[AqpResult]:
        """Execute pre-compiled units (slots must be 0..n-1) — the admission
        layer's flush entry point; identical execution to `execute`."""
        with obs.span("engine.run_compiled", n=len(compiled), tier=tier,
                      backend=backend or self.backend):
            return _execute(compiled, len(compiled),
                            self.resolver(selector, tier=tier),
                            backend=backend or self.backend, n_qmc=self.n_qmc,
                            ci_level=self.ci_level,
                            kde_backend=kde_backend or self.kde_backend)

    # -- the synchronous shell ----------------------------------------------

    def execute(self, queries: Union[AqpQuery, Sequence[AqpQuery]],
                selector: Optional[str] = None,
                backend: Optional[str] = None, mode: str = "batch",
                kde_backend: Optional[str] = None):
        """Answer a batch of AqpQuery specs; one AqpResult per query (one per
        group value for GROUP BY queries, in discovered/declared order).

        `mode="batch"` (default) returns the List[AqpResult] directly;
        `mode="progressive"` returns the `progressive` generator instead —
        (tier, results) rounds with tightening confidence intervals.

        `kde_backend` overrides the engine's density-backend default for
        this batch ("auto" | "exact" | "rff", quasi-MC path only)."""
        if mode == "progressive":
            return self.progressive(queries, selector=selector,
                                    backend=backend)
        if mode != "batch":
            raise ValueError(f"unknown mode {mode!r}; "
                             f"expected 'batch' or 'progressive'")
        return self.run_compiled(self.compile(queries), selector=selector,
                                 backend=backend, kde_backend=kde_backend)

    def progressive(self, queries: Union[AqpQuery, Sequence[AqpQuery]],
                    selector: Optional[str] = None,
                    backend: Optional[str] = None):
        """Anytime execution over `TieredReservoir` tiers: yields
        (tier, List[AqpResult]) rounds, answering from the smallest tier
        first and refining on successively larger tiers.  The final round
        runs on the full sample and is bit-identical to `execute` — callers
        can stop consuming as soon as the intervals are tight enough.
        Against stores with no tiered reservoirs this degenerates to one
        full-accuracy round."""
        compiled = self.compile(queries)
        res = self.resolver(selector)
        n_tiers = 1
        for c in compiled:
            key, _c2, _version = res.key_for(c)
            col = key[0]
            reg = self.store.joints if isinstance(col, tuple) \
                else self.store.columns
            n_tiers = max(n_tiers, getattr(reg.get(col), "n_tiers", 1))
        for t in range(n_tiers):
            tier = t if t < n_tiers - 1 else None
            yield t, self.run_compiled(compiled, selector=selector,
                                       backend=backend, tier=tier)

    def answers(self, queries, **kw) -> np.ndarray:
        """`execute`, reduced to the estimates (submission order)."""
        return np.asarray([r.estimate for r in self.execute(queries, **kw)],
                          np.float64)

    def session(self, **kwargs) -> "AqpSession":
        """A streaming admission session over this engine: submit AqpQuery
        specs from many logical clients, get futures back, micro-batches
        flush on a batch-size watermark or max-delay deadline (see
        repro.core.aqp_admission)."""
        from .aqp_admission import AqpSession
        return AqpSession(self, **kwargs)

    def _group_values(self, q: AqpQuery) -> List[Optional[float]]:
        if q.group_by is None:
            return [None]
        gb = q.group_by
        if gb.values is not None:
            return list(gb.values)
        res = self.store.columns.get(gb.column)
        if res is None:
            raise KeyError(f"unknown group_by column {gb.column!r}; "
                           f"have {sorted(self.store.columns)}")
        codes = np.unique(np.round(res.sample().astype(np.float64)))
        strata = getattr(res, "codes", None)
        if callable(strata):
            # stratified TieredReservoir: union in codes whose last uniform
            # representative was displaced — rare groups keep a result row
            codes = np.unique(np.concatenate(
                [codes, np.round(np.asarray(strata(), np.float64))]))
        if codes.size == 0:
            raise ValueError(f"group_by column {gb.column!r} has no data")
        if codes.size > self.max_groups:
            raise ValueError(
                f"group_by {gb.column!r} has {codes.size} distinct codes "
                f"(max_groups={self.max_groups}); pass "
                f"GroupBy({gb.column!r}, values=...) to pin the categories")
        return [float(v) for v in codes]


# --- legacy bridges (QueryBatch / BoxQueryBatch shims) ----------------------

def from_query(q) -> AqpQuery:
    """Compile a legacy 1-D `Query` to an AqpQuery spec."""
    return AqpQuery(q.op, (Range(q.column, q.a, q.b),))


def from_box_query(q) -> AqpQuery:
    """Compile a legacy `BoxQuery` to an AqpQuery spec."""
    target = None if q.op == "count" else q.target_index()
    return AqpQuery(q.op, (Box(q.columns, q.lo, q.hi),), target=target)


def execute_specs(specs: Sequence[AqpQuery], synopses,
                  backend: str = "jnp", n_qmc: int = 4096) -> np.ndarray:
    """Execute AqpQuery specs against a bare synopsis or a mapping (the
    legacy-shim context); returns estimates in submission order.

    GROUP BY expansion and per-query selector overrides need a store (the
    category discovery and the re-fit both live there), so specs carrying
    them are rejected here rather than silently half-executed.
    """
    for q in specs:
        if q.group_by is not None:
            raise ValueError("group_by needs a store-backed QueryEngine; "
                             "execute_specs runs against pre-fitted synopses")
        if q.selector is not None:
            raise ValueError("a per-query selector override needs a "
                             "store-backed QueryEngine; execute_specs runs "
                             "against pre-fitted synopses")
    compiled = [_compile(q, i) for i, q in enumerate(specs)]
    res = _execute(compiled, len(compiled), _MappingResolver(synopses),
                   backend=backend, n_qmc=n_qmc)
    return np.asarray([r.estimate for r in res], np.float64)
