"""Pluggable density-synopsis backends for the engine's full-H path.

The quasi-MC fallback is the one execution path that still scales linearly
in reservoir size: every query batch pays a `kde_eval_H` pass over the whole
retained sample (eq. 6, O(n * nodes)).  ROADMAP item 3 makes the density
evaluator *selectable*: a `DensitySynopsis` backend is anything that can be
fitted once per synopsis version and then evaluate batched densities —
exactly (the reference `"exact"` backend wraps `kde_eval_H`) or sublinearly
(`"rff"` compresses the sample into a fixed-size random-Fourier-feature
state whose eval cost is independent of n; hashing/ANN estimators from
PAPERS.md slot in as future backends).

The contract every backend implements:

  fit(sample, H, ...) -> synopsis   one-time fit against the retained rows
                                    and the full bandwidth matrix
  eval_batch(points) -> densities   batched f^(points), shape (m,)
  to_state() / from_state(...)      checkpointable (arrays, JSON-safe meta)
                                    payload — fitted synopses ride the
                                    TelemetryStore snapshot format
  n_fitted                          rows the fit consumed
  error_metadata()                  backend-specific accuracy facts (probe
                                    error, feature count, degraded flag)
                                    for observability and the engine's
                                    accuracy gate

Backends register by name; the engine resolves `kde_backend=` requests
through `get_backend`.  Registration is import-time (`repro.synopses`
imports the built-in backends), so `available()` is the authoritative list.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

_REGISTRY: Dict[str, type] = {}


def register(name: str):
    """Class decorator: publish a backend under `name` (used by cache keys
    and checkpoint metadata, so renaming a registered backend breaks old
    snapshots — don't)."""

    def deco(cls: type) -> type:
        existing = _REGISTRY.get(name)
        if existing is not None and existing is not cls:
            raise ValueError(f"density backend {name!r} already registered "
                             f"to {existing.__name__}")
        _REGISTRY[name] = cls
        cls.backend = name
        return cls

    return deco


def get_backend(name: str) -> type:
    cls = _REGISTRY.get(name)
    if cls is None:
        raise KeyError(f"unknown density backend {name!r}; "
                       f"have {available()}")
    return cls


def available() -> List[str]:
    return sorted(_REGISTRY)


class DensitySynopsis:
    """Base class for density-synopsis backends (see module docstring).

    Subclasses must implement `fit`, `eval_batch`, `to_state`, `from_state`
    and set `n_fitted`; `error_metadata` has a sensible default.  The
    `n_source`/`selector` attributes mirror `KDESynopsis` so fitted backends
    ride the `SynopsisCache` and the checkpoint serializer unchanged.
    """

    backend: str = "?"
    n_fitted: int = 0
    n_source: int = 0
    selector: str = "plugin"
    degraded: bool = False      # accuracy gate failed -> engine uses exact

    @classmethod
    def fit(cls, sample, H, **kwargs) -> "DensitySynopsis":
        raise NotImplementedError

    def eval_batch(self, points):
        raise NotImplementedError

    def to_state(self) -> Tuple[Dict[str, np.ndarray], Dict[str, object]]:
        raise NotImplementedError

    @classmethod
    def from_state(cls, arrays: Dict[str, np.ndarray],
                   meta: Dict[str, object]) -> "DensitySynopsis":
        raise NotImplementedError

    @property
    def nbytes(self) -> int:
        """Byte footprint for the SynopsisCache's byte bound."""
        return 0

    def error_metadata(self) -> Dict[str, object]:
        """Backend-specific accuracy facts (merged into observability
        labels and checkpoint metadata)."""
        return {"backend": self.backend, "degraded": bool(self.degraded)}
