"""Pluggable density-synopsis backends (see `base` for the contract).

Importing this package registers the built-in backends, so
`available()` reflects everything usable after `import repro.synopses`.
"""
from .base import DensitySynopsis, available, get_backend, register
from .exact import ExactSynopsis
from .rff import RFFSynopsis

__all__ = [
    "DensitySynopsis",
    "ExactSynopsis",
    "RFFSynopsis",
    "available",
    "get_backend",
    "register",
]
