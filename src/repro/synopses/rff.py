"""Random-Fourier-feature density synopsis — the sublinear full-H backend.

Gallego et al. 2022 ("Fast Kernel Density Estimation with Density Matrices
and Random Fourier Features"): Bochner's theorem writes the anisotropic
Gaussian kernel k(x, y) = exp(-1/2 (x-y)^T H^-1 (x-y)) as the expectation
of cos features under its spectral density, which for this kernel is
N(0, H^-1).  Drawing D frequencies

    w_j = L^-T z_j,   H = L L^T (Cholesky),  z_j ~ N(0, I_d)

gives Cov(w) = (L L^T)^-1 = H^-1 exactly — the full anisotropic bandwidth
is honored, not a diagonal approximation.  With phases b_j ~ U[0, 2pi) the
feature map phi(x) = sqrt(2/D) cos(Wx + b) satisfies
E[phi(x) . phi(y)] = k(x, y), so the whole n-row sample compresses into ONE
D-vector

    z_bar = (1/n) sum_i phi(X_i)                  (fit: O(n * D), once)

and the density estimate is a dot product independent of n:

    f^(p) = norm * (phi(p) . z_bar),              (eval: O(D) per point)
    norm  = (2 pi)^(-d/2) |H|^(-1/2)

The Monte-Carlo feature average can go slightly negative where the true
density is ~0; evals are deliberately NOT clipped at zero.  The noise is
zero-mean, so the quasi-MC box integrals downstream cancel it — clipping
would rectify it into a positive bias that grows with the integration
volume (measured: ~40% count inflation over a wide box at D=2048, vs <1%
unclipped).  Callers that need a nonnegative density for display should
clip at the surface, not here.  The fitted state is a fixed-size
array triple (W, b, z), so it rides the PR 5 checkpoint format untouched
and shards trivially.

Everything is seeded: the same (seed, n_features, H) always draws the same
frequencies, so a checkpoint round-trip reproduces densities bit-for-bit
(test-enforced) and cross-host fits agree.

Confidence intervals: the exact path's `qmc_subsample_se` re-evaluates the
KDE on K sample chunks — O(n * m), which would erase the sublinear win.
Here the natural independent replicates are the *features*: splitting the D
features into B blocks gives B unbiased density estimates per point, and
batch-means over the per-block query answers yields a Student-t SE at
O(m * D) total — same cost order as the estimate itself
(`block_densities`, consumed by `aqp_multid.qmc_rff_se`).

Accuracy profile (measured, 2-d, n=50k): the estimator is unbiased over the
(W, b) draw, but a *single* draw carries spatially correlated noise whose
box-integral error shrinks only as 1/sqrt(D) and grows as the bandwidth
shrinks (smaller H -> higher frequencies).  At H = 0.1 * cov, D=2048, the
per-seed COUNT error over a wide box has sd ~ 25% — and the feature-block
SE tracks it (measured SE 6-13k against a true seed-to-seed sd of 9.3k), so
reported CIs stay honest even when a draw lands far out.  The engine's
probe gate additionally catches pointwise degradation and falls back to
exact.  Use wider bandwidths (>= 0.2 * cov) or larger D where tight boxes
matter.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .base import DensitySynopsis, register

# feature-map fit is chunked over sample rows so memory stays
# O(chunk * n_features) even for 200k+ row reservoirs
FIT_CHUNK = 4096


@partial(jax.jit, static_argnames=("chunk",))
def _mean_cos(x: jax.Array, w: jax.Array, b: jax.Array,
              chunk: int = FIT_CHUNK) -> jax.Array:
    """(1/n) sum_i cos(W x_i + b) over sample rows, scanned in chunks."""
    n, d = x.shape
    c = min(chunk, max(n, 1))
    pad = (-n) % c
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    mask = (jnp.arange(n + pad) < n).astype(w.dtype)

    def body(acc, args):
        xc, mc = args
        proj = xc @ w.T + b[None, :]                      # (c, D)
        return acc + jnp.sum(jnp.cos(proj) * mc[:, None], axis=0), None

    acc, _ = jax.lax.scan(
        body, jnp.zeros((w.shape[0],), w.dtype),
        (xp.reshape(-1, c, d), mask.reshape(-1, c)))
    return acc / jnp.maximum(n, 1)


@partial(jax.jit, static_argnames=("n_blocks",))
def _block_densities(points: jax.Array, w: jax.Array, b: jax.Array,
                     z: jax.Array, norm: jax.Array,
                     n_blocks: int) -> jax.Array:
    """Per-feature-block densities, (n_blocks, m): block k rescales its
    partial dot by D / |block| so each block is an unbiased estimate of the
    same density — the batch-means replicates behind `qmc_rff_se`."""
    D = w.shape[0]
    db = D // n_blocks
    wb = w[:db * n_blocks].reshape(n_blocks, db, -1)
    bb = b[:db * n_blocks].reshape(n_blocks, db)
    zb = z[:db * n_blocks].reshape(n_blocks, db)
    rescale = jnp.asarray(D / db, w.dtype)

    def one(args):
        wk, bk, zk = args
        raw = jnp.cos(points @ wk.T + bk[None, :]) @ zk
        return norm * rescale * raw

    # lax.map, not vmap: vmap would materialise the full (B, m, D/B) cos
    # tensor at once — the whole point of blocking is bounded memory
    return jax.lax.map(one, (wb, bb, zb))


@register("rff")
class RFFSynopsis(DensitySynopsis):
    """Fitted RFF state: frequencies W (D, d), phases b (D,), and the
    scaled sample feature mean z (D,) with the 2/D feature scale folded in,
    so eval is  f^(p) = norm * (cos(W p + b) . z) — unclipped, see the
    module docstring."""

    def __init__(self, w, b, z, norm: float, n_fitted: int, seed: int):
        self.w = w
        self.b = b
        self.z = z
        self.norm = float(norm)
        self.n_fitted = int(n_fitted)
        self.seed = int(seed)
        self.probe_rel_err = float("nan")   # set by the engine's gate

    @property
    def n_features(self) -> int:
        return int(self.w.shape[0])

    @property
    def d(self) -> int:
        return int(self.w.shape[1])

    @classmethod
    def fit(cls, sample, H, n_features: int = 2048,
            seed: int = 0) -> "RFFSynopsis":
        """One-shot fit against the retained rows.  O(n * n_features); the
        result never touches the sample again."""
        x = jnp.asarray(sample, jnp.float32)
        if x.ndim == 1:
            x = x[:, None]
        n, d = x.shape
        H64 = np.asarray(H, np.float64).reshape(d, d)
        L = np.linalg.cholesky(H64)
        sign, logdet = np.linalg.slogdet(H64)
        if sign <= 0:
            raise ValueError("bandwidth matrix H must be positive definite")
        norm = math.exp(-d / 2.0 * math.log(2.0 * math.pi) - 0.5 * logdet)
        key_w, key_b = jax.random.split(jax.random.PRNGKey(seed))
        zeta = np.asarray(
            jax.random.normal(key_w, (n_features, d), jnp.float32),
            np.float64)
        # w_j = L^-T zeta_j  =>  Cov(w) = H^-1 (anisotropy honored)
        w = np.linalg.solve(L.T, zeta.T).T.astype(np.float32)
        b = jax.random.uniform(key_b, (n_features,), jnp.float32,
                               0.0, 2.0 * math.pi)
        w = jnp.asarray(w)
        z = (2.0 / n_features) * _mean_cos(x, w, b)
        out = cls(w=w, b=b, z=z, norm=norm, n_fitted=n, seed=seed)
        out.n_source = n
        return out

    def eval_batch(self, points) -> jax.Array:
        """Batched densities f^(points), (m,) — O(m * D), independent of the
        fitted sample size.  Routed through the Pallas tile kernel
        (`kernels/rff_eval.py`; interpret mode off-TPU)."""
        from repro.kernels import ops as kops

        p = jnp.asarray(points, jnp.float32)
        if p.ndim == 1:
            p = p[:, None]
        raw = kops.rff_density(p, self.w, self.b, self.z)
        return jnp.float32(self.norm) * raw

    def block_densities(self, points, n_blocks: int = 8) -> jax.Array:
        """(n_blocks, m) per-feature-block density replicates (see module
        docstring) — the CI pass's input."""
        p = jnp.asarray(points, jnp.float32)
        if p.ndim == 1:
            p = p[:, None]
        return _block_densities(p, self.w, self.b, self.z,
                                jnp.float32(self.norm), n_blocks)

    @property
    def nbytes(self) -> int:
        return sum(int(np.asarray(v).nbytes)
                   for v in (self.w, self.b, self.z))

    def error_metadata(self) -> Dict[str, object]:
        return {"backend": "rff", "degraded": bool(self.degraded),
                "n_features": self.n_features,
                "probe_rel_err": float(self.probe_rel_err)}

    # -- checkpointing -------------------------------------------------------

    def to_state(self) -> Tuple[Dict[str, np.ndarray], Dict[str, object]]:
        arrays = {"w": np.asarray(self.w), "b": np.asarray(self.b),
                  "z": np.asarray(self.z)}
        meta = {"backend": "rff", "norm": float(self.norm),
                "n_fitted": int(self.n_fitted), "seed": int(self.seed),
                "degraded": bool(self.degraded),
                "probe_rel_err": float(self.probe_rel_err)}
        return arrays, meta

    @classmethod
    def from_state(cls, arrays: Dict[str, np.ndarray],
                   meta: Dict[str, object]) -> "RFFSynopsis":
        out = cls(w=jnp.asarray(arrays["w"]), b=jnp.asarray(arrays["b"]),
                  z=jnp.asarray(arrays["z"]), norm=float(meta["norm"]),
                  n_fitted=int(meta["n_fitted"]), seed=int(meta["seed"]))
        out.degraded = bool(meta.get("degraded", False))
        out.probe_rel_err = float(meta.get("probe_rel_err", float("nan")))
        return out
