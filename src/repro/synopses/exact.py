"""The reference `"exact"` density backend: eq. 6 via `kde_eval_H`.

This is the O(n * m) direct evaluation every full-H query paid before the
backend split.  It exists as a registered backend for three reasons: the
protocol needs a ground-truth implementation to gate sublinear backends
against (the engine's probe-point accuracy gate evaluates both), tests
exercise the registry through it, and `kde_backend="exact"` stays an
explicit, first-class choice rather than the absence of one.

NOTE the engine's exact *query* path does not route through this class —
`batch_query_qmc` keeps `kde_eval_H` inlined in its single jitted pass so
exact answers stay bit-identical to the pre-backend engine (test-enforced).
`ExactSynopsis.eval_batch` is the protocol-level evaluator (gates, tests,
ad-hoc density reads).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.kde import kde_eval_H

from .base import DensitySynopsis, register


@register("exact")
class ExactSynopsis(DensitySynopsis):
    """Wraps the retained sample + full bandwidth matrix; eval is eq. 6."""

    def __init__(self, x, H):
        self.x = x if x.ndim == 2 else x[:, None]
        self.H = H
        self.n_fitted = int(self.x.shape[0])

    @classmethod
    def fit(cls, sample, H, **kwargs) -> "ExactSynopsis":
        return cls(jnp.asarray(sample), jnp.asarray(H))

    def eval_batch(self, points):
        return kde_eval_H(jnp.asarray(points), self.x, self.H)

    @property
    def nbytes(self) -> int:
        return int(np.asarray(self.x).nbytes) + int(np.asarray(self.H).nbytes)

    def to_state(self) -> Tuple[Dict[str, np.ndarray], Dict[str, object]]:
        return ({"x": np.asarray(self.x), "H": np.asarray(self.H)},
                {"backend": "exact", "n_fitted": int(self.n_fitted),
                 "degraded": bool(self.degraded)})

    @classmethod
    def from_state(cls, arrays: Dict[str, np.ndarray],
                   meta: Dict[str, object]) -> "ExactSynopsis":
        out = cls(jnp.asarray(arrays["x"]), jnp.asarray(arrays["H"]))
        out.n_fitted = int(meta.get("n_fitted", out.n_fitted))
        out.degraded = bool(meta.get("degraded", False))
        return out

    def error_metadata(self) -> Dict[str, object]:
        return {"backend": "exact", "degraded": False, "exact": True}
