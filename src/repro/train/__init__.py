from .serve_step import greedy_generate, make_decode_step
from .train_step import make_eval_step, make_train_step, split_microbatches
