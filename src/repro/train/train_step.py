"""Train step factory: loss -> grads (optionally microbatched) -> AdamW.

Microbatching: the global batch is reshaped to (n_micro, B/n_micro, S) and a
`lax.scan` accumulates fp32 grads.  This (a) bounds activation memory — each
remat checkpoint holds only the microbatch slice — and (b) lets XLA overlap
the per-microbatch gradient reduce-scatter with the next microbatch's compute
(the standard grad-accumulation overlap; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.optim import adamw


def split_microbatches(batch: Dict[str, Any], n_micro: int) -> Dict[str, Any]:
    def r(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(model, opt_cfg: adamw.AdamWConfig, n_micro: int = 1,
                    param_constraint=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    param_constraint: optional pytree of NamedShardings.  When set, params are
    re-constrained (e.g. from FSDP to TP-only sharding) ONCE at the top of the
    step, so the microbatch scan reuses one weight all-gather instead of
    re-gathering every microbatch — and the gradient reduce-scatter back to
    the FSDP layout also happens once (§Perf hillclimb H1).
    """

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def grads_of(params, batch):
        if param_constraint is not None:
            params = jax.lax.with_sharding_constraint(params, param_constraint)
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        mbs = split_microbatches(batch, n_micro)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return (loss_acc + loss, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(body, (jnp.zeros(()), g0), mbs)
        inv = 1.0 / n_micro
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        params, opt_state, metrics = adamw.update(opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_eval_step(model):
    def eval_step(params, batch):
        return model.loss(params, batch)
    return eval_step
