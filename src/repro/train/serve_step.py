"""Serving: prefill + batched greedy/temperature decode loop."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def make_decode_step(model):
    @jax.jit
    def step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)
    return step


def greedy_generate(model, params, prompt_tokens, max_new: int, max_len: int = 0,
                    extra_batch=None):
    """prompt_tokens: (B, S0) int32.  Returns (B, S0 + max_new).
    extra_batch: additional prefill inputs (e.g. whisper's enc_frames)."""
    B, S0 = prompt_tokens.shape
    max_len = max_len or (S0 + max_new)
    batch = {"tokens": prompt_tokens, **(extra_batch or {})}
    logits, cache = jax.jit(model.prefill)(params, batch)
    # prefill caches have length S0; pad the KV caches to max_len
    def pad_time(path_x):
        return path_x
    cache = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0)] * 2 + [(0, max_len - a.shape[2])] + [(0, 0)] * (a.ndim - 3))
        if a.ndim >= 3 and a.shape[2] == S0 else a, cache)
    step = make_decode_step(model)
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [prompt_tokens, tok]
    for i in range(max_new - 1):
        logits, cache = step(params, cache, tok, jnp.int32(S0 + i))
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
