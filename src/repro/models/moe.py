"""Top-k MoE block with capacity-bounded sort-based dispatch (EP-shardable).

Dispatch algorithm (honest-FLOPs, dense-shape friendly):
  1. router: softmax over E experts in fp32, top-k gates per token;
  2. flatten (token, expert) assignments, stable-argsort by expert id;
  3. position-within-expert via exclusive cumsum of per-expert counts;
     assignments beyond capacity C = ceil(cf * T * k / E) are dropped
     (scatter with mode='drop');
  4. gather tokens into the (E, C, D) expert batch, run all experts as one
     batched einsum (E rides the 'model' mesh axis => expert parallelism),
  5. scatter-add gated expert outputs back to token slots.

Compute is O(T * k * cf * D * F) — proportional to *active* experts, matching
the 6*N_active*D roofline accounting in EXPERIMENTS.md.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import layers as L


def moe_params(key, cfg, dt):
    ks = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    return {
        "router": {"w": L.dense_init(ks[0], (D, E), jnp.float32)},
        "wg": L.dense_init(ks[1], (E, D, F), dt),
        "wu": L.dense_init(ks[2], (E, D, F), dt),
        "wd": L.dense_init(ks[3], (E, F, D), dt, scale=1.0 / math.sqrt(F)),
    }


def moe_specs(cfg, fsdp):
    return {
        "router": {"w": P(None, None)},
        "wg": P("model", fsdp, None),
        "wu": P("model", fsdp, None),
        "wd": P("model", None, fsdp),
    }


def moe_block(p, x, cfg):
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(math.ceil(cfg.capacity_factor * T * k / E)))
    xt = x.reshape(T, D)

    # 1. routing (fp32)
    logits = xt.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, k)                  # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # 2-3. sort assignments by expert, compute in-expert positions
    e_flat = eids.reshape(-1)                              # (T*k,)
    tok_flat = jnp.arange(T * k, dtype=jnp.int32) // k
    gate_flat = gates.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    gate_sorted = gate_flat[order]
    counts = jnp.bincount(e_flat, length=E)
    start = jnp.cumsum(counts) - counts                    # exclusive prefix
    pos_in_e = jnp.arange(T * k) - start[e_sorted]
    keep = pos_in_e < C
    dst = jnp.where(keep, e_sorted * C + pos_in_e, E * C)  # OOB => dropped

    disp_tok = jnp.zeros((E * C,), jnp.int32).at[dst].set(tok_sorted, mode="drop")
    disp_gate = jnp.zeros((E * C,), jnp.float32).at[dst].set(gate_sorted, mode="drop")
    # slots never written keep gate 0 => contribute nothing on combine

    # 4. expert batch: (E, C, D) -> batched experts on the 'model' axis.
    # NOTE(§Perf H3, refuted): forcing P("model", None, None) constraints on
    # xe/hg/he here made things far WORSE (+123% HLO FLOPs, +770% collective
    # bytes on qwen3-moe train_4k) — GSPMD's propagated layout already keeps
    # the expert einsums EP-local; the constraints induced resharding.
    xe = jnp.take(xt, disp_tok, axis=0).reshape(E, C, D)
    hg = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"]))
    hu = jnp.einsum("ecd,edf->ecf", xe, p["wu"])
    he = jnp.einsum("ecf,efd->ecd", hg * hu, p["wd"])      # (E, C, D)

    # 5. combine: gated scatter-add back to tokens
    out = jnp.zeros((T, D), jnp.float32).at[disp_tok].add(
        he.reshape(E * C, D).astype(jnp.float32) * disp_gate[:, None])
    return out.reshape(B, S, D).astype(x.dtype)


def load_balance_loss(p, x, cfg):
    """Auxiliary load-balancing loss (Switch-style): E * sum_e f_e * p_e."""
    B, S, D = x.shape
    T = B * S
    logits = x.reshape(T, D).astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, eids = jax.lax.top_k(probs, cfg.top_k)
    f = jnp.mean(jax.nn.one_hot(eids, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    pmean = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(f * pmean)
