"""Mamba-1 selective-state-space LM (falcon-mamba-7b family).

Training/prefill runs a `lax.scan` over time inside a `lax.scan` over layers;
per-step tensors (dA etc.) are built inside the time step so nothing of size
O(S * d_inner * N) ever materialises.  Decode carries (conv_state, ssm_state)
per layer — O(1) in context length, which is why this family runs long_500k.

Sharding: d_inner rides the 'model' axis (in_proj row-sharded), so conv,
gating, x_proj and the state update are all TP-local; out_proj reduces over
'model' (one psum per layer inserted by GSPMD).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from . import layers as L


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def dt_rank(cfg) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


class MambaLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params --
    def _layer_params(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        di, N, R = cfg.d_inner, cfg.ssm_state, dt_rank(cfg)
        ks = jax.random.split(key, 5)
        a = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
        return {
            "norm": L.norm_params(cfg.d_model, "rmsnorm", dt),
            "in_proj": L.dense_init(ks[0], (cfg.d_model, 2 * di), dt),
            "conv_w": L.dense_init(ks[1], (cfg.d_conv, di), dt, scale=1.0 / math.sqrt(cfg.d_conv)),
            "conv_b": jnp.zeros((di,), dt),
            "x_proj": L.dense_init(ks[2], (di, R + 2 * N), dt),
            "dt_proj": L.dense_init(ks[3], (R, di), dt, scale=1.0 / math.sqrt(R)),
            "dt_bias": jnp.full((di,), -4.6, dt),   # softplus^-1(0.01)
            "A_log": jnp.log(a),                     # fp32
            "D": jnp.ones((di,), jnp.float32),
            "out_proj": L.dense_init(ks[4], (di, cfg.d_model), dt),
        }

    def init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        kE, kL, kH = jax.random.split(key, 3)
        return {
            "embed": {"w": L.embed_init(kE, (cfg.padded_vocab, cfg.d_model), dt)},
            "layers": jax.vmap(self._layer_params)(jax.random.split(kL, cfg.n_layers)),
            "ln_f": L.norm_params(cfg.d_model, "rmsnorm", dt),
            "lm_head": {"w": L.dense_init(kH, (cfg.d_model, cfg.padded_vocab), dt)},
        }

    def param_specs(self, mode: str = "train"):
        fsdp = "data" if mode == "train" else None
        layer = {
            "norm": {"w": P(None)},
            "in_proj": P(fsdp, "model"),
            "conv_w": P(None, "model"),
            "conv_b": P("model"),
            "x_proj": P("model", fsdp),
            "dt_proj": P(fsdp, "model"),
            "dt_bias": P("model"),
            "A_log": P("model", None),
            "D": P("model"),
            "out_proj": P("model", fsdp),
        }
        layer = jax.tree.map(lambda s: P(None, *s), layer,
                             is_leaf=lambda s: isinstance(s, P))
        return {
            "embed": {"w": P("model", fsdp)},
            "layers": layer,
            "ln_f": {"w": P(None)},
            "lm_head": {"w": P(fsdp, "model")},
        }

    # -------------------------------------------------------------- block --
    def _ssm_scan(self, lp, xc, dtv, Bm, Cm):
        """Selective scan.  xc: (B,S,di) conv output; dtv: (B,S,di);
        Bm, Cm: (B,S,N).  Returns y: (B,S,di).  fp32 state."""
        A = -jnp.exp(lp["A_log"])                    # (di, N)

        def step(h, inp):
            x_t, dt_t, b_t, c_t = inp                # (B,di),(B,di),(B,N),(B,N)
            dA = jnp.exp(dt_t[..., None] * A[None])  # (B,di,N)
            dBx = (dt_t * x_t)[..., None] * b_t[:, None, :]
            h = dA * h + dBx
            y_t = jnp.einsum("bdn,bn->bd", h, c_t)
            return h, y_t

        B_, S, di = xc.shape
        N = Bm.shape[-1]
        h0 = jnp.zeros((B_, di, N), jnp.float32)
        xs = (xc.astype(jnp.float32).transpose(1, 0, 2),
              dtv.astype(jnp.float32).transpose(1, 0, 2),
              Bm.astype(jnp.float32).transpose(1, 0, 2),
              Cm.astype(jnp.float32).transpose(1, 0, 2))
        h, ys = jax.lax.scan(step, h0, xs)
        return ys.transpose(1, 0, 2), h              # y (B,S,di), final state

    def _causal_conv(self, lp, x):
        """Depthwise causal conv over time. x: (B,S,di)."""
        cfg = self.cfg
        K = cfg.d_conv
        pads = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        out = jnp.zeros_like(x)
        for t in range(K):                           # small static K (=4)
            out = out + pads[:, t:t + x.shape[1], :] * lp["conv_w"][t][None, None, :]
        return out + lp["conv_b"][None, None, :]

    def _block(self, x, lp, want_state: bool = False):
        cfg = self.cfg
        di, N, R = cfg.d_inner, cfg.ssm_state, dt_rank(cfg)
        h = L.rmsnorm(x, lp["norm"]["w"])
        xz = h @ lp["in_proj"]
        xi, z = xz[..., :di], xz[..., di:]
        xc = jax.nn.silu(self._causal_conv(lp, xi))
        dbc = xc @ lp["x_proj"]
        dtv = jax.nn.softplus(dbc[..., :R] @ lp["dt_proj"] + lp["dt_bias"])
        Bm = dbc[..., R:R + N]
        Cm = dbc[..., R + N:]
        y, h_last = self._ssm_scan(lp, xc, dtv, Bm, Cm)
        y = y.astype(x.dtype) + lp["D"].astype(x.dtype) * xc
        y = y * jax.nn.silu(z)
        out = x + y @ lp["out_proj"]
        if want_state:
            conv_tail = xi[:, -(cfg.d_conv - 1):, :]
            return out, (conv_tail, h_last)
        return out

    # ------------------------------------------------------------ forward --
    def apply(self, params, batch):
        cfg = self.cfg
        x = jnp.take(params["embed"]["w"], batch["tokens"], axis=0)

        def block_fn(x, lp):
            return self._block(x, lp), None

        if cfg.remat:
            block_fn = L.remat_block(block_fn, cfg)
        x, _ = jax.lax.scan(block_fn, x, params["layers"])
        x = L.rmsnorm(x, params["ln_f"]["w"])
        return x @ params["lm_head"]["w"]

    def loss(self, params, batch):
        logits = self.apply(params, batch)
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                               batch.get("loss_mask"))

    # ------------------------------------------------------------- decode --
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        di, N = cfg.d_inner, cfg.ssm_state
        return {
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1, di), _dtype(cfg)),
            "ssm": jnp.zeros((cfg.n_layers, batch, di, N), jnp.float32),
        }

    def cache_specs(self):
        return {"conv": P(None, "data", None, "model"),
                "ssm": P(None, "data", "model", None)}

    def prefill(self, params, batch):
        cfg = self.cfg
        x = jnp.take(params["embed"]["w"], batch["tokens"], axis=0)

        def block_fn(x, lp):
            out, (conv_tail, h_last) = self._block(x, lp, want_state=True)
            return out, (conv_tail, h_last)

        if cfg.remat:
            block_fn = L.remat_block(block_fn, cfg)
        x, (convs, ssms) = jax.lax.scan(block_fn, x, params["layers"])
        x = L.rmsnorm(x, params["ln_f"]["w"])
        return x @ params["lm_head"]["w"], {"conv": convs, "ssm": ssms}

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B, 1).  O(1)-in-context single-token step."""
        cfg = self.cfg
        di, N, R = cfg.d_inner, cfg.ssm_state, dt_rank(cfg)
        x = jnp.take(params["embed"]["w"], tokens[:, 0], axis=0)   # (B, D)

        def block_fn(x, inp):
            lp, conv_state, h = inp                   # conv:(B,K-1,di) h:(B,di,N)
            hN = L.rmsnorm(x, lp["norm"]["w"])
            xz = hN @ lp["in_proj"]
            xi, z = xz[..., :di], xz[..., di:]        # (B, di)
            window = jnp.concatenate([conv_state, xi[:, None, :]], axis=1)  # (B,K,di)
            xc = jnp.einsum("bkd,kd->bd", window, lp["conv_w"]) + lp["conv_b"]
            xc = jax.nn.silu(xc)
            dbc = xc @ lp["x_proj"]
            dtv = jax.nn.softplus(dbc[..., :R] @ lp["dt_proj"] + lp["dt_bias"])
            Bm, Cm = dbc[..., R:R + N], dbc[..., R + N:]
            dA = jnp.exp(dtv.astype(jnp.float32)[..., None] * (-jnp.exp(lp["A_log"]))[None])
            h = dA * h + ((dtv * xc).astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, Cm.astype(jnp.float32)).astype(x.dtype)
            y = y + lp["D"].astype(x.dtype) * xc
            y = y * jax.nn.silu(z)
            x = x + y @ lp["out_proj"]
            return x, (window[:, 1:, :], h)

        x, (convs, ssms) = jax.lax.scan(block_fn, x,
                                        (params["layers"], cache["conv"], cache["ssm"]))
        x = L.rmsnorm(x, params["ln_f"]["w"])
        logits = x @ params["lm_head"]["w"]
        return logits[:, None, :], {"conv": convs, "ssm": ssms}
