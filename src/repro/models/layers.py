"""Shared transformer building blocks: norms, RoPE, GQA attention, MLPs.

Everything is functional (params = nested dicts of arrays) and scan-friendly:
per-layer parameter pytrees are stacked on a leading layer axis and consumed
by `lax.scan` in the model definitions, so the lowered HLO stays O(1) in depth
(critical for the 94-layer dry-run cells).

Attention is query-chunked (flash-style at the XLA level): scores for one
query chunk at a time, so peak activation memory is O(q_chunk * S) per head
— this is what makes prefill_32k lowerable.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_params(d_model: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"w": jnp.ones((d_model,), dtype)}
    return {"w": jnp.ones((d_model,), dtype), "b": jnp.zeros((d_model,), dtype)}


def apply_norm(x, p, kind: str):
    return rmsnorm(x, p["w"]) if kind == "rmsnorm" else layernorm(x, p["w"], p["b"])


# ---------------------------------------------------------------------------
# rotary position embeddings (partial-rotary capable, e.g. StableLM 25%)
# ---------------------------------------------------------------------------

def rope_freqs(rotary_dim: int, theta: float):
    return theta ** (-jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim)


def apply_rope(x, positions, rotary_dim: int, theta: float):
    """x: (B, S, H, Dh); positions: (B, S) or (S,). Rotates first rotary_dim."""
    if rotary_dim == 0:
        return x
    freqs = rope_freqs(rotary_dim, theta)                   # (rd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, rd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    rot, rest = x[..., :rotary_dim], x[..., rotary_dim:]
    r1, r2 = rot[..., ::2], rot[..., 1::2]
    out1 = r1 * cos - r2 * sin
    out2 = r2 * cos + r1 * sin
    rot = jnp.stack([out1, out2], axis=-1).reshape(rot.shape)
    return jnp.concatenate([rot, rest], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, causal / full / cross, query-chunked, sliding-window)
# ---------------------------------------------------------------------------

def _expand_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(b, s, h * n_rep, d)


def attention_core(q, k, v, *, causal: bool, q_offset=0, window: int = 0,
                   q_chunk: int = 1024):
    """q: (B, Sq, Hq, D); k,v: (B, Sk, Hkv, D).  Returns (B, Sq, Hq, D).

    q_offset: global position of q[0] (for decode / chunked prefill masks).
    window > 0 enables sliding-window attention (keys within `window`).
    """
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def chunk_attn(qc, off, k_lo: int, k_hi: int):
        # qc: (B, C, Hq, D); off: global position of qc[0]; [k_lo, k_hi) is
        # the static key range this chunk can possibly attend to.
        # GQA via grouped-head einsum — the KV tensors are NEVER expanded to
        # Hq heads (materialising the broadcast replicated multi-GB decode
        # caches and their collectives; §Perf hillclimb H5).
        cq = qc.shape[1]
        ks = kf[:, k_lo:k_hi]
        vs = vf[:, k_lo:k_hi]
        qg = qc.astype(jnp.float32).reshape(b, cq, hkv, rep, dh)
        scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, ks) * scale
        kpos = k_lo + jnp.arange(k_hi - k_lo)
        qpos = off + jnp.arange(cq)
        mask = jnp.ones((cq, k_hi - k_lo), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window > 0:
            mask &= qpos[:, None] - kpos[None, :] < window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, vs)
        return out.reshape(b, cq, hq, dh).astype(q.dtype)

    if sq <= q_chunk:
        return chunk_attn(q, q_offset, 0, sk)

    # Causal chunking with STATIC per-chunk key ranges (§Perf H7): query
    # chunk i only ever sees keys < (i+1)*c (minus the window lower bound),
    # so the unrolled loop halves attention FLOPs vs scoring the full S per
    # chunk.  Unrolled (not scanned): ranges must be static; the layer scan
    # above keeps total HLO size bounded.
    assert q_offset == 0 or not causal, "chunked attention assumes offset 0"
    c = q_chunk
    pad = (-sq) % c
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = qp.shape[0] and qp.shape[1] // c
    outs = []
    for i in range(n_chunks):
        qc = qp[:, i * c:(i + 1) * c]
        k_hi = min((i + 1) * c, sk) if causal else sk
        k_lo = max(0, i * c - window + 1) if (causal and window > 0) else 0
        k_lo = (k_lo // 128) * 128                     # lane-aligned start
        outs.append(chunk_attn(qc, i * c, k_lo, k_hi))
    out = jnp.concatenate(outs, axis=1)
    return out[:, :sq]


def attn_params(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                qkv_bias: bool, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d_model, n_heads * head_dim), dtype),
        "wk": dense_init(ks[1], (d_model, n_kv * head_dim), dtype),
        "wv": dense_init(ks[2], (d_model, n_kv * head_dim), dtype),
        "wo": dense_init(ks[3], (n_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def attn_qkv(p, x, n_heads: int, n_kv: int, head_dim: int):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return (q.reshape(b, s, n_heads, head_dim),
            k.reshape(b, s, n_kv, head_dim),
            v.reshape(b, s, n_kv, head_dim))


def attn_out(p, o):
    b, s, h, d = o.shape
    return o.reshape(b, s, h * d) @ p["wo"]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_params(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {"wg": dense_init(ks[0], (d_model, d_ff), dtype),
            "wu": dense_init(ks[1], (d_model, d_ff), dtype),
            "wd": dense_init(ks[2], (d_ff, d_model), dtype)}


def swiglu(p, x):
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


def gelu_mlp_params(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 2)
    return {"w1": dense_init(ks[0], (d_model, d_ff), dtype),
            "b1": jnp.zeros((d_ff,), dtype),
            "w2": dense_init(ks[1], (d_ff, d_model), dtype),
            "b2": jnp.zeros((d_model,), dtype)}


def gelu_mlp(p, x):
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, mask=None):
    """logits: (B, S, V) any float dtype; labels: (B, S) int32; mask: (B, S).

    Computed in float32; ignores positions where mask == 0.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def remat_block(fn, cfg):
    """Per-layer remat with the config's policy: "nothing" recomputes the whole
    block in backward (min memory); "dots" saves non-batch matmul outputs;
    "dots_full" saves every dot output (no matmul recompute at all — max
    FLOP saving, max activation memory; §Perf hillclimb H2)."""
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if cfg.remat_policy == "dots_full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    return jax.checkpoint(fn)


def shard_hint(x, spec):
    """Best-effort with_sharding_constraint: active when tracing inside a mesh
    context (dry-run / production), a no-op otherwise (CPU smoke tests)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
