"""Zamba2-style hybrid LM: Mamba-2 backbone + one *shared* attention block
invoked every `attn_every` SSM layers (weights reused at every invocation).

Mamba-2 recurrence (per head h, head dim Ph, state N):
    S_t = exp(dt_t * a_h) * S_{t-1} + dt_t * (x_t outer B_t)
    y_t = S_t C_t + D_h x_t
with scalar a_h per head — the SSD simplification of Mamba-1's per-channel A.

At long_500k the shared attention block runs with a sliding window
(cfg.sliding_window) so the whole model stays sub-quadratic (DESIGN.md §5).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from . import layers as L


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


class HybridLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.attn_every > 0
        self.n_groups = cfg.n_layers // cfg.attn_every
        self.n_tail = cfg.n_layers - self.n_groups * cfg.attn_every

    # ------------------------------------------------------------- params --
    def _mamba2_layer(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        ks = jax.random.split(key, 4)
        return {
            "norm": L.norm_params(cfg.d_model, "rmsnorm", dt),
            "in_proj": L.dense_init(ks[0], (cfg.d_model, 2 * di), dt),
            "conv_w": L.dense_init(ks[1], (cfg.d_conv, di), dt, scale=0.5),
            "conv_b": jnp.zeros((di,), dt),
            "bcdt_proj": L.dense_init(ks[2], (di, 2 * N + H), dt),
            "dt_bias": jnp.full((H,), -4.6, jnp.float32),
            "a_log": jnp.zeros((H,), jnp.float32),
            "D": jnp.ones((H,), jnp.float32),
            "out_proj": L.dense_init(ks[3], (di, cfg.d_model), dt),
        }

    def _shared_attn_block(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.norm_params(cfg.d_model, "rmsnorm", dt),
            "attn": L.attn_params(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.head_dim_, False, dt),
            "ln2": L.norm_params(cfg.d_model, "rmsnorm", dt),
            "mlp": L.swiglu_params(k2, cfg.d_model, cfg.d_ff, dt),
        }

    def init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        kE, kM, kA, kH = jax.random.split(key, 4)
        return {
            "embed": {"w": L.embed_init(kE, (cfg.padded_vocab, cfg.d_model), dt)},
            "mamba": jax.vmap(self._mamba2_layer)(jax.random.split(kM, cfg.n_layers)),
            "shared_attn": self._shared_attn_block(kA),
            "ln_f": L.norm_params(cfg.d_model, "rmsnorm", dt),
            "lm_head": {"w": L.dense_init(kH, (cfg.d_model, cfg.padded_vocab), dt)},
        }

    def param_specs(self, mode: str = "train"):
        fsdp = "data" if mode == "train" else None
        mamba = {
            "norm": {"w": P(None)},
            "in_proj": P(fsdp, "model"),
            "conv_w": P(None, "model"),
            "conv_b": P("model"),
            "bcdt_proj": P("model", fsdp),
            "dt_bias": P(None),
            "a_log": P(None),
            "D": P(None),
            "out_proj": P("model", fsdp),
        }
        mamba = jax.tree.map(lambda s: P(None, *s), mamba,
                             is_leaf=lambda s: isinstance(s, P))
        attn = {
            "ln1": {"w": P(None)},
            "attn": {"wq": P(fsdp, "model"), "wk": P(fsdp, "model"),
                     "wv": P(fsdp, "model"), "wo": P("model", fsdp)},
            "ln2": {"w": P(None)},
            "mlp": {"wg": P(fsdp, "model"), "wu": P(fsdp, "model"), "wd": P("model", fsdp)},
        }
        return {
            "embed": {"w": P("model", fsdp)},
            "mamba": mamba,
            "shared_attn": attn,
            "ln_f": {"w": P(None)},
            "lm_head": {"w": P(fsdp, "model")},
        }

    # -------------------------------------------------------------- mamba --
    def _causal_conv(self, lp, x):
        K = self.cfg.d_conv
        pads = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        out = jnp.zeros_like(x)
        for t in range(K):
            out = out + pads[:, t:t + x.shape[1], :] * lp["conv_w"][t][None, None, :]
        return out + lp["conv_b"][None, None, :]

    def _mamba2_block(self, x, lp, want_state: bool = False):
        cfg = self.cfg
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        Ph = di // H
        h = L.rmsnorm(x, lp["norm"]["w"])
        xz = h @ lp["in_proj"]
        xi, z = xz[..., :di], xz[..., di:]
        xc = jax.nn.silu(self._causal_conv(lp, xi))
        bcdt = xc @ lp["bcdt_proj"]
        Bm = bcdt[..., :N]
        Cm = bcdt[..., N:2 * N]
        dtv = jax.nn.softplus(bcdt[..., 2 * N:].astype(jnp.float32) + lp["dt_bias"])  # (B,S,H)
        a = -jnp.exp(lp["a_log"])                   # (H,)
        B_, S = x.shape[0], x.shape[1]
        xh = xc.reshape(B_, S, H, Ph)

        def step(state, inp):                       # state: (B,H,Ph,N) fp32
            x_t, dt_t, b_t, c_t = inp                # (B,H,Ph),(B,H),(B,N),(B,N)
            da = jnp.exp(dt_t * a[None])             # (B,H)
            upd = (dt_t[..., None, None] * x_t[..., None]) * b_t[:, None, None, :]
            state = da[..., None, None] * state + upd
            y_t = jnp.einsum("bhpn,bn->bhp", state, c_t)
            return state, y_t

        xs = (xh.astype(jnp.float32).transpose(1, 0, 2, 3),
              dtv.transpose(1, 0, 2),
              Bm.astype(jnp.float32).transpose(1, 0, 2),
              Cm.astype(jnp.float32).transpose(1, 0, 2))
        state0 = jnp.zeros((B_, H, Ph, N), jnp.float32)
        state, ys = jax.lax.scan(step, state0, xs)
        y = ys.transpose(1, 0, 2, 3)                 # (B,S,H,Ph)
        y = y + lp["D"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(B_, S, di).astype(x.dtype)
        y = y * jax.nn.silu(z)
        out = x + y @ lp["out_proj"]
        if want_state:
            return out, (xi[:, -(cfg.d_conv - 1):, :], state)
        return out

    def _attn_block(self, x, ap, positions, window: int = 0):
        cfg = self.cfg
        h = L.rmsnorm(x, ap["ln1"]["w"])
        q, k, v = L.attn_qkv(ap["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
        q = L.apply_rope(q, positions, cfg.head_dim_, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.head_dim_, cfg.rope_theta)
        o = L.attention_core(q, k, v, causal=True, window=window, q_chunk=cfg.q_chunk)
        x = x + L.attn_out(ap["attn"], o)
        h = L.rmsnorm(x, ap["ln2"]["w"])
        return x + L.swiglu(ap["mlp"], h)


    def _split_mamba(self, params):
        """(grouped [G, A, ...], tail [T, ...]) views of the stacked layers."""
        G, A, T = self.n_groups, self.cfg.attn_every, self.n_tail
        grouped = jax.tree.map(lambda a: a[:G * A].reshape(G, A, *a.shape[1:]),
                               params["mamba"])
        tail = jax.tree.map(lambda a: a[G * A:], params["mamba"])
        return grouped, tail

    # ------------------------------------------------------------ forward --
    def apply(self, params, batch, window: int = 0):
        cfg = self.cfg
        x = jnp.take(params["embed"]["w"], batch["tokens"], axis=0)
        positions = jnp.arange(x.shape[1])
        grouped, tail = self._split_mamba(params)
        ap = params["shared_attn"]

        def mamba_fn(x, lp):
            return self._mamba2_block(x, lp), None

        if cfg.remat:
            mamba_fn = L.remat_block(mamba_fn, cfg)

        def group_fn(x, glp):
            x, _ = jax.lax.scan(mamba_fn, x, glp)
            x = self._attn_block(x, ap, positions, window)
            return x, None

        x, _ = jax.lax.scan(group_fn, x, grouped)
        if self.n_tail:
            x, _ = jax.lax.scan(mamba_fn, x, tail)
        x = L.rmsnorm(x, params["ln_f"]["w"])
        return x @ params["lm_head"]["w"]

    def loss(self, params, batch):
        logits = self.apply(params, batch)
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                               batch.get("loss_mask"))

    def prefill(self, params, batch, window: int = 0):
        """Forward pass that also returns decode-ready caches."""
        cfg = self.cfg
        x = jnp.take(params["embed"]["w"], batch["tokens"], axis=0)
        positions = jnp.arange(x.shape[1])
        grouped, tail = self._split_mamba(params)
        ap = params["shared_attn"]

        def mamba_fn(x, lp):
            out, st = self._mamba2_block(x, lp, want_state=True)
            return out, st

        if cfg.remat:
            mamba_fn = L.remat_block(mamba_fn, cfg)

        def group_fn(x, glp):
            x, (gconv, gssm) = jax.lax.scan(mamba_fn, x, glp)
            h = L.rmsnorm(x, ap["ln1"]["w"])
            q, k, v = L.attn_qkv(ap["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
            q = L.apply_rope(q, positions, cfg.head_dim_, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.head_dim_, cfg.rope_theta)
            o = L.attention_core(q, k, v, causal=True, window=window, q_chunk=cfg.q_chunk)
            x = x + L.attn_out(ap["attn"], o)
            h = L.rmsnorm(x, ap["ln2"]["w"])
            x = x + L.swiglu(ap["mlp"], h)
            return x, (gconv, gssm, k, v)

        x, (convs, ssms, ks, vs) = jax.lax.scan(group_fn, x, grouped)
        new_conv = jax.tree.map(lambda a: a.reshape(self.n_groups * cfg.attn_every, *a.shape[2:]), convs)
        new_ssm = jax.tree.map(lambda a: a.reshape(self.n_groups * cfg.attn_every, *a.shape[2:]), ssms)
        if self.n_tail:
            x, (tc, ts) = jax.lax.scan(mamba_fn, x, tail)
            new_conv = jnp.concatenate([new_conv, tc], axis=0)
            new_ssm = jnp.concatenate([new_ssm, ts], axis=0)
        x = L.rmsnorm(x, params["ln_f"]["w"])
        logits = x @ params["lm_head"]["w"]
        return logits, {"conv": new_conv, "ssm": new_ssm, "k": ks, "v": vs}

    # ------------------------------------------------------------- decode --
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        Ph = di // H
        return {
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1, di), _dtype(cfg)),
            "ssm": jnp.zeros((cfg.n_layers, batch, H, Ph, N), jnp.float32),
            # one shared attention block -> one KV cache (per invocation site
            # it is re-read/re-written; sites share it causally in sequence)
            "k": jnp.zeros((self.n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim_), _dtype(cfg)),
            "v": jnp.zeros((self.n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim_), _dtype(cfg)),
        }

    def cache_specs(self):
        return {"conv": P(None, "data", None, "model"),
                "ssm": P(None, "data", "model", None, None),
                "k": P(None, "data", "model", None, None),
                "v": P(None, "data", "model", None, None)}

    def _mamba2_decode(self, x, lp, conv_state, state):
        cfg = self.cfg
        di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        Ph = di // H
        h = L.rmsnorm(x, lp["norm"]["w"])
        xz = h @ lp["in_proj"]
        xi, z = xz[..., :di], xz[..., di:]
        window = jnp.concatenate([conv_state, xi[:, None, :]], axis=1)
        xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, lp["conv_w"]) + lp["conv_b"])
        bcdt = xc @ lp["bcdt_proj"]
        Bm, Cm = bcdt[..., :N], bcdt[..., N:2 * N]
        dtv = jax.nn.softplus(bcdt[..., 2 * N:].astype(jnp.float32) + lp["dt_bias"])
        a = -jnp.exp(lp["a_log"])
        da = jnp.exp(dtv * a[None])
        xh = xc.reshape(-1, H, Ph).astype(jnp.float32)
        upd = (dtv[..., None, None] * xh[..., None]) * Bm.astype(jnp.float32)[:, None, None, :]
        state = da[..., None, None] * state + upd
        y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
        y = y + lp["D"][None, :, None] * xh
        y = y.reshape(-1, di).astype(x.dtype) * jax.nn.silu(z)
        return x + y @ lp["out_proj"], window[:, 1:, :], state

    def decode_step(self, params, cache, tokens, pos, *, window: int = 0):
        cfg = self.cfg
        x = jnp.take(params["embed"]["w"], tokens[:, 0], axis=0)
        positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)
        grouped, tail = self._split_mamba(params)
        G, A = self.n_groups, cfg.attn_every
        ap = params["shared_attn"]

        conv_g = jax.tree.map(lambda a: a[:G * A].reshape(G, A, *a.shape[1:]), cache["conv"])
        ssm_g = jax.tree.map(lambda a: a[:G * A].reshape(G, A, *a.shape[1:]), cache["ssm"])

        def group_fn(x, inp):
            glp, gconv, gssm, ck, cv = inp

            def mamba_fn(x, minp):
                lp, cs, ss = minp
                x, cs, ss = self._mamba2_decode(x, lp, cs, ss)
                return x, (cs, ss)

            x, (gconv, gssm) = jax.lax.scan(mamba_fn, x, (glp, gconv, gssm))
            # shared attention with its per-site KV cache
            h = L.rmsnorm(x[:, None, :], ap["ln1"]["w"])
            q, k, v = L.attn_qkv(ap["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
            q = L.apply_rope(q, positions, cfg.head_dim_, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.head_dim_, cfg.rope_theta)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k, pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v, pos, axis=1)
            o = L.attention_core(q, ck, cv, causal=True, q_offset=pos, window=window)
            xs = x[:, None, :] + L.attn_out(ap["attn"], o)
            h = L.rmsnorm(xs, ap["ln2"]["w"])
            x = (xs + L.swiglu(ap["mlp"], h))[:, 0, :]
            return x, (gconv, gssm, ck, cv)

        x, (conv_g, ssm_g, ks, vs) = jax.lax.scan(
            group_fn, x, (grouped, conv_g, ssm_g, cache["k"], cache["v"]))

        new_conv = jax.tree.map(lambda a: a.reshape(G * A, *a.shape[2:]), conv_g)
        new_ssm = jax.tree.map(lambda a: a.reshape(G * A, *a.shape[2:]), ssm_g)
        if self.n_tail:
            def mamba_fn(x, minp):
                lp, cs, ss = minp
                x, cs, ss = self._mamba2_decode(x, lp, cs, ss)
                return x, (cs, ss)
            tconv = jax.tree.map(lambda a: a[G * A:], cache["conv"])
            tssm = jax.tree.map(lambda a: a[G * A:], cache["ssm"])
            x, (tc, ts) = jax.lax.scan(mamba_fn, x, (tail, tconv, tssm))
            new_conv = jnp.concatenate([new_conv, tc], axis=0)
            new_ssm = jnp.concatenate([new_ssm, ts], axis=0)
        x = L.rmsnorm(x, params["ln_f"]["w"])
        logits = x @ params["lm_head"]["w"]
        return logits[:, None, :], {"conv": new_conv, "ssm": new_ssm, "k": ks, "v": vs}
