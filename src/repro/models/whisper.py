"""Whisper-style encoder-decoder (whisper-base).

The conv/mel frontend is a STUB per the assignment: `input_specs()` provides
precomputed frame embeddings (B, enc_seq, d_model); sinusoidal positions are
added on the fly (stand-in for Whisper's learned/sinusoidal tables so the
decoder is length-agnostic for the decode_32k cell).

Encoder: non-causal self-attention blocks.  Decoder: causal self-attention +
cross-attention to encoder states + GELU MLP.  Decode caches: growing self-
attention KV + fixed cross-attention KV (computed once from encoder states).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from . import layers as L


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def sinusoid_positions(positions, d_model: int):
    """positions: (S,) or (B,S) -> (..., d_model) sinusoidal embeddings."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params --
    def _enc_layer(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.norm_params(cfg.d_model, cfg.norm, dt),
            "attn": L.attn_params(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.head_dim_, False, dt),
            "ln2": L.norm_params(cfg.d_model, cfg.norm, dt),
            "mlp": L.gelu_mlp_params(k2, cfg.d_model, cfg.d_ff, dt),
        }

    def _dec_layer(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": L.norm_params(cfg.d_model, cfg.norm, dt),
            "self_attn": L.attn_params(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                       cfg.head_dim_, False, dt),
            "ln2": L.norm_params(cfg.d_model, cfg.norm, dt),
            "cross_attn": L.attn_params(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.head_dim_, False, dt),
            "ln3": L.norm_params(cfg.d_model, cfg.norm, dt),
            "mlp": L.gelu_mlp_params(k3, cfg.d_model, cfg.d_ff, dt),
        }

    def init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        kE, kEnc, kDec = jax.random.split(key, 3)
        return {
            "embed": {"w": L.embed_init(kE, (cfg.padded_vocab, cfg.d_model), dt)},
            "enc_layers": jax.vmap(self._enc_layer)(jax.random.split(kEnc, cfg.n_enc_layers)),
            "ln_enc": L.norm_params(cfg.d_model, cfg.norm, dt),
            "dec_layers": jax.vmap(self._dec_layer)(jax.random.split(kDec, cfg.n_layers)),
            "ln_f": L.norm_params(cfg.d_model, cfg.norm, dt),
        }

    def param_specs(self, mode: str = "train"):
        cfg = self.cfg
        fsdp = "data" if mode == "train" else None
        norm = {"w": P(None), "b": P(None)}
        attn = {"wq": P(fsdp, "model"), "wk": P(fsdp, "model"),
                "wv": P(fsdp, "model"), "wo": P("model", fsdp)}
        mlp = {"w1": P(fsdp, "model"), "b1": P("model"), "w2": P("model", fsdp), "b2": P(None)}
        enc = {"ln1": dict(norm), "attn": dict(attn), "ln2": dict(norm), "mlp": dict(mlp)}
        dec = {"ln1": dict(norm), "self_attn": dict(attn), "ln2": dict(norm),
               "cross_attn": dict(attn), "ln3": dict(norm), "mlp": dict(mlp)}
        stack = lambda t: jax.tree.map(lambda s: P(None, *s), t,
                                       is_leaf=lambda s: isinstance(s, P))
        return {
            "embed": {"w": P("model", fsdp)},
            "enc_layers": stack(enc),
            "ln_enc": dict(norm),
            "dec_layers": stack(dec),
            "ln_f": dict(norm),
        }

    # ------------------------------------------------------------ encoder --
    def encode(self, params, enc_frames):
        cfg = self.cfg
        x = enc_frames.astype(_dtype(cfg))
        x = x + sinusoid_positions(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)[None]

        def block(x, lp):
            h = L.apply_norm(x, lp["ln1"], cfg.norm)
            q, k, v = L.attn_qkv(lp["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
            o = L.attention_core(q, k, v, causal=False, q_chunk=cfg.q_chunk)
            x = x + L.attn_out(lp["attn"], o)
            h = L.apply_norm(x, lp["ln2"], cfg.norm)
            return x + L.gelu_mlp(lp["mlp"], h), None

        if cfg.remat:
            block = L.remat_block(block, cfg)
        x, _ = jax.lax.scan(block, x, params["enc_layers"])
        return L.apply_norm(x, params["ln_enc"], cfg.norm)

    # ------------------------------------------------------------ decoder --
    def _dec_block(self, x, lp, enc_out, positions, collect_kv: bool = False):
        cfg = self.cfg
        h = L.apply_norm(x, lp["ln1"], cfg.norm)
        q, k, v = L.attn_qkv(lp["self_attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
        o = L.attention_core(q, k, v, causal=True, q_chunk=cfg.q_chunk)
        x = x + L.attn_out(lp["self_attn"], o)
        h = L.apply_norm(x, lp["ln2"], cfg.norm)
        b, s, _ = h.shape
        se = enc_out.shape[1]
        qc = (h @ lp["cross_attn"]["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim_)
        kc = (enc_out @ lp["cross_attn"]["wk"]).reshape(b, se, cfg.n_kv_heads, cfg.head_dim_)
        vc = (enc_out @ lp["cross_attn"]["wv"]).reshape(b, se, cfg.n_kv_heads, cfg.head_dim_)
        oc = L.attention_core(qc, kc, vc, causal=False, q_chunk=cfg.q_chunk)
        x = x + L.attn_out(lp["cross_attn"], oc)
        h = L.apply_norm(x, lp["ln3"], cfg.norm)
        x = x + L.gelu_mlp(lp["mlp"], h)
        if collect_kv:
            return x, (k, v, kc, vc)
        return x

    def apply(self, params, batch):
        """Teacher-forced enc-dec forward -> decoder logits."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["enc_frames"])
        tokens = batch["tokens"]
        x = jnp.take(params["embed"]["w"], tokens, axis=0)
        positions = jnp.arange(x.shape[1])
        x = x + sinusoid_positions(positions, cfg.d_model).astype(x.dtype)[None]

        def block(x, lp):
            return self._dec_block(x, lp, enc_out, positions), None

        if cfg.remat:
            block = L.remat_block(block, cfg)
        x, _ = jax.lax.scan(block, x, params["dec_layers"])
        x = L.apply_norm(x, params["ln_f"], cfg.norm)
        return x @ params["embed"]["w"].T          # whisper ties output proj

    def loss(self, params, batch):
        logits = self.apply(params, batch)
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                               batch.get("loss_mask"))

    # ------------------------------------------------------------- decode --
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        dt = _dtype(cfg)
        kv = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
        cross = (cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim_)
        return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
                "ck": jnp.zeros(cross, dt), "cv": jnp.zeros(cross, dt)}

    def cache_specs(self):
        # self-attn cache: sequence over model (H6); cross cache: enc_seq=1500
        # is not divisible by 16, so it stays head_dim-sharded.
        s = P(None, "data", "model", None, None)
        c = P(None, "data", None, None, "model")
        return {"k": s, "v": s, "ck": c, "cv": c}

    def prefill(self, params, batch):
        """Encoder + teacher-forced decoder pass, returning decode caches."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["enc_frames"])
        tokens = batch["tokens"]
        x = jnp.take(params["embed"]["w"], tokens, axis=0)
        positions = jnp.arange(x.shape[1])
        x = x + sinusoid_positions(positions, cfg.d_model).astype(x.dtype)[None]

        def block(x, lp):
            return self._dec_block(x, lp, enc_out, positions, collect_kv=True)

        if cfg.remat:
            block = L.remat_block(block, cfg)
        x, (ks, vs, cks, cvs) = jax.lax.scan(block, x, params["dec_layers"])
        x = L.apply_norm(x, params["ln_f"], cfg.norm)
        logits = x @ params["embed"]["w"].T
        return logits, {"k": ks, "v": vs, "ck": cks, "cv": cvs}

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        x = jnp.take(params["embed"]["w"], tokens, axis=0)   # (B,1,D)
        positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)
        x = x + sinusoid_positions(positions, cfg.d_model).astype(x.dtype)

        def block(x, inp):
            lp, ck_, cv_, xk, xv = inp
            h = L.apply_norm(x, lp["ln1"], cfg.norm)
            q, k, v = L.attn_qkv(lp["self_attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
            ck_ = jax.lax.dynamic_update_slice_in_dim(ck_, k, pos, axis=1)
            cv_ = jax.lax.dynamic_update_slice_in_dim(cv_, v, pos, axis=1)
            o = L.attention_core(q, ck_, cv_, causal=True, q_offset=pos)
            x = x + L.attn_out(lp["self_attn"], o)
            h = L.apply_norm(x, lp["ln2"], cfg.norm)
            qc = L.attn_qkv(lp["cross_attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)[0]
            oc = L.attention_core(qc, xk, xv, causal=False)
            x = x + L.attn_out(lp["cross_attn"], oc)
            h = L.apply_norm(x, lp["ln3"], cfg.norm)
            x = x + L.gelu_mlp(lp["mlp"], h)
            return x, (ck_, cv_)

        x, (ks, vs) = jax.lax.scan(block, x, (params["dec_layers"], cache["k"],
                                              cache["v"], cache["ck"], cache["cv"]))
        x = L.apply_norm(x, params["ln_f"], cfg.norm)
        logits = x @ params["embed"]["w"].T
        return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"]}
