from .api import (batch_specs, batch_struct, build_model, cache_specs_with_dp,
                  decode_struct, param_specs_with_dp, param_structs)
