"""Dense / VLM / MoE decoder-only transformer LM (scan-over-layers).

One implementation covers stablelm-12b, llama3.2-1b, glm4-9b, qwen2.5-14b,
internvl2-2b (VLM: precomputed patch embeddings prepended) and the two MoE
archs (FFN swapped for a top-k expert block, see moe.py).

Layer parameters are stacked on a leading L axis and consumed by `lax.scan`
(+ optional `jax.checkpoint` remat per block), so HLO size is depth-independent
and activation memory is one layer boundary per layer.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from . import layers as L
from .moe import moe_block, moe_params, moe_specs


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params --
    def _layer_params(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        k1, k2, k3 = jax.random.split(key, 3)
        p = {
            "ln1": L.norm_params(cfg.d_model, cfg.norm, dt),
            "attn": L.attn_params(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.head_dim_, cfg.qkv_bias, dt),
            "ln2": L.norm_params(cfg.d_model, cfg.norm, dt),
        }
        if cfg.family == "moe":
            p["moe"] = moe_params(k2, cfg, dt)
        elif cfg.mlp == "swiglu":
            p["mlp"] = L.swiglu_params(k2, cfg.d_model, cfg.d_ff, dt)
        else:
            p["mlp"] = L.gelu_mlp_params(k2, cfg.d_model, cfg.d_ff, dt)
        return p

    def init(self, key):
        cfg = self.cfg
        dt = _dtype(cfg)
        kE, kL, kH, kV = jax.random.split(key, 4)
        layer_keys = jax.random.split(kL, cfg.n_layers)
        params = {
            "embed": {"w": L.embed_init(kE, (cfg.padded_vocab, cfg.d_model), dt)},
            "layers": jax.vmap(self._layer_params)(layer_keys),
            "ln_f": L.norm_params(cfg.d_model, cfg.norm, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {"w": L.dense_init(kH, (cfg.d_model, cfg.padded_vocab), dt)}
        if cfg.family == "vlm":
            params["vision_proj"] = {"w": L.dense_init(kV, (cfg.d_model, cfg.d_model), dt)}
        return params

    def param_specs(self, mode: str = "train"):
        """PartitionSpecs matching init()'s pytree.  TP over 'model'; in train
        mode large weight matrices are additionally FSDP-sharded over 'data'."""
        cfg = self.cfg
        fsdp = "data" if mode == "train" else None
        n = lambda: P(None)                      # replicated vector
        row = lambda: P(fsdp, "model")           # [in, out] -> out over model
        col = lambda: P("model", fsdp)           # [in, out] -> in over model
        norm = {"w": n()} if cfg.norm == "rmsnorm" else {"w": n(), "b": n()}
        attn = {"wq": row(), "wk": row(), "wv": row(), "wo": col()}
        if cfg.qkv_bias:
            attn.update({"bq": P("model"), "bk": P("model"), "bv": P("model")})
        layer = {"ln1": dict(norm), "attn": attn, "ln2": dict(norm)}
        if cfg.family == "moe":
            layer["moe"] = moe_specs(cfg, fsdp)
        elif cfg.mlp == "swiglu":
            layer["mlp"] = {"wg": row(), "wu": row(), "wd": col()}
        else:
            layer["mlp"] = {"w1": row(), "b1": P("model"), "w2": col(), "b2": n()}
        # prepend the stacked-layer axis
        layer = jax.tree.map(lambda s: P(None, *s), layer,
                             is_leaf=lambda s: isinstance(s, P))
        specs = {
            "embed": {"w": P("model", fsdp)},
            "layers": layer,
            "ln_f": dict(norm),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = {"w": P(fsdp, "model")}
        if cfg.family == "vlm":
            specs["vision_proj"] = {"w": P(None, "model")}
        return specs

    # ------------------------------------------------------------ forward --
    def _block(self, x, lp, positions, *, window: int = 0):
        cfg = self.cfg
        h = L.apply_norm(x, lp["ln1"], cfg.norm)
        q, k, v = L.attn_qkv(lp["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
        rd = int(cfg.head_dim_ * cfg.partial_rotary)
        q = L.apply_rope(q, positions, rd, cfg.rope_theta)
        k = L.apply_rope(k, positions, rd, cfg.rope_theta)
        o = L.attention_core(q, k, v, causal=True, window=window, q_chunk=cfg.q_chunk)
        x = x + L.attn_out(lp["attn"], o)
        h = L.apply_norm(x, lp["ln2"], cfg.norm)
        if cfg.family == "moe":
            x = x + moe_block(lp["moe"], h, cfg)
        elif cfg.mlp == "swiglu":
            x = x + L.swiglu(lp["mlp"], h)
        else:
            x = x + L.gelu_mlp(lp["mlp"], h)
        return x

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = jnp.take(params["embed"]["w"], tokens, axis=0)
        if cfg.family == "vlm":
            vis = batch["patch_embeds"].astype(x.dtype) @ params["vision_proj"]["w"]
            x = jnp.concatenate([vis, x], axis=1)
        return x

    def apply(self, params, batch):
        """Teacher-forced forward -> logits (B, S_total, V)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])

        def block_fn(x, lp):
            return self._block(x, lp, positions), None

        if cfg.remat:
            block_fn = L.remat_block(block_fn, cfg)
        x, _ = jax.lax.scan(block_fn, x, params["layers"])
        x = L.apply_norm(x, params["ln_f"], cfg.norm)
        head = params["embed"]["w"].T if cfg.tie_embeddings else params["lm_head"]["w"]
        return x @ head

    def loss(self, params, batch):
        cfg = self.cfg
        logits = self.apply(params, batch)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if cfg.family == "vlm":   # image positions carry no next-token loss
            logits = logits[:, cfg.n_vision_tokens:, :]
        return L.cross_entropy(logits[:, :-1], labels[:, 1:],
                               None if mask is None else mask[:, 1:])

    # ------------------------------------------------------------- decode --
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        dt = _dtype(cfg)
        shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    def cache_specs(self):
        # batch over data, SEQUENCE over model (H6): contracting head_dim
        # locally and psum-ing the tiny scores/outputs beats all-gathering the
        # cache over the model axis (55 GB/dev -> MBs; EXPERIMENTS.md §Perf)
        s = P(None, "data", "model", None, None)
        return {"k": s, "v": s}

    def prefill(self, params, batch):
        """Full-sequence forward that also returns the KV cache."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])

        def block_fn(x, lp):
            h = L.apply_norm(x, lp["ln1"], cfg.norm)
            q, k, v = L.attn_qkv(lp["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
            rd = int(cfg.head_dim_ * cfg.partial_rotary)
            q = L.apply_rope(q, positions, rd, cfg.rope_theta)
            k = L.apply_rope(k, positions, rd, cfg.rope_theta)
            o = L.attention_core(q, k, v, causal=True, q_chunk=cfg.q_chunk)
            x = x + L.attn_out(lp["attn"], o)
            h = L.apply_norm(x, lp["ln2"], cfg.norm)
            if cfg.family == "moe":
                x = x + moe_block(lp["moe"], h, cfg)
            elif cfg.mlp == "swiglu":
                x = x + L.swiglu(lp["mlp"], h)
            else:
                x = x + L.gelu_mlp(lp["mlp"], h)
            return x, (k, v)

        if cfg.remat:
            block_fn = L.remat_block(block_fn, cfg)
        x, (ks, vs) = jax.lax.scan(block_fn, x, params["layers"])
        x = L.apply_norm(x, params["ln_f"], cfg.norm)
        head = params["embed"]["w"].T if cfg.tie_embeddings else params["lm_head"]["w"]
        return x @ head, {"k": ks, "v": vs}

    def decode_step(self, params, cache, tokens, pos, *, window: int = 0):
        """One decode step. tokens: (B, 1) int32; pos: scalar int32 (write slot).
        Returns (logits (B, 1, V), new_cache)."""
        cfg = self.cfg
        x = jnp.take(params["embed"]["w"], tokens, axis=0)
        positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)

        def block_fn(x, inputs):
            lp, ck, cv = inputs
            h = L.apply_norm(x, lp["ln1"], cfg.norm)
            q, k, v = L.attn_qkv(lp["attn"], h, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_)
            rd = int(cfg.head_dim_ * cfg.partial_rotary)
            q = L.apply_rope(q, positions, rd, cfg.rope_theta)
            k = L.apply_rope(k, positions, rd, cfg.rope_theta)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k, pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v, pos, axis=1)
            o = L.attention_core(q, ck, cv, causal=True, q_offset=pos, window=window)
            x = x + L.attn_out(lp["attn"], o)
            h = L.apply_norm(x, lp["ln2"], cfg.norm)
            if cfg.family == "moe":
                x = x + moe_block(lp["moe"], h, cfg)
            elif cfg.mlp == "swiglu":
                x = x + L.swiglu(lp["mlp"], h)
            else:
                x = x + L.gelu_mlp(lp["mlp"], h)
            return x, (ck, cv)

        x, (ks, vs) = jax.lax.scan(block_fn, x, (params["layers"], cache["k"], cache["v"]))
        x = L.apply_norm(x, params["ln_f"], cfg.norm)
        head = params["embed"]["w"].T if cfg.tie_embeddings else params["lm_head"]["w"]
        return x @ head, {"k": ks, "v": vs}
