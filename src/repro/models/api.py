"""Unified model API: build_model(cfg) -> model object, plus abstract-shape
helpers used by the dry-run (no allocation: jax.eval_shape everywhere).

Batch dict conventions (all int32 tokens):
  train/prefill: {"tokens": (B, S)[, "labels": (B, S)][, "patch_embeds"]
                  [, "enc_frames"]}
  decode:        tokens (B, 1) + cache pytree + pos scalar
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from .hybrid import HybridLM
from .ssm import MambaLM
from .transformer import TransformerLM
from .whisper import EncDecLM


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "vlm", "moe"):
        return TransformerLM(cfg)
    if cfg.family == "ssm":
        return MambaLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for the train/prefill batch of one (arch, shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        s_text = S - cfg.n_vision_tokens
        batch["tokens"] = sd((B, s_text), jnp.int32)
        batch["labels"] = sd((B, s_text), jnp.int32)
        batch["patch_embeds"] = sd((B, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    elif cfg.family == "encdec":
        batch["tokens"] = sd((B, S), jnp.int32)
        batch["labels"] = sd((B, S), jnp.int32)
        batch["enc_frames"] = sd((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = sd((B, S), jnp.int32)
        batch["labels"] = sd((B, S), jnp.int32)
    return batch


def batch_specs(cfg: ModelConfig, dp_axes) -> Dict[str, Any]:
    """PartitionSpecs for batch_struct.  dp_axes: tuple of mesh axis names the
    batch dimension is sharded over, e.g. ("data",) or ("pod", "data")."""
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    specs: Dict[str, Any] = {"tokens": P(dp, None), "labels": P(dp, None)}
    if cfg.family == "vlm":
        specs["patch_embeds"] = P(dp, None, None)
    if cfg.family == "encdec":
        specs["enc_frames"] = P(dp, None, None)
    return specs


def decode_struct(cfg: ModelConfig, shape: ShapeConfig):
    """(tokens, cache, pos) ShapeDtypeStructs for a decode cell: one new token
    against a KV/state cache of length shape.seq_len."""
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return tokens, cache, pos


def cache_specs_with_dp(model, dp_axes, batch_size: int = 0):
    """Model cache specs with the 'data' batch axis swapped for dp_axes.
    When the batch cannot shard (e.g. long_500k B=1) it is replicated."""
    import math
    dp_total = 0
    if batch_size:
        dp_total = batch_size
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    replicate = batch_size == 1

    def fix(spec: P) -> P:
        def sub(s):
            if s == "data":
                return None if replicate else dp
            return s
        return P(*[sub(s) for s in spec])

    return jax.tree.map(fix, model.cache_specs(),
                        is_leaf=lambda s: isinstance(s, P))


def param_structs(cfg: ModelConfig):
    """Abstract parameter shapes (no allocation)."""
    model = build_model(cfg)
    return jax.eval_shape(lambda k: model.init(k), jax.random.key(0))


def param_specs_with_dp(model, mode: str, dp_axes):
    """Param specs with FSDP axis widened to dp_axes in multi-pod meshes."""
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def fix(spec: P) -> P:
        return P(*[dp if s == "data" else s for s in spec])

    return jax.tree.map(fix, model.param_specs(mode),
                        is_leaf=lambda s: isinstance(s, P))
