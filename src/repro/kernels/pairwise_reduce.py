"""Pallas TPU kernel: triangular pairwise derivative-kernel reduction (RR_fun).

Computes per-tile partials of   sum_{i<j} K^(r)((x_i - x_j) / g)
— the O(n^2) hot spot of PLUGIN (paper eqs. 16/18, parallel schema §5.4).

TPU adaptation of the paper's Fig. 3 CUDA schema (see DESIGN.md §2):
  * one Pallas grid step per k x k tile of the implicit upper-triangular
    pairwise matrix; the 1-D grid enumerates *only* triangle tiles using the
    paper's Appendix-A index math (eqs. 49/50, `triangle.bx_to_ql`) inside the
    BlockSpec index_maps — no wasted below-diagonal tiles;
  * the E (rows) and F (cols) chunks are staged into VMEM by BlockSpec, the
    analogue of the paper's shared-memory copy (Fig. 5);
  * fun is evaluated on the whole (k, k) tile on the VPU (8x128 lanes >> the
    paper's 4-lane SSE / 32-lane warp);
  * the in-tile reduction is a jnp.sum into a per-tile partial; the final
    cross-tile reduction happens outside (XLA tree-reduce), mirroring the
    paper's two-stage block reduction.

k = 256 (2 x 128 lanes, 8-sublane aligned): a (256, 256) fp32 tile is 256 KiB
of VMEM working set (diff + fun values + mask), comfortably inside ~16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import gaussian as G
from .triangle import bx_to_ql, n_tri_tiles
from .tuning import resolve_tile

TILE = 256

_FUNS = {"k4": G.k4, "k6": G.k6, "gauss": G.phi}


def _kernel(e_ref, f_ref, g_ref, out_ref, *, kind: str, n: int, k: int):
    bx = pl.program_id(0)
    q, l = bx_to_ql(bx)
    g = g_ref[0]
    e = e_ref[...]          # (k,) rows chunk   (global rows q*k + i)
    f = f_ref[...]          # (k,) cols chunk   (global cols l*k + j)
    diff = (e[:, None] - f[None, :]) / g
    vals = _FUNS[kind](diff)
    rows = q * k + jax.lax.broadcasted_iota(jnp.int32, (k, k), 0)
    cols = l * k + jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)
    mask = (rows < cols) & (cols < n) & (rows < n)
    out_ref[0] = jnp.sum(jnp.where(mask, vals, 0.0))


def pairwise_scaled_ksum(x: jax.Array, g: jax.Array, kind: str = "k4",
                         tile=None, interpret: bool = True) -> jax.Array:
    """sum_{i<j} fun((x_i - x_j)/g) for 1-D x via the triangular tile kernel.

    `tile` resolves at call time: kwarg > REPRO_PAIRWISE_TILE > module
    default — never frozen into a function default at import."""
    tile = resolve_tile("REPRO_PAIRWISE_TILE", TILE, tile)
    return _pairwise_scaled_ksum(x, g, kind, tile, interpret)


@functools.partial(jax.jit, static_argnames=("kind", "tile", "interpret"))
def _pairwise_scaled_ksum(x: jax.Array, g: jax.Array, kind: str,
                          tile: int, interpret: bool) -> jax.Array:
    n = x.shape[0]
    k = min(tile, max(8, 1 << (n - 1).bit_length())) if n < tile else tile
    pad = (-n) % k
    xp = jnp.pad(x, (0, pad))
    n_tiles = xp.shape[0] // k
    grid = (n_tri_tiles(n_tiles),)

    partials = pl.pallas_call(
        functools.partial(_kernel, kind=kind, n=n, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda bx: (bx_to_ql(bx)[0],)),   # E = row chunk q
            pl.BlockSpec((k,), lambda bx: (bx_to_ql(bx)[1],)),   # F = col chunk l
            pl.BlockSpec((1,), lambda bx: (0,)),                 # g (scalar)
        ],
        out_specs=pl.BlockSpec((1,), lambda bx: (bx,)),
        out_shape=jax.ShapeDtypeStruct((grid[0],), x.dtype),
        interpret=interpret,
    )(xp, xp, g.reshape(1).astype(x.dtype))
    return jnp.sum(partials)
