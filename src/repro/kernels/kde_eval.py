"""Pallas TPU kernel: direct KDE evaluation (paper eq. 3) — the AQP serving
hot spot (numerical integration of f^ evaluates the KDE at many grid points).

Grid: (eval-tile, data-tile).  The (k,) output block for an eval tile stays
resident while all data tiles stream through and accumulate

    f^(p) = norm * mean_i exp(-0.5 * ||p - x_i||^2 / h^2)

d is unrolled statically (d <= 16 in the paper's scope), so the (k, k)
squared-distance slab is built with d broadcast-subtract-square passes on the
VPU — no (k, k, d) intermediate.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import resolve_tile

TILE = 256


def _kernel(p_ref, x_ref, h_ref, out_ref, *, n: int, k: int, d: int):
    j = pl.program_id(1)
    p = p_ref[...]          # (k, d) eval points
    x = x_ref[...]          # (k, d) data chunk
    inv_h2 = 1.0 / (h_ref[0] * h_ref[0])

    quad = jnp.zeros((k, k), p.dtype)
    for a in range(d):
        diff = p[:, a][:, None] - x[:, a][None, :]
        quad = quad + diff * diff
    cols = j * k + jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)
    vals = jnp.where(cols < n, jnp.exp(-0.5 * quad * inv_h2), 0.0)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.sum(vals, axis=1)


def kde_eval(points: jax.Array, x: jax.Array, h: jax.Array,
             tile=None, interpret: bool = True) -> jax.Array:
    """f^(points; x, h).  points: (m, d), x: (n, d) -> (m,).

    `tile` resolves at call time: kwarg > REPRO_KDE_EVAL_TILE > module
    default."""
    tile = resolve_tile("REPRO_KDE_EVAL_TILE", TILE, tile)
    return _kde_eval(points, x, h, tile, interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _kde_eval(points: jax.Array, x: jax.Array, h: jax.Array,
              tile: int, interpret: bool) -> jax.Array:
    if points.ndim == 1:
        points = points[:, None]
    if x.ndim == 1:
        x = x[:, None]
    m, d = points.shape
    n = x.shape[0]
    k = min(tile, max(8, 1 << (max(m, n) - 1).bit_length()))
    pad_m = (-m) % k
    pad_n = (-n) % k
    pp = jnp.pad(points, ((0, pad_m), (0, 0)))
    xp = jnp.pad(x, ((0, pad_n), (0, 0)))

    sums = pl.pallas_call(
        functools.partial(_kernel, n=n, k=k, d=d),
        grid=(pp.shape[0] // k, xp.shape[0] // k),
        in_specs=[
            pl.BlockSpec((k, d), lambda i, j: (i, 0)),
            pl.BlockSpec((k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((k,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp.shape[0],), x.dtype),
        interpret=interpret,
    )(pp, xp, h.reshape(1).astype(x.dtype))

    norm = (2.0 * math.pi) ** (-d / 2.0) * h ** (-d)
    return (norm / n) * sums[:m]
