"""Pallas TPU kernel: fused LSCV_H objective inner sum (paper §6.3).

For each triangle tile, computes the quadratic forms s = (x_i-x_j)^T H^-1
(x_i-x_j) *and immediately* applies T_H and reduces — because H^-1 changes at
every Nelder-Mead step, S values cannot be precomputed (paper §4.5 last
paragraph), so the paper fuses exponent computation with the T reduction in a
single gpu-kernel.  Same fusion here: one VMEM round-trip per tile, per-tile
scalar partial out.

    T_H(s) = c_kk * exp(-s/4) - 2 * c_k * exp(-s/2)        (eqs. 33-35)

Triangle-only 1-D grid via Appendix-A index math, MXU quadratic-form
expansion as in sv_precompute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .triangle import bx_to_ql, n_tri_tiles
from .tuning import resolve_tile

TILE = 256


def _kernel(e_ref, f_ref, m_ref, c_ref, out_ref, *, n: int, k: int):
    bx = pl.program_id(0)
    q, l = bx_to_ql(bx)
    e = e_ref[...]                  # (k, d)
    f = f_ref[...]
    m = m_ref[...]                  # (d, d) = H^-1
    c_k = c_ref[0]
    c_kk = c_ref[1]

    me = e @ m
    qe = jnp.sum(me * e, axis=1)
    mf = f @ m
    qf = jnp.sum(mf * f, axis=1)
    cross = jax.lax.dot_general(me, f, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    s = qe[:, None] + qf[None, :] - 2.0 * cross.astype(e.dtype)

    t = c_kk * jnp.exp(-0.25 * s) - 2.0 * c_k * jnp.exp(-0.5 * s)
    rows = q * k + jax.lax.broadcasted_iota(jnp.int32, (k, k), 0)
    cols = l * k + jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)
    mask = (rows < cols) & (cols < n) & (rows < n)
    out_ref[0] = jnp.sum(jnp.where(mask, t, 0.0))


def gh_fused_sum(x: jax.Array, h_inv: jax.Array, c_k, c_kk,
                 tile=None, interpret: bool = True) -> jax.Array:
    """sum_{i<j} T_H(x_i - x_j).  x: (n, d), h_inv: (d, d).

    `tile` resolves at call time: kwarg > REPRO_GH_TILE > module default."""
    tile = resolve_tile("REPRO_GH_TILE", TILE, tile)
    return _gh_fused_sum(x, h_inv, c_k, c_kk, tile, interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _gh_fused_sum(x: jax.Array, h_inv: jax.Array, c_k, c_kk,
                  tile: int, interpret: bool) -> jax.Array:
    n, d = x.shape
    k = min(tile, max(8, 1 << (n - 1).bit_length())) if n < tile else tile
    pad = (-n) % k
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    n_tiles = xp.shape[0] // k
    grid = (n_tri_tiles(n_tiles),)
    consts = jnp.stack([jnp.asarray(c_k, x.dtype), jnp.asarray(c_kk, x.dtype)])

    partials = pl.pallas_call(
        functools.partial(_kernel, n=n, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, d), lambda bx: (bx_to_ql(bx)[0], 0)),
            pl.BlockSpec((k, d), lambda bx: (bx_to_ql(bx)[1], 0)),
            pl.BlockSpec((d, d), lambda bx: (0, 0)),
            pl.BlockSpec((2,), lambda bx: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda bx: (bx,)),
        out_shape=jax.ShapeDtypeStruct((grid[0],), x.dtype),
        interpret=interpret,
    )(xp, xp, h_inv.astype(x.dtype), consts)
    return jnp.sum(partials)
