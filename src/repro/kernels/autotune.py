"""Shape-keyed tile autotuner for the Pallas kernels.

PR 7 built the measurement half (`tuning.profiled_call` records fenced
per-shape wall timings; `tuning.measured()` reads them back).  This module
is the decision half: `sweep()` times a kernel over candidate tile
configurations for one (kernel, n, d, G, ...) shape — every candidate runs
through `profiled_call`, so sweep measurements land in the same
`kernel.wall_us` instrument the serving stack exports — and caches the
winner.  The current env/default configuration is always candidate #0, so
the chosen tiles are never slower than the defaults *on the swept
timings* (`entry["us"] <= entry["default_us"]` by construction; CI asserts
it through `scripts/validate_metrics.py --tuning`).

Resolution order in the ops.py wrappers (via `resolve()`):

    explicit kwarg  >  tile cache (this module)  >  env var  >  default

Shape keys bucket `n`/`G`/`m`-like sizes to the next power of two (`d` stays
exact) — the engine already quantizes batch shapes (`aqp_query._pad_count`),
so one swept entry covers the whole bucket instead of demanding an exact
size match.

Persistence: `REPRO_TUNING_CACHE=/path/tiles.json` makes every sweep
persist its choice and makes a fresh process load the file lazily on first
lookup — zero re-sweeps on restart (test-enforced).  `scripts/autotune.py`
is the CLI: it sweeps the shapes `tuning.measured()` (or a `--metrics`
snapshot) says the workload actually ran.

Instruments (process-global registry): `autotune.sweeps` counter and
`autotune.sweep_us` histogram per kernel, `autotune.cache.hits` /
`autotune.cache.misses` counters per kernel (only once a cache is active —
the no-cache fast path stays counter-free), `autotune.cache.entries` gauge.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import knobs, obs

from .tuning import env_int, profiled_call, resolve_tile

_SCHEMA_VERSION = 1

_lock = threading.Lock()
_tiles: Dict[str, Dict[str, int]] = {}     # shape key -> winning tile dict
_entries: Dict[str, dict] = {}             # shape key -> full sweep record
_loaded_from: Optional[str] = None         # path probed for REPRO_TUNING_CACHE


def _bucket(v: int) -> int:
    v = int(v)
    return v if v <= 1 else 1 << (v - 1).bit_length()


def shape_key(kernel: str, shape: Dict[str, int]) -> str:
    """Canonical cache key: kernel name plus sorted shape labels, sizes
    bucketed to the next power of two (`d` exact — it changes the kernel's
    unrolled body, not just the grid)."""
    parts = [kernel]
    for k in sorted(shape):
        v = int(shape[k])
        parts.append(f"{k}={v if k == 'd' else _bucket(v)}")
    return "|".join(parts)


def reset() -> None:
    """Drop all in-process tuner state (tests simulate a fresh process)."""
    global _loaded_from
    with _lock:
        _tiles.clear()
        _entries.clear()
        _loaded_from = None


def _ensure_loaded() -> None:
    global _loaded_from
    path = knobs.get_str("REPRO_TUNING_CACHE")
    with _lock:
        if _loaded_from == path:
            return
        _loaded_from = path
    if path and os.path.exists(path):
        load_cache(path)


def lookup(kernel: str, shape: Dict[str, int]) -> Optional[Dict[str, int]]:
    """Cached tile choice for a shape, or None.  Hot path: one dict probe
    when no cache is active (no counters, no env churn)."""
    _ensure_loaded()
    if not _tiles:
        return None
    with _lock:
        hit = _tiles.get(shape_key(kernel, shape))
    reg = obs.get_registry()
    if hit is None:
        reg.counter("autotune.cache.misses", kernel=kernel).inc()
        return None
    reg.counter("autotune.cache.hits", kernel=kernel).inc()
    return hit


def resolve(kernel: str, shape: Dict[str, int], **params) -> Tuple[int, ...]:
    """Resolve tile parameters for one kernel dispatch.

    `params` maps each tile name to (override, env_name, default); returns
    the resolved values in declaration order.  Explicit kwarg > cached
    sweep winner > env var > default (`tuning.resolve_tile`).
    """
    cached = None
    if not all(ov is not None for ov, _e, _d in params.values()):
        cached = lookup(kernel, shape)
    out = []
    for name, (override, env_name, default) in params.items():
        if override is not None:
            out.append(int(override))
        elif cached is not None and name in cached:
            out.append(int(cached[name]))
        else:
            out.append(resolve_tile(env_name, default))
    return tuple(out)


def record(kernel: str, shape: Dict[str, int], tiles: Dict[str, int],
           entry: Optional[dict] = None) -> str:
    """Install a tile choice in the in-process cache; returns its key."""
    key = shape_key(kernel, shape)
    with _lock:
        _tiles[key] = {k: int(v) for k, v in tiles.items()}
        if entry is not None:
            _entries[key] = entry
    obs.get_registry().gauge("autotune.cache.entries").set(len(_tiles))
    return key


def save_cache(path: str) -> dict:
    """Atomically write every recorded sweep entry as the tile-cache JSON
    (`scripts/validate_metrics.py --tuning` checks this schema)."""
    with _lock:
        entries = [dict(e) for e in _entries.values()]
    doc = {"version": _SCHEMA_VERSION, "ts": time.time(), "entries": entries}
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return doc


def load_cache(path: str) -> int:
    """Merge a persisted tile cache into the in-process state; returns the
    number of entries loaded.  Malformed files fail loudly — a silently
    ignored cache would re-sweep on every restart."""
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if doc.get("version") != _SCHEMA_VERSION:
        raise ValueError(f"{path}: unsupported tile-cache version "
                         f"{doc.get('version')!r}")
    n = 0
    for e in doc.get("entries", ()):
        record(str(e["kernel"]), {k: int(v) for k, v in e["shape"].items()},
               {k: int(v) for k, v in e["tiles"].items()}, entry=e)
        n += 1
    return n


# --- sweeping ---------------------------------------------------------------

def _interpret() -> bool:
    import jax
    return jax.default_backend() != "tpu"


def _dedupe(cands: Sequence[Dict[str, int]]) -> List[Dict[str, int]]:
    seen, out = set(), []
    for c in cands:
        key = tuple(sorted(c.items()))
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


def _eff(tile: int, size: int) -> int:
    """The tile size the kernel will actually use after pow2 rounding —
    candidates that collapse to the same effective tiles are duplicates."""
    return min(tile, max(8, 1 << (max(size, 1) - 1).bit_length()))


def _grid(shape: Dict[str, int], axes: Dict[str, Tuple[str, Sequence[int]]],
          defaults: Dict[str, int], quick: bool) -> List[Dict[str, int]]:
    """Candidate tile dicts: the env/default configuration first, then the
    cross product of per-axis candidates (quick mode: defaults plus the
    per-axis extremes), deduped by effective tile size."""
    names = list(axes)
    cands = [dict(defaults)]
    pools = []
    for name in names:
        size_label, pool = axes[name]
        pool = sorted({_eff(t, shape[size_label]) for t in pool})
        if quick:
            pool = sorted({pool[0], pool[-1],
                           _eff(defaults[name], shape[size_label])})
        pools.append(pool)

    def rec(i, acc):
        if i == len(names):
            cands.append(dict(acc))
            return
        for t in pools[i]:
            acc[names[i]] = t
            rec(i + 1, acc)
        del acc[names[i]]

    rec(0, {})
    eff = []
    for c in cands:
        eff.append({n: _eff(c[n], shape[axes[n][0]]) for n in names})
    # dedupe on effective tiles, keeping first occurrence (defaults win ties)
    seen, out = set(), []
    for c, e in zip(cands, eff):
        key = tuple(sorted(e.items()))
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


class _Sweep:
    def __init__(self, params, defaults, candidates, make):
        self.params = params          # tile kwarg names, in order
        self.defaults = defaults      # () -> {name: env/default value}
        self.candidates = candidates  # (shape, quick) -> [tile dict, ...]
        self.make = make              # shape -> callable(tiles) running once


def _make_aqp_batch(shape):
    import jax.numpy as jnp
    from . import aqp_batch as m
    rng = np.random.default_rng(0)
    n, G = shape["n"], shape["G"]
    x = jnp.asarray(rng.normal(0, 2, n).astype(np.float32))
    a = jnp.asarray(rng.uniform(-4, 2, G).astype(np.float32))
    b = a + jnp.asarray(rng.uniform(0.2, 3, G).astype(np.float32))
    h = jnp.float32(0.5)
    interp = _interpret()
    return lambda t: m.aqp_batch_sums(x, h, a, b, interpret=interp, **t)


def _make_aqp_boxes(shape):
    import jax.numpy as jnp
    from . import aqp_boxes as m
    rng = np.random.default_rng(0)
    n, d, G = shape["n"], shape["d"], shape["G"]
    x = jnp.asarray(rng.normal(0, 1.5, (n, d)).astype(np.float32))
    h = jnp.asarray(rng.uniform(0.2, 0.8, d).astype(np.float32))
    lo = jnp.asarray(rng.uniform(-3, 1, (G, d)).astype(np.float32))
    hi = lo + jnp.asarray(rng.uniform(0.2, 3, (G, d)).astype(np.float32))
    tgt = jnp.asarray(rng.integers(0, d, G), jnp.int32)
    interp = _interpret()
    return lambda t: m.aqp_box_sums(x, h, lo, hi, tgt, interpret=interp, **t)


def _make_aqp_grouped(shape):
    import jax.numpy as jnp
    from . import aqp_grouped as m
    rng = np.random.default_rng(0)
    n, d, G = shape["n"], shape["d"], shape["G"]
    x = jnp.asarray(rng.normal(0, 1.5, (n, d)).astype(np.float32))
    h = jnp.asarray(rng.uniform(0.2, 0.8, d).astype(np.float32))
    lo = jnp.asarray(rng.uniform(-3, -1, d).astype(np.float32))
    hi = lo + 4.0
    glo = jnp.asarray(np.arange(G, dtype=np.float32) - 0.5)
    ghi = glo + 1.0
    interp = _interpret()
    return lambda t: m.aqp_grouped_sums(x, h, lo, hi, glo, ghi, g_axis=0,
                                        tgt=min(1, d - 1),
                                        interpret=interp, **t)


def _make_qmc_reduce(shape):
    import jax.numpy as jnp
    from . import qmc_reduce as m
    rng = np.random.default_rng(0)
    n, d, G = shape["n"], shape["d"], shape["G"]
    nm = shape.get("m", 1024)
    x = jnp.asarray(rng.normal(0, 1.0, (n, d)).astype(np.float32))
    nodes = jnp.asarray(rng.uniform(-3, 3, (nm, d)).astype(np.float32))
    h_inv = jnp.asarray(np.eye(d, dtype=np.float32) * 4.0)
    log_norm = jnp.float32(-0.5 * d)
    lo = jnp.asarray(rng.uniform(-3, 0, (G, d)).astype(np.float32))
    hi = lo + 2.0
    tgt = jnp.asarray(rng.integers(0, d, G), jnp.int32)
    interp = _interpret()
    return lambda t: m.qmc_box_reduce(nodes, x, h_inv, log_norm, lo, hi,
                                      tgt, interpret=interp, **t)


def _rff_defaults():
    return {"tile": resolve_tile("REPRO_RFF_TILE", 512),
            "p_tile": resolve_tile("REPRO_RFF_P_TILE", 256)}


def _make_rff(shape):
    import jax.numpy as jnp
    from . import rff_eval as m
    rng = np.random.default_rng(0)
    n, d, G = shape["n"], shape["d"], shape["G"]    # n: features, G: points
    pts = jnp.asarray(rng.normal(0, 1, (G, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 1, (n, d)).astype(np.float32))
    b = jnp.asarray(rng.uniform(0, 6.28, n).astype(np.float32))
    z = jnp.asarray(rng.normal(0, 1, n).astype(np.float32))
    interp = _interpret()
    return lambda t: m.rff_density(pts, w, b, z, interpret=interp, **t)


_POOL = (64, 128, 256, 512, 1024)
_QPOOL = (16, 32, 64, 128, 256)

SWEEPS: Dict[str, _Sweep] = {
    "aqp_batch_sums": _Sweep(
        ("tile", "q_tile"),
        lambda: {"tile": resolve_tile("REPRO_AQP_TILE", 256),
                 "q_tile": resolve_tile("REPRO_AQP_Q_TILE", 128)},
        lambda shape, quick: _grid(
            shape, {"tile": ("n", _POOL), "q_tile": ("G", _QPOOL)},
            {"tile": resolve_tile("REPRO_AQP_TILE", 256),
             "q_tile": resolve_tile("REPRO_AQP_Q_TILE", 128)}, quick),
        _make_aqp_batch),
    "aqp_box_sums": _Sweep(
        ("tile", "q_tile"),
        lambda: {"tile": resolve_tile("REPRO_AQP_BOXES_TILE", 128),
                 "q_tile": resolve_tile("REPRO_AQP_BOXES_Q_TILE", 64)},
        lambda shape, quick: _grid(
            shape, {"tile": ("n", _POOL), "q_tile": ("G", _QPOOL)},
            {"tile": resolve_tile("REPRO_AQP_BOXES_TILE", 128),
             "q_tile": resolve_tile("REPRO_AQP_BOXES_Q_TILE", 64)}, quick),
        _make_aqp_boxes),
    "aqp_grouped_sums": _Sweep(
        ("tile", "g_tile"),
        lambda: {"tile": resolve_tile("REPRO_AQP_GROUPED_TILE", 128),
                 "g_tile": resolve_tile("REPRO_AQP_GROUPED_G_TILE", 64)},
        lambda shape, quick: _grid(
            shape, {"tile": ("n", _POOL), "g_tile": ("G", _QPOOL)},
            {"tile": resolve_tile("REPRO_AQP_GROUPED_TILE", 128),
             "g_tile": resolve_tile("REPRO_AQP_GROUPED_G_TILE", 64)}, quick),
        _make_aqp_grouped),
    "qmc_box_reduce": _Sweep(
        ("tile", "m_tile", "q_tile"),
        lambda: {"tile": resolve_tile("REPRO_QMC_TILE", 256),
                 "m_tile": resolve_tile("REPRO_QMC_M_TILE", 256),
                 "q_tile": resolve_tile("REPRO_QMC_Q_TILE", 64)},
        lambda shape, quick: _grid(
            shape, {"tile": ("n", (128, 256, 512)),
                    "m_tile": ("m", (128, 256, 512)),
                    "q_tile": ("G", (32, 64, 128))},
            {"tile": resolve_tile("REPRO_QMC_TILE", 256),
             "m_tile": resolve_tile("REPRO_QMC_M_TILE", 256),
             "q_tile": resolve_tile("REPRO_QMC_Q_TILE", 64)}, quick),
        _make_qmc_reduce),
    "rff_density": _Sweep(
        ("tile", "p_tile"),
        lambda: _rff_defaults(),
        lambda shape, quick: _grid(
            shape, {"tile": ("n", _POOL), "p_tile": ("G", _QPOOL)},
            _rff_defaults(), quick),
        _make_rff),
}


def sweep(kernel: str, shape: Dict[str, int], repeats: int = 3,
          quick: bool = False, persist: bool = True) -> dict:
    """Time every candidate tile configuration for (kernel, shape), record
    the winner in the in-process cache, and (when REPRO_TUNING_CACHE is
    set and `persist`) append it to the persisted tile cache.

    Every timed run goes through `tuning.profiled_call` with an
    `autotune="sweep"` label, so the measurements land in the standard
    `kernel.wall_us` instrument; the per-candidate mean is read back from
    the histogram deltas.  Returns the full sweep entry (schema of
    `scripts/validate_metrics.py --tuning`).
    """
    spec = SWEEPS.get(kernel)
    if spec is None:
        raise KeyError(f"no sweep registered for kernel {kernel!r}; "
                       f"have {sorted(SWEEPS)}")
    shape = {k: int(v) for k, v in shape.items()}
    run = spec.make(shape)
    candidates = _dedupe(spec.candidates(shape, quick))
    reg = obs.get_registry()
    was_enabled = obs.enabled()
    obs.enable()                 # profiled_call wall timings need fencing
    t_sweep = time.perf_counter()
    swept = []
    try:
        for tiles in candidates:
            labels = {**shape, **tiles, "autotune": "sweep"}
            hist = reg.histogram("kernel.wall_us", kernel=kernel, **labels)
            run(tiles)           # warm-up: jit trace excluded from timing
            c0, s0 = hist.count, hist.sum
            for _ in range(max(1, repeats)):
                profiled_call(kernel, lambda: run(tiles), **labels)
            us = (hist.sum - s0) / (hist.count - c0)
            swept.append({"tiles": dict(tiles), "us": us})
    finally:
        if not was_enabled:
            obs.disable()
    best = min(swept, key=lambda s: s["us"])
    entry = {
        "kernel": kernel, "shape": shape,
        "key": shape_key(kernel, shape),
        "tiles": dict(best["tiles"]), "us": best["us"],
        "default_tiles": dict(swept[0]["tiles"]),
        "default_us": swept[0]["us"],
        "repeats": int(max(1, repeats)), "swept": swept,
    }
    record(kernel, shape, best["tiles"], entry=entry)
    reg.counter("autotune.sweeps", kernel=kernel).inc()
    reg.histogram("autotune.sweep_us", kernel=kernel).observe(
        (time.perf_counter() - t_sweep) * 1e6)
    path = knobs.get_str("REPRO_TUNING_CACHE")
    if persist and path:
        save_cache(path)
    return entry
