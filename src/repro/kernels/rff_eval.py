"""Pallas TPU kernel: batched random-Fourier-feature density eval.

One launch evaluates the RFF synopsis dot product for a batch of points:

    raw[p] = sum_j  cos(w_j . x_p + b_j) * z_j

with the fitted state (W, b, z) from `repro.synopses.rff` (z carries the
2/D feature scale and the sample mean; the caller applies the kernel
normaliser and the zero clip).  Grid: (point-tile major, feature-tile
minor) — the (pk,) accumulator block stays resident while feature tiles
stream through, the same pattern as aqp_boxes.py.  Padded features
contribute exactly zero because z is zero-padded, so no feature mask is
needed; padded points are sliced off by the caller.

Tile sizes resolve per call (REPRO_RFF_TILE feature tile /
REPRO_RFF_P_TILE point tile, see tuning.resolve_tile); call-site
kwargs win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import resolve_tile

TILE = 512     # feature-tile default (env: REPRO_RFF_TILE)
P_TILE = 256   # point-tile default (env: REPRO_RFF_P_TILE)


def _kernel(p_ref, w_ref, b_ref, z_ref, out_ref):
    j = pl.program_id(1)     # feature-tile index (minor: varies fastest)
    p = p_ref[...]           # (pk, d) query points (padded rows harmless)
    w = w_ref[...]           # (fk, d) feature frequencies
    b = b_ref[...]           # (fk,)  feature phases
    z = z_ref[...]           # (fk,)  scaled sample feature mean (0 on pad)

    proj = jnp.dot(p, w.T) + b[None, :]          # (pk, fk)
    partial = jnp.cos(proj) @ z                  # (pk,)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("tile", "p_tile", "interpret"))
def _rff_density(points, w, b, z, tile, p_tile, interpret):
    m, d = points.shape
    D = w.shape[0]
    if m == 0 or D == 0:
        return jnp.zeros((m,), points.dtype)

    pk = min(p_tile, max(8, 1 << (m - 1).bit_length()))
    fk = min(tile, max(8, 1 << (D - 1).bit_length()))
    pp = jnp.pad(points, ((0, (-m) % pk), (0, 0)))
    wp = jnp.pad(w, ((0, (-D) % fk), (0, 0)))
    bp = jnp.pad(b, (0, (-D) % fk))
    zp = jnp.pad(z, (0, (-D) % fk))

    out = pl.pallas_call(
        _kernel,
        grid=(pp.shape[0] // pk, wp.shape[0] // fk),
        in_specs=[
            pl.BlockSpec((pk, d), lambda i, j: (i, 0)),
            pl.BlockSpec((fk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((fk,), lambda i, j: (j,)),
            pl.BlockSpec((fk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((pk,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp.shape[0],), points.dtype),
        interpret=interpret,
    )(pp, wp.astype(points.dtype), bp.astype(points.dtype),
      zp.astype(points.dtype))
    return out[:m]


def rff_density(points: jax.Array, w: jax.Array, b: jax.Array, z: jax.Array,
                tile: int = None, p_tile: int = None,
                interpret: bool = True):
    """Un-normalised RFF densities: cos(points @ W.T + b) @ z.

    points: (m, d); w: (D, d); b/z: (D,).  Returns (m,) raw feature dots —
    the caller (`RFFSynopsis.eval_batch`) applies the kernel normaliser and
    the max(., 0) clip.
    """
    tile = resolve_tile("REPRO_RFF_TILE", TILE, tile)
    p_tile = resolve_tile("REPRO_RFF_P_TILE", P_TILE, p_tile)
    return _rff_density(points, w, b, z, tile, p_tile, interpret)
