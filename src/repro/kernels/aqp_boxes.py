"""Pallas TPU kernel: batched multi-d box-query reduction (paper eq. 11).

One launch answers a whole batch of axis-aligned box queries against one
joint synopsis with diagonal bandwidth.  For query q (box [lo_q, hi_q],
SUM/AVG target axis t_q) and sample row x_i it accumulates

    count_raw[q] = sum_i  prod_j  dPhi_qij                      (eq. 11)
    sum_raw[q]   = sum_i  m_qit * prod_{j != t_q} dPhi_qij
      with  dPhi_qij = Phi((hi_qj - x_ij)/h_j) - Phi((lo_qj - x_ij)/h_j)
            m_qij    = x_ij dPhi_qij - h_j dphi_qij             (eq. 10/axis)

Grid: (query-tile major, data-tile minor) — the (qk, 2) accumulator block
stays resident while data tiles stream through, the same pattern as
aqp_batch.py.  The dims axis stays whole inside the block (d is small for
box predicates), so the per-axis select-and-product runs entirely in
registers/VMEM.  COUNT/SUM/AVG selection and the sample->relation scale are
applied by the caller (core/aqp_multid.py); the kernel is a pure two-channel
reduction.

Tile sizes resolve per call (REPRO_AQP_BOXES_TILE / REPRO_AQP_BOXES_Q_TILE,
see tuning.resolve_tile); call-site kwargs win.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import resolve_tile

TILE = 128     # default (env: REPRO_AQP_BOXES_TILE)
Q_TILE = 64    # default (env: REPRO_AQP_BOXES_Q_TILE)

_SQRT1_2 = 1.0 / math.sqrt(2.0)
_INV_SQRT_2PI = 1.0 / math.sqrt(2.0 * math.pi)


def _kernel(lo_ref, hi_ref, tgt_ref, x_ref, h_ref, out_ref,
            *, n: int, qk: int, k: int, d: int):
    j = pl.program_id(1)     # data-tile index (minor: varies fastest)
    lo = lo_ref[...]         # (qk, d) box lower corners
    hi = hi_ref[...]         # (qk, d) box upper corners
    tgt = tgt_ref[...]       # (qk,)  SUM/AVG target axis per query
    x = x_ref[...]           # (k, d) sample rows (padded rows masked below)
    h = h_ref[...]           # (d,)   diagonal bandwidth
    inv_h = 1.0 / h

    za = (lo[:, None, :] - x[None, :, :]) * inv_h[None, None, :]   # (qk, k, d)
    zb = (hi[:, None, :] - x[None, :, :]) * inv_h[None, None, :]
    d_Phi = 0.5 * (jax.scipy.special.erf(zb * _SQRT1_2)
                   - jax.scipy.special.erf(za * _SQRT1_2))
    d_phi = _INV_SQRT_2PI * (jnp.exp(-0.5 * zb * zb) - jnp.exp(-0.5 * za * za))
    moment = x[None, :, :] * d_Phi - h[None, None, :] * d_phi

    # SUM factors: axis t_q carries the first-moment term, every other axis
    # its Phi difference — a select beats dividing the full product by
    # dPhi_t, which blows up when a box edge leaves ~zero mass on an axis.
    axis = jax.lax.broadcasted_iota(jnp.int32, (1, 1, d), 2)
    factors = jnp.where(axis == tgt[:, None, None], moment, d_Phi)

    cnt_i = jnp.prod(d_Phi, axis=2)                    # (qk, k)
    sum_i = jnp.prod(factors, axis=2)

    rows = j * k + jax.lax.broadcasted_iota(jnp.int32, (qk, k), 1)
    valid = rows < n
    cnt = jnp.sum(jnp.where(valid, cnt_i, 0.0), axis=1)
    sm = jnp.sum(jnp.where(valid, sum_i, 0.0), axis=1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.stack([cnt, sm], axis=1)       # (qk, 2)


@functools.partial(jax.jit, static_argnames=("tile", "q_tile", "interpret"))
def _aqp_box_sums(x, h_diag, lo, hi, tgt, tile, q_tile, interpret):
    n, d = x.shape
    q = lo.shape[0]
    if n == 0 or q == 0:
        # zero grid iterations would leave the output buffer uninitialized
        z = jnp.zeros((q,), x.dtype)
        return z, z

    k = min(tile, max(8, 1 << (n - 1).bit_length()))
    qk = min(q_tile, max(8, 1 << (q - 1).bit_length()))
    xp = jnp.pad(x, ((0, (-n) % k), (0, 0)))
    lop = jnp.pad(lo, ((0, (-q) % qk), (0, 0)))
    hip = jnp.pad(hi, ((0, (-q) % qk), (0, 0)))
    tgtp = jnp.pad(tgt, (0, (-q) % qk))

    out = pl.pallas_call(
        functools.partial(_kernel, n=n, qk=qk, k=k, d=d),
        grid=(lop.shape[0] // qk, xp.shape[0] // k),
        in_specs=[
            pl.BlockSpec((qk, d), lambda i, j: (i, 0)),
            pl.BlockSpec((qk, d), lambda i, j: (i, 0)),
            pl.BlockSpec((qk,), lambda i, j: (i,)),
            pl.BlockSpec((k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((qk, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lop.shape[0], 2), x.dtype),
        interpret=interpret,
    )(lop, hip, tgtp, xp, h_diag.astype(x.dtype))
    return out[:q, 0], out[:q, 1]


def aqp_box_sums(x: jax.Array, h_diag: jax.Array, lo: jax.Array, hi: jax.Array,
                 tgt: jax.Array, tile: int = None, q_tile: int = None,
                 interpret: bool = True):
    """Two-channel (queries x samples x dims) reduction.

    x: (n, d) sample rows; h_diag: (d,); lo/hi: (q, d); tgt: (q,) int32.
    Returns (count_raw, sum_raw), each (q,): the *unscaled* eq. 11 box
    integrals summed over the retained sample.
    """
    tile = resolve_tile("REPRO_AQP_BOXES_TILE", TILE, tile)
    q_tile = resolve_tile("REPRO_AQP_BOXES_Q_TILE", Q_TILE, q_tile)
    return _aqp_box_sums(x, h_diag, lo, hi, tgt, tile, q_tile, interpret)
