"""Jitted public wrappers for the Pallas kernels.

On this CPU container, kernels run in interpret mode (the kernel body executes
in Python on CPU — correctness path); on a TPU runtime `interpret=False`
compiles through Mosaic.  `INTERPRET` flips automatically on backend.
"""
from __future__ import annotations

import jax

from . import aqp_batch as _ab
from . import aqp_boxes as _abx
from . import gh_fused as _gh
from . import kde_eval as _kde
from . import lscv_grid as _lg
from . import pairwise_reduce as _pr
from . import sv_precompute as _sv

INTERPRET = jax.default_backend() != "tpu"


def pairwise_scaled_ksum(x, g, kind="k4", tile=_pr.TILE):
    return _pr.pairwise_scaled_ksum(x, g, kind=kind, tile=tile, interpret=INTERPRET)


def sv_matrix(x, m, tile=_sv.TILE, algorithm="mxu"):
    return _sv.sv_matrix(x, m, tile=tile, algorithm=algorithm, interpret=INTERPRET)


def gh_fused_sum(x, h_inv, c_k, c_kk, tile=_gh.TILE):
    return _gh.gh_fused_sum(x, h_inv, c_k, c_kk, tile=tile, interpret=INTERPRET)


def lscv_grid_sums(x, sigma_inv, h_grid, c_k, c_kk, tile=_lg.TILE, h_tile=_lg.H_TILE):
    return _lg.lscv_grid_sums(x, sigma_inv, h_grid, c_k, c_kk, tile=tile,
                              h_tile=h_tile, interpret=INTERPRET)


def kde_eval(points, x, h, tile=_kde.TILE):
    return _kde.kde_eval(points, x, h, tile=tile, interpret=INTERPRET)


def aqp_batch_sums(x, h, a, b, tile=_ab.TILE, q_tile=_ab.Q_TILE):
    return _ab.aqp_batch_sums(x, h, a, b, tile=tile, q_tile=q_tile,
                              interpret=INTERPRET)


def aqp_box_sums(x, h_diag, lo, hi, tgt, tile=_abx.TILE, q_tile=_abx.Q_TILE):
    return _abx.aqp_box_sums(x, h_diag, lo, hi, tgt, tile=tile, q_tile=q_tile,
                             interpret=INTERPRET)
