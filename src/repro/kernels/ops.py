"""Jitted public wrappers for the Pallas kernels.

On this CPU container, kernels run in interpret mode (the kernel body executes
in Python on CPU — correctness path); on a TPU runtime `interpret=False`
compiles through Mosaic.  `INTERPRET` flips automatically on backend.

With `repro.obs` enabled, every wrapper routes through
`tuning.profiled_call`, which records fenced wall/dispatch timings into the
process-global metrics registry keyed by (kernel, shape, tile).  Disabled
(the default), each wrapper takes the direct branch — same jitted callable,
no fencing, no extra work.
"""
from __future__ import annotations

import jax

from repro import obs

from . import aqp_batch as _ab
from . import aqp_boxes as _abx
from . import gh_fused as _gh
from . import kde_eval as _kde
from . import lscv_grid as _lg
from . import pairwise_reduce as _pr
from . import rff_eval as _rff
from . import sv_precompute as _sv
from .tuning import profiled_call

INTERPRET = jax.default_backend() != "tpu"


def pairwise_scaled_ksum(x, g, kind="k4", tile=_pr.TILE):
    if not obs.enabled():
        return _pr.pairwise_scaled_ksum(x, g, kind=kind, tile=tile,
                                        interpret=INTERPRET)
    return profiled_call(
        "pairwise_scaled_ksum",
        lambda: _pr.pairwise_scaled_ksum(x, g, kind=kind, tile=tile,
                                         interpret=INTERPRET),
        n=x.shape[0], kind=kind, tile=tile)


def sv_matrix(x, m, tile=_sv.TILE, algorithm="mxu"):
    if not obs.enabled():
        return _sv.sv_matrix(x, m, tile=tile, algorithm=algorithm,
                             interpret=INTERPRET)
    return profiled_call(
        "sv_matrix",
        lambda: _sv.sv_matrix(x, m, tile=tile, algorithm=algorithm,
                              interpret=INTERPRET),
        n=x.shape[0], d=x.shape[1] if x.ndim > 1 else 1, tile=tile,
        algorithm=algorithm)


def gh_fused_sum(x, h_inv, c_k, c_kk, tile=_gh.TILE):
    if not obs.enabled():
        return _gh.gh_fused_sum(x, h_inv, c_k, c_kk, tile=tile,
                                interpret=INTERPRET)
    return profiled_call(
        "gh_fused_sum",
        lambda: _gh.gh_fused_sum(x, h_inv, c_k, c_kk, tile=tile,
                                 interpret=INTERPRET),
        n=x.shape[0], d=x.shape[1] if x.ndim > 1 else 1, tile=tile)


def lscv_grid_sums(x, sigma_inv, h_grid, c_k, c_kk, tile=_lg.TILE, h_tile=_lg.H_TILE):
    if not obs.enabled():
        return _lg.lscv_grid_sums(x, sigma_inv, h_grid, c_k, c_kk, tile=tile,
                                  h_tile=h_tile, interpret=INTERPRET)
    return profiled_call(
        "lscv_grid_sums",
        lambda: _lg.lscv_grid_sums(x, sigma_inv, h_grid, c_k, c_kk, tile=tile,
                                   h_tile=h_tile, interpret=INTERPRET),
        n=x.shape[0], G=h_grid.shape[0], tile=tile, h_tile=h_tile)


def kde_eval(points, x, h, tile=_kde.TILE):
    if not obs.enabled():
        return _kde.kde_eval(points, x, h, tile=tile, interpret=INTERPRET)
    return profiled_call(
        "kde_eval",
        lambda: _kde.kde_eval(points, x, h, tile=tile, interpret=INTERPRET),
        n=x.shape[0], G=points.shape[0], tile=tile)


def aqp_batch_sums(x, h, a, b, tile=_ab.TILE, q_tile=_ab.Q_TILE):
    if not obs.enabled():
        return _ab.aqp_batch_sums(x, h, a, b, tile=tile, q_tile=q_tile,
                                  interpret=INTERPRET)
    return profiled_call(
        "aqp_batch_sums",
        lambda: _ab.aqp_batch_sums(x, h, a, b, tile=tile, q_tile=q_tile,
                                   interpret=INTERPRET),
        n=x.shape[0], G=a.shape[0], tile=tile, q_tile=q_tile)


def rff_density(points, w, b, z, tile=_rff.TILE, p_tile=_rff.P_TILE):
    if not obs.enabled():
        return _rff.rff_density(points, w, b, z, tile=tile, p_tile=p_tile,
                                interpret=INTERPRET)
    return profiled_call(
        "rff_density",
        lambda: _rff.rff_density(points, w, b, z, tile=tile, p_tile=p_tile,
                                 interpret=INTERPRET),
        n=points.shape[0], D=w.shape[0], tile=tile, p_tile=p_tile)


def aqp_box_sums(x, h_diag, lo, hi, tgt, tile=_abx.TILE, q_tile=_abx.Q_TILE):
    if not obs.enabled():
        return _abx.aqp_box_sums(x, h_diag, lo, hi, tgt, tile=tile,
                                 q_tile=q_tile, interpret=INTERPRET)
    return profiled_call(
        "aqp_box_sums",
        lambda: _abx.aqp_box_sums(x, h_diag, lo, hi, tgt, tile=tile,
                                  q_tile=q_tile, interpret=INTERPRET),
        n=x.shape[0], d=x.shape[1] if x.ndim > 1 else 1, G=lo.shape[0],
        tile=tile, q_tile=q_tile)