"""Public wrappers for the Pallas kernels.

On this CPU container, kernels run in interpret mode (the kernel body executes
in Python on CPU — correctness path); on a TPU runtime `interpret=False`
compiles through Mosaic.  `INTERPRET` flips automatically on backend.

Tile sizes resolve at CALL time, never at import: explicit kwarg > measured
tile cache (`kernels/autotune.py`, keyed by kernel + bucketed shape) > env
var > module default.  Wrappers that sit inside a jitted caller (the 1-D and
box batch paths) resolve once per traced shape — tile choices are static
under jit anyway, so per-shape trace-time resolution is exactly as fresh as
a recompile.

With `repro.obs` enabled, every wrapper routes through
`tuning.profiled_call`, which records fenced wall/dispatch timings into the
process-global metrics registry keyed by (kernel, shape, tile).  Disabled
(the default), each wrapper takes the direct branch — same jitted callable,
no fencing, no extra work.
"""
from __future__ import annotations

import jax

from repro import obs

from . import aqp_batch as _ab
from . import aqp_boxes as _abx
from . import aqp_grouped as _agr
from . import autotune as _tune
from . import gh_fused as _gh
from . import kde_eval as _kde
from . import lscv_grid as _lg
from . import pairwise_reduce as _pr
from . import qmc_reduce as _qmc
from . import rff_eval as _rff
from . import sv_precompute as _sv
from .tuning import profiled_call

INTERPRET = jax.default_backend() != "tpu"


def pairwise_scaled_ksum(x, g, kind="k4", tile=None):
    (tile,) = _tune.resolve(
        "pairwise_scaled_ksum", {"n": x.shape[0]},
        tile=(tile, "REPRO_PAIRWISE_TILE", _pr.TILE))
    if not obs.enabled():
        return _pr.pairwise_scaled_ksum(x, g, kind=kind, tile=tile,
                                        interpret=INTERPRET)
    return profiled_call(
        "pairwise_scaled_ksum",
        lambda: _pr.pairwise_scaled_ksum(x, g, kind=kind, tile=tile,
                                         interpret=INTERPRET),
        n=x.shape[0], kind=kind, tile=tile)


def sv_matrix(x, m, tile=None, algorithm="mxu"):
    (tile,) = _tune.resolve(
        "sv_matrix", {"n": x.shape[0], "d": x.shape[1] if x.ndim > 1 else 1},
        tile=(tile, "REPRO_SV_TILE", _sv.TILE))
    if not obs.enabled():
        return _sv.sv_matrix(x, m, tile=tile, algorithm=algorithm,
                             interpret=INTERPRET)
    return profiled_call(
        "sv_matrix",
        lambda: _sv.sv_matrix(x, m, tile=tile, algorithm=algorithm,
                              interpret=INTERPRET),
        n=x.shape[0], d=x.shape[1] if x.ndim > 1 else 1, tile=tile,
        algorithm=algorithm)


def gh_fused_sum(x, h_inv, c_k, c_kk, tile=None):
    (tile,) = _tune.resolve(
        "gh_fused_sum", {"n": x.shape[0], "d": x.shape[1] if x.ndim > 1 else 1},
        tile=(tile, "REPRO_GH_TILE", _gh.TILE))
    if not obs.enabled():
        return _gh.gh_fused_sum(x, h_inv, c_k, c_kk, tile=tile,
                                interpret=INTERPRET)
    return profiled_call(
        "gh_fused_sum",
        lambda: _gh.gh_fused_sum(x, h_inv, c_k, c_kk, tile=tile,
                                 interpret=INTERPRET),
        n=x.shape[0], d=x.shape[1] if x.ndim > 1 else 1, tile=tile)


def lscv_grid_sums(x, sigma_inv, h_grid, c_k, c_kk, tile=None, h_tile=None):
    tile, h_tile = _tune.resolve(
        "lscv_grid_sums", {"n": x.shape[0], "G": h_grid.shape[0]},
        tile=(tile, "REPRO_LSCV_TILE", _lg.TILE),
        h_tile=(h_tile, "REPRO_LSCV_H_TILE", _lg.H_TILE))
    if not obs.enabled():
        return _lg.lscv_grid_sums(x, sigma_inv, h_grid, c_k, c_kk, tile=tile,
                                  h_tile=h_tile, interpret=INTERPRET)
    return profiled_call(
        "lscv_grid_sums",
        lambda: _lg.lscv_grid_sums(x, sigma_inv, h_grid, c_k, c_kk, tile=tile,
                                   h_tile=h_tile, interpret=INTERPRET),
        n=x.shape[0], G=h_grid.shape[0], tile=tile, h_tile=h_tile)


def kde_eval(points, x, h, tile=None):
    (tile,) = _tune.resolve(
        "kde_eval", {"n": x.shape[0], "G": points.shape[0]},
        tile=(tile, "REPRO_KDE_EVAL_TILE", _kde.TILE))
    if not obs.enabled():
        return _kde.kde_eval(points, x, h, tile=tile, interpret=INTERPRET)
    return profiled_call(
        "kde_eval",
        lambda: _kde.kde_eval(points, x, h, tile=tile, interpret=INTERPRET),
        n=x.shape[0], G=points.shape[0], tile=tile)


def aqp_batch_sums(x, h, a, b, tile=None, q_tile=None):
    shape = {"n": x.shape[0], "G": a.shape[0]}
    tile, q_tile = _tune.resolve(
        "aqp_batch_sums", shape,
        tile=(tile, "REPRO_AQP_TILE", _ab.TILE),
        q_tile=(q_tile, "REPRO_AQP_Q_TILE", _ab.Q_TILE))
    if not obs.enabled():
        return _ab.aqp_batch_sums(x, h, a, b, tile=tile, q_tile=q_tile,
                                  interpret=INTERPRET)
    return profiled_call(
        "aqp_batch_sums",
        lambda: _ab.aqp_batch_sums(x, h, a, b, tile=tile, q_tile=q_tile,
                                   interpret=INTERPRET),
        n=x.shape[0], G=a.shape[0], tile=tile, q_tile=q_tile)


def rff_density(points, w, b, z, tile=None, p_tile=None):
    shape = {"n": w.shape[0], "d": points.shape[1], "G": points.shape[0]}
    tile, p_tile = _tune.resolve(
        "rff_density", shape,
        tile=(tile, "REPRO_RFF_TILE", _rff.TILE),
        p_tile=(p_tile, "REPRO_RFF_P_TILE", _rff.P_TILE))
    if not obs.enabled():
        return _rff.rff_density(points, w, b, z, tile=tile, p_tile=p_tile,
                                interpret=INTERPRET)
    return profiled_call(
        "rff_density",
        lambda: _rff.rff_density(points, w, b, z, tile=tile, p_tile=p_tile,
                                 interpret=INTERPRET),
        n=points.shape[0], D=w.shape[0], tile=tile, p_tile=p_tile)


def aqp_box_sums(x, h_diag, lo, hi, tgt, tile=None, q_tile=None):
    d = x.shape[1] if x.ndim > 1 else 1
    shape = {"n": x.shape[0], "d": d, "G": lo.shape[0]}
    tile, q_tile = _tune.resolve(
        "aqp_box_sums", shape,
        tile=(tile, "REPRO_AQP_BOXES_TILE", _abx.TILE),
        q_tile=(q_tile, "REPRO_AQP_BOXES_Q_TILE", _abx.Q_TILE))
    if not obs.enabled():
        return _abx.aqp_box_sums(x, h_diag, lo, hi, tgt, tile=tile,
                                 q_tile=q_tile, interpret=INTERPRET)
    return profiled_call(
        "aqp_box_sums",
        lambda: _abx.aqp_box_sums(x, h_diag, lo, hi, tgt, tile=tile,
                                  q_tile=q_tile, interpret=INTERPRET),
        n=x.shape[0], d=d, G=lo.shape[0], tile=tile, q_tile=q_tile)


def aqp_grouped_sums(x, h_diag, lo, hi, glo, ghi, g_axis, tgt,
                     tile=None, g_tile=None):
    shape = {"n": x.shape[0], "d": x.shape[1], "G": glo.shape[0]}
    tile, g_tile = _tune.resolve(
        "aqp_grouped_sums", shape,
        tile=(tile, "REPRO_AQP_GROUPED_TILE", _agr.TILE),
        g_tile=(g_tile, "REPRO_AQP_GROUPED_G_TILE", _agr.G_TILE))
    if not obs.enabled():
        return _agr.aqp_grouped_sums(x, h_diag, lo, hi, glo, ghi, g_axis,
                                     tgt, tile=tile, g_tile=g_tile,
                                     interpret=INTERPRET)
    return profiled_call(
        "aqp_grouped_sums",
        lambda: _agr.aqp_grouped_sums(x, h_diag, lo, hi, glo, ghi, g_axis,
                                      tgt, tile=tile, g_tile=g_tile,
                                      interpret=INTERPRET),
        n=x.shape[0], d=x.shape[1], G=glo.shape[0], tile=tile, g_tile=g_tile)


def qmc_box_reduce(nodes, x, h_inv, log_norm, lo, hi, tgt,
                   tile=None, m_tile=None, q_tile=None):
    shape = {"n": x.shape[0], "d": x.shape[1], "G": lo.shape[0],
             "m": nodes.shape[0]}
    tile, m_tile, q_tile = _tune.resolve(
        "qmc_box_reduce", shape,
        tile=(tile, "REPRO_QMC_TILE", _qmc.TILE),
        m_tile=(m_tile, "REPRO_QMC_M_TILE", _qmc.M_TILE),
        q_tile=(q_tile, "REPRO_QMC_Q_TILE", _qmc.Q_TILE))
    if not obs.enabled():
        return _qmc.qmc_box_reduce(nodes, x, h_inv, log_norm, lo, hi, tgt,
                                   tile=tile, m_tile=m_tile, q_tile=q_tile,
                                   interpret=INTERPRET)
    return profiled_call(
        "qmc_box_reduce",
        lambda: _qmc.qmc_box_reduce(nodes, x, h_inv, log_norm, lo, hi, tgt,
                                    tile=tile, m_tile=m_tile, q_tile=q_tile,
                                    interpret=INTERPRET),
        n=x.shape[0], d=x.shape[1], G=lo.shape[0], m=nodes.shape[0],
        tile=tile, m_tile=m_tile, q_tile=q_tile)
