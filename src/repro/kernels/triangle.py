"""Triangular tile index math — the paper's Appendix A (eqs. 49/50), reused on
TPU to launch a *1-D Pallas grid over only the upper-triangular tiles* instead
of a rectangular grid with half the tiles masked away.

Paper mapping: bx -> (l, q), column-major enumeration of the upper triangle,
  l = ceil((sqrt(8 bx + 9) - 3) / 2)      (eq. 49, tile column)
  q = bx - l (l + 1) / 2                  (eq. 50, tile row)

The fp32 sqrt can be off by one ulp near perfect-square discriminants, so we
branchlessly correct l by checking the closed-form block counts (eq. 66):
column l is correct iff  l(l+1)/2 <= bx < (l+1)(l+2)/2.
"""
from __future__ import annotations

import jax.numpy as jnp


def n_tri_tiles(n_tiles: int) -> int:
    """Number of tiles in the upper triangle (incl. diagonal) of an
    n_tiles x n_tiles tile matrix (eq. 66 with l = n_tiles - 1)."""
    return n_tiles * (n_tiles + 1) // 2


def bx_to_ql(bx):
    """eqs. (49)/(50) with branchless +-1 correction. Returns (q, l) = (row, col).

    Works on traced int32 scalars (usable inside BlockSpec index_maps) and on
    numpy arrays.
    """
    bxf = bx.astype(jnp.float32) if hasattr(bx, "astype") else jnp.float32(bx)
    l0 = jnp.ceil((jnp.sqrt(8.0 * bxf + 9.0) - 3.0) / 2.0).astype(jnp.int32)

    def ok(l):
        lo = l * (l + 1) // 2
        hi = (l + 1) * (l + 2) // 2
        return (lo <= bx) & (bx < hi)

    l = jnp.where(ok(l0 - 1), l0 - 1, jnp.where(ok(l0), l0, l0 + 1))
    q = bx - l * (l + 1) // 2
    return q, l


def ql_to_bx(q, l):
    """Inverse mapping (for tests): bx = l(l+1)/2 + q, valid for q <= l."""
    return l * (l + 1) // 2 + q
