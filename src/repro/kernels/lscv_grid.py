"""Pallas TPU kernel: LSCV_h grid-search phase over precomputed S values.

The paper's §6.2 GPU scheme launches a 2-D computation grid — one *row of
blocks per tested h* — reducing T~ over the same precomputed S(v) values for
every h.  Here: 2-D Pallas grid (h-tile, S-tile); each step folds one (k, k)
slab of S values into `hk` per-h partials:

    T~(S; h) = c_kk * exp(-S / (4 h^2)) - 2 c_k * exp(-S / (2 h^2))  (eqs. 40-42)

The S matrix (with mask) is read O(n_h / hk) times — exactly the reuse the
§4.5 reformulation buys; the accumulator output revisits the same block across
the S-tile-index dimension (grid minor axis), the standard Pallas accumulation
pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import resolve_tile

TILE = 256
H_TILE = 8


def _kernel(s_ref, w_ref, hinv_ref, c_ref, out_ref, *, hk: int):
    j = pl.program_id(1)   # S-tile index (minor: varies fastest)
    s = s_ref[...]         # (k, k) S values (masked entries are 0)
    w = w_ref[...]         # (k, k) mask weights in {0, 1}
    c_k = c_ref[0]
    c_kk = c_ref[1]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = out_ref[...]
    for t in range(hk):            # unrolled over the h block
        inv_h2 = hinv_ref[t]       # 1 / h^2
        e2 = jnp.exp(-0.5 * s * inv_h2)
        e4 = jnp.exp(-0.25 * s * inv_h2)
        acc = acc.at[t].add(jnp.sum((c_kk * e4 - 2.0 * c_k * e2) * w))
    out_ref[...] = acc


def lscv_grid_sums(x: jax.Array, sigma_inv: jax.Array, h_grid: jax.Array,
                   c_k, c_kk, tile=None, h_tile=None,
                   interpret: bool = True) -> jax.Array:
    """For each h on the grid: sum_{i<j} T~(x_i - x_j).  Returns (n_h,).

    Phase 1 (S precompute) uses the sv_precompute kernel; phase 2 is this one.
    Tiles resolve at call time: kwarg > REPRO_LSCV_TILE / REPRO_LSCV_H_TILE >
    module defaults."""
    tile = resolve_tile("REPRO_LSCV_TILE", TILE, tile)
    h_tile = resolve_tile("REPRO_LSCV_H_TILE", H_TILE, h_tile)
    return _lscv_grid_sums(x, sigma_inv, h_grid, c_k, c_kk, tile, h_tile,
                           interpret)


@functools.partial(jax.jit, static_argnames=("tile", "h_tile", "interpret"))
def _lscv_grid_sums(x: jax.Array, sigma_inv: jax.Array, h_grid: jax.Array,
                    c_k, c_kk, tile: int, h_tile: int,
                    interpret: bool) -> jax.Array:
    from .sv_precompute import _sv_matrix

    n, d = x.shape
    n_h = h_grid.shape[0]
    s = _sv_matrix(x, sigma_inv, tile, "mxu", interpret)

    k = min(tile, s.shape[0])
    pad = (-n) % k
    sp = jnp.pad(s, ((0, pad), (0, pad)))
    idx = jnp.arange(sp.shape[0])
    w = ((idx[:, None] < idx[None, :]) & (idx[None, :] < n) & (idx[:, None] < n))
    w = w.astype(x.dtype)
    n_tiles = sp.shape[0] // k

    hk = min(h_tile, n_h)
    pad_h = (-n_h) % hk
    hinv = jnp.pad(1.0 / (h_grid * h_grid), (0, pad_h)).astype(x.dtype)
    n_h_tiles = hinv.shape[0] // hk
    consts = jnp.stack([jnp.asarray(c_k, x.dtype), jnp.asarray(c_kk, x.dtype)])

    # Grid: (h-tile major, flattened S-tile minor) so the output block for a
    # given h-tile stays resident while all S tiles stream through.
    n_s_tiles = n_tiles * n_tiles

    out = pl.pallas_call(
        functools.partial(_kernel, hk=hk),
        grid=(n_h_tiles, n_s_tiles),
        in_specs=[
            pl.BlockSpec((k, k), lambda i, j: (j // n_tiles, j % n_tiles)),
            pl.BlockSpec((k, k), lambda i, j: (j // n_tiles, j % n_tiles)),
            pl.BlockSpec((hk,), lambda i, j: (i,)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((hk,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((hinv.shape[0],), x.dtype),
        interpret=interpret,
    )(sp, w, hinv, consts)
    return out[:n_h]
