"""Pure-jnp oracles for every Pallas kernel in this package.

Each function computes exactly what the corresponding kernel computes, with no
tiling, so kernel tests can `assert_allclose` against it across shape/dtype
sweeps.  These are O(n^2)-memory implementations — test scale only.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import gaussian as G


def _pair_mask(n: int):
    idx = jnp.arange(n)
    return idx[:, None] < idx[None, :]


def pairwise_scaled_ksum(x: jnp.ndarray, g: jnp.ndarray, kind: str) -> jnp.ndarray:
    """sum_{i<j} K^(r)((x_i - x_j)/g)   (PLUGIN eqs. 16/18 inner sums)."""
    fun = {"k4": G.k4, "k6": G.k6, "gauss": G.phi}[kind]
    diff = (x[:, None] - x[None, :]) / g
    return jnp.sum(jnp.where(_pair_mask(x.shape[0]), fun(diff), 0.0))


def sv_matrix(x: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """S_{ij} = (x_i-x_j)^T M (x_i-x_j) on the strict upper triangle, else 0.
    x: (n, d)."""
    v = x[:, None, :] - x[None, :, :]
    s = jnp.einsum("ijd,de,ije->ij", v, m, v)
    return jnp.where(_pair_mask(x.shape[0]), s, 0.0)


def gh_fused_sum(x: jnp.ndarray, h_inv: jnp.ndarray, c_k, c_kk) -> jnp.ndarray:
    """sum_{i<j} T_H(x_i - x_j)  (LSCV_H eq. 32 inner sum, fused §6.3)."""
    s = sv_matrix(x, h_inv)
    t = c_kk * jnp.exp(-0.25 * s) - 2.0 * c_k * jnp.exp(-0.5 * s)
    return jnp.sum(jnp.where(_pair_mask(x.shape[0]), t, 0.0))


def lscv_grid_sums(x: jnp.ndarray, sigma_inv: jnp.ndarray, h_grid: jnp.ndarray,
                   c_k, c_kk) -> jnp.ndarray:
    """Per-h inner sums of eq. (43): for each h, sum_{i<j} T~(x_i - x_j)."""
    s = sv_matrix(x, sigma_inv)
    mask = _pair_mask(x.shape[0])

    def per_h(h):
        t = c_kk * jnp.exp(-0.25 * s / (h * h)) - 2.0 * c_k * jnp.exp(-0.5 * s / (h * h))
        return jnp.sum(jnp.where(mask, t, 0.0))

    import jax
    return jax.vmap(per_h)(h_grid)


def kde_eval(points: jnp.ndarray, x: jnp.ndarray, h) -> jnp.ndarray:
    """f^(points) per eq. (3), Gaussian kernel. points: (m, d), x: (n, d)."""
    import math
    n, d = x.shape
    diff = (points[:, None, :] - x[None, :, :]) / h
    quad = 0.5 * jnp.sum(diff * diff, axis=-1)
    norm = (2.0 * math.pi) ** (-d / 2.0) * h ** (-d)
    return norm * jnp.mean(jnp.exp(-quad), axis=1)


def aqp_box_sums(x: jnp.ndarray, h_diag: jnp.ndarray, lo: jnp.ndarray,
                 hi: jnp.ndarray, tgt: jnp.ndarray):
    """Unscaled eq. 11 box integrals for a query batch (product kernel,
    diagonal bandwidth).  x: (n,d), lo/hi: (q,d), tgt: (q,) int32 ->
    (count_raw, sum_raw), each (q,)."""
    sqrt1_2 = 1.0 / math.sqrt(2.0)
    inv_sqrt_2pi = 1.0 / math.sqrt(2.0 * math.pi)
    za = (lo[:, None, :] - x[None, :, :]) / h_diag[None, None, :]   # (q, n, d)
    zb = (hi[:, None, :] - x[None, :, :]) / h_diag[None, None, :]
    d_Phi = 0.5 * (jax.scipy.special.erf(zb * sqrt1_2)
                   - jax.scipy.special.erf(za * sqrt1_2))
    d_phi = inv_sqrt_2pi * (jnp.exp(-0.5 * zb * zb) - jnp.exp(-0.5 * za * za))
    moment = x[None, :, :] * d_Phi - h_diag[None, None, :] * d_phi
    axis = jnp.arange(x.shape[1])
    factors = jnp.where(axis[None, None, :] == tgt[:, None, None], moment, d_Phi)
    count_raw = jnp.sum(jnp.prod(d_Phi, axis=2), axis=1)
    sum_raw = jnp.sum(jnp.prod(factors, axis=2), axis=1)
    return count_raw, sum_raw


def aqp_grouped_sums(x: jnp.ndarray, h_diag: jnp.ndarray, lo: jnp.ndarray,
                     hi: jnp.ndarray, glo: jnp.ndarray, ghi: jnp.ndarray,
                     g_axis: int, tgt: int):
    """Unscaled factored GROUP BY integrals (eq. 11): shared-axes product
    crossed with G per-category windows on axis `g_axis`.  x: (n,d), lo/hi:
    (d,) shared box (group axis ignored), glo/ghi: (G,) -> (count_raw,
    sum_raw), each (G,)."""
    sqrt1_2 = 1.0 / math.sqrt(2.0)
    inv_sqrt_2pi = 1.0 / math.sqrt(2.0 * math.pi)
    za = (lo[None, :] - x) / h_diag[None, :]                        # (n, d)
    zb = (hi[None, :] - x) / h_diag[None, :]
    d_Phi = 0.5 * (jax.scipy.special.erf(zb * sqrt1_2)
                   - jax.scipy.special.erf(za * sqrt1_2))
    axis = jnp.arange(x.shape[1])
    keep = axis != g_axis
    shared_cnt = jnp.prod(jnp.where(keep[None, :], d_Phi, 1.0), axis=1)

    xg = x[:, g_axis]
    hg = h_diag[g_axis]
    gza = (glo[None, :] - xg[:, None]) / hg                         # (n, G)
    gzb = (ghi[None, :] - xg[:, None]) / hg
    g_Phi = 0.5 * (jax.scipy.special.erf(gzb * sqrt1_2)
                   - jax.scipy.special.erf(gza * sqrt1_2))
    count_raw = jnp.sum(shared_cnt[:, None] * g_Phi, axis=0)

    if tgt == g_axis:
        g_dphi = inv_sqrt_2pi * (jnp.exp(-0.5 * gzb * gzb)
                                 - jnp.exp(-0.5 * gza * gza))
        g_moment = xg[:, None] * g_Phi - hg * g_dphi
        sum_raw = jnp.sum(shared_cnt[:, None] * g_moment, axis=0)
    else:
        d_phi = inv_sqrt_2pi * (jnp.exp(-0.5 * zb * zb)
                                - jnp.exp(-0.5 * za * za))
        moment = x * d_Phi - h_diag[None, :] * d_phi
        factors = jnp.where(axis[None, :] == tgt, moment, d_Phi)
        shared_sm = jnp.prod(jnp.where(keep[None, :], factors, 1.0), axis=1)
        sum_raw = jnp.sum(shared_sm[:, None] * g_Phi, axis=0)
    return count_raw, sum_raw


def qmc_box_reduce(nodes: jnp.ndarray, x: jnp.ndarray, h_inv: jnp.ndarray,
                   log_norm, lo: jnp.ndarray, hi: jnp.ndarray,
                   tgt: jnp.ndarray):
    """Raw double sums of the fused QMC box reduction: for each box q,
    sum over nodes inside the box of the summed (not averaged) Gaussian
    kernel values against the whole sample.  nodes: (m,d), x: (n,d),
    h_inv: (d,d), lo/hi: (q,d), tgt: (q,) -> (cnt_sums, sum_sums)."""
    diff = nodes[:, None, :] - x[None, :, :]                        # (m, n, d)
    quad = 0.5 * jnp.einsum("mnd,de,mne->mn", diff, h_inv, diff)
    f_sums = jnp.sum(jnp.exp(log_norm - quad), axis=1)              # (m,)
    inside = jnp.all((nodes[None, :, :] >= lo[:, None, :])
                     & (nodes[None, :, :] <= hi[:, None, :]), axis=2)
    w = inside * f_sums[None, :]                                    # (q, m)
    cnt_sums = jnp.sum(w, axis=1)
    tvals = nodes.T[tgt]                     # (q, m): node target coordinate
    sum_sums = jnp.sum(w * tvals, axis=1)
    return cnt_sums, sum_sums


def rff_density(points: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                z: jnp.ndarray) -> jnp.ndarray:
    """Un-normalised RFF density dots: cos(points @ W.T + b) @ z.
    points: (m, d), w: (D, d), b/z: (D,) -> (m,)."""
    return jnp.cos(points @ w.T + b[None, :]) @ z


def aqp_batch_sums(x: jnp.ndarray, h, a: jnp.ndarray, b: jnp.ndarray):
    """Unscaled closed-form integrals of eqs. 9-10 for a query batch.
    x: (n,), a/b: (q,) -> (count_raw, sum_raw), each (q,)."""
    sqrt1_2 = 1.0 / math.sqrt(2.0)
    inv_sqrt_2pi = 1.0 / math.sqrt(2.0 * math.pi)
    za = (a[:, None] - x[None, :]) / h                   # (q, n)
    zb = (b[:, None] - x[None, :]) / h
    d_Phi = 0.5 * (jax.scipy.special.erf(zb * sqrt1_2)
                   - jax.scipy.special.erf(za * sqrt1_2))
    d_phi = inv_sqrt_2pi * (jnp.exp(-0.5 * zb * zb) - jnp.exp(-0.5 * za * za))
    count_raw = jnp.sum(d_Phi, axis=1)
    sum_raw = jnp.sum(x[None, :] * d_Phi - h * d_phi, axis=1)
    return count_raw, sum_raw
