"""Pallas TPU kernel: fused QMC box reduction for full-H synopses.

The quasi-MC fallback (`core/aqp_multid.py:_qmc_shared_terms`) answers a
box batch in two dense passes: a (nodes x sample) KDE evaluation producing
the shared density vector f, then a (boxes x nodes) indicator reduction
that re-materializes `f * inside_q` per box.  This kernel fuses both: the
contraction is linear in the sample and node sums, so for box q

    cnt_sums[q] = sum_m sum_i  1_q(node_m) * exp(log_norm - quad_mi)
    sum_sums[q] = sum_m sum_i  1_q(node_m) * node_m[t_q] * exp(...)
      with  quad_mi = 0.5 (node_m - x_i)^T H^-1 (node_m - x_i)

accumulates tile-by-tile without ever holding f — the caller divides by the
node count and applies vol(G) to recover the `_qmc_shared_terms` raw terms.

Grid: (box-tile major, node-tile, data-tile minor).  The (qk, 2)
accumulator block stays resident across both inner loops; the per-tile
kernel slab builds the quadratic form with d(d+1)/2 broadcast
multiply-accumulate passes over per-axis difference slabs (d is small in
the paper's scope — no (mk, k, d) intermediate), and the indicator
contraction is a (qk, mk) @ (mk,) matvec on the MXU.

Tile sizes resolve per call (REPRO_QMC_TILE data / REPRO_QMC_M_TILE node /
REPRO_QMC_Q_TILE box, see tuning.resolve_tile); call-site kwargs win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import resolve_tile

TILE = 256     # data-tile default (env: REPRO_QMC_TILE)
M_TILE = 256   # node-tile default (env: REPRO_QMC_M_TILE)
Q_TILE = 64    # box-tile default (env: REPRO_QMC_Q_TILE)


def _kernel(lo_ref, hi_ref, tgt_ref, nodes_ref, x_ref, hinv_ref, ln_ref,
            out_ref, *, n: int, m: int, qk: int, mk: int, k: int, d: int):
    j = pl.program_id(1)     # node-tile index
    l = pl.program_id(2)     # data-tile index (minor: varies fastest)
    lo = lo_ref[...]         # (qk, d) box lower corners
    hi = hi_ref[...]         # (qk, d) box upper corners
    tgt = tgt_ref[...]       # (qk,)  SUM/AVG target axis per box
    nodes = nodes_ref[...]   # (mk, d) Halton nodes (padded rows masked)
    x = x_ref[...]           # (k, d) sample rows (padded rows masked)
    hinv = hinv_ref[...]     # (d, d) bandwidth inverse (symmetric)
    log_norm = ln_ref[0]

    # quad[m, i] = (node_m - x_i)^T H^-1 (node_m - x_i), d unrolled.
    # Contract v = diff @ H^-1 BEFORE the second dot — the same order as the
    # jnp path's einsum.  An ill-conditioned H (LSCV on near-collinear
    # columns) makes H^-1 entries huge with alternating signs; v absorbs
    # that cancellation at small magnitude, where a symmetric-pair expansion
    # of the quadratic would sum three enormous terms and lose float32 bits.
    diffs = [nodes[:, a][:, None] - x[:, a][None, :] for a in range(d)]
    quad = jnp.zeros((mk, k), x.dtype)
    for a in range(d):
        v = jnp.zeros((mk, k), x.dtype)
        for e in range(d):
            v += hinv[a, e] * diffs[e]
        quad += v * diffs[a]
    vals = jnp.exp(log_norm - 0.5 * quad)              # (mk, k)

    cols = l * k + jax.lax.broadcasted_iota(jnp.int32, (mk, k), 1)
    f_part = jnp.sum(jnp.where(cols < n, vals, 0.0), axis=1)     # (mk,)
    node_rows = j * mk + jax.lax.broadcasted_iota(jnp.int32, (mk,), 0)
    f_part = jnp.where(node_rows < m, f_part, 0.0)

    inside = jnp.ones((qk, mk), jnp.bool_)
    tval = jnp.zeros((qk, mk), x.dtype)
    for a in range(d):
        na = nodes[:, a][None, :]                      # (1, mk)
        inside &= (na >= lo[:, a][:, None]) & (na <= hi[:, a][:, None])
        tval += jnp.where(tgt[:, None] == a, na, 0.0)
    ind = inside.astype(x.dtype)

    cnt = ind @ f_part                                 # (qk,) MXU matvec
    sm = (ind * tval) @ f_part

    @pl.when((j == 0) & (l == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.stack([cnt, sm], axis=1)       # (qk, 2)


@functools.partial(jax.jit, static_argnames=("tile", "m_tile", "q_tile",
                                             "interpret"))
def _qmc_box_reduce(nodes, x, h_inv, log_norm, lo, hi, tgt, tile, m_tile,
                    q_tile, interpret):
    m, d = nodes.shape
    n = x.shape[0]
    q = lo.shape[0]
    if n == 0 or m == 0 or q == 0:
        # zero grid iterations would leave the output buffer uninitialized
        z = jnp.zeros((q,), x.dtype)
        return z, z

    k = min(tile, max(8, 1 << (n - 1).bit_length()))
    mk = min(m_tile, max(8, 1 << (m - 1).bit_length()))
    qk = min(q_tile, max(8, 1 << (q - 1).bit_length()))
    xp = jnp.pad(x, ((0, (-n) % k), (0, 0)))
    np_ = jnp.pad(nodes, ((0, (-m) % mk), (0, 0)))
    lop = jnp.pad(lo, ((0, (-q) % qk), (0, 0)))
    hip = jnp.pad(hi, ((0, (-q) % qk), (0, 0)))
    tgtp = jnp.pad(tgt, (0, (-q) % qk))

    out = pl.pallas_call(
        functools.partial(_kernel, n=n, m=m, qk=qk, mk=mk, k=k, d=d),
        grid=(lop.shape[0] // qk, np_.shape[0] // mk, xp.shape[0] // k),
        in_specs=[
            pl.BlockSpec((qk, d), lambda i, j, l: (i, 0)),
            pl.BlockSpec((qk, d), lambda i, j, l: (i, 0)),
            pl.BlockSpec((qk,), lambda i, j, l: (i,)),
            pl.BlockSpec((mk, d), lambda i, j, l: (j, 0)),
            pl.BlockSpec((k, d), lambda i, j, l: (l, 0)),
            pl.BlockSpec((d, d), lambda i, j, l: (0, 0)),
            pl.BlockSpec((1,), lambda i, j, l: (0,)),
        ],
        out_specs=pl.BlockSpec((qk, 2), lambda i, j, l: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((lop.shape[0], 2), x.dtype),
        interpret=interpret,
    )(lop, hip, tgtp, np_, xp, h_inv.astype(x.dtype),
      log_norm.reshape(1).astype(x.dtype))
    return out[:q, 0], out[:q, 1]


def qmc_box_reduce(nodes: jax.Array, x: jax.Array, h_inv: jax.Array,
                   log_norm: jax.Array, lo: jax.Array, hi: jax.Array,
                   tgt: jax.Array, tile: int = None, m_tile: int = None,
                   q_tile: int = None, interpret: bool = True):
    """Fused (boxes x nodes x sample) two-channel reduction.

    nodes: (m, d) shared QMC nodes; x: (n, d) sample rows; h_inv: (d, d)
    inverse bandwidth matrix; log_norm: scalar Gaussian log-normaliser;
    lo/hi: (q, d) boxes; tgt: (q,) int32.  Returns (cnt_sums, sum_sums),
    each (q,): raw double sums of the masked kernel values — the caller
    applies vol(G)/m to recover `_qmc_shared_terms` count/sum terms.
    """
    tile = resolve_tile("REPRO_QMC_TILE", TILE, tile)
    m_tile = resolve_tile("REPRO_QMC_M_TILE", M_TILE, m_tile)
    q_tile = resolve_tile("REPRO_QMC_Q_TILE", Q_TILE, q_tile)
    return _qmc_box_reduce(nodes, x, h_inv, jnp.asarray(log_norm), lo, hi,
                           tgt, tile, m_tile, q_tile, interpret)
