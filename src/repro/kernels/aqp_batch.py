"""Pallas TPU kernel: batched AQP Phi-difference reduction (paper eqs. 9-10).

One launch answers a whole batch of range queries against one synopsis: for
every query q with range [a_q, b_q] and every sample point x_i it accumulates

    count_raw[q] = sum_i  Phi((b_q - x_i)/h) - Phi((a_q - x_i)/h)       (eq. 9)
    sum_raw[q]   = sum_i  x_i [Phi]_q,i - h [phi]_q,i                    (eq. 10)

Grid: (query-tile major, data-tile minor).  The (qk, 2) accumulator block for
a query tile stays resident while all data tiles stream through — the same
accumulation pattern as lscv_grid.py.  COUNT/SUM/AVG selection and the
sample->relation scale factor are applied by the caller (core/aqp.py), so the
kernel stays a pure two-channel reduction.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import resolve_tile

# Defaults; resolved per CALL against REPRO_AQP_TILE / REPRO_AQP_Q_TILE so a
# sweep or late env change moves them without a restart; kwargs still win.
TILE = 256
Q_TILE = 128

_SQRT1_2 = 1.0 / math.sqrt(2.0)
_INV_SQRT_2PI = 1.0 / math.sqrt(2.0 * math.pi)


def _kernel(a_ref, b_ref, x_ref, h_ref, out_ref, *, n: int, qk: int, k: int):
    j = pl.program_id(1)   # data-tile index (minor: varies fastest)
    a = a_ref[...]         # (qk,) lower range bounds
    b = b_ref[...]         # (qk,) upper range bounds
    x = x_ref[...]         # (k,) sample chunk (padded entries masked below)
    h = h_ref[0]
    inv_h = 1.0 / h

    za = (a[:, None] - x[None, :]) * inv_h              # (qk, k)
    zb = (b[:, None] - x[None, :]) * inv_h
    d_Phi = 0.5 * (jax.scipy.special.erf(zb * _SQRT1_2)
                   - jax.scipy.special.erf(za * _SQRT1_2))
    d_phi = _INV_SQRT_2PI * (jnp.exp(-0.5 * zb * zb) - jnp.exp(-0.5 * za * za))

    cols = j * k + jax.lax.broadcasted_iota(jnp.int32, (qk, k), 1)
    valid = cols < n
    d_Phi = jnp.where(valid, d_Phi, 0.0)
    d_phi = jnp.where(valid, d_phi, 0.0)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cnt = jnp.sum(d_Phi, axis=1)
    sm = jnp.sum(x[None, :] * d_Phi - h * d_phi, axis=1)
    out_ref[...] += jnp.stack([cnt, sm], axis=1)        # (qk, 2)


@functools.partial(jax.jit, static_argnames=("tile", "q_tile", "interpret"))
def _aqp_batch_sums(x, h, a, b, tile, q_tile, interpret):
    n = x.shape[0]
    q = a.shape[0]
    if n == 0 or q == 0:
        # zero grid iterations would leave the output buffer uninitialized
        z = jnp.zeros((q,), x.dtype)
        return z, z

    k = min(tile, max(8, 1 << (n - 1).bit_length()))
    qk = min(q_tile, max(8, 1 << (q - 1).bit_length()))
    xp = jnp.pad(x, (0, (-n) % k))
    ap = jnp.pad(a, (0, (-q) % qk))
    bp = jnp.pad(b, (0, (-q) % qk))

    out = pl.pallas_call(
        functools.partial(_kernel, n=n, qk=qk, k=k),
        grid=(ap.shape[0] // qk, xp.shape[0] // k),
        in_specs=[
            pl.BlockSpec((qk,), lambda i, j: (i,)),
            pl.BlockSpec((qk,), lambda i, j: (i,)),
            pl.BlockSpec((k,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((qk, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], 2), x.dtype),
        interpret=interpret,
    )(ap, bp, xp, h.reshape(1).astype(x.dtype))
    return out[:q, 0], out[:q, 1]


def aqp_batch_sums(x: jax.Array, h: jax.Array, a: jax.Array, b: jax.Array,
                   tile: int = None, q_tile: int = None,
                   interpret: bool = True):
    """Two-channel (queries x sample) reduction.  x: (n,), a/b: (q,).

    Returns (count_raw, sum_raw), each (q,): the *unscaled* closed-form
    integrals of eqs. 9-10 summed over the retained sample.
    """
    tile = resolve_tile("REPRO_AQP_TILE", TILE, tile)
    q_tile = resolve_tile("REPRO_AQP_Q_TILE", Q_TILE, q_tile)
    return _aqp_batch_sums(x, h, a, b, tile, q_tile, interpret)
