"""Pallas TPU kernels for the paper's compute hot spots (§5).

pairwise_reduce — RR_fun triangular reduction (PLUGIN psi sums)      [§5.4]
sv_precompute   — S(v) quadratic-form tiles (LSCV_h precompute)      [§5.5/§4.5]
lscv_grid       — per-h T~ reduction over precomputed S (LSCV_h)     [§6.2]
gh_fused        — fused quadratic-form + T_H reduction (LSCV_H)      [§6.3]
kde_eval        — direct KDE evaluation (AQP serving)                [eq. 3]
aqp_batch       — batched (queries x sample) Phi-diff reduction      [eqs. 9-10]
aqp_boxes       — batched (queries x samples x dims) box reduction   [eq. 11]
triangle        — Appendix-A tile index math (eqs. 49/50)
ops             — jitted wrappers; ref — pure-jnp oracles
tuning          — env-overridable tile-size defaults (real-TPU runs)
"""
from . import ops, ref, triangle
