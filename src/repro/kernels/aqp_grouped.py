"""Pallas TPU kernel: factored GROUP BY box reduction (paper eq. 11).

A GROUP BY over a dictionary column expands to one box per category that
differs from its siblings on exactly ONE axis — the group column's code
window.  Fanning those out through the generic box kernel recomputes the
shared axes' Phi factors once per category: O(n * d * G).  This kernel is
the tiled form of `core/aqp_multid.py:_grouped_box_terms`: each data tile
computes the shared-axes product ONCE and crosses it with all G per-category
group-axis windows in one sweep, O(n * d + n * G):

    count_raw[g] = sum_i  shared_cnt_i * gPhi_ig
    sum_raw[g]   = sum_i  shared_sm_i  * gfac_ig

with shared_cnt_i the product of dPhi over the non-group axes, and the
first-moment factor (eq. 10 per axis) on the target axis — carried by the
shared product when the target is a kept axis, by the group factor when the
query aggregates the group column itself (`tgt_is_group`).

Grid: (category-tile major, data-tile minor) — the (gk, 2) accumulator
block stays resident while data tiles stream through, and the per-tile
cross term is a (gk, k) @ (k,) matvec on the MXU.  COUNT/SUM/AVG selection
and the sample->relation scale are applied by the caller
(core/aqp_multid.py); the kernel is a pure two-channel reduction.

Tile sizes resolve per call (REPRO_AQP_GROUPED_TILE /
REPRO_AQP_GROUPED_G_TILE, see tuning.resolve_tile); call-site kwargs win.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import resolve_tile

TILE = 128     # data-tile default (env: REPRO_AQP_GROUPED_TILE)
G_TILE = 64    # category-tile default (env: REPRO_AQP_GROUPED_G_TILE)

_SQRT1_2 = 1.0 / math.sqrt(2.0)
_INV_SQRT_2PI = 1.0 / math.sqrt(2.0 * math.pi)


def _kernel(glo_ref, ghi_ref, x_ref, h_ref, lo_ref, hi_ref, out_ref,
            *, n: int, gk: int, k: int, d: int, g_axis: int, tgt: int,
            tgt_is_group: bool):
    j = pl.program_id(1)     # data-tile index (minor: varies fastest)
    glo = glo_ref[...]       # (gk,) per-category window on the group axis
    ghi = ghi_ref[...]
    x = x_ref[...]           # (k, d) sample rows (padded rows masked below)
    h = h_ref[...]           # (d,)   diagonal bandwidth
    lo = lo_ref[...]         # (d,)   shared box (group axis' entry ignored)
    hi = hi_ref[...]

    inv_h = 1.0 / h
    za = (lo[None, :] - x) * inv_h[None, :]            # (k, d)
    zb = (hi[None, :] - x) * inv_h[None, :]
    d_Phi = 0.5 * (jax.scipy.special.erf(zb * _SQRT1_2)
                   - jax.scipy.special.erf(za * _SQRT1_2))
    axis = jax.lax.broadcasted_iota(jnp.int32, (1, d), 1)
    keep = axis != g_axis
    shared_cnt = jnp.prod(jnp.where(keep, d_Phi, 1.0), axis=1)   # (k,)

    valid = j * k + jax.lax.broadcasted_iota(jnp.int32, (k,), 0) < n
    shared_cnt = jnp.where(valid, shared_cnt, 0.0)

    xg = x[:, g_axis]
    hg = h[g_axis]
    gza = (glo[:, None] - xg[None, :]) / hg            # (gk, k)
    gzb = (ghi[:, None] - xg[None, :]) / hg
    g_Phi = 0.5 * (jax.scipy.special.erf(gzb * _SQRT1_2)
                   - jax.scipy.special.erf(gza * _SQRT1_2))
    cnt = g_Phi @ shared_cnt                           # (gk,) MXU matvec

    if tgt_is_group:
        g_dphi = _INV_SQRT_2PI * (jnp.exp(-0.5 * gzb * gzb)
                                  - jnp.exp(-0.5 * gza * gza))
        g_moment = xg[None, :] * g_Phi - hg * g_dphi
        sm = g_moment @ shared_cnt
    else:
        d_phi = _INV_SQRT_2PI * (jnp.exp(-0.5 * zb * zb)
                                 - jnp.exp(-0.5 * za * za))
        moment = x * d_Phi - h[None, :] * d_phi
        factors = jnp.where(axis == tgt, moment, d_Phi)
        shared_sm = jnp.prod(jnp.where(keep, factors, 1.0), axis=1)
        shared_sm = jnp.where(valid, shared_sm, 0.0)
        sm = g_Phi @ shared_sm

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.stack([cnt, sm], axis=1)       # (gk, 2)


@functools.partial(jax.jit, static_argnames=("g_axis", "tgt", "tile",
                                             "g_tile", "interpret"))
def _aqp_grouped_sums(x, h_diag, lo, hi, glo, ghi, g_axis, tgt, tile,
                      g_tile, interpret):
    n, d = x.shape
    G = glo.shape[0]
    if n == 0 or G == 0:
        # zero grid iterations would leave the output buffer uninitialized
        z = jnp.zeros((G,), x.dtype)
        return z, z

    k = min(tile, max(8, 1 << (n - 1).bit_length()))
    gk = min(g_tile, max(8, 1 << (G - 1).bit_length()))
    xp = jnp.pad(x, ((0, (-n) % k), (0, 0)))
    glop = jnp.pad(glo, (0, (-G) % gk))
    ghip = jnp.pad(ghi, (0, (-G) % gk))

    out = pl.pallas_call(
        functools.partial(_kernel, n=n, gk=gk, k=k, d=d, g_axis=g_axis,
                          tgt=tgt, tgt_is_group=(tgt == g_axis)),
        grid=(glop.shape[0] // gk, xp.shape[0] // k),
        in_specs=[
            pl.BlockSpec((gk,), lambda i, j: (i,)),
            pl.BlockSpec((gk,), lambda i, j: (i,)),
            pl.BlockSpec((k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((gk, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((glop.shape[0], 2), x.dtype),
        interpret=interpret,
    )(glop, ghip, xp, h_diag.astype(x.dtype), lo.astype(x.dtype),
      hi.astype(x.dtype))
    return out[:G, 0], out[:G, 1]


def aqp_grouped_sums(x: jax.Array, h_diag: jax.Array, lo: jax.Array,
                     hi: jax.Array, glo: jax.Array, ghi: jax.Array,
                     g_axis: int, tgt: int, tile: int = None,
                     g_tile: int = None, interpret: bool = True):
    """Two-channel factored GROUP BY reduction.

    x: (n, d) sample rows; h_diag: (d,); lo/hi: (d,) the family's shared
    box (the group axis' entries are ignored); glo/ghi: (G,) per-category
    interval on axis `g_axis`; tgt: static target axis.  Returns
    (count_raw, sum_raw), each (G,): the *unscaled* eq. 11 integrals —
    identical semantics to `core/aqp_multid.py:_grouped_box_terms`.
    """
    tile = resolve_tile("REPRO_AQP_GROUPED_TILE", TILE, tile)
    g_tile = resolve_tile("REPRO_AQP_GROUPED_G_TILE", G_TILE, g_tile)
    return _aqp_grouped_sums(x, h_diag, lo, hi, glo, ghi, int(g_axis),
                             int(tgt), tile, g_tile, interpret)
