"""Pallas TPU kernel: tiled quadratic-form precompute (paper §5.5 / Fig. 6).

Fills the strict upper triangle of  S_{ij} = (x_i - x_j)^T M (x_i - x_j)
— the §4.5 LSCV_h precompute (S(v) values, eq. 39).

Two in-kernel algorithms, selected statically:

  * "paper": a faithful port of the paper's eq. (60) loop nest — for each of
    the d x d (c, a) pairs, a rank-1 broadcast update of the (k, k) tile.
    O(d^2 k^2) VPU flops per tile; this is what the CUDA kernel does.

  * "mxu": the TPU-native beyond-paper formulation.  Expand the quadratic
    form (M symmetric):
        S_{rp} = qe_r + qf_p - 2 e_r^T M f_p
    where qe_r = e_r^T M e_r, qf_p = f_p^T M f_p.  The cross term is a
    (k,d) x (d,d) x (d,k) matmul chain that runs on the MXU instead of the
    VPU, turning the tile body from d^2 elementwise passes into two small
    matmuls + rank-1 broadcasts.  Identical results (validated in tests);
    ~d/2 x fewer VPU ops per tile — the win measured in EXPERIMENTS.md §Perf.

Layout: x is staged as A^T, i.e. (n, d) row-major so a (k, d) chunk is
contiguous — the same row-major-friendly access the paper engineers for its
chunk rows F_{x,:} (end of §5.5).  d rides in the lane dimension (padded to
128 by Mosaic); k = 256 rows in sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tuning import resolve_tile

TILE = 256


def _kernel(e_ref, f_ref, m_ref, out_ref, *, n: int, k: int, d: int, algorithm: str):
    q = pl.program_id(0)
    l = pl.program_id(1)
    e = e_ref[...]          # (k, d) rows-chunk of points
    f = f_ref[...]          # (k, d) cols-chunk of points
    m = m_ref[...]          # (d, d)

    if algorithm == "paper":
        # eq. (60): Y_{r,:} = sum_a (sum_c (e_{c,r} - F_{c,:}) m_{c,a}) (e_{a,r} - F_{a,:})
        y = jnp.zeros((k, k), e.dtype)
        for a in range(d):
            part = jnp.zeros((k, k), e.dtype)
            for c in range(d):
                part = part + m[c, a] * (e[:, c][:, None] - f[:, c][None, :])
            y = y + part * (e[:, a][:, None] - f[:, a][None, :])
    else:
        # "mxu": S = qe[:,None] + qf[None,:] - 2 E M F^T   (M symmetric)
        me = e @ m                                   # (k, d) MXU
        qe = jnp.sum(me * e, axis=1)                 # (k,)
        mf = f @ m
        qf = jnp.sum(mf * f, axis=1)                 # (k,)
        cross = jax.lax.dot_general(me, f, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)  # (k, k)
        y = qe[:, None] + qf[None, :] - 2.0 * cross.astype(e.dtype)

    rows = q * k + jax.lax.broadcasted_iota(jnp.int32, (k, k), 0)
    cols = l * k + jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)
    mask = (rows < cols) & (cols < n) & (rows < n)
    out_ref[...] = jnp.where(mask, y, 0.0)


def sv_matrix(x: jax.Array, m: jax.Array, tile=None,
              algorithm: str = "mxu", interpret: bool = True) -> jax.Array:
    """Dense masked (n, n) matrix of S(v) values. x: (n, d), m: (d, d).

    `tile` resolves at call time: kwarg > REPRO_SV_TILE > module default."""
    tile = resolve_tile("REPRO_SV_TILE", TILE, tile)
    return _sv_matrix(x, m, tile, algorithm, interpret)


@functools.partial(jax.jit, static_argnames=("tile", "algorithm", "interpret"))
def _sv_matrix(x: jax.Array, m: jax.Array, tile: int,
               algorithm: str, interpret: bool) -> jax.Array:
    n, d = x.shape
    k = min(tile, max(8, 1 << (n - 1).bit_length())) if n < tile else tile
    pad = (-n) % k
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    n_tiles = xp.shape[0] // k

    out = pl.pallas_call(
        functools.partial(_kernel, n=n, k=k, d=d, algorithm=algorithm),
        grid=(n_tiles, n_tiles),
        in_specs=[
            pl.BlockSpec((k, d), lambda q, l: (q, 0)),
            pl.BlockSpec((k, d), lambda q, l: (l, 0)),
            pl.BlockSpec((d, d), lambda q, l: (0, 0)),
        ],
        out_specs=pl.BlockSpec((k, k), lambda q, l: (q, l)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], xp.shape[0]), x.dtype),
        interpret=interpret,
    )(xp, xp, m.astype(x.dtype))
    return out[:n, :n]
