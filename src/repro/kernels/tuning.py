"""Tile-size tuning knobs for the Pallas kernels.

Every kernel module resolves its default tile sizes through `env_int` at
import time, so `interpret=False` runs on real TPU can be tuned without
editing source:

    REPRO_AQP_TILE=512 REPRO_AQP_Q_TILE=256 python -m benchmarks.run ...

Call-site kwargs (`tile=`, `q_tile=` on the ops.py wrappers) still override
the environment; the env var only moves the *default*.
"""
from __future__ import annotations

import os


def env_int(name: str, default: int) -> int:
    """Positive-int env override with a loud failure on malformed values —
    a silently ignored typo in a tuning sweep wastes a TPU reservation."""
    raw = os.environ.get(name)
    if raw is None or not raw.strip():
        return default
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(f"{name} must be a positive integer, got {raw!r}")
    if value <= 0:
        raise ValueError(f"{name} must be a positive integer, got {value}")
    return value
