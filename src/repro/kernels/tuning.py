"""Tile-size tuning knobs and measured-timing hooks for the Pallas kernels.

Every tunable kernel module resolves its tile sizes through `resolve_tile`
at CALL time, so `interpret=False` runs on real TPU can be tuned without
editing source — and without restarting the process:

    REPRO_AQP_TILE=512 REPRO_AQP_Q_TILE=256 python -m benchmarks.run ...

Call-site kwargs (`tile=`, `q_tile=` on the ops.py wrappers) still override
the environment; the env var only moves the *default*.  (Tiles used to be
baked into function defaults at import, which froze them before a sweep or
late env change could move them — `resolve_tile` is the one shared
call-time helper.)  On top of env/default resolution, the ops.py wrappers
consult the measured tile cache (`kernels/autotune.py`) first.

`profiled_call` is the measurement side of tuning: with `repro.obs` enabled,
every kernel dispatch records fenced wall time, dispatch time, and a call
count into the process-global metrics registry keyed by
(kernel, n, d, G, tile, ...), so an autotuner can read `measured()` data
for exactly the shapes the workload runs instead of sweeping blind.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro import knobs, obs


def env_int(name: str, default: int) -> int:
    """Positive-int env override with a loud failure on malformed values —
    a silently ignored typo in a tuning sweep wastes a TPU reservation.
    Delegates to the central knob registry (`repro.knobs`), so an
    unregistered name fails loudly too."""
    return knobs.get_int(name, default)


def resolve_tile(env_name: str, default: int, override=None) -> int:
    """One tile size, resolved at CALL time: explicit kwarg > env var >
    module default.  Kernel modules route every tile through this instead of
    baking `tile=TILE` into function defaults — an import-time default would
    freeze the value before an in-process sweep or late env change could
    move it (regression-tested in tests/test_autotune.py)."""
    if override is not None:
        value = int(override)
        if value <= 0:
            raise ValueError(f"tile override must be a positive integer, "
                             f"got {override!r}")
        return value
    return env_int(env_name, default)


def profiled_call(kernel: str, fn, /, *args, **labels):
    """Run `fn(*args)` recording per-shape timings into the global registry.

    Records three metrics labeled with `kernel` plus whatever shape/tile
    labels the wrapper passes (n, d, G, tile, ...):

      kernel.calls        counter   dispatches
      kernel.dispatch_us  histogram time until `fn` returns (async dispatch)
      kernel.wall_us      histogram time until results are device-ready

    The dispatch/wall split matters on real TPU: jax returns futures, so
    un-fenced timings measure Python overhead, not the kernel.  Callers use
    this only on the `obs.enabled()` branch — the disabled path calls the
    kernel directly and stays bit-and-trace-identical.
    """
    reg = obs.get_registry()
    t0 = time.perf_counter()
    out = fn(*args)
    t1 = time.perf_counter()
    obs.fence(*(out if isinstance(out, tuple) else (out,)))
    t2 = time.perf_counter()
    reg.counter("kernel.calls", kernel=kernel, **labels).inc()
    reg.histogram("kernel.dispatch_us", kernel=kernel, **labels).observe(
        (t1 - t0) * 1e6)
    reg.histogram("kernel.wall_us", kernel=kernel, **labels).observe(
        (t2 - t0) * 1e6)
    return out


def measured(kernel: str = None) -> List[Dict[str, object]]:
    """Measured kernel timings from the global registry: one row per
    (kernel, shape, tile) combination with call count and wall-time summary.
    The read API for a future autotuner and for bench reporting."""
    reg = obs.get_registry()
    match = {"kernel": kernel} if kernel is not None else {}
    rows = []
    for labels, hist in reg.collect_histograms("kernel.wall_us", **match):
        rows.append({**labels, **hist.summary()})
    rows.sort(key=lambda r: (r.get("kernel", ""), -r["count"]))
    return rows
