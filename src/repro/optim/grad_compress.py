"""Int8 gradient compression with error feedback, for DP all-reduce on the
slow axes (inter-pod DCN / long ICI hops).

The quantiser keeps a persistent per-leaf fp32 residual ("error feedback"),
which provably preserves SGD convergence for contractive compressors.  The
compressed all-reduce runs inside `shard_map`: each device quantises its local
gradient shard to int8 + fp32 scale, `psum`s the int8 payload (4x fewer bytes
on the wire than fp32), and dequantises.

Used by the `train_dp_compressed` path (launch/train.py --compress-grads) and
benchmarked in EXPERIMENTS.md §Perf (collective-bytes column).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """fp -> (int8 payload, fp32 scale, new error residual)."""
    gc = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gc)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gc / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gc - deq


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, errors, axis_name: str):
    """All-reduce `grads` over `axis_name` in int8 with error feedback.

    Must be called inside shard_map with `axis_name` in scope.  Returns
    (mean-reduced fp32 grads, new error residuals).
    """
    n = jax.lax.psum(1, axis_name)

    def leaf(g, e):
        gc = g.astype(jnp.float32) + e
        # agree on one scale across replicas (one scalar pmax), then quantise
        scale = jax.lax.pmax(jnp.max(jnp.abs(gc)), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(gc / scale), -127, 127).astype(jnp.int8)
        new_e = gc - q.astype(jnp.float32) * scale
        # sum int8 payloads in int32 to avoid overflow across replicas
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * scale / n, new_e

    out = jax.tree.map(leaf, grads, errors)
    new_grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_errors = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, new_errors


def wire_bytes(params, compressed: bool) -> int:
    """Bytes per all-reduce round for the metrics in EXPERIMENTS.md."""
    per = 1 if compressed else 4
    return sum(p.size * per for p in jax.tree.leaves(params))
