from . import adamw, grad_compress
from .adamw import AdamWConfig, AdamWState
