"""AdamW with cosine schedule; optimizer state shards exactly like params
(m/v inherit the param PartitionSpec => ZeRO-style sharding comes from the
FSDP axis in the param specs for free)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def state_specs(param_specs) -> AdamWState:
    from jax.sharding import PartitionSpec as P
    is_spec = lambda s: isinstance(s, P)
    return AdamWState(m=param_specs, v=param_specs, count=P())


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    count = state.count + 1
    lr = schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    out = jax.tree.map(leaf, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(new_m, new_v, count), {"grad_norm": gnorm, "lr": lr}
