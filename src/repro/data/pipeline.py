"""Deterministic, resumable, shard-aware synthetic token pipeline.

Production shape without production data: batches are generated from a
counter-keyed PRNG (`fold_in(seed, step)`), so (a) every host produces exactly
its own slice of the global batch from (host_id, n_hosts) — no data exchange,
(b) restoring `state()` after a restart reproduces the stream bit-exactly —
the property the checkpoint/restart tests assert.

The pipeline also feeds the paper's AQP layer: per-batch telemetry columns
(sequence length, mean token id, batch loss once the trainer folds it back)
stream into `TelemetryStore` KDE synopses (data/aqp_store.py), giving O(1)
approximate queries over the whole training history.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PipelineState:
    step: int = 0


class TokenPipeline:
    def __init__(self, vocab_size: int, global_batch: int, seq_len: int,
                 seed: int = 0, host_id: int = 0, n_hosts: int = 1,
                 telemetry=None):
        assert global_batch % n_hosts == 0
        self.vocab = vocab_size
        self.global_batch = global_batch
        self.local_batch = global_batch // n_hosts
        self.seq = seq_len
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.telemetry = telemetry
        self._state = PipelineState()

    # -- persistence --------------------------------------------------------
    def state(self) -> Dict:
        return {"step": self._state.step, "seed": self.seed}

    def restore(self, state: Dict) -> None:
        assert state["seed"] == self.seed, "restoring a different stream"
        self._state.step = int(state["step"])

    # -- iteration ----------------------------------------------------------
    def _host_key(self, step: int):
        k = jax.random.fold_in(jax.random.key(self.seed), step)
        return jax.random.fold_in(k, self.host_id)

    def next(self) -> Dict[str, jnp.ndarray]:
        step = self._state.step
        key = self._host_key(step)
        k1, k2 = jax.random.split(key)
        # Zipf-ish token distribution so the KDE telemetry has structure.
        u = jax.random.uniform(k1, (self.local_batch, self.seq))
        tokens = jnp.minimum((self.vocab * u ** 2.5).astype(jnp.int32), self.vocab - 1)
        labels = jnp.roll(tokens, -1, axis=1)
        self._state.step = step + 1
        if self.telemetry is not None:
            self.telemetry.add_batch({
                "mean_token": np.asarray(jnp.mean(tokens, axis=1), np.float32),
                "seq_entropy": np.asarray(
                    jnp.std(tokens.astype(jnp.float32), axis=1), np.float32),
            })
        return {"tokens": tokens, "labels": labels}

    def __iter__(self):
        return self

    def __next__(self):
        return self.next()
