"""AQP telemetry store — the paper's technique as a first-class framework
feature (DESIGN.md §4).

Training/serving telemetry columns (per-sequence loss, length, token stats)
stream in per batch; the store keeps a bounded reservoir sample per column and
fits KDE synopses with the paper's selectors on demand.  Queries (COUNT/SUM/
AVG over a range, quantile-ish fractions) are answered from the synopsis in
O(sample) instead of O(history) — and the synopsis is *mergeable* across hosts
(reservoir union), which is the property that makes this usable on a
1000-node fleet where no host sees the global stream.

Fitting a synopsis is the expensive step (bandwidth selection is O(sample^2)
for LSCV), so the store memoises fitted synopses in a `SynopsisCache` keyed by
(column, selector, reservoir version); any reservoir update bumps the version
and invalidates stale entries on the next lookup.
"""
from __future__ import annotations

import copy
import zlib
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.aqp import KDESynopsis, Query, QueryBatch


class Reservoir:
    """Algorithm-R reservoir sample with deterministic RNG.

    `version` counts accepted updates; synopsis caches key on it so any new
    data invalidates derived synopses.
    """

    def __init__(self, capacity: int = 4096, seed: int = 0):
        self.capacity = capacity
        self.rng = np.random.default_rng(seed)
        self.buf = np.empty((capacity,), np.float32)
        self.n_seen = 0
        self.n_filled = 0      # initialized buffer slots; < capacity after a
        self.version = 0       # merge of reservoirs with smaller samples

    def add(self, values: np.ndarray) -> None:
        values = np.asarray(values, np.float32).ravel()
        if values.size == 0:
            return
        self.version += 1
        k = 0
        if self.n_filled < self.capacity and self.n_seen == self.n_filled:
            k = min(self.capacity - self.n_filled, values.size)
            self.buf[self.n_filled: self.n_filled + k] = values[:k]
            self.n_filled += k
            self.n_seen += k
        rest = values[k:]
        if rest.size:
            # Vectorised algorithm-R acceptance: one slot draw per element.
            # Replacement stays bounded by n_filled — after a merge leaves
            # n_filled < capacity with n_seen > n_filled, growing the sample
            # would overweight new data; replacing keeps it uniform.
            # Duplicate accepted slots: numpy fancy assignment keeps the last
            # write, matching sequential application order.
            stream_idx = self.n_seen + np.arange(rest.size)
            j = self.rng.integers(0, stream_idx + 1)
            accept = j < self.n_filled
            self.buf[j[accept]] = rest[accept]
            self.n_seen += rest.size

    def sample(self) -> np.ndarray:
        return self.buf[: self.n_filled].copy()

    def merge(self, other: "Reservoir") -> "Reservoir":
        """Weighted union: each side contributes in proportion to the stream
        size its sample represents (n_seen), not its retained-sample size —
        otherwise chained cross-host merges skew the mixture (a second-level
        merge would weight a single host as much as a pair of hosts)."""
        out = Reservoir(self.capacity, seed=int(self.rng.integers(1 << 31)))
        s1, s2 = self.sample(), other.sample()
        total = self.n_seen + other.n_seen
        if total == 0:
            return out
        w1 = self.n_seen / total
        w2 = other.n_seen / total
        # Cap the merged sample so the n_seen proportions are achievable from
        # the retained points: k <= len(s_i) / w_i.  Without this, a side with
        # few retained points but little stream weight would be forced in
        # wholesale and dominate the sample.
        k = min(self.capacity, len(s1) + len(s2))
        if w1 > 0:
            k = min(k, int(len(s1) / w1))
        if w2 > 0:
            k = min(k, int(len(s2) / w2))
        take1 = int(out.rng.binomial(k, w1))
        take1 = min(len(s1), max(take1, k - len(s2)))
        take2 = k - take1
        pick1 = out.rng.choice(len(s1), take1, replace=False) if take1 else []
        pick2 = out.rng.choice(len(s2), take2, replace=False) if take2 else []
        buf = np.concatenate([s1[pick1], s2[pick2]]).astype(np.float32)
        out.rng.shuffle(buf)
        out.buf[: len(buf)] = buf
        out.n_filled = len(buf)
        out.n_seen = total
        out.version = 1
        return out


class SynopsisCache:
    """Memoises fitted synopses keyed by (column, selector, sample version).

    One live entry per (column, selector): a lookup whose stored version
    differs from the reservoir's current version is a miss and is replaced on
    the next `put` — reservoir updates therefore invalidate implicitly.
    Bounded by `max_entries` (FIFO eviction; entry count, not bytes).
    """

    def __init__(self, max_entries: int = 128):
        self.max_entries = max_entries
        self._entries: Dict[Tuple[str, str], Tuple[int, KDESynopsis]] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, column: str, selector: str, version: int) -> Optional[KDESynopsis]:
        ent = self._entries.get((column, selector))
        if ent is not None and ent[0] == version:
            self.hits += 1
            return ent[1]
        self.misses += 1
        return None

    def put(self, column: str, selector: str, version: int, syn: KDESynopsis) -> None:
        key = (column, selector)
        if key not in self._entries and len(self._entries) >= self.max_entries:
            self._entries.pop(next(iter(self._entries)))
        self._entries[key] = (version, syn)

    def invalidate(self, column: Optional[str] = None) -> None:
        if column is None:
            self._entries.clear()
        else:
            for key in [k for k in self._entries if k[0] == column]:
                self._entries.pop(key)

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses, "entries": len(self._entries)}


class TelemetryStore:
    def __init__(self, capacity: int = 4096, seed: int = 0, cache_entries: int = 128):
        self.columns: Dict[str, Reservoir] = {}
        self.capacity = capacity
        self.seed = seed
        self.cache = SynopsisCache(max_entries=cache_entries)

    def add_batch(self, stats: Dict[str, np.ndarray]) -> None:
        for name, values in stats.items():
            if name not in self.columns:
                # crc32, not hash(): Python string hashing is randomised per
                # process, which would make the reservoirs nondeterministic.
                col_seed = self.seed + zlib.crc32(name.encode()) % 1000
                self.columns[name] = Reservoir(self.capacity, seed=col_seed)
            self.columns[name].add(values)

    def synopsis(self, column: str, selector: str = "plugin") -> KDESynopsis:
        res = self.columns.get(column)
        if res is None:
            raise KeyError(f"unknown column {column!r}; "
                           f"have {sorted(self.columns)}")
        syn = self.cache.get(column, selector, res.version)
        if syn is None:
            syn = KDESynopsis.fit(res.sample(), selector=selector,
                                  max_sample=self.capacity)
            syn.n_source = res.n_seen
            self.cache.put(column, selector, res.version, syn)
        return syn

    # -- queries ------------------------------------------------------------
    def count(self, column: str, a: float, b: float, selector: str = "plugin") -> float:
        return float(self.synopsis(column, selector).count(a, b))

    def avg(self, column: str, a: float, b: float, selector: str = "plugin") -> float:
        return float(self.synopsis(column, selector).avg(a, b))

    def fraction(self, column: str, a: float, b: float, selector: str = "plugin") -> float:
        res = self.columns[column]
        return self.count(column, a, b, selector) / max(res.n_seen, 1)

    def query_batch(self, queries: Sequence[Query], selector: str = "plugin",
                    backend: str = "jnp") -> np.ndarray:
        """Answer N heterogeneous queries (mixed ops/ranges/columns) with one
        jitted pass per distinct column; synopses come from the cache."""
        batch = QueryBatch(queries)
        if None in batch.columns:
            raise ValueError("every query must name a column when running "
                             "against a TelemetryStore")
        synopses = {col: self.synopsis(col, selector) for col in batch.columns}
        return batch.run(synopses, backend=backend)

    def merge(self, other: "TelemetryStore") -> "TelemetryStore":
        out = TelemetryStore(self.capacity, self.seed,
                             cache_entries=self.cache.max_entries)
        for name in set(self.columns) | set(other.columns):
            if name in self.columns and name in other.columns:
                out.columns[name] = self.columns[name].merge(other.columns[name])
            else:
                # deep copy: the merged store is a snapshot, so later updates
                # to the source store must not leak into it through aliasing
                out.columns[name] = copy.deepcopy(
                    self.columns.get(name) or other.columns[name])
        return out
