"""AQP telemetry store — the paper's technique as a first-class framework
feature (DESIGN.md §4).

Training/serving telemetry columns (per-sequence loss, length, token stats)
stream in per batch; the store keeps a bounded reservoir sample per column and
fits KDE synopses with the paper's selectors on demand.  Queries (COUNT/SUM/
AVG over a range, quantile-ish fractions) are answered from the synopsis in
O(sample) instead of O(history) — and the synopsis is *mergeable* across hosts
(reservoir union), which is the property that makes this usable on a
1000-node fleet where no host sees the global stream.

Multi-column predicates need a *joint* density, which per-column reservoirs
cannot provide (they decorrelate the columns).  `track_joint` registers a
`MultiReservoir` that samples whole telemetry *rows* over a column tuple with
the same versioned, weighted-merge semantics; `joint_synopsis` fits a
diagonal-bandwidth (or full-H) synopsis over it for eq. 11 box queries.

Fitting a synopsis is the expensive step (bandwidth selection is O(sample^2)
for LSCV), so the store memoises fitted synopses in a `SynopsisCache` keyed by
(column-or-tuple, selector, reservoir version); any reservoir update bumps the
version and invalidates stale entries on the next lookup.  The cache is a
byte-bounded LRU (`max_entries` + `max_bytes`) with hit/miss/eviction
counters surfaced through `TelemetryStore.stats()`.

The store is *durable*: `to_state()`/`from_state()` round-trip every
reservoir (buffer, stream counters, version, RNG bit-generator state — so
post-restore sampling is deterministic), every categorical sketch, the joint
registrations with their backfill flags, and the fitted synopses in the
cache.  `save(path)`/`load(path)` put that state behind the atomic keep-k
`CheckpointManager` (repro.checkpoint), so a `serve --mode aqp` restart
warm-starts instead of refitting — and exact-Eq coverage, which requires a
sketch to have seen the *whole* stream, survives the restart.  Snapshots are
taken under the store's write lock, so a snapshot racing `add_batch` can
never persist a sketch that claims rows its reservoir has not seen.
"""
from __future__ import annotations

import copy
import threading
import time
import weakref
import zlib
from collections import OrderedDict
from typing import (Callable, Dict, Hashable, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro import obs
from repro.core.aqp import KDESynopsis, Query, canonical_selector
from repro.core.aqp_multid import BoxQuery

ColumnKey = Union[str, Tuple[str, ...]]

STATE_FORMAT = 1     # bump on incompatible to_state layout changes


class Reservoir:
    """Algorithm-R reservoir sample with deterministic RNG.

    `version` counts accepted updates; synopsis caches key on it so any new
    data invalidates derived synopses.  Subclasses set `_row_shape` to sample
    composite items (MultiReservoir samples whole rows); all the acceptance
    and merge logic operates on the leading axis and is shared.
    """

    def __init__(self, capacity: int = 4096, seed: int = 0,
                 _row_shape: Tuple[int, ...] = ()):
        self.capacity = capacity
        self.rng = np.random.default_rng(seed)
        self.buf = np.empty((capacity, *_row_shape), np.float32)
        self.n_seen = 0
        self.n_filled = 0      # initialized buffer slots; < capacity after a
        self.version = 0       # merge of reservoirs with smaller samples

    def _coerce(self, values: np.ndarray) -> np.ndarray:
        return np.asarray(values, np.float32).ravel()

    def _spawn(self, seed: int) -> "Reservoir":
        return type(self)(self.capacity, seed=seed)

    def add(self, values: np.ndarray) -> None:
        values = self._coerce(values)
        if values.shape[0] == 0:
            return
        self.version += 1
        k = 0
        if self.n_filled < self.capacity and self.n_seen == self.n_filled:
            k = min(self.capacity - self.n_filled, values.shape[0])
            self.buf[self.n_filled: self.n_filled + k] = values[:k]
            self.n_filled += k
            self.n_seen += k
        rest = values[k:]
        if rest.shape[0]:
            # Vectorised algorithm-R acceptance: one slot draw per element.
            # Replacement stays bounded by n_filled — after a merge leaves
            # n_filled < capacity with n_seen > n_filled, growing the sample
            # would overweight new data; replacing keeps it uniform.
            # Duplicate accepted slots: numpy fancy assignment keeps the last
            # write, matching sequential application order.
            stream_idx = self.n_seen + np.arange(rest.shape[0])
            j = self.rng.integers(0, stream_idx + 1)
            accept = j < self.n_filled
            self.buf[j[accept]] = rest[accept]
            self.n_seen += rest.shape[0]

    def sample(self) -> np.ndarray:
        return self.buf[: self.n_filled].copy()

    def state(self) -> Tuple[np.ndarray, Dict[str, object]]:
        """(retained buffer, JSON-safe metadata) for checkpointing.  The RNG
        bit-generator state rides along so post-restore acceptance draws are
        bit-identical to the never-checkpointed reservoir's."""
        meta = {"n_seen": int(self.n_seen), "n_filled": int(self.n_filled),
                "version": int(self.version),
                "rng": self.rng.bit_generator.state}
        return self.buf[: self.n_filled].copy(), meta

    def load_state(self, buf: np.ndarray, meta: Dict[str, object]) -> None:
        n_filled = int(meta["n_filled"])
        if n_filled > self.capacity or buf.shape[0] != n_filled:
            raise ValueError(f"reservoir state has {buf.shape[0]} rows for "
                             f"n_filled={n_filled}, capacity={self.capacity}")
        self.buf[:n_filled] = np.asarray(buf, np.float32)
        self.n_filled = n_filled
        self.n_seen = int(meta["n_seen"])
        self.version = int(meta["version"])
        self.rng.bit_generator.state = meta["rng"]

    def merge(self, other: "Reservoir") -> "Reservoir":
        """Weighted union: each side contributes in proportion to the stream
        size its sample represents (n_seen), not its retained-sample size —
        otherwise chained cross-host merges skew the mixture (a second-level
        merge would weight a single host as much as a pair of hosts)."""
        out = self._spawn(seed=int(self.rng.integers(1 << 31)))
        s1, s2 = self.sample(), other.sample()
        total = self.n_seen + other.n_seen
        if total == 0:
            return out
        w1 = self.n_seen / total
        w2 = other.n_seen / total
        # Cap the merged sample so the n_seen proportions are achievable from
        # the retained points: k <= len(s_i) / w_i.  Without this, a side with
        # few retained points but little stream weight would be forced in
        # wholesale and dominate the sample.
        k = min(self.capacity, len(s1) + len(s2))
        if w1 > 0:
            k = min(k, int(len(s1) / w1))
        if w2 > 0:
            k = min(k, int(len(s2) / w2))
        take1 = int(out.rng.binomial(k, w1))
        take1 = min(len(s1), max(take1, k - len(s2)))
        take2 = k - take1
        pick1 = out.rng.choice(len(s1), take1, replace=False) if take1 else []
        pick2 = out.rng.choice(len(s2), take2, replace=False) if take2 else []
        buf = np.concatenate([s1[pick1], s2[pick2]]).astype(np.float32)
        out.rng.shuffle(buf)
        out.buf[: len(buf)] = buf
        out.n_filled = len(buf)
        out.n_seen = total
        out.version = 1
        return out


class MultiReservoir(Reservoir):
    """Row-sampling reservoir over a tuple of columns.

    Keeps whole telemetry rows (column tuples) so a *joint* density can be
    fitted — per-column reservoirs sample each column independently and lose
    every cross-column correlation.  Same versioned algorithm-R acceptance
    and weighted-merge semantics as the 1-D `Reservoir`.
    """

    def __init__(self, columns: Sequence[str], capacity: int = 4096, seed: int = 0):
        self.columns = tuple(columns)
        if len(self.columns) < 2:
            raise ValueError("MultiReservoir needs >= 2 columns; use Reservoir "
                             "for a single column")
        self.backfilled = False   # seeded from per-column reservoirs (store)
        super().__init__(capacity, seed, _row_shape=(len(self.columns),))

    def _coerce(self, values: np.ndarray) -> np.ndarray:
        rows = np.asarray(values, np.float32)
        if rows.ndim != 2 or rows.shape[1] != len(self.columns):
            raise ValueError(f"expected rows of shape (m, {len(self.columns)}) "
                             f"for columns {self.columns}, got {rows.shape}")
        return rows

    def _spawn(self, seed: int) -> "MultiReservoir":
        return MultiReservoir(self.columns, self.capacity, seed=seed)

    def merge(self, other: "Reservoir") -> "Reservoir":
        if not isinstance(other, MultiReservoir) or other.columns != self.columns:
            raise ValueError(f"cannot merge joint reservoirs over different "
                             f"columns: {self.columns} vs "
                             f"{getattr(other, 'columns', None)}")
        out = super().merge(other)
        # pseudo-rows survive a merge: the flag is sticky across unions
        out.backfilled = self.backfilled or other.backfilled
        return out

    def state(self) -> Tuple[np.ndarray, Dict[str, object]]:
        buf, meta = super().state()
        meta["backfilled"] = bool(self.backfilled)
        return buf, meta

    def load_state(self, buf: np.ndarray, meta: Dict[str, object]) -> None:
        super().load_state(buf, meta)
        self.backfilled = bool(meta.get("backfilled", False))


class TieredReservoir:
    """Verdict-style tiered sample: a geometric ladder of reservoirs.

    Tier i holds `capacity >> (n_tiers-1-i)` rows, so tier 0 is 1/2^(n-1) of
    the full sample and the top tier IS the full-capacity sample.  Every
    incoming row is offered to every tier independently, so each tier is a
    uniform sample of the whole stream on its own — a query answered from
    tier 0 is a cheap, coarse, *unbiased* answer, and progressive execution
    re-answers on successively larger tiers until the top tier reproduces
    the untiered result bit-for-bit.  Members share the versioned algorithm-R
    acceptance and weighted-merge core of `Reservoir`/`MultiReservoir`.

    Optional per-dictionary-code stratification (`strat_column`): a small
    side reservoir per distinct code of one column, so rare GROUP BY groups
    whose representatives would be displaced from the uniform tiers keep
    coverage.  Strata feed group *discovery* and worst-case retention
    (`codes()`/`stratum()`); aggregate estimates still come from the uniform
    tiers, which keeps them unbiased.

    `columns=None` samples scalars (1-D column); a tuple samples whole rows
    like `MultiReservoir`.  `version`/`n_seen`/`n_filled` delegate to the
    top tier, so synopsis caches and admission re-keying work unchanged.
    """

    backfilled = False   # tiered joints are never seeded from marginals

    def __init__(self, capacity: int = 4096, n_tiers: int = 4, seed: int = 0,
                 columns: Optional[Sequence[str]] = None,
                 strat_column: Optional[str] = None,
                 strata_capacity: int = 64, max_strata: int = 256):
        if n_tiers < 1:
            raise ValueError(f"n_tiers must be >= 1, got {n_tiers}")
        if capacity >> (n_tiers - 1) < 1:
            raise ValueError(f"capacity {capacity} too small for {n_tiers} "
                             f"tiers (tier 0 would be empty)")
        self.capacity = capacity
        self.n_tiers = n_tiers
        self.seed = seed
        self.columns = tuple(columns) if columns is not None else None
        self.strat_column = strat_column
        self.strata_capacity = strata_capacity
        self.max_strata = max_strata
        self._strat_axis: Optional[int] = None
        if strat_column is not None and self.columns is not None:
            if strat_column not in self.columns:
                raise ValueError(f"strat_column {strat_column!r} not in "
                                 f"columns {self.columns}")
            self._strat_axis = self.columns.index(strat_column)
        self.tiers = [self._spawn_member(capacity >> (n_tiers - 1 - i),
                                         seed + i)
                      for i in range(n_tiers)]
        self.strata: Dict[float, Reservoir] = {}
        self.strata_overflow = False

    def _spawn_member(self, cap: int, seed: int) -> Reservoir:
        if self.columns is None:
            return Reservoir(cap, seed=seed)
        return MultiReservoir(self.columns, cap, seed=seed)

    # synopsis caching / admission re-keying key on these; the top tier is
    # the authoritative (full) sample, so its counters speak for the whole
    @property
    def version(self) -> int:
        return self.tiers[-1].version

    @property
    def n_seen(self) -> int:
        return self.tiers[-1].n_seen

    @property
    def n_filled(self) -> int:
        return self.tiers[-1].n_filled

    def _stratum_seed(self, code: float) -> int:
        return (self.seed + 7919
                + zlib.crc32(np.float32(code).tobytes()) % 100003)

    def add(self, values: np.ndarray) -> None:
        values = self.tiers[-1]._coerce(np.asarray(values, np.float32))
        if values.shape[0] == 0:
            return
        for tier in self.tiers[:-1]:
            tier.add(values)
        if self.strat_column is not None:
            codes = values if self._strat_axis is None \
                else values[:, self._strat_axis]
            for code in np.unique(codes):
                if np.isnan(code):
                    continue
                key = float(code)
                res = self.strata.get(key)
                if res is None:
                    if len(self.strata) >= self.max_strata:
                        # stop opening NEW strata; existing ones keep updating
                        self.strata_overflow = True
                        continue
                    res = self._spawn_member(self.strata_capacity,
                                             self._stratum_seed(key))
                    self.strata[key] = res
                res.add(values[codes == code])
        self.tiers[-1].add(values)

    def sample(self, tier: Optional[int] = None) -> np.ndarray:
        """The retained sample of one tier (default: the full top tier)."""
        if tier is None:
            return self.tiers[-1].sample()
        tier = max(0, min(int(tier), self.n_tiers - 1))
        return self.tiers[tier].sample()

    def tier_sizes(self) -> List[int]:
        return [t.n_filled for t in self.tiers]

    def codes(self) -> List[float]:
        """Distinct stratification codes seen so far (sorted) — the GROUP BY
        discovery set; unions with the uniform sample's codes so rare groups
        displaced from the tiers still get result rows."""
        return sorted(self.strata)

    def stratum(self, code: float) -> Optional[np.ndarray]:
        res = self.strata.get(float(np.float32(code)))
        return None if res is None else res.sample()

    def merge(self, other: "TieredReservoir") -> "TieredReservoir":
        if not isinstance(other, TieredReservoir) \
                or other.n_tiers != self.n_tiers \
                or other.columns != self.columns \
                or other.strat_column != self.strat_column:
            raise ValueError(
                f"cannot merge tiered reservoirs with different shape: "
                f"{(self.n_tiers, self.columns, self.strat_column)} vs "
                f"{(getattr(other, 'n_tiers', None), getattr(other, 'columns', None), getattr(other, 'strat_column', None))}")
        out = TieredReservoir(
            self.capacity, self.n_tiers,
            seed=int(self.tiers[-1].rng.integers(1 << 31)),
            columns=self.columns, strat_column=self.strat_column,
            strata_capacity=self.strata_capacity,
            max_strata=self.max_strata)
        out.tiers = [a.merge(b) for a, b in zip(self.tiers, other.tiers)]
        for key in set(self.strata) | set(other.strata):
            a, b = self.strata.get(key), other.strata.get(key)
            out.strata[key] = a.merge(b) if a is not None and b is not None \
                else copy.deepcopy(a if a is not None else b)
        out.strata_overflow = self.strata_overflow or other.strata_overflow
        return out

    def state(self) -> Tuple[Dict[str, np.ndarray], Dict[str, object]]:
        """(arrays, JSON-safe metadata) for checkpointing — every tier and
        stratum rides along with its RNG state, so a restored ladder accepts
        future rows bit-identically to the never-checkpointed one."""
        arrays: Dict[str, np.ndarray] = {}
        tier_meta = []
        for i, tier in enumerate(self.tiers):
            buf, m = tier.state()
            arrays[f"tier{i}/buf"] = buf
            tier_meta.append(m)
        strata_meta = []
        for j, code in enumerate(sorted(self.strata)):
            buf, m = self.strata[code].state()
            arrays[f"strata/{j}/buf"] = buf
            strata_meta.append({"code": float(code), "meta": m})
        meta = {"kind": "tiered", "n_tiers": int(self.n_tiers),
                "capacity": int(self.capacity), "seed": int(self.seed),
                "columns": list(self.columns) if self.columns else None,
                "strat_column": self.strat_column,
                "strata_capacity": int(self.strata_capacity),
                "max_strata": int(self.max_strata),
                "strata_overflow": bool(self.strata_overflow),
                "tiers": tier_meta, "strata": strata_meta}
        return arrays, meta

    @classmethod
    def from_state(cls, arrays: Dict[str, np.ndarray],
                   meta: Dict[str, object]) -> "TieredReservoir":
        cols = meta.get("columns")
        out = cls(capacity=int(meta["capacity"]),
                  n_tiers=int(meta["n_tiers"]), seed=int(meta["seed"]),
                  columns=tuple(cols) if cols else None,
                  strat_column=meta.get("strat_column"),
                  strata_capacity=int(meta["strata_capacity"]),
                  max_strata=int(meta["max_strata"]))
        for i, m in enumerate(meta["tiers"]):
            out.tiers[i].load_state(arrays[f"tier{i}/buf"], m)
        for j, ent in enumerate(meta["strata"]):
            code = float(ent["code"])
            res = out._spawn_member(out.strata_capacity,
                                    out._stratum_seed(code))
            res.load_state(arrays[f"strata/{j}/buf"], ent["meta"])
            out.strata[code] = res
        out.strata_overflow = bool(meta.get("strata_overflow", False))
        return out


class CategoricalSketch:
    """Exact per-code frequency sketch for a dictionary column.

    Dictionary columns hold a small set of unit-spaced codes, so keeping ONE
    counter per code alongside the reservoir answers Eq-term aggregates
    *exactly* — no kernel smoothing, no sample->relation scaling.  The KDE
    code±1/2 window stays as the fallback for untracked columns (and for
    sketches that do not cover the column's whole stream).

    `n_rows` counts every value the sketch has seen; the engine only takes
    the exact path when it equals the reservoir's `n_seen` (i.e. the sketch
    was registered before any data and never missed a batch).  A column
    whose distinct-code count exceeds `max_codes` is not dictionary-like;
    the sketch marks itself `overflowed` and the exact path disables itself
    (for high-cardinality columns, `CountMinSketch` degrades to bounded-error
    counts instead).
    """

    path = "exact"    # AqpResult.path label when this sketch answers

    def __init__(self, max_codes: int = 4096):
        self.counts: Dict[float, int] = {}
        self.n_rows = 0
        self.max_codes = max_codes
        self.overflowed = False

    def add(self, values: np.ndarray) -> None:
        # float32, matching Reservoir._coerce: a code that is not exactly
        # float32-representable must count under the SAME rounded code on the
        # exact path and in the KDE sample, or the two paths disagree
        values = np.asarray(values, np.float32).ravel()
        if values.shape[0] == 0:
            return
        if not self.overflowed:
            codes, counts = np.unique(values, return_counts=True)
            for c, k in zip(codes, counts):
                self.counts[float(c)] = self.counts.get(float(c), 0) + int(k)
            if len(self.counts) > self.max_codes:
                self.overflowed = True
                self.counts.clear()
        # n_rows LAST: the store bumps the reservoir's n_seen before this
        # add runs, so a concurrent reader mid-update sees n_rows < n_seen
        # and `exact_for` conservatively routes it to the KDE fallback
        # rather than serving half-updated counts as "exact"
        self.n_rows += values.shape[0]

    def exact_for(self, n_seen: int) -> bool:
        """True when the sketch covers the column's entire stream."""
        return not self.overflowed and self.n_rows == n_seen

    def range_terms(self, lo: float, hi: float) -> Tuple[int, float]:
        """(COUNT, SUM of code values) over codes in [lo, hi] — exact."""
        cnt = 0
        sm = 0.0
        # snapshot: a concurrent add() may insert codes mid-iteration
        for code, k in list(self.counts.items()):
            if lo <= code <= hi:
                cnt += k
                sm += code * k
        return cnt, sm

    def merge(self, other: "CategoricalSketch") -> "CategoricalSketch":
        out = CategoricalSketch(max_codes=min(self.max_codes, other.max_codes))
        out.n_rows = self.n_rows + other.n_rows
        out.overflowed = self.overflowed or other.overflowed
        if not out.overflowed:
            out.counts = dict(self.counts)
            for c, k in other.counts.items():
                out.counts[c] = out.counts.get(c, 0) + k
            if len(out.counts) > out.max_codes:
                out.overflowed = True
                out.counts.clear()
        return out

    def stats(self) -> Dict[str, object]:
        return {"kind": "exact", "codes": len(self.counts),
                "rows": self.n_rows, "overflowed": self.overflowed}

    def state(self) -> Tuple[Dict[str, np.ndarray], Dict[str, object]]:
        """(arrays, JSON-safe metadata) for checkpointing."""
        # iterate items() rather than re-deriving keys from a float32 array:
        # NaN codes are legal dict keys here but can never be looked up
        # again (nan != nan), so a rebuilt-key path would KeyError
        items = list(self.counts.items())
        codes = np.asarray([c for c, _ in items], np.float32)
        counts = np.asarray([k for _, k in items], np.int64)
        meta = {"kind": "exact", "n_rows": int(self.n_rows),
                "max_codes": int(self.max_codes),
                "overflowed": bool(self.overflowed)}
        return {"codes": codes, "counts": counts}, meta

    @classmethod
    def from_state(cls, arrays: Dict[str, np.ndarray],
                   meta: Dict[str, object]) -> "CategoricalSketch":
        out = cls(max_codes=int(meta["max_codes"]))
        out.n_rows = int(meta["n_rows"])
        out.overflowed = bool(meta["overflowed"])
        out.counts = {float(c): int(k) for c, k
                      in zip(arrays["codes"], arrays["counts"])}
        return out


_CM_MAX = (1 << 32) - 1      # uint32 saturation cap for CountMinSketch cells


class CountMinSketch:
    """Bounded-error per-code counts for high-cardinality dictionary columns.

    `CategoricalSketch` is all-or-nothing: past `max_codes` distinct codes it
    overflows and every Eq query falls back to KDE smoothing.  A count-min
    sketch (Cormode & Muthukrishnan; cf. the hashing-based estimators of
    Charikar & Siminelakis) never overflows: each value increments one cell
    per row of a (depth x width) counter table through independent
    multiply-shift hashes, and a code's estimated count is the MIN over its
    depth cells.  Estimates only over-count (hash collisions add, never
    subtract): with probability >= 1 - exp(-depth) the error is at most
    (e / width) * n_rows.  Registered via `track_categorical(col, kind="cm")`
    and reported on path "exact:cm" — same coverage gate as the exact sketch
    (the sketch must have seen the whole stream), bounded error instead of
    none.

    `conservative=True` (Estan & Varghese conservative update) raises a
    code's cells only as far as needed: per distinct code in a batch,
    `cells = max(cells, estimate(code) + batch_count)`.  Every cell stays an
    upper bound for every code hashing into it (the estimate >= the code's
    true pre-batch count by induction, so estimate + batch_count >= its new
    true count, and no other cell decreases), so the min-estimate still
    never under-counts — but cells stop absorbing the full collision mass,
    which cuts realised error well below the standard update on skewed
    streams (test-enforced).  The analytic `err_bound` is unchanged (a
    worst-case bound either way).  Merging adds tables cell-wise as before —
    the per-sketch upper-bound invariant is additive — but the merged sketch
    is only flagged conservative when both inputs are.

    Counters are packed to uint32 (half the checkpoint bytes of the original
    int64 table) with SATURATING adds: a cell that would pass 2^32 - 1 clips
    there and bumps `saturated`.  A clipped cell stops being an upper bound
    for the codes hashing into it — the min-estimate could then under-count —
    so any saturation drops the coverage gate (`exact_for` returns False) and
    the engine falls back to KDE smoothing instead of serving a broken bound
    on an "exact:cm" label.  Reaching the cap takes 4 billion rows into one
    cell; the counter exists so that if it ever happens the failure is a
    visible path change, not silent wraparound.  Legacy int64 snapshots load
    unchanged (values above the cap clip and count as saturations).

    Code grid: a count-min table cannot enumerate its keys, so range
    answers walk an assumed code lattice `grid_origin + k * grid_step`
    (default: the integers).  Before this was explicit, a column whose
    dictionary codes sit off the integer lattice (half codes, scaled ids)
    answered range queries from the WRONG enumeration — COUNT missed every
    off-lattice code and SUM mis-weighted what it did hit, silently, on a
    path labelled "exact:cm".  Now the sketch verifies each batch against
    its declared grid: any off-grid value flips `off_grid` and range
    answers return None forever after (point `estimate` stays valid), so
    the engine falls back to the KDE instead of serving a wrong exact
    answer.  Declaring the true grid (`track_categorical(...,
    grid_step=0.5)`) restores exact-path coverage with correctly weighted
    sums (regression-tested).
    """

    path = "exact:cm"

    def __init__(self, width: int = 2048, depth: int = 4, seed: int = 0,
                 max_enumerate: int = 64, conservative: bool = False,
                 grid_step: float = 1.0, grid_origin: float = 0.0):
        if width < 1 or depth < 1:
            raise ValueError(f"width/depth must be >= 1, got {width}x{depth}")
        if not grid_step > 0:
            raise ValueError(f"grid_step must be > 0, got {grid_step}")
        self.width = width
        self.depth = depth
        self.seed = seed
        self.conservative = conservative
        self.max_enumerate = max_enumerate   # widest code window enumerated
        self.grid_step = float(grid_step)
        self.grid_origin = float(grid_origin)
        self.off_grid = False                # any value seen off the lattice
        self.table = np.zeros((depth, width), np.uint32)
        self.saturated = 0                   # cumulative cell-clip events
        self.n_rows = 0
        self.overflowed = False              # a CM sketch never overflows
        rng = np.random.default_rng(seed)
        # odd multipliers for 64-bit multiply-shift hashing of the code's
        # float32 bit pattern; deterministic in `seed` so merges line up
        self._mul = (rng.integers(1, 1 << 61, size=depth, dtype=np.uint64)
                     * np.uint64(2) + np.uint64(1))
        self._add = rng.integers(0, 1 << 61, size=depth, dtype=np.uint64)

    def _hash(self, codes: np.ndarray, row: int) -> np.ndarray:
        bits = np.asarray(codes, np.float32).view(np.uint32).astype(np.uint64)
        mixed = (self._mul[row] * bits + self._add[row]) >> np.uint64(33)
        return (mixed % np.uint64(self.width)).astype(np.int64)

    def add(self, values: np.ndarray) -> None:
        # float32 like Reservoir._coerce / CategoricalSketch.add: both paths
        # must bucket a non-representable code under the same rounded value
        values = np.asarray(values, np.float32).ravel()
        if values.shape[0] == 0:
            return
        if not self.off_grid:
            # snap each value to the declared lattice and compare the float32
            # bit patterns: a mismatch means the column's codes are not where
            # range enumeration will look for them, so disable range answers
            # (cell counts and point estimates stay valid)
            k = np.rint((values.astype(np.float64) - self.grid_origin)
                        / self.grid_step)
            snapped = np.asarray(self.grid_origin + k * self.grid_step,
                                 np.float32)
            if not np.array_equal(snapped.view(np.uint32),
                                  values.view(np.uint32)):
                self.off_grid = True
        if self.conservative:
            # conservative update, vectorised per distinct code: read every
            # code's current min-estimate against the pre-batch table, then
            # raise its cells to at most estimate + batch count.  Reading all
            # estimates before any write only makes estimates lower (tighter)
            # than the sequential formulation — the upper-bound invariant
            # needs estimate >= the code's own pre-batch count, which the
            # pre-batch table already guarantees.
            codes, counts = np.unique(values, return_counts=True)
            idx = np.stack([self._hash(codes, r) for r in range(self.depth)])
            cur = np.stack([self.table[r, idx[r]]
                            for r in range(self.depth)])
            target = cur.astype(np.int64).min(axis=0) + counts
            over = target > _CM_MAX
            if over.any():                   # saturate, don't wrap
                self.saturated += int(over.sum())
                target = np.minimum(target, _CM_MAX)
            target = target.astype(np.uint32)
            for r in range(self.depth):
                np.maximum.at(self.table[r], idx[r], target)
        else:
            # widen to int64 for the add (np.add.at on uint32 would wrap
            # silently), then clip back into the packed cells
            for r in range(self.depth):
                inc = np.bincount(self._hash(values, r),
                                  minlength=self.width)
                new = self.table[r].astype(np.int64) + inc
                over = new > _CM_MAX
                if over.any():
                    self.saturated += int(over.sum())
                    new = np.minimum(new, _CM_MAX)
                self.table[r] = new.astype(np.uint32)
        # n_rows last, same reason as CategoricalSketch.add: a concurrent
        # reader mid-update must see n_rows < n_seen and fall back
        self.n_rows += values.shape[0]

    def estimate(self, code: float) -> int:
        """Estimated count of one code: min over the depth cells (>= truth)."""
        idx = [self._hash(np.asarray([code], np.float32), r)[0]
               for r in range(self.depth)]
        return int(min(self.table[r, i] for r, i in zip(range(self.depth), idx)))

    def exact_for(self, n_seen: int) -> bool:
        """Coverage gate, same contract as `CategoricalSketch.exact_for`:
        True when the sketch has seen the column's entire stream.  Covered
        answers are bounded-error (err <= e/width * n_rows w.h.p.), not
        exact — the engine labels them "exact:cm".  A saturated cell has
        dropped mass and may UNDER-count, voiding the error bound, so any
        saturation drops coverage and routes queries back to the KDE."""
        return self.n_rows == n_seen and self.saturated == 0

    def _grid_codes(self, lo: float, hi: float) -> Optional[List[float]]:
        """Deduplicated float32 lattice codes inside [lo, hi], or None when
        the window spans more than `max_enumerate` grid points.  The small
        epsilon absorbs float64 division fuzz so a query bound sitting ON a
        grid point always includes it."""
        step, origin = self.grid_step, self.grid_origin
        first = int(np.ceil((lo - origin) / step - 1e-9))
        last = int(np.floor((hi - origin) / step + 1e-9))
        if last < first:
            return []
        if last - first + 1 > self.max_enumerate:
            return None
        out: List[float] = []
        seen = set()
        for k in range(first, last + 1):
            # grid points beyond float32 resolution can alias to one code;
            # count the shared cell once
            code32 = float(np.float32(origin + k * step))
            if code32 not in seen:
                seen.add(code32)
                out.append(code32)
        return out

    def range_terms(self, lo: float, hi: float) -> Optional[Tuple[int, float]]:
        """(COUNT, SUM of code values) over lattice codes in [lo, hi], each
        code's count weighted by its actual (possibly fractional) value.
        None when the window spans more than `max_enumerate` grid points (a
        count-min sketch cannot enumerate its keys, so wide windows go back
        to the KDE path rather than summing unbounded collision noise) or
        when the stream has produced off-grid values — the enumeration would
        miss them, so the KDE path answers instead."""
        if self.off_grid:
            return None
        codes = self._grid_codes(lo, hi)
        if codes is None:
            return None
        cnt = 0
        sm = 0.0
        for code32 in codes:
            k = self.estimate(code32)
            cnt += k
            sm += code32 * k
        return cnt, sm

    def err_bound(self) -> int:
        """Counts overshoot by at most this many rows, w.p. >= 1-exp(-depth)."""
        return int(np.ceil(np.e / self.width * self.n_rows))

    def range_err(self, lo: float, hi: float
                  ) -> Optional[Tuple[int, float, float]]:
        """Worst-case over-count mass for a `range_terms(lo, hi)` answer:
        (count error, positive sum error, negative sum error), or None when
        the window is too wide to enumerate or the stream went off-grid.
        Count-min only over-counts, so COUNT truth lies in
        [est - count_err, est] and SUM truth in
        [est - sum_pos_err, est + sum_neg_err] (over-counted negative codes
        push the estimated sum DOWN, so truth can sit above it)."""
        if self.off_grid:
            return None
        codes = self._grid_codes(lo, hi)
        if codes is None:
            return None
        eb = self.err_bound()
        cnt_err = 0
        sum_pos = 0.0
        sum_neg = 0.0
        for code32 in codes:
            cnt_err += eb
            if code32 >= 0:
                sum_pos += eb * code32
            else:
                sum_neg += eb * (-code32)
        return cnt_err, sum_pos, sum_neg

    def merge(self, other: "CountMinSketch") -> "CountMinSketch":
        # compare the actual hash parameters, not just the seed: a sketch
        # restored from a snapshot keeps its persisted multipliers even if
        # the local numpy derives different ones from the same seed
        if (self.width, self.depth) != (other.width, other.depth) \
                or not np.array_equal(self._mul, other._mul) \
                or not np.array_equal(self._add, other._add):
            raise ValueError(
                f"cannot merge count-min sketches with different geometry: "
                f"{(self.width, self.depth, self.seed)} vs "
                f"{(other.width, other.depth, other.seed)} "
                f"(or unequal hash parameters)")
        if (self.grid_step, self.grid_origin) != (other.grid_step,
                                                  other.grid_origin):
            raise ValueError(
                f"cannot merge count-min sketches over different code grids: "
                f"step/origin {(self.grid_step, self.grid_origin)} vs "
                f"{(other.grid_step, other.grid_origin)}")
        out = CountMinSketch(self.width, self.depth, self.seed,
                             max_enumerate=min(self.max_enumerate,
                                               other.max_enumerate),
                             conservative=self.conservative
                             and other.conservative,
                             grid_step=self.grid_step,
                             grid_origin=self.grid_origin)
        out._mul = self._mul.copy()
        out._add = self._add.copy()
        summed = self.table.astype(np.int64) + other.table.astype(np.int64)
        over = summed > _CM_MAX
        out.saturated = self.saturated + other.saturated + int(over.sum())
        if over.any():
            summed = np.minimum(summed, _CM_MAX)
        out.table = summed.astype(np.uint32)
        out.n_rows = self.n_rows + other.n_rows
        out.off_grid = self.off_grid or other.off_grid
        return out

    def stats(self) -> Dict[str, object]:
        return {"kind": "cm", "rows": self.n_rows, "overflowed": False,
                "width": self.width, "depth": self.depth,
                "conservative": self.conservative,
                "grid_step": self.grid_step, "grid_origin": self.grid_origin,
                "off_grid": self.off_grid, "saturated": self.saturated,
                "err_bound": self.err_bound()}

    def state(self) -> Tuple[Dict[str, np.ndarray], Dict[str, object]]:
        meta = {"kind": "cm", "n_rows": int(self.n_rows),
                "width": int(self.width), "depth": int(self.depth),
                "seed": int(self.seed),
                "conservative": bool(self.conservative),
                "grid_step": float(self.grid_step),
                "grid_origin": float(self.grid_origin),
                "off_grid": bool(self.off_grid),
                "saturated": int(self.saturated),
                "max_enumerate": int(self.max_enumerate)}
        # the hash multipliers are persisted, not re-derived on load: numpy
        # does not guarantee Generator streams across versions, and a table
        # read through different hashes is silently wrong
        return {"table": self.table.copy(), "mul": self._mul.copy(),
                "add": self._add.copy()}, meta

    @classmethod
    def from_state(cls, arrays: Dict[str, np.ndarray],
                   meta: Dict[str, object]) -> "CountMinSketch":
        # `conservative`/grid defaults: pre-flag snapshots load as standard
        # sketches on the integer lattice (exactly what they assumed)
        out = cls(int(meta["width"]), int(meta["depth"]), int(meta["seed"]),
                  max_enumerate=int(meta["max_enumerate"]),
                  conservative=bool(meta.get("conservative", False)),
                  grid_step=float(meta.get("grid_step", 1.0)),
                  grid_origin=float(meta.get("grid_origin", 0.0)))
        out.off_grid = bool(meta.get("off_grid", False))
        out._mul = np.asarray(arrays["mul"], np.uint64)
        out._add = np.asarray(arrays["add"], np.uint64)
        out.saturated = int(meta.get("saturated", 0))
        # legacy snapshots persisted int64 tables; values past the uint32
        # cap clip on load and register as saturations so the coverage gate
        # sees the (theoretical) broken bound rather than a wrapped cell
        raw = np.asarray(arrays["table"], np.int64).reshape(
            out.depth, out.width)
        over = raw > _CM_MAX
        if over.any():
            out.saturated += int(over.sum())
            raw = np.minimum(raw, _CM_MAX)
        out.table = raw.astype(np.uint32)
        out.n_rows = int(meta["n_rows"])
        return out


_SKETCH_KINDS = {"exact": CategoricalSketch, "cm": CountMinSketch}


def _entry_nbytes(syn) -> int:
    """Byte footprint of a cached synopsis — the device payload (sample +
    bandwidth).  `repro.synopses` backends report their own `nbytes` (an RFF
    synopsis carries no sample, only its (W, b, z) triple); legacy
    `KDESynopsis` payloads are sized from their arrays.  Payloads without
    device arrays size to 0; the entry bound still applies to them."""
    own = getattr(syn, "nbytes", None)
    if isinstance(own, int):
        return own
    nb = 0
    for attr in ("x", "h", "H"):
        v = getattr(syn, attr, None)
        if v is not None and hasattr(v, "nbytes"):
            nb += int(v.nbytes)
    return nb


class SynopsisCache:
    """Memoises fitted synopses keyed by (column-or-tuple, selector, version).

    One live entry per (column, selector): a lookup whose stored version
    differs from the reservoir's current version is a miss and is replaced on
    the next `put` — reservoir updates therefore invalidate implicitly.
    Bounded by `max_entries` and (optionally) `max_bytes`, with LRU eviction:
    hits refresh recency, eviction pops the least-recently-used entry and is
    counted in `stats()`.

    Thread-safe: concurrent query threads hit `get`/`put` (every hit mutates
    LRU order) while serving, and a snapshot (`entries`, via
    `TelemetryStore.to_state`) must see a consistent entry list — all
    internal state is guarded by one lock.
    """

    def __init__(self, max_entries: int = 128, max_bytes: Optional[int] = None,
                 metrics: Optional[obs.MetricsRegistry] = None):
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        # (column-or-tuple, selector) -> (version, synopsis, nbytes)
        self._entries: OrderedDict = OrderedDict()  # guarded-by: _lock
        self.hits = 0          # guarded-by: _lock
        self.misses = 0        # guarded-by: _lock
        self.evictions = 0     # guarded-by: _lock
        self.oversize = 0      # guarded-by: _lock
        self._bytes = 0        # guarded-by: _lock
        self._lock = threading.Lock()
        # registry mirror (always-on when a registry is supplied — one lock +
        # add per event): instruments resolved once here, not per lookup
        if metrics is not None:
            self._m_hits = metrics.counter("aqp.cache.hits")
            self._m_misses = metrics.counter("aqp.cache.misses")
            self._m_evictions = metrics.counter("aqp.cache.evictions")
            self._m_entries = metrics.gauge("aqp.cache.entries")
            self._m_bytes = metrics.gauge("aqp.cache.bytes")
        else:
            self._m_hits = self._m_misses = self._m_evictions = None
            self._m_entries = self._m_bytes = None

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._bytes

    def get(self, column: ColumnKey, selector: str, version: int) -> Optional[KDESynopsis]:
        # selector case-normalized: "Plugin" and "plugin" are the same
        # synopsis and must share one entry, not collide as two live copies
        key = (column, canonical_selector(selector))
        with self._lock:
            ent = self._entries.get(key)
            if ent is not None and ent[0] == version:
                self.hits += 1
                if self._m_hits is not None:
                    self._m_hits.inc()
                self._entries.move_to_end(key)        # LRU: refresh recency
                return ent[1]
            self.misses += 1
            if self._m_misses is not None:
                self._m_misses.inc()
            return None

    def put(self, column: ColumnKey, selector: str, version: int, syn: KDESynopsis) -> None:
        key = (column, canonical_selector(selector))
        nb = _entry_nbytes(syn)
        with self._lock:
            if self.max_bytes is not None and nb > self.max_bytes:
                # An entry that can never fit must not flush the whole cache
                # on its way through the eviction loop; refuse it and keep
                # the rest.
                self.oversize += 1
                if key in self._entries:
                    self._bytes -= self._entries.pop(key)[2]
                return
            if key in self._entries:
                self._bytes -= self._entries.pop(key)[2]
            self._entries[key] = (version, syn, nb)
            self._bytes += nb
            while (len(self._entries) > self.max_entries
                   or (self.max_bytes is not None
                       and self._bytes > self.max_bytes)):
                _, (_, _, ev_nb) = self._entries.popitem(last=False)
                self._bytes -= ev_nb
                self.evictions += 1
                if self._m_evictions is not None:
                    self._m_evictions.inc()
            if self._m_entries is not None:
                self._m_entries.set(len(self._entries))
                self._m_bytes.set(self._bytes)

    def peek(self, column: ColumnKey, selector: str,
             version: int) -> Optional[KDESynopsis]:
        """Non-counting `get`: no hit/miss counters, no LRU refresh.  The
        admission fit-offload guard uses this to ask "is the fit already
        done?" without skewing cache statistics or recency."""
        key = (column, canonical_selector(selector))
        with self._lock:
            ent = self._entries.get(key)
            if ent is not None and ent[0] == version:
                return ent[1]
            return None

    def invalidate(self, column: Optional[ColumnKey] = None) -> None:
        with self._lock:
            if column is None:
                self._entries.clear()
                self._bytes = 0
                return
            for key in [k for k in self._entries if k[0] == column]:
                self._bytes -= self._entries.pop(key)[2]

    def entries(self) -> List[Tuple[Tuple[Hashable, str], int, KDESynopsis]]:
        """Consistent snapshot of the live entries, LRU order:
        [(key, version, synopsis)] — the durable-state serializer's view."""
        with self._lock:
            return [(key, version, syn) for key, (version, syn, _nb)
                    in self._entries.items()]

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "entries": len(self._entries), "bytes": self._bytes,
                    "evictions": self.evictions, "oversize": self.oversize}


class TelemetryStore:
    def __init__(self, capacity: int = 4096, seed: int = 0,
                 cache_entries: int = 128, cache_bytes: Optional[int] = None,
                 metrics: Optional[obs.MetricsRegistry] = None):
        # the three registries allow unlocked reads by design (query paths
        # tolerate a stale view; reservoirs are internally consistent), but
        # every *mutation* must hold _write_lock so snapshots (to_state) and
        # concurrent track_*/add_batch calls cannot interleave
        self.columns: Dict[str, Reservoir] = {}         # guarded-by: _write_lock (writes)
        self.joints: Dict[Tuple[str, ...], MultiReservoir] = {}  # guarded-by: _write_lock (writes)
        self.categoricals: Dict[str, CategoricalSketch] = {}  # guarded-by: _write_lock (writes)
        self.capacity = capacity
        self.seed = seed
        # every store owns a MetricsRegistry (or shares an injected one):
        # engine/admission/cache instruments all land here, so co-hosted
        # stores and tests stay isolated while `serve --metrics-out` exports
        # one store's registry plus the process-global kernel registry
        self.metrics = metrics if metrics is not None else obs.MetricsRegistry()
        self.cache = SynopsisCache(max_entries=cache_entries,
                                   max_bytes=cache_bytes,
                                   metrics=self.metrics)
        self._listeners: List[Callable[[Dict[ColumnKey, int]], None]] = []  # guarded-by: _write_lock
        self._sessions: List["weakref.ref"] = []        # guarded-by: _write_lock
        # shared engines keyed (selector, backend): query()/session() route
        # through these so PlanCache entries persist across calls and can be
        # checkpointed/restored (warm starts skip replanning)
        self._engines: Dict[Tuple[str, str], object] = {}  # guarded-by: _write_lock (writes)
        # serializes mutation (add_batch/restore_state) against snapshots
        # (to_state): a snapshot taken mid-add_batch could otherwise persist
        # a sketch whose n_rows exceeds its reservoir's n_seen — a restored
        # store would then claim exact coverage it does not have
        self._write_lock = threading.RLock()

    def _col_seed(self, name: str) -> int:
        # crc32, not hash(): Python string hashing is randomised per
        # process, which would make the reservoirs nondeterministic.
        return self.seed + zlib.crc32(name.encode()) % 1000

    def track_joint(self, columns: Sequence[str], backfill: bool = True) -> None:
        """Register a joint (row) reservoir over a column tuple.

        Only rows arriving *after* registration are sampled exactly.  When the
        columns are already tracked per-column, the joint reservoir is seeded
        by replaying the per-column reservoirs' current samples zip-aligned
        (a window of pseudo-rows): the marginals are right immediately, but
        cross-column correlation only accumulates as real rows stream in.
        The seed is flagged as `backfilled` in `stats()`; pass
        `backfill=False` to start empty instead.
        """
        key = tuple(columns)
        # registration is a write: hold _write_lock for the whole
        # check-backfill-insert sequence so a concurrent add_batch cannot
        # advance the per-column reservoirs between the backfill read and
        # the joint's n_seen stamp (a torn backfill would under-count)
        with self._write_lock:
            if key in self.joints:
                return
            res = MultiReservoir(key, self.capacity,
                                 seed=self._col_seed("|".join(key)))
            if backfill and all(c in self.columns
                                and self.columns[c].n_filled > 0
                                for c in key):
                samples = [self.columns[c].sample() for c in key]
                k = min(s.shape[0] for s in samples)  # zip-aligned window
                res.add(np.stack([s[:k] for s in samples], axis=1))
                # The window stands in for the paired stream the per-column
                # reservoirs summarize, so the joint's stream size is theirs
                # — not k.  Without this, sample->relation scaling (and
                # weighted merges) would treat the backfill as a k-row
                # relation.
                res.n_seen = min(self.columns[c].n_seen for c in key)
                res.backfilled = True
            self.joints[key] = res

    def track_tiered(self, columns: ColumnKey, n_tiers: int = 4,
                     strat_column: Optional[str] = None,
                     strata_capacity: int = 64,
                     max_strata: int = 256) -> None:
        """Upgrade a column (str) or joint tuple to a `TieredReservoir` so
        queries can trade accuracy for latency: tier 0 answers from a
        1/2^(n_tiers-1) sample, progressive mode refines tier by tier, and
        the top tier reproduces untiered answers bit-for-bit.  Register
        *before* the first `add_batch` — an existing reservoir with data
        cannot be converted (its stream is gone).  `strat_column` keeps a
        small per-code side sample for rare GROUP BY groups."""
        if isinstance(columns, str):
            name: ColumnKey = columns
            registry: Dict = self.columns
            seed = self._col_seed(columns)
            if strat_column is not None and strat_column != columns:
                raise ValueError(f"strat_column {strat_column!r} must equal "
                                 f"the tracked column {columns!r} for 1-D "
                                 f"tiered reservoirs")
            member_cols = None
            strat = columns if strat_column is not None else None
        else:
            name = tuple(columns)
            registry = self.joints
            seed = self._col_seed("|".join(name))
            member_cols = name
            strat = strat_column
        # `registry` aliases self.columns / self.joints: the insert below is
        # a store mutation and must not interleave with add_batch's
        # create-if-missing for the same name
        with self._write_lock:
            existing = registry.get(name)
            if isinstance(existing, TieredReservoir):
                return
            if existing is not None and existing.n_seen > 0:
                raise ValueError(f"cannot convert reservoir {name!r} with "
                                 f"{existing.n_seen} rows seen to tiered; "
                                 f"call track_tiered before add_batch")
            registry[name] = TieredReservoir(
                self.capacity, n_tiers=n_tiers, seed=seed,
                columns=member_cols, strat_column=strat,
                strata_capacity=strata_capacity, max_strata=max_strata)

    def track_categorical(self, column: str, max_codes: int = 4096,
                          kind: str = "exact", width: int = 2048,
                          depth: int = 4, conservative: bool = False,
                          grid_step: float = 1.0,
                          grid_origin: float = 0.0) -> None:
        """Register a per-code frequency sketch for a dictionary column.
        Register *before* the column's first `add_batch` — the engine's
        exact Eq path requires the sketch to cover the whole stream
        (otherwise it falls back to the KDE code-window estimate; see
        `stats()["categoricals"]` for coverage).

        kind="exact" (default) keeps one exact counter per code but disables
        itself past `max_codes` distinct codes; kind="cm" keeps a
        (depth x width) count-min table instead — bounded-error counts
        (path "exact:cm") for columns too wide to enumerate.
        `conservative=True` (kind="cm" only) switches the table to
        conservative updates: same worst-case bound, much lower realised
        error on skewed streams (see `CountMinSketch`).
        `grid_step`/`grid_origin` (kind="cm" only) declare the column's code
        lattice for range enumeration — codes observed off the declared grid
        disable range answers rather than mis-weighting them (see
        `CountMinSketch`); the exact sketch keys codes directly and needs no
        grid."""
        with self._write_lock:
            if column in self.categoricals:
                return
            if kind == "exact":
                if conservative:
                    raise ValueError("conservative update is a count-min "
                                     "mode; kind='exact' counts are already "
                                     "exact")
                if (grid_step, grid_origin) != (1.0, 0.0):
                    raise ValueError("grid_step/grid_origin are count-min "
                                     "parameters; kind='exact' enumerates "
                                     "its actual codes and needs no grid")
                self.categoricals[column] = CategoricalSketch(
                    max_codes=max_codes)
            elif kind == "cm":
                # seed from the column name alone (NOT the per-host store
                # seed): cross-host merge adds the counter tables cell-wise,
                # which is only meaningful when every host hashes codes
                # identically
                self.categoricals[column] = CountMinSketch(
                    width=width, depth=depth,
                    seed=zlib.crc32(column.encode()) % 1000,
                    conservative=conservative,
                    grid_step=grid_step, grid_origin=grid_origin)
            else:
                raise ValueError(f"unknown sketch kind {kind!r}; "
                                 f"expected one of {sorted(_SKETCH_KINDS)}")

    def subscribe(self, fn: Callable[[Dict[ColumnKey, int]], None]
                  ) -> Callable[[], None]:
        """Version-change notification: `fn` is called after every
        `add_batch` with {column-or-joint-tuple: new version} for each bumped
        reservoir.  Returns an unsubscribe callable.  Admission sessions use
        this to re-key in-flight micro-batches to the fresh synopsis."""
        with self._write_lock:
            self._listeners.append(fn)

        def unsubscribe() -> None:
            with self._write_lock:
                try:
                    self._listeners.remove(fn)
                except ValueError:
                    pass
        return unsubscribe

    def _register_session(self, session) -> None:
        """Track an admission session (weakly) so `stats()` can aggregate its
        counters; called by AqpSession.__init__."""
        with self._write_lock:
            self._sessions = [r for r in self._sessions
                              if r() is not None]
            self._sessions.append(weakref.ref(session))

    def add_batch(self, stats: Dict[str, np.ndarray]) -> None:
        # Build joint rows BEFORE mutating any reservoir: a ragged batch must
        # fail cleanly, not leave per-column reservoirs updated with the
        # joints skipped (partial mutation would silently skew every joint
        # synopsis fitted afterwards).
        joint_rows = {}
        for cols in self.joints:
            if all(c in stats for c in cols):
                arrays = [np.asarray(stats[c], np.float32).ravel() for c in cols]
                sizes = {c: a.shape[0] for c, a in zip(cols, arrays)}
                if len(set(sizes.values())) > 1:
                    raise ValueError(f"joint {cols} needs row-aligned columns, "
                                     f"got lengths {sizes}")
                joint_rows[cols] = np.stack(arrays, axis=1)
        t_ingest = time.perf_counter() if obs.enabled() else 0.0
        with self._write_lock:      # vs to_state: snapshots see whole batches
            for name, values in stats.items():
                if name not in self.columns:
                    self.columns[name] = Reservoir(self.capacity,
                                                   seed=self._col_seed(name))
                res = self.columns[name]
                res.add(values)
                n_rows = np.asarray(values).size
                self.metrics.counter("aqp.ingest.rows", column=name).inc(
                    n_rows)
                self.metrics.gauge("aqp.reservoir.fill", column=name).set(
                    res.n_filled / max(res.capacity, 1))
                sketch = self.categoricals.get(name)
                if sketch is not None:
                    sketch.add(values)
                    eb = getattr(sketch, "err_bound", None)
                    if eb is not None:
                        self.metrics.gauge("aqp.sketch.err_bound",
                                           column=name).set(eb())
            for cols, rows in joint_rows.items():
                self.joints[cols].add(rows)
            self.metrics.counter("aqp.ingest.batches").inc()
            if self._listeners:
                bumped: Dict[ColumnKey, int] = {
                    name: self.columns[name].version for name in stats}
                for cols in joint_rows:
                    bumped[cols] = self.joints[cols].version
                for fn in list(self._listeners):
                    fn(bumped)
        if t_ingest:
            self.metrics.histogram("aqp.ingest.us").observe(
                (time.perf_counter() - t_ingest) * 1e6)

    def synopsis(self, column: str, selector: str = "plugin",
                 tier: Optional[int] = None) -> KDESynopsis:
        res = self.columns.get(column)
        if res is None:
            raise KeyError(f"unknown column {column!r}; "
                           f"have {sorted(self.columns)}")
        return self._fit_cached(column, res, selector, tier=tier)

    def joint_synopsis(self, columns: Sequence[str],
                       selector: str = "plugin",
                       tier: Optional[int] = None) -> KDESynopsis:
        """Joint synopsis over a tracked column tuple: per-axis diagonal
        bandwidths (plugin/silverman), scalar LSCV_h, or full-H LSCV_H."""
        key = tuple(columns)
        res = self.joints.get(key)
        if res is None:
            raise KeyError(f"no joint reservoir for columns {key!r}; call "
                           f"track_joint({key!r}) before add_batch "
                           f"(have {sorted(self.joints)})")
        return self._fit_cached(key, res, selector, tier=tier)

    def _fit_cached(self, key: ColumnKey, res: Reservoir, selector: str,
                    tier: Optional[int] = None) -> KDESynopsis:
        # lazy import: aqp_query imports this module's types at top level
        from repro.core.aqp_query import _effective_tier, _tier_key

        selector = canonical_selector(selector)
        tier = _effective_tier(res, tier)
        ckey = _tier_key(key, tier)
        syn = self.cache.get(ckey, selector, res.version)
        if syn is None:
            data = res.sample() if tier is None else res.sample(tier)
            syn = KDESynopsis.fit(data, selector=selector,
                                  max_sample=self.capacity)
            # scale against the FULL stream: every tier is a uniform sample
            # of it, so tier answers are unbiased for the same relation
            syn.n_source = res.n_seen
            self.cache.put(ckey, selector, res.version, syn)
        return syn

    # -- queries ------------------------------------------------------------
    #
    # `query` is the one entry point: a mixed batch of declarative AqpQuery
    # specs (1-D ranges, multi-d boxes, categorical Eq terms, GROUP BY) is
    # planned and executed by the QueryEngine facade.  `query_batch` /
    # `query_box_batch` are retained conveniences for the legacy Query /
    # BoxQuery types; they compile to the same engine.

    def engine(self, **kwargs) -> "QueryEngine":
        """A fresh QueryEngine facade over this store (repro.core.aqp_query).
        Prefer `shared_engine` for repeated querying — it keeps one PlanCache
        per (selector, backend) that checkpoints ride along with."""
        from repro.core.aqp_query import QueryEngine
        return QueryEngine(self, **kwargs)

    def shared_engine(self, selector: str = "plugin",
                      backend: str = "jnp") -> "QueryEngine":
        """The store-owned engine for (selector, backend), created on first
        use.  Its PlanCache persists across `query()` calls and through
        `to_state`/`restore_state`, so a warm-started store replays cached
        plans instead of replanning on its first flush."""
        key = (canonical_selector(selector), backend)
        # get-or-create under _write_lock: two racing callers must share one
        # engine (and one PlanCache), not last-writer-wins two
        with self._write_lock:
            eng = self._engines.get(key)
            if eng is None:
                eng = self.engine(selector=key[0], backend=backend)
                self._engines[key] = eng
            return eng

    def session(self, selector: str = "plugin", backend: str = "jnp",
                **kwargs) -> "AqpSession":
        """A streaming admission session over this store: submit AqpQuery
        specs from many logical clients, micro-batches coalesce across
        callers and flush on watermark/deadline (repro.core.aqp_admission).
        Remaining kwargs (watermark, max_delay, ...) go to AqpSession."""
        return self.shared_engine(selector, backend).session(**kwargs)

    def query(self, queries, selector: str = "plugin",
              backend: str = "jnp", mode: str = "batch"):
        """Answer a mixed batch of AqpQuery specs in one engine call; returns
        AqpResult rows (estimate + path + confidence interval + synopsis
        version) in submission order.  `mode="progressive"` returns the
        engine's (tier, results) generator instead (see
        `QueryEngine.progressive`)."""
        return self.shared_engine(selector, backend).execute(queries,
                                                             mode=mode)

    def count(self, column: str, a: float, b: float, selector: str = "plugin") -> float:
        return float(self.synopsis(column, selector).count(a, b))

    def avg(self, column: str, a: float, b: float, selector: str = "plugin") -> float:
        return float(self.synopsis(column, selector).avg(a, b))

    def fraction(self, column: str, a: float, b: float, selector: str = "plugin") -> float:
        res = self.columns[column]
        return self.count(column, a, b, selector) / max(res.n_seen, 1)

    def query_batch(self, queries: Sequence[Query], selector: str = "plugin",
                    backend: str = "jnp") -> np.ndarray:
        """Answer N legacy 1-D range queries (mixed ops/ranges/columns)
        through the unified engine; synopses come from the cache."""
        from repro.core.aqp_query import QueryEngine, from_query

        queries = [q if isinstance(q, Query) else Query(*q) for q in queries]
        specs = [from_query(q) for q in queries]
        return QueryEngine(self, selector=selector,
                           backend=backend).answers(specs)

    def query_box_batch(self, queries: Sequence[BoxQuery],
                        selector: str = "plugin",
                        backend: str = "jnp") -> np.ndarray:
        """Answer N legacy multi-column box queries (eq. 11) through the
        unified engine; joint synopses come from the cache."""
        from repro.core.aqp_query import QueryEngine, from_box_query

        queries = [q if isinstance(q, BoxQuery) else BoxQuery(*q)
                   for q in queries]
        specs = [from_box_query(q) for q in queries]
        return QueryEngine(self, selector=selector,
                           backend=backend).answers(specs)

    def stats(self) -> Dict[str, object]:
        """Store-level observability: cache hit/miss/eviction counters,
        per-reservoir stream sizes, which joints were seeded by the
        per-column backfill (pseudo-rows, see `track_joint`), exact-sketch
        coverage, and aggregated admission-session counters."""
        cats = {}
        for name, sketch in self.categoricals.items():
            ent = sketch.stats()
            res = self.columns.get(name)
            ent["exact"] = res is not None and sketch.exact_for(res.n_seen)
            cats[name] = ent
        return {
            "cache": self.cache.stats(),
            "columns": {name: res.n_seen for name, res in self.columns.items()},
            "joints": {key: res.n_seen for key, res in self.joints.items()},
            "backfilled": {key: res.backfilled
                           for key, res in self.joints.items()},
            "categoricals": cats,
            "admission": self._admission_stats(),
        }

    def _admission_stats(self) -> Dict[str, object]:
        """Aggregate admission counters across every session ever opened on
        this store, summed straight from the metrics registry.

        The pre-registry implementation iterated live weakrefs and summed
        `session.stats()` dicts, so a session that was closed and
        garbage-collected took its counters with it — the store-level totals
        silently dropped whole sessions' worth of work (and double-counted
        nothing only by luck of GC timing).  Registry counters are labelled
        `session=<id>` and outlive the session object, so the sums here are
        monotone regardless of session lifetime; only `sessions` (currently
        registered) and `pending` (live depth gauges) reflect the present.
        """
        with self._write_lock:
            live = [r for r in self._sessions if r() is not None]
        reg = self.metrics
        agg: Dict[str, object] = {"sessions": len(live)}
        for k in ("submitted", "executed", "flushes", "coalesced",
                  "invalidations", "blocked", "shed", "fit_requeued"):
            agg[k] = int(reg.sum_counter(f"aqp.admission.{k}"))
        agg["pending"] = int(reg.sum_gauge("aqp.admission.depth"))
        flush_reasons: Dict[str, int] = {}
        for labels, n in reg.collect_counters("aqp.admission.flush_reason"):
            reason = labels.get("reason", "?")
            flush_reasons[reason] = flush_reasons.get(reason, 0) + int(n)
        agg["flush_reasons"] = flush_reasons
        batch_rows = reg.sum_counter("aqp.admission.batch_rows")
        agg["mean_batch"] = (batch_rows / agg["flushes"]
                             if agg["flushes"] else 0.0)
        return agg

    def merge(self, other: "TelemetryStore") -> "TelemetryStore":
        out = TelemetryStore(self.capacity, self.seed,
                             cache_entries=self.cache.max_entries,
                             cache_bytes=self.cache.max_bytes)
        for name in set(self.columns) | set(other.columns):
            if name in self.columns and name in other.columns:
                out.columns[name] = self.columns[name].merge(other.columns[name])
            else:
                # deep copy: the merged store is a snapshot, so later updates
                # to the source store must not leak into it through aliasing
                out.columns[name] = copy.deepcopy(
                    self.columns.get(name) or other.columns[name])
        for key in set(self.joints) | set(other.joints):
            if key in self.joints and key in other.joints:
                out.joints[key] = self.joints[key].merge(other.joints[key])
            else:
                out.joints[key] = copy.deepcopy(
                    self.joints.get(key) or other.joints[key])
        for name in set(self.categoricals) | set(other.categoricals):
            if name in self.categoricals and name in other.categoricals:
                out.categoricals[name] = \
                    self.categoricals[name].merge(other.categoricals[name])
            else:
                # one-sided sketch: carried along, but it cannot cover the
                # merged stream, so `exact_for` disables the exact path
                out.categoricals[name] = copy.deepcopy(
                    self.categoricals.get(name) or other.categoricals[name])
        return out

    # -- durability ----------------------------------------------------------
    #
    # `to_state`/`from_state` round-trip the store's complete mutable state;
    # `save`/`load` put it behind the atomic keep-k CheckpointManager.  The
    # fitted synopses in the cache ride along, so a warm-started store skips
    # the expensive bandwidth refits entirely (the paper's whole premise is
    # that fitting is the step worth not repeating).

    def to_state(self) -> Tuple[Dict[str, np.ndarray], Dict[str, object]]:
        """Snapshot to (flat array tree, JSON-safe metadata), taken under the
        store's write lock — a snapshot racing `add_batch` sees whole batches
        only, so a persisted sketch never claims rows its reservoir has not
        seen (`from_state` re-asserts this invariant on load)."""
        with self._write_lock:
            tree: Dict[str, np.ndarray] = {}
            meta: Dict[str, object] = {
                "format": STATE_FORMAT, "capacity": int(self.capacity),
                "seed": int(self.seed), "columns": {}, "joints": [],
                "categoricals": {}, "cache": [],
            }
            for name in list(self.columns) + list(self.categoricals):
                if "/" in name:
                    raise ValueError(f"column name {name!r} contains '/', "
                                     f"which state keys reserve as a "
                                     f"separator")
            for name, res in self.columns.items():
                if isinstance(res, TieredReservoir):
                    arrays, m = res.state()
                    for k, arr in arrays.items():
                        tree[f"columns/{name}/{k}"] = arr
                else:
                    buf, m = res.state()
                    tree[f"columns/{name}/buf"] = buf
                meta["columns"][name] = m
            for i, (cols, res) in enumerate(self.joints.items()):
                if isinstance(res, TieredReservoir):
                    arrays, m = res.state()
                    for k, arr in arrays.items():
                        tree[f"joints/{i}/{k}"] = arr
                else:
                    buf, m = res.state()
                    tree[f"joints/{i}/buf"] = buf
                m["columns"] = list(cols)
                meta["joints"].append(m)
            for name, sketch in self.categoricals.items():
                arrays, m = sketch.state()
                for k, arr in arrays.items():
                    tree[f"categoricals/{name}/{k}"] = arr
                meta["categoricals"][name] = m
            for i, (key, version, syn) in enumerate(self.cache.entries()):
                col, sel = key
                ent = {
                    "column": list(col) if isinstance(col, tuple) else col,
                    "is_tuple": isinstance(col, tuple), "selector": sel,
                    "version": int(version), "n_source": int(syn.n_source),
                    "syn_selector": syn.selector,
                }
                to_state = getattr(syn, "to_state", None)
                if to_state is not None:
                    # pluggable `repro.synopses` backend (e.g. a fitted RFF
                    # state): it serializes itself; the backend name in the
                    # meta picks the deserializer on restore
                    arrays, syn_meta = to_state()
                    ent["synopsis"] = syn_meta
                    for k, arr in arrays.items():
                        tree[f"cache/{i}/{k}"] = np.asarray(arr)
                else:
                    tree[f"cache/{i}/x"] = np.asarray(syn.x)
                    if syn.h is not None:
                        tree[f"cache/{i}/h"] = np.asarray(syn.h)
                    if syn.H is not None:
                        tree[f"cache/{i}/H"] = np.asarray(syn.H)
                meta["cache"].append(ent)
            # shared engines' plan-cache keys ride along: plans rebuild from
            # the persisted synopses on restore, so warm starts skip the
            # compile-and-plan pass too (not just the bandwidth fits)
            meta["plans"] = []
            for (sel_eng, backend), eng in self._engines.items():
                entries = []
                for key, version in eng.plans.entries():
                    if not (isinstance(key, tuple) and len(key) == 3):
                        continue      # mapping-resolver keys: not durable
                    col, sel, tier = key
                    entries.append({
                        "column": list(col) if isinstance(col, tuple)
                        else col,
                        "is_tuple": isinstance(col, tuple),
                        "selector": sel, "tier": tier,
                        "version": int(version)})
                if entries:
                    meta["plans"].append({"selector": sel_eng,
                                          "backend": backend,
                                          "entries": entries})
            # the registry rides in the (JSON) manifest so cumulative
            # counters — ingest rows, admission totals — survive a restart
            meta["metrics"] = self.metrics.state()
            return tree, meta

    def restore_state(self, tree: Dict[str, np.ndarray],
                      meta: Dict[str, object]) -> None:
        """Swap this store's contents for a snapshot's, in place.  The
        restored reservoir versions are pushed through the `subscribe`
        listeners, so in-flight admission buckets re-key to them and the
        version-keyed PlanCache/SynopsisCache lookups key correctly."""
        import jax.numpy as jnp

        if int(meta.get("format", -1)) != STATE_FORMAT:
            raise ValueError(f"unsupported store-state format "
                             f"{meta.get('format')!r} (want {STATE_FORMAT})")
        with self._write_lock:
            self.capacity = int(meta["capacity"])

            def _subtree(prefix: str) -> Dict[str, np.ndarray]:
                return {k[len(prefix):]: v for k, v in tree.items()
                        if k.startswith(prefix)}

            columns: Dict[str, Reservoir] = {}
            for name, m in meta["columns"].items():
                if m.get("kind") == "tiered":
                    columns[name] = TieredReservoir.from_state(
                        _subtree(f"columns/{name}/"), m)
                    continue
                res = Reservoir(self.capacity, seed=self._col_seed(name))
                res.load_state(tree[f"columns/{name}/buf"], m)
                columns[name] = res
            joints: Dict[Tuple[str, ...], MultiReservoir] = {}
            for i, m in enumerate(meta["joints"]):
                cols = tuple(m["columns"])
                if m.get("kind") == "tiered":
                    joints[cols] = TieredReservoir.from_state(
                        _subtree(f"joints/{i}/"), m)
                    continue
                res = MultiReservoir(cols, self.capacity,
                                     seed=self._col_seed("|".join(cols)))
                res.load_state(tree[f"joints/{i}/buf"], m)
                joints[cols] = res
            categoricals: Dict[str, object] = {}
            for name, m in meta["categoricals"].items():
                prefix = f"categoricals/{name}/"
                arrays = {k[len(prefix):]: v for k, v in tree.items()
                          if k.startswith(prefix)}
                sketch = _SKETCH_KINDS[str(m["kind"])].from_state(arrays, m)
                res = columns.get(name)
                if res is not None and sketch.n_rows > res.n_seen:
                    # the coverage invariant: restoring this would let the
                    # store claim exact coverage of rows it never sampled
                    raise ValueError(
                        f"inconsistent snapshot: sketch for {name!r} has "
                        f"seen {sketch.n_rows} rows but its reservoir only "
                        f"{res.n_seen}")
                categoricals[name] = sketch
            self.columns = columns
            self.joints = joints
            self.categoricals = categoricals
            self.cache.invalidate()
            for i, ent in enumerate(meta["cache"]):
                syn_meta = ent.get("synopsis")
                if syn_meta is not None:
                    # pluggable backend entry: round-trip through its own
                    # (de)serializer, bit-for-bit (test-enforced for RFF)
                    from repro.synopses import get_backend
                    syn = get_backend(str(syn_meta["backend"])).from_state(
                        _subtree(f"cache/{i}/"), syn_meta)
                    syn.n_source = int(ent["n_source"])
                    syn.selector = str(ent["syn_selector"])
                else:
                    h = tree.get(f"cache/{i}/h")
                    H = tree.get(f"cache/{i}/H")
                    syn = KDESynopsis(
                        x=jnp.asarray(tree[f"cache/{i}/x"]),
                        h=None if h is None else jnp.asarray(h),
                        H=None if H is None else jnp.asarray(H),
                        n_source=int(ent["n_source"]),
                        selector=str(ent["syn_selector"]))
                col = tuple(ent["column"]) if ent["is_tuple"] \
                    else ent["column"]
                self.cache.put(col, str(ent["selector"]),
                               int(ent["version"]), syn)
            # rebuild shared-engine plans eagerly from the restored synopses
            # (NOT through SynopsisCache.get — priming must not count as
            # misses, the warm-start contract is zero cache misses)
            self._engines = {}
            if meta.get("plans"):
                from repro.core.aqp_query import _make_plan, _tier_key

                index = {key: (v, syn)
                         for key, v, syn in self.cache.entries()}
                for peng in meta["plans"]:
                    eng = self.shared_engine(str(peng["selector"]),
                                             str(peng["backend"]))
                    for ent in peng["entries"]:
                        col = tuple(ent["column"]) if ent["is_tuple"] \
                            else ent["column"]
                        tier = ent["tier"]
                        tier = None if tier is None else int(tier)
                        hit = index.get((_tier_key(col, tier),
                                         str(ent["selector"])))
                        if hit is not None and hit[0] == int(ent["version"]):
                            eng.plans.put((col, str(ent["selector"]), tier),
                                          int(ent["version"]),
                                          _make_plan(hit[1]))
            # optional key: pre-observability snapshots restore fine; the
            # gauges mirrored from live structures (cache size, reservoir
            # fill) are restored too but refresh on the next mutation
            if meta.get("metrics"):
                self.metrics.load_state(meta["metrics"])
            if self._listeners:
                bumped: Dict[ColumnKey, int] = {
                    name: res.version for name, res in self.columns.items()}
                for cols, res in self.joints.items():
                    bumped[cols] = res.version
                for fn in list(self._listeners):
                    fn(bumped)

    @classmethod
    def from_state(cls, tree: Dict[str, np.ndarray],
                   meta: Dict[str, object], cache_entries: int = 128,
                   cache_bytes: Optional[int] = None) -> "TelemetryStore":
        """Rebuild a store from a `to_state` snapshot."""
        store = cls(capacity=int(meta["capacity"]), seed=int(meta["seed"]),
                    cache_entries=cache_entries, cache_bytes=cache_bytes)
        store.restore_state(tree, meta)
        return store

    def save(self, path: str, step: Optional[int] = None,
             keep: int = 3) -> int:
        """Write an atomic snapshot under `path` through the keep-k
        `CheckpointManager` (crash mid-write never corrupts the latest
        completed snapshot).  Returns the step written (monotonic when
        `step` is omitted)."""
        from repro.checkpoint import CheckpointManager

        mgr = CheckpointManager(path, keep=keep, async_save=False)
        if step is None:
            latest = mgr.latest_step()
            step = 1 if latest is None else latest + 1
        t0 = time.perf_counter()
        tree, meta = self.to_state()
        mgr.save(step, tree, extra=meta)
        self.metrics.histogram("aqp.snapshot.us").observe(
            (time.perf_counter() - t0) * 1e6)
        return step

    @classmethod
    def load(cls, path: str, step: Optional[int] = None,
             cache_entries: int = 128,
             cache_bytes: Optional[int] = None) -> "TelemetryStore":
        """Warm-start a store from the latest (or a specific) snapshot under
        `path`.  Everything survives: reservoir samples and RNG states (so
        post-restore sampling is bit-identical to an uninterrupted store),
        versions, joint registrations and backfill flags, categorical-sketch
        coverage (exact-Eq answers stay exact), and the fitted synopses."""
        from repro.checkpoint import CheckpointManager

        mgr = CheckpointManager(path, async_save=False)
        if step is None:
            step = mgr.latest_step()
            if step is None:
                raise FileNotFoundError(f"no completed snapshots under "
                                        f"{path!r}")
        tree, meta = mgr.restore_flat(step)
        return cls.from_state(tree, meta, cache_entries=cache_entries,
                              cache_bytes=cache_bytes)
