"""AQP telemetry store — the paper's technique as a first-class framework
feature (DESIGN.md §4).

Training/serving telemetry columns (per-sequence loss, length, token stats)
stream in per batch; the store keeps a bounded reservoir sample per column and
fits KDE synopses with the paper's selectors on demand.  Queries (COUNT/SUM/
AVG over a range, quantile-ish fractions) are answered from the synopsis in
O(sample) instead of O(history) — and the synopsis is *mergeable* across hosts
(reservoir union), which is the property that makes this usable on a
1000-node fleet where no host sees the global stream.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.aqp import KDESynopsis


class Reservoir:
    """Algorithm-R reservoir sample with deterministic RNG."""

    def __init__(self, capacity: int = 4096, seed: int = 0):
        self.capacity = capacity
        self.rng = np.random.default_rng(seed)
        self.buf = np.empty((capacity,), np.float32)
        self.n_seen = 0

    def add(self, values: np.ndarray) -> None:
        for v in np.asarray(values, np.float32).ravel():
            if self.n_seen < self.capacity:
                self.buf[self.n_seen] = v
            else:
                j = self.rng.integers(0, self.n_seen + 1)
                if j < self.capacity:
                    self.buf[j] = v
            self.n_seen += 1

    def sample(self) -> np.ndarray:
        return self.buf[: min(self.n_seen, self.capacity)].copy()

    def merge(self, other: "Reservoir") -> "Reservoir":
        out = Reservoir(self.capacity, seed=int(self.rng.integers(1 << 31)))
        both = np.concatenate([self.sample(), other.sample()])
        self.rng.shuffle(both)
        out.add(both)
        out.n_seen = self.n_seen + other.n_seen
        return out


class TelemetryStore:
    def __init__(self, capacity: int = 4096, seed: int = 0):
        self.columns: Dict[str, Reservoir] = {}
        self.capacity = capacity
        self.seed = seed

    def add_batch(self, stats: Dict[str, np.ndarray]) -> None:
        for name, values in stats.items():
            if name not in self.columns:
                self.columns[name] = Reservoir(self.capacity, seed=self.seed + hash(name) % 1000)
            self.columns[name].add(values)

    def synopsis(self, column: str, selector: str = "plugin") -> KDESynopsis:
        res = self.columns[column]
        syn = KDESynopsis.fit(res.sample(), selector=selector,
                              max_sample=self.capacity)
        syn.n_source = res.n_seen
        return syn

    # -- queries ------------------------------------------------------------
    def count(self, column: str, a: float, b: float, selector: str = "plugin") -> float:
        return float(self.synopsis(column, selector).count(a, b))

    def avg(self, column: str, a: float, b: float, selector: str = "plugin") -> float:
        return float(self.synopsis(column, selector).avg(a, b))

    def fraction(self, column: str, a: float, b: float, selector: str = "plugin") -> float:
        res = self.columns[column]
        return self.count(column, a, b, selector) / max(res.n_seen, 1)

    def merge(self, other: "TelemetryStore") -> "TelemetryStore":
        out = TelemetryStore(self.capacity, self.seed)
        for name in set(self.columns) | set(other.columns):
            if name in self.columns and name in other.columns:
                out.columns[name] = self.columns[name].merge(other.columns[name])
            else:
                out.columns[name] = (self.columns.get(name) or other.columns[name])
        return out
