from .aqp_store import (CategoricalSketch, MultiReservoir, Reservoir,
                        SynopsisCache, TelemetryStore)
from .pipeline import TokenPipeline
