from .aqp_store import MultiReservoir, Reservoir, SynopsisCache, TelemetryStore
from .pipeline import TokenPipeline
