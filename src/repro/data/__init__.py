from .aqp_store import (CategoricalSketch, CountMinSketch, MultiReservoir,
                        Reservoir, SynopsisCache, TelemetryStore)
from .pipeline import TokenPipeline
