from .aqp_store import Reservoir, TelemetryStore
from .pipeline import TokenPipeline
