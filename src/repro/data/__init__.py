from .aqp_store import (CategoricalSketch, CountMinSketch, MultiReservoir,
                        Reservoir, SynopsisCache, TelemetryStore,
                        TieredReservoir)
from .pipeline import TokenPipeline
