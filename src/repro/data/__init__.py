from .aqp_store import Reservoir, SynopsisCache, TelemetryStore
from .pipeline import TokenPipeline
