"""Span-based tracing for the AQP serving stack.

One query admitted through `AqpSession.submit` crosses three threads
(caller -> flusher -> jax dispatch) before its CI comes back; wall-clock
deltas in any single frame can't explain where the time went.  Spans fix
that: every instrumented section opens a `Span` carrying a `trace_id`
shared by the whole query and a `parent_id` linking it into a tree
(admission.submit -> admission.flush -> engine.run_compiled ->
engine.plan / engine.kernel / engine.ci).

Design points:
  * injectable clock (`Tracer(clock=fake)`) so tests assert exact durations;
  * bounded in-memory ring (deque) — a long-running server never grows
    unbounded trace state;
  * `contextvars` hold the current span, so nesting works across
    coroutine/thread-pool boundaries *within* a thread of execution; the
    admission queue carries an explicit `ctx` across the submit->flusher
    thread hop and passes it as `parent=`;
  * spans are recorded on close (end-time known), children before parents
    get reconstructed by `tree()`;
  * `export_jsonl` writes one JSON object per line for offline analysis.

Timing inside a span is only *device-true* if the caller fences (see
`repro.obs.fence`); the engine instrumentation calls `block_until_ready`
on kernel outputs before closing kernel spans.
"""
from __future__ import annotations

import contextvars
import itertools
import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

_ids = itertools.count(1)
_CURRENT: contextvars.ContextVar[Optional["Span"]] = \
    contextvars.ContextVar("repro_obs_span", default=None)


class Span:
    """One timed section.  Use as a context manager; attrs are free-form
    (coerced to str at export so they stay JSON-safe)."""

    __slots__ = ("tracer", "name", "trace_id", "span_id", "parent_id",
                 "t0", "t1", "attrs", "_token")

    def __init__(self, tracer: "Tracer", name: str, trace_id: int,
                 parent_id: Optional[int], attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = next(_ids)
        self.parent_id = parent_id
        self.attrs = attrs
        self.t0 = 0.0
        self.t1 = 0.0
        self._token = None

    @property
    def ctx(self) -> Tuple[int, int]:
        """(trace_id, span_id): enough to parent a span in another thread."""
        return (self.trace_id, self.span_id)

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self.t0 = self.tracer.clock()
        self._token = _CURRENT.set(self)
        return self

    def __exit__(self, *exc) -> None:
        self.t1 = self.tracer.clock()
        if self._token is not None:
            _CURRENT.reset(self._token)
            self._token = None
        self.tracer._record(self)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name, "trace_id": self.trace_id,
            "span_id": self.span_id, "parent_id": self.parent_id,
            "t0": self.t0, "t1": self.t1,
            "duration_us": (self.t1 - self.t0) * 1e6,
            "attrs": {str(k): str(v) for k, v in self.attrs.items()},
        }


class _NoopSpan:
    """Disabled-mode stand-in: every operation is a no-op, `ctx` is None so
    downstream instrumentation knows there is nothing to parent onto."""

    __slots__ = ()
    ctx = None
    duration_s = 0.0

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


NOOP_SPAN = _NoopSpan()


class Tracer:
    """Bounded span recorder.

    `span(name, parent=..., **attrs)` opens a span whose parent is, in
    order of preference: the explicit `parent` ctx tuple, else the current
    span in this execution context, else none (a new root — which also
    mints a fresh trace id).
    """

    def __init__(self, clock=time.perf_counter, capacity: int = 4096):
        self.clock = clock
        self._ring: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()

    def span(self, name: str, parent: Optional[Tuple[int, int]] = None,
             **attrs) -> Span:
        if parent is not None:
            trace_id, parent_id = parent
        else:
            cur = _CURRENT.get()
            if cur is not None:
                trace_id, parent_id = cur.trace_id, cur.span_id
            else:
                trace_id, parent_id = next(_ids), None
        return Span(self, name, trace_id, parent_id, attrs)

    def current(self) -> Optional[Span]:
        return _CURRENT.get()

    def _record(self, span: Span) -> None:
        with self._lock:
            self._ring.append(span)

    def spans(self, trace_id: Optional[int] = None) -> List[Span]:
        """Closed spans, oldest first (optionally one trace only)."""
        with self._lock:
            out = list(self._ring)
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        return out

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def tree(self, trace_id: int) -> List[Dict[str, Any]]:
        """Reconstruct the span tree for one trace as nested dicts
        (each node: span fields + "children" sorted by start time)."""
        spans = self.spans(trace_id)
        nodes = {s.span_id: {**s.as_dict(), "children": []} for s in spans}
        roots: List[Dict[str, Any]] = []
        for s in sorted(spans, key=lambda s: s.t0):
            node = nodes[s.span_id]
            parent = nodes.get(s.parent_id) if s.parent_id else None
            (parent["children"] if parent else roots).append(node)
        return roots

    def export_jsonl(self, path: str,
                     trace_id: Optional[int] = None) -> int:
        """Append closed spans as JSON lines; returns the number written."""
        spans = self.spans(trace_id)
        with open(path, "a", encoding="utf-8") as f:
            for s in spans:
                f.write(json.dumps(s.as_dict(), sort_keys=True) + "\n")
        return len(spans)
