"""Thread-safe metrics registry: counters, gauges, latency histograms.

The serving stack (admission -> engine -> kernels -> CI computation) needs
machine-readable measurements, not ad-hoc dict counters: the ROADMAP's
autotuning and backend-selection items both choose code paths from measured
latency data, and the multi-tenant server is unshippable without queue-depth
and p99 visibility.  This module is the dependency-free substrate:

  `Counter`    — monotone float/int accumulator (`inc`)
  `Gauge`      — last-write-wins instantaneous value (`set`/`inc`)
  `Histogram`  — fixed log-spaced buckets with exact count/sum/min/max and
                 interpolated percentile summaries (p50/p95/p99)
  `MetricsRegistry`
               — the keyed collection: metrics are addressed by
                 (name, sorted label set) and created on first touch;
                 `snapshot()` renders everything to a plain JSON-safe dict,
                 `state()`/`load_state()` round-trip through the PR 5
                 checkpoint format so cumulative counters (e.g. ingest rows)
                 survive a serving restart.

Every metric guards its mutable state with its own lock, so concurrent
updates from query/flusher/producer threads lose no increments (test-asserted
with 8 writer threads).  Instruments are cheap enough to stay always-on —
the *expensive* instrumentation (span tracing, device-fenced latency timing,
kernel profiling) is gated separately in `repro.obs`.
"""
from __future__ import annotations

import threading
from typing import Dict, Iterable, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

# Default latency buckets in microseconds: a 1-2-5 series from 1 us to 10 s.
# Fixed (not adaptive) so histograms merge across processes and snapshots.
LATENCY_BUCKETS_US: Tuple[float, ...] = tuple(
    m * 10 ** e for e in range(7) for m in (1.0, 2.0, 5.0)) + (1e7,)


def _label_key(labels: Dict[str, object]) -> LabelKey:
    """Canonical hashable label set; values stringified so a snapshot's JSON
    round-trip reproduces the same keys."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotone accumulator.  `inc` is atomic under the instrument's lock."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: float = 0.0):
        self._lock = threading.Lock()
        self._value = value    # guarded-by: _lock

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            v = self._value
        return int(v) if float(v).is_integer() else v


class Gauge:
    """Instantaneous value (queue depth, reservoir fill, error bound)."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: float = 0.0):
        self._lock = threading.Lock()
        self._value = value    # guarded-by: _lock

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def max(self, v: float) -> None:
        """High-water-mark update (e.g. max queue depth)."""
        with self._lock:
            if v > self._value:
                self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            v = self._value
        return int(v) if float(v).is_integer() else v


class Histogram:
    """Fixed-bucket histogram with exact count/sum/min/max.

    Percentiles interpolate linearly inside the winning bucket (standard
    Prometheus-style estimation); min/max clamp the ends so p50 of a
    single-observation histogram is that observation.
    """

    __slots__ = ("_lock", "_le", "_counts", "count", "sum", "_min", "_max")

    def __init__(self, buckets: Optional[Iterable[float]] = None):
        # RLock: summary() holds it across its percentile() calls
        self._lock = threading.RLock()
        self._le = tuple(sorted(buckets)) if buckets is not None \
            else LATENCY_BUCKETS_US                # guarded-by: _lock
        self._counts = [0] * (len(self._le) + 1)   # guarded-by: _lock
        self.count = 0                             # guarded-by: _lock
        self.sum = 0.0                             # guarded-by: _lock
        self._min = float("inf")                   # guarded-by: _lock
        self._max = float("-inf")                  # guarded-by: _lock

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            # the bucket search must read _le under the lock: _load() can
            # swap _le/_counts for a restored bucket layout, and an index
            # computed against the old _le can land out of range (or in the
            # wrong bucket) of the new _counts
            # (bisect without the import: bucket lists are short, 22 entries)
            i = 0
            for le in self._le:
                if v <= le:
                    break
                i += 1
            self._counts[i] += 1
            self.count += 1
            self.sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def mean(self) -> float:
        with self._lock:
            return self.sum / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """Interpolated p-quantile (p in [0, 1]) from the bucket counts."""
        with self._lock:
            if self.count == 0:
                return 0.0
            rank = p * self.count
            acc = 0
            for i, c in enumerate(self._counts):
                if c == 0:
                    continue
                lo = self._le[i - 1] if i > 0 else max(0.0, self._min)
                hi = self._le[i] if i < len(self._le) else self._max
                if acc + c >= rank:
                    frac = (rank - acc) / c
                    est = lo + frac * (hi - lo)
                    return min(max(est, self._min), self._max)
                acc += c
            return self._max

    def summary(self) -> Dict[str, float]:
        # one lock scope for the whole row (the lock is re-entrant, so the
        # nested percentile() calls are fine): a concurrent observe cannot
        # produce a summary whose count and percentiles disagree
        with self._lock:
            return {
                "count": self.count, "sum": self.sum, "mean": self.mean,
                "min": self._min if self.count else 0.0,
                "max": self._max if self.count else 0.0,
                "p50": self.percentile(0.50), "p95": self.percentile(0.95),
                "p99": self.percentile(0.99),
            }

    def _dump(self) -> Dict[str, object]:
        with self._lock:
            return {"le": list(self._le), "counts": list(self._counts),
                    "count": self.count, "sum": self.sum,
                    "min": self._min if self.count else None,
                    "max": self._max if self.count else None}

    def _load(self, d: Dict[str, object]) -> None:
        with self._lock:
            self._le = tuple(float(x) for x in d["le"])
            self._counts = [int(c) for c in d["counts"]]
            self.count = int(d["count"])
            self.sum = float(d["sum"])
            self._min = float("inf") if d.get("min") is None else float(d["min"])
            self._max = float("-inf") if d.get("max") is None else float(d["max"])


class MetricsRegistry:
    """Keyed metric collection: one instrument per (name, label set).

    Instruments are created on first touch and never removed, so counters
    from retired components (e.g. a closed `AqpSession`) keep contributing
    to aggregates — the store-level admission stats were previously dropped
    when a session was garbage-collected.
    """

    def __init__(self):
        self._lock = threading.Lock()
        # name -> {label key -> instrument}.  (writes): the table attributes
        # are never rebound after __init__; readers only pass the reference
        # into _get/_collect, which do all dict mutation under the lock.
        self._counters: Dict[str, Dict[LabelKey, Counter]] = {}      # guarded-by: _lock (writes)
        self._gauges: Dict[str, Dict[LabelKey, Gauge]] = {}          # guarded-by: _lock (writes)
        self._histograms: Dict[str, Dict[LabelKey, Histogram]] = {}  # guarded-by: _lock (writes)

    def _get(self, table, name: str, labels: Dict[str, object], factory):
        key = _label_key(labels)
        with self._lock:
            by_label = table.setdefault(name, {})
            inst = by_label.get(key)
            if inst is None:
                inst = by_label[key] = factory()
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(self._counters, name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(self._gauges, name, labels, Gauge)

    def histogram(self, name: str, buckets: Optional[Iterable[float]] = None,
                  **labels) -> Histogram:
        return self._get(self._histograms, name, labels,
                         lambda: Histogram(buckets))

    # -- aggregation (the stats()-view API) ----------------------------------

    def _collect(self, table, name: str, match: Dict[str, object]
                 ) -> List[Tuple[Dict[str, str], object]]:
        want = {str(k): str(v) for k, v in match.items()}
        with self._lock:
            items = list(table.get(name, {}).items())
        out = []
        for key, inst in items:
            labels = dict(key)
            if all(labels.get(k) == v for k, v in want.items()):
                out.append((labels, inst))
        return out

    def collect_counters(self, name: str, **match):
        return [(lb, c.value) for lb, c in
                self._collect(self._counters, name, match)]

    def collect_gauges(self, name: str, **match):
        return [(lb, g.value) for lb, g in
                self._collect(self._gauges, name, match)]

    def collect_histograms(self, name: str, **match):
        return [(lb, h) for lb, h in
                self._collect(self._histograms, name, match)]

    def sum_counter(self, name: str, **match) -> float:
        total = sum(v for _lb, v in self.collect_counters(name, **match))
        return int(total) if float(total).is_integer() else total

    def sum_gauge(self, name: str, **match) -> float:
        total = sum(v for _lb, v in self.collect_gauges(name, **match))
        return int(total) if float(total).is_integer() else total

    def sum_histogram(self, name: str, **match) -> Tuple[float, int]:
        """(sum, count) pooled across every matching label set."""
        hs = self.collect_histograms(name, **match)
        return (sum(h.sum for _lb, h in hs), sum(h.count for _lb, h in hs))

    # -- snapshot / durability ----------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        """Plain JSON-safe dict of every instrument: the `--metrics-out`
        export format (counters/gauges as values, histograms as percentile
        summaries)."""
        with self._lock:
            counters = {n: list(t.items()) for n, t in self._counters.items()}
            gauges = {n: list(t.items()) for n, t in self._gauges.items()}
            hists = {n: list(t.items()) for n, t in self._histograms.items()}
        return {
            "counters": {n: [{"labels": dict(k), "value": c.value}
                             for k, c in entries]
                         for n, entries in counters.items()},
            "gauges": {n: [{"labels": dict(k), "value": g.value}
                           for k, g in entries]
                       for n, entries in gauges.items()},
            "histograms": {n: [{"labels": dict(k), **h.summary()}
                               for k, h in entries]
                           for n, entries in hists.items()},
        }

    def state(self) -> Dict[str, object]:
        """Durable JSON-safe state (exact bucket counts, not summaries) —
        rides in the checkpoint manifest so cumulative counters survive a
        restart."""
        with self._lock:
            counters = {n: list(t.items()) for n, t in self._counters.items()}
            gauges = {n: list(t.items()) for n, t in self._gauges.items()}
            hists = {n: list(t.items()) for n, t in self._histograms.items()}
        return {
            "counters": [{"name": n, "labels": dict(k), "value": c.value}
                         for n, entries in counters.items()
                         for k, c in entries],
            "gauges": [{"name": n, "labels": dict(k), "value": g.value}
                       for n, entries in gauges.items()
                       for k, g in entries],
            "histograms": [{"name": n, "labels": dict(k), **h._dump()}
                           for n, entries in hists.items()
                           for k, h in entries],
        }

    def load_state(self, state: Dict[str, object]) -> None:
        """Restore instruments from `state()` output.  Existing instruments
        with the same (name, labels) are overwritten — restore replaces, it
        does not merge (matching `TelemetryStore.restore_state` semantics)."""
        for ent in state.get("counters", ()):
            c = self.counter(str(ent["name"]), **ent.get("labels", {}))
            with c._lock:
                c._value = float(ent["value"])
        for ent in state.get("gauges", ()):
            g = self.gauge(str(ent["name"]), **ent.get("labels", {}))
            g.set(float(ent["value"]))
        for ent in state.get("histograms", ()):
            h = self.histogram(str(ent["name"]), buckets=ent["le"],
                               **ent.get("labels", {}))
            h._load(ent)
