"""`repro.obs` — dependency-free observability for the AQP stack.

Two kinds of instrumentation with different cost profiles:

  * **Always-on**: counters and gauges (`MetricsRegistry`).  These back the
    public `stats()` dicts and cost one lock + one float add per event — no
    gating needed, and keeping them live is what makes the multi-session
    aggregation bug fixable (closed sessions' counters persist in the
    store registry instead of dying with the session weakref).

  * **Gated on `enabled()`**: span tracing, per-path latency histograms
    with `block_until_ready` fencing, and kernel profiling.  Fencing
    changes dispatch behaviour (it synchronises the device), so these are
    opt-in: set ``REPRO_OBS=1`` in the environment or call
    :func:`enable` (e.g. ``serve --mode aqp --metrics-out ...`` does).
    When disabled, `span()` returns a shared no-op object and the kernel
    wrappers take the un-instrumented branch — zero extra jit traces and
    bit-identical numerics, both test-enforced.

Scoping: each `TelemetryStore` owns a registry (`store.metrics`) so tests
and co-hosted stores stay isolated; kernel profiling and benchmarks write
to the process-global registry (`get_registry()`), since kernels have no
store handle.  `export_json` merges any number of registries into one
snapshot file.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Optional, Tuple

from repro import knobs

from .registry import (Counter, Gauge, Histogram, LATENCY_BUCKETS_US,
                       MetricsRegistry)
from .trace import NOOP_SPAN, Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "LATENCY_BUCKETS_US", "MetricsRegistry",
    "NOOP_SPAN", "Span", "Tracer", "disable", "enable", "enabled", "fence",
    "export_json", "get_registry", "get_tracer", "set_tracer", "span",
]

_enabled = knobs.get_bool("REPRO_OBS")
_registry = MetricsRegistry()
_tracer = Tracer()


def enabled() -> bool:
    """True when the expensive instrumentation (tracing, fenced latency
    histograms, kernel profiling) is active."""
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def get_registry() -> MetricsRegistry:
    """The process-global registry (kernel profiling, benchmarks)."""
    return _registry


def get_tracer() -> Tracer:
    return _tracer


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the global tracer (tests inject a fake-clock tracer); returns
    the previous one so callers can restore it."""
    global _tracer
    prev, _tracer = _tracer, tracer
    return prev


def span(name: str, parent: Optional[Tuple[int, int]] = None, **attrs):
    """Open a span on the global tracer, or the shared no-op when disabled.

    The no-op singleton means a disabled `with obs.span(...):` costs one
    function call and no allocation."""
    if not _enabled:
        return NOOP_SPAN
    return _tracer.span(name, parent=parent, **attrs)


def fence(*values) -> None:
    """Block until every jax array in `values` is device-ready, so the
    enclosing span measures real device time rather than dispatch time.
    Non-jax values pass through silently; no-op when disabled."""
    if not _enabled:
        return
    for v in values:
        bur = getattr(v, "block_until_ready", None)
        if bur is not None:
            bur()


def export_json(path: str, *registries: MetricsRegistry,
                extra: Optional[dict] = None) -> dict:
    """Atomically write the merged snapshot of `registries` (default: the
    global one) as JSON; returns the written document.

    Snapshots merge at the metric-name level: later registries win on a
    (name, labels) clash, which cannot happen for the store/global split
    (disjoint metric names)."""
    regs = registries or (_registry,)
    doc = {"ts": time.time(), "counters": {}, "gauges": {}, "histograms": {}}
    for reg in regs:
        snap = reg.snapshot()
        for kind in ("counters", "gauges", "histograms"):
            for name, entries in snap[kind].items():
                doc[kind].setdefault(name, []).extend(entries)
    if extra:
        doc.update(extra)
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            json.dump(doc, f, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return doc
