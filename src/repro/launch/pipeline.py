"""Pipeline parallelism: SPMD microbatch pipeline over a 'pipe' mesh axis.

The assigned production meshes are (data, model) / (pod, data, model), so PP
is OFF in the 40-cell table (DESIGN.md §6) — but the machinery a >70B config
needs is here and tested: a shard_map pipeline where device p holds stage p's
layer block, activations flow stage->stage via `collective_permute`, and
microbatches keep every stage busy after the fill phase (GPipe-style schedule
with the 1F1B-shaped steady state; n_micro + n_stages - 1 ticks total).

    out = spmd_pipeline(stage_fn, stage_params, x_microbatches, mesh, "pipe")

stage_params: pytree with leading axis n_stages, sharded P("pipe", ...).
x_microbatches: (n_micro, mb, ...) replicated input microbatches.
Returns (n_micro, mb, ...) outputs (as produced by the last stage).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def spmd_pipeline(stage_fn, stage_params, xs, mesh: Mesh, axis: str = "pipe"):
    n_stages = mesh.shape[axis]
    n_micro = xs.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def shard_fn(params, xs):
        # inside shard_map: params have a leading axis of size 1 (this
        # device's stage); xs is the full replicated microbatch stack.
        local = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]

        def tick(t, carry):
            recv, outs = carry
            # stage 0 ingests microbatch t (while available); others take the
            # activation handed over by the previous stage last tick.
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            fresh = jax.lax.dynamic_index_in_dim(xs, mb_idx, keepdims=False)
            inp = jnp.where(stage == 0, fresh, recv)
            out = stage_fn(local, inp)
            # last stage commits its result for microbatch (t - n_stages + 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            commit = (stage == n_stages - 1) & (t >= n_stages - 1)
            upd = jnp.where(commit, out, jax.lax.dynamic_index_in_dim(outs, out_idx, keepdims=False))
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, out_idx, 0)
            # hand activations to the next stage
            recv = jax.lax.ppermute(out, axis, perm)
            return recv, outs

        recv0 = compat.pvary(jnp.zeros(mb_shape, xs.dtype), (axis,))
        outs0 = compat.pvary(jnp.zeros_like(xs), (axis,))
        _, outs = jax.lax.fori_loop(0, ticks, tick, (recv0, outs0))
        # only the last stage holds real outputs; broadcast them to all
        # stages so the result is replicated (one psum).
        mask = (jax.lax.axis_index(axis) == n_stages - 1).astype(xs.dtype)
        return jax.lax.psum(outs * mask, axis)

    param_specs = jax.tree.map(lambda _: P(axis), stage_params)
    f = compat.shard_map(shard_fn, mesh=mesh, in_specs=(param_specs, P()),
                      out_specs=P())
    return f(stage_params, xs)
