import os

from repro import knobs   # stdlib-only import: safe before jax initialises

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + str(knobs.get_int("REPRO_DRYRUN_DEVICES")))
# ^ MUST run before anything imports jax: it locks the device count on
# first init.  Tests shrink the placeholder count via REPRO_DRYRUN_DEVICES.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract memory / cost / roofline artifacts.

  PYTHONPATH=src python -m repro.launch.dryrun --all            # single pod 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod  # 2x16x16
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k

Outputs one JSON record per cell (appended to --out JSONL) with:
  memory_analysis (per-device bytes), raw cost_analysis, trip-corrected HLO
  dot-FLOPs, per-device collective bytes by kind, analytic MODEL_FLOPS and
  HBM bytes, and the three roofline terms.
"""
import argparse
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import roofline
from repro import compat
from repro.configs.base import (ARCH_IDS, SHAPES, SHAPES_BY_NAME, cell_runnable,
                                get_config)
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import (batch_specs, batch_struct, build_model,
                          cache_specs_with_dp, decode_struct,
                          param_specs_with_dp, param_structs)
from repro.optim import adamw
from repro.train import make_train_step


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def default_n_micro(shape, dp_total: int) -> int:
    if shape.kind != "train":
        return 1
    # one-to-two sequences per device per microbatch
    n = max(1, shape.global_batch // max(dp_total, 1) // 2)
    while shape.global_batch % n:
        n -= 1
    return n


def _fix_batch_specs(cfg, shape, dp):
    """Replicate the batch when it cannot shard over dp (e.g. long_500k B=1)."""
    import numpy as np
    specs = batch_specs(cfg, dp)
    if shape.global_batch == 1:
        specs = jax.tree.map(lambda s: P(*([None] * len(s))), specs,
                             is_leaf=lambda s: isinstance(s, P))
    return specs


def lower_cell(arch: str, shape_name: str, mesh, *, n_micro=None,
               serve_window: int = 0, gather_once: bool = False,
               remat_policy: str = ""):
    """Returns (lowered, compiled, meta) for one cell on `mesh`."""
    import dataclasses
    cfg = get_config(arch)
    if remat_policy:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    shape = SHAPES_BY_NAME[shape_name]
    model = build_model(cfg)
    dp = dp_axes(mesh)
    dp_total = math.prod(mesh.shape[a] for a in dp)
    chips = mesh.devices.size
    meta = {"arch": arch, "shape": shape_name, "mesh": dict(mesh.shape),
            "chips": chips}

    if shape.kind == "train":
        n_micro = n_micro or default_n_micro(shape, dp_total)
        meta["n_micro"] = n_micro
        p_struct = param_structs(cfg)
        p_specs = param_specs_with_dp(model, "train", dp)
        o_struct = jax.eval_shape(adamw.init, p_struct)
        o_specs = adamw.state_specs(p_specs)
        b_struct = batch_struct(cfg, shape)
        b_specs = _fix_batch_specs(cfg, shape, dp)
        # H1 (gather FSDP weights once per step) is the default whenever the
        # TP-only-resident weights + fp32 grad accumulator fit HBM; models
        # above ~20B params (qwen3-moe 235B) must keep per-microbatch FSDP.
        if cfg.param_count() < 20e9:
            gather_once = True
        constraint = None
        if gather_once:
            meta["gather_once"] = True
            constraint = _ns(mesh, param_specs_with_dp(model, "serve", dp))
        step = make_train_step(model, adamw.AdamWConfig(), n_micro,
                               param_constraint=constraint)
        fn = jax.jit(step, in_shardings=(_ns(mesh, p_specs), _ns(mesh, o_specs),
                                         _ns(mesh, b_specs)),
                     donate_argnums=(0, 1))   # params/opt buffers are reused
        with mesh:
            lowered = fn.lower(p_struct, o_struct, b_struct)
    elif shape.kind == "prefill":
        meta["n_micro"] = 1
        p_struct = param_structs(cfg)
        p_specs = param_specs_with_dp(model, "serve", dp)
        b_struct = batch_struct(cfg, shape)
        b_struct.pop("labels", None)
        b_specs = _fix_batch_specs(cfg, shape, dp)
        b_specs.pop("labels", None)
        fn = jax.jit(lambda p, b: model.prefill(p, b),
                     in_shardings=(_ns(mesh, p_specs), _ns(mesh, b_specs)))
        with mesh:
            lowered = fn.lower(p_struct, b_struct)
    else:  # decode
        meta["n_micro"] = 1
        p_struct = param_structs(cfg)
        p_specs = param_specs_with_dp(model, "serve", dp)
        tok_struct, cache_struct, pos_struct = decode_struct(cfg, shape)
        c_specs = cache_specs_with_dp(model, dp, batch_size=shape.global_batch)
        tok_spec = P(dp if len(dp) > 1 else dp[0], None) if shape.global_batch > 1 else P(None, None)
        kw = {}
        if cfg.family in ("hybrid",) and cfg.sliding_window and shape.seq_len > cfg.sliding_window:
            kw["window"] = cfg.sliding_window

        def step(p, c, t, pos):
            return model.decode_step(p, c, t, pos, **kw) if kw else model.decode_step(p, c, t, pos)

        fn = jax.jit(step, in_shardings=(_ns(mesh, p_specs), _ns(mesh, c_specs),
                                         NamedSharding(mesh, tok_spec),
                                         NamedSharding(mesh, P())),
                     donate_argnums=(1,))     # KV/state cache updated in place
        with mesh:
            lowered = fn.lower(p_struct, cache_struct, tok_struct, pos_struct)

    compiled = lowered.compile()
    return lowered, compiled, meta


def analyse_kde_cell(mesh, n: int = 1_048_576, d: int = 4, n_h: int = 150,
                     chunk: int = 64, algorithm: str = "mxu") -> dict:
    """Roofline record for the paper's own technique on the production mesh:
    distributed LSCV_h (fused grid) over every chip."""
    from repro.core.distributed import sharded_lscv_h_grid
    from repro.core import gaussian as G

    chips = mesh.devices.size
    c_k, c_kk, _ = G.lscv_h_consts(d, 1.0)
    h_grid = jnp.linspace(0.05, 0.8, n_h, dtype=jnp.float32)

    def fn(x, sigma_inv):
        return sharded_lscv_h_grid(x, sigma_inv, h_grid, c_k, c_kk, mesh, chunk,
                                   algorithm=algorithm)

    rep = NamedSharding(mesh, P())
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=(rep, rep)).lower(
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32))
        compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    hlo = roofline.HloCostModel(compiled.as_text())
    dot_flops_dev = hlo.dot_flops()
    coll_bytes_dev, coll_by_kind = hlo.collective_bytes()
    pairs = n * (n - 1) / 2
    # quadform 2 MACs/dim^2-ish + ~8 flops per (pair, h) for the two exps
    mf = pairs * (4.0 * d * d + 8.0 * n_h)
    hbm = n * d * 4.0 * (pairs / (chunk * n))   # x re-read per row-chunk slab
    t = roofline.terms(mf, hbm, coll_bytes_dev, chips)
    return {
        "arch": "kde_lscv_h", "shape": f"n{n}_d{d}_nh{n_h}_{algorithm}",
        "mesh": dict(mesh.shape), "chips": chips, "ok": True,
        "compile_s": round(t_compile, 2),
        "memory": {"argument_gb_per_dev": mem.argument_size_in_bytes / 1e9,
                   "output_gb_per_dev": mem.output_size_in_bytes / 1e9,
                   "temp_gb_per_dev": mem.temp_size_in_bytes / 1e9,
                   "alias_gb_per_dev": mem.alias_size_in_bytes / 1e9},
        "hlo_dot_flops_per_dev": dot_flops_dev,
        "collective_bytes_per_dev": coll_bytes_dev,
        "collective_by_kind": coll_by_kind,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(dot_flops_dev * chips, 1.0),
        "analytic_hbm_bytes": hbm,
        "roofline": t,
    }


def analyse_cell(arch: str, shape_name: str, mesh, *, n_micro=None,
                 gather_once: bool = False, remat_policy: str = "") -> dict:
    t0 = time.time()
    lowered, compiled, meta = lower_cell(arch, shape_name, mesh, n_micro=n_micro,
                                         gather_once=gather_once,
                                         remat_policy=remat_policy)
    t_compile = time.time() - t0

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    chips = meta["chips"]

    mem = compiled.memory_analysis()
    ca = compat.cost_analysis_dict(compiled)
    hlo = roofline.HloCostModel(compiled.as_text())
    dot_flops_dev = hlo.dot_flops()                      # per-device, trip-corrected
    coll_bytes_dev, coll_by_kind = hlo.collective_bytes()

    mf = roofline.model_flops(cfg, shape)
    hbm = roofline.hbm_bytes(cfg, shape, meta.get("n_micro", 1))
    t = roofline.terms(dot_flops_dev * chips, hbm, coll_bytes_dev, chips)

    rec = dict(meta)
    rec.update({
        "ok": True,
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_gb_per_dev": mem.argument_size_in_bytes / 1e9,
            "output_gb_per_dev": mem.output_size_in_bytes / 1e9,
            "temp_gb_per_dev": mem.temp_size_in_bytes / 1e9,
            "alias_gb_per_dev": mem.alias_size_in_bytes / 1e9,
        },
        "cost_raw": {"flops_per_dev": ca.get("flops"),
                     "bytes_accessed_per_dev": ca.get("bytes accessed")},
        "hlo_dot_flops_per_dev": dot_flops_dev,
        "hlo_dot_flops_global": dot_flops_dev * chips,
        "collective_bytes_per_dev": coll_bytes_dev,
        "collective_by_kind": coll_by_kind,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(dot_flops_dev * chips, 1.0),
        "analytic_hbm_bytes": hbm,
        "roofline": t,
    })
    return rec


def run(args) -> int:
    if args.mesh_shape:
        from repro.launch.mesh import make_mesh_from_spec
        mesh = make_mesh_from_spec(args.mesh_shape)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                ok, why = cell_runnable(a, s.name)
                if ok:
                    cells.append((a, s.name))
                else:
                    print(f"SKIP {a} x {s.name}: {why}", flush=True)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps({"arch": a, "shape": s.name,
                                                "mesh": dict(mesh.shape),
                                                "ok": False, "skipped": True,
                                                "reason": why}) + "\n")
    else:
        cells = [(args.arch, args.shape)]

    mesh_tag = "x".join(str(s) for s in mesh.devices.shape)
    if args.kde:
        parts = args.kde.split(",")
        n, d, n_h = int(parts[0]), int(parts[1]), int(parts[2])
        alg = parts[3] if len(parts) > 3 else "mxu"
        rec = analyse_kde_cell(mesh, n, d, n_h, algorithm=alg)
        print(f"PASS kde_lscv_h n{n} d{d} nh{n_h} x {mesh_tag}: "
              f"compile={rec['compile_s']}s dom={rec['roofline']['dominant']} "
              f"compute={rec['roofline']['compute_s']:.2e}s", flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
        return 0

    failures = 0
    for arch, shape_name in cells:
        tag = f"{arch} x {shape_name} x {mesh_tag}"
        try:
            rec = analyse_cell(arch, shape_name, mesh, n_micro=args.n_micro,
                               gather_once=args.gather_once,
                               remat_policy=args.remat_policy)
            print(f"PASS {tag}: compile={rec['compile_s']}s "
                  f"arg/dev={rec['memory']['argument_gb_per_dev']:.2f}GB "
                  f"temp/dev={rec['memory']['temp_gb_per_dev']:.2f}GB "
                  f"dom={rec['roofline']['dominant']}", flush=True)
        except Exception as e:  # noqa: BLE001 - report and continue
            failures += 1
            rec = {"arch": arch, "shape": shape_name, "mesh": dict(mesh.shape),
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"FAIL {tag}: {type(e).__name__}: {str(e)[:300]}", flush=True)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCH_IDS))
    ap.add_argument("--shape", default="train_4k", choices=[s.name for s in SHAPES])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--mesh-shape", default="",
                    help="override mesh, e.g. '4,2' or '2,4,2' (tests)")
    ap.add_argument("--gather-once", action="store_true",
                    help="H1: one FSDP weight all-gather per step, not per microbatch")
    ap.add_argument("--remat-policy", default="", choices=["", "nothing", "dots", "dots_full"],
                    help="H2: per-layer remat policy override")
    ap.add_argument("--kde", default="",
                    help="lower the paper's distributed LSCV_h instead: 'n,d,n_h'")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    raise SystemExit(1 if run(args) else 0)


if __name__ == "__main__":
    main()
