"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod' axis.

    The dry-run container exposes 512 placeholder devices; the single-pod mesh
    uses the first 256 of them.
    """
    import math
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_mesh_from_spec(spec: str):
    """'16,16' -> (data, model); '2,16,16' -> (pod, data, model).  For tests
    with a reduced placeholder device count."""
    import math
    shape = tuple(int(x) for x in spec.split(","))
    axes = ("pod", "data", "model")[-len(shape):]
    n = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Mesh axes that carry data parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_size(mesh) -> int:
    return mesh.shape["model"]
