"""Serving drivers.

LM mode (default): prefill + greedy decode on a smoke config.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --batch 4 --prompt-len 16 --max-new 16

AQP mode: a long-lived admission loop over a TelemetryStore.  Concurrent
query clients submit heterogeneous AqpQuery specs — 1-D ranges, multi-column
box predicates (eq. 11), categorical equality on a dictionary column — into
one `AqpSession` while a producer keeps streaming telemetry batches into the
store (bumping synopsis versions mid-flight).  The session coalesces specs
across clients into micro-batches keyed by (column tuple, selector, synopsis
version) and flushes them on a batch-size watermark or max-delay deadline;
the summary reports queue depth, flush reasons, per-flush batch sizes, and
version invalidations.

    PYTHONPATH=src python -m repro.launch.serve --mode aqp \
        --rows 200000 --clients 8 --per-client 150 --max-delay-ms 5 \
        --selector plugin

The loop is *restartable*: `--snapshot-dir` makes the producer write atomic
keep-k store snapshots (reservoirs + RNG states, sketches, fitted synopses)
every `--snapshot-every` streamed batches, and `--restore` warm-starts from
the latest snapshot instead of re-seeding — the exact categorical path stays
active (sketch coverage survives) and no synopsis is refitted.  `--max-pending`
bounds the admission queue (block or shed, `--overflow`).

    PYTHONPATH=src python -m repro.launch.serve --mode aqp \
        --snapshot-dir /tmp/aqp-snap --snapshot-every 5 --restore

Observability: `--metrics-out FILE` enables `repro.obs` (span tracing,
fenced per-path latency histograms, kernel profiling), exports a merged
JSON snapshot of the store and kernel registries every `--metrics-every`
seconds (atomic replace — a scraper never reads a torn file), and prints an
end-of-run summary table; `--trace-out FILE` appends the span ring as JSON
lines on exit.  See docs/observability.md for the metric catalogue.

    PYTHONPATH=src python -m repro.launch.serve --mode aqp \
        --metrics-out /tmp/aqp-metrics.json --metrics-every 0.5
"""
from __future__ import annotations

import argparse
import time


def run_lm(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.train import greedy_generate

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (args.batch, args.prompt_len),
                                0, cfg.vocab_size, jnp.int32)
    t0 = time.time()
    out = greedy_generate(model, params, prompt, args.max_new)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print(out[0].tolist())


def make_query_mix(n_queries: int, ranges, seed: int = 0):
    """Deterministic mixed COUNT/SUM/AVG batch.  `ranges` maps column name
    (or None for a single-synopsis batch) -> (lo, hi) sampling range.  Shared
    by the serving mode, the AQP example, and the batch benchmark."""
    import numpy as np

    from repro.core import Query

    rng = np.random.default_rng(seed)
    columns = list(ranges)
    ops = ["count", "sum", "avg"]
    queries = []
    for i in range(n_queries):
        col = columns[i % len(columns)]
        lo, hi = ranges[col]
        a = float(rng.uniform(lo, hi))
        b = float(rng.uniform(a, hi))
        # independent draw, not i % 3: cycling op and column together would
        # make every column's queries homogeneous when len(ranges) % 3 == 0
        queries.append(Query(ops[int(rng.integers(3))], a, b, column=col))
    return queries


def make_box_query_mix(n_queries: int, columns, ranges, seed: int = 0):
    """Deterministic mixed COUNT/SUM/AVG *box* batch over one column tuple.
    `columns` is the joint tuple; `ranges` maps each column -> (lo, hi)
    sampling range.  SUM/AVG target a random axis.  Shared by the serving
    mode, the AQP example, and the box benchmark."""
    import numpy as np

    from repro.core import BoxQuery

    rng = np.random.default_rng(seed)
    columns = tuple(columns)
    ops = ["count", "sum", "avg"]
    queries = []
    for _ in range(n_queries):
        lo, hi = [], []
        for col in columns:
            c_lo, c_hi = ranges[col]
            a = float(rng.uniform(c_lo, c_hi))
            lo.append(a)
            hi.append(float(rng.uniform(a, c_hi)))
        op = ops[int(rng.integers(3))]
        target = columns[int(rng.integers(len(columns)))] if op != "count" else None
        queries.append(BoxQuery(op, tuple(lo), tuple(hi), columns=columns,
                                target=target))
    return queries


def make_mixed_aqp_queries(n_queries: int, ranges, joint_cols, cat_col,
                           cat_values, n_boxes: int = None, seed: int = 0,
                           fullh_frac: float = 0.0):
    """Deterministic heterogeneous AqpQuery batch: 1-D ranges over every
    numeric column, eq. 11 boxes over `joint_cols`, and categorical Eq terms
    on `cat_col`.  `fullh_frac` of the boxes carry a per-query
    selector="lscv_H" override, routing them through the full-H QMC path
    (and the engine's density backend).  Shared by the serving mode and
    bench_aqp_engine."""
    import numpy as np

    from repro.core import AqpQuery, Box, Eq, Range

    rng = np.random.default_rng(seed)
    columns = [c for c in ranges if c not in (cat_col,)]
    ops = ["count", "sum", "avg"]
    if n_boxes is None:
        n_boxes = n_queries // 4
    n_eq = n_queries // 8 if cat_col is not None else 0
    queries = []
    for i in range(n_queries):
        op = ops[int(rng.integers(3))]
        if i % 4 == 1 and n_boxes > 0:
            n_boxes -= 1
            lo, hi = [], []
            for col in joint_cols:
                c_lo, c_hi = ranges[col]
                a = float(rng.uniform(c_lo, c_hi))
                lo.append(a)
                hi.append(float(rng.uniform(a, c_hi)))
            tgt = joint_cols[int(rng.integers(len(joint_cols)))]
            queries.append(AqpQuery(
                op, (Box(tuple(joint_cols), tuple(lo), tuple(hi)),),
                target=None if op == "count" else tgt,
                selector="lscv_H" if rng.random() < fullh_frac else None))
        elif i % 8 == 3 and n_eq > 0:
            n_eq -= 1
            queries.append(AqpQuery(
                "count", (Eq(cat_col, float(rng.choice(cat_values))),)))
        else:
            col = columns[i % len(columns)]
            lo, hi = ranges[col]
            a = float(rng.uniform(lo, hi))
            queries.append(AqpQuery(
                op, (Range(col, a, float(rng.uniform(a, hi)))),
                target=None if op == "count" else col))
    return queries


def _make_telemetry(rng, n):
    import numpy as np

    return {
        "loss": rng.gamma(3.0, 0.7, n).astype(np.float32),
        "latency_ms": np.where(rng.random(n) < 0.8, rng.normal(40, 8, n),
                               rng.normal(160, 30, n)).astype(np.float32),
        "seq_len": rng.integers(16, 2048, n).astype(np.float32),
        # dictionary-coded categorical column (e.g. which model variant
        # served the request): unit-spaced codes, served by Eq terms
        "model_id": rng.integers(0, 4, n).astype(np.float32),
    }


def _print_metrics_summary(store) -> None:
    """End-of-run metrics table: latency histograms, caches, flush mix."""
    from repro import obs

    rows = []
    for labels, h in store.metrics.collect_histograms("aqp.query.latency_us"):
        tag = labels.get("path", "?")
        if labels.get("tier") not in (None, "None"):
            tag += f"@t{labels['tier']}"
        rows.append((tag, h.summary()))
    for labels, h in obs.get_registry().collect_histograms("kernel.wall_us"):
        rows.append((f"kernel:{labels.get('kernel', '?')}", h.summary()))
    if rows:
        print(f"[serve:aqp] {'metric':<28s} {'count':>7s} {'p50us':>9s} "
              f"{'p95us':>9s} {'p99us':>9s} {'maxus':>9s}")
        for tag, s in sorted(rows, key=lambda r: -r[1]["count"]):
            print(f"[serve:aqp] {tag:<28s} {s['count']:>7d} {s['p50']:>9.1f} "
                  f"{s['p95']:>9.1f} {s['p99']:>9.1f} {s['max']:>9.1f}")
    hits = store.metrics.sum_counter("aqp.cache.hits")
    misses = store.metrics.sum_counter("aqp.cache.misses")
    phits = store.metrics.sum_counter("aqp.plan.hits")
    pmisses = store.metrics.sum_counter("aqp.plan.misses")
    print(f"[serve:aqp] metrics: synopsis cache hit rate "
          f"{hits / max(1, hits + misses):.1%}, plan cache hit rate "
          f"{phits / max(1, phits + pmisses):.1%}, ingested "
          f"{store.metrics.sum_counter('aqp.ingest.batches')} batches")


def run_aqp(args) -> None:
    import threading
    from collections import Counter

    import numpy as np

    from repro import obs
    from repro.core import AqpQuery, Range
    from repro.data import TelemetryStore

    if args.metrics_out or args.trace_out:
        # spans + fenced latency histograms + kernel profiling for this run
        obs.enable()

    rng = np.random.default_rng(0)
    n = args.rows
    joint_cols = ("loss", "latency_ms")
    restored_step = None
    if args.restore:
        if not args.snapshot_dir:
            raise SystemExit("--restore needs --snapshot-dir")
        from repro.checkpoint import CheckpointManager
        restored_step = CheckpointManager(args.snapshot_dir,
                                          async_save=False).latest_step()
        if restored_step is None:
            raise SystemExit(f"--restore: no completed snapshots under "
                             f"{args.snapshot_dir!r}")
        # warm start: reservoirs, sketches (exact coverage intact), joint
        # registrations, and fitted synopses all come back from the snapshot
        store = TelemetryStore.load(args.snapshot_dir)
        n = max(res.n_seen for res in store.columns.values())
    else:
        telemetry = _make_telemetry(rng, n)
        store = TelemetryStore(capacity=args.capacity, seed=0)
        # tiered ladders (before add_batch, like joints): tier 0 serves the
        # "coarse" priority class, the top tier IS the full sample
        store.track_tiered("loss", n_tiers=4)
        store.track_tiered("latency_ms", n_tiers=4)
        store.track_tiered(joint_cols, n_tiers=4)   # joints sample whole rows
        store.track_categorical("model_id")  # exact per-code counts for Eq terms
        store.add_batch(telemetry)
        # registering after add_batch backfills from the per-column reservoirs
        store.track_joint(("model_id", "latency_ms"))
    # query-mix sampling ranges come from the reservoir samples (not the raw
    # stream) on BOTH paths, so a restarted process regenerates the exact
    # same client query stream as the run that wrote the snapshot — with a
    # quiescent producer, the printed sample rows are bit-identical across
    # the restart
    ranges = {c: (float(s.min()), float(s.max()))
              for c, s in ((c, store.columns[c].sample())
                           for c in store.columns if c != "model_id")}
    engine = store.engine(selector=args.selector, backend=args.backend)
    # per-engine default for full-H density evaluation: "exact" pins the
    # reference path, "rff" forces the sublinear synopsis, "auto" crosses
    # over by fitted-sample size (REPRO_KDE_CROSSOVER)
    engine.kde_backend = args.kde_backend

    # Closed-loop clients hold one outstanding query each, so a bucket can
    # never exceed the client count: a deeper watermark would leave every
    # flush to the deadline and cap throughput at clients/max_delay.
    watermark = args.watermark if args.watermark is not None \
        else max(2, args.clients)

    # Warm-up fits the synopses (cache miss) and compiles the batched passes
    # near the flush shapes, so the timed loop measures steady state.
    warm = make_mixed_aqp_queries(
        max(watermark, 64), ranges, joint_cols, "model_id",
        (0.0, 1.0, 2.0, 3.0), seed=99, fullh_frac=args.fullh_frac)
    engine.execute(warm)
    if args.coarse_frac > 0:
        # coarse traffic answers from tier 0: fit those synopses too
        engine.run_compiled(engine.compile(warm), tier=0)

    session = engine.session(watermark=watermark,
                             max_delay=args.max_delay_ms / 1e3,
                             max_pending=args.max_pending,
                             overflow=args.overflow)
    per_client: dict = {}
    results_lock = threading.Lock()
    stop_producer = threading.Event()
    snapshots = [0]

    stop_metrics = threading.Event()
    exports = [0]

    def export_metrics() -> None:
        obs.export_json(args.metrics_out, store.metrics, obs.get_registry(),
                        extra={"mode": "aqp", "rows": int(n)})
        exports[0] += 1

    def metrics_writer() -> None:
        while not stop_metrics.wait(args.metrics_every):
            export_metrics()

    mthread = None
    if args.metrics_out:
        mthread = threading.Thread(target=metrics_writer, daemon=True)
        mthread.start()

    if args.snapshot_dir and not args.restore:
        # a restartable loop snapshots at startup too: --restore works even
        # if the process dies before the producer's first cadence tick
        store.save(args.snapshot_dir)
        snapshots[0] += 1

    def client(ci: int) -> None:
        specs = make_mixed_aqp_queries(
            args.per_client, ranges, joint_cols, "model_id",
            (0.0, 1.0, 2.0, 3.0), seed=10 + ci,
            fullh_frac=args.fullh_frac)
        crng = np.random.default_rng(500 + ci)
        got = []
        for q in specs:                       # closed loop: 1 outstanding
            priority = "coarse" if crng.random() < args.coarse_frac \
                else None                     # None -> the session default
            got.append(session.submit(q, priority=priority).result())
        with results_lock:
            per_client[ci] = got

    def producer() -> None:
        # keep streaming telemetry while queries are in flight: every batch
        # bumps reservoir versions, re-keying pending micro-batches
        prng = np.random.default_rng(1234)
        batches = 0
        while not stop_producer.wait(args.stream_every_ms / 1e3):
            store.add_batch(_make_telemetry(prng, args.stream_rows))
            batches += 1
            if args.snapshot_dir and batches % args.snapshot_every == 0:
                store.save(args.snapshot_dir)   # atomic keep-k, under the
                snapshots[0] += 1               # store's write lock

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(args.clients)]
    prod = threading.Thread(target=producer, daemon=True)
    depth_samples = []
    t0 = time.perf_counter()
    prod.start()
    for t in threads:
        t.start()
    while any(t.is_alive() for t in threads):
        depth_samples.append(session.pending)
        time.sleep(0.002)
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    stop_producer.set()
    prod.join(timeout=2.0)
    session.close()
    if args.metrics_out:
        stop_metrics.set()
        if mthread is not None:
            mthread.join(timeout=2.0)
        export_metrics()    # final snapshot includes the closing flush
    if args.trace_out:
        obs.get_tracer().export_jsonl(args.trace_out)

    # client order (not thread finish order): the sample rows below are
    # reproducible run-to-run when the producer is quiescent
    results = [r for ci in sorted(per_client) for r in per_client[ci]]
    st = session.stats()
    cs = store.cache.stats()
    paths = Counter(r.path for r in results)
    qps = len(results) / dt
    print(f"[serve:aqp] {len(results)} mixed queries from {args.clients} "
          f"concurrent clients over {len(store.columns)} columns "
          f"({n:,} seed rows) "
          f"in {dt * 1e3:.1f} ms -> {qps:,.0f} queries/s [{args.backend}]")
    if restored_step is not None:
        print(f"[serve:aqp] durability: warm-started from snapshot step "
              f"{restored_step} ({args.snapshot_dir}) — no refit, sketch "
              f"coverage intact")
    if args.snapshot_dir:
        print(f"[serve:aqp] durability: {snapshots[0]} snapshots written to "
              f"{args.snapshot_dir} (every {args.snapshot_every} streamed "
              f"batches, keep-3)")
    print(f"[serve:aqp] admission: {st['flushes']} flushes "
          f"(reasons: " + ", ".join(f"{k}={v}" for k, v
                                    in sorted(st['flush_reasons'].items()))
          + f"), mean batch {st['mean_batch']:.1f}, "
          f"{st['coalesced']} coalesced, "
          f"{st['invalidations']} version invalidations"
          + (f", backpressure: {st['blocked']} blocked, {st['shed']} shed "
             f"(max_pending={st['max_pending']})"
             if st["max_pending"] is not None else "")
          + (", priorities: " + ", ".join(
              f"{k}={v}" for k, v in sorted(st["priorities"].items()))
             if st["priorities"] else ""))
    if depth_samples:
        print(f"[serve:aqp] queue depth: max {max(depth_samples)}, "
              f"mean {sum(depth_samples) / len(depth_samples):.1f} "
              f"({len(depth_samples)} samples); "
              f"plan cache {st['plan_cache']['hits']} hits / "
              f"{st['plan_cache']['misses']} misses")
    print(f"[serve:aqp] execution paths: "
          + ", ".join(f"{p}={c}" for p, c in sorted(paths.items())))
    print(f"[serve:aqp] synopsis cache: {cs['hits']} hits / {cs['misses']} misses "
          f"({cs['entries']} entries, {cs['bytes']:,} bytes, "
          f"{cs['evictions']} evictions)")
    bf = store.stats()["backfilled"]
    print(f"[serve:aqp] joints: " + ", ".join(
        f"{k} ({'backfilled' if v else 'streamed'})" for k, v in bf.items()))
    cat = store.stats()["categoricals"].get("model_id", {})
    print(f"[serve:aqp] model_id sketch: {cat.get('codes', 0)} codes, "
          f"{cat.get('rows', 0):,} rows, "
          f"exact={'yes' if cat.get('exact') else 'no (KDE fallback)'}")
    if args.metrics_out:
        print(f"[serve:aqp] metrics: {exports[0]} snapshots -> "
              f"{args.metrics_out} (every {args.metrics_every:g}s)")
        _print_metrics_summary(store)
    if args.trace_out:
        print(f"[serve:aqp] traces: span ring appended to {args.trace_out}")
    for r in results[:6]:
        q = r.query
        terms = " & ".join(
            f"{t.column}={t.value:.0f}" if hasattr(t, "value")
            else (f"[{t.a:.1f},{t.b:.1f}] {t.column}" if hasattr(t, "a")
                  else " & ".join(f"{a:.1f}<={c}<={b:.1f}"
                                  for c, a, b in zip(t.columns, t.lo, t.hi)))
            for t in q.predicates)
        ci = "exact" if r.ci_lo == r.ci_hi \
            else f"±{(r.ci_hi - r.ci_lo) / 2:,.1f} @{r.ci_level:.0%}"
        print(f"  {q.aggregate.upper():5s} WHERE {terms} ~= {r.estimate:,.2f} "
              f"[{r.path}, {ci}, n_eff {r.n_effective:,}]")

    # GROUP BY over the dictionary column: one spec, one result per category,
    # answered by the factored grouped kernel (shared box terms once per flush)
    gb = engine.execute(AqpQuery("avg", (Range("latency_ms", 0.0, 500.0),),
                                 target="latency_ms", group_by="model_id"))
    print(f"[serve:aqp] AVG(latency_ms) GROUP BY model_id "
          f"[{gb[0].path}]: "
          + ", ".join(f"{r.group:.0f}: {r.estimate:.1f}" for r in gb))


def main() -> None:
    from repro.configs.base import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "aqp"])
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--clients", type=int, default=8,
                    help="concurrent query clients feeding the AqpSession")
    ap.add_argument("--per-client", type=int, default=150,
                    help="queries each client submits (closed loop)")
    ap.add_argument("--watermark", type=int, default=None,
                    help="flush a micro-batch at this many pending queries "
                         "(default: the client count — closed-loop clients "
                         "can never fill a deeper bucket)")
    ap.add_argument("--max-delay-ms", type=float, default=5.0,
                    help="max time a pending query waits before its bucket "
                         "flushes on deadline")
    ap.add_argument("--stream-every-ms", type=float, default=50.0,
                    help="producer cadence for streaming telemetry batches "
                         "(bumps synopsis versions mid-flight)")
    ap.add_argument("--stream-rows", type=int, default=20_000,
                    help="rows per streamed telemetry batch")
    ap.add_argument("--capacity", type=int, default=2048)
    ap.add_argument("--snapshot-dir", default=None,
                    help="write atomic keep-k store snapshots here (enables "
                         "--restore on the next run)")
    ap.add_argument("--snapshot-every", type=int, default=5,
                    help="streamed producer batches between snapshots")
    ap.add_argument("--restore", action="store_true",
                    help="warm-start from the latest snapshot in "
                         "--snapshot-dir instead of re-seeding (reservoirs, "
                         "sketch coverage, and fitted synopses all survive)")
    ap.add_argument("--coarse-frac", type=float, default=0.0,
                    help="fraction of client queries submitted with "
                         "priority='coarse' (answered from the smallest "
                         "reservoir tier: faster, wider intervals)")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="bound the admission queue depth (default: "
                         "unbounded)")
    ap.add_argument("--overflow", default="block", choices=["block", "shed"],
                    help="policy at --max-pending: park the submitter or "
                         "raise AdmissionFull")
    ap.add_argument("--selector", default="plugin",
                    choices=["plugin", "silverman", "lscv_h"])
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--fullh-frac", type=float, default=0.0,
                    help="fraction of box queries carrying a per-query "
                         "selector='lscv_H' override: routed through the "
                         "full-H QMC path and the --kde-backend density "
                         "backend")
    ap.add_argument("--kde-backend", default="auto",
                    choices=["auto", "exact", "rff"],
                    help="density backend for full-H queries: exact KDE, "
                         "the sublinear RFF synopsis, or size-based auto "
                         "crossover (default)")
    ap.add_argument("--metrics-out", default=None,
                    help="enable repro.obs and write a merged JSON metrics "
                         "snapshot here every --metrics-every seconds "
                         "(atomic replace; see docs/observability.md)")
    ap.add_argument("--metrics-every", type=float, default=1.0,
                    help="seconds between --metrics-out snapshots")
    ap.add_argument("--trace-out", default=None,
                    help="append the span ring as JSON lines on exit "
                         "(enables repro.obs)")
    args = ap.parse_args()
    if args.snapshot_every < 1:
        ap.error(f"--snapshot-every must be >= 1, got {args.snapshot_every}")
    if args.metrics_every <= 0:
        ap.error(f"--metrics-every must be > 0, got {args.metrics_every}")
    if not 0.0 <= args.coarse_frac <= 1.0:
        ap.error(f"--coarse-frac must be in [0, 1], got {args.coarse_frac}")
    if not 0.0 <= args.fullh_frac <= 1.0:
        ap.error(f"--fullh-frac must be in [0, 1], got {args.fullh_frac}")

    if args.mode == "aqp":
        run_aqp(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
