"""Batched serving driver: prefill + greedy decode on a smoke config.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --batch 4 --prompt-len 16 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_config
from repro.models import build_model
from repro.train import greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (args.batch, args.prompt_len),
                                0, cfg.vocab_size, jnp.int32)
    t0 = time.time()
    out = greedy_generate(model, params, prompt, args.max_new)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print(out[0].tolist())


if __name__ == "__main__":
    main()
