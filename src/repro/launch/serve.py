"""Serving drivers.

LM mode (default): prefill + greedy decode on a smoke config.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
        --batch 4 --prompt-len 16 --max-new 16

AQP mode: stand up a TelemetryStore over synthetic telemetry columns and
serve a mixed COUNT/SUM/AVG query batch through the batched engine
(core/aqp.py QueryBatch) — one jitted pass per column, synopses cached.
A joint (loss, latency_ms) reservoir additionally serves multi-column box
predicates (eq. 11) through BoxQueryBatch — one jitted pass per column tuple.

    PYTHONPATH=src python -m repro.launch.serve --mode aqp \
        --rows 200000 --queries 2000 --box-queries 256 --selector plugin
"""
from __future__ import annotations

import argparse
import time


def run_lm(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.models import build_model
    from repro.train import greedy_generate

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (args.batch, args.prompt_len),
                                0, cfg.vocab_size, jnp.int32)
    t0 = time.time()
    out = greedy_generate(model, params, prompt, args.max_new)
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    print(out[0].tolist())


def make_query_mix(n_queries: int, ranges, seed: int = 0):
    """Deterministic mixed COUNT/SUM/AVG batch.  `ranges` maps column name
    (or None for a single-synopsis batch) -> (lo, hi) sampling range.  Shared
    by the serving mode, the AQP example, and the batch benchmark."""
    import numpy as np

    from repro.core import Query

    rng = np.random.default_rng(seed)
    columns = list(ranges)
    ops = ["count", "sum", "avg"]
    queries = []
    for i in range(n_queries):
        col = columns[i % len(columns)]
        lo, hi = ranges[col]
        a = float(rng.uniform(lo, hi))
        b = float(rng.uniform(a, hi))
        # independent draw, not i % 3: cycling op and column together would
        # make every column's queries homogeneous when len(ranges) % 3 == 0
        queries.append(Query(ops[int(rng.integers(3))], a, b, column=col))
    return queries


def make_box_query_mix(n_queries: int, columns, ranges, seed: int = 0):
    """Deterministic mixed COUNT/SUM/AVG *box* batch over one column tuple.
    `columns` is the joint tuple; `ranges` maps each column -> (lo, hi)
    sampling range.  SUM/AVG target a random axis.  Shared by the serving
    mode, the AQP example, and the box benchmark."""
    import numpy as np

    from repro.core import BoxQuery

    rng = np.random.default_rng(seed)
    columns = tuple(columns)
    ops = ["count", "sum", "avg"]
    queries = []
    for _ in range(n_queries):
        lo, hi = [], []
        for col in columns:
            c_lo, c_hi = ranges[col]
            a = float(rng.uniform(c_lo, c_hi))
            lo.append(a)
            hi.append(float(rng.uniform(a, c_hi)))
        op = ops[int(rng.integers(3))]
        target = columns[int(rng.integers(len(columns)))] if op != "count" else None
        queries.append(BoxQuery(op, tuple(lo), tuple(hi), columns=columns,
                                target=target))
    return queries


def run_aqp(args) -> None:
    import numpy as np

    from repro.data import TelemetryStore

    rng = np.random.default_rng(0)
    n = args.rows
    telemetry = {
        "loss": rng.gamma(3.0, 0.7, n).astype(np.float32),
        "latency_ms": np.where(rng.random(n) < 0.8, rng.normal(40, 8, n),
                               rng.normal(160, 30, n)).astype(np.float32),
        "seq_len": rng.integers(16, 2048, n).astype(np.float32),
    }
    joint_cols = ("loss", "latency_ms")
    store = TelemetryStore(capacity=args.capacity, seed=0)
    store.track_joint(joint_cols)          # before add_batch: joints sample rows
    store.add_batch(telemetry)

    columns = list(telemetry)
    ranges = {c: (float(v.min()), float(v.max())) for c, v in telemetry.items()}
    queries = make_query_mix(args.queries, ranges, seed=1)

    # Warm-up fits the synopses (cache miss) and compiles the batched pass
    # at the serving batch shape, so the timed run measures steady state.
    store.query_batch(queries, selector=args.selector, backend=args.backend)
    t0 = time.perf_counter()
    answers = store.query_batch(queries, selector=args.selector,
                                backend=args.backend)
    dt = time.perf_counter() - t0

    qps = len(queries) / dt
    cs = store.cache.stats()
    print(f"[serve:aqp] {len(queries)} queries over {len(columns)} columns "
          f"({n:,} rows each) in {dt * 1e3:.1f} ms -> {qps:,.0f} queries/s "
          f"[{args.backend}]")
    print(f"[serve:aqp] synopsis cache: {cs['hits']} hits / {cs['misses']} misses "
          f"({cs['entries']} entries, {cs['bytes']:,} bytes, "
          f"{cs['evictions']} evictions)")
    for q, ans in list(zip(queries, answers))[:6]:
        print(f"  {q.op.upper():5s}({q.column}) in [{q.a:9.2f}, {q.b:9.2f}] "
              f"~= {ans:,.2f}")

    if args.box_queries > 0:
        box_queries = make_box_query_mix(args.box_queries, joint_cols,
                                         ranges, seed=2)
        store.query_box_batch(box_queries, selector=args.selector,
                              backend=args.backend)           # warm-up
        t0 = time.perf_counter()
        box_answers = store.query_box_batch(box_queries, selector=args.selector,
                                            backend=args.backend)
        dt = time.perf_counter() - t0
        print(f"[serve:aqp] {len(box_queries)} box queries over joint "
              f"{joint_cols} in {dt * 1e3:.1f} ms -> "
              f"{len(box_queries) / dt:,.0f} queries/s [{args.backend}]")
        for q, ans in list(zip(box_queries, box_answers))[:4]:
            box = " & ".join(f"{a:.1f}<={c}<={b:.1f}"
                             for c, a, b in zip(q.columns, q.lo, q.hi))
            tgt = f"({q.target})" if q.op != "count" else ""
            print(f"  {q.op.upper():5s}{tgt} WHERE {box} ~= {ans:,.2f}")


def main() -> None:
    from repro.configs.base import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="lm", choices=["lm", "aqp"])
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--rows", type=int, default=200_000)
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--box-queries", type=int, default=256,
                    help="multi-column box predicates served from the joint "
                         "synopsis (0 disables)")
    ap.add_argument("--capacity", type=int, default=2048)
    ap.add_argument("--selector", default="plugin",
                    choices=["plugin", "silverman", "lscv_h"])
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    args = ap.parse_args()

    if args.mode == "aqp":
        run_aqp(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
