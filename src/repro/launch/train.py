"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 50 --ckpt-dir /tmp/run1

Wires together: config -> model -> mesh -> sharded train step -> data pipeline
-> AQP telemetry -> checkpoint manager (atomic/async/keep-k) -> straggler
monitor.  On start it resumes from the latest checkpoint if one exists
(params, optimizer state, data-pipeline cursor), which is the crash-restart
path; `--simulate-failure-at N` exercises it in one process.  Gradient
compression (--compress-grads) demonstrates the int8 error-feedback DP
all-reduce on a shard_map path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager, StragglerMonitor
from repro.configs.base import ARCH_IDS, get_config
from repro.data import TelemetryStore, TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import adamw
from repro.train import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--simulate-failure-at", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                                total_steps=args.steps)

    telemetry = TelemetryStore()
    pipeline = TokenPipeline(cfg.vocab_size, args.batch, args.seq,
                             telemetry=telemetry)

    params = model.init(jax.random.key(0))
    opt_state = adamw.init(params)
    step0 = 0

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt is not None and ckpt.latest_step() is not None:
        latest = ckpt.latest_step()
        (params, opt_state), extra = ckpt.restore(latest, (params, opt_state))
        pipeline.restore(extra["pipeline"])
        step0 = extra["step"]
        print(f"[train] resumed from step {step0}")

    train_step = jax.jit(make_train_step(model, opt_cfg, args.n_micro),
                         donate_argnums=(0, 1))
    monitor = StragglerMonitor()

    for step in range(step0, args.steps):
        t0 = time.time()
        batch = pipeline.next()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        if args.simulate_failure_at == step:
            print(f"[train] simulated failure at step {step}; restart to resume")
            raise SystemExit(42)
        dt = time.time() - t0
        monitor.record(host=0, step_time=dt)
        telemetry.add_batch({"loss": np.asarray([float(metrics["loss"])], np.float32)})
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step={step} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} dt={dt*1e3:.0f}ms", flush=True)
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt_state),
                      {"step": step + 1, "pipeline": pipeline.state()})
    if ckpt is not None:
        ckpt.save(args.steps, (params, opt_state),
                  {"step": args.steps, "pipeline": pipeline.state()})
        ckpt.wait()

    # AQP over training telemetry (the paper's technique in the loop):
    if "loss" in telemetry.columns and telemetry.columns["loss"].n_seen >= 8:
        lo = float(np.min(telemetry.columns["loss"].sample()))
        hi = float(np.max(telemetry.columns["loss"].sample()))
        frac = telemetry.fraction("loss", lo, (lo + hi) / 2, selector="silverman")
        print(f"[aqp] fraction of steps with loss in lower half-range: {frac:.3f}")
    print("[train] done")


if __name__ == "__main__":
    main()
