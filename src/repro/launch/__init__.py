from .mesh import (dp_axes, make_host_mesh, make_mesh_from_spec,
                   make_production_mesh, tp_size)
