"""Elastic scaling + straggler mitigation controller.

On real fleets this sits next to the cluster manager; everything here is the
deterministic decision logic, unit-tested on CPU:

* `plan_mesh(n_devices, ...)` — given the surviving device count, choose the
  largest (data, model) grid (model axis must divide the TP-shardable dims)
  and report how many devices idle.  After a failure the driver: (1) stops,
  (2) re-plans the mesh, (3) reshard-restores the latest checkpoint
  (checkpoint.restore with the new mesh's shardings), (4) resumes the data
  pipeline from its persisted cursor.  End-to-end simulated in
  tests/test_elastic.py.
* `StragglerMonitor` — per-host EWMA of step times; hosts slower than
  mean + k*sigma for `patience` consecutive steps are flagged for eviction
  (the driver treats eviction like a failure: re-plan without that host).
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Dict, List, Optional, Tuple


def plan_mesh(n_devices: int, tp_max: int = 16,
              tp_divisor_of: Tuple[int, ...] = ()) -> Tuple[int, int]:
    """Largest (data, model) grid with data*model <= n_devices.

    Prefers the biggest power-of-two model axis <= tp_max that divides every
    dim in `tp_divisor_of` (e.g. n_kv_heads*head_dim, d_ff), then fills data.
    """
    tp = 1
    cand = 1
    while cand <= min(tp_max, n_devices):
        if all(d % cand == 0 for d in tp_divisor_of):
            tp = cand
        cand *= 2
    data = n_devices // tp
    return data, tp


@dataclasses.dataclass
class HostStat:
    ewma: float = 0.0
    var: float = 0.0
    n: int = 0
    strikes: int = 0


class StragglerMonitor:
    def __init__(self, alpha: float = 0.2, k_sigma: float = 3.0, patience: int = 5):
        self.alpha = alpha
        self.k_sigma = k_sigma
        self.patience = patience
        self.hosts: Dict[int, HostStat] = defaultdict(HostStat)

    def record(self, host: int, step_time: float) -> None:
        st = self.hosts[host]
        if st.n == 0:
            st.ewma = step_time
        else:
            delta = step_time - st.ewma
            st.ewma += self.alpha * delta
            st.var = (1 - self.alpha) * (st.var + self.alpha * delta * delta)
        st.n += 1

    def _fleet_stats(self) -> Tuple[float, float]:
        ew = [s.ewma for s in self.hosts.values() if s.n > 0]
        mu = sum(ew) / len(ew)
        var = sum((e - mu) ** 2 for e in ew) / max(len(ew) - 1, 1)
        return mu, math.sqrt(var)

    def update_strikes(self) -> None:
        mu, sigma = self._fleet_stats()
        thresh = mu + self.k_sigma * max(sigma, 1e-9) + 1e-9
        for st in self.hosts.values():
            if st.n > 0 and st.ewma > thresh:
                st.strikes += 1
            else:
                st.strikes = 0

    def stragglers(self) -> List[int]:
        self.update_strikes()
        return [h for h, st in self.hosts.items() if st.strikes >= self.patience]


@dataclasses.dataclass
class FailureEvent:
    step: int
    lost_hosts: Tuple[int, ...]


class ElasticController:
    """Glue: tracks alive hosts, plans meshes, logs decisions."""

    def __init__(self, n_hosts: int, devices_per_host: int, tp_divisor_of=()):
        self.alive = set(range(n_hosts))
        self.devices_per_host = devices_per_host
        self.tp_divisor_of = tuple(tp_divisor_of)
        self.events: List[FailureEvent] = []

    def fail(self, step: int, hosts) -> Tuple[int, int]:
        self.alive -= set(hosts)
        self.events.append(FailureEvent(step, tuple(hosts)))
        return self.current_mesh()

    def current_mesh(self) -> Tuple[int, int]:
        return plan_mesh(len(self.alive) * self.devices_per_host,
                         tp_divisor_of=self.tp_divisor_of)
