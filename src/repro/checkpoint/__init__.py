from .checkpoint import CheckpointManager
from .elastic import ElasticController, StragglerMonitor, plan_mesh
