"""Fault-tolerant checkpointing: atomic, async, keep-k, mesh-agnostic restore.

Format: one .npz of flattened leaves (keyed by tree path) + a JSON manifest
(step, extra state, leaf dtypes).  Writes go to `<dir>/tmp.<step>` and are
`os.replace`d into `<dir>/step_<step>` — a crash mid-write never corrupts the
latest checkpoint, and `latest_step()` only sees completed renames.

Restore is *mesh-shape-agnostic*: leaves come back as host numpy and are
`device_put` with the target sharding pytree — the elastic-rescale path
(checkpoint saved on mesh A, restored on mesh B) is tested in
tests/test_checkpoint.py.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import numpy as np


_BF16 = np.dtype("bfloat16") if hasattr(np, "bfloat16") else None
try:
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover
    pass


def _flatten(tree) -> Dict[str, np.ndarray]:
    """Flatten to npz-storable arrays.  bfloat16 has no numpy cast support,
    so it is stored losslessly as a uint16 bit view under a '.bf16' key."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if _BF16 is not None and arr.dtype == _BF16:
            flat[key + ".bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(template)
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in leaves_with_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key + ".bf16" in flat:
            arr = flat[key + ".bf16"].view(_BF16)
        else:
            arr = flat[key]
        want_dtype = getattr(leaf, "dtype", None)
        if want_dtype is not None and arr.dtype != want_dtype:
            arr = arr.astype(want_dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool: Optional[ThreadPoolExecutor] = (
            ThreadPoolExecutor(max_workers=1) if async_save else None)
        self._pending: Optional[Future] = None

    # -- write ---------------------------------------------------------------
    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict) -> None:
        tmp = os.path.join(self.dir, f"tmp.{step}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "extra": extra}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        """Snapshot to host memory synchronously, write asynchronously."""
        self.wait()                                  # one outstanding write max
        flat = _flatten(jax.tree.map(lambda x: np.asarray(x), tree))
        extra = extra or {}
        if self._pool is None:
            self._write(step, flat, extra)
        else:
            self._pending = self._pool.submit(self._write, step, flat, extra)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore_flat(self, step: int):
        """Load one checkpoint as (flat {path-key: np.ndarray}, extra) with
        no template — for state that is naturally a flat keyed dict rather
        than a model pytree (e.g. `TelemetryStore.save`'s durable-AQP
        snapshots).  bf16-view keys are folded back to bfloat16."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        for key in [k for k in flat if k.endswith(".bf16")]:
            flat[key[: -len(".bf16")]] = flat.pop(key).view(_BF16)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return flat, manifest["extra"]

    def restore(self, step: int, template: Any, shardings: Any = None):
        """Load leaves and (re)shard onto the current mesh.

        `template` supplies the pytree structure/dtypes (params from a fresh
        abstract init).  `shardings` (optional pytree of NamedSharding) places
        each leaf — pass shardings built from the *new* mesh to elastically
        restore onto different hardware.
        """
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree, manifest["extra"]
