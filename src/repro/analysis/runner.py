"""Checker orchestration: run, apply pragmas, summarise.

`run_all` / `run` return a result dict per checker::

    {"lock-discipline": {"violations": [...], "allowed": [...]}, ...}

plus a synthetic ``pragma`` entry for malformed/unknown allow-pragmas —
a reason-less pragma is itself a finding, never a suppression.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, Iterable, List, Optional

from . import (host_sync, instrument_drift, kernel_contract, knob_registry,
               lock_discipline)
from .base import (Project, Violation, apply_pragmas, bare_pragma_violations)

CHECKERS = {
    lock_discipline.CHECK: lock_discipline.check,
    kernel_contract.CHECK: kernel_contract.check,
    host_sync.CHECK: host_sync.check,
    knob_registry.CHECK: knob_registry.check,
    instrument_drift.CHECK: instrument_drift.check,
}

DEFAULT_ROOTS = ("src", "scripts", "benchmarks")


def run(project: Project,
        select: Optional[Iterable[str]] = None) -> Dict[str, dict]:
    ids = list(select) if select else list(CHECKERS)
    unknown = [i for i in ids if i not in CHECKERS]
    if unknown:
        raise KeyError(f"unknown checker(s) {unknown}; "
                       f"have {sorted(CHECKERS)}")
    results: Dict[str, dict] = {}
    for check_id in ids:
        raw = CHECKERS[check_id](project)
        unallowed, allowed = apply_pragmas(project, raw)
        results[check_id] = {"violations": unallowed, "allowed": allowed}
    results["pragma"] = {
        "violations": bare_pragma_violations(project, CHECKERS),
        "allowed": [],
    }
    return results


def run_all(root: Path,
            roots: Iterable[str] = DEFAULT_ROOTS,
            select: Optional[Iterable[str]] = None) -> Dict[str, dict]:
    return run(Project(Path(root), roots), select)


def total_unallowed(results: Dict[str, dict]) -> List[Violation]:
    out: List[Violation] = []
    for res in results.values():
        out.extend(res["violations"])
    return out
