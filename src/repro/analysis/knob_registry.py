"""knob-registry: every ``REPRO_*`` env knob is registered, read through
``repro.knobs``, and documented.

Three failure modes this kills:

  * a raw ``os.environ.get("REPRO_X")`` at a call site — two sites can
    silently fork on defaults, and nothing documents the knob.  All reads
    go through the typed accessors in ``repro.knobs`` (the one audited raw
    read lives there); *writes* (``os.environ["REPRO_X"] = ...``) stay
    legal — CLIs set knobs for child code on purpose.
  * a ``REPRO_*`` name referenced in src/scripts/benchmarks that the
    registry does not know — a typo'd knob reads as "unset" forever.
  * registry/docs drift — every registered knob must appear in the
    ``docs/analysis.md`` knob table and vice versa, and a knob nothing
    references is dead weight.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from .base import Project, Violation, attr_chain, str_const

CHECK = "knob-registry"

KNOBS_REL = "src/repro/knobs.py"
DOCS_REL = "docs/analysis.md"
NAME_RE = re.compile(r"\bREPRO_[A-Z0-9_]+\b")


def _env_read(node: ast.AST) -> Optional[Tuple[str, int]]:
    """(knob_name, line) when `node` reads a REPRO_* env var directly."""
    if isinstance(node, ast.Call):
        chain = attr_chain(node.func)
        if chain.endswith("environ.get") or chain.endswith("os.getenv") \
                or chain == "getenv":
            name = str_const(node.args[0]) if node.args else None
            if name and name.startswith("REPRO_"):
                return name, node.lineno
    if isinstance(node, ast.Subscript) and isinstance(node.ctx, ast.Load):
        if attr_chain(node.value).endswith("environ"):
            name = str_const(node.slice)
            if name and name.startswith("REPRO_"):
                return name, node.lineno
    return None


def _registered(project: Project) -> Dict[str, int]:
    """KNOB name -> registration line, parsed from knobs.py (static — the
    checker must not depend on importing the code under analysis)."""
    sf = project.get(KNOBS_REL)
    if sf is None:
        return {}
    out: Dict[str, int] = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call) and \
                attr_chain(node.func).endswith("register"):
            name = str_const(node.args[0]) if node.args else None
            if name:
                out[name] = node.lineno
    return out


def _doc_names(project: Project, docs_rel: str) -> Set[str]:
    path = project.root / docs_rel
    if not path.is_file():
        return set()
    return set(NAME_RE.findall(path.read_text(encoding="utf-8")))


def check(project: Project, registry: Optional[Dict[str, int]] = None,
          docs_rel: str = DOCS_REL) -> List[Violation]:
    out: List[Violation] = []
    registered = _registered(project) if registry is None else registry

    referenced: Dict[str, Tuple[str, int]] = {}
    for sf in project.files():
        is_registry = sf.rel == KNOBS_REL
        # the analysis package's own docstrings name placeholder knobs
        is_meta = sf.rel.startswith("src/repro/analysis/")
        for node in ast.walk(sf.tree):
            read = _env_read(node)
            if read and not is_registry:
                name, line = read
                out.append(Violation(
                    CHECK, sf.rel, line,
                    f"raw environ read of {name} — go through repro.knobs "
                    f"(get_int/get_bool/get_str) so defaults cannot fork"))
            if is_registry or is_meta:
                continue
            if isinstance(node, ast.Constant) and \
                    isinstance(node.value, str):
                for name in NAME_RE.findall(node.value):
                    referenced.setdefault(name, (sf.rel, node.lineno))
                    if name not in registered:
                        out.append(Violation(
                            CHECK, sf.rel, node.lineno,
                            f"{name} is not registered in repro/knobs.py — "
                            f"a typo'd knob reads as unset forever"))

    docs = _doc_names(project, docs_rel)
    for name, line in sorted(registered.items()):
        if docs and name not in docs:
            out.append(Violation(
                CHECK, KNOBS_REL, line,
                f"{name} is registered but missing from the {docs_rel} "
                f"knob table"))
        if name not in referenced:
            out.append(Violation(
                CHECK, KNOBS_REL, line,
                f"{name} is registered but nothing reads it — dead knob"))
    if docs:
        for name in sorted(docs - set(registered)):
            out.append(Violation(
                CHECK, docs_rel, 1,
                f"{name} appears in {docs_rel} but is not registered in "
                f"repro/knobs.py"))
    return out
