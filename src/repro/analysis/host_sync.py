"""host-sync: no hidden device synchronisation on hot paths.

jax dispatch is async — the engine's throughput comes from keeping the
device queue full.  Any of ``.item()``, ``float(tracer)``,
``np.asarray(...)`` or ``block_until_ready`` forces the host to wait for
the device, which is invisible in the source and brutal in a profile.  The
rules, scoped to the engine/admission/kernel hot modules:

  * ``.item()`` — never; pull scalars out with ``np.asarray`` ONCE at the
    API boundary, not per-value.
  * ``block_until_ready`` — only inside ``repro.obs.fence()`` (which is
    gated on ``obs.enabled()`` so production dispatch stays async).
  * inside jit-compiled functions and Pallas kernel bodies:
    ``float()``/``int()``/``np.asarray``/``np.array`` — these either sync
    a tracer or fail at trace time; both are bugs.
  * ``jax.jit`` called inside a function body — a fresh jit wrapper per
    call defeats the compile cache (unhashable/unbounded cache keys);
    jit belongs at module scope or behind an explicit cache.
  * a For/While loop whose body both dispatches a kernel and converts the
    result to host (``float``/``np.asarray``) — a per-iteration sync
    barrier; batch the dispatches, convert once after the loop.

Cold paths (summaries, export, CLIs) are out of scope; genuinely needed
syncs on a hot path carry ``# repro: allow[host-sync] <why>``.
"""
from __future__ import annotations

import ast
from typing import List

from .base import Project, Violation, attr_chain, call_leaf

CHECK = "host-sync"

HOT = (
    "src/repro/kernels/",
    "src/repro/core/aqp.py",
    "src/repro/core/aqp_query.py",
    "src/repro/core/aqp_admission.py",
    "src/repro/core/aqp_multid.py",
    "src/repro/core/aqp_ci.py",
    "src/repro/data/aqp_store.py",
)

CONVERTERS = {"float", "int"}
NP_CONVERTERS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                 "jax.device_get"}
DISPATCHERS = {
    "batch_query", "batch_query_boxes", "batch_query_grouped",
    "kde_eval", "aqp_batch_sums", "aqp_box_sums", "aqp_grouped_sums",
    "qmc_box_reduce", "rff_density", "lscv_grid_sums", "gh_fused_sum",
    "sv_matrix", "pairwise_scaled_ksum",
}


def _is_hot(rel: str) -> bool:
    return any(rel == h or (h.endswith("/") and rel.startswith(h))
               for h in HOT)


def _is_jitted(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        chain = attr_chain(dec if not isinstance(dec, ast.Call) else dec.func)
        if "jit" in chain.split("."):
            return True
        if isinstance(dec, ast.Call):  # functools.partial(jax.jit, ...)
            for arg in dec.args:
                if "jit" in attr_chain(arg).split("."):
                    return True
    return False


def _is_kernel_body(fn: ast.FunctionDef) -> bool:
    params = fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs
    return any(a.arg.endswith("_ref") for a in params)


def check(project: Project) -> List[Violation]:
    out: List[Violation] = []
    for sf in project.files("src/"):
        if not _is_hot(sf.rel):
            continue

        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                if (call_leaf(node) == "item"
                        and isinstance(node.func, ast.Attribute)
                        and not node.args):
                    out.append(Violation(
                        CHECK, sf.rel, node.lineno,
                        ".item() synchronises the device per scalar — "
                        "convert once at the API boundary"))
            if (isinstance(node, ast.Attribute)
                    and node.attr == "block_until_ready"):
                out.append(Violation(
                    CHECK, sf.rel, node.lineno,
                    "block_until_ready outside obs.fence() — fencing must "
                    "stay gated on obs.enabled()"))

        for fn in [n for n in ast.walk(sf.tree)
                   if isinstance(n, ast.FunctionDef)]:
            # jax.jit inside a function body: new wrapper (and compile
            # cache) per call
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    chain = attr_chain(node.func)
                    if chain in ("jax.jit", "jax.pmap"):
                        out.append(Violation(
                            CHECK, sf.rel, node.lineno,
                            f"{chain}() inside {fn.name}() — per-call jit "
                            f"wrappers defeat the compile cache; hoist to "
                            f"module scope or an explicit cache"))

            if _is_jitted(fn) or _is_kernel_body(fn):
                for node in ast.walk(fn):
                    if not isinstance(node, ast.Call):
                        continue
                    chain = attr_chain(node.func)
                    if (chain in CONVERTERS or chain in NP_CONVERTERS):
                        out.append(Violation(
                            CHECK, sf.rel, node.lineno,
                            f"{chain}() inside traced function {fn.name}() "
                            f"— syncs a tracer to host (or fails to trace)"))

        # per-iteration sync: loop body that dispatches AND converts
        for loop in [n for n in ast.walk(sf.tree)
                     if isinstance(n, (ast.For, ast.While))]:
            dispatches = converts = None
            for node in ast.walk(loop):
                if not isinstance(node, ast.Call):
                    continue
                chain = attr_chain(node.func)
                leaf = call_leaf(node)
                if leaf in DISPATCHERS:
                    dispatches = node
                if chain in CONVERTERS or chain in NP_CONVERTERS:
                    converts = node
            if dispatches is not None and converts is not None:
                out.append(Violation(
                    CHECK, sf.rel, converts.lineno,
                    f"loop at line {loop.lineno} dispatches a kernel and "
                    f"converts to host every iteration — batch the "
                    f"dispatches, convert once after the loop"))
    return out
